//! Multi-start beam search over placement candidates.
//!
//! The driver is a plain local search: each start (compact, scatter, then
//! fixed-seed random placements) keeps a beam of incumbents, scores the
//! whole neighborhood of the beam as one batch through
//! [`crate::parallel::par_map`], and advances while the best neighbor
//! *strictly* improves on the start's best. Strict improvement plus a
//! global scoring budget guarantees termination.
//!
//! Determinism: candidate enumeration order is fixed
//! ([`SearchSpace::neighbors`]), `par_map` returns results in input
//! order, delta evaluation is bit-identical to the full solve, and score
//! ties break on the candidate encoding ([`Candidate`]'s derived `Ord`).
//! So the incumbent trace is a pure function of `(space, config)` — the
//! same with or without threads, delta evaluation, or the memo
//! (property-tested in `tests/optimizer_conformance.rs`).
//!
//! Objectives score from the analytic model's per-core rates; `makespan`
//! additionally co-simulates the finalists (best candidate per start)
//! with [`crate::timeline::simulate_placed`] and picks the winner by
//! simulated time. The in-search makespan surrogate is the bandwidth-only
//! bound `max_g volume / rate_g`; the finalist co-simulation adds
//! desynchronization and per-domain contention dynamics on top.

use std::time::Instant;

use crate::desync::{CoSimConfig, Phase, Program, SimStats, SyncKind};
use crate::error::Result;
use crate::kernels::KernelId;
use crate::parallel::par_map;
use crate::sharing::{share_remote, RemoteShare};
use crate::simulator::XorShift64;
use crate::timeline::simulate_placed;
use crate::topology::{RankLayout, RemoteTraffic};

use super::delta::{DeltaEval, DeltaStats};
use super::memo::ShardedScoreMemo;
use super::space::{Candidate, SearchSpace};

/// What the search maximizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Aggregate model bandwidth, `Σ n_g · rate_g` (GB/s).
    Throughput,
    /// Negative bandwidth-bound completion time of the slowest group,
    /// `-max_g volume / rate_g`; finalists are re-ranked by a real
    /// [`simulate_placed`] co-simulation.
    Makespan,
    /// Worst normalized per-group progress, `min_g rate_g / (f_g · b_s,g)`
    /// — maximizing it minimizes the worst interference slowdown.
    MaxInterference,
}

impl Objective {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Objective> {
        match s.to_ascii_lowercase().as_str() {
            "throughput" | "tput" => Ok(Objective::Throughput),
            "makespan" => Ok(Objective::Makespan),
            "max-interference" | "interference" => Ok(Objective::MaxInterference),
            other => Err(crate::error::Error::InvalidPlan(format!(
                "unknown objective '{other}' (throughput, makespan, max-interference)"
            ))),
        }
    }

    /// CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Objective::Throughput => "throughput",
            Objective::Makespan => "makespan",
            Objective::MaxInterference => "max-interference",
        }
    }

    /// Score a candidate from the model's per-core rates (higher wins).
    fn score(self, space: &SearchSpace, gb_per_core: f64, rates: &[f64]) -> f64 {
        match self {
            Objective::Throughput => {
                space.groups.iter().zip(rates).map(|(g, r)| g.n as f64 * r).sum()
            }
            Objective::Makespan => {
                let worst = space
                    .groups
                    .iter()
                    .zip(rates)
                    .map(|(_, r)| gb_per_core / r.max(f64::MIN_POSITIVE))
                    .fold(0.0f64, f64::max);
                -worst
            }
            Objective::MaxInterference => space
                .groups
                .iter()
                .zip(rates)
                .map(|(g, r)| r / (g.f * g.bs_gbs))
                .fold(f64::INFINITY, f64::min),
        }
    }
}

/// Tuning knobs of one search run.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// What to maximize.
    pub objective: Objective,
    /// Seed of the random starts (fixed seed ⇒ identical trace).
    pub seed: u64,
    /// Number of starts: compact, scatter, then `starts - 2` random.
    pub starts: usize,
    /// Beam width (1 = greedy hill climbing).
    pub beam: usize,
    /// Total scoring budget across all starts (candidates scored).
    pub budget: usize,
    /// Per-core data volume, GB — the time unit of the makespan
    /// objective and the finalist co-simulation.
    pub gb_per_core: f64,
    /// Score candidate batches through [`par_map`] (off = serial).
    pub parallel: bool,
    /// Score moves incrementally with [`DeltaEval`] (off = every
    /// candidate is a full [`share_remote`] re-solve).
    pub use_delta: bool,
    /// Memoize candidate scores in a [`ShardedScoreMemo`].
    pub memoize: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            objective: Objective::Throughput,
            seed: 42,
            starts: 4,
            beam: 2,
            budget: 2000,
            gb_per_core: 8.0,
            parallel: true,
            use_delta: true,
            memoize: true,
        }
    }
}

/// One improvement of the global best during the search.
#[derive(Debug, Clone)]
pub struct TraceStep {
    /// Candidates scored (across the whole search) when this incumbent
    /// took the lead.
    pub scored_at: u64,
    /// Start index it came from.
    pub start: usize,
    /// Beam step within the start (0 = the start candidate itself).
    pub step: usize,
    /// Its score.
    pub score: f64,
    /// Mix-DSL-style label of the candidate.
    pub label: String,
    /// The candidate.
    pub candidate: Candidate,
}

/// Result of one search run.
#[derive(Debug, Clone)]
pub struct OptResult {
    /// Winning candidate.
    pub best: Candidate,
    /// Its mix-DSL-style label.
    pub best_label: String,
    /// Its score under the configured objective.
    pub best_score: f64,
    /// Its per-core model rates, GB/s, in group order.
    pub best_rates: Vec<f64>,
    /// The full sharing solution of the winner (per-domain and per-link
    /// interface summaries for the report).
    pub share: RemoteShare,
    /// Incumbent improvements, in order.
    pub trace: Vec<TraceStep>,
    /// Candidates scored (memo hits included) — the throughput
    /// numerator of the bench.
    pub scored: u64,
    /// Candidates actually evaluated against the model (memo misses).
    pub evaluated: u64,
    /// Delta-evaluator counters, merged across the search.
    pub delta: DeltaStats,
    /// Cache counters (`memo_*` filled from the score memo; the co-sim
    /// fields come from the finalist simulation when one ran).
    pub stats: SimStats,
    /// Wall-clock spent searching, seconds.
    pub wall_s: f64,
    /// Simulated makespan of the winner, seconds (makespan objective
    /// only).
    pub makespan_s: Option<f64>,
}

/// One beam slot: a scored candidate plus (when delta evaluation is on)
/// its solved incumbent state.
struct Node {
    cand: Candidate,
    score: f64,
    de: Option<DeltaEval>,
}

/// Score one candidate from scratch (the no-delta path).
fn full_rates(space: &SearchSpace, cand: &Candidate) -> Result<Vec<f64>> {
    Ok(share_remote(&space.shape, &space.remote_groups(cand))?.per_core_gbs)
}

/// Run the search. See the module docs for the guarantees.
pub fn optimize(space: &SearchSpace, cfg: &SearchConfig) -> Result<OptResult> {
    optimize_with_memo(space, cfg, &ShardedScoreMemo::new(), 0)
}

/// [`optimize`] against a caller-owned score memo under namespace `ns`
/// (use [`SearchSpace::fingerprint`] when the memo outlives one space).
///
/// This is the cross-request entry point of the `repro serve` service:
/// one process-wide memo stays warm across admissions. Sharing is exact —
/// a candidate's score is a pure function of `(space, candidate)` (delta
/// evaluation is bit-identical to the full solve), so pre-warmed entries
/// change only the `evaluated` / cache counters, never the incumbent
/// trace or the winner. The returned `stats.memo_*` counters read the
/// *shared* memo, i.e. they are cumulative across every search that used
/// it.
pub fn optimize_with_memo(
    space: &SearchSpace,
    cfg: &SearchConfig,
    memo: &ShardedScoreMemo,
    ns: u64,
) -> Result<OptResult> {
    let t0 = Instant::now();
    let mut rng = XorShift64::new(cfg.seed);
    let mut scored: u64 = 0;
    let mut evaluated: u64 = 0;
    let mut delta = DeltaStats::default();
    let mut trace: Vec<TraceStep> = Vec::new();
    let mut global_best: Option<(f64, Candidate, Vec<f64>)> = None;

    let n_ifaces = (space.shape.n_domains() + space.shape.links().len()) as u64;
    let starts = cfg.starts.max(1);
    let budget = cfg.budget.max(1);

    for start in 0..starts {
        if scored >= budget as u64 {
            break;
        }
        let start_cand = match start {
            0 => space.start_compact()?,
            1 => space.start_scatter()?,
            _ => space.start_random(&mut rng)?,
        };

        // Score the start itself (always a real evaluation so the beam
        // has an incumbent state to delta against).
        let de = if cfg.use_delta {
            Some(DeltaEval::new(space.shape.clone(), space.remote_groups(&start_cand))?)
        } else {
            None
        };
        let rates = match &de {
            Some(de) => de.rates().to_vec(),
            None => full_rates(space, &start_cand)?,
        };
        let start_score = cfg.objective.score(space, cfg.gb_per_core, &rates);
        scored += 1;
        evaluated += 1;
        delta.evals += 1;
        delta.iface_evals += n_ifaces;
        if cfg.memoize {
            memo.insert_ns(ns, &start_cand, start_score);
        }
        let mut local_best = start_score;
        if global_best.as_ref().is_none_or(|(s, _, _)| start_score > *s) {
            global_best = Some((start_score, start_cand.clone(), rates.clone()));
            trace.push(TraceStep {
                scored_at: scored,
                start,
                step: 0,
                score: start_score,
                label: space.label(&start_cand),
                candidate: start_cand.clone(),
            });
        }
        let mut frontier: Vec<Node> = vec![Node { cand: start_cand, score: start_score, de }];

        for step in 1.. {
            if scored >= budget as u64 {
                break;
            }
            // The batch: every neighbor of every beam slot, deduped,
            // tagged with the slot it deltas against.
            let mut batch: Vec<(Candidate, usize)> = Vec::new();
            for (pi, node) in frontier.iter().enumerate() {
                for mv in space.neighbors(&node.cand) {
                    batch.push((node.cand.apply(mv), pi));
                }
            }
            batch.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
            batch.dedup_by(|a, b| a.0 == b.0);
            batch.retain(|(c, _)| frontier.iter().all(|n| n.cand != *c));
            let room = (budget as u64 - scored) as usize;
            batch.truncate(room);
            if batch.is_empty() {
                break;
            }

            // Score the batch: memo probe, then delta against the parent
            // slot (or a full re-solve). Returns per-candidate counters;
            // merging stays on this thread so no atomics are needed.
            let score_one = |item: &(Candidate, usize)| -> Result<(f64, DeltaStats, bool)> {
                let (cand, pi) = item;
                if cfg.memoize {
                    if let Some(s) = memo.lookup_ns(ns, cand) {
                        return Ok((s, DeltaStats::default(), false));
                    }
                }
                let (rates, stats) = match &frontier[*pi].de {
                    Some(de) => {
                        let outcome = de.eval(&space.changes(&frontier[*pi].cand, cand))?;
                        (outcome.rates, outcome.stats)
                    }
                    None => {
                        let rates = full_rates(space, cand)?;
                        (
                            rates,
                            DeltaStats {
                                evals: 1,
                                iface_evals: n_ifaces,
                                full_solves: 1,
                                ..DeltaStats::default()
                            },
                        )
                    }
                };
                let s = cfg.objective.score(space, cfg.gb_per_core, &rates);
                if cfg.memoize {
                    memo.insert_ns(ns, cand, s);
                }
                Ok((s, stats, true))
            };
            let results: Vec<Result<(f64, DeltaStats, bool)>> = if cfg.parallel {
                par_map(&batch, score_one)
            } else {
                batch.iter().map(score_one).collect()
            };

            let mut wave: Vec<(f64, usize)> = Vec::with_capacity(batch.len());
            for (bi, r) in results.into_iter().enumerate() {
                let (s, st, was_eval) = r?;
                scored += 1;
                if was_eval {
                    evaluated += 1;
                }
                delta.merge(st);
                wave.push((s, bi));
            }
            // Best first; ties break on the candidate encoding so the
            // ranking is independent of scoring order.
            wave.sort_by(|a, b| {
                b.0.total_cmp(&a.0).then_with(|| batch[a.1].0.cmp(&batch[b.1].0))
            });

            let top_score = wave[0].0;
            if top_score <= local_best {
                break;
            }
            local_best = top_score;

            // Promote the beam: re-evaluate each survivor against its
            // parent slot and commit, giving it its own incumbent state.
            let mut next: Vec<Node> = Vec::with_capacity(cfg.beam.max(1));
            for &(s, bi) in wave.iter().take(cfg.beam.max(1)) {
                let (cand, pi) = &batch[bi];
                let de = match &frontier[*pi].de {
                    Some(parent) => {
                        let mut de = parent.clone();
                        let outcome = de.eval(&space.changes(&frontier[*pi].cand, cand))?;
                        de.commit(outcome);
                        Some(de)
                    }
                    None => None,
                };
                next.push(Node { cand: cand.clone(), score: s, de });
            }

            if global_best.as_ref().is_none_or(|(s, _, _)| top_score > *s) {
                let winner = &next[0];
                let rates = match &winner.de {
                    Some(de) => de.rates().to_vec(),
                    None => full_rates(space, &winner.cand)?,
                };
                global_best = Some((top_score, winner.cand.clone(), rates));
                trace.push(TraceStep {
                    scored_at: scored,
                    start,
                    step,
                    score: top_score,
                    label: space.label(&winner.cand),
                    candidate: winner.cand.clone(),
                });
            }
            frontier = next;
        }
    }

    let (mut best_score, mut best, mut best_rates) =
        global_best.expect("at least one start was scored");

    // Makespan finalists: re-rank the surrogate's favorites with a real
    // co-simulation of the winning placements.
    let mut makespan_s = None;
    let mut sim_stats = SimStats::default();
    if cfg.objective == Objective::Makespan {
        let mut finalists: Vec<Candidate> =
            trace.iter().rev().map(|t| t.candidate.clone()).collect();
        finalists.dedup();
        finalists.truncate(4);
        let mut ranked: Option<(f64, Candidate)> = None;
        for cand in &finalists {
            let (m, st) = simulate_makespan(space, cand, cfg.gb_per_core);
            if ranked.as_ref().is_none_or(|(best_m, _)| m < *best_m) {
                ranked = Some((m, cand.clone()));
                sim_stats = st;
            }
        }
        if let Some((m, cand)) = ranked {
            if cand != best {
                best_rates = full_rates(space, &cand)?;
                best_score = cfg.objective.score(space, cfg.gb_per_core, &best_rates);
                best = cand;
            }
            makespan_s = Some(m);
        }
    }

    let share = share_remote(&space.shape, &space.remote_groups(&best))?;
    let (memo_hits, memo_misses, memo_entries) = memo.stats();
    sim_stats.memo_hits = memo_hits;
    sim_stats.memo_misses = memo_misses;
    sim_stats.memo_entries = memo_entries;

    Ok(OptResult {
        best_label: space.label(&best),
        best,
        best_score,
        best_rates,
        share,
        trace,
        scored,
        evaluated,
        delta,
        stats: sim_stats,
        wall_s: t0.elapsed().as_secs_f64(),
        makespan_s,
    })
}

/// Build the finalist co-simulation inputs for one candidate: every
/// group's ranks on its home domain, one kernel phase per group (all
/// ranks run all phases — the co-simulation measures how the *placement*
/// bears the program, not per-group heterogeneity), remote fractions
/// averaged per home domain weighted by resident cores.
///
/// Shared between the in-search finalist simulation and the `repro serve`
/// makespan probe so both simulate byte-identical setups. Returns
/// `(program, layout, chars, n_ranks)`.
pub(crate) fn makespan_setup(
    space: &SearchSpace,
    cand: &Candidate,
    gb_per_core: f64,
) -> (Program, RankLayout, Vec<(KernelId, f64, f64)>, usize) {
    let nd = space.shape.n_domains();
    let mut rank_domain = Vec::new();
    let mut frac_num = vec![0.0f64; nd];
    let mut frac_den = vec![0.0f64; nd];
    for (gi, g) in space.groups.iter().enumerate() {
        let d = cand.home[gi] as usize;
        rank_domain.extend(std::iter::repeat_n(d, g.n));
        frac_num[d] += g.n as f64 * cand.remote_ppm[gi] as f64 / 1e6;
        frac_den[d] += g.n as f64;
    }
    let frac: Vec<f64> =
        frac_num.iter().zip(&frac_den).map(|(n, d)| if *d > 0.0 { n / d } else { 0.0 }).collect();
    let remote =
        if frac.iter().any(|&f| f > 0.0) { Some(RemoteTraffic { frac }) } else { None };
    let n_ranks = rank_domain.len();
    let layout = RankLayout {
        n_domains: nd,
        rank_domain,
        bw_scale: space.shape.bw_scale.clone(),
        socket_of: space.shape.socket_of.clone(),
        node_of: space.node_of.clone(),
        link_bw_gbs: space.shape.link_bw_gbs,
        link_bw_rev_gbs: space.shape.link_bw_rev_gbs,
        collective_extra_s: space.collective_extra_s,
        remote,
    };
    let mut chars: Vec<(KernelId, f64, f64)> = Vec::new();
    let mut phases = Vec::new();
    for g in &space.groups {
        if !chars.iter().any(|(k, _, _)| *k == g.kernel) {
            chars.push((g.kernel, g.f, g.bs_gbs));
        }
        phases.push(Phase::Kernel {
            kernel: g.kernel,
            volume_bytes: gb_per_core * 1e9,
            sync: SyncKind::None,
            label: "opt",
        });
    }
    (Program { phases, iterations: 1 }, layout, chars, n_ranks)
}

/// Co-simulate one candidate via [`makespan_setup`]. Returns the
/// simulated makespan (slowest rank) and the run's engine counters.
fn simulate_makespan(space: &SearchSpace, cand: &Candidate, gb_per_core: f64) -> (f64, SimStats) {
    let (program, layout, chars, n_ranks) = makespan_setup(space, cand, gb_per_core);
    let config = CoSimConfig::default();
    let result = simulate_placed(&program, n_ranks, &config, &chars, &layout);
    let makespan = result
        .finish_s
        .iter()
        .copied()
        .map(|f| if f.is_finite() { f } else { result.t_end_s })
        .fold(0.0f64, f64::max);
    (makespan, result.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::space::OptGroup;
    use crate::sharing::TopoShape;

    fn space2x2() -> SearchSpace {
        let shape = TopoShape {
            socket_of: vec![0, 0, 1, 1],
            bw_scale: vec![1.0; 4],
            link_bw_gbs: 30.0,
            link_bw_rev_gbs: 30.0,
            l3_bw_gbs: 0.0,
        };
        let mk = |name: &str, n: usize, f: f64, bs: f64| OptGroup {
            name: name.into(),
            kernel: KernelId::Dcopy,
            n,
            f,
            bs_gbs: bs,
            pinned: None,
            fixed_remote_ppm: None,
            kind: crate::sharing::GroupKind::Mem,
        };
        SearchSpace::new(
            shape,
            vec![8; 4],
            vec![
                mk("a", 6, 0.9, 40.0),
                mk("b", 6, 0.8, 38.0),
                mk("c", 4, 0.2, 20.0),
                mk("d", 4, 0.3, 24.0),
            ],
            super::super::space::DEFAULT_REMOTE_LEVELS.to_vec(),
        )
        .unwrap()
    }

    #[test]
    fn winner_beats_compact_and_scatter_starts() {
        let space = space2x2();
        let cfg = SearchConfig { budget: 400, ..SearchConfig::default() };
        let res = optimize(&space, &cfg).unwrap();
        for start in [space.start_compact().unwrap(), space.start_scatter().unwrap()] {
            let rates = full_rates(&space, &start).unwrap();
            let s = cfg.objective.score(&space, cfg.gb_per_core, &rates);
            assert!(res.best_score >= s, "winner {} < start {s}", res.best_score);
        }
    }

    #[test]
    fn fixed_seed_gives_identical_traces_across_modes() {
        let space = space2x2();
        let base = SearchConfig { budget: 300, ..SearchConfig::default() };
        let fullcfg = SearchConfig {
            parallel: false,
            use_delta: false,
            memoize: false,
            ..base.clone()
        };
        let a = optimize(&space, &base).unwrap();
        let b = optimize(&space, &fullcfg).unwrap();
        assert_eq!(a.best, b.best);
        assert_eq!(a.best_score.to_bits(), b.best_score.to_bits());
        assert_eq!(a.trace.len(), b.trace.len());
        for (x, y) in a.trace.iter().zip(&b.trace) {
            assert_eq!(x.candidate, y.candidate);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
        }
    }

    #[test]
    fn warm_shared_memo_changes_counters_not_the_outcome() {
        let space = space2x2();
        let cfg = SearchConfig { budget: 300, ..SearchConfig::default() };
        let ns = space.fingerprint();
        let memo = ShardedScoreMemo::new();
        let cold = optimize_with_memo(&space, &cfg, &memo, ns).unwrap();
        let warm = optimize_with_memo(&space, &cfg, &memo, ns).unwrap();
        assert_eq!(cold.best, warm.best);
        assert_eq!(cold.best_score.to_bits(), warm.best_score.to_bits());
        assert_eq!(cold.trace.len(), warm.trace.len());
        for (x, y) in cold.trace.iter().zip(&warm.trace) {
            assert_eq!(x.candidate, y.candidate);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
        }
        assert_eq!(cold.scored, warm.scored);
        assert!(warm.evaluated < cold.evaluated, "warm run should hit the memo");
        // The reference optimize() is the same search against a fresh memo.
        let fresh = optimize(&space, &cfg).unwrap();
        assert_eq!(fresh.best, cold.best);
        assert_eq!(fresh.best_score.to_bits(), cold.best_score.to_bits());
    }

    #[test]
    fn makespan_objective_reports_a_simulated_time() {
        let space = space2x2();
        let cfg = SearchConfig {
            objective: Objective::Makespan,
            budget: 150,
            starts: 2,
            ..SearchConfig::default()
        };
        let res = optimize(&space, &cfg).unwrap();
        let m = res.makespan_s.expect("makespan objective simulates finalists");
        assert!(m > 0.0 && m.is_finite());
    }
}
