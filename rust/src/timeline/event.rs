//! The priority-queue event core.
//!
//! The queue holds the *externally scheduled* events: staggered starts,
//! noise arrivals, idle expiries, and collective releases. Phase
//! completions are not stored here — under a fixed composition the next
//! completion time is a closed-form number, so the engine keeps it as a
//! single analytic time and compares it against the queue head
//! ([`crate::timeline::engine`]); at equal times queue events win, which
//! gives completions the lowest tie-break priority.
//!
//! Events that can become stale (noise arrivals for ranks that were
//! preempted meanwhile) are validated lazily at pop time, keeping
//! cancellation O(1).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What an event does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A rank's (possibly staggered) program start.
    Start,
    /// A noise arrival. Valid only while the rank runs a kernel and the
    /// arrival time still matches the rank's stream (a deferred arrival is
    /// consumed by `enter_running` instead and the popped event dropped).
    Noise,
    /// End of an idle interval — an explicit `Phase::Idle` or a noise idle.
    IdleEnd,
    /// Release of a collective: every rank has arrived and the collective
    /// cost has elapsed. `idx` carries the flat phase index.
    CollectiveRelease,
}

impl EventKind {
    /// Same-time tie-break priority. Noise preempts everything that drains
    /// bytes at the same instant, mirroring the legacy stepper where
    /// `poll` runs before the per-step drain.
    fn priority(self) -> u8 {
        match self {
            EventKind::Start => 0,
            EventKind::Noise => 1,
            EventKind::IdleEnd => 2,
            EventKind::CollectiveRelease => 3,
        }
    }
}

/// One scheduled event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Absolute simulation time, seconds.
    pub t: f64,
    /// Event kind.
    pub kind: EventKind,
    /// Rank index (`Start`/`Noise`/`IdleEnd`), flat phase index
    /// (`CollectiveRelease`).
    pub idx: usize,
    /// Insertion order (total-order tie break, FIFO within ties).
    seq: u64,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed on every field: `BinaryHeap` is a max-heap and we want
        // the earliest event (then lowest priority/idx/seq) on top.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.kind.priority().cmp(&self.kind.priority()))
            .then_with(|| other.idx.cmp(&self.idx))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-queue of [`Event`]s.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    /// An empty queue.
    pub fn new() -> Self {
        EventQueue::default()
    }

    /// Schedule an event.
    pub fn push(&mut self, t: f64, kind: EventKind, idx: usize) {
        debug_assert!(t.is_finite(), "non-finite event time");
        self.heap.push(Event { t, kind, idx, seq: self.seq });
        self.seq += 1;
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.t)
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    /// Pending event count (including stale entries awaiting lazy skip).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total number of events ever scheduled.
    pub fn scheduled(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::CollectiveRelease, 0);
        q.push(1.0, EventKind::IdleEnd, 2);
        q.push(2.0, EventKind::Start, 1);
        assert_eq!(q.peek_time(), Some(1.0));
        let ts: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.t).collect();
        assert_eq!(ts, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn same_time_orders_by_kind_priority_then_idx() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::CollectiveRelease, 0);
        q.push(1.0, EventKind::Noise, 5);
        q.push(1.0, EventKind::Noise, 2);
        q.push(1.0, EventKind::Start, 9);
        let order: Vec<(EventKind, usize)> =
            std::iter::from_fn(|| q.pop()).map(|e| (e.kind, e.idx)).collect();
        assert_eq!(
            order,
            vec![
                (EventKind::Start, 9),
                (EventKind::Noise, 2),
                (EventKind::Noise, 5),
                (EventKind::CollectiveRelease, 0),
            ]
        );
    }

    #[test]
    fn full_ties_are_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::IdleEnd, 1);
        q.push(1.0, EventKind::IdleEnd, 1);
        q.push(1.0, EventKind::IdleEnd, 1);
        assert_eq!(q.len(), 3);
        assert_eq!(q.scheduled(), 3);
        let mut last = None;
        while let Some(e) = q.pop() {
            assert_eq!(e.t, 1.0);
            last = Some(e);
        }
        assert!(last.is_some());
        assert!(q.is_empty());
    }
}
