//! Model-guided pairing of a task queue onto one contention domain.
//!
//! The paper's task-parallel outlook: a queue of tasks is gang-scheduled
//! two at a time, each pair sharing the domain half/half. The planner
//! picks partners by the *predicted* co-run slot time — the sharing model
//! (Eqs. 4+5) when both halves saturate, plain demand subtraction when a
//! compute-bound (low `f`) task barely touches the interface (the
//! paper's Fig. 2 scenario split).
//!
//! [`plan_pairing`] with `beam == 1` reproduces the greedy policy the
//! `task_scheduler` example originally hand-rolled (LPT anchor, best
//! partner by slot time with a 2% tie tolerance, then most filled work):
//! the example now calls this planner and simulates the resulting plan.
//! `beam > 1` keeps the `beam` best partial schedules by accumulated
//! predicted time instead of committing to the single greedy choice.

use crate::sharing::{share_two_groups, KernelGroup};

/// One queued task, reduced to what the model needs.
#[derive(Debug, Clone)]
pub struct PairTask {
    /// Display name (reports only).
    pub name: String,
    /// Memory request fraction of the task's kernel (Eq. 2).
    pub f: f64,
    /// Saturated bandwidth of the task's kernel, GB/s.
    pub bs_gbs: f64,
    /// Data volume the task moves, GB.
    pub gbytes: f64,
}

/// A pairing schedule: `(anchor, partner)` task indices in execution
/// order; a trailing unpaired task runs solo on the full domain.
#[derive(Debug, Clone, PartialEq)]
pub struct PairPlan {
    /// Task-index pairs, in slot order.
    pub pairs: Vec<(usize, Option<usize>)>,
    /// Predicted total time of the plan, seconds (model-side estimate —
    /// callers wanting a simulator-grade number evaluate the pairs
    /// themselves, like the `task_scheduler` example does).
    pub predicted_total_s: f64,
}

/// Predicted co-run slot of anchor `a` and partner `b` on `cores` split
/// half/half: `(slot time, filled time)` = `(max, min)` of the two
/// per-task times under the predicted bandwidths.
fn predict_slot(cores: usize, a: &PairTask, b: &PairTask) -> (f64, f64) {
    let half = cores / 2;
    let (na, nb) = (half, cores - half);
    let (da, db) = (na as f64 * a.f * a.bs_gbs, nb as f64 * b.f * b.bs_gbs);
    let sat_a = na as f64 * a.f >= 0.95;
    let sat_b = nb as f64 * b.f >= 0.95;
    let (bw_a, bw_b) = match (sat_a, sat_b) {
        (true, true) => {
            let p = share_two_groups(
                &KernelGroup { n: na, f: a.f, bs_gbs: a.bs_gbs },
                &KernelGroup { n: nb, f: b.f, bs_gbs: b.bs_gbs },
            );
            (p.group_bw_gbs[0], p.group_bw_gbs[1])
        }
        (true, false) => (da.min(a.bs_gbs - db), db),
        (false, true) => (da, db.min(b.bs_gbs - da)),
        (false, false) => (da, db),
    };
    let ta = a.gbytes / bw_a.max(1e-9);
    let tb = b.gbytes / bw_b.max(1e-9);
    (ta.max(tb), ta.min(tb))
}

/// Predicted solo time of a task on the full domain (homogeneous
/// bandwidth `min(n f b_s, b_s)`).
fn predict_solo(cores: usize, t: &PairTask) -> f64 {
    t.gbytes / (cores as f64 * t.f * t.bs_gbs).min(t.bs_gbs)
}

/// Rank partner `x` against `y` for a fixed anchor: slot time with a 2%
/// tolerance, then maximize the filled work inside the slot.
fn better_partner(sx: (f64, f64), sy: (f64, f64)) -> std::cmp::Ordering {
    let ((tx, fx), (ty, fy)) = (sx, sy);
    if (tx - ty).abs() / tx.max(ty).max(1e-9) < 0.02 {
        fy.partial_cmp(&fx).expect("finite fill times")
    } else {
        tx.partial_cmp(&ty).expect("finite slot times")
    }
}

/// One partial schedule during the beam search.
#[derive(Debug, Clone)]
struct Partial {
    /// Remaining queue, ascending by solo time (anchors pop off the back).
    queue: Vec<usize>,
    pairs: Vec<(usize, Option<usize>)>,
    total_s: f64,
}

/// Plan the pairing of `tasks` on a `cores`-core domain.
///
/// Anchors are chosen longest-predicted-solo-first (classic LPT, half
/// domain as the reference size); partners by [`better_partner`]. With
/// `beam == 1` this is exactly the greedy policy; larger beams explore
/// the `beam` best partner choices per slot and keep the `beam` best
/// partial schedules. Deterministic: ties break on task index.
pub fn plan_pairing(cores: usize, tasks: &[PairTask], beam: usize) -> PairPlan {
    let beam = beam.max(1);
    if tasks.is_empty() {
        return PairPlan { pairs: Vec::new(), predicted_total_s: 0.0 };
    }
    // LPT order: ascending solo time on half the domain, pop from back.
    let half_solo = |i: usize| {
        let t = &tasks[i];
        t.gbytes / (cores as f64 / 2.0 * t.f * t.bs_gbs).min(t.bs_gbs)
    };
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&x, &y| half_solo(x).partial_cmp(&half_solo(y)).expect("finite solo times"));

    let mut frontier = vec![Partial { queue: order, pairs: Vec::new(), total_s: 0.0 }];
    loop {
        if frontier.iter().all(|p| p.queue.is_empty()) {
            break;
        }
        let mut next: Vec<Partial> = Vec::new();
        for p in &frontier {
            let mut p = p.clone();
            let Some(a) = p.queue.pop() else {
                next.push(p);
                continue;
            };
            if p.queue.is_empty() {
                p.total_s += predict_solo(cores, &tasks[a]);
                p.pairs.push((a, None));
                next.push(p);
                continue;
            }
            // The `beam` best partners, each extracted with the same
            // `min_by` fold the greedy uses (the 2%-tolerance comparator
            // is not transitive, so a sort could panic — a fold cannot,
            // and beam 1 then matches the greedy pick exactly).
            let slots: Vec<(f64, f64)> = p
                .queue
                .iter()
                .map(|&b| predict_slot(cores, &tasks[a], &tasks[b]))
                .collect();
            let mut ranked: Vec<usize> = Vec::with_capacity(beam);
            let mut pool: Vec<usize> = (0..p.queue.len()).collect();
            while ranked.len() < beam && !pool.is_empty() {
                let at = pool
                    .iter()
                    .enumerate()
                    .min_by(|(_, &x), (_, &y)| better_partner(slots[x], slots[y]))
                    .map(|(i, _)| i)
                    .expect("nonempty pool");
                ranked.push(pool.remove(at));
            }
            for &qi in &ranked {
                let mut q = p.clone();
                let b = q.queue.remove(qi);
                q.total_s += predict_slot(cores, &tasks[a], &tasks[b]).0;
                q.pairs.push((a, Some(b)));
                next.push(q);
            }
        }
        next.sort_by(|x, y| {
            x.total_s.total_cmp(&y.total_s).then_with(|| x.pairs.cmp(&y.pairs))
        });
        next.truncate(beam);
        frontier = next;
    }
    let best = frontier
        .into_iter()
        .min_by(|x, y| x.total_s.total_cmp(&y.total_s).then_with(|| x.pairs.cmp(&y.pairs)))
        .expect("nonempty frontier");
    PairPlan { pairs: best.pairs, predicted_total_s: best.total_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(name: &str, f: f64, bs: f64, gb: f64) -> PairTask {
        PairTask { name: name.into(), f, bs_gbs: bs, gbytes: gb }
    }

    /// The hand-rolled greedy from the pre-optimizer `task_scheduler`
    /// example, kept verbatim as the reference beam-1 must match.
    fn reference_greedy(cores: usize, tasks: &[PairTask]) -> Vec<(usize, Option<usize>)> {
        let half_solo = |i: usize| {
            let t = &tasks[i];
            t.gbytes / (cores as f64 / 2.0 * t.f * t.bs_gbs).min(t.bs_gbs)
        };
        let mut queue: Vec<usize> = (0..tasks.len()).collect();
        queue.sort_by(|&x, &y| half_solo(x).partial_cmp(&half_solo(y)).unwrap());
        let mut pairs = Vec::new();
        while let Some(a) = queue.pop() {
            if queue.is_empty() {
                pairs.push((a, None));
                break;
            }
            let best = queue
                .iter()
                .enumerate()
                .min_by(|(_, &x), (_, &y)| {
                    better_partner(
                        predict_slot(cores, &tasks[a], &tasks[x]),
                        predict_slot(cores, &tasks[a], &tasks[y]),
                    )
                })
                .map(|(i, _)| i)
                .unwrap();
            let b = queue.remove(best);
            pairs.push((a, Some(b)));
        }
        pairs
    }

    fn mixed_queue() -> Vec<PairTask> {
        let mut tasks = Vec::new();
        for i in 0..4 {
            tasks.push(task("stream", 0.85, 25.0, 60.0 + 5.0 * i as f64));
            tasks.push(task("dgemm", 0.01, 30.0, 4.0));
            tasks.push(task("ddot2", 0.7, 27.0, 60.0));
            tasks.push(task("dgemm", 0.01, 30.0, 4.0));
        }
        tasks
    }

    #[test]
    fn beam_one_matches_the_reference_greedy() {
        let tasks = mixed_queue();
        let plan = plan_pairing(18, &tasks, 1);
        assert_eq!(plan.pairs, reference_greedy(18, &tasks));
    }

    #[test]
    fn odd_queue_leaves_one_solo_task() {
        let tasks = vec![
            task("a", 0.8, 25.0, 50.0),
            task("b", 0.5, 25.0, 30.0),
            task("c", 0.02, 30.0, 5.0),
        ];
        let plan = plan_pairing(16, &tasks, 1);
        assert_eq!(plan.pairs.len(), 2);
        assert_eq!(plan.pairs.last().unwrap().1, None);
        assert!(plan.predicted_total_s > 0.0);
    }

    #[test]
    fn wider_beam_never_predicts_worse() {
        let tasks = mixed_queue();
        let greedy = plan_pairing(18, &tasks, 1);
        let beamed = plan_pairing(18, &tasks, 3);
        assert!(beamed.predicted_total_s <= greedy.predicted_total_s + 1e-12);
    }
}
