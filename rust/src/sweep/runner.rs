//! Parallel sweep runner: measures pairing cases on a machine with a chosen
//! engine and attaches the analytic-model prediction (Eqs. 4+5) computed
//! from Eq.-3-measured `f` and `b_s` — exactly the paper's procedure.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::config::Machine;
use crate::error::Result;
use crate::kernels::{kernel, KernelId};
use crate::runtime::{PjrtSimExecutor, SimCase};
use crate::sharing::{share_two_groups, KernelGroup};
use crate::simulator::{measure_f_bs, run_engine, CoreWorkload, Engine, KernelMeasurement};
use crate::sweep::plan::PairingCase;
use crate::sweep::results::{CaseResult, ResultSet};

/// Measurement engine selection for a sweep.
pub enum MeasureEngine<'a> {
    /// In-process fluid simulator, parallelized over OS threads.
    Fluid,
    /// In-process discrete-event simulator, parallelized over OS threads.
    Des,
    /// The AOT JAX/Pallas artifact through PJRT (batched).
    Pjrt(&'a PjrtSimExecutor),
}

impl MeasureEngine<'_> {
    fn inproc(&self) -> Option<Engine> {
        match self {
            MeasureEngine::Fluid => Some(Engine::Fluid),
            MeasureEngine::Des => Some(Engine::Des),
            MeasureEngine::Pjrt(_) => None,
        }
    }
}

/// Process-wide characterization cache: (machine, kernel, engine kind) →
/// Eq.-3 measurement. Characterizations are deterministic per engine, so
/// caching is safe; it removes the dominant redundant work from multi-call
/// sweeps (Fig. 8/9 regenerate hundreds of `run_cases` calls).
fn char_cache() -> &'static Mutex<HashMap<(crate::config::MachineId, KernelId, u8), KernelMeasurement>> {
    static CACHE: OnceLock<Mutex<HashMap<(crate::config::MachineId, KernelId, u8), KernelMeasurement>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

fn engine_kind(engine: &MeasureEngine) -> u8 {
    match engine {
        MeasureEngine::Fluid => 0,
        MeasureEngine::Des => 1,
        MeasureEngine::Pjrt(_) => 2,
    }
}

/// Characterize every kernel appearing in `cases` (Eq. 3: solo + full
/// domain) with the same engine used for the pairing measurements.
/// Results are served from the process-wide cache when available.
fn characterize(
    machine: &Machine,
    kernels: &[KernelId],
    engine: &MeasureEngine,
) -> Result<HashMap<KernelId, KernelMeasurement>> {
    let kind = engine_kind(engine);
    let mut out = HashMap::new();
    let mut missing: Vec<KernelId> = Vec::new();
    {
        let cache = char_cache().lock().unwrap();
        for &k in kernels {
            match cache.get(&(machine.id, k, kind)) {
                Some(m) => {
                    out.insert(k, *m);
                }
                None => missing.push(k),
            }
        }
    }
    if !missing.is_empty() {
        match engine {
            MeasureEngine::Pjrt(exec) => {
                // Two configs per kernel: 1 core and the full domain.
                let mut cases = Vec::new();
                for &k in &missing {
                    let w = CoreWorkload::from_kernel(&kernel(k), machine, 0);
                    cases.push(SimCase { machine: machine.clone(), workloads: vec![w] });
                    cases.push(SimCase { machine: machine.clone(), workloads: vec![w; machine.cores] });
                }
                let bw = exec.run(&cases)?;
                for (i, &k) in missing.iter().enumerate() {
                    let b1 = bw[2 * i][0];
                    let bs: f64 = bw[2 * i + 1].iter().sum();
                    out.insert(k, KernelMeasurement { b1_gbs: b1, bs_gbs: bs, f: b1 / bs });
                }
            }
            _ => {
                let eng = engine.inproc().unwrap();
                for &k in &missing {
                    out.insert(k, measure_f_bs(&kernel(k), machine, eng));
                }
            }
        }
        let mut cache = char_cache().lock().unwrap();
        for &k in &missing {
            cache.insert((machine.id, k, kind), out[&k]);
        }
    }
    Ok(out)
}

/// Compose the per-case result from raw per-core bandwidths.
fn to_result(
    machine: &Machine,
    case: &PairingCase,
    per_core: &[f64],
    chars: &HashMap<KernelId, KernelMeasurement>,
) -> CaseResult {
    let g0: f64 = per_core.iter().take(case.n1).sum();
    let g1: f64 = per_core.iter().skip(case.n1).take(case.n2).sum();
    let m1 = chars[&case.k1];
    let m2 = chars[&case.k2];
    let pred = share_two_groups(
        &KernelGroup { n: case.n1, f: m1.f, bs_gbs: m1.bs_gbs },
        &KernelGroup { n: case.n2, f: m2.f, bs_gbs: m2.bs_gbs },
    );
    CaseResult {
        machine: machine.id,
        kernels: [case.k1, case.k2],
        n: [case.n1, case.n2],
        measured_per_core: [
            if case.n1 > 0 { g0 / case.n1 as f64 } else { 0.0 },
            if case.n2 > 0 { g1 / case.n2 as f64 } else { 0.0 },
        ],
        model_per_core: pred.per_core_gbs,
        measured_total: g0 + g1,
        model_total: pred.group_bw_gbs[0] + pred.group_bw_gbs[1],
    }
}

fn workloads_for(machine: &Machine, case: &PairingCase) -> Vec<CoreWorkload> {
    let mut ws = vec![CoreWorkload::from_kernel(&kernel(case.k1), machine, 0); case.n1];
    ws.extend(vec![CoreWorkload::from_kernel(&kernel(case.k2), machine, 1); case.n2]);
    ws
}

/// Run `cases` on `machine` with `engine`; results are in plan order.
pub fn run_cases(machine: &Machine, cases: &[PairingCase], engine: &MeasureEngine) -> Result<ResultSet> {
    for c in cases {
        c.validate(machine)?;
    }
    let mut kernels: Vec<KernelId> = cases.iter().flat_map(|c| [c.k1, c.k2]).collect();
    kernels.sort_by_key(|k| k.key());
    kernels.dedup();
    let chars = characterize(machine, &kernels, engine)?;

    match engine {
        MeasureEngine::Pjrt(exec) => {
            let sim_cases: Vec<SimCase> = cases
                .iter()
                .map(|c| SimCase { machine: machine.clone(), workloads: workloads_for(machine, c) })
                .collect();
            let bw = exec.run(&sim_cases)?;
            Ok(ResultSet {
                cases: cases
                    .iter()
                    .zip(&bw)
                    .map(|(c, pc)| to_result(machine, c, pc, &chars))
                    .collect(),
            })
        }
        _ => {
            let eng = engine.inproc().unwrap();
            let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
            let results: Mutex<Vec<(usize, CaseResult)>> = Mutex::new(Vec::with_capacity(cases.len()));
            let next = std::sync::atomic::AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..workers.min(cases.len().max(1)) {
                    scope.spawn(|| loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= cases.len() {
                            break;
                        }
                        let ws = workloads_for(machine, &cases[i]);
                        let pc = run_engine(machine, &ws, eng);
                        let r = to_result(machine, &cases[i], &pc, &chars);
                        results.lock().unwrap().push((i, r));
                    });
                }
            });
            let mut pairs = results.into_inner().unwrap();
            pairs.sort_by_key(|(i, _)| *i);
            Ok(ResultSet { cases: pairs.into_iter().map(|(_, r)| r).collect() })
        }
    }
}

/// Convenience wrapper that loads the artifact bundle and runs via PJRT.
pub fn run_cases_pjrt(
    machine: &Machine,
    cases: &[PairingCase],
    exec: &PjrtSimExecutor,
) -> Result<ResultSet> {
    run_cases(machine, cases, &MeasureEngine::Pjrt(exec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::sweep::plan::full_domain_splits;

    #[test]
    fn fluid_sweep_produces_ordered_results() {
        let m = machine(MachineId::Rome);
        let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
        let rs = run_cases(&m, &cases, &MeasureEngine::Fluid).unwrap();
        assert_eq!(rs.cases.len(), cases.len());
        for (c, r) in cases.iter().zip(&rs.cases) {
            assert_eq!(c.n1, r.n[0]);
            assert!(r.measured_total > 0.0);
        }
    }

    #[test]
    fn model_error_small_on_bdw1_pairing_sweep() {
        // Preview of the Fig. 8 claim on one pairing.
        let m = machine(MachineId::Bdw1);
        let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
        let rs = run_cases(&m, &cases, &MeasureEngine::Fluid).unwrap();
        let errs = rs.all_errors();
        let max = errs.iter().cloned().fold(0.0, f64::max);
        assert!(max < 0.10, "max error {max}");
    }
}
