//! Kernel stream signatures.
//!
//! The unit of work throughout is **one cache line of iterations** — 8
//! double-precision elements. All traffic counts are cache lines per unit.

/// Read/write/RFO stream decomposition (Table II column "Elem. transf.").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamCounts {
    /// Read streams (lines loaded per unit).
    pub reads: usize,
    /// Write-back streams (dirty lines evicted per unit).
    pub writes: usize,
    /// Read-for-ownership streams (write-allocate transfers per unit).
    pub rfo: usize,
}

impl StreamCounts {
    /// Total lines over the memory interface per unit (R + W + RFO).
    pub fn total(&self) -> usize {
        self.reads + self.writes + self.rfo
    }

    /// Fraction of memory lines that are writes (write-backs). RFO lines are
    /// reads from the interface's point of view.
    pub fn write_frac(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.writes as f64 / self.total() as f64
        }
    }
}

/// Broad class of a kernel (Table II row groups).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    /// Streaming kernel without write streams (vectorSUM, DDOTx).
    ReadOnly,
    /// Streaming kernel with at least one write stream.
    ReadWrite,
    /// Stencil with cache reuse governed by layer conditions.
    Stencil,
}

/// Full traffic/instruction signature of a loop kernel on a given machine
/// *class* (traffic is machine-independent except for victim-LLC effects,
/// which [`crate::ecm`] applies).
#[derive(Debug, Clone)]
pub struct KernelSignature {
    /// Canonical name (Table II).
    pub name: String,
    /// Pseudo-code of the loop body, for documentation and reports.
    pub body: String,
    /// Class of the kernel.
    pub class: KernelClass,
    /// Lines over the *memory* interface per unit.
    pub mem: StreamCounts,
    /// Lines over L2↔L3 per unit (differs from `mem` for stencils where the
    /// layer condition at L2 is violated, and on victim LLCs).
    pub l3: StreamCounts,
    /// Lines over L1↔L2 per unit.
    pub l2: StreamCounts,
    /// Load instructions (scalar element loads) per iteration — SIMD
    /// packing is applied by the ECM model using the machine's register
    /// width. For stencils this counts loads that hit L1/registers too.
    pub loads_per_iter: usize,
    /// Store instructions per iteration.
    pub stores_per_iter: usize,
    /// Floating-point operations per iteration.
    pub flops_per_iter: usize,
    /// Code balance in byte/flop at the *memory* level (Table II B_c).
    pub code_balance: f64,
}

impl KernelSignature {
    /// Convenience constructor for pure streaming kernels, where the traffic
    /// is identical on every level of the hierarchy.
    #[allow(clippy::too_many_arguments)]
    pub fn streaming(
        name: &str,
        body: &str,
        class: KernelClass,
        reads: usize,
        writes: usize,
        rfo: usize,
        loads_per_iter: usize,
        stores_per_iter: usize,
        flops_per_iter: usize,
    ) -> Self {
        let sc = StreamCounts { reads, writes, rfo };
        let bytes_per_iter = sc.total() as f64 * crate::CACHE_LINE_BYTES / crate::ELEMS_PER_LINE as f64;
        let code_balance = if flops_per_iter == 0 {
            f64::INFINITY
        } else {
            bytes_per_iter / flops_per_iter as f64
        };
        KernelSignature {
            name: name.to_string(),
            body: body.to_string(),
            class,
            mem: sc,
            l3: sc,
            l2: sc,
            loads_per_iter,
            stores_per_iter,
            flops_per_iter,
            code_balance,
        }
    }

    /// Bytes over the memory interface per iteration.
    pub fn bytes_per_iter(&self) -> f64 {
        self.mem.total() as f64 * crate::CACHE_LINE_BYTES / crate::ELEMS_PER_LINE as f64
    }

    /// Write fraction of the memory traffic (drives the saturated-bandwidth
    /// difference between read-only and read-write kernels).
    pub fn write_frac(&self) -> f64 {
        self.mem.write_frac()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_counts_total_and_write_frac() {
        // STREAM triad: a[i] = b[i] + s*c[i] -> 2R + 1W + 1RFO (Table II).
        let sc = StreamCounts { reads: 2, writes: 1, rfo: 1 };
        assert_eq!(sc.total(), 4);
        assert!((sc.write_frac() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn streaming_ctor_computes_code_balance() {
        // DAXPY: 3 lines / 8 iters = 24 B/iter, 2 flops -> 12 B/F (Table II).
        let k = KernelSignature::streaming(
            "daxpy", "a[i] = a[i] + s*b[i]", KernelClass::ReadWrite, 2, 1, 0, 2, 1, 2,
        );
        assert!((k.code_balance - 12.0).abs() < 1e-12);
        assert!((k.bytes_per_iter() - 24.0).abs() < 1e-12);
    }
}
