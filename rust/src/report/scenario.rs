//! k-group scenario share tables — the report surface of the scenario
//! engine (what Figs. 6/7 are to the two-group sweeps).

use std::fmt::Write as _;

use crate::config::Machine;
use crate::error::Result;
use crate::report::experiments::ExperimentCtx;
use crate::report::table::AsciiTable;
use crate::scenario::{run_scenario, run_scenario_on, Scenario};
use crate::topology::{Placement, Topology};

/// Run `scenario` on `machine` with the context's engine and render one
/// share table per phase: measured vs multigroup-model per-core bandwidth
/// and bandwidth share α per group. Also writes
/// `scenario_<name>.csv` under the context's output directory.
pub fn scenario_report(ctx: &ExperimentCtx, machine: &Machine, scenario: &Scenario) -> Result<String> {
    scenario.validate(machine)?;
    let result = run_scenario(machine, scenario, &ctx.measure_engine())?;

    let mut out = String::new();
    writeln!(
        out,
        "SCENARIO '{}' on {} — k-group bandwidth shares (engine: {})",
        result.name,
        machine.name,
        ctx.engine_name()
    )
    .unwrap();

    let mut worst_err = 0.0f64;
    for (pi, phase) in result.phases.iter().enumerate() {
        writeln!(
            out,
            "\nphase {}/{}: {}   [{}, b_mix {:.1} GB/s]",
            pi + 1,
            result.phases.len(),
            phase.mix.label(),
            if phase.saturated { "saturated" } else { "nonsaturated" },
            phase.b_mix_gbs
        )
        .unwrap();
        let mut t = AsciiTable::new(&[
            "group", "kernel", "n", "meas/core", "model/core", "alpha meas", "alpha model", "err%",
        ]);
        for (gi, g) in phase.groups.iter().enumerate() {
            worst_err = worst_err.max(g.error());
            t.row(vec![
                format!("{gi}"),
                g.kernel.key().to_string(),
                g.n.to_string(),
                format!("{:.2}", g.measured_per_core),
                format!("{:.2}", g.model_per_core),
                format!("{:.3}", phase.measured_alpha(gi)),
                format!("{:.3}", g.model_alpha),
                format!("{:.1}", g.error() * 100.0),
            ]);
        }
        if phase.mix.idle_cores > 0 {
            t.row(vec![
                "-".into(),
                "(idle)".into(),
                phase.mix.idle_cores.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
        }
        out.push_str(&t.render());
        writeln!(
            out,
            "total: measured {:.1} GB/s, model {:.1} GB/s",
            phase.measured_total_gbs, phase.model_total_gbs
        )
        .unwrap();
    }
    writeln!(
        out,
        "\nworst per-group model error: {:.2}% (paper's two-group bound: <8%)",
        worst_err * 100.0
    )
    .unwrap();

    std::fs::create_dir_all(&ctx.out_dir)?;
    result.write_csv(&ctx.out_dir.join(format!("scenario_{}.csv", result.file_stem())))?;
    Ok(out)
}

/// Run `scenario` on a multi-domain topology and render, per phase, the
/// socket-level aggregate table plus one per-domain share table (each
/// domain's shares are its own Eqs. 4+5 over its resident groups). Also
/// writes `scenario_<name>_<topology>.csv` under the context's output
/// directory.
pub fn topology_scenario_report(
    ctx: &ExperimentCtx,
    topo: &Topology,
    placement: Placement,
    scenario: &Scenario,
) -> Result<String> {
    // run_scenario_on re-validates (active cores + placement split) per
    // phase, so no separate validate_on pass here.
    let result = run_scenario_on(topo, placement, scenario, &ctx.measure_engine())?;

    let mut out = String::new();
    writeln!(
        out,
        "SCENARIO '{}' on {} — topology {} ({} domains x {} cores), placement {} (engine: {})",
        result.name,
        topo.base.name,
        result.topology,
        topo.n_domains(),
        topo.base.cores,
        placement.name(),
        ctx.engine_name()
    )
    .unwrap();

    let mut worst_err = 0.0f64;
    for (pi, phase) in result.phases.iter().enumerate() {
        writeln!(out, "\nphase {}/{}: {}", pi + 1, result.phases.len(), phase.mix.label())
            .unwrap();
        if phase.remote_converged == Some(false) {
            // The gated remote fixed point hit its sweep cap: the model
            // columns of this phase are the last iterate, not a fixed
            // point — flag them instead of printing them as exact.
            writeln!(
                out,
                "WARNING: remote fixed point did not converge within the sweep cap; \
                 model columns are approximate"
            )
            .unwrap();
        }
        let mut t = AsciiTable::new(&[
            "group", "kernel", "n", "meas/core", "model/core", "alpha model", "err%",
        ]);
        for (gi, g) in phase.socket.iter().enumerate() {
            t.row(vec![
                format!("{gi}"),
                g.kernel.key().to_string(),
                g.n.to_string(),
                format!("{:.2}", g.measured_per_core),
                format!("{:.2}", g.model_per_core),
                format!("{:.3}", g.model_alpha),
                format!("{:.1}", g.error() * 100.0),
            ]);
        }
        out.push_str("socket aggregate:\n");
        out.push_str(&t.render());
        writeln!(
            out,
            "total: measured {:.1} GB/s, model {:.1} GB/s",
            phase.measured_total_gbs, phase.model_total_gbs
        )
        .unwrap();
        for (did, dr) in phase.domain_ids.iter().zip(&phase.domains) {
            // A domain can carry remote traffic without hosting any group
            // (its resident table would be empty): summarize the interface
            // and move on.
            if dr.groups.is_empty() && dr.mix.idle_cores == 0 {
                writeln!(
                    out,
                    "[d{did}] (remote traffic only)   [{}, b_mix {:.1} GB/s]",
                    if dr.saturated { "saturated" } else { "nonsaturated" },
                    dr.b_mix_gbs
                )
                .unwrap();
                continue;
            }
            writeln!(
                out,
                "[d{did}] {}   [{}, b_mix {:.1} GB/s]",
                dr.mix.label(),
                if dr.saturated { "saturated" } else { "nonsaturated" },
                dr.b_mix_gbs
            )
            .unwrap();
            let mut dt = AsciiTable::new(&[
                "kernel", "n", "meas/core", "model/core", "alpha meas", "alpha model", "err%",
            ]);
            for (gi, g) in dr.groups.iter().enumerate() {
                worst_err = worst_err.max(g.error());
                dt.row(vec![
                    g.kernel.key().to_string(),
                    g.n.to_string(),
                    format!("{:.2}", g.measured_per_core),
                    format!("{:.2}", g.model_per_core),
                    format!("{:.3}", dr.measured_alpha(gi)),
                    format!("{:.3}", g.model_alpha),
                    format!("{:.1}", g.error() * 100.0),
                ]);
            }
            if dr.mix.idle_cores > 0 {
                dt.row(vec![
                    "(idle)".into(),
                    dr.mix.idle_cores.to_string(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
            out.push_str(&dt.render());
        }
        // Remote-access phases additionally report every directed
        // inter-socket link interface that carried traffic (simulated =
        // lines that actually crossed it in the multi-interface engine;
        // model = the direction's water-fill grant).
        for link in &phase.links {
            writeln!(
                out,
                "[link {}] b_link {:.1} GB/s   [{}, simulated {:.1} GB/s, model {:.1} GB/s]",
                link.label(),
                link.link_bw_gbs,
                if link.saturated { "saturated" } else { "nonsaturated" },
                link.measured_total_gbs,
                link.model_total_gbs,
            )
            .unwrap();
            let mut lt = AsciiTable::new(&[
                "group", "kernel", "n", "sim GB/s", "model GB/s", "alpha model",
            ]);
            for (g, origin) in link.groups.iter().zip(&link.origins) {
                lt.row(vec![
                    format!("{origin}"),
                    g.kernel.key().to_string(),
                    g.n.to_string(),
                    format!("{:.2}", g.measured_bw_gbs),
                    format!("{:.2}", g.model_bw_gbs),
                    format!("{:.3}", g.model_alpha),
                ]);
            }
            out.push_str(&lt.render());
        }
        // Phases with cache-bound groups additionally report every shared
        // L3 that carried traffic. Bandwidths are L3-level (lines crossing
        // L2↔L3), not DRAM traffic.
        for l3 in &phase.l3 {
            writeln!(
                out,
                "[L3 {}] b_l3 {:.1} GB/s   [{}, simulated {:.1} GB/s, model {:.1} GB/s]",
                l3.label(),
                l3.l3_bw_gbs,
                if l3.saturated { "saturated" } else { "nonsaturated" },
                l3.measured_total_gbs,
                l3.model_total_gbs,
            )
            .unwrap();
            let mut ct = AsciiTable::new(&[
                "group", "kernel", "n", "sim GB/s", "model GB/s", "alpha model",
            ]);
            for (g, origin) in l3.groups.iter().zip(&l3.origins) {
                ct.row(vec![
                    format!("{origin}"),
                    g.kernel.key().to_string(),
                    g.n.to_string(),
                    format!("{:.2}", g.measured_bw_gbs),
                    format!("{:.2}", g.model_bw_gbs),
                    format!("{:.3}", g.model_alpha),
                ]);
            }
            out.push_str(&ct.render());
        }
    }
    writeln!(
        out,
        "\nworst per-domain per-group model error: {:.2}% (paper's two-group bound: <8%)",
        worst_err * 100.0
    )
    .unwrap();

    std::fs::create_dir_all(&ctx.out_dir)?;
    result.write_csv(&ctx.out_dir.join(format!(
        "scenario_{}_{}.csv",
        result.file_stem(),
        result.topology
    )))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};

    #[test]
    fn rome_socket_topology_report_renders_and_writes_csv() {
        let dir = std::env::temp_dir().join("membw-topo-report");
        let ctx = ExperimentCtx::fluid(dir.clone());
        let m = machine(MachineId::Rome);
        let topo = Topology::socket(&m);
        let sc = Scenario::parse(
            "rome-socket",
            "dcopy:8@d0+ddot2:8@d1+stream:8@d2+daxpy:8@d3 / dcopy:16@scatter+idle:16",
        )
        .unwrap();
        let text = topology_scenario_report(&ctx, &topo, Placement::Compact, &sc).unwrap();
        assert!(text.contains("topology rome-1s4d"), "{text}");
        assert!(text.contains("socket aggregate:"));
        assert!(text.contains("[d0]") && text.contains("[d3]"));
        let csv =
            std::fs::read_to_string(dir.join("scenario_rome-socket_rome-1s4d.csv")).unwrap();
        assert!(csv.lines().count() > 8);
        assert!(csv.contains(",socket,"));
    }

    #[test]
    fn two_socket_remote_report_renders_link_tables() {
        let dir = std::env::temp_dir().join("membw-topo-remote-report");
        let ctx = ExperimentCtx::fluid(dir.clone());
        let m = machine(MachineId::Rome);
        let topo = Topology::parse(&m, "2x4").unwrap();
        let sc = Scenario::parse(
            "rome-2x4-remote",
            "dcopy:32@scatter%r0.25+ddot2:32@scatter%r0.25",
        )
        .unwrap();
        let text = topology_scenario_report(&ctx, &topo, Placement::Compact, &sc).unwrap();
        assert!(text.contains("topology rome-2s4d"), "{text}");
        // Scatter with symmetric remote fractions drives traffic in both
        // directions, so both directed interfaces render.
        assert!(text.contains("[link s0->s1]"), "{text}");
        assert!(text.contains("[link s1->s0]"), "{text}");
        assert!(text.contains("alpha model"));
        let csv = std::fs::read_to_string(dir.join("scenario_rome-2x4-remote_rome-2s4d.csv"))
            .unwrap();
        assert!(csv.contains(",l0-1,"), "forward link rows in the CSV");
        assert!(csv.contains(",l1-0,"), "reverse link rows in the CSV");
        assert!(csv.contains("%r0.25"), "remote suffix in the mix label");
    }

    #[test]
    fn l3_bound_report_renders_l3_table() {
        let dir = std::env::temp_dir().join("membw-topo-l3-report");
        let ctx = ExperimentCtx::fluid(dir.clone());
        let m = machine(MachineId::Rome);
        let topo = Topology::socket(&m);
        let sc = Scenario::parse("rome-l3", "jacobil3-v1:4@d0@l3+dcopy:4@d0+idle:24").unwrap();
        let text = topology_scenario_report(&ctx, &topo, Placement::Compact, &sc).unwrap();
        assert!(text.contains("[L3 l3s0]"), "{text}");
        assert!(text.contains("b_l3"), "{text}");
        let csv = std::fs::read_to_string(dir.join("scenario_rome-l3_rome-1s4d.csv")).unwrap();
        assert!(csv.contains(",l3s0,"), "L3 rows in the CSV: {csv}");
        assert!(csv.contains("@l3"), "bound suffix in the mix label");
    }

    #[test]
    fn demo_scenario_report_renders_and_writes_csv() {
        let dir = std::env::temp_dir().join("membw-scenario-report");
        let ctx = ExperimentCtx::fluid(dir.clone());
        let m = machine(MachineId::Rome);
        let sc = Scenario::demo(&m);
        let text = scenario_report(&ctx, &m, &sc).unwrap();
        assert!(text.contains("SCENARIO 'demo'"));
        assert!(text.contains("alpha model"));
        assert!(text.contains("(idle)"));
        let csv = std::fs::read_to_string(dir.join("scenario_demo.csv")).unwrap();
        // header + (3 + 2 + 4) group rows over the three demo phases
        assert_eq!(csv.lines().count(), 1 + 9);
    }
}
