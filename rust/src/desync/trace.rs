//! Phase traces and timeline analytics (the ITAC substitute).

use std::collections::HashMap;

/// One completed phase execution of one rank.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseRecord {
    /// MPI rank.
    pub rank: usize,
    /// Iteration index.
    pub iteration: usize,
    /// Phase label ("DDOT2#1", "Allreduce#2", ...).
    pub label: &'static str,
    /// Start time, seconds.
    pub t_start: f64,
    /// End time, seconds.
    pub t_end: f64,
}

impl PhaseRecord {
    /// Duration in seconds.
    pub fn duration(&self) -> f64 {
        self.t_end - self.t_start
    }
}

/// A point of the concurrency timeline: how many ranks execute a phase.
#[derive(Debug, Clone, Copy)]
pub struct ConcurrencyPoint {
    /// Time, seconds.
    pub t: f64,
    /// Number of ranks inside the phase at `t`.
    pub count: usize,
}

/// The full trace of a co-simulation.
#[derive(Debug, Clone, Default)]
pub struct TraceLog {
    /// All completed phase records.
    pub records: Vec<PhaseRecord>,
}

impl TraceLog {
    /// Records of one label, optionally restricted to one iteration.
    pub fn of(&self, label: &str, iteration: Option<usize>) -> Vec<&PhaseRecord> {
        self.records
            .iter()
            .filter(|r| r.label == label && iteration.map(|i| r.iteration == i).unwrap_or(true))
            .collect()
    }

    /// Per-rank durations of a phase in one iteration (rank-indexed).
    pub fn durations_by_rank(&self, label: &str, iteration: usize, n_ranks: usize) -> Vec<f64> {
        let mut out = vec![0.0; n_ranks];
        for r in self.of(label, Some(iteration)) {
            out[r.rank] += r.duration();
        }
        out
    }

    /// Per-rank start times of a phase in one iteration.
    pub fn starts_by_rank(&self, label: &str, iteration: usize, n_ranks: usize) -> Vec<f64> {
        let mut out = vec![f64::NAN; n_ranks];
        for r in self.of(label, Some(iteration)) {
            if out[r.rank].is_nan() || r.t_start < out[r.rank] {
                out[r.rank] = r.t_start;
            }
        }
        out
    }

    /// Concurrency timeline of a label: at each phase boundary, how many
    /// ranks are inside (the bottom panels of Fig. 3).
    pub fn concurrency(&self, label: &str) -> Vec<ConcurrencyPoint> {
        let mut events: Vec<(f64, i64)> = Vec::new();
        for r in self.records.iter().filter(|r| r.label == label) {
            events.push((r.t_start, 1));
            events.push((r.t_end, -1));
        }
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut count = 0i64;
        events
            .into_iter()
            .map(|(t, d)| {
                count += d;
                ConcurrencyPoint { t, count: count.max(0) as usize }
            })
            .collect()
    }

    /// Render an ASCII timeline of an interval: one row per rank, one
    /// column per time bucket, showing the first letter of the phase label
    /// occupying that bucket (the Fig. 1/3 top panels).
    pub fn render_ascii(&self, t0: f64, t1: f64, n_ranks: usize, width: usize) -> String {
        let mut grid = vec![vec![' '; width]; n_ranks];
        let letters: HashMap<&str, char> = self
            .records
            .iter()
            .map(|r| (r.label, r.label.chars().next().unwrap_or('?')))
            .collect();
        for r in &self.records {
            if r.t_end < t0 || r.t_start > t1 || r.rank >= n_ranks {
                continue;
            }
            let col = |t: f64| {
                (((t - t0) / (t1 - t0)) * width as f64).floor().clamp(0.0, width as f64 - 1.0) as usize
            };
            let (a, b) = (col(r.t_start.max(t0)), col(r.t_end.min(t1)));
            for cell in grid[r.rank][a..=b].iter_mut() {
                *cell = letters[r.label];
            }
        }
        grid.into_iter()
            .enumerate()
            .map(|(rank, row)| format!("r{rank:02} |{}|", row.into_iter().collect::<String>()))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(rank: usize, label: &'static str, t0: f64, t1: f64) -> PhaseRecord {
        PhaseRecord { rank, iteration: 0, label, t_start: t0, t_end: t1 }
    }

    #[test]
    fn durations_and_starts() {
        let log = TraceLog {
            records: vec![rec(0, "DDOT2", 1.0, 1.5), rec(1, "DDOT2", 1.2, 1.4)],
        };
        let d = log.durations_by_rank("DDOT2", 0, 2);
        assert!((d[0] - 0.5).abs() < 1e-12);
        assert!((d[1] - 0.2).abs() < 1e-12);
        let s = log.starts_by_rank("DDOT2", 0, 2);
        assert_eq!(s, vec![1.0, 1.2]);
    }

    #[test]
    fn concurrency_counts_overlaps() {
        let log = TraceLog {
            records: vec![rec(0, "K", 0.0, 2.0), rec(1, "K", 1.0, 3.0), rec(2, "K", 1.5, 1.8)],
        };
        let c = log.concurrency("K");
        let max = c.iter().map(|p| p.count).max().unwrap();
        assert_eq!(max, 3);
        assert_eq!(c.last().unwrap().count, 0);
    }

    #[test]
    fn ascii_render_shape() {
        let log = TraceLog { records: vec![rec(0, "SymGS", 0.0, 0.6), rec(1, "DDOT2", 0.4, 1.0)] };
        let s = log.render_ascii(0.0, 1.0, 2, 40);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains('S'));
        assert!(lines[1].contains('D'));
    }
}
