//! Rank-level co-simulation of barrier-free bulk-synchronous MPI programs
//! on one memory contention domain — the paper's motivating HPCG scenario
//! (Sect. I-A, Figs. 1 and 3) and its proposed application ("a new kind of
//! MPI simulation technique that can take node-level bottlenecks into
//! account", Sect. VI).
//!
//! Each MPI rank executes a *phase program* (loop kernels with data volumes,
//! collectives, point-to-point halo waits, idle noise). At every time step
//! the ranks concurrently inside loop kernels are grouped by kernel and the
//! multigroup sharing model (generalized Eqs. 4+5) assigns each rank its
//! instantaneous bandwidth; kernel progress is the integral of that
//! bandwidth over its data volume.
//!
//! * [`program`] — phase programs and the HPCG program builder,
//! * [`engine`] — the time-stepped co-simulation engine,
//! * [`trace`] — phase traces, concurrency timelines, ASCII rendering,
//! * [`noise`] — reproducible system-noise injection.

mod engine;
mod noise;
mod program;
mod trace;

pub use engine::{CoSimConfig, CoSimEngine, CoSimResult};
pub use noise::NoiseModel;
pub use program::{hpcg_program, HpcgVariant, Phase, Program, SyncKind};
pub use trace::{ConcurrencyPoint, PhaseRecord, TraceLog};
