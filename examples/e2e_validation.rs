//! END-TO-END VALIDATION DRIVER (recorded in EXPERIMENTS.md).
//!
//! Exercises the full three-layer stack on the paper's headline experiment:
//!
//! 1. loads the AOT-compiled JAX/Pallas contention simulator
//!    (`artifacts/contention_sim.hlo.txt`) through PJRT — **no Python at
//!    runtime**;
//! 2. characterizes all 10 pairing-set kernels on all 4 machines via the
//!    artifact (Eq. 3);
//! 3. runs the full Fig. 8 sweep (45 pairings × 4 machines × all symmetric
//!    thread counts) through the artifact, batched 64 configurations at a
//!    time;
//! 4. compares against the analytic model (Eqs. 4+5) and prints the error
//!    table, asserting the paper's headline claim (max error < 8%).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_validation
//! ```

use std::time::Instant;

use membw::config::{machine, MachineId};
use membw::kernels::pairing_set;
use membw::runtime::{ArtifactPaths, PjrtRuntime, PjrtSimExecutor};
use membw::stats::ErrorStats;
use membw::sweep::{pairing_cases, run_cases, symmetric_splits, MeasureEngine};

fn main() {
    let t0 = Instant::now();
    let runtime = PjrtRuntime::cpu().expect("PJRT CPU client");
    println!("PJRT platform: {}", runtime.platform());
    let exec = PjrtSimExecutor::load(&runtime, &ArtifactPaths::default_dir())
        .expect("artifact bundle — run `make artifacts` first");
    println!("artifact: {:?}", exec.meta());
    let engine = MeasureEngine::Pjrt(&exec);

    let pairs = pairing_cases(&pairing_set(), false);
    let mut all_errors: Vec<f64> = Vec::new();
    let mut total_cases = 0usize;
    for mid in MachineId::ALL {
        let m = machine(mid);
        let t_m = Instant::now();
        // One batched sweep per machine: all pairings x all thread counts
        // packed into full 64-config PJRT batches.
        let cases: Vec<_> = pairs.iter().flat_map(|&(k1, k2)| symmetric_splits(&m, k1, k2)).collect();
        total_cases += cases.len();
        let rs = run_cases(&m, &cases, &engine).expect("sweep");
        let machine_errors = rs.all_errors();
        let stats = ErrorStats::of(&machine_errors);
        println!(
            "[{}] {:4} errors | median {:.2}% max {:.2}% | <5%: {:.1}% <8%: {:.1}% | {:.1}s",
            mid.key(),
            stats.n,
            stats.median * 100.0,
            stats.max * 100.0,
            stats.frac_below_5pct * 100.0,
            stats.frac_below_8pct * 100.0,
            t_m.elapsed().as_secs_f64()
        );
        all_errors.extend(machine_errors);
    }

    let global = ErrorStats::of(&all_errors);
    println!(
        "\nGLOBAL over {} pairing cases ({} per-kernel errors): median {:.2}%, max {:.2}%",
        total_cases,
        global.n,
        global.median * 100.0,
        global.max * 100.0
    );
    println!(
        "paper claim: max < 8%, 75% of cases < 5%  |  ours: max {:.2}%, {:.1}% < 5%",
        global.max * 100.0,
        global.frac_below_5pct * 100.0
    );
    println!("total wall time: {:.1}s (all measurement through the PJRT artifact)", t0.elapsed().as_secs_f64());

    assert!(global.max < 0.08, "headline claim violated: max error {:.2}%", global.max * 100.0);
    assert!(global.frac_below_5pct > 0.75);
    println!("E2E VALIDATION OK");
}
