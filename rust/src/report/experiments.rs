//! One report generator per paper table/figure. Each returns the rendered
//! text (also suitable for EXPERIMENTS.md) and writes CSV series under the
//! results directory.

use std::fmt::Write as _;
use std::path::PathBuf;

use crate::config::{builtin_machines, machine, Machine, MachineId};
use crate::desync::{hpcg_program, CoSimConfig, CoSimEngine, HpcgVariant, NoiseModel};
use crate::ecm;
use crate::error::Result;
use crate::kernels::{kernel, pairing_set, KernelClass, KernelId};
use crate::report::table::AsciiTable;
use crate::runtime::PjrtSimExecutor;
use crate::scenario::CharSource;
use crate::simulator::{measure_f_bs, Engine};
use crate::stats::{skewness_dimensioned, BoxSummary, ErrorStats};
use crate::sweep::{
    full_domain_splits, pairing_cases, run_cases, symmetric_splits, MeasureEngine, PairingCase,
    ResultSet,
};

/// Shared context for experiment generation.
pub struct ExperimentCtx {
    /// Output directory for CSV series.
    pub out_dir: PathBuf,
    /// In-process engine used when no PJRT executor is supplied.
    pub engine: Engine,
    /// Optional PJRT executor (the AOT artifact path); preferred when set.
    pub pjrt: Option<PjrtSimExecutor>,
}

impl ExperimentCtx {
    /// Context using the in-process fluid engine.
    pub fn fluid(out_dir: PathBuf) -> Self {
        ExperimentCtx { out_dir, engine: Engine::Fluid, pjrt: None }
    }

    pub(crate) fn measure_engine(&self) -> MeasureEngine<'_> {
        match (&self.pjrt, self.engine) {
            (Some(exec), _) => MeasureEngine::Pjrt(exec),
            (None, Engine::Fluid) => MeasureEngine::Fluid,
            (None, Engine::Des) => MeasureEngine::Des,
        }
    }

    /// Characterization source for co-simulations: the context's measurement
    /// engine, served through the process-wide `CharCache`.
    pub(crate) fn char_source(&self) -> CharSource<'_> {
        CharSource::Measured(self.measure_engine())
    }

    pub(crate) fn engine_name(&self) -> &'static str {
        match (&self.pjrt, self.engine) {
            (Some(_), _) => "pjrt(jax/pallas artifact)",
            (None, Engine::Fluid) => "fluid(rust)",
            (None, Engine::Des) => "des(rust)",
        }
    }

    fn run(&self, m: &Machine, cases: &[PairingCase]) -> Result<ResultSet> {
        run_cases(m, cases, &self.measure_engine())
    }

    fn save(&self, name: &str, rs: &ResultSet) -> Result<()> {
        rs.write_csv(&self.out_dir.join(format!("{name}.csv")))?;
        Ok(())
    }
}

/// The three pairings shown in Figs. 6/7.
fn fig6_pairings() -> [(KernelId, KernelId); 3] {
    [
        (KernelId::Dcopy, KernelId::Ddot2),
        (KernelId::JacobiV1L3, KernelId::Ddot1),
        (KernelId::Stream, KernelId::JacobiV1L2),
    ]
}

/// Table I: machine specifications.
pub fn table1_report() -> String {
    let mut t = AsciiTable::new(&[
        "machine", "model", "uarch", "cores", "GHz", "SIMD", "LLC", "transfers", "theor GB/s", "read GB/s",
    ]);
    for m in builtin_machines() {
        t.row(vec![
            m.id.key().to_string(),
            m.name.clone(),
            m.microarch.clone(),
            m.cores.to_string(),
            format!("{:.2}", m.freq_ghz),
            format!("{}B", m.simd_bytes),
            format!("{:?}", m.llc),
            format!("{:?}", m.overlap),
            format!("{:.1}", m.theor_bw_gbs),
            format!("{:.1}", m.read_bw_gbs),
        ]);
    }
    format!("TABLE I — machine models (paper Table I + calibration)\n\n{}", t.render())
}

/// Table II: kernel characterization — ECM-predicted and Eq.-3-measured
/// `f` and `b_s` on all four machines.
pub fn table2_report(ctx: &ExperimentCtx) -> Result<String> {
    let mut out = String::new();
    writeln!(out, "TABLE II — kernel characterization (engine: {})", ctx.engine_name()).unwrap();
    writeln!(out).unwrap();

    let mut csv = String::from("kernel,machine,mem_lines,code_balance,f_ecm,f_meas,bs_ecm_gbs,bs_meas_gbs,b1_meas_gbs\n");
    let mut t = AsciiTable::new(&[
        "kernel", "transf", "B_c[B/F]", "f bdw1", "f bdw2", "f clx", "f rome", "bs bdw1", "bs bdw2", "bs clx", "bs rome",
    ]);
    for (id, k) in crate::kernels::all_kernels() {
        let mut fs = Vec::new();
        let mut bss = Vec::new();
        for mid in MachineId::ALL {
            let m = machine(mid);
            let meas = match &ctx.pjrt {
                Some(_) => measure_f_bs(&k, &m, Engine::Fluid), // Eq. 3 route
                None => measure_f_bs(&k, &m, ctx.engine),
            };
            let pred = ecm::predict(&k, &m);
            writeln!(
                csv,
                "{},{},{},{:.3},{:.4},{:.4},{:.2},{:.2},{:.2}",
                id.key(),
                mid.key(),
                k.mem.total(),
                k.code_balance,
                pred.f,
                meas.f,
                pred.bs_gbs,
                meas.bs_gbs,
                meas.b1_gbs,
            )
            .unwrap();
            fs.push(meas.f);
            bss.push(meas.bs_gbs);
        }
        let bc = if k.code_balance.is_finite() { format!("{:.2}", k.code_balance) } else { "—".into() };
        let class = match k.class {
            KernelClass::Stencil => " (L3)",
            _ => "",
        };
        t.row(vec![
            k.name.clone(),
            format!("{}{}", k.mem.total(), class),
            bc,
            format!("{:.3}", fs[0]),
            format!("{:.3}", fs[1]),
            format!("{:.3}", fs[2]),
            format!("{:.3}", fs[3]),
            format!("{:.1}", bss[0]),
            format!("{:.1}", bss[1]),
            format!("{:.1}", bss[2]),
            format!("{:.1}", bss[3]),
        ]);
    }
    out.push_str(&t.render());
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("table2.csv"), csv)?;
    Ok(out)
}

/// Fig. 4: the thread parameter space.
pub fn fig4_report() -> String {
    let mut out = String::from("FIG. 4 — thread parameter space (orange = full domain, blue = symmetric)\n\n");
    for mid in MachineId::ALL {
        let m = machine(mid);
        let (orange, blue) = crate::sweep::fig4_points(&m);
        writeln!(
            out,
            "{:5} ({:2} cores): {} full-domain splits, {} symmetric points",
            mid.key(),
            m.cores,
            orange.len(),
            blue.len()
        )
        .unwrap();
    }
    out
}

/// Figs. 6 (full domain) and 7 (symmetric scaling), shared implementation.
fn fig67_report(ctx: &ExperimentCtx, symmetric: bool) -> Result<String> {
    let (figname, split_fn): (_, fn(&Machine, KernelId, KernelId) -> Vec<PairingCase>) = if symmetric {
        ("FIG. 7 — symmetric thread scaling", symmetric_splits as _)
    } else {
        ("FIG. 6 — fully populated domain", full_domain_splits as _)
    };
    let mut out = String::new();
    writeln!(out, "{figname} (engine: {})", ctx.engine_name()).unwrap();

    for (k1, k2) in fig6_pairings() {
        writeln!(out, "\n=== pairing {} + {} ===", kernel(k1).name, kernel(k2).name).unwrap();
        for mid in MachineId::ALL {
            let m = machine(mid);
            let cases = split_fn(&m, k1, k2);
            let rs = ctx.run(&m, &cases)?;
            let tag = format!(
                "{}_{}_{}_{}",
                if symmetric { "fig7" } else { "fig6" },
                mid.key(),
                k1.key(),
                k2.key()
            );
            ctx.save(&tag, &rs)?;
            let mut t = AsciiTable::new(&[
                "n1", "n2", "meas pc1", "model pc1", "meas pc2", "model pc2", "total", "err1%", "err2%",
            ]);
            for c in &rs.cases {
                let e = c.errors();
                t.row(vec![
                    c.n[0].to_string(),
                    c.n[1].to_string(),
                    format!("{:.2}", c.measured_per_core[0]),
                    format!("{:.2}", c.model_per_core[0]),
                    format!("{:.2}", c.measured_per_core[1]),
                    format!("{:.2}", c.model_per_core[1]),
                    format!("{:.1}", c.measured_total),
                    format!("{:.1}", e[0] * 100.0),
                    format!("{:.1}", e[1] * 100.0),
                ]);
            }
            writeln!(out, "\n[{}] per-core bandwidth (GB/s)", mid.key()).unwrap();
            out.push_str(&t.render());
        }
    }
    Ok(out)
}

/// Fig. 6.
pub fn fig6_report(ctx: &ExperimentCtx) -> Result<String> {
    fig67_report(ctx, false)
}

/// Fig. 7.
pub fn fig7_report(ctx: &ExperimentCtx) -> Result<String> {
    fig67_report(ctx, true)
}

/// Fig. 8: modeling-error overview across all pairings, symmetric scaling.
pub fn fig8_report(ctx: &ExperimentCtx) -> Result<String> {
    let pairs = pairing_cases(&pairing_set(), false);
    let mut out = String::new();
    writeln!(
        out,
        "FIG. 8 — relative model error, {} pairings, symmetric scaling (engine: {})",
        pairs.len(),
        ctx.engine_name()
    )
    .unwrap();
    writeln!(out, "error = |(b_observed - b_model) / b_model| per kernel per thread count\n").unwrap();

    let mut all_errors: Vec<f64> = Vec::new();
    let mut csv = String::from("machine,n_per_kernel,kernel1,kernel2,err1,err2\n");
    for mid in MachineId::ALL {
        let m = machine(mid);
        let mut machine_errors: Vec<f64> = Vec::new();
        // Group by thread count for the per-count box plots of the paper.
        let mut by_count: Vec<Vec<f64>> = vec![Vec::new(); m.cores / 2 + 1];
        // One batched sweep per machine: all pairings x all thread counts.
        let cases: Vec<PairingCase> = pairs
            .iter()
            .flat_map(|&(k1, k2)| symmetric_splits(&m, k1, k2))
            .collect();
        let rs = ctx.run(&m, &cases)?;
        {
            for c in &rs.cases {
                let e = c.errors();
                by_count[c.n[0]].extend(e);
                machine_errors.extend(e);
                writeln!(csv, "{},{},{},{},{:.5},{:.5}", mid.key(), c.n[0], c.kernels[0].key(), c.kernels[1].key(), e[0], e[1]).unwrap();
            }
        }
        all_errors.extend(machine_errors.iter());
        let stats = ErrorStats::of(&machine_errors);
        writeln!(
            out,
            "[{}] n={} median {:.2}% max {:.2}% | <5%: {:.0}% of cases, <8%: {:.0}%",
            mid.key(),
            stats.n,
            stats.median * 100.0,
            stats.max * 100.0,
            stats.frac_below_5pct * 100.0,
            stats.frac_below_8pct * 100.0
        )
        .unwrap();
        // Per-thread-count box plot (ASCII) as in the paper's panels.
        for (n, errs) in by_count.iter().enumerate().skip(1) {
            if errs.is_empty() {
                continue;
            }
            let b = BoxSummary::of(errs);
            writeln!(out, "  n={:2} {} max={:.1}%", n, b.render_ascii(0.12, 48), b.max * 100.0).unwrap();
        }
    }
    let global = ErrorStats::of(&all_errors);
    writeln!(
        out,
        "\nGLOBAL: {} cases, median {:.2}%, max {:.2}%, <5%: {:.0}%, <8%: {:.0}%  (paper: max <8%, 75% of cases <5%)",
        global.n,
        global.median * 100.0,
        global.max * 100.0,
        global.frac_below_5pct * 100.0,
        global.frac_below_8pct * 100.0
    )
    .unwrap();
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("fig8_errors.csv"), csv)?;
    Ok(out)
}

/// Fig. 9: bandwidth gain/loss of the first kernel in a pairing relative to
/// its self-paired bandwidth, at half/half occupation.
pub fn fig9_report(ctx: &ExperimentCtx) -> Result<String> {
    let set = pairing_set();
    let mut out = String::new();
    writeln!(out, "FIG. 9 — bandwidth gain/loss vs self-pairing, half/half domain (engine: {})", ctx.engine_name()).unwrap();
    let mut csv = String::from("machine,kernel1,kernel2,percore_gbs,self_gbs,rel\n");

    for mid in MachineId::ALL {
        let m = machine(mid);
        let half = m.cores / 2;
        writeln!(out, "\n[{}]", mid.key()).unwrap();
        // One batched sweep per machine: all (k1, k2) cases at once (the
        // self-pairings are included in the grid, k2 == k1).
        let mut cases: Vec<PairingCase> = Vec::with_capacity(set.len() * set.len());
        for &k1 in &set {
            for &k2 in &set {
                cases.push(PairingCase { k1, k2, n1: half, n2: m.cores - half });
            }
        }
        let rs = ctx.run(&m, &cases)?;
        for (i, &k1) in set.iter().enumerate() {
            let self_pc = rs.cases[i * set.len() + i].measured_per_core[0];
            for (j, &k2) in set.iter().enumerate() {
                let pc = rs.cases[i * set.len() + j].measured_per_core[0];
                let rel = pc / self_pc;
                writeln!(csv, "{},{},{},{:.4},{:.4},{:.4}", mid.key(), k1.key(), k2.key(), pc, self_pc, rel).unwrap();
                let gain = ((rel - 1.0) * 50.0).round().clamp(-20.0, 20.0) as i64;
                let bar: String = if gain >= 0 {
                    format!("{:>20}|{:<20}", "", "+".repeat(gain as usize))
                } else {
                    format!("{:>20}|{:<20}", "-".repeat((-gain) as usize), "")
                };
                writeln!(out, "  {:12} vs {:12} {} {:+.1}%", k1.key(), k2.key(), bar, (rel - 1.0) * 100.0).unwrap();
            }
        }
    }
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("fig9_gainloss.csv"), csv)?;
    Ok(out)
}

/// Fig. 1: plain HPCG co-simulation — desynchronization timelines and
/// per-rank DDOT2 runtimes sorted by start time. The co-sim runs on the
/// event-driven timeline engine; kernel characterizations come from the
/// context's engine through the shared `CharCache`.
pub fn fig1_report(ctx: &ExperimentCtx) -> Result<String> {
    let mut out = String::from("FIG. 1 — plain HPCG co-simulation (multigroup sharing model)\n");
    let mut csv = String::from("machine,rank,sorted_idx,ddot2_start_s,ddot2_duration_ms\n");
    for (mid, ranks) in [(MachineId::Bdw2, 9), (MachineId::Clx, 20)] {
        let m = machine(mid);
        let prog = hpcg_program(HpcgVariant::Plain, 96, 3);
        let cfg = CoSimConfig {
            dt_s: 20e-6,
            t_max_s: 600.0,
            initial_stagger_s: 0.2e-3,
            neighbor_radius: 3,
            noise: NoiseModel::mild(42),
        };
        let eng = CoSimEngine::with_source(&m, prog, ranks, cfg, &ctx.char_source())?;
        let r = eng.run();

        let iter = 1; // skip the first iteration (start-up transient)
        let starts = r.trace.starts_by_rank("DDOT2#1", iter, ranks);
        let durs = r.trace.durations_by_rank("DDOT2#1", iter, ranks);
        let mut order: Vec<usize> = (0..ranks).collect();
        order.sort_by(|&a, &b| starts[a].partial_cmp(&starts[b]).unwrap());

        writeln!(out, "\n[{}] {} ranks — DDOT2 runtime per rank, sorted by start time (early→late):", mid.key(), ranks).unwrap();
        for (idx, &rank) in order.iter().enumerate() {
            writeln!(out, "  #{idx:2} rank {rank:2}: start +{:.3} ms, duration {:.3} ms", (starts[rank] - starts[order[0]]) * 1e3, durs[rank] * 1e3).unwrap();
            writeln!(csv, "{},{},{},{:.6},{:.4}", mid.key(), rank, idx, starts[rank], durs[rank] * 1e3).unwrap();
        }
        let early = durs[order[0]];
        let late = durs[*order.last().unwrap()];
        writeln!(out, "  early-starter {:.3} ms vs late-starter {:.3} ms ({}), paper: late starters are faster", early * 1e3, late * 1e3, if late < early { "late FASTER ✓" } else { "late slower ✗" }).unwrap();

        // Timeline snippet around the DDOT2 of the chosen iteration.
        if let Some(rec) = r.trace.of("DDOT2#1", Some(iter)).first() {
            let t0 = rec.t_start - 0.01;
            writeln!(out, "\n  timeline (S=SymGS, A=SpMV/Allreduce, D=DDOT):").unwrap();
            out.push_str(&r.trace.render_ascii(t0, t0 + 0.05, ranks, 100));
            out.push('\n');
        }
    }
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("fig1_ddot2.csv"), csv)?;
    Ok(out)
}

/// Fig. 3: modified HPCG (no reductions) — concurrency timelines and
/// skewness of the accumulated DDOT time distributions. Runs on the
/// event-driven timeline engine with characterizations from the context's
/// engine (shared `CharCache`).
pub fn fig3_report(ctx: &ExperimentCtx) -> Result<String> {
    let mut out = String::from("FIG. 3 — modified HPCG (no Allreduce) on CLX\n");
    let m = machine(MachineId::Clx);
    let ranks = 20;
    let prog = hpcg_program(HpcgVariant::Modified, 96, 3);
    let cfg = CoSimConfig {
        dt_s: 20e-6,
        t_max_s: 600.0,
        initial_stagger_s: 0.2e-3,
        neighbor_radius: 3,
        noise: NoiseModel::mild(7),
    };
    let eng = CoSimEngine::with_source(&m, prog.clone(), ranks, cfg, &ctx.char_source())?;
    let r = eng.run();

    let mut csv = String::from("label,rank,duration_ms\n");
    writeln!(out, "\nskewness of per-rank accumulated kernel time (cbrt of 3rd central moment, ms):").unwrap();
    // DDOT2#1 tail overlaps the halo wait of SymGS-post (resync expected);
    // DDOT2#2 and DDOT1 are followed by low-f DAXPY/WAXPBY (desync).
    for (label, expect) in [("DDOT2#1", "negative (resync)"), ("DDOT2#2", "positive (desync)"), ("DDOT1", "positive (desync)")] {
        let durs = r.trace.durations_by_rank(label, 1, ranks);
        for (rank, d) in durs.iter().enumerate() {
            writeln!(csv, "{label},{rank},{:.4}", d * 1e3).unwrap();
        }
        let skew_ms = skewness_dimensioned(&durs.iter().map(|d| d * 1e3).collect::<Vec<_>>());
        writeln!(out, "  {label:8}: skew = {skew_ms:+.3} ms (expected {expect})").unwrap();
    }
    writeln!(out, "\nconcurrency timeline of DDOT2#2 (ranks inside the kernel):").unwrap();
    let conc = r.trace.concurrency("DDOT2#2");
    let max_c = conc.iter().map(|p| p.count).max().unwrap_or(0);
    writeln!(out, "  peak concurrency {max_c} of {ranks} ranks ({} boundary events)", conc.len()).unwrap();
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("fig3_skewness.csv"), csv)?;
    Ok(out)
}

/// Ablation (DESIGN.md §5.10): the paper argues that the request fraction
/// `f` — not code balance or plain thread counts — is the right weight for
/// bandwidth sharing. Replay the Fig. 8 sweep scoring the f-model against
/// the equal-share and code-balance baselines.
pub fn ablation_report(ctx: &ExperimentCtx) -> Result<String> {
    use crate::sharing::{code_balance_share, equal_share, KernelGroup};
    let pairs = pairing_cases(&pairing_set(), false);
    let mut out = String::new();
    writeln!(out, "ABLATION — f-model (paper) vs equal-share vs code-balance weighting").unwrap();
    writeln!(out, "error metric as in Fig. 8; symmetric scaling, all pairings
").unwrap();

    let mut err_model: Vec<f64> = Vec::new();
    let mut err_equal: Vec<f64> = Vec::new();
    let mut err_bc: Vec<f64> = Vec::new();
    for mid in MachineId::ALL {
        let m = machine(mid);
        let cases: Vec<PairingCase> = pairs
            .iter()
            .flat_map(|&(k1, k2)| symmetric_splits(&m, k1, k2))
            .collect();
        let rs = ctx.run(&m, &cases)?;
        for c in &rs.cases {
            err_model.extend(c.errors());
            // Equal-share baseline: per-core bandwidth identical across
            // groups = measured_total / n_t (what `equal_share` predicts
            // once normalized to the observed total).
            let nt = (c.n[0] + c.n[1]) as f64;
            let eq_pc = c.measured_total / nt;
            err_equal.push(crate::stats::rel_error(c.measured_per_core[0], eq_pc));
            err_equal.push(crate::stats::rel_error(c.measured_per_core[1], eq_pc));
            // Code-balance baseline: weight by B_c instead of f.
            let b1 = kernel(c.kernels[0]);
            let b2 = kernel(c.kernels[1]);
            let bc = code_balance_share(
                &[
                    KernelGroup { n: c.n[0], f: 1.0, bs_gbs: c.model_total },
                    KernelGroup { n: c.n[1], f: 1.0, bs_gbs: c.model_total },
                ],
                &[b1.code_balance, b2.code_balance],
            );
            // Normalize the code-balance split to the measured total.
            let denom: f64 = bc.groups.iter().map(|e| e.group_bw_gbs).sum();
            for g in 0..2 {
                let pc = if denom > 0.0 && c.n[g] > 0 {
                    c.measured_total * bc.groups[g].group_bw_gbs / denom / c.n[g] as f64
                } else {
                    0.0
                };
                err_bc.push(crate::stats::rel_error(c.measured_per_core[g], pc));
            }
            // Sanity: `equal_share` is the formal version of the eq_pc
            // shortcut above (uniform f) — both split by thread count.
            debug_assert!({
                let es = equal_share(&[
                    KernelGroup { n: c.n[0], f: 0.5, bs_gbs: 60.0 },
                    KernelGroup { n: c.n[1], f: 0.5, bs_gbs: 60.0 },
                ]);
                (es.groups[0].alpha - c.n[0] as f64 / nt).abs() < 1e-9
            });
        }
    }
    for (name, errs) in [("f-model (Eqs. 4+5)", &err_model), ("equal share", &err_equal), ("code balance", &err_bc)] {
        let st = ErrorStats::of(errs);
        writeln!(
            out,
            "{:22} median {:5.2}%  max {:6.2}%  <5%: {:5.1}%  <8%: {:5.1}%",
            name,
            st.median * 100.0,
            st.max * 100.0,
            st.frac_below_5pct * 100.0,
            st.frac_below_8pct * 100.0
        )
        .unwrap();
    }
    writeln!(out, "
paper's argument: f embeds machine overlap behaviour; code balance does not.").unwrap();
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.out_dir.join("ablation.txt"), &out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_model_beats_baselines() {
        let ctx = ExperimentCtx::fluid(std::env::temp_dir().join("membw-ablation"));
        let text = ablation_report(&ctx).unwrap();
        // The f-model line must show a lower max error than both baselines.
        let max_of = |tag: &str| -> f64 {
            let line = text.lines().find(|l| l.starts_with(tag)).unwrap();
            let idx = line.find("max").unwrap();
            line[idx + 3..].trim().split('%').next().unwrap().trim().parse().unwrap()
        };
        assert!(max_of("f-model") < max_of("equal share"));
        assert!(max_of("f-model") < max_of("code balance"));
    }

    #[test]
    fn table1_lists_four_machines() {
        let s = table1_report();
        for key in ["bdw1", "bdw2", "clx", "rome"] {
            assert!(s.contains(key), "missing {key}");
        }
    }

    #[test]
    fn fig4_report_counts() {
        let s = fig4_report();
        assert!(s.contains("9 full-domain splits")); // BDW-1: 10 cores
        assert!(s.contains("10 symmetric points")); // CLX: 20 cores
    }
}
