//! Crate-wide error type.

use thiserror::Error;

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes of the coordinator.
#[derive(Debug, Error)]
pub enum Error {
    /// An unknown machine id was requested from the registry.
    #[error("unknown machine '{0}' (known: {1})")]
    UnknownMachine(String, String),

    /// An unknown kernel name was requested from the registry.
    #[error("unknown kernel '{0}' (known: {1})")]
    UnknownKernel(String, String),

    /// A configuration file failed to parse.
    #[error("config error in {path}: {msg}")]
    Config { path: String, msg: String },

    /// An experiment plan is inconsistent (e.g. thread counts exceed domain).
    #[error("invalid plan: {0}")]
    InvalidPlan(String),

    /// The PJRT runtime failed (client creation, artifact load, execution).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// An AOT artifact is missing — run `make artifacts` first.
    #[error("artifact not found: {0} (run `make artifacts`)")]
    MissingArtifact(String),

    /// A simulation failed to converge to steady state.
    #[error("simulation did not reach steady state: {0}")]
    NoSteadyState(String),

    /// Any I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Convenience constructor for runtime errors from the `xla` crate.
    pub fn runtime<E: std::fmt::Display>(e: E) -> Self {
        Error::Runtime(e.to_string())
    }
}
