"""Layer-1 Pallas kernel: one cycle-chunk of the batched contention simulation.

This is the compute hot-spot of the reproduction: the fluid-queueing model of
a memory contention domain (see DESIGN.md §4 and the Rust mirror in
``rust/src/simulator/fluid.rs`` — the two implementations MUST stay in sync),
advanced ``cycles`` steps for a whole batch of configurations at once.

State/parameter layout (Struct-of-Arrays, f32):

* ``d``      [B, N]  intrinsic demand per core, lines/cycle (0 = idle core)
* ``c``      [B, N]  service-cost factor per line (1.0 = pure read)
* ``win``    [B, N]  prefetch-window depth ``W = D0 + beta * d * c * L0``
* ``cap``    [B, 1]  interface capacity, cost-lines/cycle
* ``occ``    [B, N]  queued requests per core (carried state)
* ``served`` [B, N]  cumulative served lines (carried state)

Per cycle: issue ``min(d, max(win - occ, 0))``; drain proportionally to
occupancy with capacity ``cap`` in cost units.

TPU mapping (DESIGN.md §Hardware-Adaptation): configurations are independent,
so the kernel tiles the batch dimension into VMEM-sized blocks and keeps all
six planes resident across the ``fori_loop`` — no HBM round-trips inside a
chunk. ``interpret=True`` everywhere: the CPU PJRT client cannot execute
Mosaic custom-calls; numerics are identical.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default artifact geometry. N_CORES must cover the largest machine (CLX: 20
# cores); the batch tile is sized so the VMEM working set stays small
# (6 planes x 32 x 24 x 4 B ≈ 18 KiB).
BATCH = 64
N_CORES = 24
TILE_B = 32
CHUNK_CYCLES = 4096


def _chunk_kernel(d_ref, c_ref, win_ref, cap_ref, occ_ref, served_ref,
                  occ_out_ref, served_out_ref, *, cycles: int):
    """Advance the fluid model `cycles` steps for one batch tile."""
    d = d_ref[...]
    c = c_ref[...]
    win = win_ref[...]
    cap = cap_ref[...]

    def body(_, state):
        occ, served = state
        # Issue: demand-rate- and window-limited.
        occ = occ + jnp.minimum(d, jnp.maximum(win - occ, 0.0))
        # Service: proportional to occupancy, capacity in cost units.
        occ_cost = jnp.sum(occ * c, axis=1, keepdims=True)
        lam = jnp.minimum(cap / jnp.maximum(occ_cost, 1e-12), 1.0)
        s = lam * occ
        return occ - s, served + s

    occ, served = jax.lax.fori_loop(
        0, cycles, body, (occ_ref[...], served_ref[...]))
    occ_out_ref[...] = occ
    served_out_ref[...] = served


@partial(jax.jit, static_argnames=("cycles",))
def contention_chunk(d, c, win, cap, occ, served, *, cycles: int = CHUNK_CYCLES):
    """Run one chunk of the batched contention simulation via Pallas.

    All arrays are f32; shapes as in the module docstring. Returns the
    updated ``(occ, served)`` state. The caller (the Rust runtime, or
    ``model.simulate``) strings chunks together and handles warm-up.
    """
    b, n = d.shape
    assert b % TILE_B == 0, f"batch {b} must be a multiple of {TILE_B}"
    grid = (b // TILE_B,)
    row_spec = pl.BlockSpec((TILE_B, n), lambda i: (i, 0))
    cap_spec = pl.BlockSpec((TILE_B, 1), lambda i: (i, 0))
    out_shape = (
        jax.ShapeDtypeStruct((b, n), jnp.float32),
        jax.ShapeDtypeStruct((b, n), jnp.float32),
    )
    return pl.pallas_call(
        partial(_chunk_kernel, cycles=cycles),
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec, cap_spec, row_spec, row_spec],
        out_specs=(row_spec, row_spec),
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(d, c, win, cap, occ, served)
