//! Incremental re-rating of placement candidates ("delta evaluation").
//!
//! A neighborhood move changes one or two groups' `(home, remote_frac)`.
//! Only the interfaces whose *member portions* change can produce
//! different water-fill grants: the pass-1 grant of an interface is a
//! pure function of its member `(group, weight, target)` list, and a
//! group contributes at most one portion per memory interface. So a move
//! re-runs [`fill_mem_iface`]/[`fill_link_iface`] on the dirty interfaces
//! only and copies every other grant from the incumbent, keyed by
//! `(group, target)`.
//!
//! **Dirty rule** (validated bit-exact against the full solve by
//! `python/optimizer_mirror.py`, 300 cases × 8 moves):
//!
//! * memory interface `d` is dirty iff some changed group's portion
//!   weight at target `d` differs (exact `f64` inequality — no epsilon);
//! * a link is dirty iff some changed group's `(weight, link)` pair at a
//!   target differs, in which case both the old and the new link of that
//!   target are marked.
//!
//! Clean interfaces see bit-identical member inputs in the same order
//! (portions are group-major and each group posts at most one portion
//! per interface), so copying their grants is exact, not approximate.
//!
//! Gating is where incrementality ends: once [`any_gated`] fires, the
//! Gauss-Seidel fixed point couples every interface, so the evaluator
//! falls back to the full [`share_remote`] solve — trivially
//! bit-identical, just not incremental. The stored state always keeps
//! the *pass-1* grants (what clean-copy needs) and the *final* rates
//! (what scoring needs).
//!
//! Changes may alter a group's `home` and `remote_frac` only; `n`, `f`,
//! and `bs_gbs` must stay fixed (the dirty rule keys on weights, not
//! traffic character — debug-asserted in [`DeltaEval::eval`]).

use crate::error::Result;
use crate::sharing::remote::{
    any_gated, expand_portions, fill_l3_iface, fill_link_iface, fill_mem_iface, lockstep_rate,
    share_remote,
};
use crate::sharing::{GroupKind, Portion, RemoteGroup, TopoShape};

/// Counters of the delta evaluator, merged across a whole search.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeltaStats {
    /// Candidate evaluations performed (full or incremental).
    pub evals: u64,
    /// Interfaces re-rated from scratch.
    pub iface_evals: u64,
    /// Interfaces whose grants were copied from the incumbent.
    pub iface_reused: u64,
    /// Evaluations that fell back to the full Gauss-Seidel solve.
    pub full_solves: u64,
}

impl DeltaStats {
    /// Accumulate another counter set into this one.
    pub fn merge(&mut self, other: DeltaStats) {
        self.evals += other.evals;
        self.iface_evals += other.iface_evals;
        self.iface_reused += other.iface_reused;
        self.full_solves += other.full_solves;
    }
}

/// The result of evaluating a move against an incumbent: the would-be new
/// incumbent state plus counters. Score from [`EvalOutcome::rates`];
/// [`DeltaEval::commit`] it to advance the incumbent.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    groups: Vec<RemoteGroup>,
    portions: Vec<Portion>,
    mem_grant: Vec<f64>,
    link_grant: Vec<f64>,
    l3_grant: Vec<f64>,
    /// Final per-core rate of each group, GB/s (post fixed point when the
    /// candidate is gated).
    pub rates: Vec<f64>,
    /// Whether the candidate needed the Gauss-Seidel fallback.
    pub gated: bool,
    /// Counters of this one evaluation (`evals == 1`).
    pub stats: DeltaStats,
}

/// Incremental evaluator holding one incumbent placement's solved state.
///
/// [`DeltaEval::eval`] takes `&self` — a frontier node's evaluator can
/// score all its neighbor moves from parallel threads, then
/// [`DeltaEval::commit`] the chosen outcome.
#[derive(Debug, Clone)]
pub struct DeltaEval {
    shape: TopoShape,
    links: Vec<(usize, usize)>,
    groups: Vec<RemoteGroup>,
    portions: Vec<Portion>,
    /// Pass-1 (uncapped water-fill) grants per portion — the clean-copy
    /// source. NOT the final grants when the incumbent is gated.
    mem_grant: Vec<f64>,
    link_grant: Vec<f64>,
    l3_grant: Vec<f64>,
    rates: Vec<f64>,
}

impl DeltaEval {
    /// Solve `groups` from scratch and hold the state as the incumbent.
    pub fn new(shape: TopoShape, groups: Vec<RemoteGroup>) -> Result<DeltaEval> {
        let links = shape.links();
        let mut de = DeltaEval {
            shape,
            links,
            groups: Vec::new(),
            portions: Vec::new(),
            mem_grant: Vec::new(),
            link_grant: Vec::new(),
            l3_grant: Vec::new(),
            rates: Vec::new(),
        };
        let outcome = de.solve_full(groups)?;
        de.commit(outcome);
        Ok(de)
    }

    /// Final per-core rates of the incumbent, GB/s, in group order.
    pub fn rates(&self) -> &[f64] {
        &self.rates
    }

    /// The incumbent's groups.
    pub fn groups(&self) -> &[RemoteGroup] {
        &self.groups
    }

    /// Evaluate `changes` (per-group replacements, `(index, new_group)`)
    /// against the incumbent, re-rating dirty interfaces only.
    ///
    /// Bit-identical to solving the changed placement with
    /// [`share_remote`]: same rates always, same grants whenever the
    /// candidate is ungated (property-tested in
    /// `tests/optimizer_conformance.rs` and mirrored in Python).
    pub fn eval(&self, changes: &[(usize, RemoteGroup)]) -> Result<EvalOutcome> {
        let n3 = if self.shape.l3_bw_gbs > 0.0 { self.shape.n_sockets() } else { 0 };
        if changes.is_empty() {
            return Ok(EvalOutcome {
                groups: self.groups.clone(),
                portions: self.portions.clone(),
                mem_grant: self.mem_grant.clone(),
                link_grant: self.link_grant.clone(),
                l3_grant: self.l3_grant.clone(),
                rates: self.rates.clone(),
                gated: false,
                stats: DeltaStats {
                    evals: 1,
                    iface_reused: (self.shape.n_domains() + self.links.len() + n3) as u64,
                    ..DeltaStats::default()
                },
            });
        }

        let nd = self.shape.n_domains();
        let nl = self.links.len();
        let k = self.groups.len();
        let links_modeled = self.shape.link_bw_gbs > 0.0;

        let mut new_groups = self.groups.clone();
        let mut dirty_mem = vec![false; nd];
        let mut dirty_link = vec![false; nl];
        let mut dirty_l3 = vec![false; n3];
        for &(gi, ng) in changes {
            let og = &self.groups[gi];
            debug_assert!(
                ng.n == og.n && ng.f == og.f && ng.bs_gbs == og.bs_gbs && ng.kind == og.kind,
                "delta changes may only move a group, not change its traffic character"
            );
            match og.kind {
                // A compute-bound group posts no portions: moving it
                // changes nothing anywhere in the fixed point.
                GroupKind::Compute => {
                    new_groups[gi] = ng;
                    continue;
                }
                // An L3-resident group posts one portion on its home
                // socket's L3 plus (when it drains to DRAM at all) the
                // tandem continuation on the home memory interface; a
                // home move dirties both ends of both.
                GroupKind::L3 { .. } => {
                    if ng.home != og.home {
                        dirty_l3[self.shape.socket_of[og.home]] = true;
                        dirty_l3[self.shape.socket_of[ng.home]] = true;
                        if og.f * og.bs_gbs > 0.0 {
                            dirty_mem[og.home] = true;
                            dirty_mem[ng.home] = true;
                        }
                    }
                    new_groups[gi] = ng;
                    continue;
                }
                GroupKind::Mem => {}
            }
            // Per-target (weight, link) of the old and new routing.
            let mut old_w = vec![(0.0f64, None); nd];
            for (t, link, w) in crate::sharing::portion_routes(
                &self.shape.socket_of,
                &self.links,
                links_modeled,
                og.home,
                og.remote_frac,
            ) {
                old_w[t] = (w, link);
            }
            let mut new_w = vec![(0.0f64, None); nd];
            for (t, link, w) in crate::sharing::portion_routes(
                &self.shape.socket_of,
                &self.links,
                links_modeled,
                ng.home,
                ng.remote_frac,
            ) {
                new_w[t] = (w, link);
            }
            for t in 0..nd {
                let (wo, lo) = old_w[t];
                let (wn, ln) = new_w[t];
                if wo != wn {
                    dirty_mem[t] = true;
                }
                if (wo, lo) != (wn, ln) {
                    if let Some(li) = lo {
                        dirty_link[li] = true;
                    }
                    if let Some(li) = ln {
                        dirty_link[li] = true;
                    }
                }
            }
            new_groups[gi] = ng;
        }

        let new_portions = expand_portions(&self.shape, &new_groups, &self.links)?;
        let np = new_portions.len();

        // Old portion index per (group, target): unique once split by
        // the mem flag, because a group posts at most one mem-facing
        // portion per target plus (for L3 groups) one L3-facing one.
        let mut old_at_mem = vec![usize::MAX; k * nd];
        let mut old_at_l3 = vec![usize::MAX; k * nd];
        for (i, p) in self.portions.iter().enumerate() {
            if p.mem {
                old_at_mem[p.group * nd + p.target] = i;
            } else {
                old_at_l3[p.group * nd + p.target] = i;
            }
        }

        // One pass over the new portions: collect member lists of the
        // dirty interfaces, copy incumbent grants everywhere else.
        let mut mem_grant = vec![0.0f64; np];
        let mut link_grant = vec![0.0f64; np];
        let mut l3_grant = vec![0.0f64; np];
        let mut mem_idx: Vec<Vec<usize>> = vec![Vec::new(); nd];
        let mut link_idx: Vec<Vec<usize>> = vec![Vec::new(); nl];
        let mut l3_idx: Vec<Vec<usize>> = vec![Vec::new(); n3];
        for (i, p) in new_portions.iter().enumerate() {
            if p.mem {
                if dirty_mem[p.target] {
                    mem_idx[p.target].push(i);
                } else {
                    mem_grant[i] = self.mem_grant[old_at_mem[p.group * nd + p.target]];
                }
            }
            if let Some(li) = p.link {
                if dirty_link[li] {
                    link_idx[li].push(i);
                } else {
                    link_grant[i] = self.link_grant[old_at_mem[p.group * nd + p.target]];
                }
            }
            if let Some(s3) = p.l3 {
                if dirty_l3[s3] {
                    l3_idx[s3].push(i);
                } else {
                    l3_grant[i] = self.l3_grant[old_at_l3[p.group * nd + p.target]];
                }
            }
        }

        let caps = vec![f64::INFINITY; k];
        let mut stats = DeltaStats { evals: 1, ..DeltaStats::default() };
        for d in 0..nd {
            if dirty_mem[d] {
                fill_mem_iface(
                    &self.shape,
                    &new_groups,
                    &new_portions,
                    &mem_idx[d],
                    d,
                    &caps,
                    &mut mem_grant,
                );
                stats.iface_evals += 1;
            } else {
                stats.iface_reused += 1;
            }
        }
        for li in 0..nl {
            if dirty_link[li] {
                fill_link_iface(
                    &self.shape,
                    &new_groups,
                    &new_portions,
                    &link_idx[li],
                    li,
                    &self.links,
                    &caps,
                    &mut link_grant,
                );
                stats.iface_evals += 1;
            } else {
                stats.iface_reused += 1;
            }
        }
        for s in 0..n3 {
            if dirty_l3[s] {
                fill_l3_iface(
                    &self.shape,
                    &new_groups,
                    &new_portions,
                    &l3_idx[s],
                    &caps,
                    &mut l3_grant,
                );
                stats.iface_evals += 1;
            } else {
                stats.iface_reused += 1;
            }
        }

        let rates: Vec<f64> = (0..k)
            .map(|gi| {
                lockstep_rate(&new_groups, &new_portions, &mem_grant, &link_grant, &l3_grant, gi)
            })
            .collect();

        if any_gated(&new_groups, &new_portions, &mem_grant, &link_grant, &l3_grant, &rates) {
            // The fixed point couples every interface; fall back to the
            // full solve for the rates but keep the pass-1 grants as the
            // clean-copy source of later moves.
            let full = share_remote(&self.shape, &new_groups)?;
            stats.full_solves += 1;
            return Ok(EvalOutcome {
                groups: new_groups,
                portions: new_portions,
                mem_grant,
                link_grant,
                l3_grant,
                rates: full.per_core_gbs,
                gated: true,
                stats,
            });
        }

        Ok(EvalOutcome {
            groups: new_groups,
            portions: new_portions,
            mem_grant,
            link_grant,
            l3_grant,
            rates,
            gated: false,
            stats,
        })
    }

    /// Make `outcome` the new incumbent.
    pub fn commit(&mut self, outcome: EvalOutcome) {
        self.groups = outcome.groups;
        self.portions = outcome.portions;
        self.mem_grant = outcome.mem_grant;
        self.link_grant = outcome.link_grant;
        self.l3_grant = outcome.l3_grant;
        self.rates = outcome.rates;
    }

    /// Full from-scratch solve shaped as an [`EvalOutcome`] (used by
    /// [`DeltaEval::new`]): pass-1 fill for the grant store, final rates
    /// from [`share_remote`].
    fn solve_full(&self, groups: Vec<RemoteGroup>) -> Result<EvalOutcome> {
        let portions = expand_portions(&self.shape, &groups, &self.links)?;
        let np = portions.len();
        let nd = self.shape.n_domains();
        let caps = vec![f64::INFINITY; groups.len()];
        let mut mem_grant = vec![0.0f64; np];
        let mut link_grant = vec![0.0f64; np];
        let mut l3_grant = vec![0.0f64; np];
        let mut stats = DeltaStats { evals: 1, ..DeltaStats::default() };
        for d in 0..nd {
            let idx: Vec<usize> =
                (0..np).filter(|&p| portions[p].target == d && portions[p].mem).collect();
            fill_mem_iface(&self.shape, &groups, &portions, &idx, d, &caps, &mut mem_grant);
            stats.iface_evals += 1;
        }
        for li in 0..self.links.len() {
            let idx: Vec<usize> = (0..np).filter(|&p| portions[p].link == Some(li)).collect();
            fill_link_iface(
                &self.shape,
                &groups,
                &portions,
                &idx,
                li,
                &self.links,
                &caps,
                &mut link_grant,
            );
            stats.iface_evals += 1;
        }
        let n3 = if self.shape.l3_bw_gbs > 0.0 { self.shape.n_sockets() } else { 0 };
        for s in 0..n3 {
            let idx: Vec<usize> = (0..np).filter(|&p| portions[p].l3 == Some(s)).collect();
            fill_l3_iface(&self.shape, &groups, &portions, &idx, &caps, &mut l3_grant);
            stats.iface_evals += 1;
        }
        let rates: Vec<f64> = (0..groups.len())
            .map(|gi| lockstep_rate(&groups, &portions, &mem_grant, &link_grant, &l3_grant, gi))
            .collect();
        let gated = any_gated(&groups, &portions, &mem_grant, &link_grant, &l3_grant, &rates);
        let rates = if gated {
            stats.full_solves += 1;
            share_remote(&self.shape, &groups)?.per_core_gbs
        } else {
            rates
        };
        Ok(EvalOutcome { groups, portions, mem_grant, link_grant, l3_grant, rates, gated, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharing::share_remote;
    use crate::simulator::XorShift64;

    fn shape(nd_per_socket: usize, sockets: usize, link: f64) -> TopoShape {
        let mut socket_of = Vec::new();
        for s in 0..sockets {
            for _ in 0..nd_per_socket {
                socket_of.push(s);
            }
        }
        let n = socket_of.len();
        TopoShape {
            socket_of,
            bw_scale: vec![1.0; n],
            link_bw_gbs: link,
            link_bw_rev_gbs: link,
            l3_bw_gbs: 0.0,
        }
    }

    fn shape_l3(nd_per_socket: usize, sockets: usize, link: f64, l3: f64) -> TopoShape {
        TopoShape { l3_bw_gbs: l3, ..shape(nd_per_socket, sockets, link) }
    }

    fn random_groups(rng: &mut XorShift64, nd: usize, k: usize) -> Vec<RemoteGroup> {
        (0..k)
            .map(|_| RemoteGroup {
                home: rng.next_below(nd),
                n: 1 + rng.next_below(8),
                f: 0.05 + 0.9 * rng.next_f64(),
                bs_gbs: 10.0 + 40.0 * rng.next_f64(),
                remote_frac: if nd >= 2 && rng.next_below(2) == 1 {
                    [0.0, 0.1, 0.25, 0.5][rng.next_below(4)]
                } else {
                    0.0
                },
                kind: GroupKind::Mem,
            })
            .collect()
    }

    /// Like [`random_groups`] but roughly a third of the groups are
    /// L3-resident (local-only, with and without a DRAM tandem) and a
    /// sixth compute-bound, exercising every portion flavour.
    fn random_kinded_groups(rng: &mut XorShift64, nd: usize, k: usize) -> Vec<RemoteGroup> {
        let mut groups = random_groups(rng, nd, k);
        for g in &mut groups {
            match rng.next_below(6) {
                0 | 1 => {
                    g.remote_frac = 0.0;
                    if rng.next_below(2) == 0 {
                        g.f = 0.0;
                        g.bs_gbs = 0.0;
                    }
                    g.kind = GroupKind::L3 {
                        f_l3: 0.2 + 0.6 * rng.next_f64(),
                        bs_l3_gbs: 40.0 + 40.0 * rng.next_f64(),
                    };
                }
                2 => g.kind = GroupKind::Compute,
                _ => {}
            }
        }
        groups
    }

    fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn delta_matches_full_solve_on_random_move_sequences() {
        let mut rng = XorShift64::new(0xD17A);
        for case in 0..60 {
            let sh = shape(2, 2, if case % 3 == 0 { 0.0 } else { 30.0 });
            let nd = sh.n_domains();
            let mut groups = random_groups(&mut rng, nd, 2 + rng.next_below(4));
            let mut de = DeltaEval::new(sh.clone(), groups.clone()).unwrap();
            for _ in 0..6 {
                let gi = rng.next_below(groups.len());
                let mut ng = groups[gi];
                if rng.next_below(2) == 0 {
                    ng.home = rng.next_below(nd);
                } else {
                    ng.remote_frac = [0.0, 0.1, 0.25, 0.5][rng.next_below(4)];
                }
                let outcome = de.eval(&[(gi, ng)]).unwrap();
                groups[gi] = ng;
                let full = share_remote(&sh, &groups).unwrap();
                assert_bits_eq(&outcome.rates, &full.per_core_gbs, "rates");
                de.commit(outcome);
            }
        }
    }

    #[test]
    fn delta_matches_full_solve_with_l3_and_compute_groups() {
        let mut rng = XorShift64::new(0xCAC4E);
        for case in 0..40 {
            let sh = shape_l3(2, 2, if case % 3 == 0 { 0.0 } else { 30.0 }, 120.0);
            let nd = sh.n_domains();
            let mut groups = random_kinded_groups(&mut rng, nd, 3 + rng.next_below(4));
            let mut de = DeltaEval::new(sh.clone(), groups.clone()).unwrap();
            for _ in 0..6 {
                let gi = rng.next_below(groups.len());
                let mut ng = groups[gi];
                if matches!(ng.kind, GroupKind::Mem) && rng.next_below(2) == 0 {
                    ng.remote_frac = [0.0, 0.1, 0.25, 0.5][rng.next_below(4)];
                } else {
                    ng.home = rng.next_below(nd);
                }
                let outcome = de.eval(&[(gi, ng)]).unwrap();
                groups[gi] = ng;
                let full = share_remote(&sh, &groups).unwrap();
                assert_bits_eq(&outcome.rates, &full.per_core_gbs, "rates");
                de.commit(outcome);
            }
        }
    }

    #[test]
    fn empty_change_reproduces_the_incumbent() {
        let sh = shape(2, 2, 30.0);
        let groups = random_groups(&mut XorShift64::new(3), 4, 3);
        let de = DeltaEval::new(sh, groups).unwrap();
        let outcome = de.eval(&[]).unwrap();
        assert_bits_eq(&outcome.rates, de.rates(), "rates");
        assert_eq!(outcome.stats.iface_evals, 0);
    }

    #[test]
    fn swap_move_marks_both_groups_dirty_and_matches() {
        let sh = shape(1, 2, 25.0);
        let mut groups = vec![
            RemoteGroup {
                home: 0,
                n: 4,
                f: 0.4,
                bs_gbs: 30.0,
                remote_frac: 0.25,
                kind: GroupKind::Mem,
            },
            RemoteGroup {
                home: 1,
                n: 4,
                f: 0.6,
                bs_gbs: 25.0,
                remote_frac: 0.0,
                kind: GroupKind::Mem,
            },
        ];
        let de = DeltaEval::new(sh.clone(), groups.clone()).unwrap();
        let changes = vec![
            (0usize, RemoteGroup { home: 1, ..groups[0] }),
            (1usize, RemoteGroup { home: 0, ..groups[1] }),
        ];
        let outcome = de.eval(&changes).unwrap();
        groups[0].home = 1;
        groups[1].home = 0;
        let full = share_remote(&sh, &groups).unwrap();
        assert_bits_eq(&outcome.rates, &full.per_core_gbs, "rates");
    }
}
