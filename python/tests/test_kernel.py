"""Pallas kernel vs pure-jnp reference — the core L1 correctness signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.contention import TILE_B, contention_chunk
from compile.kernels.ref import ref_chunk, ref_chunk_py


def make_case(rng, b, n, idle_frac=0.2):
    """Random but physically plausible configuration batch."""
    d = rng.uniform(0.02, 0.25, size=(b, n)).astype(np.float32)
    idle = rng.uniform(size=(b, n)) < idle_frac
    d[idle] = 0.0
    c = rng.uniform(1.0, 1.3, size=(b, n)).astype(np.float32)
    l0 = rng.uniform(180.0, 280.0, size=(b, 1)).astype(np.float32)
    win = (1.5 + d * c * l0).astype(np.float32)
    cap = rng.uniform(0.2, 0.7, size=(b, 1)).astype(np.float32)
    occ = np.zeros((b, n), np.float32)
    served = np.zeros((b, n), np.float32)
    return d, c, win, cap, occ, served


def test_pallas_matches_jnp_reference():
    rng = np.random.default_rng(42)
    args = make_case(rng, TILE_B * 2, 24)
    got_occ, got_served = contention_chunk(*args, cycles=512)
    want_occ, want_served = ref_chunk(*args, cycles=512)
    np.testing.assert_allclose(got_occ, want_occ, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(got_served, want_served, rtol=2e-5, atol=1e-4)


def test_pallas_matches_python_loop():
    rng = np.random.default_rng(7)
    args = make_case(rng, TILE_B, 6)
    got_occ, got_served = contention_chunk(*args, cycles=64)
    want_occ, want_served = ref_chunk_py(*args, cycles=64)
    np.testing.assert_allclose(got_occ, want_occ, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_served, want_served, rtol=1e-4, atol=1e-3)


def test_state_chaining_equivalent_to_single_run():
    """Two chunks of S cycles == one chunk of 2S cycles (state carries)."""
    rng = np.random.default_rng(3)
    d, c, win, cap, occ, served = make_case(rng, TILE_B, 8)
    o1, s1 = contention_chunk(d, c, win, cap, occ, served, cycles=256)
    o1, s1 = contention_chunk(d, c, win, cap, o1, s1, cycles=256)
    o2, s2 = contention_chunk(d, c, win, cap, occ, served, cycles=512)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(s1, s2, rtol=2e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    cycles=st.integers(min_value=1, max_value=256),
)
def test_kernel_invariants_hypothesis(n, seed, cycles):
    """Property sweep: conservation and non-negativity for arbitrary shapes."""
    rng = np.random.default_rng(seed)
    d, c, win, cap, occ, served = make_case(rng, TILE_B, n)
    occ2, served2 = contention_chunk(d, c, win, cap, occ, served, cycles=cycles)
    occ2 = np.asarray(occ2)
    served2 = np.asarray(served2)
    assert (occ2 >= -1e-5).all()
    assert (served2 >= -1e-5).all()
    # Occupancy never exceeds the window.
    assert (occ2 <= np.asarray(win) + 1e-4).all()
    # Served cost per cycle cannot exceed capacity.
    served_cost = (served2 * np.asarray(c)).sum(axis=1)
    assert (served_cost <= np.asarray(cap)[:, 0] * cycles * (1 + 1e-5)).all()
    # Idle cores never get bandwidth.
    assert (served2[np.asarray(d) == 0.0] == 0.0).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kernel_matches_reference_hypothesis(seed):
    rng = np.random.default_rng(seed)
    args = make_case(rng, TILE_B, int(rng.integers(2, 24)))
    got = contention_chunk(*args, cycles=128)
    want = ref_chunk(*args, cycles=128)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=5e-5, atol=1e-4)


def test_batch_must_be_tile_multiple():
    rng = np.random.default_rng(0)
    args = make_case(rng, TILE_B + 1, 4)
    with pytest.raises(AssertionError):
        contention_chunk(*args, cycles=8)
