//! Machine models — the paper's Table I, augmented with the calibration
//! parameters our simulator substrate needs (queue latency, prefetch depth,
//! write-service penalty).
//!
//! Calibration anchors (paper Table II, STREAM row):
//!
//! | machine | f (STREAM) | b_s (STREAM) | b_s (read-only) |
//! |---------|-----------|--------------|-----------------|
//! | BDW-1   | 0.309     |  53.2 GB/s   | ~66.9 GB/s      |
//! | BDW-2   | 0.228     |  62.2 GB/s   | ~66.9 GB/s      |
//! | CLX     | 0.199     | 102.4 GB/s   | ~110  GB/s      |
//! | Rome    | 0.838     |  32.2 GB/s   | ~35   GB/s      |

use crate::error::{Error, Result};

/// Identifiers of the four machines the paper validates on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MachineId {
    /// Intel Xeon E5-2630 v4 (Broadwell EP, 10 cores/domain).
    Bdw1,
    /// Intel Xeon E5-2697 v4 (Broadwell EP, 18 cores/domain).
    Bdw2,
    /// Intel Xeon Gold 6248 (Cascade Lake SP, 20 cores/domain).
    Clx,
    /// AMD Epyc 7452 "Rome" in NPS4 mode (8 cores/ccNUMA domain).
    Rome,
}

impl MachineId {
    /// All built-in machines in paper order (columns (a)–(d) of Figs. 6–9).
    pub const ALL: [MachineId; 4] = [MachineId::Bdw1, MachineId::Bdw2, MachineId::Clx, MachineId::Rome];

    /// Short lowercase name used on the CLI and in file names.
    pub fn key(&self) -> &'static str {
        match self {
            MachineId::Bdw1 => "bdw1",
            MachineId::Bdw2 => "bdw2",
            MachineId::Clx => "clx",
            MachineId::Rome => "rome",
        }
    }

    /// Parse a CLI key (case-insensitive, whitespace-trimmed; accepts the
    /// paper's own spellings — `BDW-1`, `CLX`, `Rome` — next to the short
    /// keys). Every machine-name flag in the CLI routes through here, so
    /// aliases behave identically everywhere.
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "bdw1" | "bdw-1" | "broadwell1" | "broadwell-1" => Ok(MachineId::Bdw1),
            "bdw2" | "bdw-2" | "broadwell2" | "broadwell-2" => Ok(MachineId::Bdw2),
            "clx" | "clx-sp" | "cascadelake" | "cascade-lake" => Ok(MachineId::Clx),
            "rome" | "rome-nps4" | "epyc" | "zen2" => Ok(MachineId::Rome),
            other => Err(Error::UnknownMachine(
                other.to_string(),
                "bdw1, bdw2, clx, rome".to_string(),
            )),
        }
    }
}

/// Look up a machine by any accepted CLI spelling (see [`MachineId::parse`]).
pub fn machine_by_name(s: &str) -> Result<Machine> {
    Ok(machine(MachineId::parse(s)?))
}

/// Last-level-cache organization (Table I "LLC organization").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LlcKind {
    /// Inclusive LLC (BDW): every memory line also moves over L2↔L3.
    Inclusive,
    /// Victim LLC (CLX, Rome): loads go memory→L2 directly; only evicted
    /// (dirty) lines travel L2↔L3.
    Victim,
}

/// Overlap behaviour of in-hierarchy transfers (Table I "El. transfers").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverlapKind {
    /// Intel server CPUs: data transfers serialize (ECM sum rule, Eq. 1).
    NonOverlapping,
    /// AMD Rome: cache transfers overlap with memory transfers (max rule),
    /// pushing the memory request fraction f towards 1.
    Overlapping,
}

/// Queueing/calibration parameters of the simulated memory interface.
///
/// These encode the *mechanisms* the analytic model deliberately ignores —
/// they are the source of the (small) model error measured in Fig. 8.
#[derive(Debug, Clone, Copy)]
pub struct QueueParams {
    /// Unloaded memory latency in core cycles.
    pub base_latency_cy: f64,
    /// Additive prefetch depth floor (lines a core keeps in flight even at
    /// negligible demand). Compresses shares towards equality — a real
    /// second-order effect the analytic model does not capture.
    pub depth_floor: f64,
    /// Bandwidth-delay scaling of the prefetch depth: a core demanding
    /// `d` lines/cy keeps `depth_floor + beta * d * latency` lines queued.
    /// This is the paper's Fig. 5 mechanism ("a kernel with higher f can
    /// queue more requests per core").
    pub depth_beta: f64,
    /// Strength of the ECM latency penalty (`p0 * u(n-1) * (n-1)` in the
    /// simplified recursive scaling model of Hofmann et al. [6]); 1.0 means
    /// the textbook value `p0 = T_Mem/2`.
    pub latency_penalty: f64,
    /// Extra service cost of a written (RFO/write-back) line, as a fraction
    /// of the read service cost. Saturating in the write-line mix; this is
    /// what makes `b_s` kernel-dependent (read-only kernels 5–15% faster).
    pub write_penalty: f64,
}

/// One memory contention domain of a multicore CPU — the paper's Table I row
/// plus simulator calibration.
#[derive(Debug, Clone)]
pub struct Machine {
    /// Registry id.
    pub id: MachineId,
    /// Human-readable name (processor model).
    pub name: String,
    /// Microarchitecture ("Broadwell EP", "Cascade Lake SP", "Zen 2").
    pub microarch: String,
    /// Physical cores on one ccNUMA contention domain (SMT ignored).
    pub cores: usize,
    /// ccNUMA memory domains per socket: 1 on the monolithic Intel chips,
    /// 4 on Rome in NPS4 mode (its Table I row describes *one* of them).
    /// [`crate::topology::Topology::socket`] expands this into explicit
    /// per-domain contention domains.
    pub domains_per_socket: usize,
    /// Fixed (base) clock of core and uncore, GHz.
    pub freq_ghz: f64,
    /// SIMD register width in bytes (32 = AVX2, 64 = AVX-512).
    pub simd_bytes: usize,
    /// Load instructions retired per cycle (Table I "LD/ST throughput").
    pub ld_per_cy: f64,
    /// Store instructions retired per cycle.
    pub st_per_cy: f64,
    /// L1↔L2 bandwidth, bytes per cycle per core.
    pub l1l2_bpc: f64,
    /// L2↔L3 bandwidth, bytes per cycle per core.
    pub l2l3_bpc: f64,
    /// LLC organization.
    pub llc: LlcKind,
    /// Transfer overlap behaviour (ECM machine model rule).
    pub overlap: OverlapKind,
    /// Theoretical memory bandwidth of the domain, GB/s (Table I).
    pub theor_bw_gbs: f64,
    /// Achievable saturated bandwidth of a single-stream read-only kernel,
    /// GB/s (calibration anchor; ≤ theoretical).
    pub read_bw_gbs: f64,
    /// Relative bandwidth loss per concurrent address stream beyond the
    /// first (DRAM page/bank conflicts). Zero on the Intel machines; on
    /// Rome this is what makes `b_s(DSCAL) > b_s(DAXPY) > b_s(STREAM)`
    /// (Table II) and thereby reverses the DSCAL/DAXPY f-ordering.
    pub stream_penalty: f64,
    /// Per-line latency residue in cycles that even perfect prefetching does
    /// not hide (limited MLP). Dominates the low single-core bandwidth of
    /// CLX relative to its saturated bandwidth.
    pub latency_residue_cy: f64,
    /// Whether the latency residue applies to *all* memory lines (Rome: the
    /// single L2↔mem port exposes write-backs too) or only to read/RFO
    /// lines (Intel: store buffers drain write-backs off the critical
    /// path). The Intel setting is what makes f_DSCAL > f_DAXPY there.
    pub residue_on_all_lines: bool,
    /// Saturated bandwidth of the FORWARD direction (lower → higher socket
    /// index) of one inter-socket link, GB/s (QPI/UPI on the Intel
    /// machines, xGMI on Rome). Links are full duplex: each direction of a
    /// socket pair is its own contention interface. Not a Table I
    /// quantity — the paper models a single contention domain; these are
    /// spec-sheet estimates used by the remote-access extension, where each
    /// directed link is an additional contention interface. `0`
    /// disables link contention (remote traffic then only contends on the
    /// target domain's memory interface).
    pub link_bw_gbs: f64,
    /// Saturated bandwidth of the REVERSE direction (higher → lower socket
    /// index), GB/s. Equal to [`Machine::link_bw_gbs`] on the symmetric
    /// full-duplex interconnects of every built-in machine; machine TOML
    /// may set `link_bw_rev_gbs` for asymmetric fabrics (old files without
    /// the key load as symmetric duplex).
    pub link_bw_rev_gbs: f64,
    /// One-way inter-socket hop latency, microseconds. Feeds the
    /// topology-aware collective cost: each Allreduce release on an
    /// `S`-socket topology pays an extra `(S-1) * link_latency_us` of
    /// barrier latency. `0` disables the term.
    pub link_latency_us: f64,
    /// Aggregate shared-L3 bandwidth per SOCKET, GB/s. Not a Table I
    /// quantity — the paper assumes every kernel is memory-bound; this
    /// feeds the cache-topology extension, where L3-resident groups
    /// contend on a per-socket shared-L3 interface instead of the memory
    /// controller. Built-in values are spec-sheet estimates (aggregate
    /// L2↔L3 transfer capability across the socket's cores / CCXs). `0`
    /// disables the L3 interface: the cache-topology layers are then
    /// bit-identical to the memory-only model, and `@l3` mix overrides
    /// are rejected.
    pub l3_bw_gbs: f64,
    /// Queueing calibration of the memory interface.
    pub queue: QueueParams,
}

/// A characterization-relevant fingerprint of a machine row.
///
/// Kernel characterizations (Eq. 3: `b_1`, `b_s`, `f`) depend on the row's
/// core count and memory/link bandwidths — *not* only on its registry
/// [`MachineId`]. Derived rows (SNC sub-domains with halved cores and
/// bandwidth, DIMM-scaled topology domains) share their parent's id but
/// must never share its cache entries, so the characterization cache keys
/// on this fingerprint instead of the bare id (see
/// [`crate::scenario::CharKey`]). Bandwidths are captured as IEEE-754 bit
/// patterns: two rows alias only if they are numerically identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MachineFingerprint {
    /// Registry id of the row (or of the row it was derived from).
    pub id: MachineId,
    /// Cores on the contention domain.
    pub cores: usize,
    /// Bit pattern of the achievable read bandwidth (`read_bw_gbs`).
    read_bw_bits: u64,
    /// Bit pattern of the theoretical bandwidth (`theor_bw_gbs`).
    theor_bw_bits: u64,
    /// Hash of the inter-socket link table (`link_bw_gbs`,
    /// `link_bw_rev_gbs`, `link_latency_us`).
    link_table_bits: u64,
    /// FNV-style fold of every remaining characterization-relevant numeric
    /// (clock, ECM machine parameters, queue calibration, LLC/overlap
    /// kinds): a TOML-loaded row that reuses a registry id but edits, say,
    /// `queue.depth_floor` or `freq_ghz` must not alias the registry
    /// entry's cache line either.
    calib_bits: u64,
}

/// One FNV-1a-style mixing step over a 64-bit word.
fn mix_bits(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
}

impl Machine {
    /// The row's characterization fingerprint (see [`MachineFingerprint`]).
    pub fn fingerprint(&self) -> MachineFingerprint {
        let mut calib = 0xCBF2_9CE4_8422_2325u64; // FNV offset basis
        for v in [
            self.freq_ghz.to_bits(),
            self.simd_bytes as u64,
            self.ld_per_cy.to_bits(),
            self.st_per_cy.to_bits(),
            self.l1l2_bpc.to_bits(),
            self.l2l3_bpc.to_bits(),
            matches!(self.llc, LlcKind::Victim) as u64,
            matches!(self.overlap, OverlapKind::Overlapping) as u64,
            self.stream_penalty.to_bits(),
            self.latency_residue_cy.to_bits(),
            self.residue_on_all_lines as u64,
            self.queue.base_latency_cy.to_bits(),
            self.queue.depth_floor.to_bits(),
            self.queue.depth_beta.to_bits(),
            self.queue.latency_penalty.to_bits(),
            self.queue.write_penalty.to_bits(),
            self.l3_bw_gbs.to_bits(),
        ] {
            calib = mix_bits(calib, v);
        }
        MachineFingerprint {
            id: self.id,
            cores: self.cores,
            read_bw_bits: self.read_bw_gbs.to_bits(),
            theor_bw_bits: self.theor_bw_gbs.to_bits(),
            link_table_bits: self.link_bw_gbs.to_bits()
                ^ self.link_bw_rev_gbs.to_bits().rotate_left(16)
                ^ self.link_latency_us.to_bits().rotate_left(32),
            calib_bits: calib,
        }
    }

    /// Cycles to move one cache line over a path of `bpc` bytes/cycle.
    pub fn line_cycles(&self, bpc: f64) -> f64 {
        crate::CACHE_LINE_BYTES / bpc
    }

    /// Read-only memory bandwidth in bytes per core cycle (domain total).
    pub fn read_bw_bpc(&self) -> f64 {
        self.read_bw_gbs / self.freq_ghz
    }

    /// Memory interface capacity in (read-cost) lines per cycle.
    pub fn capacity_lines_per_cy(&self) -> f64 {
        self.read_bw_bpc() / crate::CACHE_LINE_BYTES
    }

    /// Saturated bandwidth for a traffic mix with `write_frac` of all memory
    /// lines being writes and `streams` concurrent address streams, GB/s.
    ///
    /// The write penalty saturates quickly in the write fraction: empirically
    /// (paper Table II) *any* substantial write stream costs the full
    /// read/write-turnaround penalty, whether it is 1 line of 2 (DSCAL) or
    /// 1 of 4 (STREAM/ADD/WAXPBY). The stream penalty (Rome only) models
    /// DRAM page-conflict losses growing with the number of streams.
    pub fn saturated_bw(&self, write_frac: f64, streams: usize) -> f64 {
        self.read_bw_gbs / self.cost_factor(write_frac, streams)
    }

    /// Mean service-cost factor per line of a traffic mix (1.0 = one pure
    /// read stream). `b_s = read_bw / cost_factor`.
    pub fn cost_factor(&self, write_frac: f64, streams: usize) -> f64 {
        let g = 1.0 - (-write_frac / 0.12).exp(); // saturating mix response
        let wr = 1.0 + self.queue.write_penalty * g;
        let extra = streams.saturating_sub(1) as f64;
        let st = (1.0 - self.stream_penalty * extra).max(0.5);
        wr / st
    }

    /// Convert a line rate (lines/cy, domain aggregate) to GB/s.
    pub fn lines_per_cy_to_gbs(&self, lines_per_cy: f64) -> f64 {
        lines_per_cy * crate::CACHE_LINE_BYTES * self.freq_ghz
    }
}

/// Look up a built-in machine.
pub fn machine(id: MachineId) -> Machine {
    builtin_machines()
        .into_iter()
        .find(|m| m.id == id)
        .expect("all MachineId variants are built in")
}

/// The four machines of the paper (Table I) with simulator calibration.
pub fn builtin_machines() -> Vec<Machine> {
    vec![
        Machine {
            id: MachineId::Bdw1,
            name: "Intel Xeon E5-2630 v4".into(),
            microarch: "Broadwell EP".into(),
            cores: 10,
            domains_per_socket: 1,
            freq_ghz: 2.2,
            simd_bytes: 32,
            ld_per_cy: 2.0,
            st_per_cy: 1.0,
            l1l2_bpc: 64.0,
            l2l3_bpc: 32.0,
            llc: LlcKind::Inclusive,
            overlap: OverlapKind::NonOverlapping,
            theor_bw_gbs: 68.3,
            read_bw_gbs: 66.9,
            stream_penalty: 0.0,
            latency_residue_cy: 3.2,
            residue_on_all_lines: false,
            // 2x QPI 9.6 GT/s between the sockets of the dual-socket node,
            // full duplex: 38.4 GB/s per direction.
            link_bw_gbs: 38.4,
            link_bw_rev_gbs: 38.4,
            link_latency_us: 0.6,
            // Estimated aggregate shared-L3 bandwidth per socket.
            l3_bw_gbs: 320.0,
            queue: QueueParams {
                base_latency_cy: 200.0,
                depth_floor: 1.5,
                depth_beta: 1.0,
                latency_penalty: 1.0,
                write_penalty: 0.26,
            },
        },
        Machine {
            id: MachineId::Bdw2,
            name: "Intel Xeon E5-2697 v4".into(),
            microarch: "Broadwell EP".into(),
            cores: 18,
            domains_per_socket: 1,
            freq_ghz: 2.3,
            simd_bytes: 32,
            ld_per_cy: 2.0,
            st_per_cy: 1.0,
            l1l2_bpc: 64.0,
            l2l3_bpc: 32.0,
            llc: LlcKind::Inclusive,
            overlap: OverlapKind::NonOverlapping,
            theor_bw_gbs: 76.8,
            read_bw_gbs: 66.9,
            stream_penalty: 0.0,
            // Longer ring, more cores -> higher uncontended L3/mem latency.
            latency_residue_cy: 6.0,
            residue_on_all_lines: false,
            // Same dual-socket QPI generation as BDW-1.
            link_bw_gbs: 38.4,
            link_bw_rev_gbs: 38.4,
            link_latency_us: 0.6,
            // Estimated aggregate shared-L3 bandwidth per socket.
            l3_bw_gbs: 560.0,
            queue: QueueParams {
                base_latency_cy: 230.0,
                depth_floor: 1.5,
                depth_beta: 1.0,
                latency_penalty: 1.0,
                write_penalty: 0.085,
            },
        },
        Machine {
            id: MachineId::Clx,
            name: "Intel Xeon Gold 6248".into(),
            microarch: "Cascade Lake SP".into(),
            cores: 20,
            domains_per_socket: 1,
            freq_ghz: 2.5,
            simd_bytes: 64,
            ld_per_cy: 2.0,
            st_per_cy: 1.0,
            l1l2_bpc: 64.0,
            l2l3_bpc: 32.0, // 16+16 B/cy mesh
            llc: LlcKind::Victim,
            overlap: OverlapKind::NonOverlapping,
            theor_bw_gbs: 140.8,
            read_bw_gbs: 110.0,
            stream_penalty: 0.0,
            // CLX: single-core bandwidth is low relative to saturated
            // bandwidth ("more scalable", Sect. V) — high per-line residue.
            latency_residue_cy: 6.0,
            residue_on_all_lines: false,
            // 3x UPI 10.4 GT/s on the Gold 6248 dual-socket node.
            link_bw_gbs: 62.4,
            link_bw_rev_gbs: 62.4,
            link_latency_us: 0.5,
            // Estimated aggregate shared-L3 bandwidth per socket.
            l3_bw_gbs: 700.0,
            queue: QueueParams {
                base_latency_cy: 220.0,
                depth_floor: 1.5,
                depth_beta: 1.0,
                latency_penalty: 1.0,
                write_penalty: 0.075,
            },
        },
        Machine {
            id: MachineId::Rome,
            name: "AMD Epyc 7452".into(),
            microarch: "Zen 2 (Rome), NPS4".into(),
            cores: 8,
            domains_per_socket: 4,
            freq_ghz: 2.35,
            simd_bytes: 32,
            ld_per_cy: 2.0,
            st_per_cy: 1.0,
            l1l2_bpc: 64.0,
            l2l3_bpc: 32.0,
            llc: LlcKind::Victim,
            overlap: OverlapKind::Overlapping,
            theor_bw_gbs: 42.7, // 2 DDR4-2666 channels per NPS4 domain
            read_bw_gbs: 35.0,
            stream_penalty: 0.022,
            // Overlapping hierarchy: almost everything hides behind the
            // memory transfer; tiny residue keeps f just below 1.
            latency_residue_cy: 0.9,
            residue_on_all_lines: true,
            // 4x xGMI-2 between the sockets of a dual-socket Rome node.
            link_bw_gbs: 64.0,
            link_bw_rev_gbs: 64.0,
            link_latency_us: 0.7,
            // Estimated aggregate shared-L3 bandwidth per socket.
            l3_bw_gbs: 1400.0,
            queue: QueueParams {
                base_latency_cy: 260.0,
                depth_floor: 1.5,
                depth_beta: 1.0,
                latency_penalty: 0.6,
                write_penalty: 0.02,
            },
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_builtin_machines() {
        let ms = builtin_machines();
        assert_eq!(ms.len(), 4);
        let cores: Vec<usize> = ms.iter().map(|m| m.cores).collect();
        assert_eq!(cores, vec![10, 18, 20, 8]); // Table I / Fig. 6 caption
    }

    #[test]
    fn parse_roundtrip() {
        for id in MachineId::ALL {
            assert_eq!(MachineId::parse(id.key()).unwrap(), id);
        }
        assert!(MachineId::parse("power9").is_err());
    }

    #[test]
    fn parse_accepts_paper_spellings_and_aliases() {
        // The paper writes "BDW-1", "BDW-2", "CLX", "Rome" — all must parse,
        // in any case, with surrounding whitespace.
        let aliases: [(&str, MachineId); 12] = [
            ("BDW-1", MachineId::Bdw1),
            ("broadwell-1", MachineId::Bdw1),
            (" bdw1 ", MachineId::Bdw1),
            ("BDW-2", MachineId::Bdw2),
            ("broadwell-2", MachineId::Bdw2),
            ("CLX", MachineId::Clx),
            ("clx-sp", MachineId::Clx),
            ("cascade-lake", MachineId::Clx),
            ("Rome", MachineId::Rome),
            ("rome-nps4", MachineId::Rome),
            ("EPYC", MachineId::Rome),
            ("zen2", MachineId::Rome),
        ];
        for (name, want) in aliases {
            assert_eq!(MachineId::parse(name).unwrap(), want, "alias '{name}'");
            assert_eq!(machine_by_name(name).unwrap().id, want);
        }
    }

    #[test]
    fn domains_per_socket_matches_table1() {
        // NPS4 Rome has four ccNUMA domains per socket; the Intel chips are
        // monolithic.
        for m in builtin_machines() {
            let want = if m.id == MachineId::Rome { 4 } else { 1 };
            assert_eq!(m.domains_per_socket, want, "{}", m.name);
        }
    }

    #[test]
    fn link_parameters_are_positive_and_below_memory_bandwidth() {
        // Every built-in machine is a dual-socket part in the paper's
        // testbed: the inter-socket link must exist, and one link must be
        // slower than the (socket-aggregate) memory it ships lines for —
        // otherwise remote accesses could never contend on it.
        for m in builtin_machines() {
            assert!(m.link_bw_gbs > 0.0, "{}", m.name);
            // All built-in interconnects are symmetric full duplex.
            assert_eq!(m.link_bw_rev_gbs.to_bits(), m.link_bw_gbs.to_bits(), "{}", m.name);
            assert!(m.link_latency_us > 0.0, "{}", m.name);
            let socket_bw = m.read_bw_gbs * m.domains_per_socket as f64;
            assert!(
                m.link_bw_gbs < socket_bw,
                "{}: link {} !< socket {}",
                m.name,
                m.link_bw_gbs,
                socket_bw
            );
        }
    }

    #[test]
    fn read_only_bandwidth_exceeds_write_bandwidth() {
        for m in builtin_machines() {
            // Compare a 2-stream read-only kernel (DDOT2) against the
            // 4-stream STREAM triad, as the paper does.
            let read = m.saturated_bw(0.0, 2);
            let write = m.saturated_bw(0.25, 4);
            assert!(read > write, "{}: read {read} !> write {write}", m.name);
            // Paper: read-only kernels get roughly 5–15% more.
            let ratio = read / write;
            assert!(
                (1.03..1.30).contains(&ratio),
                "{}: read/write ratio {ratio}",
                m.name
            );
        }
    }

    #[test]
    fn stream_saturated_bandwidth_matches_anchor() {
        // STREAM has 4 memory lines, 1 of which is a write-back -> wf = 0.25.
        // (The RFO line is a read at the interface.)
        let anchors = [
            (MachineId::Bdw1, 53.2),
            (MachineId::Bdw2, 62.2),
            (MachineId::Clx, 102.4),
            (MachineId::Rome, 32.2),
        ];
        for (id, want) in anchors {
            let m = machine(id);
            let got = m.saturated_bw(0.25, 4);
            let err = (got - want).abs() / want;
            assert!(err < 0.03, "{}: b_s(STREAM) = {got:.1}, want {want}", m.name);
        }
    }

    #[test]
    fn fingerprint_discriminates_characterization_relevant_fields() {
        let m = machine(MachineId::Rome);
        assert_eq!(m.fingerprint(), machine(MachineId::Rome).fingerprint());
        let mut halved = m.clone();
        halved.cores /= 2;
        assert_ne!(m.fingerprint(), halved.fingerprint());
        let mut scaled = m.clone();
        scaled.read_bw_gbs *= 0.5;
        assert_ne!(m.fingerprint(), scaled.fingerprint());
        let mut relinked = m.clone();
        relinked.link_latency_us *= 2.0;
        assert_ne!(m.fingerprint(), relinked.fingerprint());
        let mut rev = m.clone();
        rev.link_bw_rev_gbs *= 0.5;
        assert_ne!(m.fingerprint(), rev.fingerprint());
        // Calibration fields matter too: a TOML row reusing the id but
        // editing the queue model or the clock must not alias the cache.
        let mut requeued = m.clone();
        requeued.queue.depth_floor += 0.5;
        assert_ne!(m.fingerprint(), requeued.fingerprint());
        let mut clocked = m.clone();
        clocked.freq_ghz *= 1.1;
        assert_ne!(m.fingerprint(), clocked.fingerprint());
        // The shared-L3 capacity feeds classification and the L3 water-fill.
        let mut recached = m.clone();
        recached.l3_bw_gbs *= 0.5;
        assert_ne!(m.fingerprint(), recached.fingerprint());
    }

    #[test]
    fn capacity_consistent_with_bandwidth() {
        let m = machine(MachineId::Clx);
        let c = m.capacity_lines_per_cy();
        assert!((m.lines_per_cy_to_gbs(c) - m.read_bw_gbs).abs() < 1e-9);
    }
}
