//! Experiment plans — the thread parameter space of Fig. 4.
//!
//! Two families:
//! * **full domain** (orange dots): `n_I + n_II = n_t`, `n_I = 1..n_t-1`;
//! * **symmetric scaling** (blue dots): `n_I = n_II = 1..n_t/2`.

use crate::config::Machine;
use crate::error::{Error, Result};
use crate::kernels::KernelId;

/// Which slice of the Fig. 4 parameter space a plan enumerates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    /// Orange dots: the domain is fully occupied.
    FullDomain,
    /// Blue dots: equal thread counts, scaling towards saturation.
    Symmetric,
}

/// One pairing configuration to measure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PairingCase {
    /// Kernel of group I.
    pub k1: KernelId,
    /// Kernel of group II.
    pub k2: KernelId,
    /// Threads running `k1`.
    pub n1: usize,
    /// Threads running `k2`.
    pub n2: usize,
}

impl PairingCase {
    /// Validate against a machine.
    pub fn validate(&self, m: &Machine) -> Result<()> {
        if self.n1 + self.n2 > m.cores {
            return Err(Error::InvalidPlan(format!(
                "{}+{} threads exceed the {}-core domain of {}",
                self.n1, self.n2, m.cores, m.name
            )));
        }
        if self.n1 == 0 && self.n2 == 0 {
            return Err(Error::InvalidPlan("empty pairing".into()));
        }
        Ok(())
    }
}

/// Full-domain splits of a pairing on a machine (orange dots of Fig. 4).
pub fn full_domain_splits(m: &Machine, k1: KernelId, k2: KernelId) -> Vec<PairingCase> {
    (1..m.cores)
        .map(|n1| PairingCase { k1, k2, n1, n2: m.cores - n1 })
        .collect()
}

/// Symmetric-scaling splits of a pairing (blue dots of Fig. 4).
pub fn symmetric_splits(m: &Machine, k1: KernelId, k2: KernelId) -> Vec<PairingCase> {
    (1..=m.cores / 2)
        .map(|n| PairingCase { k1, k2, n1: n, n2: n })
        .collect()
}

/// All distinct unordered pairs (plus optional self-pairings) from a kernel
/// set — the Fig. 8 (pairs only) and Fig. 9 (with self-pairings) plans.
pub fn pairing_cases(set: &[KernelId], include_self: bool) -> Vec<(KernelId, KernelId)> {
    let mut out = Vec::new();
    for (i, &a) in set.iter().enumerate() {
        for &b in set.iter().skip(if include_self { i } else { i + 1 }) {
            out.push((a, b));
        }
    }
    out
}

/// The complete Fig. 4 dot set for a machine: (n1, n2) tuples.
pub fn fig4_points(m: &Machine) -> (Vec<(usize, usize)>, Vec<(usize, usize)>) {
    let orange = (1..m.cores).map(|n1| (n1, m.cores - n1)).collect();
    let blue = (1..=m.cores / 2).map(|n| (n, n)).collect();
    (orange, blue)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::pairing_set;

    #[test]
    fn full_domain_covers_all_splits_exactly_once() {
        let m = machine(MachineId::Bdw1);
        let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
        assert_eq!(cases.len(), m.cores - 1);
        for c in &cases {
            assert_eq!(c.n1 + c.n2, m.cores);
            c.validate(&m).unwrap();
        }
        let mut n1s: Vec<usize> = cases.iter().map(|c| c.n1).collect();
        n1s.dedup();
        assert_eq!(n1s.len(), m.cores - 1);
    }

    #[test]
    fn symmetric_reaches_half_domain() {
        let m = machine(MachineId::Clx);
        let cases = symmetric_splits(&m, KernelId::Stream, KernelId::JacobiV1L2);
        assert_eq!(cases.len(), 10);
        assert_eq!(cases.last().unwrap().n1, 10);
    }

    #[test]
    fn pairing_counts_match_paper() {
        let set = pairing_set();
        // Fig. 8: "30 pairings per thread count and architecture" — all
        // unordered pairs of a 10-kernel set is 45; the paper used a
        // 30-subset. We generate all 45 and report both (DESIGN.md).
        assert_eq!(pairing_cases(&set, false).len(), 45);
        // Fig. 9: including self-pairings.
        assert_eq!(pairing_cases(&set, true).len(), 55);
    }

    #[test]
    fn invalid_plan_rejected() {
        let m = machine(MachineId::Rome);
        let bad = PairingCase { k1: KernelId::Ddot2, k2: KernelId::Dcopy, n1: 5, n2: 5 };
        assert!(bad.validate(&m).is_err());
    }
}
