//! Dependency-free data parallelism: a dynamically scheduled, lock-free
//! parallel map over OS threads.
//!
//! The build is offline (no rayon), so the crate carries its own minimal
//! worker pool: an atomic ticket counter hands each input index to exactly
//! one worker, results land in pre-sized per-index slots, and a thread
//! scope joins everything before the slots are read back — rayon-style
//! dynamic scheduling without the dependency. Shared by the scenario
//! measurement pipeline (mix fan-out) and the multi-interface DES
//! (independent connected components replay concurrently; see
//! [`crate::simulator::NetDesSimulator`]).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Dynamically scheduled parallel map over a slice (results in input order).
///
/// Workers pull the next index from a shared atomic counter, so long and
/// short items balance automatically — the scheduling rayon's `par_iter`
/// would give, without the dependency (offline build). Results go straight
/// into pre-sized per-index slots: the atomic ticket makes each index the
/// exclusive property of one worker, so the hot path takes no lock and
/// needs no post-sort.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(items.len());
    let next = AtomicUsize::new(0);

    struct Slots<R>(Vec<UnsafeCell<Option<R>>>);
    // SAFETY: each index is claimed by exactly one worker via the unique
    // `fetch_add` ticket below, so no cell is ever aliased across threads;
    // the thread scope joins all workers before the slots are read back.
    unsafe impl<R: Send> Sync for Slots<R> {}

    let slots: Slots<R> = Slots((0..items.len()).map(|_| UnsafeCell::new(None)).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: ticket `i` is unique to this worker (see above).
                unsafe { *slots.0[i].get() = Some(r) };
            });
        }
    });
    slots
        .0
        .into_iter()
        .map(|c| c.into_inner().expect("every slot written by a worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = par_map(&items, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
        assert!(par_map(&[] as &[usize], |&x: &usize| x).is_empty());
    }

    #[test]
    fn par_map_fills_every_slot_under_unbalanced_load() {
        // Highly skewed per-item cost exercises the dynamic scheduling; a
        // lost or duplicated ticket would leave a hole or wrong value.
        let items: Vec<usize> = (0..503).collect();
        let out = par_map(&items, |&x| {
            if x % 97 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x * x
        });
        assert_eq!(out.len(), items.len());
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }
}
