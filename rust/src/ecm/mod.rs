//! Execution-Cache-Memory (ECM) performance model — the paper's modeling
//! substrate (Hofmann et al. [6,7], Stengel et al. [8]).
//!
//! Provides:
//! * the single-core composition rule (Eq. 1) for non-overlapping (Intel)
//!   and overlapping (AMD Rome) hierarchies,
//! * the memory request fraction `f = T_Mem / T_ECM` (Eq. 2),
//! * saturated-bandwidth prediction per kernel (read/write service mix),
//! * the simplified recursive multicore scaling model with latency penalty
//!   `p0 * u(n-1) * (n-1)`, `p0 = T_Mem/2` (Sect. III).

mod application;
mod prediction;
mod scaling;

pub use application::{effective_l3_lines, ApplicationModel};
pub use prediction::{predict, EcmPrediction};
pub use scaling::{scaling_curve, ScalingPoint};
