//! Topology conformance suite.
//!
//! Three pins required by the topology layer:
//!
//! 1. **Degenerate equivalence** — every entry point run through a 1-domain
//!    [`Topology`] is bit-identical to its pre-topology single-domain path:
//!    same measured and modeled shares from the mix pipeline, same traces
//!    from the co-simulator. The topology layer must be a strict
//!    generalization, not a reimplementation.
//! 2. **Per-domain model fidelity** — on the 4-domain NPS4 Rome socket with
//!    independent per-domain mixes, every domain's bandwidth shares equal
//!    the paper's Eq. 5 evaluated over that domain's resident groups to
//!    1e-12, and domains are fully independent (a domain's results do not
//!    change when other domains are populated).
//! 3. **Remote-access degeneracy** — the remote extension with
//!    `remote_frac = 0` is bit-identical to the per-domain paths at the
//!    sharing, scenario, and co-simulation layers, while the nonzero-`%r`
//!    dual-socket Rome scenario runs end to end with per-domain *and*
//!    per-link shares (the acceptance case), SNC specs characterize on
//!    their derived rows, and malformed `%r` suffixes surface as
//!    structured `Error::MixParse`.

use membw::config::{machine, MachineId};
use membw::desync::{hpcg_program, CoSimConfig, CoSimEngine, HpcgVariant, NoiseModel};
use membw::error::Error;
use membw::scenario::{
    run_mixes, run_mixes_on, run_scenario, run_scenario_on, CharCache, CharSource, EngineKind,
    Mix, Scenario,
};
use membw::sharing::{share_domains, share_remote, GroupKind, KernelGroup, RemoteGroup};
use membw::sweep::MeasureEngine;
use membw::topology::{Placement, Topology};

/// Mix pipeline, 1-domain topology: measured and modeled per-core values,
/// shares, and totals are bit-identical to `run_mixes` on every machine.
#[test]
fn degenerate_mix_pipeline_is_bit_identical() {
    for mid in MachineId::ALL {
        let m = machine(mid);
        let half = m.cores / 2;
        let mixes = vec![
            Mix::parse(&format!("dcopy:{}+ddot2:{}", half, m.cores - half)).unwrap(),
            Mix::parse(&format!("stream:{half}+idle:{}", m.cores - half)).unwrap(),
        ];
        let flat = run_mixes(&m, &mixes, &MeasureEngine::Fluid).unwrap();
        let topo = Topology::single(&m);
        for placement in [Placement::Compact, Placement::Scatter] {
            let placed = run_mixes_on(&topo, placement, &mixes, &MeasureEngine::Fluid).unwrap();
            for (t, f) in placed.cases.iter().zip(&flat.cases) {
                assert_eq!(t.domain_ids, vec![0], "{mid:?}: one active domain");
                assert_eq!(t.domains[0].mix, f.mix, "{mid:?}: sub-mix is the mix");
                assert_eq!(
                    t.measured_total_gbs.to_bits(),
                    f.measured_total_gbs.to_bits(),
                    "{mid:?}: measured total"
                );
                assert_eq!(t.model_total_gbs.to_bits(), f.model_total_gbs.to_bits());
                for (a, b) in t.domains[0].groups.iter().zip(&f.groups) {
                    assert_eq!(a.measured_per_core.to_bits(), b.measured_per_core.to_bits());
                    assert_eq!(a.model_per_core.to_bits(), b.model_per_core.to_bits());
                    assert_eq!(a.model_alpha.to_bits(), b.model_alpha.to_bits());
                }
                for (a, b) in t.socket.iter().zip(&f.groups) {
                    assert_eq!(a.measured_bw_gbs.to_bits(), b.measured_bw_gbs.to_bits());
                    assert_eq!(a.model_bw_gbs.to_bits(), b.model_bw_gbs.to_bits());
                }
            }
        }
    }
}

/// Scenario pipeline, 1-domain topology: phase-by-phase equivalence.
#[test]
fn degenerate_scenario_pipeline_is_bit_identical() {
    let m = machine(MachineId::Bdw1);
    let sc = Scenario::parse("conf", "dcopy:4+ddot2:6 / dcopy:3+idle:7").unwrap();
    let flat = run_scenario(&m, &sc, &MeasureEngine::Fluid).unwrap();
    let placed =
        run_scenario_on(&Topology::single(&m), Placement::Compact, &sc, &MeasureEngine::Fluid)
            .unwrap();
    assert_eq!(placed.phases.len(), flat.phases.len());
    for (t, f) in placed.phases.iter().zip(&flat.phases) {
        for (a, b) in t.socket.iter().zip(&f.groups) {
            assert_eq!(a.measured_per_core.to_bits(), b.measured_per_core.to_bits());
            assert_eq!(a.model_per_core.to_bits(), b.model_per_core.to_bits());
        }
    }
}

/// Co-simulation, 1-domain topology: noisy Fig. 3-style run produces a
/// bit-identical trace through `with_topology` and the plain engine.
#[test]
fn degenerate_cosim_trace_is_bit_identical() {
    let m = machine(MachineId::Clx);
    let prog = hpcg_program(HpcgVariant::Modified, 48, 2);
    let cfg = CoSimConfig {
        dt_s: 20e-6,
        t_max_s: 600.0,
        initial_stagger_s: 0.2e-3,
        neighbor_radius: 3,
        noise: NoiseModel::mild(7),
    };
    let plain = CoSimEngine::new(&m, prog.clone(), 10, cfg.clone()).unwrap();
    let placed = CoSimEngine::with_topology(
        &m,
        &Topology::single(&m),
        Placement::Compact,
        prog,
        10,
        cfg,
        &CharSource::Ecm,
    )
    .unwrap();
    let (a, b) = (plain.run(), placed.run());
    assert_eq!(a.events, b.events);
    assert_eq!(a.trace.records.len(), b.trace.records.len());
    for (x, y) in a.trace.records.iter().zip(&b.trace.records) {
        assert_eq!(x.rank, y.rank);
        assert_eq!(x.label, y.label);
        assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
        assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
    }
    for (x, y) in a.finish_s.iter().zip(&b.finish_s) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// 4-domain Rome socket, independent per-domain mixes: every domain's
/// model shares reproduce Eq. 5 (`α_i = n_i f_i / Σ n_k f_k`) over that
/// domain's resident groups to 1e-12.
#[test]
fn rome_socket_reproduces_per_domain_eq5_shares() {
    let m = machine(MachineId::Rome);
    let topo = Topology::socket(&m);
    // Four different two-group pairings, one per ccNUMA domain.
    let mix = Mix::parse(
        "dcopy:4@d0+ddot2:4@d0+stream:4@d1+daxpy:4@d1+vecsum:4@d2+dscal:4@d2+waxpby:4@d3+ddot1:4@d3",
    )
    .unwrap();
    let rs = run_mixes_on(&topo, Placement::Compact, &[mix], &MeasureEngine::Fluid).unwrap();
    let case = &rs.cases[0];
    assert_eq!(case.domain_ids, vec![0, 1, 2, 3]);
    let chars = |k| {
        CharCache::global()
            .lookup(&(m.fingerprint(), k, EngineKind::Fluid))
            .expect("characterized by run_mixes_on")
    };
    for dr in &case.domains {
        assert!(dr.saturated, "8 Rome cores saturate the domain");
        let nf: Vec<f64> = dr.groups.iter().map(|g| g.n as f64 * chars(g.kernel).f).collect();
        let total: f64 = nf.iter().sum();
        for (g, nf_i) in dr.groups.iter().zip(&nf) {
            let eq5 = nf_i / total;
            assert!(
                (g.model_alpha - eq5).abs() < 1e-12,
                "{:?}: alpha {} vs Eq.5 {}",
                g.kernel,
                g.model_alpha,
                eq5
            );
        }
    }
}

/// Domains are independent end to end: domain 0's measured and modeled
/// results do not change when the other three domains get populated.
#[test]
fn rome_socket_domains_are_independent() {
    let m = machine(MachineId::Rome);
    let topo = Topology::socket(&m);
    let solo = Mix::parse("dcopy:4@d0+ddot2:4@d0").unwrap();
    let full = Mix::parse(
        "dcopy:4@d0+ddot2:4@d0+stream:8@d1+daxpy:8@d2+schoenauer:4@d3+idle:4",
    )
    .unwrap();
    let a = run_mixes_on(&topo, Placement::Compact, &[solo], &MeasureEngine::Fluid).unwrap();
    let b = run_mixes_on(&topo, Placement::Compact, &[full], &MeasureEngine::Fluid).unwrap();
    let (d0_solo, d0_full) = (&a.cases[0].domains[0], &b.cases[0].domains[0]);
    assert_eq!(d0_solo.groups.len(), d0_full.groups.len());
    for (x, y) in d0_solo.groups.iter().zip(&d0_full.groups) {
        assert_eq!(x.kernel, y.kernel);
        assert_eq!(x.measured_per_core.to_bits(), y.measured_per_core.to_bits());
        assert_eq!(x.model_per_core.to_bits(), y.model_per_core.to_bits());
        assert_eq!(x.model_alpha.to_bits(), y.model_alpha.to_bits());
    }
}

/// Remote conformance, sharing layer: `share_remote` with every fraction
/// at 0 reproduces the per-domain `share_domains` evaluation bit for bit —
/// the remote extension is a strict generalization of PR 3's model.
#[test]
fn remote_zero_share_model_is_bit_identical_to_share_domains() {
    let m = machine(MachineId::Rome);
    let topo = Topology::parse(&m, "2x4").unwrap();
    let shape = topo.shape();
    // Two populated domains (one per socket), two groups each.
    let d0 = vec![
        KernelGroup { n: 4, f: 0.84, bs_gbs: 32.0 },
        KernelGroup { n: 4, f: 0.75, bs_gbs: 33.0 },
    ];
    let d5 = vec![
        KernelGroup { n: 6, f: 0.30, bs_gbs: 35.0 },
        KernelGroup { n: 2, f: 0.55, bs_gbs: 34.0 },
    ];
    let mut remote_groups: Vec<RemoteGroup> = Vec::new();
    for g in &d0 {
        let rg = RemoteGroup { home: 0, n: g.n, f: g.f, bs_gbs: g.bs_gbs, remote_frac: 0.0, kind: GroupKind::Mem };
        remote_groups.push(rg);
    }
    for g in &d5 {
        let rg = RemoteGroup { home: 5, n: g.n, f: g.f, bs_gbs: g.bs_gbs, remote_frac: 0.0, kind: GroupKind::Mem };
        remote_groups.push(rg);
    }
    let remote = share_remote(&shape, &remote_groups).unwrap();
    let local = share_domains(&[d0, d5]);
    for (i, entry) in local[0].groups.iter().enumerate() {
        assert_eq!(remote.per_core_gbs[i].to_bits(), entry.per_core_gbs.to_bits());
        assert_eq!(remote.group_bw_gbs[i].to_bits(), entry.group_bw_gbs.to_bits());
    }
    for (i, entry) in local[1].groups.iter().enumerate() {
        assert_eq!(remote.per_core_gbs[2 + i].to_bits(), entry.per_core_gbs.to_bits());
    }
    assert_eq!(remote.domains[0].b_mix_gbs.to_bits(), local[0].b_mix_gbs.to_bits());
    assert_eq!(remote.domains[5].b_mix_gbs.to_bits(), local[1].b_mix_gbs.to_bits());
    // Nothing crosses the link.
    assert!(remote.portions.iter().all(|p| p.link.is_none()));
}

/// Remote conformance, scenario layer: a scenario whose remote fractions
/// are all zero (explicit `%r0` suffixes and `with_default_remote(0.0)`)
/// is bit-identical to the PR 3 topology pipeline.
#[test]
fn remote_zero_mix_pipeline_is_bit_identical() {
    let m = machine(MachineId::Rome);
    let topo = Topology::socket(&m);
    let plain = vec![
        Mix::parse("dcopy:8@d0+ddot2:8@d1+stream:16@scatter").unwrap(),
        Mix::parse("daxpy:16@scatter+idle:16").unwrap(),
    ];
    // %r0 normalizes to "no remote traffic" at parse time...
    let zeroed = vec![
        Mix::parse("dcopy:8@d0%r0+ddot2:8@d1%r0+stream:16@scatter%r0").unwrap(),
        Mix::parse("daxpy:16@scatter%r0+idle:16").unwrap(),
    ];
    // ...and so does the CLI's --remote-frac 0 default.
    let defaulted: Vec<Mix> = plain.iter().map(|mx| mx.clone().with_default_remote(0.0)).collect();
    let a = run_mixes_on(&topo, Placement::Compact, &plain, &MeasureEngine::Fluid).unwrap();
    for other in [zeroed, defaulted] {
        let b = run_mixes_on(&topo, Placement::Compact, &other, &MeasureEngine::Fluid).unwrap();
        assert_eq!(a.cases.len(), b.cases.len());
        for (x, y) in a.cases.iter().zip(&b.cases) {
            assert_eq!(x.domain_ids, y.domain_ids);
            assert!(y.links.is_empty(), "no remote traffic, no link records");
            assert_eq!(x.measured_total_gbs.to_bits(), y.measured_total_gbs.to_bits());
            assert_eq!(x.model_total_gbs.to_bits(), y.model_total_gbs.to_bits());
            for (dx, dy) in x.domains.iter().zip(&y.domains) {
                for (gx, gy) in dx.groups.iter().zip(&dy.groups) {
                    assert_eq!(gx.measured_per_core.to_bits(), gy.measured_per_core.to_bits());
                    assert_eq!(gx.model_per_core.to_bits(), gy.model_per_core.to_bits());
                    assert_eq!(gx.model_alpha.to_bits(), gy.model_alpha.to_bits());
                }
            }
        }
    }
}

/// The acceptance scenario: a dual-socket Rome (2 x NPS4) with a nonzero
/// remote-access fraction runs end to end and reports per-domain *and*
/// per-link shares.
#[test]
fn rome_2x4_remote_scenario_end_to_end() {
    let m = machine(MachineId::Rome);
    let topo = Topology::parse(&m, "2x4").unwrap();
    assert_eq!(topo.n_domains(), 8);
    let sc = Scenario::parse(
        "rome-2x4",
        "dcopy:32@scatter+ddot2:32@scatter / dcopy:8@d0+ddot2:8@d4+idle:48",
    )
    .unwrap()
    .with_default_remote(0.25);
    let rs = run_scenario_on(&topo, Placement::Compact, &sc, &MeasureEngine::Fluid).unwrap();
    assert_eq!(rs.phases.len(), 2);
    for phase in &rs.phases {
        // Per-domain shares: every domain hosting groups has model α
        // summing to 1; visitor-only interfaces still report their b_mix.
        for dr in &phase.domains {
            if !dr.groups.is_empty() {
                let alpha_sum: f64 = dr.groups.iter().map(|g| g.model_alpha).sum();
                assert!((alpha_sum - 1.0).abs() < 1e-9, "domain alpha sum {alpha_sum}");
            }
            assert!(dr.b_mix_gbs > 0.0);
        }
        // Per-link shares: both phases drive traffic both ways across the
        // duplex xGMI link, so both directed interfaces report.
        assert_eq!(phase.links.len(), 2, "one socket pair, two directed interfaces");
        assert_eq!(phase.links[0].sockets, (0, 1));
        assert_eq!(phase.links[1].sockets, (1, 0));
        for link in &phase.links {
            assert_eq!(link.link_bw_gbs.to_bits(), m.link_bw_gbs.to_bits());
            assert!(link.model_total_gbs > 0.0);
            assert!(link.measured_total_gbs > 0.0);
            assert!(
                link.model_total_gbs <= link.link_bw_gbs * (1.0 + 1e-9),
                "model grant {} cannot exceed the direction capacity {}",
                link.model_total_gbs,
                link.link_bw_gbs
            );
            let alpha_sum: f64 = link.groups.iter().map(|g| g.model_alpha).sum();
            assert!((alpha_sum - 1.0).abs() < 1e-9, "link alpha sum {alpha_sum}");
        }
        // Socket aggregates cover every original group.
        assert_eq!(phase.socket.len(), phase.mix.groups.len());
        assert!(phase.measured_total_gbs > 0.0);
        assert!(phase.model_total_gbs > 0.0);
    }
    // Order-of-magnitude agreement between model and the multi-interface
    // substrate. The paper's 8% two-group bound does not extend to mixed
    // split streams: the slowest-portion rule amplifies the fluid engine's
    // depth-floor generosity towards tiny remote portions (a real
    // second-order effect the thread-weighted model ignores), so only a
    // loose band is pinned here — the *homogeneous* remote case is
    // pinned at the 8% ceiling in rust/tests/simulator_conformance.rs.
    for phase in &rs.phases {
        for g in &phase.socket {
            assert!(g.measured_bw_gbs > 0.0 && g.model_bw_gbs > 0.0);
            let ratio = g.model_bw_gbs / g.measured_bw_gbs;
            assert!((0.2..5.0).contains(&ratio), "model/measured ratio {ratio}");
        }
    }
}

/// SNC sub-domains are characterized on the derived row, not the socket:
/// a CLX SNC2 domain has half the memory channels, so its saturated mix
/// bandwidth lands near half the socket row's — and the model still
/// matches the measurement, because both run on the derived row.
#[test]
fn clx_snc2_scenario_runs_on_derived_rows() {
    let m = machine(MachineId::Clx);
    let snc2 = Topology::parse(&m, "snc2").unwrap();
    let mix = vec![Mix::parse("dcopy:10@d0+ddot2:10@d1").unwrap()];
    let rs = run_mixes_on(&snc2, Placement::Compact, &mix, &MeasureEngine::Fluid).unwrap();
    let case = &rs.cases[0];
    assert_eq!(case.domain_ids, vec![0, 1]);
    for dr in &case.domains {
        assert!(dr.saturated, "10 cores saturate an SNC2 half-socket");
        assert!(
            dr.b_mix_gbs > 0.3 * m.read_bw_gbs && dr.b_mix_gbs < 0.7 * m.read_bw_gbs,
            "half-socket b_mix {} vs socket read bw {}",
            dr.b_mix_gbs,
            m.read_bw_gbs
        );
        for g in &dr.groups {
            assert!(g.error() < 0.15, "{:?}: err {}", g.kernel, g.error());
        }
    }
    // The co-simulator runs derived rows directly: since the CharCache
    // keys on the full machine fingerprint, the SNC sub-domain row gets
    // its own (halved-bandwidth) characterizations instead of being
    // rejected. All 20 ranks complete over the two half-socket domains.
    let prog = hpcg_program(HpcgVariant::Plain, 16, 1);
    let cfg = CoSimConfig { dt_s: 50e-6, t_max_s: 600.0, ..Default::default() };
    let eng = CoSimEngine::with_topology(
        &m,
        &snc2,
        Placement::Compact,
        prog.clone(),
        20,
        cfg.clone(),
        &CharSource::Ecm,
    )
    .unwrap();
    let r = eng.run();
    assert!(r.finish_s.iter().all(|f| f.is_finite()), "finish: {:?}", r.finish_s);
    // The halved domains drain slower than the monolithic socket: the same
    // program on 10 full-socket ranks finishes strictly earlier than on an
    // SNC2 half-socket's 10 ranks (same per-domain rank count, half b_s).
    let full =
        CoSimEngine::new(&m, prog, 10, cfg).unwrap().run();
    assert!(
        r.finish_s[0] > full.finish_s[0],
        "SNC half-socket {} !> monolithic {}",
        r.finish_s[0],
        full.finish_s[0]
    );
}

/// Remote parse errors surface as structured `Error::MixParse`, and
/// remote mixes are rejected on single-domain topologies.
#[test]
fn remote_error_paths_are_structured() {
    for bad in ["dcopy:4%r", "dcopy:4%r2", "dcopy:4%x0.2", "idle:2%r0.1"] {
        match Mix::parse(bad).unwrap_err() {
            Error::MixParse { spec, .. } => assert_eq!(spec, bad),
            other => panic!("'{bad}': wanted MixParse, got {other}"),
        }
    }
    let m = machine(MachineId::Clx);
    let single = Topology::single(&m);
    let remote = vec![Mix::parse("dcopy:4%r0.5").unwrap()];
    assert!(run_mixes_on(&single, Placement::Compact, &remote, &MeasureEngine::Fluid).is_err());
}

/// Full-socket HPCG co-simulation: with identical per-domain composition
/// the 32-rank socket behaves like four copies of the 8-rank domain.
#[test]
fn rome_socket_cosim_matches_single_domain_per_domain() {
    let m = machine(MachineId::Rome);
    let prog = hpcg_program(HpcgVariant::Plain, 48, 2);
    let cfg = CoSimConfig { dt_s: 50e-6, t_max_s: 600.0, ..Default::default() };
    let solo = CoSimEngine::new(&m, prog.clone(), 8, cfg.clone()).unwrap().run();
    let topo = Topology::socket(&m);
    let socket = CoSimEngine::with_topology(
        &m,
        &topo,
        Placement::Compact,
        prog,
        32,
        cfg,
        &CharSource::Ecm,
    )
    .unwrap()
    .run();
    assert!(socket.finish_s.iter().all(|f| f.is_finite()));
    assert_eq!(socket.trace.records.len(), 4 * solo.trace.records.len());
    // Lockstep start, no noise, same composition everywhere: every rank of
    // the socket finishes when the 8-rank domain run does.
    let want = solo.finish_s[0];
    for (r, fin) in socket.finish_s.iter().enumerate() {
        assert!(
            (fin - want).abs() <= 1e-12 * want.abs(),
            "rank {r}: {fin} vs single-domain {want}"
        );
    }
}
