//! Property-based tests on coordinator invariants (hand-rolled random-case
//! driver — the offline build has no proptest; `XorShift64` supplies
//! deterministic cases and failures print the seed for replay).

use membw::config::{builtin_machines, machine, MachineId};
use membw::kernels::{kernel, pairing_set, KernelId};
use membw::sharing::{share_multigroup, share_two_groups, KernelGroup};
use membw::simulator::{run_engine, CoreWorkload, Engine, XorShift64};
use membw::sweep::{full_domain_splits, symmetric_splits, PairingCase};

const CASES: usize = 200;

fn random_group(rng: &mut XorShift64) -> KernelGroup {
    KernelGroup {
        n: 1 + rng.next_below(16),
        f: 0.05 + 0.9 * rng.next_f64(),
        bs_gbs: 20.0 + 100.0 * rng.next_f64(),
    }
}

/// Shares sum to one; bandwidth is conserved; no group beats its solo speed.
#[test]
fn prop_sharing_model_invariants() {
    let mut rng = XorShift64::new(0xFEED01);
    for case in 0..CASES {
        let k = 1 + rng.next_below(5);
        let groups: Vec<KernelGroup> = (0..k).map(|_| random_group(&mut rng)).collect();
        let out = share_multigroup(&groups);
        let alpha_sum: f64 = out.groups.iter().map(|g| g.alpha).sum();
        assert!((alpha_sum - 1.0).abs() < 1e-6, "case {case}: alphas sum to {alpha_sum}");
        let total: f64 = out.groups.iter().map(|g| g.group_bw_gbs).sum();
        assert!(total <= out.b_mix_gbs + 1e-6, "case {case}: total {total} > b_mix {}", out.b_mix_gbs);
        for (g, e) in groups.iter().zip(&out.groups) {
            assert!(
                e.per_core_gbs <= g.f * g.bs_gbs + 1e-6,
                "case {case}: group beats solo speed"
            );
            assert!(e.per_core_gbs >= -1e-9, "case {case}: negative bandwidth");
        }
    }
}

/// The two-group wrapper agrees with the multigroup model.
#[test]
fn prop_two_group_equals_multigroup() {
    let mut rng = XorShift64::new(0xFEED02);
    for _ in 0..CASES {
        let a = random_group(&mut rng);
        let b = random_group(&mut rng);
        let two = share_two_groups(&a, &b);
        let multi = share_multigroup(&[a, b]);
        for g in 0..2 {
            assert!((two.per_core_gbs[g] - multi.groups[g].per_core_gbs).abs() < 1e-9);
        }
    }
}

/// Raising a kernel's f never lowers its own per-core bandwidth share
/// (monotonicity of Eq. 5).
#[test]
fn prop_share_monotone_in_f() {
    let mut rng = XorShift64::new(0xFEED03);
    for case in 0..CASES {
        let a = random_group(&mut rng);
        let b = random_group(&mut rng);
        let bumped = KernelGroup { f: (a.f * 1.1).min(1.0), ..a };
        let base = share_two_groups(&a, &b).per_core_gbs[0];
        let more = share_two_groups(&bumped, &b).per_core_gbs[0];
        assert!(more >= base - 1e-9, "case {case}: f up, share down ({base} -> {more})");
    }
}

/// In the saturated regime the group shares must sum to exactly one and the
/// allocated bandwidths must sum to the overlapped saturated bandwidth
/// b_mix (generalized Eq. 4) — nothing is lost to the water-filling.
#[test]
fn prop_saturated_shares_partition_b_mix() {
    let mut rng = XorShift64::new(0xFEED07);
    let mut saturated_seen = 0usize;
    for case in 0..CASES {
        let k = 2 + rng.next_below(4);
        let groups: Vec<KernelGroup> = (0..k).map(|_| random_group(&mut rng)).collect();
        let out = share_multigroup(&groups);
        if !out.saturated {
            continue;
        }
        saturated_seen += 1;
        let alpha_sum: f64 = out.groups.iter().map(|g| g.alpha).sum();
        assert!((alpha_sum - 1.0).abs() < 1e-9, "case {case}: alphas sum to {alpha_sum}");
        let total: f64 = out.groups.iter().map(|g| g.group_bw_gbs).sum();
        assert!(
            (total - out.b_mix_gbs).abs() < 1e-6,
            "case {case}: saturated allocation {total} != b_mix {}",
            out.b_mix_gbs
        );
    }
    assert!(saturated_seen > CASES / 4, "sampler must reach the saturated regime");
}

/// Independent closed-form reference for the k=2 model: Eq. (4) for b_mix,
/// then either the raw Eq. (5) proportional split or the demand-capped
/// branch, written out by hand (no water-filling loop). Mirrors the
/// 1e-12 cap margin of `share_multigroup` so agreement is exact.
fn k2_reference(a: &KernelGroup, b: &KernelGroup) -> (f64, [f64; 2]) {
    let (n1, n2) = (a.n as f64, b.n as f64);
    let b_mix = (n1 * a.bs_gbs + n2 * b.bs_gbs) / (n1 + n2);
    let d = [n1 * a.f * a.bs_gbs, n2 * b.f * b.bs_gbs];
    let w = [n1 * a.f, n2 * b.f];
    let budget = b_mix.min(d[0] + d[1]);
    let alloc = [budget * w[0] / (w[0] + w[1]), budget * w[1] / (w[0] + w[1])];
    let bw = if alloc[0] >= d[0] - 1e-12 && alloc[1] >= d[1] - 1e-12 {
        [d[0], d[1]]
    } else if alloc[0] >= d[0] - 1e-12 {
        // Group 1 capped at its solo demand; group 2 takes the rest.
        let rest = (budget - d[0]).max(0.0);
        [d[0], if rest >= d[1] - 1e-12 { d[1] } else { rest }]
    } else if alloc[1] >= d[1] - 1e-12 {
        let rest = (budget - d[1]).max(0.0);
        [if rest >= d[0] - 1e-12 { d[0] } else { rest }, d[1]]
    } else {
        alloc
    };
    (b_mix, bw)
}

/// `share_multigroup` at k=2 must reproduce the hand-derived closed-form
/// two-group model (Eqs. 4+5 with demand capping) to 1e-12 — an independent
/// reference, not the library's own `share_two_groups` wrapper (which just
/// delegates to `share_multigroup`).
#[test]
fn prop_multigroup_k2_matches_eq5_to_1e12() {
    let g = |n: usize, f: f64, bs: f64| KernelGroup { n, f, bs_gbs: bs };
    // Crafted pairs that provably exercise each branch of the closed form:
    // raw proportional Eq. 5, nonsaturated (both groups at solo demand), and
    // saturated with exactly one group demand-capped.
    let mut cases: Vec<(KernelGroup, KernelGroup)> = vec![
        (g(6, 0.35, 55.0), g(4, 0.20, 66.0)),  // saturated, uncapped
        (g(1, 0.10, 60.0), g(1, 0.10, 60.0)),  // nonsaturated, both capped
        (g(1, 0.95, 20.0), g(4, 0.35, 120.0)), // saturated, group 1 capped
    ];
    let mut rng = XorShift64::new(0xFEED08);
    for _ in 0..CASES {
        cases.push((random_group(&mut rng), random_group(&mut rng)));
    }
    for (case, (a, b)) in cases.into_iter().enumerate() {
        let multi = share_multigroup(&[a, b]);
        let (b_mix_ref, bw_ref) = k2_reference(&a, &b);
        assert!((multi.b_mix_gbs - b_mix_ref).abs() < 1e-12, "case {case}: Eq. 4");
        let total_ref: f64 = bw_ref.iter().sum();
        for gi in 0..2 {
            assert!(
                (multi.groups[gi].group_bw_gbs - bw_ref[gi]).abs() < 1e-12,
                "case {case} group {gi}: {} vs reference {}",
                multi.groups[gi].group_bw_gbs,
                bw_ref[gi]
            );
            let alpha_ref = bw_ref[gi] / total_ref;
            assert!((multi.groups[gi].alpha - alpha_ref).abs() < 1e-12, "case {case}");
            let n = if gi == 0 { a.n } else { b.n } as f64;
            assert!((multi.groups[gi].per_core_gbs - bw_ref[gi] / n).abs() < 1e-12);
        }
        // The wrapper must stay a faithful view of the multigroup result.
        let two = share_two_groups(&a, &b);
        for gi in 0..2 {
            assert!((two.per_core_gbs[gi] - multi.groups[gi].per_core_gbs).abs() < 1e-12);
        }
    }
}

/// A single solo core reduces to the ECM single-thread value `f * b_s` —
/// exactly, for any admissible (f, b_s).
#[test]
fn prop_solo_core_reduces_to_ecm_value() {
    let mut rng = XorShift64::new(0xFEED09);
    for case in 0..CASES {
        let f = 0.05 + 0.9 * rng.next_f64();
        let bs = 20.0 + 100.0 * rng.next_f64();
        let out = share_multigroup(&[KernelGroup { n: 1, f, bs_gbs: bs }]);
        assert!(!out.saturated, "case {case}: one core with f<1 cannot saturate");
        assert!(
            (out.groups[0].per_core_gbs - f * bs).abs() < 1e-12,
            "case {case}: solo core got {} instead of f*b_s = {}",
            out.groups[0].per_core_gbs,
            f * bs
        );
    }
}

/// Fluid-engine conservation: per-core bandwidths are non-negative, the
/// total respects capacity, idle cores get nothing, and homogeneous groups
/// get near-identical per-core bandwidth.
#[test]
fn prop_fluid_engine_invariants() {
    let mut rng = XorShift64::new(0xFEED04);
    let kernels = pairing_set();
    for case in 0..40 {
        let m = machine(MachineId::ALL[rng.next_below(4)]);
        let n_active = 1 + rng.next_below(m.cores);
        let k1 = kernels[rng.next_below(kernels.len())];
        let k2 = kernels[rng.next_below(kernels.len())];
        let mut ws = Vec::new();
        for i in 0..n_active {
            let k = if i % 2 == 0 { k1 } else { k2 };
            ws.push(CoreWorkload::from_kernel(&kernel(k), &m, i % 2));
        }
        let per_core = run_engine(&m, &ws, Engine::Fluid);
        let total: f64 = per_core.iter().sum();
        assert!(total <= m.read_bw_gbs * 1.005, "case {case}: total {total} over capacity");
        assert!(per_core.iter().all(|&x| x >= 0.0));
        // Same-kernel cores must get (nearly) equal bandwidth.
        for g in 0..2 {
            let sel: Vec<f64> = per_core
                .iter()
                .zip(&ws)
                .filter(|(_, w)| w.group == g)
                .map(|(&x, _)| x)
                .collect();
            if sel.len() > 1 {
                let max = sel.iter().cloned().fold(f64::MIN, f64::max);
                let min = sel.iter().cloned().fold(f64::MAX, f64::min);
                assert!(
                    (max - min) / max < 0.01,
                    "case {case}: same-kernel cores diverge ({min}..{max})"
                );
            }
        }
    }
}

/// DES and fluid agree on random pairings within a tolerance band.
#[test]
fn prop_des_fluid_agreement() {
    let mut rng = XorShift64::new(0xFEED05);
    let kernels = pairing_set();
    for case in 0..12 {
        let m = machine(MachineId::ALL[rng.next_below(4)]);
        let n1 = 1 + rng.next_below(m.cores / 2);
        let n2 = 1 + rng.next_below(m.cores - n1);
        let k1 = kernels[rng.next_below(kernels.len())];
        let k2 = kernels[rng.next_below(kernels.len())];
        let mut ws = vec![CoreWorkload::from_kernel(&kernel(k1), &m, 0); n1];
        ws.extend(vec![CoreWorkload::from_kernel(&kernel(k2), &m, 1); n2]);
        let fluid = run_engine(&m, &ws, Engine::Fluid);
        let des = run_engine(&m, &ws, Engine::Des);
        let f_tot: f64 = fluid.iter().sum();
        let d_tot: f64 = des.iter().sum();
        let rel = (f_tot - d_tot).abs() / f_tot;
        assert!(
            rel < 0.08,
            "case {case} ({:?} {k1:?}x{n1} + {k2:?}x{n2}): fluid {f_tot} vs des {d_tot}",
            m.id
        );
    }
}

/// The default (short) fluid run agrees with a 5x longer one — the cycle
/// budget is past convergence.
#[test]
fn prop_fluid_cycle_convergence() {
    use membw::simulator::{FluidConfig, FluidSimulator};
    let mut rng = XorShift64::new(0xFEED06);
    let kernels = pairing_set();
    for case in 0..10 {
        let m = machine(MachineId::ALL[rng.next_below(4)]);
        let k1 = kernels[rng.next_below(kernels.len())];
        let k2 = kernels[rng.next_below(kernels.len())];
        let mut ws = vec![CoreWorkload::from_kernel(&kernel(k1), &m, 0); m.cores / 2];
        ws.extend(vec![CoreWorkload::from_kernel(&kernel(k2), &m, 1); m.cores - m.cores / 2]);
        let short = FluidSimulator::new(&m, FluidConfig::default()).run(&ws);
        let long = FluidSimulator::new(&m, FluidConfig { warmup_cycles: 20_000, measure_cycles: 60_000 })
            .run(&ws);
        for (a, b) in short.per_core_gbs.iter().zip(&long.per_core_gbs) {
            let rel = (a - b).abs() / b.max(1e-9);
            assert!(rel < 0.002, "case {case}: short {a} vs long {b}");
        }
    }
}

/// Plan enumeration covers the Fig. 4 dots exactly once and never exceeds
/// the domain.
#[test]
fn prop_plans_cover_fig4() {
    for m in builtin_machines() {
        let full = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
        assert_eq!(full.len(), m.cores - 1);
        for (i, c) in full.iter().enumerate() {
            assert_eq!(c.n1, i + 1);
            assert_eq!(c.n1 + c.n2, m.cores);
            c.validate(&m).unwrap();
        }
        let sym = symmetric_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
        assert_eq!(sym.len(), m.cores / 2);
        for c in &sym {
            assert_eq!(c.n1, c.n2);
            c.validate(&m).unwrap();
        }
        // Overfull plans must be rejected.
        let bad = PairingCase { k1: KernelId::Dcopy, k2: KernelId::Ddot2, n1: m.cores, n2: 1 };
        assert!(bad.validate(&m).is_err());
    }
}

/// Eq. 3 consistency under the fluid engine for every pairing-set kernel on
/// every machine: measured f within a tight band of the ECM prediction.
#[test]
fn prop_eq3_close_to_ecm_everywhere() {
    for mid in MachineId::ALL {
        let m = machine(mid);
        for k in pairing_set() {
            let sig = kernel(k);
            let meas = membw::simulator::measure_f_bs(&sig, &m, Engine::Fluid);
            let pred = membw::ecm::predict(&sig, &m);
            let rel = (meas.f - pred.f).abs() / pred.f;
            assert!(
                rel < 0.12,
                "{mid:?}/{k:?}: measured f {} vs ECM {}",
                meas.f,
                pred.f
            );
        }
    }
}

/// The event-driven co-simulation is *exactly* independent of the legacy
/// step-size knob: `dt_s` parameterizes only the retired stepper, so traces
/// must be bit-identical across wildly different values.
#[test]
fn prop_cosim_trace_independent_of_dt_knob() {
    use membw::desync::{hpcg_program, CoSimConfig, CoSimEngine, HpcgVariant, NoiseModel};
    let m = machine(MachineId::Clx);
    let mut base: Option<Vec<(usize, &'static str, u64, u64)>> = None;
    for dt in [20e-6, 1e-3, 0.5] {
        let cfg = CoSimConfig {
            dt_s: dt,
            t_max_s: 600.0,
            initial_stagger_s: 0.2e-3,
            neighbor_radius: 3,
            noise: NoiseModel::mild(7),
        };
        let prog = hpcg_program(HpcgVariant::Modified, 48, 2);
        let eng = CoSimEngine::new(&m, prog, 10, cfg).unwrap();
        let r = eng.run();
        let sig: Vec<(usize, &'static str, u64, u64)> = r
            .trace
            .records
            .iter()
            .map(|x| (x.rank, x.label, x.t_start.to_bits(), x.t_end.to_bits()))
            .collect();
        assert!(!sig.is_empty());
        match &base {
            None => base = Some(sig),
            Some(b) => assert_eq!(b, &sig, "dt={dt} changed the event-driven trace"),
        }
    }
}

/// Per-domain sharing model: within every domain the shares sum to 1
/// (saturated or not — the allocator normalizes), no group beats its solo
/// speed, and empty domains stay empty.
#[test]
fn prop_domain_alpha_sums_to_one_within_each_domain() {
    use membw::sharing::share_domains;
    let mut rng = XorShift64::new(0xD0_0A11);
    for case in 0..CASES {
        let nd = 1 + rng.next_below(4);
        let domains: Vec<Vec<KernelGroup>> = (0..nd)
            .map(|_| {
                let k = 1 + rng.next_below(4);
                (0..k).map(|_| random_group(&mut rng)).collect()
            })
            .collect();
        let shares = share_domains(&domains);
        assert_eq!(shares.len(), nd);
        for (d, s) in shares.iter().enumerate() {
            let alpha_sum: f64 = s.groups.iter().map(|g| g.alpha).sum();
            assert!(
                (alpha_sum - 1.0).abs() < 1e-6,
                "case {case} domain {d}: alphas sum to {alpha_sum}"
            );
            for (g, e) in domains[d].iter().zip(&s.groups) {
                assert!(e.per_core_gbs <= g.f * g.bs_gbs + 1e-6, "case {case} domain {d}");
            }
        }
    }
}

/// ccNUMA independence: perturbing domain 0's mix leaves every other
/// domain's shares bit-identical.
#[test]
fn prop_domains_are_independent() {
    use membw::sharing::share_domains;
    let mut rng = XorShift64::new(0xD0_0A12);
    for case in 0..CASES {
        let nd = 2 + rng.next_below(3);
        let domains: Vec<Vec<KernelGroup>> = (0..nd)
            .map(|_| {
                let k = 1 + rng.next_below(4);
                (0..k).map(|_| random_group(&mut rng)).collect()
            })
            .collect();
        let before = share_domains(&domains);
        let mut perturbed = domains.clone();
        perturbed[0] = vec![random_group(&mut rng)];
        let after = share_domains(&perturbed);
        for d in 1..nd {
            for (a, b) in before[d].groups.iter().zip(&after[d].groups) {
                assert_eq!(
                    a.alpha.to_bits(),
                    b.alpha.to_bits(),
                    "case {case}: domain {d} saw domain 0's perturbation"
                );
                assert_eq!(a.per_core_gbs.to_bits(), b.per_core_gbs.to_bits());
            }
        }
    }
}

/// Randomized-trace pin for the incremental water-fill engine: over random
/// noise seeds, rank counts, and placements — with and without remote
/// traffic, on single-socket, dual-socket, and multi-node cluster
/// topologies — the interface-composition re-rating path
/// ([`RatingMode::Incremental`], the default) must reproduce the retained
/// full-recompute reference *bit for bit*: same event count, same phase
/// records, same per-rank finish times.
#[test]
fn prop_incremental_rating_bit_identical_to_full_recompute() {
    use membw::desync::{hpcg_program, CoSimConfig, CoSimEngine, HpcgVariant, NoiseModel};
    use membw::scenario::CharSource;
    use membw::topology::{Placement, Topology};
    let rome = machine(MachineId::Rome);
    let mut rng = XorShift64::new(0xC1_0B01);
    // (topology spec, remote fraction): 0.0 exercises the independent-domain
    // ShareCache path, >0.0 the coupled remote water-fill — on one socket,
    // across the xGMI link, and across identical cluster nodes.
    let specs: &[(&str, f64)] = &[("1x4", 0.0), ("2x4", 0.25), ("2n1x4", 0.25), ("4n1x4", 0.5)];
    for &(spec, frac) in specs {
        let topo = Topology::parse(&rome, spec).unwrap();
        for rep in 0..3 {
            let noise = match rng.next_below(3) {
                0 => NoiseModel::off(),
                _ => NoiseModel::mild(1 + rng.next_below(1 << 20) as u64),
            };
            let cfg = CoSimConfig {
                dt_s: 20e-6,
                t_max_s: 600.0,
                initial_stagger_s: 0.1e-3,
                noise,
                neighbor_radius: 1 + rng.next_below(3),
            };
            let placement =
                if rng.next_below(2) == 0 { Placement::Compact } else { Placement::Scatter };
            let total = topo.total_cores();
            let n_ranks = total / 2 + rng.next_below(total / 2) + 1;
            let prog = hpcg_program(HpcgVariant::Modified, 48, 2);
            let eng = if frac > 0.0 {
                CoSimEngine::with_topology_remote(
                    &rome,
                    &topo,
                    placement,
                    frac,
                    prog,
                    n_ranks,
                    cfg,
                    &CharSource::Ecm,
                )
                .unwrap()
            } else {
                CoSimEngine::with_topology(
                    &rome,
                    &topo,
                    placement,
                    prog,
                    n_ranks,
                    cfg,
                    &CharSource::Ecm,
                )
                .unwrap()
            };
            let inc = eng.run();
            let full = eng.run_full_recompute();
            let tag = format!("{spec} %r{frac} rep {rep} ({n_ranks} ranks)");
            assert_eq!(inc.events, full.events, "{tag}: event counts diverge");
            assert_eq!(inc.t_end_s.to_bits(), full.t_end_s.to_bits(), "{tag}: t_end");
            assert_eq!(
                inc.trace.records.len(),
                full.trace.records.len(),
                "{tag}: record counts diverge"
            );
            for (a, b) in inc.trace.records.iter().zip(&full.trace.records) {
                assert_eq!(a.rank, b.rank, "{tag}");
                assert_eq!(a.label, b.label, "{tag}");
                assert_eq!(a.t_start.to_bits(), b.t_start.to_bits(), "{tag}: t_start");
                assert_eq!(a.t_end.to_bits(), b.t_end.to_bits(), "{tag}: t_end");
            }
            for (r, (a, b)) in inc.finish_s.iter().zip(&full.finish_s).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: finish of rank {r}");
            }
        }
    }
}

/// Regression pin: the all-dirty fallback (every refresh re-rating every
/// node) is gone. On a remote-traffic cluster the incremental run must
/// actually skip clean nodes — nonzero reuse counter, strictly fewer node
/// ratings than the full-recompute reference — and on a cluster whose
/// ranks all land on node 0, the idle nodes must never be re-rated after
/// their first rating.
#[test]
fn prop_incremental_skips_clean_nodes() {
    use membw::desync::{hpcg_program, CoSimConfig, CoSimEngine, HpcgVariant, NoiseModel};
    use membw::scenario::CharSource;
    use membw::topology::{Placement, Topology};
    let rome = machine(MachineId::Rome);
    let topo = Topology::parse(&rome, "4n1x4").unwrap();
    let cfg = CoSimConfig {
        dt_s: 20e-6,
        t_max_s: 600.0,
        initial_stagger_s: 0.1e-3,
        noise: NoiseModel::mild(11),
        neighbor_radius: 2,
    };
    // All four nodes busy: staggered noise keeps compositions changing on
    // one node while the others are mid-phase, so reuse and re-rating both
    // happen.
    let busy = CoSimEngine::with_topology_remote(
        &rome,
        &topo,
        Placement::Compact,
        0.25,
        hpcg_program(HpcgVariant::Modified, 48, 2),
        topo.total_cores(),
        cfg.clone(),
        &CharSource::Ecm,
    )
    .unwrap();
    let inc = busy.run();
    let full = busy.run_full_recompute();
    assert!(inc.stats.node_rates_reused > 0, "incremental run never skipped a clean node");
    assert_eq!(full.stats.node_rates_reused, 0, "the reference must re-rate everything");
    assert!(
        inc.stats.rate_evals < full.stats.rate_evals,
        "incremental ({}) must rate fewer nodes than full recompute ({})",
        inc.stats.rate_evals,
        full.stats.rate_evals
    );

    // Compact placement of one node's worth of ranks: nodes 1-3 idle. With
    // the fallback gone, their ratings can only come from the initial
    // all-dirty sweep, so skips dominate ratings.
    let lop = CoSimEngine::with_topology_remote(
        &rome,
        &topo,
        Placement::Compact,
        0.25,
        hpcg_program(HpcgVariant::Modified, 48, 2),
        topo.total_cores() / 4,
        cfg,
        &CharSource::Ecm,
    )
    .unwrap();
    let r = lop.run();
    assert!(
        r.stats.node_rates_reused >= r.stats.rate_evals,
        "idle nodes kept getting re-rated: {} reused vs {} rated",
        r.stats.node_rates_reused,
        r.stats.rate_evals
    );
}

/// On a 1-domain machine, scatter and compact placement are the same thing:
/// identical splits and identical rank layouts for random mixes.
#[test]
fn prop_scatter_equals_compact_on_single_domain() {
    use membw::scenario::Mix;
    use membw::topology::{Placement, Topology};
    let pool = pairing_set();
    let mut rng = XorShift64::new(0xD0_0A13);
    for mid in MachineId::ALL {
        let m = machine(mid);
        let topo = Topology::single(&m);
        for case in 0..50 {
            let k = 1 + rng.next_below(3);
            let mut mix = Mix::new();
            let mut used = 0usize;
            for _ in 0..k {
                let cores = 1 + rng.next_below((m.cores - used).max(1).min(6));
                if used + cores > m.cores {
                    break;
                }
                mix = mix.with(pool[rng.next_below(pool.len())], cores);
                used += cores;
            }
            if mix.active_cores() == 0 {
                continue;
            }
            if used < m.cores && rng.next_below(2) == 1 {
                mix = mix.idle(rng.next_below(m.cores - used + 1));
            }
            let a = Placement::Compact.split(&topo, &mix).unwrap();
            let b = Placement::Scatter.split(&topo, &mix).unwrap();
            assert_eq!(a, b, "{mid:?} case {case}: split differs on one domain");
            assert_eq!(a.domains[0].mix, mix, "{mid:?} case {case}: split is the identity");
            let ra = Placement::Compact.rank_layout(&topo, mix.active_cores()).unwrap();
            let rb = Placement::Scatter.rank_layout(&topo, mix.active_cores()).unwrap();
            assert_eq!(ra, rb, "{mid:?} case {case}: rank layout differs");
        }
    }
}

// --- cache-topology properties (shared-L3 interfaces, compute groups) ---

mod cache_topology {
    use super::*;
    use membw::optimizer::DeltaEval;
    use membw::sharing::{share_remote, GroupKind, RemoteGroup, TopoShape};

    fn random_shape(rng: &mut XorShift64, l3_gbs: f64) -> TopoShape {
        let sockets = 1 + rng.next_below(2);
        let dpn = 1 + rng.next_below(2);
        let mut socket_of = Vec::new();
        for s in 0..sockets {
            for _ in 0..dpn {
                socket_of.push(s);
            }
        }
        let n = socket_of.len();
        let link = if sockets > 1 { 8.0 + 56.0 * rng.next_f64() } else { 0.0 };
        TopoShape {
            socket_of,
            bw_scale: vec![1.0; n],
            link_bw_gbs: link,
            link_bw_rev_gbs: link,
            l3_bw_gbs: l3_gbs,
        }
    }

    fn random_remote_group(rng: &mut XorShift64, nd: usize) -> RemoteGroup {
        RemoteGroup {
            home: rng.next_below(nd),
            n: 1 + rng.next_below(8),
            f: 0.05 + 0.9 * rng.next_f64(),
            bs_gbs: 10.0 + 40.0 * rng.next_f64(),
            remote_frac: if nd >= 2 && rng.next_below(2) == 1 {
                [0.0, 0.1, 0.25, 0.5][rng.next_below(4)]
            } else {
                0.0
            },
            kind: GroupKind::Mem,
        }
    }

    /// Roughly a third of the groups L3-resident (with and without a DRAM
    /// tandem), a sixth compute-bound — every portion flavour appears.
    fn random_kinded_group(rng: &mut XorShift64, nd: usize) -> RemoteGroup {
        let mut g = random_remote_group(rng, nd);
        match rng.next_below(6) {
            0 | 1 => {
                g.remote_frac = 0.0;
                if rng.next_below(2) == 0 {
                    g.f = 0.0;
                    g.bs_gbs = 0.0;
                }
                g.kind = GroupKind::L3 {
                    f_l3: 0.2 + 0.6 * rng.next_f64(),
                    bs_l3_gbs: 40.0 + 40.0 * rng.next_f64(),
                };
            }
            2 => g.kind = GroupKind::Compute,
            _ => {}
        }
        g
    }

    /// Memory-bound-only mixes are bitwise invariant to the shape's
    /// `l3_bw_gbs` — the structural degenerate-case guarantee, over random
    /// shapes, group counts, and remote fractions.
    #[test]
    fn prop_mem_only_mixes_invariant_to_l3_bw() {
        let mut rng = XorShift64::new(0xCAC4E1);
        for case in 0..CASES {
            let shape0 = random_shape(&mut rng, 0.0);
            let nd = shape0.n_domains();
            let k = 1 + rng.next_below(5);
            let groups: Vec<RemoteGroup> =
                (0..k).map(|_| random_remote_group(&mut rng, nd)).collect();
            let shape1 = TopoShape { l3_bw_gbs: 60.0 + 200.0 * rng.next_f64(), ..shape0.clone() };
            let a = share_remote(&shape0, &groups).unwrap();
            let b = share_remote(&shape1, &groups).unwrap();
            assert_eq!(a.iterations, b.iterations, "case {case}");
            for (x, y) in a.per_core_gbs.iter().zip(&b.per_core_gbs) {
                assert_eq!(x.to_bits(), y.to_bits(), "case {case}: rate perturbed by l3_bw");
            }
            for iface in &b.l3 {
                assert_eq!(iface.demand_gbs, 0.0, "case {case}: phantom L3 demand");
            }
        }
    }

    /// Per-interface conservation with every group kind in play: grants on
    /// each memory controller, each link direction, and each shared L3 sum
    /// to at most the interface capacity (equality when saturated), and
    /// every group's rate respects its own roofline cap.
    #[test]
    fn prop_interface_grants_conserve_capacity_with_l3() {
        let mut rng = XorShift64::new(0xCAC4E2);
        for case in 0..CASES {
            let shape = random_shape(&mut rng, 120.0);
            let nd = shape.n_domains();
            let k = 1 + rng.next_below(5);
            let groups: Vec<RemoteGroup> =
                (0..k).map(|_| random_kinded_group(&mut rng, nd)).collect();
            let share = share_remote(&shape, &groups).unwrap();
            assert_eq!(share.l3.len(), shape.n_sockets(), "case {case}");

            for s in 0..shape.n_sockets() {
                let granted: f64 = share
                    .portions
                    .iter()
                    .filter(|p| p.l3 == Some(s) && !p.mem)
                    .map(|p| p.l3_grant_gbs)
                    .sum();
                assert!(
                    granted <= shape.l3_bw_gbs * (1.0 + 1e-9),
                    "case {case}: L3 s{s} over capacity ({granted})"
                );
                if share.l3[s].saturated {
                    assert!(
                        (granted - shape.l3_bw_gbs).abs() < 1e-6,
                        "case {case}: saturated L3 s{s} grants {granted}"
                    );
                }
            }
            for d in 0..nd {
                let granted: f64 = share
                    .portions
                    .iter()
                    .filter(|p| p.target == d && p.mem)
                    .map(|p| p.mem_bw_gbs)
                    .sum();
                assert!(
                    granted <= share.domains[d].b_mix_gbs * (1.0 + 1e-9) + 1e-9,
                    "case {case}: d{d} over b_mix ({granted} vs {})",
                    share.domains[d].b_mix_gbs
                );
            }
            for (gi, g) in groups.iter().enumerate() {
                let rate = share.per_core_gbs[gi];
                assert!(rate >= -1e-9, "case {case}: negative rate");
                match g.kind {
                    GroupKind::Mem => {
                        assert!(rate <= g.f * g.bs_gbs * (1.0 + 1e-9), "case {case}")
                    }
                    GroupKind::L3 { f_l3, bs_l3_gbs } => {
                        assert!(rate <= f_l3 * bs_l3_gbs * (1.0 + 1e-9), "case {case}")
                    }
                    GroupKind::Compute => {
                        assert_eq!(rate.to_bits(), (g.f * g.bs_gbs).to_bits(), "case {case}")
                    }
                }
            }
        }
    }

    /// Random move walks with L3 and compute candidates in the pool: the
    /// delta evaluator's rates stay bit-identical to the from-scratch
    /// fixed point after every commit.
    #[test]
    fn prop_delta_walks_bit_identical_with_l3_candidates() {
        let mut rng = XorShift64::new(0xCAC4E3);
        for case in 0..60 {
            let shape = random_shape(&mut rng, 100.0 + 100.0 * rng.next_f64());
            let nd = shape.n_domains();
            let k = 2 + rng.next_below(4);
            let mut groups: Vec<RemoteGroup> =
                (0..k).map(|_| random_kinded_group(&mut rng, nd)).collect();
            let mut de = DeltaEval::new(shape.clone(), groups.clone()).unwrap();
            for step in 0..8 {
                let gi = rng.next_below(groups.len());
                let mut ng = groups[gi];
                if matches!(ng.kind, GroupKind::Mem) && rng.next_below(2) == 0 {
                    ng.remote_frac =
                        if nd >= 2 { [0.0, 0.1, 0.25, 0.5][rng.next_below(4)] } else { 0.0 };
                } else {
                    ng.home = rng.next_below(nd);
                }
                let outcome = de.eval(&[(gi, ng)]).unwrap();
                groups[gi] = ng;
                let full = share_remote(&shape, &groups).unwrap();
                for (a, b) in outcome.rates.iter().zip(&full.per_core_gbs) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "case {case} step {step}: delta diverged from full solve"
                    );
                }
                de.commit(outcome);
            }
        }
    }
}
