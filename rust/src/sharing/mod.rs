//! The paper's contribution: the analytic bandwidth-sharing model.
//!
//! * `model` — Eqs. (4) and (5) for two thread groups,
//! * `multigroup` — the natural k-group generalization (used by the
//!   desynchronization co-simulator and the task-scheduler example), plus
//!   the per-ccNUMA-domain evaluation [`share_domains`] (domains share no
//!   state; each gets its own Eqs. 4+5) and the fractional-thread-weight
//!   form [`share_weighted`] the remote-access extension builds on,
//! * [`remote`] — the remote-access extension: groups whose cache-line
//!   streams split between their home domain, remote domains, and the
//!   inter-socket links (UPI/xGMI), each an Eqs. (4)+(5) interface,
//! * `baseline` — the naive models the paper argues against (equal share
//!   per thread; code-balance-weighted share), kept as ablation baselines,
//! * `desync_predictor` — qualitative desync/resync prediction from
//!   kernel pairings (Sect. V closing discussion),
//! * `share_cache` — memoized multigroup evaluations keyed by group
//!   composition (the contention-timeline engine's hot lookup).
//!
//! # Examples
//!
//! The saturated two-group share is the paper's Eq. (5),
//! `α₁ = n₁f₁ / (n₁f₁ + n₂f₂)`:
//!
//! ```
//! use membw::sharing::{share_multigroup, KernelGroup};
//!
//! let share = share_multigroup(&[
//!     KernelGroup { n: 6, f: 0.35, bs_gbs: 55.0 },
//!     KernelGroup { n: 4, f: 0.20, bs_gbs: 66.0 },
//! ]);
//! let eq5 = 6.0 * 0.35 / (6.0 * 0.35 + 4.0 * 0.20);
//! assert!(share.saturated);
//! assert!((share.groups[0].alpha - eq5).abs() < 1e-9);
//! ```

mod baseline;
mod desync_predictor;
mod model;
mod multigroup;
pub mod remote;
mod share_cache;

pub use baseline::{code_balance_share, equal_share, BaselineKind};
pub use desync_predictor::{predict_skew, OverlapPartner, SkewPrediction};
pub use model::{overlapped_saturated_bw, share_two_groups, KernelGroup, SharingPrediction};
pub use multigroup::{
    share_domains, share_multigroup, share_weighted, share_weighted_capacity,
    share_weighted_capped, GroupShare, GroupShareEntry, WeightedGroup,
};
pub use remote::{
    portion_routes, share_remote, GroupKind, InterfaceShare, Portion, RemoteGroup,
    RemoteRateModel, RemoteShare, TopoShape,
};
pub use share_cache::{ShareCache, ShareCacheStats, MAX_GROUP_CORES, MAX_SLOTS};
