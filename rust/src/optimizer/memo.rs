//! Sharded, concurrency-safe score memo for batched candidate scoring.
//!
//! The existing composition memos ([`crate::sharing::RemoteRateModel`]'s
//! `HashMap` and the 2-entry-MRU `ShareCache`) are built for one
//! sequential caller: a single lock (or `&mut self`) in front of either
//! would serialize the 16 scoring threads of [`crate::parallel::par_map`],
//! and an MRU of depth 2 thrashes when every thread probes a different
//! candidate. This memo shards the key space over [`N_SHARDS`] mutexes
//! keyed by an FNV-1a hash of the candidate encoding, so concurrent
//! lookups only contend when they hash to the same shard.
//!
//! Memoizing by candidate alone (ignoring which incumbent the evaluation
//! started from) is sound because a candidate's score is
//! parent-independent: delta evaluation is bit-identical to the full
//! re-solve (see [`crate::optimizer::DeltaEval`]), so every path to a
//! candidate produces the same rates.
//!
//! # Namespaces
//!
//! A [`Candidate`] is only meaningful relative to its
//! [`crate::optimizer::SearchSpace`] (the same `home`/`remote_ppm`
//! vectors describe different placements in different spaces), so a memo
//! shared across searches over *different* spaces — the `repro serve`
//! service keeps one process-wide memo alive across all requests — must
//! not let their entries alias. [`ShardedScoreMemo::lookup_ns`] /
//! [`ShardedScoreMemo::insert_ns`] therefore key every entry by a caller
//! namespace (`SearchSpace::fingerprint`); the un-suffixed
//! [`ShardedScoreMemo::lookup`] / [`ShardedScoreMemo::insert`] are the
//! namespace-0 special case used by single-space searches.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::space::Candidate;

/// Number of shards (power of two so the hash folds with a mask).
const N_SHARDS: usize = 16;

/// Per-shard entry cap: like `RemoteRateModel`, a full shard is cleared
/// rather than evicted entry-by-entry — searches revisit recent
/// candidates, so a periodic flush keeps the common case a hit without
/// unbounded growth. 1 M candidates ≈ 100 MB worst case across shards.
const MAX_ENTRIES_PER_SHARD: usize = 65_536;

/// Concurrency-safe `(namespace, candidate)` → score memo.
pub struct ShardedScoreMemo {
    shards: Vec<Mutex<HashMap<u64, HashMap<Candidate, f64>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for ShardedScoreMemo {
    fn default() -> Self {
        ShardedScoreMemo::new()
    }
}

impl ShardedScoreMemo {
    /// An empty memo.
    pub fn new() -> ShardedScoreMemo {
        ShardedScoreMemo {
            shards: (0..N_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// FNV-1a over the namespace and candidate encoding, folded to a
    /// shard index.
    fn shard_of(ns: u64, c: &Candidate) -> usize {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        let mut eat = |byte: u8| {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        };
        for b in ns.to_le_bytes() {
            eat(b);
        }
        for &d in &c.home {
            for b in d.to_le_bytes() {
                eat(b);
            }
        }
        for &r in &c.remote_ppm {
            for b in r.to_le_bytes() {
                eat(b);
            }
        }
        // Fold the high bits in so the mask doesn't only see FNV's
        // low-entropy low byte.
        ((h ^ (h >> 32)) as usize) & (N_SHARDS - 1)
    }

    /// The memoized score of `c` under namespace `ns`, counting a hit or
    /// miss.
    pub fn lookup_ns(&self, ns: u64, c: &Candidate) -> Option<f64> {
        let shard = self.shards[Self::shard_of(ns, c)].lock().expect("score memo poisoned");
        match shard.get(&ns).and_then(|inner| inner.get(c)) {
            Some(&s) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(s)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record `score` for `c` under namespace `ns` (clearing the shard
    /// first when full).
    pub fn insert_ns(&self, ns: u64, c: &Candidate, score: f64) {
        let mut shard = self.shards[Self::shard_of(ns, c)].lock().expect("score memo poisoned");
        if shard.values().map(HashMap::len).sum::<usize>() >= MAX_ENTRIES_PER_SHARD {
            shard.clear();
        }
        shard.entry(ns).or_default().insert(c.clone(), score);
    }

    /// The memoized score of `c` in the default namespace.
    pub fn lookup(&self, c: &Candidate) -> Option<f64> {
        self.lookup_ns(0, c)
    }

    /// Record `score` for `c` in the default namespace.
    pub fn insert(&self, c: &Candidate, score: f64) {
        self.insert_ns(0, c, score)
    }

    /// `(hits, misses, entries)` across all shards and namespaces.
    pub fn stats(&self) -> (u64, u64, usize) {
        let entries = self
            .shards
            .iter()
            .map(|s| {
                s.lock().expect("score memo poisoned").values().map(HashMap::len).sum::<usize>()
            })
            .sum();
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed), entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(h: Vec<u16>, r: Vec<u32>) -> Candidate {
        Candidate { home: h, remote_ppm: r }
    }

    #[test]
    fn lookup_insert_round_trip_and_counters() {
        let memo = ShardedScoreMemo::new();
        let c = cand(vec![0, 1, 2], vec![0, 250_000, 0]);
        assert_eq!(memo.lookup(&c), None);
        memo.insert(&c, 42.5);
        assert_eq!(memo.lookup(&c), Some(42.5));
        let (hits, misses, entries) = memo.stats();
        assert_eq!((hits, misses, entries), (1, 1, 1));
    }

    #[test]
    fn distinct_candidates_do_not_collide() {
        let memo = ShardedScoreMemo::new();
        for i in 0..64u16 {
            memo.insert(&cand(vec![i, i + 1], vec![u32::from(i), 0]), i as f64);
        }
        for i in 0..64u16 {
            assert_eq!(memo.lookup(&cand(vec![i, i + 1], vec![u32::from(i), 0])), Some(i as f64));
        }
    }

    #[test]
    fn namespaces_do_not_alias() {
        // The same candidate encoding means different placements in
        // different search spaces; entries must stay per-namespace.
        let memo = ShardedScoreMemo::new();
        let c = cand(vec![1, 0], vec![0, 0]);
        memo.insert_ns(7, &c, 1.0);
        memo.insert_ns(9, &c, 2.0);
        memo.insert(&c, 3.0); // default namespace 0
        assert_eq!(memo.lookup_ns(7, &c), Some(1.0));
        assert_eq!(memo.lookup_ns(9, &c), Some(2.0));
        assert_eq!(memo.lookup(&c), Some(3.0));
        assert_eq!(memo.lookup_ns(8, &c), None);
        let (_, _, entries) = memo.stats();
        assert_eq!(entries, 3);
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let memo = ShardedScoreMemo::new();
        let cands: Vec<Candidate> =
            (0..256u16).map(|i| cand(vec![i % 4, i / 4], vec![0, u32::from(i) * 1000])).collect();
        let results = crate::parallel::par_map(&cands, |c| {
            memo.insert(c, f64::from(c.home[1]));
            memo.lookup(c)
        });
        for (c, r) in cands.iter().zip(results) {
            assert_eq!(r, Some(f64::from(c.home[1])), "{c:?}");
        }
    }
}
