#!/usr/bin/env python3
"""Pure-Python mirror of the multi-interface simulation substrate.

Mirrors `rust/src/simulator/network.rs` (and the single-interface seed
loops it generalizes) operation for operation — same IEEE-754 double
arithmetic in the same order, same xorshift64* draw sequence — so the two
implementations can be compared *bitwise*. Run it directly:

    python3 python/netfluid_mirror.py

It executes the mirror's own conformance checks:

1. the generalized multi-interface fluid loop, run on a degenerate
   single-interface network, is bit-identical to the seed fused loop of
   `rust/src/simulator/fluid.rs`;
2. the generalized multi-interface DES, run with r = 0 on a multi-domain
   network, decomposes into components that replay the seed DES of
   `rust/src/simulator/des.rs` per domain, bit for bit;
3. the stranded-capacity fix: `share_remote` is a global fixed point
   (gated groups release the grants their slowest portion cannot use),
   links are DIRECTED full-duplex interfaces, and both simulators issue
   lockstep streams (one shared window per stream); the historical
   single-pass/half-duplex numbers are pinned for the degenerate cases
   (no gating, r = 0, single interface, one-direction duplex traffic);
4. the worked 2xNPS4 Rome example and the gated-regime example of
   `docs/SIMULATORS.md`: multi-interface fluid vs the analytic fixed
   point within the paper's 8% ceiling (the old single pass is >8% off
   in the gated regime, and no link ever exceeds its capacity).

Keep this file in sync with the Rust — it is the reference the docs'
numbers are cross-checked against (see docs/SIMULATORS.md).
"""

import heapq
import math

CACHE_LINE = 64.0
ELEMS_PER_LINE = 8.0

# --------------------------------------------------------------------------
# Machine rows (rust/src/config/machine.rs) — the fields the simulators use.
# --------------------------------------------------------------------------

MACHINES = {
    "bdw1": dict(cores=10, freq=2.2, simd=32, ld_per_cy=2.0, l1l2=64.0, l2l3=32.0,
                 llc="inclusive", overlap="sum", read_bw=66.9, stream_pen=0.0,
                 residue=3.2, residue_all=False, link_bw=38.4,
                 L0=200.0, D0=1.5, beta=1.0, wp=0.26),
    "rome": dict(cores=8, freq=2.35, simd=32, ld_per_cy=2.0, l1l2=64.0, l2l3=32.0,
                 llc="victim", overlap="max", read_bw=35.0, stream_pen=0.022,
                 residue=0.9, residue_all=True, link_bw=64.0,
                 L0=260.0, D0=1.5, beta=1.0, wp=0.02),
}

# Streaming kernels: (reads, writes, rfo, loads/iter, stores/iter, flops/iter)
KERNELS = {
    "dcopy": (1, 1, 1, 1, 1, 0),
    "ddot2": (2, 0, 0, 2, 0, 2),
    "stream": (2, 1, 1, 2, 1, 2),
    "daxpy": (2, 1, 0, 2, 1, 2),
}


def cost_factor(m, write_frac, streams):
    g = 1.0 - math.exp(-write_frac / 0.12)
    wr = 1.0 + m["wp"] * g
    st = max(1.0 - m["stream_pen"] * (streams - 1), 0.5)
    return wr / st


def saturated_bw(m, write_frac, streams):
    return m["read_bw"] / cost_factor(m, write_frac, streams)


def capacity_lines_per_cy(m):
    return m["read_bw"] / m["freq"] / CACHE_LINE


def to_gbs(m, lines_per_cy):
    return lines_per_cy * CACHE_LINE * m["freq"]


def ecm_workload(m, kname):
    """Mirror of ecm::predict -> CoreWorkload: (d, c, f, bs)."""
    reads, writes, rfo, loads, stores, flops = KERNELS[kname]
    total = reads + writes + rfo
    wf = writes / total
    lanes = m["simd"] / 8.0
    iters = ELEMS_PER_LINE
    t_ol = iters * flops / (2.0 * lanes * 2.0)
    t_l1reg = math.ceil(iters * loads / lanes) / m["ld_per_cy"]
    t_l1l2 = total * CACHE_LINE / m["l1l2"]
    if m["llc"] == "inclusive":
        l3_lines = total
    else:
        l3_lines = max(reads - reads, 0) + writes  # l3 == mem for streaming
    t_l2l3 = l3_lines * CACHE_LINE / m["l2l3"]
    bs = saturated_bw(m, wf, total)
    t_mem = total * CACHE_LINE / (bs / m["freq"])
    residue_lines = total if m["residue_all"] else reads + rfo
    t_lat = m["residue"] * residue_lines
    if m["overlap"] == "sum":
        t_ecm = max(t_ol, t_l1reg + t_l1l2 + t_l2l3 + t_mem + t_lat)
    else:
        t_ecm = max(t_ol, t_l1reg, t_l1l2, t_l2l3, t_mem + t_lat)
    f = t_mem / t_ecm
    d = total / t_ecm
    c = cost_factor(m, wf, total)
    return d, c, f, bs


def ecm_workload_stencil(m):
    """LC-at-L3 jacobi-like 2D stencil (mirror-representative of
    kernels::jacobi_traffic with the layer condition satisfied at L3):
    DRAM sees 3 streams/line (1 read + 1 write + 1 RFO), L2<->L3 sees 5
    (the two extra stencil rows hit in L3). Returns
    (d_l3, c, f, bs, f_l3, bs_l3, l3_frac) where d_l3 is the L2-miss line
    rate, (f, bs) the DRAM-level chars, (f_l3, bs_l3) the L3-level chars,
    and l3_frac the fraction of L2-miss lines that stop at the shared L3.

    Identities the tandem folding relies on (exact in f64):
      f  * bs  == d_mem * 64 * freq   (DRAM demand per core)
      f3 * bs3 == d_l3  * 64 * freq   (L3 demand per core)
    """
    mem_total, l3_total = 3, 5
    wf = 1.0 / mem_total
    loads, stores, flops = 4.0, 1.0, 4.0
    lanes = m["simd"] / 8.0
    iters = ELEMS_PER_LINE
    t_ol = iters * flops / (2.0 * lanes * 2.0)
    t_l1reg = math.ceil(iters * loads / lanes) / m["ld_per_cy"]
    t_l1l2 = l3_total * CACHE_LINE / m["l1l2"]
    t_l2l3 = l3_total * CACHE_LINE / m["l2l3"]
    bs = saturated_bw(m, wf, mem_total)
    t_mem = mem_total * CACHE_LINE / (bs / m["freq"])
    residue_lines = mem_total if m["residue_all"] else mem_total - 1
    t_lat = m["residue"] * residue_lines
    if m["overlap"] == "sum":
        t_ecm = max(t_ol, t_l1reg + t_l1l2 + t_l2l3 + t_mem + t_lat)
    else:
        t_ecm = max(t_ol, t_l1reg, t_l1l2, t_l2l3, t_mem + t_lat)
    f = t_mem / t_ecm
    f3 = t_l2l3 / t_ecm
    bs3 = m["l2l3"] * m["freq"]
    d_l3 = l3_total / t_ecm
    c = cost_factor(m, wf, mem_total)
    l3_frac = 1.0 - mem_total / l3_total
    return d_l3, c, f, bs, f3, bs3, l3_frac


# --------------------------------------------------------------------------
# xorshift64* (rust/src/simulator/xorshift.rs)
# --------------------------------------------------------------------------

M64 = (1 << 64) - 1


class XorShift64:
    def __init__(self, seed):
        self.state = seed if seed != 0 else 0x9E3779B97F4A7C15

    def next_u64(self):
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & M64
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & M64

    def next_f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)


# --------------------------------------------------------------------------
# Seed single-interface loops (fluid.rs / des.rs, verbatim semantics)
# --------------------------------------------------------------------------

def fluid_seed(m, workloads, warmup=4096, measure=12288):
    """workloads: list of (d, c). Returns (per_core_lines_per_cy, util)."""
    cap = capacity_lines_per_cy(m)
    n = len(workloads)
    d = [w[0] for w in workloads]
    c = [w[1] for w in workloads]
    win = [m["D0"] + m["beta"] * d[i] * c[i] * m["L0"] for i in range(n)]
    occ = [0.0] * n
    served = [0.0] * n
    u_accum = 0.0
    occ_cost = 0.0
    for cycle in range(warmup + measure + 1):
        measuring = cycle > warmup
        lam = min(cap / occ_cost, 1.0) if occ_cost > 1e-12 else 1.0
        if measuring:
            u_accum += min(occ_cost / cap, 1.0)
        keep = 1.0 - lam
        occ_cost = 0.0
        for i in range(n):
            o_pre = occ[i]
            if measuring:
                served[i] += lam * o_pre
            o = o_pre * keep
            if d[i] > 0.0:
                o += min(d[i], max(win[i] - o, 0.0))
            occ[i] = o
            occ_cost += o * c[i]
    return [s / measure for s in served], u_accum / measure


def des_seed(m, workloads, warmup=40000.0, measure=400000.0, seed=0xB4D5EED):
    """Seed DES. workloads: list of (d, c). Returns per-core served lines/cy."""
    cap = capacity_lines_per_cy(m)
    rng = XorShift64(seed)
    n = len(workloads)
    gap, window, cost, queued, busy_flag = [], [], [], [], [False]
    outstanding = [0] * n
    blocked = [False] * n
    served = [0] * n
    for d, c in workloads:
        gap.append(1.0 / d if d > 0.0 else math.inf)
        w = m["D0"] + m["beta"] * d * c * m["L0"]
        window.append(max(int(math.floor(w + 0.5)), 1))  # f64::round, half away
        cost.append(c / cap)
        queued.append(0)
    heap = []
    for i in range(n):
        if math.isfinite(gap[i]):
            heapq.heappush(heap, (rng.next_f64() * gap[i], i, 0))
    t_end = warmup + measure

    def try_serve(t):
        if busy_flag[0]:
            return
        total = sum(queued)
        if total == 0:
            return
        x = int(rng.next_f64() * total)
        pick = 0
        for i in range(n):
            if x < queued[i]:
                pick = i
                break
            x -= queued[i]
        queued[pick] -= 1
        busy_flag[0] = True
        heapq.heappush(heap, (t + cost[pick], pick, 1))

    while heap:
        t, idx, kind = heapq.heappop(heap)
        if t >= t_end:
            break
        if kind == 0:
            if outstanding[idx] < window[idx]:
                queued[idx] += 1
                outstanding[idx] += 1
                blocked[idx] = False
                jitter = 0.95 + 0.1 * rng.next_f64()
                heapq.heappush(heap, (t + gap[idx] * jitter, idx, 0))
                try_serve(t)
            else:
                blocked[idx] = True
        else:
            outstanding[idx] -= 1
            if t >= warmup:
                served[idx] += 1
            busy_flag[0] = False
            if blocked[idx]:
                blocked[idx] = False
                heapq.heappush(heap, (t, idx, 0))
            try_serve(t)
    return [s / measure for s in served]


# --------------------------------------------------------------------------
# The interface network (network.rs)
# --------------------------------------------------------------------------

class Net:
    """mem_caps: lines/cy per domain; links: DIRECTED socket pairs (a, b)
    with per-direction capacities link_caps (lines/cy) / link_caps_gbs;
    l3_caps_gbs: one shared-L3 interface per socket (empty = unmodeled)."""

    def __init__(self, mem_caps, socket_of, links, link_caps_gbs, m, l3_caps_gbs=None):
        self.mem_caps = mem_caps
        self.socket_of = socket_of
        self.links = links
        self.link_caps_gbs = link_caps_gbs
        self.link_caps = [g / m["freq"] / CACHE_LINE for g in link_caps_gbs]
        self.l3_caps_gbs = l3_caps_gbs or []
        self.l3_caps = [g / m["freq"] / CACHE_LINE for g in self.l3_caps_gbs]
        self.m = m


def directed_links(sockets):
    """All ordered socket pairs (a, b), a != b, lexicographic."""
    return [(a, b) for a in range(sockets) for b in range(sockets) if a != b]


def net_of(m, sockets, domains_per_socket, bw_scale=None):
    nd = sockets * domains_per_socket
    scale = bw_scale or [1.0] * nd
    mem_caps = [capacity_lines_per_cy(m) * s for s in scale]
    socket_of = [d // domains_per_socket for d in range(nd)]
    links = directed_links(sockets) if m["link_bw"] > 0 else []
    fwd = m["link_bw"]
    rev = m.get("link_bw_rev", fwd) or fwd
    link_caps_gbs = [fwd if a < b else rev for a, b in links]
    l3 = m.get("l3_bw", 0.0)
    l3_caps_gbs = [l3] * sockets if l3 > 0.0 else []
    return Net(mem_caps, socket_of, links, link_caps_gbs, m, l3_caps_gbs)


def route(net, streams):
    """streams: (d, c, home, r) or (d, c, home, r, l3_frac). Returns
    portions (stream, target, link_or_None, weight, l3_socket_or_None,
    mem_stage_bool). A cross-socket portion rides the directed link
    (socket_of[home] -> socket_of[target]). A stream with l3_frac > 0 is
    L3-resident: `d` is its L2-miss line rate, l3_frac of those lines stop
    at the home socket's shared L3 (l3-only portion) and the rest continue
    to DRAM in tandem (L3 stage first, then the home memory interface)."""
    nd = len(net.mem_caps)
    portions = []
    for si, s in enumerate(streams):
        d, c, home, r = s[:4]
        l3f = s[4] if len(s) > 4 else 0.0
        if l3f > 0.0:
            assert r == 0.0, "L3-resident streams do not spread remotely"
            assert net.l3_caps, "L3-resident stream on a net without an L3 node"
            sock = net.socket_of[home]
            portions.append((si, home, None, l3f, sock, False))
            if l3f < 1.0:
                portions.append((si, home, None, 1.0 - l3f, sock, True))
            continue
        home_w = 1.0 - r
        if home_w > 0.0:
            portions.append((si, home, None, home_w, None, True))
        if r > 0.0:
            w = r / (nd - 1)
            for t in range(nd):
                if t == home:
                    continue
                link = None
                if net.socket_of[t] != net.socket_of[home] and net.links:
                    link = net.links.index((net.socket_of[home], net.socket_of[t]))
                portions.append((si, t, link, w, None, True))
    return portions


def fluid_net(net, streams, warmup=4096, measure=12288):
    """Generalized fluid loop with lockstep streams: each stream owns ONE
    issue window shared by all its portions, and issued occupancy is split
    across portions in proportion to their routing weights — a lagging
    portion (e.g. a link-gated remote slice) clogs the shared window and
    throttles the whole stream, which is what the analytic lockstep rule
    `min_p grant_p / w_p` assumes. With r = 0 every stream has exactly one
    portion and the loop is bit-identical to the seed fused loop.

    L3-resident streams drain their l3-only portions at the shared-L3
    node's rate and their tandem portions at min(lam_l3, lam_mem); an L3
    line costs 1.0 at the L3 node, and only mem-stage occupancy reaches
    the memory interface (weighted by the stream's DRAM cost factor c).

    Returns (per-portion lines/cy, portions, per-interface utilization
    [mem..., links..., l3...])."""
    m = net.m
    nd = len(net.mem_caps)
    nl = len(net.links)
    n3 = len(net.l3_caps)
    ns = len(streams)
    portions = route(net, streams)
    np_ = len(portions)
    by_stream = [[i for i in range(np_) if portions[i][0] == s] for s in range(ns)]
    ds = [streams[s][0] for s in range(ns)]
    cs = [streams[s][1] for s in range(ns)]
    # The concurrency window hides DRAM latency, so it is sized from the
    # DRAM-equivalent demand d*(1 - l3_frac): L3 hits complete at cache
    # latency and do not hold a miss slot. Bitwise d*1.0 == d at frac 0.
    l3fs = [streams[s][4] if len(streams[s]) > 4 else 0.0 for s in range(ns)]
    win = [m["D0"] + m["beta"] * (ds[s] * (1.0 - l3fs[s])) * cs[s] * m["L0"]
           for s in range(ns)]
    occ = [0.0] * np_
    served = [0.0] * np_
    occ_mem = [0.0] * nd
    occ_link = [0.0] * nl
    occ_l3 = [0.0] * n3
    u_mem = [0.0] * nd
    u_link = [0.0] * nl
    u_l3 = [0.0] * n3
    for cycle in range(warmup + measure + 1):
        measuring = cycle > warmup
        lam_mem = [min(net.mem_caps[d] / occ_mem[d], 1.0) if occ_mem[d] > 1e-12 else 1.0
                   for d in range(nd)]
        lam_link = [min(net.link_caps[l] / occ_link[l], 1.0) if occ_link[l] > 1e-12 else 1.0
                    for l in range(nl)]
        lam_l3 = [min(net.l3_caps[s3] / occ_l3[s3], 1.0) if occ_l3[s3] > 1e-12 else 1.0
                  for s3 in range(n3)]
        if measuring:
            for d in range(nd):
                u_mem[d] += min(occ_mem[d] / net.mem_caps[d], 1.0)
            for l in range(nl):
                u_link[l] += min(occ_link[l] / net.link_caps[l], 1.0)
            for s3 in range(n3):
                u_l3[s3] += min(occ_l3[s3] / net.l3_caps[s3], 1.0)
        occ_mem = [0.0] * nd
        occ_link = [0.0] * nl
        occ_l3 = [0.0] * n3
        # Drain every portion at its interface rate.
        for i in range(np_):
            _, tgt, link, _, l3s, mem = portions[i]
            if l3s is None:
                lam = lam_mem[tgt] if link is None else min(lam_mem[tgt], lam_link[link])
            else:
                lam = min(lam_l3[l3s], lam_mem[tgt]) if mem else lam_l3[l3s]
            o_pre = occ[i]
            if measuring:
                served[i] += lam * o_pre
            occ[i] = o_pre * (1.0 - lam)
        # Issue per stream through the shared window, split by weight.
        for s in range(ns):
            if ds[s] > 0.0:
                occ_s = sum(occ[i] for i in by_stream[s])
                inflow = min(ds[s], max(win[s] - occ_s, 0.0))
                for i in by_stream[s]:
                    occ[i] += inflow * portions[i][3]
        for i in range(np_):
            _, tgt, link, _, l3s, mem = portions[i]
            if mem:
                occ_mem[tgt] += occ[i] * cs[portions[i][0]]
            if link is not None:
                occ_link[link] += occ[i]
            if l3s is not None:
                occ_l3[l3s] += occ[i]
    util = ([u / measure for u in u_mem] + [u / measure for u in u_link]
            + [u / measure for u in u_l3])
    return [s / measure for s in served], portions, util


def des_net(net, streams, warmup=40000.0, measure=400000.0, seed=0xB4D5EED):
    """Generalized DES with lockstep streams: one issue process and one
    outstanding-line window per STREAM (portion picked per line with
    probability = routing weight), links a first service stage (cost
    1/C_link per line), the target memory interface the second. A stream's
    interfaces are all coupled through its shared window, so connected
    components are built over both link crossings and stream membership.
    With r = 0 every stream has one portion, no portion-pick draw is made,
    and each domain replays the seed DES bit for bit.

    L3-resident streams: the shared-L3 node is a first service stage (cost
    1/C_l3 per line, like a link); an l3-only portion completes there,
    a tandem portion continues into the home memory interface.

    Returns (per-portion lines/cy, portions)."""
    m = net.m
    nd = len(net.mem_caps)
    nl = len(net.links)
    n3 = len(net.l3_caps)
    ns = len(streams)
    portions = route(net, streams)
    np_ = len(portions)

    # Union-find over interfaces (mem d -> d, link l -> nd + l, L3 node
    # s -> nd + nl + s); a stream couples every interface its portions touch.
    parent = list(range(nd + nl + n3))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)

    for p in portions:
        if p[2] is not None:
            union(p[1], nd + p[2])
        if p[4] is not None:
            union(p[1], nd + nl + p[4])
    for s in range(ns):
        targets = [portions[i][1] for i in range(np_) if portions[i][0] == s]
        for t in targets[1:]:
            union(targets[0], t)

    comp_of_iface = [find(x) for x in range(nd + nl + n3)]
    comps = sorted(set(comp_of_iface[portions[i][1]] for i in range(np_)))
    served = [0] * np_
    for comp in comps:
        # Local streams (issuers) and local portions (service customers).
        sl = [s for s in range(ns)
              if any(p[0] == s and comp_of_iface[p[1]] == comp for p in portions)]
        local = [i for i in range(np_) if comp_of_iface[portions[i][1]] == comp]
        rng = XorShift64(seed)
        k = len(local)
        ks = len(sl)
        pof = [[j for j in range(k) if portions[local[j]][0] == s] for s in sl]
        gap, window = [], []
        outstanding, blocked = [0] * ks, [False] * ks
        for s in sl:
            d, c = streams[s][0], streams[s][1]
            l3f = streams[s][4] if len(streams[s]) > 4 else 0.0
            gap.append(1.0 / d if d > 0.0 else math.inf)
            w = m["D0"] + m["beta"] * (d * (1.0 - l3f)) * c * m["L0"]
            window.append(max(int(math.floor(w + 0.5)), 1))
        mcost, lcost, l3cost = [], [], []
        q_mem, q_link, q_l3 = [0] * k, [0] * k, [0] * k
        stream_of = []
        for i in local:
            _, tgt, link, _, l3s, _ = portions[i]
            c = streams[portions[i][0]][1]
            mcost.append(c / net.mem_caps[tgt])
            lcost.append(1.0 / net.link_caps[link] if link is not None else 0.0)
            l3cost.append(1.0 / net.l3_caps[l3s] if l3s is not None else 0.0)
            stream_of.append(sl.index(portions[i][0]))
        mem_busy = {}
        link_busy = {}
        l3_busy = {}
        heap = []
        for sj in range(ks):
            if math.isfinite(gap[sj]):
                heapq.heappush(heap, (rng.next_f64() * gap[sj], sj, 0))
        t_end = warmup + measure

        def try_serve_mem(t, d):
            if mem_busy.get(d, False):
                return
            members = [j for j in range(k)
                       if portions[local[j]][1] == d and portions[local[j]][5]]
            total = sum(q_mem[j] for j in members)
            if total == 0:
                return
            x = int(rng.next_f64() * total)
            pick = members[0]
            for j in members:
                if x < q_mem[j]:
                    pick = j
                    break
                x -= q_mem[j]
            q_mem[pick] -= 1
            mem_busy[d] = True
            heapq.heappush(heap, (t + mcost[pick], pick, 1))

        def try_serve_link(t, l):
            if link_busy.get(l, False):
                return
            members = [j for j in range(k) if portions[local[j]][2] == l]
            total = sum(q_link[j] for j in members)
            if total == 0:
                return
            x = int(rng.next_f64() * total)
            pick = members[0]
            for j in members:
                if x < q_link[j]:
                    pick = j
                    break
                x -= q_link[j]
            q_link[pick] -= 1
            link_busy[l] = True
            heapq.heappush(heap, (t + lcost[pick], pick, 2))

        def try_serve_l3(t, s3):
            if l3_busy.get(s3, False):
                return
            members = [j for j in range(k) if portions[local[j]][4] == s3]
            total = sum(q_l3[j] for j in members)
            if total == 0:
                return
            x = int(rng.next_f64() * total)
            pick = members[0]
            for j in members:
                if x < q_l3[j]:
                    pick = j
                    break
                x -= q_l3[j]
            q_l3[pick] -= 1
            l3_busy[s3] = True
            heapq.heappush(heap, (t + l3cost[pick], pick, 3))

        while heap:
            t, j, kind = heapq.heappop(heap)
            if t >= t_end:
                break
            if kind == 0:
                # j is a local stream index.
                if outstanding[j] < window[j]:
                    outstanding[j] += 1
                    blocked[j] = False
                    jitter = 0.95 + 0.1 * rng.next_f64()
                    heapq.heappush(heap, (t + gap[j] * jitter, j, 0))
                    mine = pof[j]
                    if len(mine) == 1:
                        p = mine[0]
                    else:
                        x = rng.next_f64()
                        p = mine[-1]
                        for cand in mine:
                            w = portions[local[cand]][3]
                            if x < w:
                                p = cand
                                break
                            x -= w
                    link = portions[local[p]][2]
                    l3s = portions[local[p]][4]
                    if link is not None:
                        q_link[p] += 1
                        try_serve_link(t, link)
                    elif l3s is not None:
                        q_l3[p] += 1
                        try_serve_l3(t, l3s)
                    else:
                        q_mem[p] += 1
                        try_serve_mem(t, portions[local[p]][1])
                else:
                    blocked[j] = True
            elif kind == 2:
                # j is a local portion index leaving its link stage.
                _, tgt, link, _, _, _ = portions[local[j]]
                q_mem[j] += 1
                link_busy[link] = False
                try_serve_mem(t, tgt)
                try_serve_link(t, link)
            elif kind == 3:
                # j is a local portion index leaving the shared-L3 stage.
                _, tgt, _, _, l3s, mem = portions[local[j]]
                l3_busy[l3s] = False
                if mem:
                    # Tandem portion: the line continues to the memory iface.
                    q_mem[j] += 1
                    try_serve_mem(t, tgt)
                    try_serve_l3(t, l3s)
                else:
                    # L3-only portion: the line completes at the L3 node.
                    sj = stream_of[j]
                    outstanding[sj] -= 1
                    if t >= warmup:
                        served[local[j]] += 1
                    if blocked[sj]:
                        blocked[sj] = False
                        heapq.heappush(heap, (t, sj, 0))
                    try_serve_l3(t, l3s)
            else:
                # j is a local portion index whose line finished at memory.
                _, tgt, link, _, _, _ = portions[local[j]]
                sj = stream_of[j]
                outstanding[sj] -= 1
                if t >= warmup:
                    served[local[j]] += 1
                mem_busy[tgt] = False
                if blocked[sj]:
                    blocked[sj] = False
                    heapq.heappush(heap, (t, sj, 0))
                try_serve_mem(t, tgt)
    return [s / measure for s in served], portions


def lockstep_per_stream(net, streams, per_portion, portions):
    """min_p drain_p / weight_p, in GB/s."""
    out = []
    for si in range(len(streams)):
        rate = math.inf
        for i, p in enumerate(portions):
            if p[0] == si:
                rate = min(rate, to_gbs(net.m, per_portion[i]) / p[3])
        out.append(rate if math.isfinite(rate) else 0.0)
    return out


# --------------------------------------------------------------------------
# The analytic model (sharing/multigroup.rs + sharing/remote.rs)
# --------------------------------------------------------------------------

def share_weighted_capacity(groups, capacity):
    """groups: list of (n, f, bs). Returns per-group bandwidth."""
    return share_weighted_capped(groups, capacity, [math.inf] * len(groups))


def share_weighted_capped(groups, capacity, rate_caps):
    """share_weighted_capacity with per-group per-core rate caps: the
    demand of group i is min(n f bs, n rate_caps[i]). With all caps
    infinite this is bit-identical to the uncapped fill."""
    k = len(groups)
    demand = [min(n * f * bs, n * rate_caps[i]) for i, (n, f, bs) in enumerate(groups)]
    weight = [n * f for n, f, _ in groups]
    bw = [0.0] * k
    capped = [False] * k
    remaining = min(capacity, sum(demand))
    for _ in range(k):
        wsum = sum(weight[i] for i in range(k) if not capped[i])
        if wsum <= 0.0 or remaining <= 0.0:
            break
        newly = False
        for i in range(k):
            if capped[i]:
                continue
            if remaining * weight[i] / wsum >= demand[i] - 1e-12:
                bw[i] = demand[i]
                capped[i] = True
                newly = True
        if newly:
            remaining = max(min(capacity, sum(demand))
                            - sum(bw[i] for i in range(k) if capped[i]), 0.0)
        else:
            for i in range(k):
                if not capped[i]:
                    bw[i] = remaining * weight[i] / wsum
            break
    return bw


def _gkind(g):
    """Group kind: None (memory-bound), ("l3", f_l3, bs_l3), or ("comp",)."""
    return g[5] if len(g) > 5 else None


def _expand_portions(net, groups):
    """Analytic portion expansion: 7-tuples (group, target, link_or_None,
    weight, l3_socket_or_None, mem_stage_bool, cap_scale), routed through
    the same directed-link rule as route().

    A memory-bound group expands exactly as before (all portions mem-stage,
    cap_scale 1.0). An L3-kind group expands to at most two weight-1.0
    single-stage portions on its home socket/domain: an L3 portion carrying
    ALL its L2-miss traffic (chars f_l3, bs_l3) and — when f*bs > 0 — a
    mem portion carrying its DRAM continuation (group chars f, bs). The mem
    portion's cap_scale = (f*bs)/(f_l3*bs_l3) converts the group's
    L3-level per-core rate cap into DRAM-level units, so the lockstep min
    across the two portions is taken in one common (L3-level) unit.
    A compute-bound group expands to no portions at all."""
    nd = len(net.mem_caps)
    portions = []
    for gi, g in enumerate(groups):
        home, n, f, bs, r = g[:5]
        kind = _gkind(g)
        if kind is not None and kind[0] == "comp":
            continue
        if kind is not None and kind[0] == "l3":
            assert r == 0.0, "L3-resident groups do not spread remotely"
            assert net.l3_caps_gbs, "L3 group on a net without an L3 node"
            f3, bs3 = kind[1], kind[2]
            sock = net.socket_of[home]
            portions.append((gi, home, None, 1.0, sock, False, 1.0))
            if f * bs > 0.0:
                portions.append((gi, home, None, 1.0, None, True,
                                 (f * bs) / (f3 * bs3)))
            continue
        if 1.0 - r > 0.0:
            portions.append((gi, home, None, 1.0 - r, None, True, 1.0))
        if r > 0.0:
            w = r / (nd - 1)
            for t in range(nd):
                if t == home:
                    continue
                link = None
                if net.socket_of[t] != net.socket_of[home] and net.links:
                    link = net.links.index((net.socket_of[home], net.socket_of[t]))
                portions.append((gi, t, link, w, None, True, 1.0))
    return portions


def _fill(net, groups, portions, caps):
    """One global water-fill over every interface with per-group per-core
    rate caps (caps are in the group's reporting unit; a portion's
    cap_scale converts them to its own interface's unit). Returns
    (mem_grant, link_grant, l3_grant) per portion."""
    nd = len(net.mem_caps)
    scale = [net.mem_caps[d] / capacity_lines_per_cy(net.m) for d in range(nd)]
    mem_grant = [0.0] * len(portions)
    link_grant = [0.0] * len(portions)
    l3_grant = [0.0] * len(portions)
    for d in range(nd):
        idx = [i for i, p in enumerate(portions) if p[1] == d and p[5]]
        wg = [(groups[portions[i][0]][1] * portions[i][3],
               groups[portions[i][0]][2],
               groups[portions[i][0]][3] * scale[d]) for i in idx]
        n_tot = sum(g[0] for g in wg)
        if n_tot == 0.0:
            continue
        b_mix = sum(g[0] * g[2] for g in wg) / n_tot
        rc = [caps[portions[i][0]] * portions[i][6] for i in idx]
        for i, bw in zip(idx, share_weighted_capped(wg, b_mix, rc)):
            mem_grant[i] = bw
    for l in range(len(net.links)):
        idx = [i for i, p in enumerate(portions) if p[2] == l]
        if not idx:
            continue
        wg = [(groups[portions[i][0]][1] * portions[i][3],
               groups[portions[i][0]][2],
               groups[portions[i][0]][3] * scale[portions[i][1]]) for i in idx]
        rc = [caps[portions[i][0]] * portions[i][6] for i in idx]
        for i, bw in zip(idx, share_weighted_capped(wg, net.link_caps_gbs[l], rc)):
            link_grant[i] = bw
    for s3 in range(len(net.l3_caps_gbs)):
        idx = [i for i, p in enumerate(portions) if p[4] == s3]
        if not idx:
            continue
        wg = []
        for i in idx:
            g = groups[portions[i][0]]
            kind = _gkind(g)
            wg.append((g[1] * portions[i][3], kind[1], kind[2]))
        rc = [caps[portions[i][0]] * portions[i][6] for i in idx]
        for i, bw in zip(idx, share_weighted_capped(wg, net.l3_caps_gbs[s3], rc)):
            l3_grant[i] = bw
    return mem_grant, link_grant, l3_grant


def _portion_grant(portions, mem_grant, link_grant, l3_grant, i):
    p = portions[i]
    if p[4] is not None and not p[5]:
        return l3_grant[i]
    if p[2] is None:
        return mem_grant[i]
    return min(mem_grant[i], link_grant[i])


def _group_rate(groups, portions, mem_grant, link_grant, l3_grant, gi):
    """Lockstep rate of one group: min_p grant_p / (n w_p) / cap_scale_p,
    reported in the group's own unit (DRAM GB/s for memory-bound groups,
    L3-level GB/s for L3 groups). Compute-bound groups never queue on any
    shared interface and run at their core-bound rate f*bs."""
    g = groups[gi]
    kind = _gkind(g)
    if kind is not None and kind[0] == "comp":
        return g[2] * g[3]
    n = g[1]
    if n == 0:
        return 0.0
    rate = math.inf
    for i, p in enumerate(portions):
        if p[0] != gi:
            continue
        grant = _portion_grant(portions, mem_grant, link_grant, l3_grant, i)
        rate = min(rate, grant / (n * p[3]) / p[6])
    return rate if math.isfinite(rate) else 0.0


def share_remote(net, groups, max_sweeps=64, tol=1e-12):
    """groups: (home, n, f, bs, r) or (home, n, f, bs, r, kind) with kind
    None | ("l3", f_l3, bs_l3) | ("comp",). Returns (per_core, portions,
    info). Mirrors sharing::remote::share_remote: global fixed-point
    water-fill over memory, link, AND shared-L3 interfaces.

    Pass 1 is the plain uncapped fill; if no group is gated by a slower
    portion the result is returned verbatim (iterations == 1, bit-identical
    to the historical single-pass evaluation). Otherwise Gauss-Seidel
    sweeps re-evaluate each group uncapped against the others capped at
    their current rates, so capacity stranded on a gated group's fast
    portions is redistributed; sweeps stop when no cap moves by more than
    tol (relative) or after max_sweeps."""
    k = len(groups)
    portions = _expand_portions(net, groups)
    caps = [math.inf] * k
    mem_grant, link_grant, l3_grant = _fill(net, groups, portions, caps)
    rates = [_group_rate(groups, portions, mem_grant, link_grant, l3_grant, g)
             for g in range(k)]
    gated = [False] * k
    for i, p in enumerate(portions):
        g, w = p[0], p[3]
        n = groups[g][1]
        if n == 0:
            continue
        grant = _portion_grant(portions, mem_grant, link_grant, l3_grant, i)
        if grant / (n * w) / p[6] > rates[g] * (1.0 + 1e-9):
            gated[g] = True
    info = dict(iterations=1, mem_grant=mem_grant, link_grant=link_grant,
                l3_grant=l3_grant)
    if not any(gated):
        return rates, portions, info
    iterations = 1
    for _ in range(max_sweeps):
        delta = math.inf if any(not math.isfinite(c) for c in caps) else 0.0
        for g in range(k):
            saved = caps[g]
            caps[g] = math.inf
            mg, lg, tg = _fill(net, groups, portions, caps)
            r = _group_rate(groups, portions, mg, lg, tg, g)
            caps[g] = r
            if math.isfinite(saved):
                delta = max(delta, abs(r - saved) / max(saved, 1.0))
        iterations += 1
        if delta <= tol:
            break
    mem_grant, link_grant, l3_grant = _fill(net, groups, portions, caps)
    info = dict(iterations=iterations, mem_grant=mem_grant, link_grant=link_grant,
                l3_grant=l3_grant)
    return caps, portions, info


# --------------------------------------------------------------------------
# Conformance checks
# --------------------------------------------------------------------------

def check_fluid_degenerate():
    for mname in ("bdw1", "rome"):
        m = MACHINES[mname]
        wl = [ecm_workload(m, "dcopy")[:2]] * 4 + [ecm_workload(m, "ddot2")[:2]] * 3
        wl += [(0.0, 1.0)]  # idle core
        seed_pc, seed_u = fluid_seed(m, wl)
        net = net_of(m, 1, 1)
        streams = [(d, c, 0, 0.0) for d, c in wl]
        pp, portions, util = fluid_net(net, streams)
        assert len(pp) == len(wl)
        for a, b in zip(seed_pc, pp):
            assert a == b, f"fluid degenerate mismatch on {mname}: {a} vs {b}"
        assert seed_u == util[0], f"utilization mismatch on {mname}"
    print("ok: generalized fluid == seed fluid (single interface, bitwise)")


def check_fluid_r0_multidomain():
    m = MACHINES["rome"]
    dc = ecm_workload(m, "dcopy")[:2]
    dd = ecm_workload(m, "ddot2")[:2]
    # Domain 0: 4x dcopy + 2x ddot2; domain 1 (scaled 0.5): 3x ddot2.
    net = net_of(m, 1, 2, bw_scale=[1.0, 0.5])
    streams = ([(dc[0], dc[1], 0, 0.0)] * 4 + [(dd[0], dd[1], 0, 0.0)] * 2
               + [(dd[0], dd[1], 1, 0.0)] * 3)
    pp, portions, _ = fluid_net(net, streams)
    # Per-domain seed runs (scaled domain: scaled capacity).
    seed0, _ = fluid_seed(m, [dc] * 4 + [dd] * 2)
    m_scaled = dict(m)
    m_scaled["read_bw"] = m["read_bw"] * 0.5
    seed1, _ = fluid_seed(m_scaled, [dd] * 3)
    want = seed0 + seed1
    for a, b in zip(want, pp):
        assert a == b, f"fluid r=0 multi-domain mismatch: {a} vs {b}"
    print("ok: generalized fluid r=0 == per-domain seed runs (bitwise)")


def check_des_degenerate_and_r0():
    m = MACHINES["rome"]
    dc = ecm_workload(m, "dcopy")[:2]
    dd = ecm_workload(m, "ddot2")[:2]
    cfg = dict(warmup=20000.0, measure=100000.0)
    # Degenerate single interface.
    wl = [dc] * 3 + [dd] * 2
    seed_pc = des_seed(m, wl, **cfg)
    net = net_of(m, 1, 1)
    pp, portions = des_net(net, [(d, c, 0, 0.0) for d, c in wl], **cfg)
    for a, b in zip(seed_pc, pp):
        assert a == b, f"DES degenerate mismatch: {a} vs {b}"
    # r=0 over two domains == two independent seed runs.
    net2 = net_of(m, 1, 2)
    streams = [(dc[0], dc[1], 0, 0.0)] * 3 + [(dd[0], dd[1], 1, 0.0)] * 4
    pp2, _ = des_net(net2, streams, **cfg)
    want = des_seed(m, [dc] * 3, **cfg) + des_seed(m, [dd] * 4, **cfg)
    for a, b in zip(want, pp2):
        assert a == b, f"DES r=0 multi-domain mismatch: {a} vs {b}"
    print("ok: generalized DES == seed DES (degenerate + r=0, bitwise)")


def worked_example(verbose=True):
    """docs/SIMULATORS.md: 2 x NPS4 Rome, dcopy:64@scatter %r0.5 —
    the xGMI link is the bottleneck of every cross-socket portion."""
    m = MACHINES["rome"]
    net = net_of(m, 2, 4)
    d, c, f, bs = ecm_workload(m, "dcopy")
    # 64 cores, 8 per domain, each sending half its lines remote.
    streams = [(d, c, dom, 0.5) for dom in range(8) for _ in range(8)]
    pp, portions, util = fluid_net(net, streams)
    sim_pc = lockstep_per_stream(net, streams, pp, portions)
    groups = [(dom, 8, f, bs, 0.5) for dom in range(8)]
    model_pc, _, _ = share_remote(net, groups)
    # Per-direction link throughput: sum of cross-portion drains, in GB/s.
    link_gbs = [sum(to_gbs(m, pp[i]) for i, p in enumerate(portions) if p[2] == l)
                for l in range(len(net.links))]
    errs = [abs(sim_pc[8 * dom] - model_pc[dom]) / model_pc[dom] for dom in range(8)]
    if verbose:
        print("\nworked example: 2xNPS4 Rome, dcopy on all 64 cores, r = 0.5")
        print(f"  kernel chars: f = {f:.3f}, b_s = {bs:.2f} GB/s, "
              f"d = {d:.4f} lines/cy, c = {c:.4f}")
        print(f"  model  per-core: {model_pc[0]:.3f} GB/s (link-gated)")
        print(f"  fluid  per-core: {sim_pc[0]:.3f} GB/s "
              f"(err {errs[0] * 100:.2f}%)")
        for l, (a, b) in enumerate(net.links):
            print(f"  link s{a}->s{b}: {link_gbs[l]:.2f} GB/s simulated vs "
                  f"{net.link_caps_gbs[l]:.1f} GB/s capacity (util {util[8 + l]:.3f})")
    for l in range(len(net.links)):
        assert link_gbs[l] <= net.link_caps_gbs[l] * 1.001, "link exceeded capacity"
    assert max(errs) < 0.08, f"link-gated fluid vs model error {max(errs)}"
    print("ok: link-gated fluid within 8% of the analytic water-fill "
          f"(worst {max(errs) * 100:.2f}%)")
    return sim_pc, model_pc, link_gbs


def mixed_example(verbose=True):
    """The docs/MODEL.md-style example: dcopy:8@d0%r0.25 + ddot2:8@d4."""
    m = MACHINES["rome"]
    net = net_of(m, 2, 4)
    d1, c1, f1, bs1 = ecm_workload(m, "dcopy")
    d2, c2, f2, bs2 = ecm_workload(m, "ddot2")
    streams = [(d1, c1, 0, 0.25)] * 8 + [(d2, c2, 4, 0.0)] * 8
    pp, portions, _ = fluid_net(net, streams)
    sim_pc = lockstep_per_stream(net, streams, pp, portions)
    model_pc, _, _ = share_remote(net, [(0, 8, f1, bs1, 0.25), (4, 8, f2, bs2, 0.0)])
    if verbose:
        print("\nmixed example: dcopy:8@d0%r0.25 + ddot2:8@d4 on 2x4 Rome")
        print(f"  dcopy: model {model_pc[0]:.3f}, fluid {sim_pc[0]:.3f} GB/s/core")
        print(f"  ddot2: model {model_pc[1]:.3f}, fluid {sim_pc[8]:.3f} GB/s/core")
    return sim_pc, model_pc


def check_stranded_capacity():
    """The tentpole regression: a link-gated group must not strand its
    memory-interface grant. Two sockets x one domain, 2 GB/s link, f=0.8,
    b_s=32: group A (n=4, r=0.5) is link-gated at 1.0 GB/s/core; group B
    (n=4, r=0) must then receive the freed home bandwidth: 7.5 GB/s/core,
    where the historical single pass stranded it at 16/3 = 5.333."""
    m = dict(read_bw=32.0, freq=1.0, link_bw=2.0)
    net = net_of(m, 2, 1)
    groups = [(0, 4, 0.8, 32.0, 0.5), (0, 4, 0.8, 32.0, 0.0)]
    pc, portions, info = share_remote(net, groups)
    assert info["iterations"] > 1, "gated case must iterate"
    assert abs(pc[0] - 1.0) < 1e-12, f"A per-core {pc[0]!r} != 1.0"
    assert abs(pc[1] - 7.5) < 1e-12, f"B per-core {pc[1]!r} != 7.5"
    # The historical single pass: one uncapped fill of domain 0.
    old = share_weighted_capacity([(2.0, 0.8, 32.0), (4.0, 0.8, 32.0)], 32.0)
    old_b = old[1] / 4.0
    assert abs(old_b - 16.0 / 3.0) < 1e-12
    assert old_b < pc[1] - 2.0, "old single pass must under-predict B"
    print(f"ok: stranded capacity redistributed (B {old_b:.3f} -> {pc[1]:.3f} "
          f"GB/s/core, {info['iterations']} iterations)")


def check_fixed_point_degenerates():
    """No-gating cases terminate in one pass (the uncapped fill verbatim)."""
    m = MACHINES["rome"]
    d, c, f, bs = ecm_workload(m, "dcopy")
    f2, bs2 = ecm_workload(m, "ddot2")[2:]
    # r = 0 on a multi-domain net: one portion per group, never gated.
    net = net_of(m, 2, 2)
    pc, _, info = share_remote(net, [(0, 4, f, bs, 0.0), (3, 4, f2, bs2, 0.0)])
    assert info["iterations"] == 1, "r=0 must terminate in one pass"
    # Single interface.
    net1 = net_of(m, 1, 1)
    pc1, _, info1 = share_remote(net1, [(0, 4, f, bs, 0.0), (0, 4, f2, bs2, 0.0)])
    assert info1["iterations"] == 1, "single interface must terminate in one pass"
    # Wide link, balanced portions: gating never triggers.
    m_wide = dict(m, link_bw=1e6)
    netw = net_of(m_wide, 2, 1)
    pcw, _, infow = share_remote(netw, [(0, 8, f, bs, 0.5)])
    assert infow["iterations"] == 1, "ungated remote case must terminate in one pass"
    print("ok: no-gating cases terminate in one fixed-point pass")


def check_duplex_one_direction():
    """Directed full-duplex links with one-direction traffic reproduce the
    historical half-duplex numbers (pinned from the pre-duplex mirror)."""
    m = MACHINES["rome"]
    d, c, f, bs = ecm_workload(m, "dcopy")
    net = net_of(m, 2, 1)
    pins = [
        ([(0, 8, f, bs, 0.25)], [5.473993867539909]),
        ([(0, 8, f, bs, 0.5)], [8.210990801309864]),
        # Two identical groups: saturated but ungated by symmetry (one pass).
        ([(0, 4, f, bs, 0.5), (0, 4, f, bs, 0.5)],
         [8.210990801309864, 8.210990801309864]),
    ]
    for groups, want in pins:
        pc, portions, _ = share_remote(net, groups)
        # All cross-socket traffic rides the s0->s1 direction only.
        assert all(p[2] in (None, 0) for p in portions)
        for a, b in zip(pc, want):
            assert a == b, f"one-direction duplex mismatch: {a!r} vs {b!r}"
    print("ok: one-direction traffic on duplex links == half-duplex pins (bitwise)")


def gated_example(verbose=True):
    """The gated-regime conformance case: Rome narrowed to an 8 GB/s link,
    dcopy:4@d0%r0.5 + ddot2:4@d0. The dcopy group is link-gated; the old
    single pass strands its home grant and under-predicts ddot2. The fluid
    simulation agrees with the fixed point, not the single pass."""
    m = dict(MACHINES["rome"], link_bw=8.0)
    net = net_of(m, 2, 1)
    d1, c1, f1, bs1 = ecm_workload(m, "dcopy")
    d2, c2, f2, bs2 = ecm_workload(m, "ddot2")
    streams = [(d1, c1, 0, 0.5)] * 4 + [(d2, c2, 0, 0.0)] * 4
    pp, portions, _ = fluid_net(net, streams)
    sim_pc = lockstep_per_stream(net, streams, pp, portions)
    groups = [(0, 4, f1, bs1, 0.5), (0, 4, f2, bs2, 0.0)]
    model_pc, mportions, info = share_remote(net, groups)
    # Historical single pass: uncapped fill only.
    caps = [math.inf] * len(groups)
    mg, lg, tg = _fill(net, groups, mportions, caps)
    old_pc = [_group_rate(groups, mportions, mg, lg, tg, g)
              for g in range(len(groups))]
    errs = [abs(sim_pc[4 * g] - model_pc[g]) / model_pc[g] for g in range(2)]
    old_err = abs(sim_pc[4] - old_pc[1]) / old_pc[1]
    if verbose:
        print("\ngated example: dcopy:4@d0%r0.5 + ddot2:4@d0, 8 GB/s link")
        print(f"  dcopy: model {model_pc[0]:.3f}, old {old_pc[0]:.3f}, "
              f"fluid {sim_pc[0]:.3f} GB/s/core (err {errs[0] * 100:.2f}%)")
        print(f"  ddot2: model {model_pc[1]:.3f}, old {old_pc[1]:.3f}, "
              f"fluid {sim_pc[4]:.3f} GB/s/core (err {errs[1] * 100:.2f}%, "
              f"old err {old_err * 100:.2f}%)")
        print(f"  fixed point: {info['iterations']} iterations")
    assert info["iterations"] > 1
    assert max(errs) < 0.08, f"gated-regime fluid vs fixed point error {max(errs)}"
    assert old_err > 0.08, "old single pass should be outside the 8% ceiling"
    print("ok: gated-regime fluid within 8% of the fixed point "
          f"(worst {max(errs) * 100:.2f}%); single pass off by {old_err * 100:.1f}%")
    return sim_pc, model_pc, old_pc


def check_l3_degenerate():
    """Memory-bound-only traffic on a net WITH a configured L3 node is
    bit-identical to the same net without one, at every layer (model,
    fluid, DES) — the structural degenerate-case guarantee that lets
    builtin machine rows carry l3_bw_gbs estimates without perturbing any
    existing scenario."""
    m = MACHINES["rome"]
    m_l3 = dict(m, l3_bw=120.0)
    dc = ecm_workload(m, "dcopy")
    dd = ecm_workload(m, "ddot2")
    net = net_of(m, 2, 1)
    net_l3 = net_of(m_l3, 2, 1)
    groups = [(0, 4, dc[2], dc[3], 0.25), (1, 3, dd[2], dd[3], 0.0)]
    pc_a, po_a, info_a = share_remote(net, groups)
    pc_b, po_b, info_b = share_remote(net_l3, groups)
    assert pc_a == pc_b, "model perturbed by an unused L3 node"
    assert info_a["iterations"] == info_b["iterations"]
    assert info_a["mem_grant"] == info_b["mem_grant"]
    assert [p[:4] for p in po_a] == [p[:4] for p in po_b]
    streams = [(dc[0], dc[1], 0, 0.25)] * 4 + [(dd[0], dd[1], 1, 0.0)] * 3
    fa, _, ua = fluid_net(net, streams)
    fb, _, ub = fluid_net(net_l3, streams)
    assert fa == fb, "fluid perturbed by an unused L3 node"
    assert ua == ub[:len(ua)] and all(u == 0.0 for u in ub[len(ua):])
    cfg = dict(warmup=20000.0, measure=100000.0)
    da, _ = des_net(net, streams, **cfg)
    db, _ = des_net(net_l3, streams, **cfg)
    assert da == db, "DES perturbed by an unused L3 node"
    print("ok: mem-only traffic with an L3 node configured is bit-identical "
          "to no L3 node (model + fluid + DES)")


def check_compute_zero_share():
    """A compute-bound group caps at its core-bound rate f*bs and consumes
    zero bandwidth share: its memory-bound peers are bitwise unchanged."""
    m = dict(MACHINES["rome"], l3_bw=120.0)
    net = net_of(m, 1, 1)
    _, _, f, bs = ecm_workload(m, "dcopy")
    alone, _, _ = share_remote(net, [(0, 4, f, bs, 0.0)])
    both, portions, info = share_remote(
        net, [(0, 4, f, bs, 0.0), (0, 4, 0.05, bs, 0.0, ("comp",))])
    assert both[0] == alone[0], "compute peer perturbed the memory-bound group"
    assert both[1] == 0.05 * bs, "compute group must run at f*bs"
    assert all(p[0] == 0 for p in portions), "compute group expanded portions"
    assert info["iterations"] == 1
    print("ok: compute-bound groups cap at f*bs and consume zero "
          "bandwidth share (peers bitwise unchanged)")


def check_pure_l3():
    """A fully L3-resident group (no DRAM traffic at all) water-fills the
    shared-L3 node exactly like a memory group fills a controller."""
    m = dict(MACHINES["rome"], l3_bw=120.0)
    net = net_of(m, 1, 1)
    f3, bs3 = 0.625, m["l2l3"] * m["freq"]
    pc, portions, info = share_remote(net, [(0, 8, 0.0, 0.0, 0.0, ("l3", f3, bs3))])
    want = min(f3 * bs3, 120.0 / 8.0)  # demand 8*47 GB/s >> 120 -> fair split
    assert abs(pc[0] - want) < 1e-12, f"pure-L3 rate {pc[0]!r} != {want!r}"
    assert len(portions) == 1 and portions[0][4] == 0 and not portions[0][5]
    assert info["iterations"] == 1
    assert info["l3_grant"][0] == 120.0
    print(f"ok: pure-L3 group water-fills the L3 node ({pc[0]:.3f} GB/s/core)")


def l3_mixed_example(verbose=True):
    """THE LC-at-L3 conformance case: a jacobi-like stencil whose layer
    condition holds at L3 (5 L2-miss lines per update, 3 continuing to
    DRAM) shares one Rome domain with streaming dcopy, under a 120 GB/s
    shared-L3 node. The stencil contends on BOTH the L3 node (all its
    L2-miss lines) and the memory controller (its DRAM continuation, in
    tandem); dcopy contends on the memory controller only. Both
    interfaces saturate, the fixed point engages, and the fluid
    simulation stays within the paper's 8% ceiling of the model."""
    m = dict(MACHINES["rome"], l3_bw=120.0)
    net = net_of(m, 1, 1)
    d_l3, c, f, bs, f3, bs3, frac = ecm_workload_stencil(m)
    dd, dc_, fd, bsd = ecm_workload(m, "dcopy")
    streams = [(d_l3, c, 0, 0.0, frac)] * 4 + [(dd, dc_, 0, 0.0)] * 4
    pp, portions, util = fluid_net(net, streams)
    sim_pc = lockstep_per_stream(net, streams, pp, portions)
    groups = [(0, 4, f, bs, 0.0, ("l3", f3, bs3)), (0, 4, fd, bsd, 0.0)]
    model_pc, mportions, info = share_remote(net, groups)
    des_pp, des_portions = des_net(net, streams, warmup=20000.0, measure=100000.0)
    des_pc = lockstep_per_stream(net, streams, des_pp, des_portions)
    errs = [abs(sim_pc[0] - model_pc[0]) / model_pc[0],
            abs(sim_pc[4] - model_pc[1]) / model_pc[1]]
    des_errs = [abs(des_pc[0] - model_pc[0]) / model_pc[0],
                abs(des_pc[4] - model_pc[1]) / model_pc[1]]
    if verbose:
        print("\nLC-at-L3 mixed example: stencil:4@l3 + dcopy:4 on one Rome "
              "domain, 120 GB/s shared L3")
        print(f"  stencil chars: f = {f:.4f}, b_s = {bs:.2f} | "
              f"f_l3 = {f3:.4f}, b_l3 = {bs3:.2f} GB/s, l3_frac = {frac:.2f}")
        print(f"  stencil (L3-level): model {model_pc[0]:.3f}, "
              f"fluid {sim_pc[0]:.3f}, DES {des_pc[0]:.3f} GB/s/core "
              f"(fluid err {errs[0] * 100:.2f}%)")
        print(f"  dcopy  (DRAM):      model {model_pc[1]:.3f}, "
              f"fluid {sim_pc[4]:.3f}, DES {des_pc[4]:.3f} GB/s/core "
              f"(fluid err {errs[1] * 100:.2f}%)")
        print(f"  fixed point: {info['iterations']} iterations; "
              f"util mem {util[0]:.3f}, l3 {util[1]:.3f}")
    assert max(errs) < 0.08, f"LC-at-L3 fluid vs model error {max(errs)}"
    assert max(des_errs) < 0.12, f"LC-at-L3 DES vs model error {max(des_errs)}"
    print("ok: LC-at-L3 mixed scenario fluid within 8% of the fixed point "
          f"(worst {max(errs) * 100:.2f}%; DES worst {max(des_errs) * 100:.2f}%)")
    return sim_pc, model_pc, info


if __name__ == "__main__":
    check_fluid_degenerate()
    check_fluid_r0_multidomain()
    check_des_degenerate_and_r0()
    check_stranded_capacity()
    check_fixed_point_degenerates()
    check_duplex_one_direction()
    worked_example()
    gated_example()
    mixed_example()
    check_l3_degenerate()
    check_compute_zero_share()
    check_pure_l3()
    l3_mixed_example()
    print("\nall mirror checks passed")
