"""Pure-jnp (and pure-Python) oracles for the Pallas contention kernel.

``ref_chunk`` is the correctness reference pytest compares the Pallas kernel
against; ``ref_chunk_py`` is an even more naive per-config Python loop used
to validate the vectorization itself.
"""

import jax
import jax.numpy as jnp
import numpy as np


def ref_chunk(d, c, win, cap, occ, served, *, cycles: int):
    """Reference implementation with lax.scan — no Pallas, same math."""

    def body(state, _):
        occ, served = state
        occ = occ + jnp.minimum(d, jnp.maximum(win - occ, 0.0))
        occ_cost = jnp.sum(occ * c, axis=1, keepdims=True)
        lam = jnp.minimum(cap / jnp.maximum(occ_cost, 1e-12), 1.0)
        s = lam * occ
        return (occ - s, served + s), None

    (occ, served), _ = jax.lax.scan(body, (occ, served), None, length=cycles)
    return occ, served


def ref_chunk_py(d, c, win, cap, occ, served, *, cycles: int):
    """Naive per-config NumPy loop (float32 throughout, like the kernel)."""
    d = np.asarray(d, np.float32).copy()
    c = np.asarray(c, np.float32)
    win = np.asarray(win, np.float32)
    cap = np.asarray(cap, np.float32)
    occ = np.asarray(occ, np.float32).copy()
    served = np.asarray(served, np.float32).copy()
    b, n = d.shape
    for _ in range(cycles):
        for k in range(b):
            for i in range(n):
                if d[k, i] > 0.0:
                    occ[k, i] += min(d[k, i], max(win[k, i] - occ[k, i], np.float32(0.0)))
            occ_cost = np.float32((occ[k] * c[k]).sum())
            lam = min(cap[k, 0] / max(occ_cost, np.float32(1e-12)), np.float32(1.0))
            s = (lam * occ[k]).astype(np.float32)
            occ[k] -= s
            served[k] += s
    return occ, served
