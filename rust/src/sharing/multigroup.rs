//! Multigroup generalization of the sharing model.
//!
//! The paper derives Eqs. (4)/(5) for two groups but nothing in the
//! derivation is specific to two; the desynchronization co-simulator needs
//! the k-group form (at any instant, ranks are spread over several kernels
//! plus idle phases). Idle/communicating cores are simply *absent* from the
//! group list — that is scenario (c) of Fig. 2.

use crate::sharing::model::KernelGroup;

/// Per-group result of the multigroup model.
#[derive(Debug, Clone, Copy)]
pub struct GroupShareEntry {
    /// Bandwidth share of the group (generalized Eq. 5; sums to 1 over
    /// groups in the saturated regime).
    pub alpha: f64,
    /// Aggregate bandwidth of the group, GB/s.
    pub group_bw_gbs: f64,
    /// Per-core bandwidth within the group, GB/s.
    pub per_core_gbs: f64,
}

/// Result of the multigroup model.
#[derive(Debug, Clone)]
pub struct GroupShare {
    /// Overlapped saturated bandwidth (generalized Eq. 4), GB/s.
    pub b_mix_gbs: f64,
    /// Per-group outcome, in input order.
    pub groups: Vec<GroupShareEntry>,
    /// Whether the domain is saturated (raw proportional regime).
    pub saturated: bool,
}

/// A kernel group with a *fractional* thread weight.
///
/// The remote-access extension splits one group's cache-line stream over
/// several contention interfaces; the portion landing on an interface acts
/// like `n·w` threads of the group (with `w` the traffic weight), which is
/// in general not an integer. Nothing in the Eqs. (4)+(5) derivation needs
/// integer thread counts, so the water-fill below is written against this
/// type; [`share_multigroup`] is the exact integer wrapper.
#[derive(Debug, Clone, Copy)]
pub struct WeightedGroup {
    /// Effective thread count (`n · weight`; may be fractional).
    pub n: f64,
    /// Memory request fraction of the kernel (Eq. 2).
    pub f: f64,
    /// Saturated bandwidth of the kernel on this interface, GB/s.
    pub bs_gbs: f64,
}

/// Generalized Eqs. (4)+(5) with demand capping for the nonsaturated case.
///
/// Water-filling: a group can never obtain more than its unconstrained
/// demand `n·f·b_s` (that would mean running faster than solo execution).
/// Uncapped groups split the remaining bandwidth proportionally to
/// `n_k · f_k`. The iteration converges in ≤ k rounds.
pub fn share_multigroup(groups: &[KernelGroup]) -> GroupShare {
    let weighted: Vec<WeightedGroup> = groups
        .iter()
        .map(|g| WeightedGroup { n: g.n as f64, f: g.f, bs_gbs: g.bs_gbs })
        .collect();
    share_weighted(&weighted)
}

/// [`share_multigroup`] over fractional thread weights: the interface
/// capacity is the generalized Eq. (4) thread-weighted mean of the groups'
/// saturated bandwidths. Bit-identical to [`share_multigroup`] when every
/// `n` is integral (pinned by the conformance suite).
pub fn share_weighted(groups: &[WeightedGroup]) -> GroupShare {
    let n_tot: f64 = groups.iter().map(|g| g.n).sum();
    if n_tot == 0.0 {
        return GroupShare { b_mix_gbs: 0.0, groups: vec![], saturated: false };
    }
    // Generalized Eq. (4): thread-weighted mean saturated bandwidth.
    let b_mix: f64 = groups.iter().map(|g| g.n * g.bs_gbs).sum::<f64>() / n_tot;
    share_weighted_capacity(groups, b_mix)
}

/// [`share_weighted`] with an explicit interface capacity instead of the
/// Eq. (4) mean — the form the inter-socket link interfaces need: a link
/// saturates at its own `link_bw`, regardless of which kernels' lines it
/// carries, while each portion's *demand* is still `n·f·b_s` of the memory
/// interface it targets.
pub fn share_weighted_capacity(groups: &[WeightedGroup], capacity_gbs: f64) -> GroupShare {
    share_weighted_capped(groups, capacity_gbs, &vec![f64::INFINITY; groups.len()])
}

/// [`share_weighted_capacity`] with per-group per-core rate caps: the
/// demand of group `i` is `min(n·f·b_s, n·rate_caps[i])`. The remote
/// fixed point uses the caps to re-offer only what a gated group's
/// slowest portion can actually drain, so the water-fill redistributes
/// the rest. With every cap infinite this is bit-identical to the
/// uncapped fill (`min(x, ∞) = x`), which is what makes the no-gating
/// fast path of [`crate::sharing::share_remote`] exact.
pub fn share_weighted_capped(
    groups: &[WeightedGroup],
    capacity_gbs: f64,
    rate_caps: &[f64],
) -> GroupShare {
    debug_assert_eq!(groups.len(), rate_caps.len());
    let b_mix = capacity_gbs;
    let demand: Vec<f64> = groups
        .iter()
        .zip(rate_caps)
        .map(|(g, &cap)| (g.n * g.f * g.bs_gbs).min(g.n * cap))
        .collect();
    let weight: Vec<f64> = groups.iter().map(|g| g.n * g.f).collect();
    let total_demand: f64 = demand.iter().sum();
    let saturated = total_demand >= b_mix;

    // Water-fill: start with everyone uncapped; repeatedly cap groups whose
    // proportional allocation would exceed their demand.
    let k = groups.len();
    let mut bw = vec![0.0f64; k];
    let mut capped = vec![false; k];
    let mut remaining = b_mix.min(total_demand);
    for _round in 0..k {
        let wsum: f64 = (0..k).filter(|&i| !capped[i]).map(|i| weight[i]).sum();
        if wsum <= 0.0 || remaining <= 0.0 {
            break;
        }
        let mut newly_capped = false;
        for i in 0..k {
            if capped[i] {
                continue;
            }
            let alloc = remaining * weight[i] / wsum;
            if alloc >= demand[i] - 1e-12 {
                bw[i] = demand[i];
                capped[i] = true;
                newly_capped = true;
            }
        }
        if newly_capped {
            remaining = (b_mix.min(total_demand)
                - (0..k).filter(|&i| capped[i]).map(|i| bw[i]).sum::<f64>())
            .max(0.0);
        } else {
            // No caps hit: final proportional split of the remainder.
            for i in 0..k {
                if !capped[i] {
                    bw[i] = remaining * weight[i] / wsum;
                }
            }
            break;
        }
    }

    let total_alloc: f64 = bw.iter().sum();
    let entries: Vec<GroupShareEntry> = (0..k)
        .map(|i| GroupShareEntry {
            alpha: if total_alloc > 0.0 { bw[i] / total_alloc } else { 0.0 },
            group_bw_gbs: bw[i],
            per_core_gbs: if groups[i].n > 0.0 { bw[i] / groups[i].n } else { 0.0 },
        })
        .collect();

    GroupShare { b_mix_gbs: b_mix, groups: entries, saturated }
}

/// Evaluate the sharing model independently on every ccNUMA domain.
///
/// `domains[d]` lists the groups resident on domain `d`; the result is one
/// [`GroupShare`] per domain, in order. Domains share no state — Eqs. (4)
/// and (5) see only the groups on the same memory interface, which is the
/// physical content of "ccNUMA domain" and what makes scatter vs. compact
/// placement matter. A property suite pins the independence (perturbing one
/// domain's mix leaves every other domain's shares bit-identical).
pub fn share_domains(domains: &[Vec<KernelGroup>]) -> Vec<GroupShare> {
    domains.iter().map(|groups| share_multigroup(groups)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(n: usize, f: f64, bs: f64) -> KernelGroup {
        KernelGroup { n, f, bs_gbs: bs }
    }

    #[test]
    fn per_domain_evaluation_is_independent() {
        let d0 = vec![g(4, 0.84, 32.0), g(4, 0.75, 33.0)];
        let d1 = vec![g(4, 0.30, 35.0), g(4, 0.55, 34.0)];
        let both = share_domains(&[d0.clone(), d1.clone()]);
        // Each domain equals its standalone evaluation, bit for bit.
        for (joint, solo) in both.iter().zip([share_multigroup(&d0), share_multigroup(&d1)]) {
            assert_eq!(joint.b_mix_gbs.to_bits(), solo.b_mix_gbs.to_bits());
            for (a, b) in joint.groups.iter().zip(&solo.groups) {
                assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
            }
        }
        // Perturbing domain 0 leaves domain 1 untouched.
        let perturbed = share_domains(&[vec![g(8, 0.9, 30.0)], d1]);
        for (a, b) in perturbed[1].groups.iter().zip(&both[1].groups) {
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
            assert_eq!(a.per_core_gbs.to_bits(), b.per_core_gbs.to_bits());
        }
    }

    #[test]
    fn reduces_to_two_group_model_when_saturated() {
        let a = g(6, 0.35, 55.0);
        let b = g(4, 0.20, 66.0);
        let multi = share_multigroup(&[a, b]);
        // Raw Eq. 5 values.
        let alpha1 = 6.0 * 0.35 / (6.0 * 0.35 + 4.0 * 0.20);
        assert!(multi.saturated);
        assert!((multi.groups[0].alpha - alpha1).abs() < 1e-9);
        let b_mix = (6.0 * 55.0 + 4.0 * 66.0) / 10.0;
        assert!((multi.b_mix_gbs - b_mix).abs() < 1e-12);
    }

    #[test]
    fn three_groups_conserve_bandwidth() {
        let gs = [g(4, 0.3, 55.0), g(3, 0.25, 60.0), g(3, 0.8, 35.0)];
        let multi = share_multigroup(&gs);
        let total: f64 = multi.groups.iter().map(|e| e.group_bw_gbs).sum();
        assert!(total <= multi.b_mix_gbs + 1e-9);
        let alpha_sum: f64 = multi.groups.iter().map(|e| e.alpha).sum();
        assert!((alpha_sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn low_demand_group_is_capped_at_solo_speed() {
        // A single near-idle thread (tiny f) next to a saturating group must
        // not be awarded more than its own demand.
        let gs = [g(1, 0.02, 60.0), g(9, 0.4, 55.0)];
        let multi = share_multigroup(&gs);
        let solo = 0.02 * 60.0;
        assert!(multi.groups[0].per_core_gbs <= solo + 1e-9);
    }

    #[test]
    fn empty_and_zero_thread_groups() {
        assert!(share_multigroup(&[]).groups.is_empty());
        let multi = share_multigroup(&[g(0, 0.3, 60.0), g(2, 0.3, 60.0)]);
        assert_eq!(multi.groups.len(), 2);
        assert_eq!(multi.groups[0].group_bw_gbs, 0.0);
    }

    #[test]
    fn single_group_reproduces_homogeneous_saturation() {
        // Full domain, one kernel: aggregate = min(n f b_s, b_s).
        let multi = share_multigroup(&[g(10, 0.3, 60.0)]);
        assert!((multi.groups[0].group_bw_gbs - 60.0).abs() < 1e-9);
        let multi2 = share_multigroup(&[g(2, 0.3, 60.0)]);
        assert!((multi2.groups[0].group_bw_gbs - 2.0 * 0.3 * 60.0).abs() < 1e-9);
    }
}
