//! The line protocol of `repro serve`: one JSON object per line.
//!
//! The repo deliberately carries zero dependencies, so this is a small
//! hand-rolled recursive-descent JSON parser. It is a *hardened text
//! surface*: arbitrary bytes must come back as a structured
//! [`Error::InvalidPlan`] with a byte position — never a panic and never
//! unbounded recursion (nesting is capped at [`MAX_DEPTH`]). The fuzz
//! suite (`tests/fuzz_surfaces.rs`) throws byte soups at
//! [`Request::parse`] to hold it to that.
//!
//! Request grammar (one object per line; unknown keys are ignored):
//!
//! ```text
//! {"op":"submit","id":"<job>","mix":"<mix DSL>"}   admit a job
//! {"op":"finish","id":"<job>"}                     retire a job
//! {"op":"query","id":"<job>"}                      placement + rates
//! {"op":"snapshot"}                                fleet state + counters
//! ```

use crate::error::{Error, Result};

/// Maximum nesting depth the parser accepts (arrays/objects). Requests
/// are flat in practice; the cap turns a `[[[[…` bomb into an error
/// instead of a stack overflow.
const MAX_DEPTH: usize = 64;

/// A parsed JSON value. Object keys keep their input order (`Vec`, not a
/// map) so round-trips and error positions stay deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always parsed as `f64`).
    Num(f64),
    /// A string, with escapes resolved.
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object, in key order.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// The value of `key` if this is an object containing it.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// The string content if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse one JSON document (surrounding whitespace allowed, trailing
/// garbage rejected).
pub fn parse_json(s: &str) -> Result<JsonValue> {
    let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("end of input"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, expected: &str) -> Error {
        let found = match self.bytes.get(self.pos) {
            Some(&b) if b.is_ascii_graphic() => format!("'{}'", b as char),
            Some(&b) => format!("byte 0x{b:02x}"),
            None => "end of input".to_string(),
        };
        Error::InvalidPlan(format!(
            "request parse error at byte {}: expected {expected}, found {found}",
            self.pos
        ))
    }

    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> bool {
        if self.bytes.get(self.pos) == Some(&b) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn value(&mut self, depth: usize) -> Result<JsonValue> {
        if depth > MAX_DEPTH {
            return Err(Error::InvalidPlan(format!(
                "request parse error at byte {}: nesting deeper than {MAX_DEPTH}",
                self.pos
            )));
        }
        match self.bytes.get(self.pos) {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal(b"true", JsonValue::Bool(true)),
            Some(b'f') => self.literal(b"false", JsonValue::Bool(false)),
            Some(b'n') => self.literal(b"null", JsonValue::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("a JSON value")),
        }
    }

    fn literal(&mut self, word: &[u8], v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(word) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(std::str::from_utf8(word).expect("ascii literal")))
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        if self.eat(b'-') {}
        while matches!(self.bytes.get(self.pos), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.eat(b'.') {
            while matches!(self.bytes.get(self.pos), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.bytes.get(self.pos), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.bytes.get(self.pos), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.bytes.get(self.pos), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number");
        match text.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(JsonValue::Num(n)),
            _ => {
                self.pos = start;
                Err(self.err("a finite JSON number"))
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        if !self.eat(b'"') {
            return Err(self.err("'\"'"));
        }
        let mut out = String::new();
        loop {
            match self.bytes.get(self.pos) {
                None => return Err(self.err("closing '\"'")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.bytes.get(self.pos) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1; // past the 'u'
                            let cp = self.hex4()?;
                            // Surrogate pairs: a high surrogate must be
                            // followed by an escaped low surrogate.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if !(self.eat(b'\\') && self.eat(b'u')) {
                                    return Err(self.err("a low-surrogate \\u escape"));
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("a low surrogate"));
                                }
                                let v = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(v)
                            } else {
                                char::from_u32(cp)
                            };
                            match c {
                                Some(c) => out.push(c),
                                None => return Err(self.err("a valid unicode escape")),
                            }
                            // hex4 advanced past the digits already.
                            continue;
                        }
                        _ => return Err(self.err("a string escape")),
                    }
                    self.pos += 1;
                }
                Some(&b) if b < 0x20 => return Err(self.err("no raw control bytes")),
                Some(_) => {
                    // Copy one UTF-8 scalar (input is &str, so boundaries
                    // are valid).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("valid UTF-8"))?;
                    let c = rest.chars().next().expect("non-empty checked above");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    /// Four hex digits starting at the current position.
    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.bytes.get(self.pos) {
                Some(&b @ b'0'..=b'9') => (b - b'0') as u32,
                Some(&b @ b'a'..=b'f') => (b - b'a' + 10) as u32,
                Some(&b @ b'A'..=b'F') => (b - b'A' + 10) as u32,
                _ => return Err(self.err("4 hex digits")),
            };
            v = (v << 4) | d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<JsonValue> {
        self.pos += 1; // past '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.eat(b']') {
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            if self.eat(b']') {
                return Ok(JsonValue::Arr(items));
            }
            if !self.eat(b',') {
                return Err(self.err("',' or ']'"));
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<JsonValue> {
        self.pos += 1; // past '{'
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.eat(b'}') {
            return Ok(JsonValue::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            if !self.eat(b':') {
                return Err(self.err("':'"));
            }
            self.skip_ws();
            let val = self.value(depth + 1)?;
            pairs.push((key, val));
            self.skip_ws();
            if self.eat(b'}') {
                return Ok(JsonValue::Obj(pairs));
            }
            if !self.eat(b',') {
                return Err(self.err("',' or '}'"));
            }
        }
    }
}

/// Escape a string for embedding in emitted JSON.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One request of the serve protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Admit job `id` running `mix` (mix DSL, see `Mix::parse`).
    Submit {
        /// Job identifier (any non-empty string, unique among live jobs).
        id: String,
        /// The mix DSL spec.
        mix: String,
    },
    /// Retire job `id`, freeing its cores.
    Finish {
        /// Job identifier.
        id: String,
    },
    /// Report job `id`'s placement and current model rates.
    Query {
        /// Job identifier.
        id: String,
    },
    /// Report the whole fleet, final makespan probe, and counters.
    Snapshot,
}

impl Request {
    /// Parse one request line. Never panics on malformed input.
    pub fn parse(line: &str) -> Result<Request> {
        let v = parse_json(line)?;
        let op = v
            .get("op")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| Error::InvalidPlan("request needs a string \"op\" key".into()))?;
        let id_of = |v: &JsonValue| -> Result<String> {
            let id = v
                .get("id")
                .and_then(JsonValue::as_str)
                .ok_or_else(|| {
                    Error::InvalidPlan(format!("op \"{op}\" needs a string \"id\" key"))
                })?;
            if id.is_empty() {
                return Err(Error::InvalidPlan("job id must be non-empty".into()));
            }
            Ok(id.to_string())
        };
        match op {
            "submit" => {
                let mix = v
                    .get("mix")
                    .and_then(JsonValue::as_str)
                    .ok_or_else(|| {
                        Error::InvalidPlan("op \"submit\" needs a string \"mix\" key".into())
                    })?
                    .to_string();
                Ok(Request::Submit { id: id_of(&v)?, mix })
            }
            "finish" => Ok(Request::Finish { id: id_of(&v)? }),
            "query" => Ok(Request::Query { id: id_of(&v)? }),
            "snapshot" => Ok(Request::Snapshot),
            other => Err(Error::InvalidPlan(format!(
                "unknown op '{other}' (submit, finish, query, snapshot)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_each_request_form() {
        assert_eq!(
            Request::parse(r#"{"op":"submit","id":"j0","mix":"dcopy:6"}"#).unwrap(),
            Request::Submit { id: "j0".into(), mix: "dcopy:6".into() }
        );
        assert_eq!(
            Request::parse(r#"{"op":"finish","id":"j0"}"#).unwrap(),
            Request::Finish { id: "j0".into() }
        );
        assert_eq!(
            Request::parse(r#"{"op":"query","id":"j0"}"#).unwrap(),
            Request::Query { id: "j0".into() }
        );
        assert_eq!(Request::parse(r#"{"op":"snapshot"}"#).unwrap(), Request::Snapshot);
        // Unknown keys are ignored; key order is free.
        assert_eq!(
            Request::parse(r#"{"mix":"ddot2:4","note":1,"id":"a","op":"submit"}"#).unwrap(),
            Request::Submit { id: "a".into(), mix: "ddot2:4".into() }
        );
    }

    #[test]
    fn structured_errors_on_malformed_input() {
        for bad in [
            "",
            "{",
            "notjson",
            r#"{"op":"submit"}"#,
            r#"{"op":"launch","id":"x"}"#,
            r#"{"op":"submit","id":"","mix":"dcopy:4"}"#,
            r#"{"op":"submit","id":3,"mix":"dcopy:4"}"#,
            r#"{"op":"snapshot"} trailing"#,
            "{\"op\":\"snapshot\"\u{0}}",
        ] {
            let e = Request::parse(bad).unwrap_err();
            assert!(matches!(e, Error::InvalidPlan(_)), "{bad:?} -> {e:?}");
        }
    }

    #[test]
    fn deep_nesting_is_rejected_not_overflowed() {
        let mut s = String::from(r#"{"op":"#);
        s.push_str(&"[".repeat(10_000));
        let e = Request::parse(&s).unwrap_err();
        assert!(format!("{e}").contains("nesting"), "{e}");
    }

    #[test]
    fn strings_resolve_escapes_and_surrogates() {
        let v = parse_json(r#""a\"b\\c\nA😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\nA\u{1F600}");
        // Escaped BMP scalar plus an escaped surrogate pair.
        let v = parse_json("\"\\u0041\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "A\u{1F600}");
        // A lone high surrogate is an error, not a panic.
        assert!(parse_json(r#""\ud83d x""#).is_err());
    }

    #[test]
    fn json_escape_round_trips_through_parse() {
        let original = "mix \"x\"\\\n\tudone\u{1}";
        let quoted = format!("\"{}\"", json_escape(original));
        let v = parse_json(&quoted).unwrap();
        assert_eq!(v.as_str().unwrap(), original);
    }
}
