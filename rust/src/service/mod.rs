//! The `repro serve` layer: a streaming co-scheduling service.
//!
//! `repro optimize` answers one placement question and exits; real
//! schedulers face a *stream* — jobs arrive, run, and retire while the
//! fleet's placement must stay good. This module turns the optimizer
//! into that long-running service:
//!
//! * [`request`] — the line-delimited JSON protocol: a dependency-free
//!   recursive-descent [`request::parse_json`] (the crate links no JSON
//!   crate by design) and the [`Request`] grammar
//!   (`submit` / `finish` / `query` / `snapshot`).
//! * [`fleet`] — the [`Service`] engine: incremental-but-exact admission
//!   over a pinned residual space, periodic full repacks as a drift
//!   bound, one process-wide score memo + characterization cache shared
//!   across all requests, and a checkpoint-resumed makespan probe over
//!   [`crate::timeline::simulate_placed_until`].
//!
//! The protocol is replayable: a fixed-seed session maps a request file
//! to byte-identical response lines (modulo process-global cache
//! counters in `snapshot`), which is what the CI smoke test and
//! `tests/service_conformance.rs` pin. `BENCH_serve.json` measures the
//! amortized admission throughput against per-request cold `optimize`
//! runs. See `docs/CLI.md` for the request grammar and a worked session.

pub mod fleet;
pub mod request;

pub use fleet::{service_memo, ServeConfig, Service};
pub use request::{json_escape, parse_json, JsonValue, Request};
