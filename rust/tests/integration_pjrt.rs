//! Integration: the PJRT runtime path — load the AOT JAX/Pallas artifact,
//! execute it, and cross-validate against the in-process engines.
//!
//! These tests require `make artifacts` to have run; they skip (with a
//! notice) when the bundle is absent so `cargo test` works from a clean
//! checkout.

use membw::config::{machine, MachineId};
use membw::kernels::{kernel, KernelId};
use membw::runtime::{ArtifactPaths, PjrtRuntime, PjrtSimExecutor, SimCase};
use membw::simulator::{run_engine, CoreWorkload, Engine};
use membw::sweep::{run_cases, symmetric_splits, MeasureEngine};

fn load() -> Option<(PjrtRuntime, PjrtSimExecutor)> {
    let dir = ArtifactPaths::default_dir();
    if ArtifactPaths::locate(&dir).is_err() {
        eprintln!("NOTE: artifacts missing, PJRT integration tests skipped");
        return None;
    }
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let exec = PjrtSimExecutor::load(&rt, &dir).expect("compile artifact");
    Some((rt, exec))
}

#[test]
fn artifact_meta_covers_all_machines() {
    let Some((_rt, exec)) = load() else { return };
    let meta = exec.meta();
    for mid in MachineId::ALL {
        assert!(machine(mid).cores <= meta.n_cores, "{mid:?} exceeds artifact width");
    }
}

#[test]
fn pjrt_matches_fluid_engine_on_mixed_batch() {
    let Some((_rt, exec)) = load() else { return };
    // One case per machine, mixed kernels, single batch.
    let cases: Vec<SimCase> = MachineId::ALL
        .iter()
        .map(|&mid| {
            let m = machine(mid);
            let mut ws = vec![CoreWorkload::from_kernel(&kernel(KernelId::Dcopy), &m, 0); m.cores / 2];
            ws.extend(vec![
                CoreWorkload::from_kernel(&kernel(KernelId::Ddot2), &m, 1);
                m.cores - m.cores / 2
            ]);
            SimCase { machine: m, workloads: ws }
        })
        .collect();
    let out = exec.run(&cases).expect("pjrt run");
    for (case, pjrt_bw) in cases.iter().zip(&out) {
        let fluid_bw = run_engine(&case.machine, &case.workloads, Engine::Fluid);
        assert_eq!(pjrt_bw.len(), fluid_bw.len());
        for (i, (a, b)) in pjrt_bw.iter().zip(&fluid_bw).enumerate() {
            let rel = (a - b).abs() / b.max(1e-9);
            assert!(
                rel < 0.02,
                "{} core {i}: pjrt {a} vs fluid {b}",
                case.machine.name
            );
        }
    }
}

#[test]
fn pjrt_sweep_reproduces_fig8_subset() {
    let Some((_rt, exec)) = load() else { return };
    let m = machine(MachineId::Bdw1);
    let cases = symmetric_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
    let rs = run_cases(&m, &cases, &MeasureEngine::Pjrt(&exec)).unwrap();
    let errs = rs.all_errors();
    let max = errs.iter().cloned().fold(0.0, f64::max);
    assert!(max < 0.08, "max model error via pjrt: {max}");
}

#[test]
fn pjrt_batch_padding_is_transparent() {
    let Some((_rt, exec)) = load() else { return };
    let m = machine(MachineId::Rome);
    let w = CoreWorkload::from_kernel(&kernel(KernelId::Daxpy), &m, 0);
    let case = SimCase { machine: m.clone(), workloads: vec![w; 4] };
    // 1 case vs the same case replicated past one batch boundary.
    let solo = exec.run(std::slice::from_ref(&case)).unwrap();
    let many = exec.run(&vec![case; exec.meta().batch + 3]).unwrap();
    for bw in &many {
        for (a, b) in bw.iter().zip(&solo[0]) {
            assert!((a - b).abs() < 1e-6, "padding changed results");
        }
    }
}
