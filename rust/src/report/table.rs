//! Minimal ASCII table builder for terminal reports.

/// Builds left-padded ASCII tables.
#[derive(Debug, Clone, Default)]
pub struct AsciiTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl AsciiTable {
    /// Start a table with a header row.
    pub fn new(header: &[&str]) -> Self {
        AsciiTable { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let sep = width.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  ");
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = AsciiTable::new(&["kernel", "f"]);
        t.row(vec!["DDOT2".into(), "0.25".into()]);
        t.row(vec!["vecSUM".into(), "0.241".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        AsciiTable::new(&["a", "b"]).row(vec!["x".into()]);
    }
}
