//! Descriptive statistics.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Median (of a sorted copy); 0 for an empty slice. Even sizes average the
/// two central elements, matching the convention of `ErrorStats` and the
/// box-plot quantiles.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let mid = sorted.len() / 2;
    if sorted.len() % 2 == 0 {
        (sorted[mid - 1] + sorted[mid]) / 2.0
    } else {
        sorted[mid]
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Standard (dimensionless) skewness: third standardized moment.
pub fn skewness_standard(xs: &[f64]) -> f64 {
    let s = std_dev(xs);
    if s == 0.0 || xs.len() < 3 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| ((x - m) / s).powi(3)).sum::<f64>() / xs.len() as f64
}

/// Dimensioned skewness — signed cube root of the third central moment.
///
/// The paper quotes skewness values in *milliseconds* (−0.27 ms, +0.42 ms,
/// +1.0 ms), i.e. a quantity carrying the unit of the underlying variable.
/// `cbrt(m3)` has exactly that property and the same sign as the standard
/// skewness.
pub fn skewness_dimensioned(xs: &[f64]) -> f64 {
    if xs.len() < 3 {
        return 0.0;
    }
    let m = mean(xs);
    let m3 = xs.iter().map(|x| (x - m).powi(3)).sum::<f64>() / xs.len() as f64;
    m3.signum() * m3.abs().cbrt()
}

/// Compact summary of a sample.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Dimensioned skewness (unit of the variable).
    pub skew: f64,
}

impl Summary {
    /// Summarize a sample.
    pub fn of(xs: &[f64]) -> Self {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_dev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            skew: skewness_dimensioned(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_of_odd_even_empty() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn skewness_signs() {
        // Right-tailed sample: positive skew (desynchronization signature).
        let right = [1.0, 1.0, 1.0, 1.0, 1.0, 10.0];
        assert!(skewness_standard(&right) > 0.0);
        assert!(skewness_dimensioned(&right) > 0.0);
        // Left-tailed: negative skew (resynchronization signature).
        let left = [10.0, 10.0, 10.0, 10.0, 10.0, 1.0];
        assert!(skewness_standard(&left) < 0.0);
        assert!(skewness_dimensioned(&left) < 0.0);
        // Symmetric: ~zero.
        let sym = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!(skewness_dimensioned(&sym).abs() < 1e-9);
    }

    #[test]
    fn dimensioned_skew_scales_linearly() {
        // cbrt(m3) carries the variable's unit: scaling the sample by c
        // scales the skewness by c (unlike the standardized moment).
        let xs = [1.0, 1.0, 1.0, 5.0];
        let scaled: Vec<f64> = xs.iter().map(|x| x * 3.0).collect();
        let a = skewness_dimensioned(&xs);
        let b = skewness_dimensioned(&scaled);
        assert!((b / a - 3.0).abs() < 1e-9);
    }

    #[test]
    fn summary_handles_small_samples() {
        let s = Summary::of(&[1.0]);
        assert_eq!(s.n, 1);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.skew, 0.0);
    }
}
