//! Loop-kernel substrate — the paper's Table II as executable data.
//!
//! A kernel is characterized *only* by its data-traffic signature: how many
//! cache lines it moves per unit of work over each level of the memory
//! hierarchy, and how many load/store/arithmetic instructions it retires.
//! The paper's central observation is that nothing else matters for
//! bandwidth sharing.

mod layer_condition;
mod registry;
mod signature;

pub use layer_condition::{analyze_lc, jacobi_traffic, LayerCondition, LcAnalysis};
pub use registry::{all_kernels, kernel, kernel_names, pairing_set, KernelId};
pub use signature::{KernelClass, KernelSignature, StreamCounts};
