//! Measurement protocols on top of the simulation engines — the analogue of
//! the paper's LIKWID measurement procedures (Sect. II).

use crate::config::Machine;
use crate::kernels::KernelSignature;
use crate::simulator::des::{DesConfig, DesSimulator};
use crate::simulator::fluid::{FluidConfig, FluidSimulator};
use crate::simulator::workload::CoreWorkload;

/// Which measurement engine to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Fast fluid-queueing simulator (same physics as the PJRT artifact).
    Fluid,
    /// Line-granularity discrete-event simulator (reference).
    Des,
}

impl Engine {
    /// Parse a CLI key.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fluid" => Ok(Engine::Fluid),
            "des" => Ok(Engine::Des),
            other => Err(crate::Error::InvalidPlan(format!(
                "unknown engine '{other}' (fluid, des)"
            ))),
        }
    }
}

/// Run an arbitrary workload vector and return per-core bandwidths (GB/s).
pub fn run_engine(machine: &Machine, workloads: &[CoreWorkload], engine: Engine) -> Vec<f64> {
    match engine {
        Engine::Fluid => FluidSimulator::new(machine, FluidConfig::default())
            .run(workloads)
            .per_core_gbs,
        Engine::Des => DesSimulator::new(machine, DesConfig::default())
            .run(workloads)
            .per_core_gbs,
    }
}

/// Single-kernel characterization — the paper's Eq. (3) procedure.
#[derive(Debug, Clone, Copy)]
pub struct KernelMeasurement {
    /// Measured single-threaded memory bandwidth `b_meas`, GB/s.
    pub b1_gbs: f64,
    /// Measured saturated (full-domain) bandwidth `b_s`, GB/s.
    pub bs_gbs: f64,
    /// Memory request fraction `f = b_meas / b_s` (Eq. 3).
    pub f: f64,
}

/// Measure `b_1`, `b_s` and `f` of a kernel on a machine (Eq. 3).
pub fn measure_f_bs(kernel: &KernelSignature, machine: &Machine, engine: Engine) -> KernelMeasurement {
    let w = CoreWorkload::from_kernel(kernel, machine, 0);
    let solo = run_engine(machine, &[w], engine);
    let full = run_engine(machine, &vec![w; machine.cores], engine);
    let b1 = solo[0];
    let bs: f64 = full.iter().sum();
    KernelMeasurement { b1_gbs: b1, bs_gbs: bs, f: if bs > 0.0 { b1 / bs } else { 0.0 } }
}

/// Measured outcome of a two-kernel pairing.
#[derive(Debug, Clone)]
pub struct PairingMeasurement {
    /// Threads per group.
    pub n: [usize; 2],
    /// Mean per-core bandwidth per group, GB/s.
    pub per_core_gbs: [f64; 2],
    /// Aggregate bandwidth per group, GB/s.
    pub group_bw_gbs: [f64; 2],
    /// Overall memory bandwidth, GB/s.
    pub total_gbs: f64,
}

/// Measure a two-kernel pairing with `n1`/`n2` threads.
pub fn measure_pairing(
    machine: &Machine,
    k1: &KernelSignature,
    n1: usize,
    k2: &KernelSignature,
    n2: usize,
    engine: Engine,
) -> PairingMeasurement {
    assert!(n1 + n2 <= machine.cores, "pairing exceeds domain size");
    let mut ws = vec![CoreWorkload::from_kernel(k1, machine, 0); n1];
    ws.extend(vec![CoreWorkload::from_kernel(k2, machine, 1); n2]);
    let per_core = run_engine(machine, &ws, engine);
    let g0: f64 = per_core.iter().take(n1).sum();
    let g1: f64 = per_core.iter().skip(n1).sum();
    PairingMeasurement {
        n: [n1, n2],
        per_core_gbs: [
            if n1 > 0 { g0 / n1 as f64 } else { 0.0 },
            if n2 > 0 { g1 / n2 as f64 } else { 0.0 },
        ],
        group_bw_gbs: [g0, g1],
        total_gbs: g0 + g1,
    }
}

/// Symmetric thread scaling of a pairing (the blue dots of Fig. 4): equal
/// thread counts per kernel from 1+1 up to half the domain each.
pub fn measure_scaling(
    machine: &Machine,
    k1: &KernelSignature,
    k2: &KernelSignature,
    engine: Engine,
) -> Vec<PairingMeasurement> {
    (1..=machine.cores / 2)
        .map(|n| measure_pairing(machine, k1, n, k2, n, engine))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::{kernel, KernelId};

    #[test]
    fn eq3_f_close_to_ecm_prediction() {
        // The Eq. 3 measured f must be consistent with the ECM-predicted f
        // (the paper offers both routes; they should agree).
        for mid in [MachineId::Bdw1, MachineId::Rome] {
            let m = machine(mid);
            let k = kernel(KernelId::Stream);
            let meas = measure_f_bs(&k, &m, Engine::Fluid);
            let pred = crate::ecm::predict(&k, &m);
            let err = (meas.f - pred.f).abs() / pred.f;
            assert!(err < 0.08, "{mid:?}: measured f {} vs ECM {}", meas.f, pred.f);
        }
    }

    #[test]
    fn pairing_measurement_partitions_total() {
        let m = machine(MachineId::Bdw2);
        let p = measure_pairing(
            &m,
            &kernel(KernelId::Stream),
            9,
            &kernel(KernelId::JacobiV1L2),
            9,
            Engine::Fluid,
        );
        let sum = p.group_bw_gbs[0] + p.group_bw_gbs[1];
        assert!((sum - p.total_gbs).abs() < 1e-6);
        assert!(p.total_gbs <= m.read_bw_gbs * 1.001);
    }

    #[test]
    fn scaling_has_half_domain_points() {
        let m = machine(MachineId::Rome);
        let pts = measure_scaling(&m, &kernel(KernelId::Ddot2), &kernel(KernelId::Dcopy), Engine::Fluid);
        assert_eq!(pts.len(), m.cores / 2);
        // Per-core bandwidth must not increase with contention.
        for w in pts.windows(2) {
            assert!(w[1].per_core_gbs[0] <= w[0].per_core_gbs[0] * 1.02);
        }
    }
}
