//! Task-scheduler demo — the paper's outlook: "it should also be useful in
//! modeling the performance of task-parallel code".
//!
//! A queue of tasks is gang-scheduled onto a contention domain two groups
//! at a time. Tasks are either **memory-bound** (Table II kernels) or
//! **compute-bound** (a locally defined DGEMM-like kernel whose `T_OL`
//! dominates, giving it a tiny memory request fraction `f` through exactly
//! the same ECM machinery).
//!
//! Policies compared:
//!
//! * **Clustered**: run same-kind tasks back-to-back (naive
//!   "locality-friendly" policy). Pairs of compute-bound tasks leave the
//!   memory interface idle — bandwidth that can never be recovered.
//! * **FIFO**: take the next two tasks in queue order.
//! * **Model-guided**: partner choice minimizing the co-run time
//!   *predicted by the sharing model* (Eqs. 4+5), via the optimizer's
//!   pairing planner ([`membw::optimizer::plan_pairing`]; beam 1 is the
//!   greedy policy this example originally hand-rolled). The model knows
//!   that a low-f compute task and a high-f memory task barely interfere,
//!   so it overlaps them.
//!
//! Makespans are evaluated with the fluid simulator (not the model), so
//! the comparison is fair.
//!
//! ```bash
//! cargo run --release --example task_scheduler
//! ```

use membw::config::{machine, Machine, MachineId};
use membw::kernels::{kernel, KernelClass, KernelId, KernelSignature};
use membw::optimizer::{plan_pairing, PairTask};
use membw::simulator::{measure_f_bs, measure_pairing, Engine, KernelMeasurement};

/// A compute-bound task kernel: one read stream, 128 flops per element —
/// `T_OL` dominates the ECM composition and `f` comes out tiny.
fn dgemm_like() -> KernelSignature {
    KernelSignature::streaming(
        "DGEMM-ish", "c[i] += dot(A_row, B_col)  (cache-blocked)", KernelClass::ReadOnly,
        1, 0, 0, 1, 0, 128,
    )
}

#[derive(Clone, Debug)]
struct Task {
    name: &'static str,
    sig: KernelSignature,
    gbytes: f64,
}

/// Simulated wall time of co-running two tasks on half the domain each,
/// until both finish (the leftover runs homogeneously on the full domain).
fn co_run_time(m: &Machine, a: &Task, b: &Task) -> f64 {
    let half = m.cores / 2;
    let meas = measure_pairing(m, &a.sig, half, &b.sig, m.cores - half, Engine::Fluid);
    let t_a = a.gbytes / meas.group_bw_gbs[0];
    let t_b = b.gbytes / meas.group_bw_gbs[1];
    let (first, leftover, solo) = if t_a < t_b {
        (t_a, (t_b - t_a) * meas.group_bw_gbs[1], &b.sig)
    } else {
        (t_b, (t_a - t_b) * meas.group_bw_gbs[0], &a.sig)
    };
    let c = measure_f_bs(solo, m, Engine::Fluid);
    // Full-domain homogeneous bandwidth = min(n f b_s, b_s).
    let full_bw = (m.cores as f64 * c.f * c.bs_gbs).min(c.bs_gbs);
    first + leftover / full_bw
}

fn pairwise_schedule(m: &Machine, order: &[Task]) -> f64 {
    order
        .chunks(2)
        .map(|pair| match pair {
            [a, b] => co_run_time(m, a, b),
            [a] => {
                let c = measure_f_bs(&a.sig, m, Engine::Fluid);
                a.gbytes / (m.cores as f64 * c.f * c.bs_gbs).min(c.bs_gbs)
            }
            _ => unreachable!(),
        })
        .sum()
}

/// Plan the pairing with the optimizer's model-guided planner (beam 1 =
/// the greedy this example originally hand-rolled), then evaluate the
/// resulting plan with the fluid simulator — same fairness rule as the
/// other two policies.
fn model_guided_schedule(m: &Machine, tasks: &[Task], chars: &[(String, KernelMeasurement)]) -> f64 {
    let lookup = |t: &Task| {
        chars.iter().find(|(n, _)| *n == t.sig.name).expect("characterized").1
    };
    let pair_tasks: Vec<PairTask> = tasks
        .iter()
        .map(|t| {
            let c = lookup(t);
            PairTask { name: t.name.to_string(), f: c.f, bs_gbs: c.bs_gbs, gbytes: t.gbytes }
        })
        .collect();
    let plan = plan_pairing(m.cores, &pair_tasks, 1);
    plan.pairs
        .iter()
        .map(|&(a, b)| match b {
            Some(b) => co_run_time(m, &tasks[a], &tasks[b]),
            None => {
                let c = lookup(&tasks[a]);
                tasks[a].gbytes / (m.cores as f64 * c.f * c.bs_gbs).min(c.bs_gbs)
            }
        })
        .sum()
}

fn main() {
    let m = machine(MachineId::Bdw1);
    // Half memory-bound streaming tasks, half compute-bound tasks.
    let mut tasks = Vec::new();
    for i in 0..4 {
        tasks.push(Task { name: "stream", sig: kernel(KernelId::Stream), gbytes: 60.0 + 5.0 * i as f64 });
        tasks.push(Task { name: "dgemm", sig: dgemm_like(), gbytes: 4.0 });
        tasks.push(Task { name: "ddot2", sig: kernel(KernelId::Ddot2), gbytes: 60.0 });
        tasks.push(Task { name: "dgemm", sig: dgemm_like(), gbytes: 4.0 });
    }
    println!("machine: {} — {} tasks (8 memory-bound, 8 compute-bound)", m.name, tasks.len());

    // Characterize every distinct kernel once (Eq. 3).
    let mut chars: Vec<(String, KernelMeasurement)> = Vec::new();
    for t in &tasks {
        if !chars.iter().any(|(n, _)| *n == t.sig.name) {
            chars.push((t.sig.name.clone(), measure_f_bs(&t.sig, &m, Engine::Fluid)));
        }
    }
    for (n, c) in &chars {
        println!("  {n:10} f = {:.3}, b_s = {:.1} GB/s", c.f, c.bs_gbs);
    }

    let mut clustered = tasks.clone();
    clustered.sort_by(|a, b| a.name.cmp(b.name));
    let t_clustered = pairwise_schedule(&m, &clustered);
    let t_fifo = pairwise_schedule(&m, &tasks);
    let t_model = model_guided_schedule(&m, &tasks, &chars);
    println!("\nclustered (same-kind pairs) : {t_clustered:.2} s");
    println!("FIFO pairing                : {t_fifo:.2} s");
    println!("model-guided pairing        : {t_model:.2} s");
    println!(
        "model-guided speedup        : {:+.1}% vs clustered, {:+.1}% vs FIFO",
        (t_clustered / t_model - 1.0) * 100.0,
        (t_fifo / t_model - 1.0) * 100.0
    );
    assert!(t_model < t_clustered, "overlapping compute with memory must win");
    assert!(t_model <= t_fifo * 1.02, "must be competitive with the lucky FIFO interleave");
}
