//! Report surface of the `repro serve` session: the request/response
//! transcript and the final fleet placement, rendered as the same ASCII
//! tables the rest of the report layer uses.

use std::fmt::Write as _;

use crate::report::table::AsciiTable;
use crate::service::{ServeConfig, Service};
use crate::topology::Topology;

/// Render one serve session: header, the numbered request → response
/// transcript (responses elided to their leading fields past 100 chars —
/// the full lines live in the JSON session log next to this report), and
/// the final fleet table.
pub fn serve_report(
    topo: &Topology,
    cfg: &ServeConfig,
    transcript: &[(String, String)],
    service: &Service,
) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "SERVE on {} — objective {}, seed {}, repack every {}, {} requests",
        topo.label(),
        cfg.objective.name(),
        cfg.seed,
        if cfg.repack_every == 0 {
            "never".to_string()
        } else {
            cfg.repack_every.to_string()
        },
        transcript.len(),
    )
    .unwrap();

    writeln!(out, "\ntranscript:").unwrap();
    let mut tt = AsciiTable::new(&["#", "request", "response"]);
    for (i, (req, resp)) in transcript.iter().enumerate() {
        let short = if resp.chars().count() > 100 {
            let head: String = resp.chars().take(97).collect();
            format!("{head}...")
        } else {
            resp.clone()
        };
        tt.row(vec![i.to_string(), req.clone(), short]);
    }
    out.push_str(&tt.render());

    writeln!(out, "\nfinal fleet ({} live jobs):", service.jobs_len()).unwrap();
    let mut ft = AsciiTable::new(&["job", "kernel", "n", "home", "%r"]);
    for (id, groups) in service.placements() {
        for (kernel, cores, home, remote_ppm) in groups {
            ft.row(vec![
                id.clone(),
                kernel.key().to_string(),
                cores.to_string(),
                format!("d{home}"),
                format!("{:.2}", remote_ppm as f64 / 1e6),
            ]);
        }
    }
    out.push_str(&ft.render());
    if let Some(r) = service.last_result() {
        writeln!(out, "fleet score: {:.3} ({})", r.best_score, r.best_label).unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine_by_name;
    use crate::scenario::CharSource;

    #[test]
    fn renders_header_transcript_and_fleet() {
        let m = machine_by_name("rome").unwrap();
        let topo = Topology::parse(&m, "2x4").unwrap();
        let cfg = ServeConfig::default();
        let mut s = Service::new(topo.clone(), cfg.clone(), CharSource::Ecm);
        let req = r#"{"op":"submit","id":"j0","mix":"dcopy:6"}"#.to_string();
        let resp = s.handle_line(&req);
        let text = serve_report(&topo, &cfg, &[(req, resp)], &s);
        assert!(text.contains("SERVE on"), "{text}");
        assert!(text.contains("transcript"), "{text}");
        assert!(text.contains("dcopy"), "{text}");
        assert!(text.contains("fleet score"), "{text}");
    }
}
