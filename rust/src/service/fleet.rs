//! The long-running co-scheduling engine behind `repro serve`.
//!
//! The service holds a *fleet*: the set of admitted jobs and the placement
//! the optimizer committed for them. Admission is **incremental but
//! exact**:
//!
//! * On `submit`, settled jobs keep their committed placement — their
//!   groups enter the search space *pinned* (home fixed, remote fraction
//!   frozen), so the beam search only explores the new job's groups over
//!   the residual capacity. Pinning is a hard constraint of
//!   [`SearchSpace`] itself, so this is bit-identical to a cold
//!   [`optimize`] run over the same residual space — not an
//!   approximation of it (pinned in `tests/service_conformance.rs`).
//! * Every [`ServeConfig::repack_every`]-th submit is a *repack*: all
//!   groups go in free (only mix-native `@dN` pins and `%r` freezes
//!   survive), bounding the drift a greedy admission sequence can
//!   accumulate. A repack equals the cold `repro optimize` of the
//!   combined mix.
//! * On `finish`, the retired job's cores are freed and the residual
//!   fleet is re-scored through the same pinned-space path (a fully
//!   pinned space has exactly one candidate, so this is a cheap exact
//!   re-rate, not a search).
//!
//! All requests share one process-wide [`ShardedScoreMemo`] (namespaced
//! by [`SearchSpace::fingerprint`]) and the process-wide
//! [`CharCache`], so repeated admissions of similar fleets hit warm
//! caches; the hit rates surface in every `snapshot` response.
//!
//! The *makespan probe* co-simulates the committed placement through the
//! checkpointable timeline engine
//! ([`crate::timeline::simulate_placed_until`]): each `query` advances
//! the simulation by one [`ServeConfig::probe_slice_s`] slice from its
//! [`EngineCheckpoint`] instead of re-simulating from `t = 0`, and
//! `snapshot` drives it to completion. Checkpoint/resume is bit-identical
//! to an uninterrupted run, so the probe's makespan equals the one-shot
//! simulation of the same placement.

use std::collections::HashMap;
use std::sync::OnceLock;

use crate::desync::{CoSimConfig, Program, SimStats};
use crate::error::{Error, Result};
use crate::kernels::KernelId;
use crate::optimizer::{
    optimize_with_memo, Objective, OptGroup, OptResult, SearchConfig, SearchSpace,
    ShardedScoreMemo, DEFAULT_REMOTE_LEVELS,
};
use crate::optimizer::search::makespan_setup;
use crate::scenario::{CharCache, CharSource, Mix};
use crate::sharing::GroupKind;
use crate::timeline::{
    resume_placed, simulate_placed_until, EngineCheckpoint, RatingMode, SimStep,
};
use crate::topology::{RankLayout, Topology};

use super::request::{json_escape, Request};

/// The process-wide score memo every service instance shares (mirrors
/// [`CharCache::global`]). Namespacing by space fingerprint keeps
/// concurrent fleets from aliasing.
pub fn service_memo() -> &'static ShardedScoreMemo {
    static MEMO: OnceLock<ShardedScoreMemo> = OnceLock::new();
    MEMO.get_or_init(ShardedScoreMemo::new)
}

/// Tuning knobs of a serve session.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Search objective for every admission.
    pub objective: Objective,
    /// Search seed (fixed seed ⇒ byte-identical session replay).
    pub seed: u64,
    /// Multi-start count per admission.
    pub starts: usize,
    /// Beam width.
    pub beam: usize,
    /// Scoring budget per admission.
    pub budget: usize,
    /// Per-core data volume, GB (makespan probe time unit).
    pub gb_per_core: f64,
    /// Every n-th submit re-packs the whole fleet from scratch (0 =
    /// never): the drift bound on incremental admission.
    pub repack_every: usize,
    /// How much simulated time one `query` advances the makespan probe.
    pub probe_slice_s: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let s = SearchConfig::default();
        ServeConfig {
            objective: s.objective,
            seed: s.seed,
            starts: s.starts,
            beam: s.beam,
            budget: s.budget,
            gb_per_core: s.gb_per_core,
            repack_every: 8,
            probe_slice_s: 0.05,
        }
    }
}

/// One group of an admitted job: its committed placement plus the
/// mix-native constraints that survive a repack.
#[derive(Debug, Clone)]
struct JobGroup {
    kernel: KernelId,
    cores: usize,
    /// Committed home domain.
    home: u16,
    /// Committed remote fraction (ppm).
    remote_ppm: u32,
    /// `@dN` pin from the mix (survives repacks).
    mix_pin: Option<usize>,
    /// `%r` freeze from the mix (survives repacks).
    mix_ppm: Option<u32>,
}

/// One admitted job.
#[derive(Debug, Clone)]
struct Job {
    id: String,
    mix_label: String,
    groups: Vec<JobGroup>,
}

/// The incrementally advanced makespan co-simulation of the committed
/// placement.
struct Probe {
    program: Program,
    layout: RankLayout,
    chars: Vec<(KernelId, f64, f64)>,
    n_ranks: usize,
    /// Paused engine state (`None` before the first advance or after
    /// completion).
    cp: Option<EngineCheckpoint>,
    /// Next stop time.
    t_next: f64,
    /// Final makespan once the simulation completed.
    makespan: Option<f64>,
    /// Engine counters of the completed run.
    stats: SimStats,
}

impl Probe {
    /// Advance the simulation by one slice (no-op once complete).
    /// Returns the simulated time reached.
    fn advance(&mut self, slice: f64) -> f64 {
        if let Some(m) = self.makespan {
            return m;
        }
        let config = CoSimConfig::default();
        let step = match self.cp.take() {
            None => simulate_placed_until(
                &self.program,
                self.n_ranks,
                &config,
                &self.chars,
                &self.layout,
                RatingMode::Incremental,
                self.t_next,
            ),
            Some(cp) => resume_placed(
                &self.program,
                self.n_ranks,
                &config,
                &self.chars,
                &self.layout,
                RatingMode::Incremental,
                cp,
                self.t_next,
            ),
        };
        match step {
            SimStep::Paused(cp) => {
                let t = cp.t_end();
                self.cp = Some(cp);
                self.t_next += slice;
                t
            }
            SimStep::Done(r) => {
                let m = r
                    .finish_s
                    .iter()
                    .copied()
                    .map(|f| if f.is_finite() { f } else { r.t_end_s })
                    .fold(0.0f64, f64::max);
                self.makespan = Some(m);
                self.stats = r.stats;
                m
            }
        }
    }

    /// Drive the simulation to completion.
    fn finish(&mut self) -> f64 {
        while self.makespan.is_none() {
            self.t_next = f64::INFINITY;
            self.advance(0.0);
        }
        self.makespan.expect("loop exits only when set")
    }
}

/// The streaming co-scheduling service. One instance per `repro serve`
/// session; the score memo and characterization cache are process-wide.
pub struct Service<'a> {
    topo: Topology,
    cfg: ServeConfig,
    source: CharSource<'a>,
    memo: &'static ShardedScoreMemo,
    chars: HashMap<KernelId, (f64, f64)>,
    jobs: Vec<Job>,
    /// Result of the latest optimize pass over the fleet.
    last: Option<OptResult>,
    probe: Option<Probe>,
    submits: u64,
    finishes: u64,
    repacks: u64,
    scored: u64,
    evaluated: u64,
    probe_resumes: u64,
}

impl<'a> Service<'a> {
    /// A service over a topology with a characterization source.
    pub fn new(topo: Topology, cfg: ServeConfig, source: CharSource<'a>) -> Service<'a> {
        Service {
            topo,
            cfg,
            source,
            memo: service_memo(),
            chars: HashMap::new(),
            jobs: Vec::new(),
            last: None,
            probe: None,
            submits: 0,
            finishes: 0,
            repacks: 0,
            scored: 0,
            evaluated: 0,
            probe_resumes: 0,
        }
    }

    /// Live job count.
    pub fn jobs_len(&self) -> usize {
        self.jobs.len()
    }

    /// The latest optimize result over the fleet (for tests/benches).
    pub fn last_result(&self) -> Option<&OptResult> {
        self.last.as_ref()
    }

    /// The committed placement: per job, `(id, [(kernel, cores, home,
    /// remote_ppm)])` in admission order (for tests/benches).
    pub fn placements(&self) -> Vec<(String, Vec<(KernelId, usize, u16, u32)>)> {
        self.jobs
            .iter()
            .map(|j| {
                (
                    j.id.clone(),
                    j.groups
                        .iter()
                        .map(|g| (g.kernel, g.cores, g.home, g.remote_ppm))
                        .collect(),
                )
            })
            .collect()
    }

    fn search_config(&self) -> SearchConfig {
        SearchConfig {
            objective: self.cfg.objective,
            seed: self.cfg.seed,
            starts: self.cfg.starts,
            beam: self.cfg.beam,
            budget: self.cfg.budget,
            gb_per_core: self.cfg.gb_per_core,
            ..SearchConfig::default()
        }
    }

    /// Characterize any of `mix`'s kernels the service hasn't seen yet
    /// (warm [`CharCache::global`] entries make repeats free).
    fn characterize(&mut self, mix: &Mix) -> Result<()> {
        let kernels = mix.kernels();
        let meas = CharCache::global().characterize_source(&self.topo.base, &kernels, &self.source)?;
        for (&k, c) in meas.iter() {
            self.chars.insert(k, (c.f, c.bs_gbs));
        }
        Ok(())
    }

    /// One [`OptGroup`] per group of an admitted job. `settled` pins the
    /// committed placement; otherwise only the mix-native constraints
    /// apply (the repack path).
    fn job_groups(job: &Job, chars: &HashMap<KernelId, (f64, f64)>, settled: bool) -> Vec<OptGroup> {
        job.groups
            .iter()
            .map(|g| {
                let &(f, bs_gbs) = chars.get(&g.kernel).expect("admitted kernels characterized");
                let (pinned, fixed) = if settled {
                    (Some(g.home as usize), Some(g.remote_ppm))
                } else {
                    (g.mix_pin, g.mix_ppm)
                };
                OptGroup {
                    name: g.kernel.key().to_string(),
                    kernel: g.kernel,
                    n: g.cores,
                    f,
                    bs_gbs,
                    pinned,
                    fixed_remote_ppm: fixed,
                    kind: GroupKind::Mem,
                }
            })
            .collect()
    }

    /// Build the fleet's search space: existing jobs first (pinned unless
    /// `repack`), then the incoming mix's groups under their mix-native
    /// constraints. Construction mirrors [`SearchSpace::from_mix`] field
    /// for field, so an empty fleet's space is identical to the one
    /// `repro optimize` builds for the same mix.
    fn build_space(&self, incoming: Option<&Mix>, repack: bool) -> Result<SearchSpace> {
        let mut groups: Vec<OptGroup> = Vec::new();
        for job in &self.jobs {
            groups.extend(Self::job_groups(job, &self.chars, !repack));
        }
        if let Some(mix) = incoming {
            for g in &mix.groups {
                if !matches!(
                    g.bound,
                    crate::scenario::BoundHint::Auto | crate::scenario::BoundHint::Mem
                ) {
                    return Err(Error::InvalidPlan(format!(
                        "group '{}:{}{}': the co-scheduling service places groups on the \
                         DRAM roofline; drop the `{}` suffix",
                        g.kernel.key(),
                        g.cores,
                        g.bound.suffix(),
                        g.bound.suffix(),
                    )));
                }
                let &(f, bs_gbs) = self.chars.get(&g.kernel).ok_or_else(|| {
                    Error::InvalidPlan(format!("kernel {:?} not characterized", g.kernel))
                })?;
                groups.push(OptGroup {
                    name: g.kernel.key().to_string(),
                    kernel: g.kernel,
                    n: g.cores,
                    f,
                    bs_gbs,
                    pinned: match g.place {
                        crate::topology::GroupPlacement::Domain(d) => Some(d),
                        _ => None,
                    },
                    fixed_remote_ppm: if g.remote_ppm > 0 { Some(g.remote_ppm) } else { None },
                    kind: GroupKind::Mem,
                });
            }
        }
        let domain_cores: Vec<usize> =
            self.topo.domains.iter().map(|d| d.machine.cores).collect();
        let mut space = SearchSpace::new(
            self.topo.shape(),
            domain_cores,
            groups,
            DEFAULT_REMOTE_LEVELS.to_vec(),
        )?;
        space.node_of = self.topo.node_of();
        space.collective_extra_s = self.topo.collective_extra_s();
        Ok(space)
    }

    /// Run the shared-memo search over `space` and account its counters.
    fn optimize_fleet(&mut self, space: &SearchSpace) -> Result<OptResult> {
        let result =
            optimize_with_memo(space, &self.search_config(), self.memo, space.fingerprint())?;
        self.scored += result.scored;
        self.evaluated += result.evaluated;
        Ok(result)
    }

    /// Rebuild the makespan probe for the committed placement.
    fn rebuild_probe(&mut self, space: &SearchSpace, result: &OptResult) {
        let (program, layout, chars, n_ranks) =
            makespan_setup(space, &result.best, self.cfg.gb_per_core);
        self.probe = if n_ranks > 0 {
            Some(Probe {
                program,
                layout,
                chars,
                n_ranks,
                cp: None,
                t_next: self.cfg.probe_slice_s.max(1e-6),
                makespan: None,
                stats: SimStats::default(),
            })
        } else {
            None
        };
    }

    /// Commit `result.best` back onto the jobs (group order is admission
    /// order, so the space's groups map 1:1 onto the fleet's).
    fn commit(&mut self, result: &OptResult) {
        let mut gi = 0;
        for job in &mut self.jobs {
            for g in &mut job.groups {
                g.home = result.best.home[gi];
                g.remote_ppm = result.best.remote_ppm[gi];
                gi += 1;
            }
        }
        debug_assert_eq!(gi, result.best.home.len(), "fleet/space group count mismatch");
    }

    /// Admit a job: parse, characterize, search the residual (or repack),
    /// commit. Errors leave the fleet untouched.
    pub fn submit(&mut self, id: &str, mix_spec: &str) -> Result<()> {
        if self.jobs.iter().any(|j| j.id == id) {
            return Err(Error::InvalidPlan(format!("job id '{id}' is already live")));
        }
        let mix = Mix::parse(mix_spec)?;
        if mix.groups.is_empty() {
            return Err(Error::InvalidPlan(format!(
                "mix '{}' has no active groups to place",
                mix.label()
            )));
        }
        self.characterize(&mix)?;
        let repack = self.cfg.repack_every > 0
            && !self.jobs.is_empty()
            && (self.submits + 1) % self.cfg.repack_every as u64 == 0;
        let space = self.build_space(Some(&mix), repack)?;
        let result = self.optimize_fleet(&space)?;
        // Only commit after the search succeeded.
        self.jobs.push(Job {
            id: id.to_string(),
            mix_label: mix.label(),
            groups: mix
                .groups
                .iter()
                .map(|g| JobGroup {
                    kernel: g.kernel,
                    cores: g.cores,
                    home: 0,
                    remote_ppm: 0,
                    mix_pin: match g.place {
                        crate::topology::GroupPlacement::Domain(d) => Some(d),
                        _ => None,
                    },
                    mix_ppm: if g.remote_ppm > 0 { Some(g.remote_ppm) } else { None },
                })
                .collect(),
        });
        self.commit(&result);
        self.submits += 1;
        if repack {
            self.repacks += 1;
        }
        self.rebuild_probe(&space, &result);
        self.last = Some(result);
        Ok(())
    }

    /// Retire a job and exactly re-rate the residual fleet.
    pub fn finish(&mut self, id: &str) -> Result<()> {
        let idx = self
            .jobs
            .iter()
            .position(|j| j.id == id)
            .ok_or_else(|| Error::InvalidPlan(format!("no live job with id '{id}'")))?;
        self.jobs.remove(idx);
        self.finishes += 1;
        if self.jobs.is_empty() {
            self.last = None;
            self.probe = None;
            return Ok(());
        }
        // Fully pinned residual space: exactly one candidate, so this is
        // an exact re-rate of the surviving placement, not a search.
        let space = self.build_space(None, false)?;
        let result = self.optimize_fleet(&space)?;
        self.commit(&result);
        self.rebuild_probe(&space, &result);
        self.last = Some(result);
        Ok(())
    }

    /// A job's placement and rates, advancing the makespan probe one
    /// slice.
    fn query_response(&mut self, id: &str) -> Result<String> {
        let (job_idx, gi0) = {
            let mut gi = 0;
            let mut found = None;
            for (ji, job) in self.jobs.iter().enumerate() {
                if job.id == id {
                    found = Some((ji, gi));
                    break;
                }
                gi += job.groups.len();
            }
            found.ok_or_else(|| Error::InvalidPlan(format!("no live job with id '{id}'")))?
        };
        let probe_t = match &mut self.probe {
            Some(p) => {
                self.probe_resumes += 1;
                p.advance(self.cfg.probe_slice_s.max(1e-6))
            }
            None => 0.0,
        };
        let last = self.last.as_ref().expect("live jobs imply a result");
        let job = &self.jobs[job_idx];
        let groups: Vec<String> = job
            .groups
            .iter()
            .enumerate()
            .map(|(k, g)| {
                format!(
                    r#"{{"kernel":"{}","cores":{},"home":{},"remote_ppm":{},"rate_gbs":{}}}"#,
                    g.kernel.key(),
                    g.cores,
                    g.home,
                    g.remote_ppm,
                    last.best_rates[gi0 + k],
                )
            })
            .collect();
        Ok(format!(
            r#"{{"ok":true,"op":"query","id":"{}","mix":"{}","groups":[{}],"probe_t_s":{}}}"#,
            json_escape(&job.id),
            json_escape(&job.mix_label),
            groups.join(","),
            probe_t,
        ))
    }

    /// The full fleet state: placements, completed makespan probe, and
    /// every cache/search counter.
    fn snapshot_response(&mut self) -> String {
        let makespan = match &mut self.probe {
            Some(p) => {
                self.probe_resumes += 1;
                Some(p.finish())
            }
            None => None,
        };
        let jobs: Vec<String> = self
            .jobs
            .iter()
            .map(|j| {
                let placement: Vec<String> = j
                    .groups
                    .iter()
                    .map(|g| {
                        let mut s = format!("{}:{}@d{}", g.kernel.key(), g.cores, g.home);
                        if g.remote_ppm > 0 {
                            s.push_str(&format!("%r{}", g.remote_ppm as f64 / 1e6));
                        }
                        s
                    })
                    .collect();
                format!(
                    r#"{{"id":"{}","mix":"{}","placement":"{}"}}"#,
                    json_escape(&j.id),
                    json_escape(&j.mix_label),
                    json_escape(&placement.join("+")),
                )
            })
            .collect();
        let (memo_hits, memo_misses, memo_entries) = self.memo.stats();
        let cc = CharCache::global().stats();
        let score = self.last.as_ref().map(|r| r.best_score);
        format!(
            concat!(
                r#"{{"ok":true,"op":"snapshot","jobs":[{}],"score":{},"makespan_s":{},"#,
                r#""counters":{{"submits":{},"finishes":{},"repacks":{},"scored":{},"#,
                r#""evaluated":{},"probe_resumes":{},"#,
                r#""memo":{{"hits":{},"misses":{},"entries":{}}},"#,
                r#""char_cache":{{"hits":{},"misses":{},"entries":{}}}}}}}"#
            ),
            jobs.join(","),
            score.map_or_else(|| "null".to_string(), |s| s.to_string()),
            makespan.map_or_else(|| "null".to_string(), |m| m.to_string()),
            self.submits,
            self.finishes,
            self.repacks,
            self.scored,
            self.evaluated,
            self.probe_resumes,
            memo_hits,
            memo_misses,
            memo_entries,
            cc.hits,
            cc.misses,
            cc.entries,
        )
    }

    /// Handle one request line, returning one JSON response line. Every
    /// failure path returns a structured `"ok":false` response — the
    /// session keeps running.
    pub fn handle_line(&mut self, line: &str) -> String {
        let err = |e: Error| format!(r#"{{"ok":false,"error":"{}"}}"#, json_escape(&e.to_string()));
        let req = match Request::parse(line) {
            Ok(r) => r,
            Err(e) => return err(e),
        };
        match req {
            Request::Submit { id, mix } => match self.submit(&id, &mix) {
                Ok(()) => {
                    let last = self.last.as_ref().expect("submit succeeded");
                    format!(
                        concat!(
                            r#"{{"ok":true,"op":"submit","id":"{}","placement":"{}","#,
                            r#""score":{},"scored":{},"evaluated":{},"jobs":{}}}"#
                        ),
                        json_escape(&id),
                        json_escape(&last.best_label),
                        last.best_score,
                        last.scored,
                        last.evaluated,
                        self.jobs.len(),
                    )
                }
                Err(e) => err(e),
            },
            Request::Finish { id } => match self.finish(&id) {
                Ok(()) => format!(
                    r#"{{"ok":true,"op":"finish","id":"{}","jobs":{}}}"#,
                    json_escape(&id),
                    self.jobs.len(),
                ),
                Err(e) => err(e),
            },
            Request::Query { id } => match self.query_response(&id) {
                Ok(s) => s,
                Err(e) => err(e),
            },
            Request::Snapshot => self.snapshot_response(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine_by_name;

    fn service() -> Service<'static> {
        let m = machine_by_name("rome").unwrap();
        let topo = Topology::parse(&m, "2x4").unwrap();
        Service::new(topo, ServeConfig::default(), CharSource::Ecm)
    }

    #[test]
    fn submit_finish_query_snapshot_round_trip() {
        let mut s = service();
        let r = s.handle_line(r#"{"op":"submit","id":"j0","mix":"dcopy:6"}"#);
        assert!(r.contains(r#""ok":true"#), "{r}");
        assert!(r.contains(r#""op":"submit""#), "{r}");
        let r = s.handle_line(r#"{"op":"submit","id":"j1","mix":"ddot2:6"}"#);
        assert!(r.contains(r#""jobs":2"#), "{r}");
        let r = s.handle_line(r#"{"op":"query","id":"j1"}"#);
        assert!(r.contains(r#""op":"query""#) && r.contains("rate_gbs"), "{r}");
        let r = s.handle_line(r#"{"op":"finish","id":"j0"}"#);
        assert!(r.contains(r#""jobs":1"#), "{r}");
        let r = s.handle_line(r#"{"op":"snapshot"}"#);
        assert!(r.contains(r#""makespan_s":"#) && r.contains(r#""submits":2"#), "{r}");
        assert!(r.contains(r#""finishes":1"#), "{r}");
    }

    #[test]
    fn errors_are_structured_and_leave_the_fleet_intact() {
        let mut s = service();
        assert!(s.handle_line(r#"{"op":"submit","id":"j0","mix":"dcopy:6"}"#).contains("true"));
        // Duplicate id.
        let r = s.handle_line(r#"{"op":"submit","id":"j0","mix":"ddot2:4"}"#);
        assert!(r.contains(r#""ok":false"#) && r.contains("already live"), "{r}");
        // Unparseable mix.
        let r = s.handle_line(r#"{"op":"submit","id":"j1","mix":"???"}"#);
        assert!(r.contains(r#""ok":false"#), "{r}");
        // Unknown job.
        let r = s.handle_line(r#"{"op":"finish","id":"nope"}"#);
        assert!(r.contains(r#""ok":false"#), "{r}");
        // Garbage line.
        let r = s.handle_line("garbage {{{");
        assert!(r.contains(r#""ok":false"#), "{r}");
        assert_eq!(s.jobs_len(), 1);
    }

    #[test]
    fn overfull_admission_is_rejected_and_fleet_survives() {
        let mut s = service();
        assert!(s.handle_line(r#"{"op":"submit","id":"a","mix":"dcopy:30"}"#).contains("true"));
        // 2x4 rome has 64 cores; a second 40-core job cannot fit.
        let r = s.handle_line(r#"{"op":"submit","id":"b","mix":"ddot2:40"}"#);
        assert!(r.contains(r#""ok":false"#), "{r}");
        assert_eq!(s.jobs_len(), 1);
        // The fleet still answers queries.
        assert!(s.handle_line(r#"{"op":"query","id":"a"}"#).contains("true"));
    }

    #[test]
    fn session_replay_is_deterministic() {
        let lines = [
            r#"{"op":"submit","id":"j0","mix":"dcopy:6"}"#,
            r#"{"op":"submit","id":"j1","mix":"ddot2:6+daxpy:4"}"#,
            r#"{"op":"query","id":"j0"}"#,
            r#"{"op":"finish","id":"j0"}"#,
            r#"{"op":"submit","id":"j2","mix":"stream:8%r0.25"}"#,
            r#"{"op":"snapshot"}"#,
        ];
        let run = || -> Vec<String> {
            let mut s = service();
            lines.iter().map(|l| s.handle_line(l)).collect()
        };
        let a = run();
        let b = run();
        // Everything except the process-global cache counters (which grow
        // across replays within one process) must match byte for byte.
        for (x, y) in a.iter().zip(&b).take(lines.len() - 1) {
            assert_eq!(x, y);
        }
    }
}
