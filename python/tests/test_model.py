"""L2 model tests: physical steady states of the batched simulation and the
batched analytic model vs its scalar reference."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.contention import BATCH, N_CORES

# A BDW-1-like machine (see rust/src/config/machine.rs): 66.9 GB/s read
# bandwidth at 2.2 GHz -> capacity in lines/cycle.
CAP = np.float32(66.9 / 2.2 / 64.0)
L0 = np.float32(200.0)
D0 = np.float32(1.5)


def config(demands, costs):
    """Build one full-batch configuration with the first row populated."""
    d = np.zeros((BATCH, N_CORES), np.float32)
    c = np.ones((BATCH, N_CORES), np.float32)
    d[0, : len(demands)] = demands
    c[0, : len(costs)] = costs
    win = (D0 + d * c * L0).astype(np.float32)
    cap = np.full((BATCH, 1), CAP, np.float32)
    return d, c, win, cap


def test_solo_core_served_rate_equals_demand():
    d, c, win, cap = config([0.117], [1.23])
    served = np.asarray(model.simulate(d, c, win, cap))
    cycles = 3 * 4096
    rate = served[0, 0] / cycles
    assert abs(rate - 0.117) / 0.117 < 0.01, rate


def test_saturated_domain_serves_at_capacity():
    d, c, win, cap = config([0.117] * 10, [1.0] * 10)
    served = np.asarray(model.simulate(d, c, win, cap))
    cycles = 3 * 4096
    cost_rate = (served[0] * np.asarray(c)[0]).sum() / cycles
    assert abs(cost_rate - CAP) / CAP < 0.02, cost_rate


def test_share_proportional_to_window():
    """At saturation, per-core shares follow the prefetch windows."""
    demands = [0.15] * 5 + [0.08] * 5
    d, c, win, cap = config(demands, [1.0] * 10)
    served = np.asarray(model.simulate(d, c, win, cap))
    hi = served[0, :5].mean()
    lo = served[0, 5:10].mean()
    want = (D0 + 0.15 * L0) / (D0 + 0.08 * L0)
    assert abs(hi / lo - want) / want < 0.05, (hi / lo, want)


def test_analytic_matches_scalar_reference():
    rng = np.random.default_rng(11)
    k = 256
    n1 = rng.integers(1, 10, size=k).astype(np.float32)
    n2 = rng.integers(1, 10, size=k).astype(np.float32)
    f1 = rng.uniform(0.1, 0.9, size=k).astype(np.float32)
    f2 = rng.uniform(0.1, 0.9, size=k).astype(np.float32)
    bs1 = rng.uniform(30, 110, size=k).astype(np.float32)
    bs2 = rng.uniform(30, 110, size=k).astype(np.float32)
    per1, per2 = model.analytic_two_group(n1, f1, bs1, n2, f2, bs2)
    for i in range(k):
        w1, w2 = model.analytic_two_group_scalar(
            float(n1[i]), float(f1[i]), float(bs1[i]),
            float(n2[i]), float(f2[i]), float(bs2[i]))
        np.testing.assert_allclose(per1[i], w1, rtol=1e-4)
        np.testing.assert_allclose(per2[i], w2, rtol=1e-4)


@settings(max_examples=50, deadline=None)
@given(
    n1=st.integers(1, 16), n2=st.integers(1, 16),
    f1=st.floats(0.05, 0.99), f2=st.floats(0.05, 0.99),
    bs1=st.floats(20.0, 120.0), bs2=st.floats(20.0, 120.0),
)
def test_analytic_invariants_hypothesis(n1, n2, f1, f2, bs1, bs2):
    per1, per2 = model.analytic_two_group_scalar(n1, f1, bs1, n2, f2, bs2)
    # Nobody runs faster than solo.
    assert per1 <= f1 * bs1 + 1e-9
    assert per2 <= f2 * bs2 + 1e-9
    # Total never exceeds the overlapped saturated bandwidth (Eq. 4).
    b_mix = (n1 * bs1 + n2 * bs2) / (n1 + n2)
    assert n1 * per1 + n2 * per2 <= b_mix + 1e-6
    # Homogeneous pairing: equal per-core bandwidth.
    pa, pb = model.analytic_two_group_scalar(n1, f1, bs1, n1, f1, bs1)
    assert abs(pa - pb) < 1e-9
