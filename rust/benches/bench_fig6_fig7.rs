//! Bench: regenerate Figs. 6 (full-domain pairings) and 7 (symmetric
//! scaling) and time the sweeps per machine.

use membw::benchutil::Bench;
use membw::config::{machine, MachineId};
use membw::kernels::KernelId;
use membw::report::{fig6_report, fig7_report, ExperimentCtx};
use membw::sweep::{full_domain_splits, run_cases, MeasureEngine};

fn main() {
    let mut b = Bench::new("fig6_fig7");

    // Time one full-domain pairing sweep per machine (fluid engine).
    for mid in MachineId::ALL {
        let m = machine(mid);
        let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
        b.run(&format!("fig6 sweep dcopy+ddot2 [{}]", mid.key()), 3, || {
            let _ = run_cases(&m, &cases, &MeasureEngine::Fluid).unwrap();
        });
    }

    // Regenerate the full figures.
    let ctx = ExperimentCtx::fluid(std::path::PathBuf::from("results"));
    let mut fig6 = String::new();
    b.run("full Fig. 6 (3 pairings x 4 machines)", 1, || {
        fig6 = fig6_report(&ctx).expect("fig6");
    });
    let mut fig7 = String::new();
    b.run("full Fig. 7 (3 pairings x 4 machines)", 1, || {
        fig7 = fig7_report(&ctx).expect("fig7");
    });
    // Print the per-pairing summaries only (figures land in results/).
    for line in fig6.lines().chain(fig7.lines()) {
        if line.starts_with("FIG") || line.starts_with("===") || line.starts_with('[') {
            println!("{line}");
        }
    }
    b.finish();
}
