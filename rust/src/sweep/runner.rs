//! Pairing sweep runner — the k=2 special case of the scenario engine.
//!
//! Historically this module owned its own measurement loop and
//! characterization cache; both now live in [`crate::scenario`], and this
//! runner only converts [`PairingCase`]s into two-group [`Mix`]es, delegates
//! to the batched parallel [`crate::scenario::run_mixes`] pipeline, and
//! reshapes the k-group results into the legacy two-group [`CaseResult`]
//! records (what the Fig. 6–9 reports consume). The analytic prediction is
//! the multigroup generalization evaluated at k=2, which is exactly
//! Eqs. (4)+(5) — see `share_two_groups`.

use crate::config::Machine;
use crate::error::Result;
use crate::runtime::PjrtSimExecutor;
use crate::scenario::{run_mixes, Mix};
use crate::sweep::plan::PairingCase;
use crate::sweep::results::{CaseResult, ResultSet};

pub use crate::scenario::MeasureEngine;

/// Run `cases` on `machine` with `engine`; results are in plan order.
pub fn run_cases(machine: &Machine, cases: &[PairingCase], engine: &MeasureEngine) -> Result<ResultSet> {
    for c in cases {
        c.validate(machine)?;
    }
    let mixes: Vec<Mix> = cases.iter().map(Mix::from_pairing).collect();
    let mixed = run_mixes(machine, &mixes, engine)?;
    Ok(ResultSet {
        cases: cases
            .iter()
            .zip(&mixed.cases)
            .map(|(c, m)| CaseResult {
                machine: machine.id,
                kernels: [c.k1, c.k2],
                n: [c.n1, c.n2],
                measured_per_core: [m.groups[0].measured_per_core, m.groups[1].measured_per_core],
                model_per_core: [m.groups[0].model_per_core, m.groups[1].model_per_core],
                measured_total: m.measured_total_gbs,
                model_total: m.model_total_gbs,
            })
            .collect(),
    })
}

/// Convenience wrapper that loads the artifact bundle and runs via PJRT.
pub fn run_cases_pjrt(
    machine: &Machine,
    cases: &[PairingCase],
    exec: &PjrtSimExecutor,
) -> Result<ResultSet> {
    run_cases(machine, cases, &MeasureEngine::Pjrt(exec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::KernelId;
    use crate::sweep::plan::full_domain_splits;

    #[test]
    fn fluid_sweep_produces_ordered_results() {
        let m = machine(MachineId::Rome);
        let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
        let rs = run_cases(&m, &cases, &MeasureEngine::Fluid).unwrap();
        assert_eq!(rs.cases.len(), cases.len());
        for (c, r) in cases.iter().zip(&rs.cases) {
            assert_eq!(c.n1, r.n[0]);
            assert!(r.measured_total > 0.0);
        }
    }

    #[test]
    fn model_error_small_on_bdw1_pairing_sweep() {
        // Preview of the Fig. 8 claim on one pairing.
        let m = machine(MachineId::Bdw1);
        let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
        let rs = run_cases(&m, &cases, &MeasureEngine::Fluid).unwrap();
        let errs = rs.all_errors();
        let max = errs.iter().cloned().fold(0.0, f64::max);
        assert!(max < 0.10, "max error {max}");
    }

    #[test]
    fn pairing_prediction_equals_two_group_model() {
        // The scenario pipeline must attach exactly the Eqs. (4)+(5)
        // prediction the two-group wrapper computes.
        use crate::scenario::{CharCache, EngineKind};
        use crate::sharing::{share_two_groups, KernelGroup};
        let m = machine(MachineId::Bdw1);
        let case = PairingCase { k1: KernelId::Dcopy, k2: KernelId::Ddot2, n1: 6, n2: 4 };
        let rs = run_cases(&m, &[case], &MeasureEngine::Fluid).unwrap();
        let get = |k| {
            CharCache::global()
                .lookup(&(m.fingerprint(), k, EngineKind::Fluid))
                .expect("characterized by run_cases")
        };
        let c1 = get(KernelId::Dcopy);
        let c2 = get(KernelId::Ddot2);
        let pred = share_two_groups(
            &KernelGroup { n: 6, f: c1.f, bs_gbs: c1.bs_gbs },
            &KernelGroup { n: 4, f: c2.f, bs_gbs: c2.bs_gbs },
        );
        for g in 0..2 {
            assert!(
                (rs.cases[0].model_per_core[g] - pred.per_core_gbs[g]).abs() < 1e-12,
                "group {g}"
            );
        }
    }
}
