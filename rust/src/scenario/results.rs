//! Result records for k-group mixes: measured vs modeled bandwidth per
//! group, with CSV and JSON-lines emission (hand-rolled — the build is
//! offline).

use std::io::Write;
use std::path::Path;

use crate::config::MachineId;
use crate::error::Result;
use crate::kernels::KernelId;
use crate::scenario::spec::Mix;
use crate::stats::rel_error;

/// Outcome of one kernel group within a measured mix.
#[derive(Debug, Clone)]
pub struct GroupOutcome {
    /// Kernel of the group.
    pub kernel: KernelId,
    /// Cores in the group.
    pub n: usize,
    /// Measured aggregate bandwidth of the group, GB/s.
    pub measured_bw_gbs: f64,
    /// Measured per-core bandwidth, GB/s.
    pub measured_per_core: f64,
    /// Multigroup-model aggregate bandwidth, GB/s.
    pub model_bw_gbs: f64,
    /// Multigroup-model per-core bandwidth, GB/s.
    pub model_per_core: f64,
    /// Model bandwidth share α of the group (sums to 1 over groups).
    pub model_alpha: f64,
}

impl GroupOutcome {
    /// Relative per-core model error (the paper's Fig. 8 metric).
    pub fn error(&self) -> f64 {
        rel_error(self.measured_per_core, self.model_per_core)
    }
}

/// Outcome of one measured mix: per-group results plus totals.
#[derive(Debug, Clone)]
pub struct MixResult {
    /// Machine the mix ran on.
    pub machine: MachineId,
    /// The mix specification.
    pub mix: Mix,
    /// Per-group outcomes, in mix order.
    pub groups: Vec<GroupOutcome>,
    /// Measured aggregate bandwidth over all groups, GB/s.
    pub measured_total_gbs: f64,
    /// Modeled aggregate bandwidth, GB/s.
    pub model_total_gbs: f64,
    /// Overlapped saturated bandwidth (generalized Eq. 4), GB/s.
    pub b_mix_gbs: f64,
    /// Whether the model ran in the saturated regime.
    pub saturated: bool,
}

impl MixResult {
    /// Per-group relative errors (groups with zero cores are skipped).
    pub fn errors(&self) -> Vec<f64> {
        self.groups.iter().filter(|g| g.n > 0).map(|g| g.error()).collect()
    }

    /// Measured bandwidth share of group `gi`.
    pub fn measured_alpha(&self, gi: usize) -> f64 {
        if self.measured_total_gbs > 0.0 {
            self.groups[gi].measured_bw_gbs / self.measured_total_gbs
        } else {
            0.0
        }
    }

    /// CSV header matching [`MixResult::to_csv_rows`].
    pub fn csv_header() -> &'static str {
        "machine,mix,k,idle,group,kernel,n,meas_pc_gbs,model_pc_gbs,meas_bw_gbs,model_bw_gbs,alpha_meas,alpha_model,err"
    }

    /// One CSV row per group.
    pub fn to_csv_rows(&self) -> Vec<String> {
        self.groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                format!(
                    "{},{},{},{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.5},{:.5},{:.5}",
                    self.machine.key(),
                    self.mix.label(),
                    self.mix.k(),
                    self.mix.idle_cores,
                    gi,
                    g.kernel.key(),
                    g.n,
                    g.measured_per_core,
                    g.model_per_core,
                    g.measured_bw_gbs,
                    g.model_bw_gbs,
                    self.measured_alpha(gi),
                    g.model_alpha,
                    g.error(),
                )
            })
            .collect()
    }

    /// One JSON object per mix (hand-rolled).
    pub fn to_json(&self) -> String {
        let groups: Vec<String> = self
            .groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                format!(
                    "{{\"kernel\":\"{}\",\"n\":{},\"meas_pc\":{:.5},\"model_pc\":{:.5},\
                     \"alpha_meas\":{:.6},\"alpha_model\":{:.6},\"err\":{:.6}}}",
                    g.kernel.key(),
                    g.n,
                    g.measured_per_core,
                    g.model_per_core,
                    self.measured_alpha(gi),
                    g.model_alpha,
                    g.error(),
                )
            })
            .collect();
        format!(
            "{{\"machine\":\"{}\",\"mix\":\"{}\",\"idle\":{},\"saturated\":{},\
             \"meas_total\":{:.5},\"model_total\":{:.5},\"b_mix\":{:.5},\"groups\":[{}]}}",
            self.machine.key(),
            self.mix.label(),
            self.mix.idle_cores,
            self.saturated,
            self.measured_total_gbs,
            self.model_total_gbs,
            self.b_mix_gbs,
            groups.join(","),
        )
    }
}

/// A set of mix results with persistence helpers.
#[derive(Debug, Clone, Default)]
pub struct MixResultSet {
    /// All mix results, in input order.
    pub cases: Vec<MixResult>,
}

impl MixResultSet {
    /// All per-group relative errors, flattened.
    pub fn all_errors(&self) -> Vec<f64> {
        self.cases.iter().flat_map(|c| c.errors()).collect()
    }

    /// Write as CSV (one row per group).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", MixResult::csv_header())?;
        for c in &self.cases {
            for row in c.to_csv_rows() {
                writeln!(f, "{row}")?;
            }
        }
        Ok(())
    }

    /// Write as JSON lines (one object per mix).
    pub fn write_jsonl(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        for c in &self.cases {
            writeln!(f, "{}", c.to_json())?;
        }
        Ok(())
    }
}

/// Result of a time-phased scenario: one [`MixResult`] per phase.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// Scenario name.
    pub name: String,
    /// Machine the scenario ran on.
    pub machine: MachineId,
    /// Per-phase results, in time order.
    pub phases: Vec<MixResult>,
}

impl ScenarioResult {
    /// All per-group relative errors over all phases.
    pub fn all_errors(&self) -> Vec<f64> {
        self.phases.iter().flat_map(|p| p.errors()).collect()
    }

    /// Safe file stem derived from the scenario name.
    pub fn file_stem(&self) -> String {
        crate::scenario::slugify(&self.name)
    }

    /// Write all phases as one CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        MixResultSet { cases: self.phases.clone() }.write_csv(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelId;

    fn sample() -> MixResult {
        MixResult {
            machine: MachineId::Bdw1,
            mix: Mix::new().with(KernelId::Dcopy, 6).with(KernelId::Ddot2, 4).idle(0),
            groups: vec![
                GroupOutcome {
                    kernel: KernelId::Dcopy,
                    n: 6,
                    measured_bw_gbs: 37.7,
                    measured_per_core: 6.29,
                    model_bw_gbs: 38.6,
                    model_per_core: 6.44,
                    model_alpha: 0.65,
                },
                GroupOutcome {
                    kernel: KernelId::Ddot2,
                    n: 4,
                    measured_bw_gbs: 20.0,
                    measured_per_core: 5.0,
                    model_bw_gbs: 20.4,
                    model_per_core: 5.09,
                    model_alpha: 0.35,
                },
            ],
            measured_total_gbs: 57.7,
            model_total_gbs: 59.0,
            b_mix_gbs: 59.0,
            saturated: true,
        }
    }

    #[test]
    fn errors_match_fig8_definition() {
        let r = sample();
        let e = r.errors();
        assert_eq!(e.len(), 2);
        assert!((e[0] - (6.44 - 6.29) / 6.44).abs() < 1e-12);
    }

    #[test]
    fn measured_alpha_partitions_total() {
        let r = sample();
        assert!((r.measured_alpha(0) + r.measured_alpha(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn csv_rows_match_header_arity() {
        let r = sample();
        let header_cols = MixResult::csv_header().split(',').count();
        for row in r.to_csv_rows() {
            assert_eq!(row.split(',').count(), header_cols);
        }
    }

    #[test]
    fn json_is_wellformed_enough() {
        let j = sample().to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(j.contains("\"mix\":\"dcopy:6+ddot2:4\""));
    }

    #[test]
    fn files_roundtrip() {
        let dir = std::env::temp_dir().join("membw-scenario-results-test");
        let set = MixResultSet { cases: vec![sample(), sample()] };
        set.write_csv(&dir.join("mixes.csv")).unwrap();
        set.write_jsonl(&dir.join("mixes.jsonl")).unwrap();
        let csv = std::fs::read_to_string(dir.join("mixes.csv")).unwrap();
        assert_eq!(csv.lines().count(), 1 + 2 * 2, "header + 2 groups x 2 mixes");
        let jsonl = std::fs::read_to_string(dir.join("mixes.jsonl")).unwrap();
        assert_eq!(jsonl.lines().count(), 2);
    }
}
