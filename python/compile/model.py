"""Layer-2 JAX model: the batched contention simulation and the batched
analytic sharing model (paper Eqs. 4+5), both built on the Layer-1 Pallas
kernel / plain jnp and AOT-lowered to HLO by ``aot.py``.

Python runs at build time only; the Rust coordinator executes the lowered
HLO through PJRT on the request path.
"""

from functools import partial

import jax
import jax.numpy as jnp

from compile.kernels.contention import CHUNK_CYCLES, contention_chunk


@partial(jax.jit, static_argnames=("warmup_chunks", "measure_chunks", "cycles"))
def simulate(d, c, win, cap, *, warmup_chunks: int = 1, measure_chunks: int = 3,
             cycles: int = CHUNK_CYCLES):
    """Full batched simulation: warm-up, then measurement.

    Returns ``served`` lines per (config, core) accumulated over
    ``measure_chunks * cycles`` cycles, after ``warmup_chunks * cycles`` of
    warm-up. The caller converts lines/cycle to GB/s with the machine's
    frequency.
    """
    b, n = d.shape
    occ = jnp.zeros((b, n), jnp.float32)
    served = jnp.zeros((b, n), jnp.float32)
    for _ in range(warmup_chunks):
        occ, served = contention_chunk(d, c, win, cap, occ, served, cycles=cycles)
    served = jnp.zeros_like(served)  # discard warm-up traffic
    for _ in range(measure_chunks):
        occ, served = contention_chunk(d, c, win, cap, occ, served, cycles=cycles)
    return served


@jax.jit
def analytic_two_group(n1, f1, bs1, n2, f2, bs2):
    """Batched analytic sharing model — paper Eqs. (4) and (5) with the
    demand cap for the nonsaturated case (matches
    ``rust/src/sharing/multigroup.rs`` for two groups).

    All inputs are f32 vectors of the same length (one entry per case).
    Returns per-core bandwidths ``(b1_core, b2_core)`` in the same unit as
    ``bs``.
    """
    n1f = n1.astype(jnp.float32)
    n2f = n2.astype(jnp.float32)
    ntot = jnp.maximum(n1f + n2f, 1e-9)
    b_mix = (n1f * bs1 + n2f * bs2) / ntot  # Eq. (4)

    dem1 = n1f * f1 * bs1  # unconstrained group demands
    dem2 = n2f * f2 * bs2
    budget = jnp.minimum(b_mix, dem1 + dem2)

    w1 = n1f * f1
    w2 = n2f * f2
    wsum = jnp.maximum(w1 + w2, 1e-12)
    raw1 = budget * w1 / wsum  # Eq. (5) share of the budget
    raw2 = budget * w2 / wsum

    # Two-group water-fill: if a group's proportional allocation exceeds its
    # demand, cap it and give the leftover to the other group (up to its own
    # demand).
    bw1 = jnp.where(raw1 > dem1, dem1, jnp.where(raw2 > dem2, jnp.minimum(budget - dem2, dem1), raw1))
    bw2 = jnp.where(raw2 > dem2, dem2, jnp.where(raw1 > dem1, jnp.minimum(budget - dem1, dem2), raw2))

    per1 = jnp.where(n1f > 0, bw1 / jnp.maximum(n1f, 1.0), 0.0)
    per2 = jnp.where(n2f > 0, bw2 / jnp.maximum(n2f, 1.0), 0.0)
    return per1, per2


def analytic_two_group_scalar(n1, f1, bs1, n2, f2, bs2):
    """Plain-Python scalar reference for ``analytic_two_group`` (tests)."""
    ntot = n1 + n2
    if ntot == 0:
        return 0.0, 0.0
    b_mix = (n1 * bs1 + n2 * bs2) / ntot
    dem1, dem2 = n1 * f1 * bs1, n2 * f2 * bs2
    budget = min(b_mix, dem1 + dem2)
    w1, w2 = n1 * f1, n2 * f2
    wsum = max(w1 + w2, 1e-12)
    raw1, raw2 = budget * w1 / wsum, budget * w2 / wsum
    if raw1 > dem1:
        bw1, bw2 = dem1, min(budget - dem1, dem2)
    elif raw2 > dem2:
        bw2, bw1 = dem2, min(budget - dem2, dem1)
    else:
        bw1, bw2 = raw1, raw2
    return (bw1 / n1 if n1 else 0.0), (bw2 / n2 if n2 else 0.0)
