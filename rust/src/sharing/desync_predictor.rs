//! Qualitative desynchronization prediction (Sect. V, closing discussion).
//!
//! If a kernel is sandwiched between a high-f kernel (before) and a low-f
//! kernel (after), early starters are slowed down (they still compete with
//! the heavy predecessor running on other cores) while late starters are
//! sped up (they overlap the light successor) — desynchronization is
//! *amplified* (positive skewness of the accumulated-time distribution).
//! Overlap with idleness (e.g. MPI_Allreduce waiting) *resynchronizes*
//! (negative skewness).

/// What the tail end of a kernel's execution overlaps with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OverlapPartner {
    /// Another loop kernel with request fraction `f`.
    Kernel { f: f64 },
    /// Idleness (waiting in a collective, or no work) — scenario (c).
    Idle,
}

/// Predicted direction of the desynchronization dynamics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SkewPrediction {
    /// Positive skewness: desynchronization amplified.
    Desynchronize,
    /// Negative skewness: resynchronization.
    Resynchronize,
    /// No strong prediction (f values too close).
    Neutral,
}

/// Relative f difference below which we refuse to predict a direction.
const NEUTRAL_BAND: f64 = 0.03;

/// Predict the skewness sign for a kernel with request fraction `f_kernel`
/// whose stragglers overlap `before` (what early finishers left behind) and
/// whose early starters overlap `after` (what late ranks are still doing).
///
/// * `after` idle ⇒ late starters run at full bandwidth ⇒ they catch up ⇒
///   resynchronization (Fig. 3a, skewness −0.27 ms).
/// * `after` a lower-f kernel ⇒ late starters of the *next* kernel compete
///   less ⇒ the spread grows ⇒ desynchronization (Fig. 3b, +0.42/+1.0 ms).
pub fn predict_skew(f_kernel: f64, after: OverlapPartner) -> SkewPrediction {
    match after {
        OverlapPartner::Idle => SkewPrediction::Resynchronize,
        OverlapPartner::Kernel { f } => {
            let rel = (f - f_kernel) / f_kernel.max(1e-12);
            if rel > NEUTRAL_BAND {
                // Successor is hungrier: early finishers steal bandwidth from
                // stragglers -> spread grows.
                SkewPrediction::Desynchronize
            } else if rel < -NEUTRAL_BAND {
                // Successor is lighter: stragglers still inside the kernel
                // get *more* bandwidth than the early starters had -> shrink.
                // NOTE: the paper observes the *amplifying* case for
                // DDOT2 -> DAXPY because f_DAXPY > f_DDOT2 on CLX.
                SkewPrediction::Resynchronize
            } else {
                SkewPrediction::Neutral
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_after_resynchronizes() {
        // Fig. 3(a): DDOT2 tail overlaps MPI_Wait idleness -> negative skew.
        assert_eq!(predict_skew(0.252, OverlapPartner::Idle), SkewPrediction::Resynchronize);
    }

    #[test]
    fn hungrier_successor_desynchronizes() {
        // Fig. 3(b): DDOT2 (f = 0.252) followed by DAXPY (f = 0.315).
        assert_eq!(
            predict_skew(0.252, OverlapPartner::Kernel { f: 0.315 }),
            SkewPrediction::Desynchronize
        );
    }

    #[test]
    fn near_equal_f_is_neutral() {
        assert_eq!(
            predict_skew(0.30, OverlapPartner::Kernel { f: 0.301 }),
            SkewPrediction::Neutral
        );
    }
}
