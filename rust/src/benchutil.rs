//! Minimal benchmarking harness (the offline build has no criterion).
//!
//! Usage in a `[[bench]]` target with `harness = false`:
//!
//! ```ignore
//! let mut b = membw::benchutil::Bench::new("fig8");
//! b.run("fluid sweep bdw1", 10, || { ... });
//! b.finish();
//! ```

use std::time::Instant;

/// One bench suite; prints criterion-style lines and a summary.
pub struct Bench {
    suite: String,
    results: Vec<(String, f64, f64, f64)>, // (name, med, mean, min) in seconds
}

impl Bench {
    /// Start a suite.
    pub fn new(suite: &str) -> Self {
        println!("== bench suite: {suite} ==");
        Bench { suite: suite.to_string(), results: vec![] }
    }

    /// Run `f` `iters` times (plus one warm-up) and record statistics.
    pub fn run<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) {
        f(); // warm-up
        let mut times: Vec<f64> = (0..iters.max(1))
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed().as_secs_f64()
            })
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times[0];
        println!(
            "{:-40} med {:>12} mean {:>12} min {:>12}  ({} iters)",
            name,
            fmt_time(med),
            fmt_time(mean),
            fmt_time(min),
            iters
        );
        self.results.push((name.to_string(), med, mean, min));
    }

    /// Run once and report a throughput in the given unit.
    pub fn throughput<F: FnOnce() -> f64>(&mut self, name: &str, unit: &str, f: F) {
        let t0 = Instant::now();
        let units = f();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:-40} {:>12.3e} {unit}/s  ({:.3}s wall, {:.3e} {unit})",
            name,
            units / dt,
            dt,
            units
        );
        self.results.push((name.to_string(), dt, dt, dt));
    }

    /// Print the summary footer.
    pub fn finish(self) {
        println!("== {} done: {} benches ==", self.suite, self.results.len());
    }
}

/// Human-readable duration.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.5).ends_with(" s"));
        assert!(fmt_time(2.5e-3).ends_with(" ms"));
        assert!(fmt_time(2.5e-6).ends_with(" µs"));
        assert!(fmt_time(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench::new("selftest");
        let mut count = 0usize;
        b.run("noop", 3, || count += 1);
        assert_eq!(count, 4); // 3 + warm-up
        b.finish();
    }
}
