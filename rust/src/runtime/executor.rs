//! High-level batched execution of the contention-simulation artifact.
//!
//! Packs simulation cases (machine + per-core workloads) into the
//! artifact's `[B, N]` f32 planes, executes through PJRT, and unpacks
//! per-core bandwidths in GB/s. Cases for *different machines* can share a
//! batch — the capacity is a per-config runtime input.

use std::path::Path;

use crate::config::Machine;
use crate::error::Result;
use crate::runtime::artifact::{ArtifactMeta, ArtifactPaths};
use crate::runtime::client::{PjrtExecutable, PjrtRuntime};
use crate::simulator::CoreWorkload;

/// One simulation case: a machine and its per-core workload vector.
#[derive(Debug, Clone)]
pub struct SimCase {
    /// Machine the case runs on (frequency, capacity, queue parameters).
    pub machine: Machine,
    /// One workload per active core (≤ machine.cores).
    pub workloads: Vec<CoreWorkload>,
}

/// Executor for the batched contention-simulation artifact.
pub struct PjrtSimExecutor {
    exe: PjrtExecutable,
    meta: ArtifactMeta,
}

impl PjrtSimExecutor {
    /// Load and compile the artifact bundle from `dir`.
    pub fn load(runtime: &PjrtRuntime, dir: &Path) -> Result<Self> {
        let paths = ArtifactPaths::locate(dir)?;
        let meta = paths.load_meta()?;
        let exe = runtime.load_hlo_text(&paths.contention_sim)?;
        Ok(PjrtSimExecutor { exe, meta })
    }

    /// Artifact geometry.
    pub fn meta(&self) -> ArtifactMeta {
        self.meta
    }

    /// Path the compiled artifact was loaded from (identifies the bundle,
    /// e.g. for characterization-cache keying).
    pub fn source(&self) -> &str {
        &self.exe.source
    }

    /// Run an arbitrary number of cases; cases are packed `batch` at a time
    /// (the final partial batch is padded with idle configs). Returns
    /// per-case per-core bandwidths in GB/s, aligned with the input order.
    pub fn run(&self, cases: &[SimCase]) -> Result<Vec<Vec<f64>>> {
        let mut out = Vec::with_capacity(cases.len());
        for chunk in cases.chunks(self.meta.batch) {
            out.extend(self.run_batch(chunk)?);
        }
        Ok(out)
    }

    /// Run one (possibly partial) batch.
    fn run_batch(&self, cases: &[SimCase]) -> Result<Vec<Vec<f64>>> {
        let b = self.meta.batch;
        let n = self.meta.n_cores;
        assert!(cases.len() <= b);

        let mut d = vec![0.0f32; b * n];
        let mut c = vec![1.0f32; b * n];
        let mut win = vec![0.0f32; b * n];
        let mut cap = vec![1.0f32; b]; // harmless nonzero for padded configs

        for (k, case) in cases.iter().enumerate() {
            let m = &case.machine;
            assert!(case.workloads.len() <= n, "artifact n_cores too small");
            cap[k] = m.capacity_lines_per_cy() as f32;
            let q = &m.queue;
            for (i, w) in case.workloads.iter().enumerate() {
                d[k * n + i] = w.demand_lines_per_cy as f32;
                c[k * n + i] = w.cost_factor as f32;
                win[k * n + i] =
                    (q.depth_floor + q.depth_beta * w.demand_lines_per_cy * w.cost_factor * q.base_latency_cy)
                        as f32;
            }
        }

        let bn = [b as i64, n as i64];
        let b1 = [b as i64, 1i64];
        let outputs = self.exe.run_f32(&[
            (&d, &bn[..]),
            (&c, &bn[..]),
            (&win, &bn[..]),
            (&cap, &b1[..]),
        ])?;
        let served = &outputs[0];

        let cycles = self.meta.measure_cycles as f64;
        Ok(cases
            .iter()
            .enumerate()
            .map(|(k, case)| {
                case.workloads
                    .iter()
                    .enumerate()
                    .map(|(i, _)| {
                        let lines_per_cy = served[k * n + i] as f64 / cycles;
                        case.machine.lines_per_cy_to_gbs(lines_per_cy)
                    })
                    .collect()
            })
            .collect())
    }
}
