//! Conformance properties of the placement optimizer
//! (`membw::optimizer`), end to end through the public API:
//!
//! * the search winner is never worse than the deterministic compact /
//!   scatter starts or a fully hand-pinned placement,
//! * incremental delta re-rating is bit-identical to a full
//!   `share_remote` re-solve along randomized move sequences,
//! * a fixed seed gives an identical incumbent trace, independent of the
//!   delta / parallel / memo fast paths.

use std::collections::HashMap;

use membw::config::{machine, MachineId};
use membw::kernels::KernelId;
use membw::optimizer::{optimize, DeltaEval, SearchConfig, SearchSpace};
use membw::scenario::{CharCache, CharSource, Mix};
use membw::sharing::share_remote;
use membw::simulator::XorShift64;
use membw::topology::Topology;

/// ECM-characterized `(f, b_s)` per kernel of a mix, the same source the
/// CLI uses.
fn chars_of(topo: &Topology, mix: &Mix) -> HashMap<KernelId, (f64, f64)> {
    let mut kernels: Vec<KernelId> = mix.groups.iter().map(|g| g.kernel).collect();
    kernels.sort_by_key(|k| k.key());
    kernels.dedup();
    let meas = CharCache::global()
        .characterize_source(&topo.base, &kernels, &CharSource::Ecm)
        .expect("ECM characterization");
    meas.iter().map(|(&k, c)| (k, (c.f, c.bs_gbs))).collect()
}

/// Full-model throughput score of a candidate, the Objective::Throughput
/// formula recomputed independently: `Σ n_g · rate_g`.
fn full_score(space: &SearchSpace, cand: &membw::optimizer::Candidate) -> f64 {
    let share = share_remote(&space.shape, &space.remote_groups(cand)).expect("full solve");
    share
        .per_core_gbs
        .iter()
        .zip(&space.groups)
        .map(|(r, g)| g.n as f64 * r)
        .sum()
}

#[test]
fn winner_is_never_worse_than_compact_scatter_or_pinned_baselines() {
    let m = machine(MachineId::Rome);
    let topo = Topology::parse(&m, "2x2").unwrap();
    let mix = Mix::parse("dcopy:8+ddot2:8+stream:8+daxpy:8").unwrap();
    let space = SearchSpace::from_mix(&topo, &mix, &chars_of(&topo, &mix)).unwrap();
    let cfg = SearchConfig { budget: 400, starts: 3, ..SearchConfig::default() };
    let result = optimize(&space, &cfg).unwrap();

    let compact = space.start_compact().unwrap();
    let scatter = space.start_scatter().unwrap();
    for (name, base) in [("compact", &compact), ("scatter", &scatter)] {
        let s = full_score(&space, base);
        assert!(
            result.best_score >= s - 1e-9,
            "winner {} must be >= the {name} start {s} ({})",
            result.best_score,
            space.label(base),
        );
    }

    // A fully hand-pinned placement (one group per domain) is also a
    // feasible point of the same space, so the winner must cover it too.
    let pinned_mix = Mix::parse("dcopy:8@d0+ddot2:8@d1+stream:8@d2+daxpy:8@d3").unwrap();
    let pinned_space =
        SearchSpace::from_mix(&topo, &pinned_mix, &chars_of(&topo, &pinned_mix)).unwrap();
    let pinned = pinned_space.start_compact().unwrap();
    assert_eq!(pinned.home, vec![0, 1, 2, 3], "pins must be honored");
    let s = full_score(&space, &pinned);
    assert!(
        result.best_score >= s - 1e-9,
        "winner {} must be >= the pinned placement {s}",
        result.best_score,
    );
}

#[test]
fn delta_re_rating_is_bit_identical_to_full_solves_on_random_walks() {
    let m = machine(MachineId::Rome);
    let topo = Topology::parse(&m, "2x4").unwrap();
    // One group with a frozen remote fraction so cross-socket link
    // interfaces carry traffic from the first step on.
    let mix = Mix::parse("dcopy:8%r0.25+ddot2:8+stream:8+daxpy:8+vecsum:8").unwrap();
    let space = SearchSpace::from_mix(&topo, &mix, &chars_of(&topo, &mix)).unwrap();

    for seed in [1u64, 7, 0xC0FFEE] {
        let mut rng = XorShift64::new(seed);
        let mut cand = space.start_compact().unwrap();
        let mut de =
            DeltaEval::new(space.shape.clone(), space.remote_groups(&cand)).unwrap();
        for step in 0..40 {
            let moves = space.neighbors(&cand);
            assert!(!moves.is_empty(), "the neighborhood must not be empty");
            let mv = moves[rng.next_below(moves.len())];
            let next = cand.apply(mv);
            let out = de.eval(&space.changes(&cand, &next)).unwrap();
            let full = share_remote(&space.shape, &space.remote_groups(&next)).unwrap();
            for (gi, (a, b)) in out.rates.iter().zip(&full.per_core_gbs).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "seed {seed} step {step} group {gi}: delta {a} != full {b} for {:?}",
                    mv,
                );
            }
            de.commit(out);
            cand = next;
        }
    }
}

#[test]
fn fixed_seed_traces_are_identical_across_fast_paths() {
    let m = machine(MachineId::Rome);
    let topo = Topology::parse(&m, "2x2").unwrap();
    let mix = Mix::parse("dcopy:8+ddot2:8+stream:8+daxpy:8").unwrap();
    let space = SearchSpace::from_mix(&topo, &mix, &chars_of(&topo, &mix)).unwrap();
    let cfg = SearchConfig { budget: 250, starts: 4, ..SearchConfig::default() };

    let reference = optimize(&space, &cfg).unwrap();
    let rerun = optimize(&space, &cfg).unwrap();
    let serial_full = optimize(
        &space,
        &SearchConfig { parallel: false, use_delta: false, memoize: false, ..cfg },
    )
    .unwrap();

    for (tag, other) in [("rerun", &rerun), ("serial full re-solve", &serial_full)] {
        assert_eq!(reference.best, other.best, "{tag}: winner differs");
        assert_eq!(
            reference.best_score.to_bits(),
            other.best_score.to_bits(),
            "{tag}: best score differs"
        );
        assert_eq!(reference.scored, other.scored, "{tag}: scored count differs");
        assert_eq!(reference.trace.len(), other.trace.len(), "{tag}: trace length differs");
        for (a, b) in reference.trace.iter().zip(&other.trace) {
            assert_eq!(a.candidate, b.candidate, "{tag}: incumbent differs");
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "{tag}: incumbent score differs");
            assert_eq!(
                (a.scored_at, a.start, a.step),
                (b.scored_at, b.start, b.step),
                "{tag}: incumbent position differs"
            );
        }
    }
}
