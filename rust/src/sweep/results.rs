//! Result records: measured vs modeled bandwidth per case, with CSV and
//! JSON-lines emission (hand-rolled — the build is offline).

use std::io::Write;
use std::path::Path;

use crate::config::MachineId;
use crate::error::Result;
use crate::kernels::KernelId;
use crate::stats::rel_error;

/// Outcome of one pairing case: measurement + model prediction.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// Machine the case ran on.
    pub machine: MachineId,
    /// Kernels of the pairing.
    pub kernels: [KernelId; 2],
    /// Threads per group.
    pub n: [usize; 2],
    /// Measured (simulated) per-core bandwidth per group, GB/s.
    pub measured_per_core: [f64; 2],
    /// Analytic-model per-core bandwidth per group, GB/s.
    pub model_per_core: [f64; 2],
    /// Measured aggregate bandwidth, GB/s.
    pub measured_total: f64,
    /// Modeled aggregate bandwidth, GB/s.
    pub model_total: f64,
}

impl CaseResult {
    /// Relative per-core model errors per group (paper Fig. 8 metric).
    pub fn errors(&self) -> [f64; 2] {
        [
            rel_error(self.measured_per_core[0], self.model_per_core[0]),
            rel_error(self.measured_per_core[1], self.model_per_core[1]),
        ]
    }

    /// CSV header matching [`CaseResult::to_csv_row`].
    pub fn csv_header() -> &'static str {
        "machine,kernel1,kernel2,n1,n2,meas_pc1_gbs,meas_pc2_gbs,model_pc1_gbs,model_pc2_gbs,meas_total_gbs,model_total_gbs,err1,err2"
    }

    /// One CSV row.
    pub fn to_csv_row(&self) -> String {
        let e = self.errors();
        format!(
            "{},{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{:.5},{:.5}",
            self.machine.key(),
            self.kernels[0].key(),
            self.kernels[1].key(),
            self.n[0],
            self.n[1],
            self.measured_per_core[0],
            self.measured_per_core[1],
            self.model_per_core[0],
            self.model_per_core[1],
            self.measured_total,
            self.model_total,
            e[0],
            e[1],
        )
    }

    /// One JSON object (hand-rolled; all fields are numbers/short strings).
    pub fn to_json(&self) -> String {
        let e = self.errors();
        format!(
            "{{\"machine\":\"{}\",\"kernel1\":\"{}\",\"kernel2\":\"{}\",\"n1\":{},\"n2\":{},\
             \"meas_pc\":[{:.5},{:.5}],\"model_pc\":[{:.5},{:.5}],\
             \"meas_total\":{:.5},\"model_total\":{:.5},\"err\":[{:.6},{:.6}]}}",
            self.machine.key(),
            self.kernels[0].key(),
            self.kernels[1].key(),
            self.n[0],
            self.n[1],
            self.measured_per_core[0],
            self.measured_per_core[1],
            self.model_per_core[0],
            self.model_per_core[1],
            self.measured_total,
            self.model_total,
            e[0],
            e[1],
        )
    }
}

/// A set of case results with persistence helpers.
#[derive(Debug, Clone, Default)]
pub struct ResultSet {
    /// All case results, in plan order.
    pub cases: Vec<CaseResult>,
}

impl ResultSet {
    /// All per-group relative errors, flattened (Fig. 8 input).
    pub fn all_errors(&self) -> Vec<f64> {
        self.cases.iter().flat_map(|c| c.errors()).collect()
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", CaseResult::csv_header())?;
        for c in &self.cases {
            writeln!(f, "{}", c.to_csv_row())?;
        }
        Ok(())
    }

    /// Write as JSON lines.
    pub fn write_jsonl(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        for c in &self.cases {
            writeln!(f, "{}", c.to_json())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case() -> CaseResult {
        CaseResult {
            machine: MachineId::Bdw1,
            kernels: [KernelId::Dcopy, KernelId::Ddot2],
            n: [6, 4],
            measured_per_core: [6.29, 5.00],
            model_per_core: [6.44, 5.09],
            measured_total: 57.7,
            model_total: 59.0,
        }
    }

    #[test]
    fn errors_match_paper_definition() {
        let c = case();
        let e = c.errors();
        assert!((e[0] - (6.44 - 6.29) / 6.44).abs() < 1e-12);
    }

    #[test]
    fn csv_row_has_header_arity() {
        let c = case();
        assert_eq!(
            c.to_csv_row().split(',').count(),
            CaseResult::csv_header().split(',').count()
        );
    }

    #[test]
    fn json_is_wellformed_enough() {
        let j = case().to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(j.contains("\"machine\":\"bdw1\""));
    }

    #[test]
    fn files_roundtrip() {
        let dir = std::env::temp_dir().join("membw-results-test");
        let set = ResultSet { cases: vec![case(), case()] };
        set.write_csv(&dir.join("r.csv")).unwrap();
        set.write_jsonl(&dir.join("r.jsonl")).unwrap();
        let csv = std::fs::read_to_string(dir.join("r.csv")).unwrap();
        assert_eq!(csv.lines().count(), 3);
    }
}
