//! HPCG desynchronization demo — the paper's motivating observation
//! (Sect. I-A, Figs. 1 and 3) as a co-simulation.
//!
//! Runs the plain HPCG variant (with MPI_Allreduce) and the modified one
//! (reductions removed), renders timelines, and prints the skewness
//! analysis that distinguishes resynchronizing from desynchronizing
//! kernels.
//!
//! ```bash
//! cargo run --release --example hpcg_desync
//! ```

use membw::config::{machine, MachineId};
use membw::desync::{hpcg_program, CoSimConfig, CoSimEngine, HpcgVariant, NoiseModel};
use membw::sharing::{predict_skew, OverlapPartner, SkewPrediction};
use membw::stats::skewness_dimensioned;

fn main() {
    let m = machine(MachineId::Clx);
    let ranks = m.cores;
    let cfg = CoSimConfig {
        dt_s: 20e-6,
        t_max_s: 600.0,
        initial_stagger_s: 0.2e-3,
        neighbor_radius: 3,
        noise: NoiseModel::mild(7),
    };

    for variant in [HpcgVariant::Plain, HpcgVariant::Modified] {
        println!("=== HPCG {variant:?} on {} ({ranks} ranks) ===", m.name);
        let prog = hpcg_program(variant, 96, 3);
        let eng = CoSimEngine::new(&m, prog, ranks, cfg.clone()).expect("engine");
        // Event-driven timeline engine: exact (zero dt error), resolves the
        // run in a few thousand events instead of ~10^5 time steps.
        let t0 = std::time::Instant::now();
        let r = eng.run();
        println!(
            "  {} events, {} phase records, {:.1} ms wall",
            r.events,
            r.trace.records.len(),
            t0.elapsed().as_secs_f64() * 1e3
        );

        // Timeline around the DDOT2 of the middle iteration.
        if let Some(rec) = r.trace.of("DDOT2#1", Some(1)).first() {
            let t0 = rec.t_start - 0.005;
            println!("{}", r.trace.render_ascii(t0, t0 + 0.04, ranks, 100));
        }

        // Per-kernel skewness (Fig. 3 analysis).
        println!("\n  accumulated-time skewness (ms), iteration 1:");
        for label in ["DDOT2#1", "DDOT2#2", "DDOT1"] {
            let durs = r.trace.durations_by_rank(label, 1, ranks);
            let skew = skewness_dimensioned(&durs.iter().map(|d| d * 1e3).collect::<Vec<_>>());
            println!("    {label:8}: {skew:+.3} ms");
        }
        println!();
    }

    // Close the loop: the model's qualitative prediction (Sect. V).
    println!("model prediction (Sect. V): sandwich a kernel between phases and ask");
    let f_ddot2 = membw::ecm::predict(&membw::kernels::kernel(membw::kernels::KernelId::Ddot2), &m).f;
    let f_daxpy = membw::ecm::predict(&membw::kernels::kernel(membw::kernels::KernelId::Daxpy), &m).f;
    let p1 = predict_skew(f_ddot2, OverlapPartner::Idle);
    let p2 = predict_skew(f_ddot2, OverlapPartner::Kernel { f: f_daxpy });
    assert_eq!(p1, SkewPrediction::Resynchronize);
    assert_eq!(p2, SkewPrediction::Desynchronize);
    println!("  DDOT2 → halo wait (idle)      : {p1:?}  (negative skew)");
    println!("  DDOT2 → DAXPY (f {f_daxpy:.3} > {f_ddot2:.3}): {p2:?} (positive skew)");
}
