//! Report surface of the placement optimizer: incumbent trace, winner
//! share tables, and the search-throughput / cache counters that make
//! `repro optimize` runs comparable.

use std::fmt::Write as _;

use crate::error::Result;
use crate::optimizer::{OptResult, SearchConfig, SearchSpace};
use crate::report::experiments::ExperimentCtx;
use crate::report::table::AsciiTable;
use crate::topology::Topology;

/// Render one search result: the configuration, the incumbent trace, the
/// winner's per-group and per-interface share tables, and the
/// evaluations/s + cache-counter footer. Also writes
/// `optimizer_<topology>.csv` (trace + winner rows) under the context's
/// output directory.
pub fn optimizer_report(
    ctx: &ExperimentCtx,
    topo: &Topology,
    space: &SearchSpace,
    cfg: &SearchConfig,
    result: &OptResult,
) -> Result<String> {
    let mut out = String::new();
    writeln!(
        out,
        "OPTIMIZE on {} — objective {}, {} groups, {} starts, beam {}, budget {}, seed {}",
        topo.label(),
        cfg.objective.name(),
        space.k(),
        cfg.starts,
        cfg.beam,
        cfg.budget,
        cfg.seed
    )
    .unwrap();

    writeln!(out, "\nincumbent trace ({} improvements):", result.trace.len()).unwrap();
    let mut tt = AsciiTable::new(&["scored", "start", "step", "score", "candidate"]);
    for step in &result.trace {
        tt.row(vec![
            step.scored_at.to_string(),
            step.start.to_string(),
            step.step.to_string(),
            format!("{:.3}", step.score),
            step.label.clone(),
        ]);
    }
    out.push_str(&tt.render());

    writeln!(out, "\nwinner: {}   score {:.3}", result.best_label, result.best_score).unwrap();
    if let Some(m) = result.makespan_s {
        writeln!(out, "simulated makespan: {m:.3} s").unwrap();
    }
    let mut wt = AsciiTable::new(&["group", "kernel", "n", "home", "%r", "rate/core", "agg GB/s"]);
    for (gi, g) in space.groups.iter().enumerate() {
        wt.row(vec![
            gi.to_string(),
            g.name.clone(),
            g.n.to_string(),
            format!("d{}", result.best.home[gi]),
            format!("{:.2}", result.best.remote_ppm[gi] as f64 / 1e6),
            format!("{:.2}", result.best_rates[gi]),
            format!("{:.1}", result.share.group_bw_gbs[gi]),
        ]);
    }
    out.push_str(&wt.render());

    let mut dt = AsciiTable::new(&["iface", "b_mix GB/s", "demand GB/s", "state"]);
    for (d, iface) in result.share.domains.iter().enumerate() {
        dt.row(vec![
            format!("d{d}"),
            format!("{:.1}", iface.b_mix_gbs),
            format!("{:.1}", iface.demand_gbs),
            if iface.saturated { "saturated" } else { "nonsaturated" }.to_string(),
        ]);
    }
    for (li, link) in space.shape.links().iter().zip(&result.share.links) {
        if link.demand_gbs <= 0.0 {
            continue;
        }
        dt.row(vec![
            format!("s{}->s{}", li.0, li.1),
            format!("{:.1}", link.b_mix_gbs),
            format!("{:.1}", link.demand_gbs),
            if link.saturated { "saturated" } else { "nonsaturated" }.to_string(),
        ]);
    }
    out.push_str("winner interfaces:\n");
    out.push_str(&dt.render());

    let evals_per_s = result.scored as f64 / result.wall_s.max(1e-12);
    writeln!(
        out,
        "\nsearch: {} scored ({} evaluated) in {:.3} s — {:.0} evaluations/s",
        result.scored, result.evaluated, result.wall_s, evals_per_s
    )
    .unwrap();
    writeln!(
        out,
        "delta: {} evals, {} interfaces re-rated, {} reused ({:.1}% saved), {} full solves",
        result.delta.evals,
        result.delta.iface_evals,
        result.delta.iface_reused,
        100.0 * result.delta.iface_reused as f64
            / (result.delta.iface_evals + result.delta.iface_reused).max(1) as f64,
        result.delta.full_solves
    )
    .unwrap();
    writeln!(
        out,
        "score memo: {} hits, {} misses, {} entries",
        result.stats.memo_hits, result.stats.memo_misses, result.stats.memo_entries
    )
    .unwrap();

    std::fs::create_dir_all(&ctx.out_dir)?;
    let mut csv = String::from("kind,index,start,step,score,home,remote_frac,rate_per_core\n");
    for step in &result.trace {
        writeln!(
            csv,
            "trace,{},{},{},{},,,",
            step.scored_at, step.start, step.step, step.score
        )
        .unwrap();
    }
    for gi in 0..space.k() {
        writeln!(
            csv,
            "winner,{gi},,,{},{},{},{}",
            result.best_score,
            result.best.home[gi],
            result.best.remote_ppm[gi] as f64 / 1e6,
            result.best_rates[gi]
        )
        .unwrap();
    }
    std::fs::write(ctx.out_dir.join(format!("optimizer_{}.csv", topo.label())), csv)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::KernelId;
    use crate::optimizer::optimize;
    use crate::scenario::Mix;
    use std::collections::HashMap;

    #[test]
    fn report_renders_and_writes_csv() {
        let dir = std::env::temp_dir().join("membw-optimizer-report");
        let ctx = ExperimentCtx::fluid(dir.clone());
        let m = machine(MachineId::Rome);
        let topo = Topology::parse(&m, "2x2").unwrap();
        let mix = Mix::parse("dcopy:16+ddot2:16").unwrap();
        let chars: HashMap<KernelId, (f64, f64)> = [
            (KernelId::Dcopy, (0.85, 30.0)),
            (KernelId::Ddot2, (0.7, 28.0)),
        ]
        .into_iter()
        .collect();
        let space = SearchSpace::from_mix(&topo, &mix, &chars).unwrap();
        let cfg = SearchConfig { budget: 120, starts: 2, ..SearchConfig::default() };
        let result = optimize(&space, &cfg).unwrap();
        let text = optimizer_report(&ctx, &topo, &space, &cfg, &result).unwrap();
        assert!(text.contains("OPTIMIZE on"), "{text}");
        assert!(text.contains("incumbent trace"));
        assert!(text.contains("winner:"));
        assert!(text.contains("evaluations/s"));
        assert!(text.contains("score memo:"));
        let csv =
            std::fs::read_to_string(dir.join(format!("optimizer_{}.csv", topo.label()))).unwrap();
        assert!(csv.starts_with("kind,index"));
        assert!(csv.contains("winner,0"));
    }
}
