//! Shared kernel-characterization cache.
//!
//! Every measurement pipeline needs the Eq.-3 characterization (solo +
//! full-domain run → `b_1`, `b_s`, `f`) of each kernel it touches, measured
//! with the same engine as the pairing/mix measurements. Characterizations
//! are deterministic per (machine row, kernel, engine), so a process-wide
//! cache is safe; it removes the dominant redundant work from multi-call
//! sweeps (the Fig. 8/9 reports regenerate hundreds of `run_cases` calls).
//!
//! The machine component of the key is a **full fingerprint**
//! ([`MachineFingerprint`]: registry id, cores, read/theoretical bandwidth
//! bits, link-table hash, and a fold of the clock/ECM/queue calibration
//! fields), not the bare [`crate::config::MachineId`] —
//! derived rows (SNC sub-domains, DIMM-scaled topology domains) share
//! their parent's id but have different physics, and must characterize
//! independently (pinned by the id-collision regression test below).
//!
//! The cache is thread-safe (sweeps run batched and parallel) and exposes
//! hit/miss statistics so tests can pin its behaviour. Use
//! [`CharCache::global`] for the shared instance or [`CharCache::new`] for
//! an isolated one (tests).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::config::{Machine, MachineFingerprint};
use crate::error::Result;
use crate::kernels::{kernel, KernelId};
use crate::runtime::SimCase;
use crate::scenario::runner::MeasureEngine;
use crate::simulator::{measure_f_bs, CoreWorkload, KernelMeasurement};

/// Which engine produced a characterization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Analytic ECM prediction (no measurement; the paper's model route).
    Ecm,
    /// In-process fluid simulator.
    Fluid,
    /// In-process discrete-event simulator.
    Des,
    /// AOT JAX/Pallas artifact via PJRT, tagged with a hash of the artifact
    /// source path so characterizations from different bundles loaded in the
    /// same process never alias in the global cache.
    Pjrt(u64),
}

/// Where kernel characterizations come from — the analytic ECM route or an
/// Eq.-3 measurement on one of the scenario engines. Both are served
/// through the same [`CharCache`], so co-simulations and measurement
/// pipelines share entries process-wide.
pub enum CharSource<'a> {
    /// ECM prediction: `f` from Eq. 2, `b_s` from the machine model.
    Ecm,
    /// Eq.-3 measurement (solo + full-domain run) on a scenario engine.
    Measured(MeasureEngine<'a>),
}

impl CharSource<'_> {
    /// Cache keying kind.
    pub fn kind(&self) -> EngineKind {
        match self {
            CharSource::Ecm => EngineKind::Ecm,
            CharSource::Measured(e) => e.kind(),
        }
    }

    /// Short display name.
    pub fn name(&self) -> &'static str {
        match self {
            CharSource::Ecm => "ecm",
            CharSource::Measured(e) => e.name(),
        }
    }
}

/// Cache key: one characterization per (machine fingerprint, kernel,
/// engine). The fingerprint — not the bare id — keeps derived machine rows
/// (SNC sub-domains, scaled topology domains) from aliasing their parent's
/// entries; build it with [`Machine::fingerprint`].
pub type CharKey = (MachineFingerprint, KernelId, EngineKind);

/// Snapshot of cache counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to measure.
    pub misses: u64,
    /// Entries currently stored.
    pub entries: usize,
}

/// Thread-safe characterization cache with hit/miss accounting.
#[derive(Default)]
pub struct CharCache {
    map: Mutex<HashMap<CharKey, KernelMeasurement>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CharCache {
    /// An empty, isolated cache.
    pub fn new() -> Self {
        CharCache::default()
    }

    /// The process-wide shared cache.
    pub fn global() -> &'static CharCache {
        static GLOBAL: OnceLock<CharCache> = OnceLock::new();
        GLOBAL.get_or_init(CharCache::new)
    }

    /// Look up one characterization, counting a hit or miss.
    pub fn lookup(&self, key: &CharKey) -> Option<KernelMeasurement> {
        let found = self.map.lock().unwrap().get(key).copied();
        match found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Store one characterization.
    pub fn insert(&self, key: CharKey, m: KernelMeasurement) {
        self.map.lock().unwrap().insert(key, m);
    }

    /// Whether a key is cached (does not count as a hit or miss).
    pub fn contains(&self, key: &CharKey) -> bool {
        self.map.lock().unwrap().contains_key(key)
    }

    /// Counter + size snapshot.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.lock().unwrap().len(),
        }
    }

    /// Drop all entries and reset the counters.
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    /// Characterize every kernel in `kernels` on `machine` from `source`
    /// (analytic ECM or a measurement engine), serving cached entries and
    /// computing only the missing ones.
    pub fn characterize_source(
        &self,
        machine: &Machine,
        kernels: &[KernelId],
        source: &CharSource,
    ) -> Result<HashMap<KernelId, KernelMeasurement>> {
        match source {
            CharSource::Measured(engine) => self.characterize(machine, kernels, engine),
            CharSource::Ecm => {
                let mut out = HashMap::new();
                for &k in kernels {
                    let key = (machine.fingerprint(), k, EngineKind::Ecm);
                    let m = match self.lookup(&key) {
                        Some(m) => m,
                        None => {
                            let p = crate::ecm::predict(&kernel(k), machine);
                            let m = KernelMeasurement {
                                b1_gbs: p.b1_gbs,
                                bs_gbs: p.bs_gbs,
                                f: p.f,
                            };
                            self.insert(key, m);
                            m
                        }
                    };
                    out.insert(k, m);
                }
                Ok(out)
            }
        }
    }

    /// Characterize every kernel in `kernels` on `machine` with `engine`
    /// (Eq. 3: solo + full domain), serving cached entries and measuring —
    /// batched, for the PJRT engine — only the missing ones.
    pub fn characterize(
        &self,
        machine: &Machine,
        kernels: &[KernelId],
        engine: &MeasureEngine,
    ) -> Result<HashMap<KernelId, KernelMeasurement>> {
        let kind = engine.kind();
        let fp = machine.fingerprint();
        let mut out = HashMap::new();
        let mut missing: Vec<KernelId> = Vec::new();
        for &k in kernels {
            match self.lookup(&(fp, k, kind)) {
                Some(m) => {
                    out.insert(k, m);
                }
                None => missing.push(k),
            }
        }
        if missing.is_empty() {
            return Ok(out);
        }
        match engine {
            MeasureEngine::Pjrt(exec) => {
                // Two configs per kernel: 1 core and the full domain, all in
                // one batched dispatch.
                let mut cases = Vec::new();
                for &k in &missing {
                    let w = CoreWorkload::from_kernel(&kernel(k), machine, 0);
                    cases.push(SimCase { machine: machine.clone(), workloads: vec![w] });
                    cases.push(SimCase {
                        machine: machine.clone(),
                        workloads: vec![w; machine.cores],
                    });
                }
                let bw = exec.run(&cases)?;
                for (i, &k) in missing.iter().enumerate() {
                    let b1 = bw[2 * i][0];
                    let bs: f64 = bw[2 * i + 1].iter().sum();
                    out.insert(k, KernelMeasurement { b1_gbs: b1, bs_gbs: bs, f: b1 / bs });
                }
            }
            _ => {
                let eng = engine.inproc().expect("non-PJRT engines are in-process");
                for &k in &missing {
                    out.insert(k, measure_f_bs(&kernel(k), machine, eng));
                }
            }
        }
        for &k in &missing {
            self.insert((fp, k, kind), out[&k]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};

    fn rome() -> Machine {
        machine(MachineId::Rome)
    }

    #[test]
    fn miss_then_hit_on_isolated_cache() {
        let cache = CharCache::new();
        let m = rome();
        let ks = [KernelId::Dcopy, KernelId::Ddot2];
        let first = cache.characterize(&m, &ks, &MeasureEngine::Fluid).unwrap();
        let s1 = cache.stats();
        assert_eq!(s1.misses, 2);
        assert_eq!(s1.hits, 0);
        assert_eq!(s1.entries, 2);

        let second = cache.characterize(&m, &ks, &MeasureEngine::Fluid).unwrap();
        let s2 = cache.stats();
        assert_eq!(s2.misses, 2, "no re-measurement");
        assert_eq!(s2.hits, 2);
        assert_eq!(s2.entries, 2);
        for k in ks {
            assert_eq!(first[&k].b1_gbs, second[&k].b1_gbs);
            assert_eq!(first[&k].bs_gbs, second[&k].bs_gbs);
            assert_eq!(first[&k].f, second[&k].f);
        }
    }

    #[test]
    fn engines_are_cached_separately() {
        let cache = CharCache::new();
        let m = rome();
        let ks = [KernelId::Ddot2];
        cache.characterize(&m, &ks, &MeasureEngine::Fluid).unwrap();
        assert!(cache.contains(&(m.fingerprint(), KernelId::Ddot2, EngineKind::Fluid)));
        assert!(!cache.contains(&(m.fingerprint(), KernelId::Ddot2, EngineKind::Des)));
        cache.characterize(&m, &ks, &MeasureEngine::Des).unwrap();
        let s = cache.stats();
        assert_eq!(s.entries, 2, "fluid and des entries are distinct");
        assert_eq!(s.misses, 2);
    }

    #[test]
    fn characterization_is_deterministic_per_engine() {
        let m = rome();
        for engine in [MeasureEngine::Fluid, MeasureEngine::Des] {
            let a = CharCache::new().characterize(&m, &[KernelId::Daxpy], &engine).unwrap();
            let b = CharCache::new().characterize(&m, &[KernelId::Daxpy], &engine).unwrap();
            assert_eq!(a[&KernelId::Daxpy].b1_gbs.to_bits(), b[&KernelId::Daxpy].b1_gbs.to_bits());
            assert_eq!(a[&KernelId::Daxpy].bs_gbs.to_bits(), b[&KernelId::Daxpy].bs_gbs.to_bits());
        }
    }

    #[test]
    fn ecm_source_is_cached_and_matches_prediction() {
        let cache = CharCache::new();
        let m = rome();
        let ks = [KernelId::Ddot2, KernelId::Daxpy];
        let out = cache.characterize_source(&m, &ks, &CharSource::Ecm).unwrap();
        assert_eq!(cache.stats().misses, 2);
        for k in ks {
            let p = crate::ecm::predict(&kernel(k), &m);
            assert_eq!(out[&k].f.to_bits(), p.f.to_bits());
            assert_eq!(out[&k].bs_gbs.to_bits(), p.bs_gbs.to_bits());
            assert_eq!(out[&k].b1_gbs.to_bits(), p.b1_gbs.to_bits());
        }
        let again = cache.characterize_source(&m, &ks, &CharSource::Ecm).unwrap();
        let s = cache.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 2);
        assert_eq!(again[&KernelId::Ddot2].f.to_bits(), out[&KernelId::Ddot2].f.to_bits());
        // ECM entries never alias measured ones.
        assert!(cache.contains(&(m.fingerprint(), KernelId::Ddot2, EngineKind::Ecm)));
        assert!(!cache.contains(&(m.fingerprint(), KernelId::Ddot2, EngineKind::Fluid)));
    }

    #[test]
    fn measured_source_delegates_to_engine_characterization() {
        let cache = CharCache::new();
        let m = rome();
        let via_source = cache
            .characterize_source(&m, &[KernelId::Dcopy], &CharSource::Measured(MeasureEngine::Fluid))
            .unwrap();
        let direct = cache.characterize(&m, &[KernelId::Dcopy], &MeasureEngine::Fluid).unwrap();
        assert_eq!(
            via_source[&KernelId::Dcopy].f.to_bits(),
            direct[&KernelId::Dcopy].f.to_bits()
        );
        assert_eq!(cache.stats().entries, 1, "one shared entry");
    }

    /// Regression for the pre-fingerprint id-collision: two rows with the
    /// same `MachineId` but different bandwidths (an SNC half-socket next
    /// to its parent socket) must characterize independently — the old
    /// bare-id key served the socket's f/b_s to the derived row.
    #[test]
    fn derived_rows_with_equal_id_characterize_independently() {
        let cache = CharCache::new();
        let m = rome();
        let mut derived = m.clone();
        derived.cores /= 2;
        derived.read_bw_gbs /= 2.0;
        derived.theor_bw_gbs /= 2.0;
        assert_eq!(m.id, derived.id, "precondition: ids collide");
        assert_ne!(m.fingerprint(), derived.fingerprint());
        let a = cache.characterize(&m, &[KernelId::Dcopy], &MeasureEngine::Fluid).unwrap();
        let b = cache.characterize(&derived, &[KernelId::Dcopy], &MeasureEngine::Fluid).unwrap();
        let s = cache.stats();
        assert_eq!(s.entries, 2, "one entry per fingerprint, no aliasing");
        assert_eq!(s.misses, 2, "the derived row is measured, not served stale");
        // The halved row's saturated bandwidth is really about half.
        let (bs_full, bs_half) = (a[&KernelId::Dcopy].bs_gbs, b[&KernelId::Dcopy].bs_gbs);
        assert!(
            bs_half < 0.6 * bs_full && bs_half > 0.4 * bs_full,
            "derived b_s {bs_half} vs parent {bs_full}"
        );
        // Scaled link parameters change the fingerprint too (link table).
        let mut relinked = m.clone();
        relinked.link_bw_gbs *= 2.0;
        assert_ne!(m.fingerprint(), relinked.fingerprint());
    }

    #[test]
    fn clear_resets_counters_and_entries() {
        let cache = CharCache::new();
        let m = rome();
        cache.characterize(&m, &[KernelId::Dcopy], &MeasureEngine::Fluid).unwrap();
        cache.clear();
        assert_eq!(cache.stats(), CacheStats::default());
    }

    #[test]
    fn concurrent_characterize_is_safe_and_consistent() {
        let cache = CharCache::new();
        let m = rome();
        let ks = [KernelId::Dcopy, KernelId::Ddot2, KernelId::Stream];
        let results: Vec<HashMap<KernelId, KernelMeasurement>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| scope.spawn(|| cache.characterize(&m, &ks, &MeasureEngine::Fluid).unwrap()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &results[1..] {
            for k in ks {
                assert_eq!(r[&k].f.to_bits(), results[0][&k].f.to_bits());
            }
        }
        let s = cache.stats();
        assert_eq!(s.entries, 3);
        assert_eq!(s.hits + s.misses, 8 * 3);
        // At least one thread measured each kernel; duplicated measurement
        // under the race is permitted (last write wins, values identical).
        assert!(s.misses >= 3);
    }
}
