//! Crate-wide error type (hand-rolled — the offline build has no external
//! error-derive crate).

use std::fmt;

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes of the coordinator.
#[derive(Debug)]
pub enum Error {
    /// An unknown machine id was requested from the registry.
    UnknownMachine(String, String),

    /// An unknown kernel name was requested from the registry.
    UnknownKernel(String, String),

    /// A configuration file failed to parse.
    Config {
        /// Path of the offending file.
        path: String,
        /// What went wrong.
        msg: String,
    },

    /// An experiment plan is inconsistent (e.g. thread counts exceed domain).
    InvalidPlan(String),

    /// A workload-mix / scenario spec failed to parse. Carries the full
    /// spec, the byte offset of the offending token, what the parser
    /// expected there, and what it found instead.
    MixParse {
        /// The complete spec string handed to the parser.
        spec: String,
        /// Byte offset of the offending token within `spec`.
        pos: usize,
        /// Expected token class (e.g. "core count").
        expected: String,
        /// The offending token (empty if the spec ended early).
        found: String,
    },

    /// The PJRT runtime failed (client creation, artifact load, execution).
    Runtime(String),

    /// An AOT artifact is missing — run `make artifacts` first.
    MissingArtifact(String),

    /// A simulation failed to converge to steady state.
    NoSteadyState(String),

    /// Any I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::UnknownMachine(name, known) => {
                write!(f, "unknown machine '{name}' (known: {known})")
            }
            Error::UnknownKernel(name, known) => {
                write!(f, "unknown kernel '{name}' (known: {known})")
            }
            Error::Config { path, msg } => write!(f, "config error in {path}: {msg}"),
            Error::InvalidPlan(msg) => write!(f, "invalid plan: {msg}"),
            Error::MixParse { spec, pos, expected, found } => {
                let found = if found.is_empty() { "end of input" } else { found.as_str() };
                write!(
                    f,
                    "mix parse error at byte {pos} of '{spec}': expected {expected}, found {found}"
                )
            }
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::MissingArtifact(path) => {
                write!(f, "artifact not found: {path} (run `make artifacts`)")
            }
            Error::NoSteadyState(msg) => {
                write!(f, "simulation did not reach steady state: {msg}")
            }
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Convenience constructor for runtime errors from the `xla` crate.
    pub fn runtime<E: std::fmt::Display>(e: E) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_parse_error_carries_position_and_expectation() {
        let e = Error::MixParse {
            spec: "dcopy:".into(),
            pos: 6,
            expected: "core count".into(),
            found: String::new(),
        };
        let msg = e.to_string();
        assert!(msg.contains("byte 6"), "{msg}");
        assert!(msg.contains("core count"), "{msg}");
        assert!(msg.contains("end of input"), "{msg}");
    }

    #[test]
    fn messages_keep_key_substrings() {
        assert!(Error::MissingArtifact("a.hlo".into()).to_string().contains("make artifacts"));
        let c = Error::Config { path: "m.toml".into(), msg: "missing key".into() };
        assert!(c.to_string().contains("m.toml"));
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("io error"));
    }
}
