//! Tiny deterministic PRNG (xorshift64*) — keeps simulations reproducible
//! without an external dependency.

/// xorshift64* generator.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator; `seed` must not be zero (0 is mapped to a fixed
    /// non-zero constant).
    pub fn new(seed: u64) -> Self {
        XorShift64 { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_f64() * n as f64) as usize % n.max(1)
    }

    /// Sample an index proportionally to `weights` (all ≥ 0; if the total is
    /// zero, returns None).
    pub fn weighted_pick(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return None;
        }
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1)
    }

    /// Exponentially distributed sample with the given mean.
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = XorShift64::new(7);
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn weighted_pick_respects_weights() {
        let mut g = XorShift64::new(1234);
        let weights = [1.0, 3.0];
        let mut counts = [0usize; 2];
        for _ in 0..40_000 {
            counts[g.weighted_pick(&weights).unwrap()] += 1;
        }
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "ratio {ratio}");
        assert_eq!(g.weighted_pick(&[0.0, 0.0]), None);
    }

    #[test]
    fn exp_mean_roughly_correct() {
        let mut g = XorShift64::new(99);
        let mean: f64 = (0..20_000).map(|_| g.next_exp(5.0)).sum::<f64>() / 20_000.0;
        assert!((4.8..5.2).contains(&mean), "mean {mean}");
    }
}
