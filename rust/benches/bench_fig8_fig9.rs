//! Bench: regenerate Fig. 8 (error overview) and Fig. 9 (gain/loss bars),
//! timing the full validation sweeps. Uses the PJRT artifact when present
//! (the hot path), falling back to the in-process fluid engine.

use membw::benchutil::Bench;
use membw::report::{fig8_report, fig9_report, ExperimentCtx};
use membw::runtime::{ArtifactPaths, PjrtRuntime, PjrtSimExecutor};
use membw::simulator::Engine;

fn main() {
    let mut b = Bench::new("fig8_fig9");

    let pjrt = PjrtRuntime::cpu()
        .ok()
        .and_then(|rt| PjrtSimExecutor::load(&rt, &ArtifactPaths::default_dir()).ok());
    let engine_name = if pjrt.is_some() { "pjrt" } else { "fluid" };
    let ctx = ExperimentCtx {
        out_dir: std::path::PathBuf::from("results"),
        engine: Engine::Fluid,
        pjrt,
    };

    let mut fig8 = String::new();
    b.run(&format!("full Fig. 8 sweep ({engine_name})"), 1, || {
        fig8 = fig8_report(&ctx).expect("fig8");
    });
    // Print the per-machine and global error summaries.
    for line in fig8.lines() {
        if line.starts_with('[') || line.starts_with("GLOBAL") {
            println!("{line}");
        }
    }

    let mut fig9 = String::new();
    b.run(&format!("full Fig. 9 sweep ({engine_name})"), 1, || {
        fig9 = fig9_report(&ctx).expect("fig9");
    });
    println!("fig9: {} bars", fig9.lines().filter(|l| l.contains(" vs ")).count());
    b.finish();
}
