//! Scenario-engine demo: measure arbitrary k-group workload mixes — kernel
//! groups plus idle cores, in time-phased sequences — through the unified
//! batched runner, and compare against the multigroup sharing model
//! (generalized Eqs. 4+5).
//!
//! Also demonstrates that the classic two-group pairing sweep is exactly
//! the k=2 special case of this pipeline.
//!
//! ```bash
//! cargo run --release --example scenario_mixes
//! ```

use membw::config::{machine, MachineId};
use membw::kernels::KernelId;
use membw::scenario::{run_mixes, run_scenario, MeasureEngine, Mix, Scenario};
use membw::sweep::{full_domain_splits, run_cases};

fn main() {
    let m = machine(MachineId::Clx);
    println!("machine: {} ({} cores per ccNUMA domain)\n", m.name, m.cores);

    // 1. A three-phase scenario: full 3-group contention, a partially idle
    //    phase (scenario (c) of Fig. 2), and a 4-group mix.
    let scenario = Scenario::new("phases")
        .then(
            Mix::new()
                .with(KernelId::Dcopy, 7)
                .with(KernelId::Ddot2, 7)
                .with(KernelId::Stream, 6),
        )
        .then(Mix::new().with(KernelId::Dcopy, 7).with(KernelId::Ddot2, 7).idle(6))
        .then(
            Mix::new()
                .with(KernelId::VecSum, 5)
                .with(KernelId::Daxpy, 5)
                .with(KernelId::Schoenauer, 5)
                .with(KernelId::Dscal, 5),
        );
    let r = run_scenario(&m, &scenario, &MeasureEngine::Fluid).expect("scenario run");
    for (pi, phase) in r.phases.iter().enumerate() {
        println!(
            "phase {} [{}] — {}, b_mix {:.1} GB/s",
            pi + 1,
            phase.mix.label(),
            if phase.saturated { "saturated" } else { "nonsaturated" },
            phase.b_mix_gbs
        );
        for (gi, g) in phase.groups.iter().enumerate() {
            println!(
                "  {:10} x{:2}  measured {:5.2} GB/s/core  model {:5.2}  \
                 alpha {:.3} vs {:.3}  err {:4.1}%",
                g.kernel.key(),
                g.n,
                g.measured_per_core,
                g.model_per_core,
                phase.measured_alpha(gi),
                g.model_alpha,
                g.error() * 100.0
            );
        }
    }

    // 2. Cross-engine agreement on a 3-group mix: fluid vs DES.
    let mix = Mix::parse("dcopy:7+ddot2:7+stream:6").expect("mix spec");
    let fluid = run_mixes(&m, std::slice::from_ref(&mix), &MeasureEngine::Fluid).expect("fluid");
    let des = run_mixes(&m, std::slice::from_ref(&mix), &MeasureEngine::Des).expect("des");
    println!(
        "\ncross-engine [{}]: fluid total {:.1} GB/s, DES total {:.1} GB/s",
        mix.label(),
        fluid.cases[0].measured_total_gbs,
        des.cases[0].measured_total_gbs
    );

    // 3. The pairing sweep is the k=2 special case: running the Fig. 6 plan
    //    through `sweep::run_cases` (which delegates to the scenario
    //    pipeline) and through k=2 mixes directly is bit-identical.
    let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
    let legacy = run_cases(&m, &cases, &MeasureEngine::Fluid).expect("pairing sweep");
    let mixes: Vec<Mix> = cases.iter().map(Mix::from_pairing).collect();
    let unified = run_mixes(&m, &mixes, &MeasureEngine::Fluid).expect("mix sweep");
    let mut worst: f64 = 0.0;
    for (c, u) in legacy.cases.iter().zip(&unified.cases) {
        for g in 0..2 {
            worst = worst.max((c.measured_per_core[g] - u.groups[g].measured_per_core).abs());
            worst = worst.max((c.model_per_core[g] - u.groups[g].model_per_core).abs());
        }
    }
    println!(
        "pairing-vs-scenario pipeline max |delta| over {} full-domain splits: {:.2e} GB/s",
        cases.len(),
        worst
    );
    assert!(worst < 1e-9, "the two paths must be the same pipeline");
    println!("OK: the two-group sweep is the k=2 special case of the scenario engine");
}
