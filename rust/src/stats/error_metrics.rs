//! Relative-error metrics for model validation (Fig. 8).

/// Paper's error definition: `|(b_observed − b_model) / b_model|`.
pub fn rel_error(observed: f64, model: f64) -> f64 {
    if model == 0.0 {
        if observed == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        ((observed - model) / model).abs()
    }
}

/// Maximum relative error over paired samples.
pub fn max_rel_error(observed: &[f64], model: &[f64]) -> f64 {
    observed
        .iter()
        .zip(model)
        .map(|(&o, &m)| rel_error(o, m))
        .fold(0.0, f64::max)
}

/// Aggregate error statistics for a set of validation cases.
#[derive(Debug, Clone, Copy)]
pub struct ErrorStats {
    /// Number of cases.
    pub n: usize,
    /// Median relative error.
    pub median: f64,
    /// Maximum relative error.
    pub max: f64,
    /// Fraction of cases with error below 5% (paper: 75%).
    pub frac_below_5pct: f64,
    /// Fraction of cases with error below 8% (paper: 100%).
    pub frac_below_8pct: f64,
}

impl ErrorStats {
    /// Compute the aggregate statistics from raw per-case errors.
    pub fn of(errors: &[f64]) -> Self {
        if errors.is_empty() {
            return ErrorStats { n: 0, median: 0.0, max: 0.0, frac_below_5pct: 1.0, frac_below_8pct: 1.0 };
        }
        let mut sorted = errors.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if sorted.len() % 2 == 1 {
            sorted[sorted.len() / 2]
        } else {
            0.5 * (sorted[sorted.len() / 2 - 1] + sorted[sorted.len() / 2])
        };
        let below = |t: f64| sorted.iter().filter(|&&e| e < t).count() as f64 / sorted.len() as f64;
        ErrorStats {
            n: sorted.len(),
            median,
            max: *sorted.last().unwrap(),
            frac_below_5pct: below(0.05),
            frac_below_8pct: below(0.08),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_definition_matches_paper() {
        assert!((rel_error(105.0, 100.0) - 0.05).abs() < 1e-12);
        assert!((rel_error(95.0, 100.0) - 0.05).abs() < 1e-12);
        assert_eq!(rel_error(0.0, 0.0), 0.0);
    }

    #[test]
    fn stats_aggregate() {
        let errors = [0.01, 0.02, 0.03, 0.06, 0.09];
        let s = ErrorStats::of(&errors);
        assert_eq!(s.n, 5);
        assert!((s.median - 0.03).abs() < 1e-12);
        assert!((s.max - 0.09).abs() < 1e-12);
        assert!((s.frac_below_5pct - 0.6).abs() < 1e-12);
        assert!((s.frac_below_8pct - 0.8).abs() < 1e-12);
    }
}
