//! The measurement substrate: simulators of a memory contention domain.
//!
//! Stands in for the paper's physical BDW/CLX/Rome machines. Two independent
//! implementations with the same physics (see `DESIGN.md` §4):
//!
//! * `fluid` — time-stepped fluid-queueing simulator (per-cycle fractional
//!   state). The JAX/Pallas artifact executed via PJRT implements exactly
//!   this model; the Rust version here is the cross-validation mirror and
//!   the engine used where PJRT batching is inconvenient.
//! * `des` — line-granularity discrete-event simulator with an explicit
//!   FCFS-with-lottery memory queue, integer line requests, and stochastic
//!   tie-breaking. Higher fidelity, slower; the reference.
//! * `network` — the multi-interface generalization both engines are built
//!   on: a set of interfaces (per-domain memory controllers + inter-socket
//!   links), each core's stream split into routed portions, every
//!   interface water-filled independently, the slowest portion gating the
//!   stream (see `docs/SIMULATORS.md`). The single-interface engines above
//!   are its degenerate one-interface case (delegation pinned bit-identical
//!   by `rust/tests/simulator_conformance.rs`).
//!
//! Both deliberately model mechanisms the analytic sharing model ignores
//! (prefetch-depth floors, queueing latency, write-service penalty, the ECM
//! latency penalty) — the model error measured in Fig. 8 is real.

mod des;
mod fluid;
mod measurement;
mod network;
mod workload;
mod xorshift;

pub use des::{DesConfig, DesResult, DesSimulator};
pub use fluid::{FluidConfig, FluidResult, FluidSimulator};
pub use measurement::{
    measure_f_bs, measure_pairing, measure_scaling, run_engine, Engine, KernelMeasurement,
    PairingMeasurement,
};
pub use network::{
    route_streams, run_net_engine, IfaceNet, NetDesSimulator, NetFluidSimulator, NetPortion,
    NetResult, NetStream,
};
pub use workload::CoreWorkload;
pub use xorshift::XorShift64;
