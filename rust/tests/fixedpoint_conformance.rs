//! Fixed-point water-fill conformance — the stranded-capacity bugfix.
//!
//! The historical `share_remote` made one water-fill pass per interface
//! and gated every group by its slowest portion, *discarding* the
//! capacity a gated group could no longer drain. This suite pins the
//! global fixed-point replacement against the authoritative Python
//! reference (`python/netfluid_mirror.py`, whose self-checks derive every
//! number asserted here):
//!
//! 1. the stranded-capacity regression — a link-gated group must return
//!    its surplus memory grant to the co-resident group (old answer 16/3,
//!    fixed point 7.5);
//! 2. degenerate bit-identity — no gating (one pass), `r = 0`
//!    (== `share_domains`), a single interface (== Eqs. 4+5 via
//!    `share_multigroup`), and one-direction duplex traffic (== the old
//!    half-duplex numbers, since an idle reverse direction changes no
//!    contended interface);
//! 3. the gated regime end to end — the multi-interface fluid simulator
//!    agrees with the fixed point within the paper's 8% ceiling on a
//!    scenario where the single-pass answer is off by ~14%.

use membw::config::{machine, MachineId};
use membw::kernels::{kernel, KernelId};
use membw::sharing::{
    share_domains, share_multigroup, share_remote, share_weighted, share_weighted_capacity,
    GroupKind, KernelGroup, RemoteGroup, TopoShape, WeightedGroup,
};
use membw::simulator::{CoreWorkload, FluidConfig, IfaceNet, NetFluidSimulator, NetStream};
use membw::topology::Topology;

/// Rome full-socket dcopy/ddot2 characterization `(f, b_s)`, exactly as
/// `python/netfluid_mirror.py::ecm_workload` computes it (shortest
/// round-trip representations, so the parsed literals are bit-identical
/// to the mirror's doubles).
const DCOPY_F: f64 = 0.8357432872482309;
const DCOPY_BS: f64 = 32.843963205239454;
const DDOT2_F: f64 = 0.8299900114233997;
const DDOT2_BS: f64 = 34.23;

/// Two monolithic sockets joined by a symmetric-duplex link.
fn two_socket(link_gbs: f64) -> TopoShape {
    TopoShape {
        socket_of: vec![0, 1],
        bw_scale: vec![1.0, 1.0],
        link_bw_gbs: link_gbs,
        link_bw_rev_gbs: link_gbs,
        l3_bw_gbs: 0.0,
    }
}

/// The stranded-capacity regression (mirror `check_stranded_capacity`).
///
/// Group A (r = 0.5) is gated at 1 GB/s/core by a 2 GB/s link; under the
/// single-pass model its home portion still held a proportional share of
/// the d0 memory interface that A could never drain, capping co-resident
/// group B at 16/3 GB/s/core. The fixed point re-offers the stranded
/// share and B reaches 7.5 GB/s/core.
#[test]
fn stranded_capacity_is_returned_to_the_ungated_group() {
    let shape = two_socket(2.0);
    let groups = [
        RemoteGroup { home: 0, n: 4, f: 0.8, bs_gbs: 32.0, remote_frac: 0.5, kind: GroupKind::Mem },
        RemoteGroup { home: 0, n: 4, f: 0.8, bs_gbs: 32.0, remote_frac: 0.0, kind: GroupKind::Mem },
    ];
    let share = share_remote(&shape, &groups).unwrap();
    assert!(
        (share.per_core_gbs[0] - 1.0).abs() < 1e-9,
        "gated group: {} vs mirror 1.0",
        share.per_core_gbs[0]
    );
    assert!(
        (share.per_core_gbs[1] - 7.5).abs() < 1e-9,
        "ungated group: {} vs mirror 7.5",
        share.per_core_gbs[1]
    );
    assert!(share.iterations > 1, "a gated scenario must take extra sweeps");

    // The historical single-pass answer for B: the d0 interface split
    // between A's home portion (2 effective threads) and B, nothing
    // returned. Demonstrably short by > 2 GB/s/core of real capacity.
    let old = share_weighted_capacity(
        &[
            WeightedGroup { n: 2.0, f: 0.8, bs_gbs: 32.0 },
            WeightedGroup { n: 4.0, f: 0.8, bs_gbs: 32.0 },
        ],
        32.0,
    );
    let old_b = old.groups[1].per_core_gbs;
    assert!((old_b - 16.0 / 3.0).abs() < 1e-12, "single-pass B: {old_b} vs 16/3");
    assert!(
        share.per_core_gbs[1] > old_b + 2.0,
        "fixed point must beat the single pass: {} vs {old_b}",
        share.per_core_gbs[1]
    );
}

/// Degenerate pin: when no portion outruns its group's lockstep rate the
/// uncapped first pass *is* the fixed point — one water-fill, bitwise the
/// historical single-pass answer (mirror `check_duplex_one_direction`:
/// 8.210990801309864 GB/s/core).
#[test]
fn ungated_scenario_terminates_in_one_pass() {
    let shape = two_socket(64.0);
    // Half the lines stay home, half cross: the d0 and d1 memory
    // interfaces gate both portions at the same rate, so nothing is
    // stranded — with one group or two identical ones.
    let one = share_remote(
        &shape,
        &[RemoteGroup { home: 0, n: 8, f: DCOPY_F, bs_gbs: DCOPY_BS, remote_frac: 0.5, kind: GroupKind::Mem }],
    )
    .unwrap();
    assert_eq!(one.iterations, 1, "ungated: the first pass is the fixed point");
    assert!((one.per_core_gbs[0] - 8.210990801309864).abs() < 1e-9);

    let two = share_remote(
        &shape,
        &[
            RemoteGroup { home: 0, n: 4, f: DCOPY_F, bs_gbs: DCOPY_BS, remote_frac: 0.5, kind: GroupKind::Mem },
            RemoteGroup { home: 0, n: 4, f: DCOPY_F, bs_gbs: DCOPY_BS, remote_frac: 0.5, kind: GroupKind::Mem },
        ],
    )
    .unwrap();
    assert_eq!(two.iterations, 1);
    assert_eq!(
        two.per_core_gbs[0].to_bits(),
        two.per_core_gbs[1].to_bits(),
        "identical groups share identically"
    );
    assert!((two.per_core_gbs[0] - 8.210990801309864).abs() < 1e-9);
}

/// Degenerate pin: with `r = 0` everywhere the remote evaluation is the
/// per-domain Eqs. 4+5 of [`share_domains`], bit for bit — links exist
/// but carry no portions.
#[test]
fn zero_remote_matches_share_domains_bitwise() {
    let shape = two_socket(40.0);
    let groups = [
        RemoteGroup { home: 0, n: 4, f: 0.84, bs_gbs: 32.0, remote_frac: 0.0, kind: GroupKind::Mem },
        RemoteGroup { home: 0, n: 4, f: 0.75, bs_gbs: 33.0, remote_frac: 0.0, kind: GroupKind::Mem },
        RemoteGroup { home: 1, n: 6, f: 0.30, bs_gbs: 35.0, remote_frac: 0.0, kind: GroupKind::Mem },
    ];
    let share = share_remote(&shape, &groups).unwrap();
    assert_eq!(share.iterations, 1);

    let domains = share_domains(&[
        vec![
            KernelGroup { n: 4, f: 0.84, bs_gbs: 32.0 },
            KernelGroup { n: 4, f: 0.75, bs_gbs: 33.0 },
        ],
        vec![KernelGroup { n: 6, f: 0.30, bs_gbs: 35.0 }],
    ]);
    let want = [
        domains[0].groups[0].per_core_gbs,
        domains[0].groups[1].per_core_gbs,
        domains[1].groups[0].per_core_gbs,
    ];
    for (g, w) in share.per_core_gbs.iter().zip(&want) {
        assert_eq!(g.to_bits(), w.to_bits(), "r=0 diverged from share_domains");
    }
    assert_eq!(share.domains[0].b_mix_gbs.to_bits(), domains[0].b_mix_gbs.to_bits());
    assert_eq!(share.domains[1].b_mix_gbs.to_bits(), domains[1].b_mix_gbs.to_bits());
    for link in &share.links {
        assert_eq!(link.demand_gbs, 0.0, "no remote traffic, no link demand");
    }
}

/// Degenerate pin: a single-domain shape with local groups is exactly the
/// paper's Eqs. (4)+(5) — bitwise [`share_multigroup`].
#[test]
fn single_interface_matches_eq5_bitwise() {
    let shape = TopoShape {
        socket_of: vec![0],
        bw_scale: vec![1.0],
        link_bw_gbs: 0.0,
        link_bw_rev_gbs: 0.0,
        l3_bw_gbs: 0.0,
    };
    let groups = [
        RemoteGroup { home: 0, n: 6, f: 0.35, bs_gbs: 55.0, remote_frac: 0.0, kind: GroupKind::Mem },
        RemoteGroup { home: 0, n: 4, f: 0.20, bs_gbs: 66.0, remote_frac: 0.0, kind: GroupKind::Mem },
    ];
    let share = share_remote(&shape, &groups).unwrap();
    let eq5 = share_multigroup(&[
        KernelGroup { n: 6, f: 0.35, bs_gbs: 55.0 },
        KernelGroup { n: 4, f: 0.20, bs_gbs: 66.0 },
    ]);
    assert_eq!(share.iterations, 1);
    assert_eq!(share.domains[0].b_mix_gbs.to_bits(), eq5.b_mix_gbs.to_bits());
    assert_eq!(share.domains[0].saturated, eq5.saturated);
    for (gi, want) in eq5.groups.iter().enumerate() {
        assert_eq!(share.per_core_gbs[gi].to_bits(), want.per_core_gbs.to_bits());
        assert_eq!(share.group_bw_gbs[gi].to_bits(), want.group_bw_gbs.to_bits());
    }
}

/// Degenerate pin: traffic in only ONE direction of a symmetric-duplex
/// link reproduces the old half-duplex numbers bitwise — the idle reverse
/// direction adds an interface but no contention. Mirror
/// `check_duplex_one_direction`: 5.473993867539909 (r = 0.25) and
/// 8.210990801309864 (r = 0.5) GB/s/core.
#[test]
fn one_direction_duplex_matches_half_duplex_numbers() {
    let shape = two_socket(64.0);

    // r = 0.25: the home memory interface gates (6 effective threads on
    // b_mix = b_s), so the per-core rate is the old single-pass home rate
    // even though the fixed point takes extra sweeps to trim the remote
    // portion's surplus.
    let quarter = share_remote(
        &shape,
        &[RemoteGroup { home: 0, n: 8, f: DCOPY_F, bs_gbs: DCOPY_BS, remote_frac: 0.25, kind: GroupKind::Mem }],
    )
    .unwrap();
    let old_home = share_weighted(&[WeightedGroup { n: 6.0, f: DCOPY_F, bs_gbs: DCOPY_BS }]);
    assert_eq!(
        quarter.per_core_gbs[0].to_bits(),
        old_home.groups[0].per_core_gbs.to_bits(),
        "one-direction duplex r=0.25 diverged from the half-duplex home rate"
    );
    assert!((quarter.per_core_gbs[0] - 5.473993867539909).abs() < 1e-9, "mirror pin");
    // All cross-traffic rides the forward direction; the reverse
    // interface exists (directed enumeration) but is offered nothing.
    assert_eq!(shape.links()[1], (1, 0));
    assert_eq!(quarter.links[1].demand_gbs, 0.0);

    // r = 0.5: fully ungated (both portions gate at the same rate).
    let half = share_remote(
        &shape,
        &[RemoteGroup { home: 0, n: 8, f: DCOPY_F, bs_gbs: DCOPY_BS, remote_frac: 0.5, kind: GroupKind::Mem }],
    )
    .unwrap();
    let old_half = share_weighted(&[WeightedGroup { n: 4.0, f: DCOPY_F, bs_gbs: DCOPY_BS }]);
    assert_eq!(half.iterations, 1);
    assert_eq!(half.per_core_gbs[0].to_bits(), old_half.groups[0].per_core_gbs.to_bits());
    assert!((half.per_core_gbs[0] - 8.210990801309864).abs() < 1e-9, "mirror pin");
}

/// The gated regime end to end (mirror `gated_example`): dual-socket Rome
/// with the link squeezed to 8 GB/s, 4 dcopy cores at r = 0.5 sharing
/// their home domain with 4 local ddot2 cores. The link gates dcopy at
/// 4.0 GB/s/core; the fixed point hands the stranded d0 share to ddot2
/// (6.442 GB/s/core, mirror ≤ 1e-9). The multi-interface fluid simulator
/// agrees with the fixed point within the paper's 8% ceiling while the
/// single-pass answer (5.615 GB/s/core) is ~14% below the simulated
/// truth — the regression is visible in measurement, not just in model
/// arithmetic.
#[test]
fn gated_regime_fluid_matches_fixed_point_and_refutes_single_pass() {
    let mut m = machine(MachineId::Rome);
    m.link_bw_gbs = 8.0;
    m.link_bw_rev_gbs = 8.0;
    let topo = Topology::parse(&m, "2x1").unwrap();
    let net = IfaceNet::of_topology(&topo);
    let dm = &topo.domains[0].machine;
    let wa = CoreWorkload::from_kernel(&kernel(KernelId::Dcopy), dm, 0);
    let wb = CoreWorkload::from_kernel(&kernel(KernelId::Ddot2), dm, 1);
    let mut streams = vec![NetStream { workload: wa, home: 0, remote_frac: 0.5, l3_frac: 0.0 }; 4];
    streams.extend(vec![NetStream { workload: wb, home: 0, remote_frac: 0.0, l3_frac: 0.0 }; 4]);
    let sim = NetFluidSimulator::new(&net, FluidConfig::default()).run(&streams);

    let shape = two_socket(8.0);
    let groups = [
        RemoteGroup { home: 0, n: 4, f: DCOPY_F, bs_gbs: DCOPY_BS, remote_frac: 0.5, kind: GroupKind::Mem },
        RemoteGroup { home: 0, n: 4, f: DDOT2_F, bs_gbs: DDOT2_BS, remote_frac: 0.0, kind: GroupKind::Mem },
    ];
    let share = share_remote(&shape, &groups).unwrap();
    assert!(share.iterations > 1, "the squeezed link gates dcopy");
    assert!(
        (share.per_core_gbs[0] - 4.0).abs() < 1e-9,
        "link-gated dcopy: 8 GB/s over 2 effective threads"
    );
    assert!(
        (share.per_core_gbs[1] - 6.441996933769955).abs() < 1e-9,
        "ddot2 with the returned share: {} vs mirror",
        share.per_core_gbs[1]
    );

    // Fluid agrees with the fixed point within the paper's ceiling
    // (mirror: 0.0% on dcopy, 0.7% on ddot2).
    for (g, label) in [(0usize, "dcopy"), (1, "ddot2")] {
        let sim_pc = sim.per_stream_gbs[4 * g];
        let err = (sim_pc - share.per_core_gbs[g]).abs() / share.per_core_gbs[g];
        assert!(
            err < 0.08,
            "{label}: fluid {sim_pc} vs fixed point {} ({:.1}%)",
            share.per_core_gbs[g],
            err * 100.0
        );
    }

    // ... and the historical single pass is provably wrong here: the d0
    // interface split with nothing returned under-predicts ddot2 by ~14%
    // of what the simulator actually measures.
    let old = share_weighted(&[
        WeightedGroup { n: 2.0, f: DCOPY_F, bs_gbs: DCOPY_BS },
        WeightedGroup { n: 4.0, f: DDOT2_F, bs_gbs: DDOT2_BS },
    ]);
    let old_b = old.groups[1].per_core_gbs;
    assert!((old_b - 5.615023991765522).abs() < 1e-9, "single-pass ddot2: {old_b} vs mirror");
    let old_err = (sim.per_stream_gbs[4] - old_b).abs() / old_b;
    assert!(
        old_err > 0.08,
        "single pass should miss the measured rate beyond the ceiling ({:.1}%)",
        old_err * 100.0
    );

    // The forward direction is pinned at its capacity; the reverse one is
    // idle (all cross-traffic flows socket 0 → socket 1).
    assert!(share.links[0].saturated);
    assert!(sim.link_total_gbs[0] > 0.9 * 8.0 && sim.link_total_gbs[0] <= 8.0 * 1.001);
    assert_eq!(sim.link_total_gbs[1], 0.0);
    assert_eq!(share.links[1].demand_gbs, 0.0);
}
