//! Experiment orchestration: plans (the Fig. 4 parameter space), parallel
//! runners over the measurement engines, and result records.

mod plan;
mod results;
mod runner;

pub use plan::{fig4_points, full_domain_splits, pairing_cases, symmetric_splits, PairingCase, PlanKind};
pub use results::{CaseResult, ResultSet};
pub use runner::{run_cases, run_cases_pjrt, MeasureEngine};
