//! Bench: PJRT runtime — artifact compile time, single-batch dispatch
//! latency, and end-to-end configuration throughput of the AOT JAX/Pallas
//! simulator (the paper-sweep hot path).

use membw::benchutil::Bench;
use membw::config::{machine, MachineId};
use membw::kernels::{kernel, KernelId};
use membw::runtime::{ArtifactPaths, PjrtRuntime, PjrtSimExecutor, SimCase};
use membw::simulator::CoreWorkload;

fn main() {
    let mut b = Bench::new("runtime");
    let Ok(rt) = PjrtRuntime::cpu() else {
        println!("PJRT unavailable — skipping runtime bench");
        return;
    };
    println!("platform: {}", rt.platform());

    let dir = ArtifactPaths::default_dir();
    if ArtifactPaths::locate(&dir).is_err() {
        println!("artifacts missing (run `make artifacts`) — skipping");
        return;
    }

    let mut exec: Option<PjrtSimExecutor> = None;
    b.run("load + compile contention_sim.hlo.txt", 3, || {
        exec = Some(PjrtSimExecutor::load(&rt, &dir).expect("load"));
    });
    let exec = exec.unwrap();
    let meta = exec.meta();
    println!("geometry: {meta:?}");

    let m = machine(MachineId::Clx);
    let w = CoreWorkload::from_kernel(&kernel(KernelId::Stream), &m, 0);
    let one = vec![SimCase { machine: m.clone(), workloads: vec![w; m.cores] }];
    b.run("dispatch 1 case (padded batch)", 5, || {
        let _ = exec.run(&one).expect("run");
    });

    let full: Vec<SimCase> = (0..meta.batch)
        .map(|i| SimCase {
            machine: m.clone(),
            workloads: vec![w; 1 + i % m.cores],
        })
        .collect();
    b.throughput("full batch of configurations", "configs", || {
        let _ = exec.run(&full).expect("run");
        meta.batch as f64
    });

    // Simulated core-cycles per wall second through the artifact.
    let cycles = ((meta.warmup_chunks + meta.measure_chunks) * meta.chunk_cycles) as f64;
    b.throughput("simulated core-cycles via pjrt", "core-cy", || {
        let _ = exec.run(&full).expect("run");
        cycles * (meta.batch * meta.n_cores) as f64
    });

    b.finish();
}
