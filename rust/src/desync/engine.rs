//! The time-stepped co-simulation engine.
//!
//! At every step, ranks currently inside loop kernels are grouped by kernel
//! and the multigroup sharing model (generalized Eqs. 4+5) assigns each
//! group its per-core bandwidth; everything else (collectives, halo waits,
//! noise idling) is bookkeeping. This is the paper's "MPI simulation
//! technique that can take node-level bottlenecks into account" (Sect. VI).

use std::collections::HashMap;

use crate::config::Machine;
use crate::desync::noise::{NoiseModel, NoiseStream};
use crate::desync::program::{Phase, Program, SyncKind};
use crate::desync::trace::{PhaseRecord, TraceLog};
use crate::ecm;
use crate::error::{Error, Result};
use crate::kernels::{kernel, KernelId};
use crate::sharing::{share_multigroup, KernelGroup};

/// Co-simulation configuration.
#[derive(Debug, Clone)]
pub struct CoSimConfig {
    /// Time step, seconds. Kernel durations are resolved to ~dt accuracy.
    pub dt_s: f64,
    /// Hard wall on simulated time.
    pub t_max_s: f64,
    /// Initial per-rank start stagger, seconds (rank r starts at r*stagger;
    /// 0 = lockstep start).
    pub initial_stagger_s: f64,
    /// Halo radius of the `SyncKind::Neighbors` dependency: how many ranks
    /// on each side must have completed the previous phase. 1 models a 1D
    /// chain; HPCG's 3D decomposition couples more broadly (default 3).
    pub neighbor_radius: usize,
    /// Noise model.
    pub noise: NoiseModel,
}

impl Default for CoSimConfig {
    fn default() -> Self {
        CoSimConfig {
            dt_s: 20e-6,
            t_max_s: 120.0,
            initial_stagger_s: 0.0,
            neighbor_radius: 3,
            noise: NoiseModel::off(),
        }
    }
}

/// Result of a co-simulation.
#[derive(Debug, Clone)]
pub struct CoSimResult {
    /// Full phase trace.
    pub trace: TraceLog,
    /// Per-rank completion time, seconds.
    pub finish_s: Vec<f64>,
    /// Simulated time at which the run ended.
    pub t_end_s: f64,
}

#[derive(Debug, Clone, PartialEq)]
enum RankState {
    /// Waiting for its staggered start.
    NotStarted,
    /// Between phases; next phase is `flat` (sync not yet satisfied).
    Ready { flat: usize },
    /// Running a kernel phase.
    Running { flat: usize, kernel: KernelId, remaining: f64, started: f64 },
    /// Arrived at a collective, waiting for the others.
    Collective { flat: usize, arrived: f64 },
    /// Idling until `until` (explicit Idle phase or noise).
    Idling { flat: Option<usize>, until: f64, resume: Box<RankState>, started: f64 },
    /// Program complete.
    Done,
}

/// The engine.
pub struct CoSimEngine<'a> {
    /// Machine the ranks run on (kept for diagnostics / future extensions).
    pub machine: &'a Machine,
    program: Program,
    n_ranks: usize,
    config: CoSimConfig,
    /// Pre-computed (f, b_s) per kernel (ECM route — the co-sim is the
    /// *application* of the analytic model, not its validation).
    chars: HashMap<KernelId, (f64, f64)>,
}

impl<'a> CoSimEngine<'a> {
    /// Build an engine for `n_ranks` ranks of `program` on `machine`.
    pub fn new(machine: &'a Machine, program: Program, n_ranks: usize, config: CoSimConfig) -> Result<Self> {
        if n_ranks == 0 || n_ranks > machine.cores {
            return Err(Error::InvalidPlan(format!(
                "{n_ranks} ranks on a {}-core domain",
                machine.cores
            )));
        }
        let mut chars = HashMap::new();
        for phase in &program.phases {
            if let Phase::Kernel { kernel: k, .. } = phase {
                let p = ecm::predict(&kernel(*k), machine);
                chars.insert(*k, (p.f, p.bs_gbs));
            }
        }
        Ok(CoSimEngine { machine, program, n_ranks, config, chars })
    }

    /// Run the co-simulation.
    pub fn run(&self) -> CoSimResult {
        let n = self.n_ranks;
        let dt = self.config.dt_s;
        let mut t = 0.0f64;
        let mut states: Vec<RankState> = (0..n).map(|_| RankState::NotStarted).collect();
        let mut completed_upto: Vec<i64> = vec![-1; n]; // last completed flat index
        let mut trace = TraceLog::default();
        let mut finish = vec![f64::NAN; n];
        let mut noise: Vec<NoiseStream> = (0..n).map(|r| self.config.noise.stream(r)).collect();
        // Collective instance -> (ranks arrived, all-arrived time).
        let mut collectives: HashMap<usize, (usize, f64)> = HashMap::new();
        // Memoized sharing-model evaluations by group composition.
        let mut share_cache: HashMap<Vec<(KernelId, usize)>, HashMap<KernelId, f64>> = HashMap::new();

        let total = self.program.total_phases();
        while t < self.config.t_max_s && states.iter().any(|s| *s != RankState::Done) {
            // 1. Start transitions.
            for r in 0..n {
                loop {
                    match states[r].clone() {
                        RankState::NotStarted => {
                            if t >= r as f64 * self.config.initial_stagger_s {
                                states[r] = RankState::Ready { flat: 0 };
                            } else {
                                break;
                            }
                        }
                        RankState::Ready { flat } => {
                            if flat >= total {
                                states[r] = RankState::Done;
                                finish[r] = t;
                                break;
                            }
                            match self.program.phase(flat).unwrap().clone() {
                                Phase::Kernel { kernel: k, volume_bytes, sync, .. } => {
                                    if self.sync_ok(sync, r, flat, &completed_upto) {
                                        states[r] = RankState::Running {
                                            flat,
                                            kernel: k,
                                            remaining: volume_bytes,
                                            started: t,
                                        };
                                    }
                                    break;
                                }
                                Phase::Allreduce { .. } => {
                                    let e = collectives.entry(flat).or_insert((0, f64::NAN));
                                    e.0 += 1;
                                    if e.0 == n {
                                        e.1 = t; // all arrived
                                    }
                                    states[r] = RankState::Collective { flat, arrived: t };
                                    break;
                                }
                                Phase::Idle { duration_s, .. } => {
                                    states[r] = RankState::Idling {
                                        flat: Some(flat),
                                        until: t + duration_s,
                                        resume: Box::new(RankState::Ready { flat: flat + 1 }),
                                        started: t,
                                    };
                                    break;
                                }
                            }
                        }
                        _ => break,
                    }
                }
            }

            // 2. Bandwidth sharing among running kernel ranks. The group
            // composition changes only at phase boundaries (rarely relative
            // to dt), so evaluations are memoized by composition.
            let mut composition: Vec<(KernelId, usize)> = Vec::new();
            for s in &states {
                if let RankState::Running { kernel: k, .. } = s {
                    match composition.iter_mut().find(|(kk, _)| kk == k) {
                        Some((_, cnt)) => *cnt += 1,
                        None => composition.push((*k, 1)),
                    }
                }
            }
            composition.sort_by_key(|(k, _)| k.key());
            let per_core: &HashMap<KernelId, f64> =
                share_cache.entry(composition.clone()).or_insert_with(|| {
                    let groups: Vec<KernelGroup> = composition
                        .iter()
                        .map(|(k, n)| {
                            let (f, bs) = self.chars[k];
                            KernelGroup { n: *n, f, bs_gbs: bs }
                        })
                        .collect();
                    let share = share_multigroup(&groups);
                    composition
                        .iter()
                        .zip(&share.groups)
                        .map(|((k, _), e)| (*k, e.per_core_gbs * 1e9)) // bytes/s
                        .collect()
                });

            // 3. Advance.
            for r in 0..n {
                match states[r].clone() {
                    RankState::Running { flat, kernel: k, mut remaining, started } => {
                        // Noise can preempt the kernel.
                        if let Some(dur) = noise[r].poll(t, dt) {
                            states[r] = RankState::Idling {
                                flat: None,
                                until: t + dur,
                                resume: Box::new(RankState::Running { flat, kernel: k, remaining, started }),
                                started: t,
                            };
                            continue;
                        }
                        remaining -= per_core[&k] * dt;
                        if remaining <= 0.0 {
                            let phase = self.program.phase(flat).unwrap();
                            trace.records.push(PhaseRecord {
                                rank: r,
                                iteration: flat / self.program.phases.len(),
                                label: phase.label(),
                                t_start: started,
                                t_end: t + dt,
                            });
                            completed_upto[r] = flat as i64;
                            states[r] = RankState::Ready { flat: flat + 1 };
                        } else {
                            states[r] = RankState::Running { flat, kernel: k, remaining, started };
                        }
                    }
                    RankState::Collective { flat, arrived } => {
                        let (count, all_at) = collectives[&flat];
                        if count == n && !all_at.is_nan() {
                            let cost = match self.program.phase(flat).unwrap() {
                                Phase::Allreduce { cost_s, .. } => *cost_s,
                                _ => 0.0,
                            };
                            if t >= all_at + cost {
                                let phase = self.program.phase(flat).unwrap();
                                trace.records.push(PhaseRecord {
                                    rank: r,
                                    iteration: flat / self.program.phases.len(),
                                    label: phase.label(),
                                    t_start: arrived,
                                    t_end: t,
                                });
                                completed_upto[r] = flat as i64;
                                states[r] = RankState::Ready { flat: flat + 1 };
                            }
                        }
                    }
                    RankState::Idling { flat, until, resume, started } => {
                        if t >= until {
                            if let Some(fl) = flat {
                                let phase = self.program.phase(fl).unwrap();
                                trace.records.push(PhaseRecord {
                                    rank: r,
                                    iteration: fl / self.program.phases.len(),
                                    label: phase.label(),
                                    t_start: started,
                                    t_end: t,
                                });
                                completed_upto[r] = fl as i64;
                            }
                            states[r] = *resume;
                        }
                    }
                    _ => {}
                }
            }

            t += dt;
        }

        CoSimResult { trace, finish_s: finish, t_end_s: t }
    }

    /// Is the sync precondition of phase `flat` satisfied for rank `r`?
    fn sync_ok(&self, sync: SyncKind, r: usize, flat: usize, completed: &[i64]) -> bool {
        match sync {
            SyncKind::None => true,
            SyncKind::Global => true, // handled by the collective machinery
            SyncKind::Neighbors => {
                if flat == 0 {
                    return true;
                }
                let n = self.n_ranks;
                let prev = flat as i64 - 1;
                let radius = self.config.neighbor_radius.min(n / 2);
                (1..=radius).all(|k| {
                    completed[(r + n - k) % n] >= prev && completed[(r + k) % n] >= prev
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::desync::program::{hpcg_program, HpcgVariant};

    fn small_config() -> CoSimConfig {
        CoSimConfig { dt_s: 50e-6, t_max_s: 600.0, ..Default::default() }
    }

    #[test]
    fn all_ranks_complete_without_noise() {
        let m = machine(MachineId::Rome);
        let prog = hpcg_program(HpcgVariant::Plain, 48, 2);
        let eng = CoSimEngine::new(&m, prog, 4, small_config()).unwrap();
        let r = eng.run();
        assert!(r.finish_s.iter().all(|f| f.is_finite()), "finish: {:?}", r.finish_s);
        // Lockstep start, no noise: ranks stay synchronized through the
        // collectives — finish times must be (nearly) identical.
        let min = r.finish_s.iter().cloned().fold(f64::MAX, f64::min);
        let max = r.finish_s.iter().cloned().fold(0.0, f64::max);
        assert!((max - min) / max < 0.02, "spread {}", max - min);
    }

    #[test]
    fn allreduce_resynchronizes_staggered_start() {
        let m = machine(MachineId::Bdw1);
        let prog = hpcg_program(HpcgVariant::Plain, 48, 2);
        let mut cfg = small_config();
        cfg.initial_stagger_s = 5e-3;
        let eng = CoSimEngine::new(&m, prog, 6, cfg).unwrap();
        let r = eng.run();
        // After the first Allreduce, all ranks leave at the same time.
        let recs = r.trace.of("Allreduce#1", Some(0));
        assert_eq!(recs.len(), 6);
        let ends: Vec<f64> = recs.iter().map(|x| x.t_end).collect();
        let spread = ends.iter().cloned().fold(0.0, f64::max) - ends.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 1e-3, "collective exit spread {spread}");
    }

    #[test]
    fn trace_contains_all_phases_per_rank() {
        let m = machine(MachineId::Clx);
        let prog = hpcg_program(HpcgVariant::Modified, 32, 1);
        let phases = prog.phases.len();
        let eng = CoSimEngine::new(&m, prog, 5, small_config()).unwrap();
        let r = eng.run();
        assert_eq!(r.trace.records.len(), phases * 5);
    }

    /// The Fig. 3 headline: skewness signs of the DDOT distributions.
    /// DDOT2#1 (tail overlaps halo waits) resynchronizes; DDOT2#2 and
    /// DDOT1 (followed by higher-f DAXPY/WAXPBY) desynchronize.
    #[test]
    fn fig3_skewness_signs() {
        use crate::desync::noise::NoiseModel;
        let m = machine(MachineId::Clx);
        let prog = hpcg_program(HpcgVariant::Modified, 96, 3);
        let cfg = CoSimConfig {
            dt_s: 20e-6,
            t_max_s: 600.0,
            initial_stagger_s: 0.2e-3,
            neighbor_radius: 3,
            noise: NoiseModel::mild(7),
        };
        let eng = CoSimEngine::new(&m, prog, 20, cfg).unwrap();
        let r = eng.run();
        let skew = |label: &str| {
            let d = r.trace.durations_by_rank(label, 1, 20);
            crate::stats::skewness_dimensioned(&d)
        };
        assert!(skew("DDOT2#1") < 0.0, "DDOT2#1 must resynchronize");
        assert!(skew("DDOT2#2") > 0.0, "DDOT2#2 must desynchronize");
        assert!(skew("DDOT1") > 0.0, "DDOT1 must desynchronize");
    }

    #[test]
    fn rejects_too_many_ranks() {
        let m = machine(MachineId::Rome);
        let prog = hpcg_program(HpcgVariant::Plain, 16, 1);
        assert!(CoSimEngine::new(&m, prog, 9, small_config()).is_err());
    }
}
