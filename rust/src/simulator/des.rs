//! Line-granularity discrete-event simulator of a memory contention domain.
//!
//! Higher-fidelity reference implementation of the same physics as
//! [`crate::simulator::FluidSimulator`]:
//!
//! * each core generates one *integer* cache-line request every
//!   `1/d` cycles (with a small jitter to break phase locking), but only
//!   while its outstanding-request count is below its prefetch window
//!   `W = D0 + β d c L0`;
//! * a single memory server serves one line at a time; the service time of
//!   a line is `c / C` cycles (write lines cost more);
//! * the next line to serve is drawn by a weighted lottery over cores,
//!   weighted by queue occupancy — a stochastic approximation of FR-FCFS
//!   arbitration that matches the fluid model's proportional-share rule in
//!   expectation.
//!
//! The DES adds discretization and stochastic arbitration noise on top of
//! the fluid model — `cargo test` cross-validates the two (they agree to a
//! few percent), and the PJRT artifact is validated against both.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::Machine;
use crate::simulator::workload::CoreWorkload;
use crate::simulator::xorshift::XorShift64;

/// Configuration of a DES run.
#[derive(Debug, Clone)]
pub struct DesConfig {
    /// Warm-up cycles before measurement.
    pub warmup_cycles: f64,
    /// Measured cycles.
    pub measure_cycles: f64,
    /// RNG seed (lottery + jitter).
    pub seed: u64,
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig { warmup_cycles: 40_000.0, measure_cycles: 400_000.0, seed: 0xB4D5EED }
    }
}

/// Result of a DES run.
#[derive(Debug, Clone)]
pub struct DesResult {
    /// Per-core memory bandwidth, GB/s.
    pub per_core_gbs: Vec<f64>,
    /// Aggregate bandwidth, GB/s.
    pub total_gbs: f64,
    /// Fraction of measured time the memory server was busy.
    pub utilization: f64,
    /// Total line-service events processed (for perf accounting).
    pub events: u64,
}

impl DesResult {
    /// Mean per-core bandwidth of one group, GB/s.
    pub fn group_per_core(&self, workloads: &[CoreWorkload], group: usize) -> f64 {
        let sel: Vec<f64> = self
            .per_core_gbs
            .iter()
            .zip(workloads)
            .filter(|(_, w)| w.group == group)
            .map(|(&bw, _)| bw)
            .collect();
        if sel.is_empty() {
            0.0
        } else {
            sel.iter().sum::<f64>() / sel.len() as f64
        }
    }
}

/// Event kinds (encoded as a u8 in the heap tuple): a core generating its
/// next request, or the server finishing the line in service.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    /// Core tries to generate its next request.
    Issue { core: usize },
}

/// Heap entry ordered by time (f64 bits — valid for non-negative times).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct TimeKey(u64);

impl TimeKey {
    fn of(t: f64) -> Self {
        debug_assert!(t >= 0.0 && t.is_finite());
        TimeKey(t.to_bits())
    }
    fn time(&self) -> f64 {
        f64::from_bits(self.0)
    }
}

/// The discrete-event simulator.
pub struct DesSimulator<'a> {
    machine: &'a Machine,
    config: DesConfig,
}

struct CoreState {
    gap_cy: f64,     // cycles between generated requests (1/d)
    window: usize,   // max outstanding lines
    cost_cy: f64,    // service cycles per line (c / C)
    queued: usize,   // lines waiting at the interface
    in_service: bool,
    outstanding: usize, // queued + in_service
    blocked: bool,      // demand clock paused on a full window
    served: u64,        // lines served inside the measurement window
}

impl<'a> DesSimulator<'a> {
    /// Create a DES for `machine`.
    pub fn new(machine: &'a Machine, config: DesConfig) -> Self {
        DesSimulator { machine, config }
    }

    /// Run the DES for the given per-core workloads.
    pub fn run(&self, workloads: &[CoreWorkload]) -> DesResult {
        let m = self.machine;
        assert!(workloads.len() <= m.cores);
        let cap = m.capacity_lines_per_cy();
        let q = &m.queue;
        let mut rng = XorShift64::new(self.config.seed);

        let mut cores: Vec<CoreState> = workloads
            .iter()
            .map(|w| {
                let window =
                    (q.depth_floor + q.depth_beta * w.demand_lines_per_cy * w.cost_factor * q.base_latency_cy)
                        .round()
                        .max(1.0) as usize;
                CoreState {
                    gap_cy: if w.is_active() { 1.0 / w.demand_lines_per_cy } else { f64::INFINITY },
                    window,
                    cost_cy: w.cost_factor / cap,
                    queued: 0,
                    in_service: false,
                    outstanding: 0,
                    blocked: false,
                    served: 0,
                }
            })
            .collect();

        let mut heap: BinaryHeap<Reverse<(TimeKey, usize, u8)>> = BinaryHeap::new();
        // Encode events as (time, core, kind) with kind 0=Issue 1=ServiceDone
        // (service completions are pushed directly where service starts).
        let push = |heap: &mut BinaryHeap<Reverse<(TimeKey, usize, u8)>>, t: f64, e: Event| {
            let Event::Issue { core } = e;
            heap.push(Reverse((TimeKey::of(t), core, 0u8)));
        };

        // Stagger initial issues to avoid a synchronized start.
        for (i, c) in cores.iter().enumerate() {
            if c.gap_cy.is_finite() {
                push(&mut heap, rng.next_f64() * c.gap_cy, Event::Issue { core: i });
            }
        }

        let t_end = self.config.warmup_cycles + self.config.measure_cycles;
        let mut server_busy = false;
        let mut busy_accum = 0.0f64;
        let mut events: u64 = 0;

        // Start service on the weighted-lottery winner, if any queue is
        // non-empty and the server is idle.
        fn try_serve(
            t: f64,
            cores: &mut [CoreState],
            server_busy: &mut bool,
            rng: &mut XorShift64,
            heap: &mut BinaryHeap<Reverse<(TimeKey, usize, u8)>>,
        ) {
            if *server_busy {
                return;
            }
            // Inline weighted lottery over queue occupancies (no allocation
            // in the hot path — this runs once per line-service event).
            let total: usize = cores.iter().map(|c| c.queued).sum();
            if total == 0 {
                return;
            }
            let mut x = (rng.next_f64() * total as f64) as usize;
            let mut pick = 0;
            for (i, c) in cores.iter().enumerate() {
                if x < c.queued {
                    pick = i;
                    break;
                }
                x -= c.queued;
            }
            cores[pick].queued -= 1;
            cores[pick].in_service = true;
            *server_busy = true;
            let done = t + cores[pick].cost_cy;
            heap.push(Reverse((TimeKey::of(done), pick, 1u8)));
        }

        while let Some(Reverse((key, core, kind))) = heap.pop() {
            let t = key.time();
            if t >= t_end {
                break;
            }
            events += 1;
            match kind {
                0 => {
                    // Issue event.
                    let c = &mut cores[core];
                    if c.outstanding < c.window {
                        c.queued += 1;
                        c.outstanding += 1;
                        c.blocked = false;
                        let jitter = 0.95 + 0.1 * rng.next_f64();
                        push(&mut heap, t + c.gap_cy * jitter, Event::Issue { core });
                        try_serve(t, &mut cores, &mut server_busy, &mut rng, &mut heap);
                    } else {
                        // Window full: pause the demand clock until a
                        // completion unblocks us.
                        c.blocked = true;
                    }
                }
                _ => {
                    // ServiceDone event.
                    let in_measure = t >= self.config.warmup_cycles;
                    {
                        let c = &mut cores[core];
                        c.in_service = false;
                        c.outstanding -= 1;
                        if in_measure {
                            c.served += 1;
                        }
                    }
                    if in_measure {
                        busy_accum += cores[core].cost_cy;
                    }
                    server_busy = false;
                    if cores[core].blocked {
                        cores[core].blocked = false;
                        push(&mut heap, t, Event::Issue { core });
                    }
                    try_serve(t, &mut cores, &mut server_busy, &mut rng, &mut heap);
                }
            }
        }

        let cycles = self.config.measure_cycles;
        let per_core_gbs: Vec<f64> = cores
            .iter()
            .map(|c| m.lines_per_cy_to_gbs(c.served as f64 / cycles))
            .collect();
        let total_gbs = per_core_gbs.iter().sum();
        DesResult {
            per_core_gbs,
            total_gbs,
            utilization: (busy_accum / cycles).min(1.0),
            events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::{kernel, KernelId};
    use crate::simulator::fluid::{FluidConfig, FluidSimulator};

    fn wl(k: KernelId, mid: MachineId, group: usize) -> CoreWorkload {
        CoreWorkload::from_kernel(&kernel(k), &machine(mid), group)
    }

    #[test]
    fn solo_core_matches_ecm() {
        let m = machine(MachineId::Bdw1);
        let des = DesSimulator::new(&m, DesConfig::default());
        let r = des.run(&[wl(KernelId::Stream, MachineId::Bdw1, 0)]);
        let p = crate::ecm::predict(&kernel(KernelId::Stream), &m);
        let err = (r.per_core_gbs[0] - p.b1_gbs).abs() / p.b1_gbs;
        assert!(err < 0.05, "DES {} vs ECM {}", r.per_core_gbs[0], p.b1_gbs);
    }

    #[test]
    fn saturates_full_domain() {
        let m = machine(MachineId::Clx);
        let des = DesSimulator::new(&m, DesConfig::default());
        let ws = vec![wl(KernelId::Stream, MachineId::Clx, 0); m.cores];
        let r = des.run(&ws);
        let bs = m.saturated_bw(0.25, 4);
        let err = (r.total_gbs - bs).abs() / bs;
        assert!(err < 0.06, "DES total {} vs b_s {}", r.total_gbs, bs);
        assert!(r.utilization > 0.95);
    }

    #[test]
    fn des_agrees_with_fluid_on_pairings() {
        // Cross-validation of the two measurement engines.
        let m = machine(MachineId::Bdw1);
        let des = DesSimulator::new(&m, DesConfig::default());
        let fluid = FluidSimulator::new(&m, FluidConfig::default());
        let mut ws = vec![wl(KernelId::Dcopy, MachineId::Bdw1, 0); 6];
        ws.extend(vec![wl(KernelId::Ddot2, MachineId::Bdw1, 1); 4]);
        let rd = des.run(&ws);
        let rf = fluid.run(&ws);
        for g in 0..2 {
            let a = rd.group_per_core(&ws, g);
            let b = rf.group_per_core(&ws, g);
            let err = (a - b).abs() / b;
            assert!(err < 0.06, "group {g}: DES {a} vs fluid {b}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let m = machine(MachineId::Rome);
        let ws = vec![wl(KernelId::Daxpy, MachineId::Rome, 0); 4];
        let cfg = DesConfig { measure_cycles: 50_000.0, ..Default::default() };
        let a = DesSimulator::new(&m, cfg.clone()).run(&ws);
        let b = DesSimulator::new(&m, cfg).run(&ws);
        assert_eq!(a.per_core_gbs, b.per_core_gbs);
    }
}
