//! `repro` — the Layer-3 coordinator CLI.
//!
//! Subcommands (hand-rolled argument parsing; the build is fully offline):
//!
//! ```text
//! repro machines                        # Table I
//! repro kernels                         # kernel registry
//! repro characterize [--engine E]       # Table II (f, b_s per kernel)
//! repro pair --machine M --k1 A --k2 B --n1 X --n2 Y [--engine E]
//! repro scenarios [--machine M] [--engine E] [--out results/]
//!                 [--mix "dcopy:4+ddot2:4+idle:2 / dcopy:8+stream:2"]
//!                 [--topology domain|socket|<D>|<S>x<D>|snc<N>|<S>xsnc<N>|<N>n<spec>]
//!                 [--placement compact|scatter] [--remote-frac F]
//!                 [--name NAME]            # k-group share tables
//!                 # topology mixes take @dN / @scatter / @compact pins and
//!                 # %r remote-access fractions:
//!                 #   --topology 2x4 --mix "dcopy:32@scatter%r0.25+ddot2:32@scatter"
//! repro experiment <table2|fig1|fig3|fig4|fig6|fig7|fig8|fig9|all>
//!                  [--engine fluid|des|pjrt] [--out results/]
//! repro hpcg [--variant plain|modified] [--machine M] [--ranks N]
//!            [--topology domain|socket|<D>|<S>x<D>|snc<N>|<S>xsnc<N>|<N>n<spec>]
//!            [--placement compact|scatter] [--remote-frac F]
//!            [--engine ecm|fluid|des|pjrt]   # characterization source
//! repro optimize [--machine M] [--topology <S>x<D>|...] [--mix "dcopy:8+ddot2:8"]
//!                [--objective throughput|makespan|max-interference]
//!                [--starts N] [--beam B] [--budget N] [--seed S]
//!                [--gb-per-core G] [--engine ecm|fluid|des|pjrt] [--out results/]
//!                # placement search: `@dN` pins and `%r` fractions in the
//!                # mix are hard constraints; everything else is searched
//! repro serve [--machine M] [--topology <S>x<D>|...] [--file requests.jsonl]
//!             [--objective throughput|makespan|max-interference]
//!             [--starts N] [--beam B] [--budget N] [--seed S]
//!             [--gb-per-core G] [--repack-every N] [--probe-slice S]
//!             [--out results/]
//!             # streaming co-scheduler: line-delimited JSON requests
//!             # (submit/finish/query/snapshot) from --file or stdin;
//!             # response lines on stdout (docs/CLI.md has the grammar)
//! repro bench [--mode smoke|full] [--out results/]
//!             # BENCH_{cosim,topology,multi_iface,cache,cluster,optimizer,serve}.json
//! repro dump-configs <dir>              # write machine TOMLs
//! repro selftest                        # PJRT artifact vs rust engines
//! ```
//!
//! Flag parsing is strict: a flag without a value and an unknown flag are
//! both hard errors (`--machine --engine des` no longer swallows
//! `--engine` as the machine name).

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use membw::config::{builtin_machines, machine, machine_by_name, machine_to_toml, MachineId};
use membw::desync::{hpcg_program, CoSimConfig, CoSimEngine, HpcgVariant, NoiseModel, SimStats};
use membw::error::Result;
use membw::kernels::{all_kernels, kernel, KernelId};
use membw::optimizer::{optimize, Objective, SearchConfig, SearchSpace};
use membw::report::{self, ExperimentCtx};
use membw::runtime::{ArtifactPaths, PjrtRuntime, PjrtSimExecutor, SimCase};
use membw::scenario::{run_mixes, run_mixes_on, CharCache, CharSource, Mix, Scenario};
use membw::service::{service_memo, ServeConfig, Service};
use membw::simulator::{measure_f_bs, measure_pairing, CoreWorkload, Engine};
use membw::sweep::{run_cases, MeasureEngine, PairingCase};
use membw::topology::{GroupPlacement, Placement, Topology};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Parse `--key value` flags from the tail of an argument list.
///
/// Strict: every flag must carry a value and appear in `allowed`; a value
/// may not itself look like a flag. Both misuses are errors instead of the
/// silent mis-parses the old parser produced.
fn flags(args: &[String], allowed: &[&str]) -> Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let arg = &args[i];
        let key = match arg.strip_prefix("--") {
            Some(k) => k,
            None => {
                return Err(membw::Error::InvalidPlan(format!(
                    "unexpected argument '{arg}' (expected a --flag)"
                )));
            }
        };
        if !allowed.contains(&key) {
            return Err(membw::Error::InvalidPlan(format!(
                "unknown flag --{key} (expected: {})",
                allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(", ")
            )));
        }
        match args.get(i + 1) {
            Some(v) if !v.starts_with("--") => {
                map.insert(key.to_string(), v.clone());
                i += 2;
            }
            _ => {
                return Err(membw::Error::InvalidPlan(format!(
                    "flag --{key} requires a value"
                )));
            }
        }
    }
    Ok(map)
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest: &[String] = if args.len() > 1 { &args[1..] } else { &[] };
    match cmd {
        "machines" => cmd_machines(),
        "kernels" => cmd_kernels(),
        "characterize" => cmd_characterize(&flags(rest, &["engine", "out"])?),
        "pair" => cmd_pair(&flags(rest, &["machine", "k1", "k2", "n1", "n2", "engine"])?),
        "scenarios" => cmd_scenarios(&flags(
            rest,
            &["machine", "engine", "out", "mix", "name", "topology", "placement", "remote-frac"],
        )?),
        "experiment" => cmd_experiment(rest),
        "hpcg" => cmd_hpcg(&flags(
            rest,
            &[
                "variant",
                "machine",
                "ranks",
                "nx",
                "iterations",
                "engine",
                "topology",
                "placement",
                "remote-frac",
            ],
        )?),
        "optimize" => cmd_optimize(&flags(
            rest,
            &[
                "machine",
                "topology",
                "mix",
                "objective",
                "starts",
                "beam",
                "budget",
                "seed",
                "gb-per-core",
                "engine",
                "out",
            ],
        )?),
        "serve" => cmd_serve(&flags(
            rest,
            &[
                "machine",
                "topology",
                "objective",
                "starts",
                "beam",
                "budget",
                "seed",
                "gb-per-core",
                "repack-every",
                "probe-slice",
                "file",
                "out",
            ],
        )?),
        "bench" => cmd_bench(&flags(rest, &["mode", "out"])?),
        "dump-configs" => cmd_dump_configs(rest),
        "selftest" => cmd_selftest(&flags(rest, &["tol"])?),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "repro — bandwidth-sharing model reproduction (Afzal/Hager/Wellein 2020)\n\
commands:\n  machines | kernels | characterize | pair | scenarios | experiment <id> | hpcg | optimize | serve | bench | dump-configs <dir> | selftest\n\
run `repro experiment all --out results/` to regenerate every table and figure;\n\
`repro scenarios --mix \"dcopy:4+ddot2:4+idle:2\"` measures a k-group workload mix;\n\
`repro scenarios --machine rome --topology socket --mix \"dcopy:16@scatter+ddot2:16@scatter\"`\n\
  resolves a mix onto the four NPS4 ccNUMA domains (per-domain + socket tables);\n\
`repro scenarios --machine rome --topology 2x4 --remote-frac 0.25 --mix \"dcopy:32@scatter+ddot2:32@scatter\"`\n\
  runs a dual-socket Rome with remote accesses crossing the xGMI link (per-link tables);\n\
`repro hpcg --machine rome --topology socket` co-simulates a full 32-rank Rome socket;\n\
`repro optimize --machine rome --topology 2x4 --mix \"dcopy:8+ddot2:8+stream:8+daxpy:8\"`\n\
  searches home domains and %r fractions for the best placement (docs/OPTIMIZER.md);\n\
`repro serve --file session.jsonl` runs the streaming co-scheduler: jobs\n\
  submitted/retired over line-delimited JSON, admitted by exact residual\n\
  search with a shared score memo and a checkpoint-resumed makespan probe;\n\
`repro bench` runs the fixed-seed benchmarks and writes BENCH_cosim.json,\n\
  BENCH_topology.json, BENCH_multi_iface.json, BENCH_cache.json\n\
  (shared-L3 cache-topology mixes), BENCH_cluster.json\n\
  (the 64-node cluster co-sim: incremental re-rating vs full recompute),\n\
  BENCH_optimizer.json (placement-search evaluation throughput)\n\
  and BENCH_serve.json (amortized admissions vs per-request cold optimize);\n\
see docs/CLI.md for every flag with sample output.";

fn cmd_machines() -> Result<()> {
    println!("{}", report::table1_report());
    Ok(())
}

fn cmd_kernels() -> Result<()> {
    let mut t = report::AsciiTable::new(&["kernel", "class", "body", "mem(R+W+RFO)", "B_c [B/F]"]);
    for (_, k) in all_kernels() {
        let bc = if k.code_balance.is_finite() { format!("{:.2}", k.code_balance) } else { "—".into() };
        t.row(vec![
            k.name.clone(),
            format!("{:?}", k.class),
            k.body.clone(),
            format!("{} ({}+{}+{})", k.mem.total(), k.mem.reads, k.mem.writes, k.mem.rfo),
            bc,
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn parse_engine(f: &HashMap<String, String>) -> Result<Engine> {
    match f.get("engine").map(String::as_str) {
        None | Some("fluid") => Ok(Engine::Fluid),
        Some(other) => Engine::parse(other),
    }
}

fn cmd_characterize(f: &HashMap<String, String>) -> Result<()> {
    let engine = parse_engine(f)?;
    let out = f.get("out").cloned().unwrap_or_else(|| "results".into());
    let ctx = ExperimentCtx { out_dir: PathBuf::from(out), engine, pjrt: None };
    println!("{}", report::table2_report(&ctx)?);
    Ok(())
}

fn cmd_pair(f: &HashMap<String, String>) -> Result<()> {
    let m = machine_by_name(f.get("machine").map(String::as_str).unwrap_or("clx"))?;
    let k1 = KernelId::parse(f.get("k1").map(String::as_str).unwrap_or("dcopy"))?;
    let k2 = KernelId::parse(f.get("k2").map(String::as_str).unwrap_or("ddot2"))?;
    let n1: usize = f.get("n1").and_then(|s| s.parse().ok()).unwrap_or(m.cores / 2);
    let n2: usize = f.get("n2").and_then(|s| s.parse().ok()).unwrap_or(m.cores - m.cores / 2);
    let engine = parse_engine(f)?;

    let meas = measure_pairing(&m, &kernel(k1), n1, &kernel(k2), n2, engine);
    let c1 = measure_f_bs(&kernel(k1), &m, engine);
    let c2 = measure_f_bs(&kernel(k2), &m, engine);
    let pred = membw::sharing::share_two_groups(
        &membw::sharing::KernelGroup { n: n1, f: c1.f, bs_gbs: c1.bs_gbs },
        &membw::sharing::KernelGroup { n: n2, f: c2.f, bs_gbs: c2.bs_gbs },
    );
    println!(
        "{} : {} x{}  +  {} x{}   [{:?}]",
        m.name,
        kernel(k1).name,
        n1,
        kernel(k2).name,
        n2,
        engine
    );
    println!(
        "  kernel I : f={:.3} bs={:.1}  measured {:.2} GB/s/core, model {:.2} GB/s/core",
        c1.f, c1.bs_gbs, meas.per_core_gbs[0], pred.per_core_gbs[0]
    );
    println!(
        "  kernel II: f={:.3} bs={:.1}  measured {:.2} GB/s/core, model {:.2} GB/s/core",
        c2.f, c2.bs_gbs, meas.per_core_gbs[1], pred.per_core_gbs[1]
    );
    println!(
        "  total    : measured {:.1} GB/s, model {:.1} GB/s",
        meas.total_gbs,
        pred.group_bw_gbs[0] + pred.group_bw_gbs[1]
    );
    Ok(())
}

/// Parse an optional `--remote-frac` value (a number in `[0, 1]`).
fn parse_remote_frac(f: &HashMap<String, String>) -> Result<Option<f64>> {
    match f.get("remote-frac") {
        None => Ok(None),
        Some(s) => match s.trim().parse::<f64>() {
            Ok(v) if v.is_finite() && (0.0..=1.0).contains(&v) => Ok(Some(v)),
            _ => Err(membw::Error::InvalidPlan(format!(
                "bad --remote-frac '{s}' (expected a number in [0, 1])"
            ))),
        },
    }
}

/// Measure a k-group workload mix (or `/`-separated scenario) and print the
/// per-group share table. Without `--mix`, runs the built-in demo scenario
/// scaled to the machine. With `--topology socket` (or `<D>`, `<S>x<D>`,
/// `snc<N>`, a `<N>n<spec>` cluster) the mix is resolved onto the ccNUMA
/// domains by `--placement`
/// compact|scatter (plus any `@dN` pins in the mix) and per-domain +
/// socket-aggregate tables are printed; `--remote-frac F` (or per-group
/// `%rF` suffixes) splits cache-line streams over remote domains and the
/// inter-socket links, adding per-link tables.
fn cmd_scenarios(f: &HashMap<String, String>) -> Result<()> {
    let m = machine_by_name(f.get("machine").map(String::as_str).unwrap_or("clx"))?;
    let ctx = make_ctx(f)?;
    let scenario = match f.get("mix") {
        Some(spec) => Scenario::parse(f.get("name").map(String::as_str).unwrap_or("cli"), spec)?,
        None => Scenario::demo(&m),
    };
    let remote_frac = parse_remote_frac(f)?;
    let text = match f.get("topology") {
        Some(spec) => {
            let topo = Topology::parse(&m, spec)?;
            let placement =
                Placement::parse(f.get("placement").map(String::as_str).unwrap_or("compact"))?;
            let scenario = match remote_frac {
                Some(frac) => scenario.with_default_remote(frac),
                None => scenario,
            };
            report::topology_scenario_report(&ctx, &topo, placement, &scenario)?
        }
        None => {
            if f.contains_key("placement") {
                return Err(membw::Error::InvalidPlan(
                    "--placement requires --topology".into(),
                ));
            }
            if remote_frac.is_some() {
                return Err(membw::Error::InvalidPlan(
                    "--remote-frac requires --topology".into(),
                ));
            }
            // Mix-embedded pins (`@dN`/`@scatter`/`@compact`) and remote
            // fractions would be silently meaningless on the flat
            // single-domain path.
            if scenario
                .mixes
                .iter()
                .any(|mx| mx.groups.iter().any(|g| g.place != GroupPlacement::Auto))
            {
                return Err(membw::Error::InvalidPlan(
                    "mix placement suffixes (@dN, @scatter, @compact) require --topology".into(),
                ));
            }
            if scenario.has_remote() {
                return Err(membw::Error::InvalidPlan(
                    "mix remote fractions (%rF) require --topology".into(),
                ));
            }
            report::scenario_report(&ctx, &m, &scenario)?
        }
    };
    println!("{text}");
    std::fs::write(
        ctx.out_dir.join(format!("scenario_{}.txt", scenario.file_stem())),
        &text,
    )?;
    Ok(())
}

fn make_ctx(f: &HashMap<String, String>) -> Result<ExperimentCtx> {
    let out = PathBuf::from(f.get("out").cloned().unwrap_or_else(|| "results".into()));
    match f.get("engine").map(String::as_str) {
        Some("pjrt") => {
            let runtime = PjrtRuntime::cpu()?;
            eprintln!("# PJRT: {}", runtime.platform());
            let exec = PjrtSimExecutor::load(&runtime, &ArtifactPaths::default_dir())?;
            Ok(ExperimentCtx { out_dir: out, engine: Engine::Fluid, pjrt: Some(exec) })
        }
        Some("des") => Ok(ExperimentCtx { out_dir: out, engine: Engine::Des, pjrt: None }),
        None | Some("fluid") => Ok(ExperimentCtx { out_dir: out, engine: Engine::Fluid, pjrt: None }),
        Some(other) => Err(membw::Error::InvalidPlan(format!(
            "unknown engine '{other}' (fluid, des, pjrt)"
        ))),
    }
}

fn cmd_experiment(rest: &[String]) -> Result<()> {
    let id = rest.first().map(String::as_str).unwrap_or("all");
    let f = flags(if rest.len() > 1 { &rest[1..] } else { &[] }, &["engine", "out"])?;
    let ctx = make_ctx(&f)?;
    std::fs::create_dir_all(&ctx.out_dir)?;
    let run = |name: &str, text: String| {
        println!("{text}");
        let path = ctx.out_dir.join(format!("{name}.txt"));
        let _ = std::fs::write(path, text);
    };
    match id {
        "table1" => run("table1", report::table1_report()),
        "table2" => run("table2", report::table2_report(&ctx)?),
        "fig1" => run("fig1", report::fig1_report(&ctx)?),
        "fig3" => run("fig3", report::fig3_report(&ctx)?),
        "fig4" => run("fig4", report::fig4_report()),
        "fig6" => run("fig6", report::fig6_report(&ctx)?),
        "fig7" => run("fig7", report::fig7_report(&ctx)?),
        "fig8" => run("fig8", report::fig8_report(&ctx)?),
        "fig9" => run("fig9", report::fig9_report(&ctx)?),
        "ablation" => run("ablation", report::ablation_report(&ctx)?),
        "all" => {
            run("table1", report::table1_report());
            run("table2", report::table2_report(&ctx)?);
            run("fig4", report::fig4_report());
            run("fig6", report::fig6_report(&ctx)?);
            run("fig7", report::fig7_report(&ctx)?);
            run("fig8", report::fig8_report(&ctx)?);
            run("fig9", report::fig9_report(&ctx)?);
            run("ablation", report::ablation_report(&ctx)?);
            run("fig1", report::fig1_report(&ctx)?);
            run("fig3", report::fig3_report(&ctx)?);
        }
        other => {
            return Err(membw::Error::InvalidPlan(format!("unknown experiment '{other}'")));
        }
    }
    Ok(())
}

fn cmd_hpcg(f: &HashMap<String, String>) -> Result<()> {
    let variant = match f.get("variant").map(String::as_str) {
        Some("modified") => HpcgVariant::Modified,
        None | Some("plain") => HpcgVariant::Plain,
        Some(other) => {
            return Err(membw::Error::InvalidPlan(format!(
                "unknown variant '{other}' (plain, modified)"
            )));
        }
    };
    let m = machine_by_name(f.get("machine").map(String::as_str).unwrap_or("clx"))?;
    let topo = match f.get("topology") {
        Some(spec) => Some(Topology::parse(&m, spec)?),
        None => {
            if f.contains_key("placement") {
                return Err(membw::Error::InvalidPlan(
                    "--placement requires --topology".into(),
                ));
            }
            if f.contains_key("remote-frac") {
                return Err(membw::Error::InvalidPlan(
                    "--remote-frac requires --topology".into(),
                ));
            }
            None
        }
    };
    let placement =
        Placement::parse(f.get("placement").map(String::as_str).unwrap_or("compact"))?;
    let remote_frac = parse_remote_frac(f)?;
    let default_ranks = topo.as_ref().map(|t| t.total_cores()).unwrap_or(m.cores);
    let ranks: usize = f.get("ranks").and_then(|s| s.parse().ok()).unwrap_or(default_ranks);
    let nx: usize = f.get("nx").and_then(|s| s.parse().ok()).unwrap_or(96);
    let iters: usize = f.get("iterations").and_then(|s| s.parse().ok()).unwrap_or(2);
    let engine_key = f.get("engine").map(String::as_str).unwrap_or("ecm");

    // The PJRT executor must outlive the characterization source.
    let pjrt_exec: Option<PjrtSimExecutor> = if engine_key == "pjrt" {
        let runtime = PjrtRuntime::cpu()?;
        eprintln!("# PJRT: {}", runtime.platform());
        Some(PjrtSimExecutor::load(&runtime, &ArtifactPaths::default_dir())?)
    } else {
        None
    };
    let source = match engine_key {
        "ecm" => CharSource::Ecm,
        "fluid" => CharSource::Measured(MeasureEngine::Fluid),
        "des" => CharSource::Measured(MeasureEngine::Des),
        "pjrt" => CharSource::Measured(MeasureEngine::Pjrt(pjrt_exec.as_ref().unwrap())),
        other => {
            return Err(membw::Error::InvalidPlan(format!(
                "unknown characterization engine '{other}' (ecm, fluid, des, pjrt)"
            )));
        }
    };

    let prog = hpcg_program(variant, nx, iters);
    let cfg = CoSimConfig {
        dt_s: 20e-6,
        t_max_s: 900.0,
        initial_stagger_s: 0.2e-3,
        neighbor_radius: 3,
        noise: NoiseModel::mild(42),
    };
    let eng = match (&topo, remote_frac) {
        (Some(t), Some(frac)) => CoSimEngine::with_topology_remote(
            &m, t, placement, frac, prog, ranks, cfg, &source,
        )?,
        (Some(t), None) => CoSimEngine::with_topology(&m, t, placement, prog, ranks, cfg, &source)?,
        (None, _) => CoSimEngine::with_source(&m, prog, ranks, cfg, &source)?,
    };
    let t0 = Instant::now();
    let r = eng.run();
    let wall = t0.elapsed().as_secs_f64();
    match &topo {
        Some(t) => println!(
            "HPCG ({variant:?}) on {} [topology {}, placement {}{}]: {ranks} ranks, nx={nx}, {iters} iterations, chars: {}",
            m.name,
            t.label(),
            placement.name(),
            remote_frac.map(|fr| format!(", remote {fr}")).unwrap_or_default(),
            source.name()
        ),
        None => println!(
            "HPCG ({variant:?}) on {}: {ranks} ranks, nx={nx}, {iters} iterations, chars: {}",
            m.name,
            source.name()
        ),
    }
    println!(
        "simulated time: {:.3} s, {} phase records, {} events, {:.1} ms wall",
        r.t_end_s,
        r.trace.records.len(),
        r.events,
        wall * 1e3
    );
    if let Some(rec) = r.trace.of("DDOT2#1", Some(iters.saturating_sub(1))).first() {
        let t0 = rec.t_start - 0.01;
        println!("{}", r.trace.render_ascii(t0, t0 + 0.06, ranks, 110));
    }
    Ok(())
}

/// Search placements of a k-group mix over a ccNUMA topology with the
/// analytic model as the scoring inner loop (`docs/OPTIMIZER.md`). `@dN`
/// pins and explicit `%r` fractions in the mix are hard constraints; free
/// groups get their home domain and remote fraction searched. Prints the
/// incumbent trace and winner tables, writes `optimizer_<topology>.{txt,csv}`
/// under `--out`.
fn cmd_optimize(f: &HashMap<String, String>) -> Result<()> {
    let m = machine_by_name(f.get("machine").map(String::as_str).unwrap_or("rome"))?;
    let topo = Topology::parse(&m, f.get("topology").map(String::as_str).unwrap_or("2x4"))?;
    let mix = Mix::parse(
        f.get("mix").map(String::as_str).unwrap_or("dcopy:8+ddot2:8+stream:8+daxpy:8"),
    )?;
    let engine_key = f.get("engine").map(String::as_str).unwrap_or("ecm");
    // The PJRT executor must outlive the characterization source.
    let pjrt_exec: Option<PjrtSimExecutor> = if engine_key == "pjrt" {
        let runtime = PjrtRuntime::cpu()?;
        eprintln!("# PJRT: {}", runtime.platform());
        Some(PjrtSimExecutor::load(&runtime, &ArtifactPaths::default_dir())?)
    } else {
        None
    };
    let source = match engine_key {
        "ecm" => CharSource::Ecm,
        "fluid" => CharSource::Measured(MeasureEngine::Fluid),
        "des" => CharSource::Measured(MeasureEngine::Des),
        "pjrt" => CharSource::Measured(MeasureEngine::Pjrt(pjrt_exec.as_ref().unwrap())),
        other => {
            return Err(membw::Error::InvalidPlan(format!(
                "unknown characterization engine '{other}' (ecm, fluid, des, pjrt)"
            )));
        }
    };

    // Characterize against the base machine: RemoteGroup.bs_gbs is the
    // nominal saturated bandwidth; the model scales per portion through
    // shape.bw_scale (same convention as the scenario runner).
    let mut kernels: Vec<KernelId> = mix.groups.iter().map(|g| g.kernel).collect();
    kernels.sort_by_key(|k| k.key());
    kernels.dedup();
    let meas = CharCache::global().characterize_source(&topo.base, &kernels, &source)?;
    let chars: HashMap<KernelId, (f64, f64)> =
        meas.iter().map(|(&k, c)| (k, (c.f, c.bs_gbs))).collect();
    let space = SearchSpace::from_mix(&topo, &mix, &chars)?;

    let parse_num = |key: &str, default: usize| -> Result<usize> {
        match f.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                membw::Error::InvalidPlan(format!("--{key} expects an integer, got '{v}'"))
            }),
        }
    };
    let defaults = SearchConfig::default();
    let cfg = SearchConfig {
        objective: Objective::parse(
            f.get("objective").map(String::as_str).unwrap_or("throughput"),
        )?,
        seed: parse_num("seed", defaults.seed as usize)? as u64,
        starts: parse_num("starts", defaults.starts)?,
        beam: parse_num("beam", defaults.beam)?,
        budget: parse_num("budget", defaults.budget)?,
        gb_per_core: match f.get("gb-per-core") {
            None => defaults.gb_per_core,
            Some(v) => v.parse().map_err(|_| {
                membw::Error::InvalidPlan(format!("--gb-per-core expects a number, got '{v}'"))
            })?,
        },
        ..defaults
    };

    let result = optimize(&space, &cfg)?;
    let out = PathBuf::from(f.get("out").cloned().unwrap_or_else(|| "results".into()));
    // The report only needs the output directory; `--engine` above picks the
    // characterization source, not a measurement engine.
    let ctx = ExperimentCtx { out_dir: out, engine: Engine::Fluid, pjrt: None };
    let text = report::optimizer_report(&ctx, &topo, &space, &cfg, &result)?;
    println!("{text}");
    std::fs::write(ctx.out_dir.join(format!("optimizer_{}.txt", topo.label())), &text)?;
    Ok(())
}

/// The streaming co-scheduling service (`docs/CLI.md` has the request
/// grammar and a worked session). Requests come line-delimited from
/// `--file` (blank lines and `#` comments skipped) or stdin; response
/// lines go to stdout — stdout carries *only* protocol lines, so a
/// session can be piped. The full response log is also written to
/// `serve_session.json` and a human-readable transcript to
/// `serve_<topology>.txt` under `--out` (progress notes go to stderr).
/// Characterization is always ECM: the serve path must be deterministic
/// and replayable, which measured engines are not across hosts.
fn cmd_serve(f: &HashMap<String, String>) -> Result<()> {
    let m = machine_by_name(f.get("machine").map(String::as_str).unwrap_or("rome"))?;
    let topo = Topology::parse(&m, f.get("topology").map(String::as_str).unwrap_or("2x4"))?;
    let parse_num = |key: &str, default: usize| -> Result<usize> {
        match f.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                membw::Error::InvalidPlan(format!("--{key} expects an integer, got '{v}'"))
            }),
        }
    };
    let parse_f64 = |key: &str, default: f64| -> Result<f64> {
        match f.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                membw::Error::InvalidPlan(format!("--{key} expects a number, got '{v}'"))
            }),
        }
    };
    let defaults = ServeConfig::default();
    let cfg = ServeConfig {
        objective: Objective::parse(
            f.get("objective").map(String::as_str).unwrap_or("throughput"),
        )?,
        seed: parse_num("seed", defaults.seed as usize)? as u64,
        starts: parse_num("starts", defaults.starts)?,
        beam: parse_num("beam", defaults.beam)?,
        budget: parse_num("budget", defaults.budget)?,
        gb_per_core: parse_f64("gb-per-core", defaults.gb_per_core)?,
        repack_every: parse_num("repack-every", defaults.repack_every)?,
        probe_slice_s: parse_f64("probe-slice", defaults.probe_slice_s)?,
    };
    let lines: Vec<String> = match f.get("file") {
        Some(path) => std::fs::read_to_string(path)?.lines().map(str::to_string).collect(),
        None => {
            use std::io::BufRead as _;
            let stdin = std::io::stdin();
            let mut v = Vec::new();
            for line in stdin.lock().lines() {
                v.push(line?);
            }
            v
        }
    };

    let mut service = Service::new(topo.clone(), cfg.clone(), CharSource::Ecm);
    let mut transcript: Vec<(String, String)> = Vec::new();
    for line in &lines {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let resp = service.handle_line(line);
        println!("{resp}");
        transcript.push((line.to_string(), resp));
    }

    let out_dir = PathBuf::from(f.get("out").cloned().unwrap_or_else(|| "results".into()));
    std::fs::create_dir_all(&out_dir)?;
    let log: String = transcript.iter().map(|(_, r)| format!("{r}\n")).collect();
    let log_path = out_dir.join("serve_session.json");
    std::fs::write(&log_path, &log)?;
    let text = report::serve_report(&topo, &cfg, &transcript, &service);
    let txt_path = out_dir.join(format!("serve_{}.txt", topo.label()));
    std::fs::write(&txt_path, &text)?;
    eprintln!("wrote {} and {}", log_path.display(), txt_path.display());
    Ok(())
}

/// Fixed-seed performance benchmarks: the Fig. 3 co-simulation, a
/// scenario-pipeline workload, the 4-domain Rome-socket topology co-sim,
/// the multi-interface remote-access pipeline vs its single-interface
/// baseline, and the 64-node cluster co-sim (incremental re-rating vs the
/// full-recompute reference), plus the placement-optimizer search
/// (delta + parallel + memo vs a sequential full-re-solve baseline on an
/// 8-group dual-socket Rome mix), and the cache-topology pipeline
/// (explicit `@l3` groups contending at a shared-L3 node next to DRAM
/// streams), and the serve session (amortized streaming admissions
/// against per-request cold optimize runs). Emits `BENCH_cosim.json`,
/// `BENCH_topology.json`, `BENCH_multi_iface.json`, `BENCH_cache.json`,
/// `BENCH_cluster.json`, `BENCH_optimizer.json`, and `BENCH_serve.json`
/// under `--out` (CI uploads all as artifacts,
/// checks their existence, and gates events/s regressions against the
/// committed baselines). Every payload carries the cache counters of the
/// run: the shared characterization cache plus, for co-sims, the
/// per-domain share memos and the remote rate-model memo, and for the
/// optimizer, the sharded score-memo counters.
fn cmd_bench(f: &HashMap<String, String>) -> Result<()> {
    let out_dir = PathBuf::from(f.get("out").cloned().unwrap_or_else(|| "results".into()));
    let smoke = match f.get("mode").map(String::as_str) {
        Some("smoke") => true,
        None | Some("full") => false,
        Some(other) => {
            return Err(membw::Error::InvalidPlan(format!(
                "unknown bench mode '{other}' (smoke, full)"
            )));
        }
    };
    std::fs::create_dir_all(&out_dir)?;
    let reps = if smoke { 1 } else { 5 };

    // --- co-sim: the Fig. 3 configuration, fixed seed, with and without
    // noise (noise off is the exact-equivalence configuration of the golden
    // suite and the headline-speedup pin; mild(7) is the figure run) ---
    let m = machine(MachineId::Clx);
    let ranks = 20;
    let fig3_cfg = |noise: NoiseModel| CoSimConfig {
        dt_s: 20e-6,
        t_max_s: 600.0,
        initial_stagger_s: 0.2e-3,
        neighbor_radius: 3,
        noise,
    };
    struct CosimRow {
        tag: &'static str,
        wall_s: f64,
        events: u64,
        records: usize,
        legacy_wall_s: Option<f64>,
        speedup: Option<f64>,
        stats: SimStats,
    }
    // Cache counters as a JSON object, shared by every BENCH payload.
    let stats_json = |s: &SimStats| {
        format!(
            "{{ \"rate_evals\": {}, \"node_rates_reused\": {}, \"share_hits\": {}, \
             \"share_misses\": {}, \"remote_hits\": {}, \"remote_misses\": {}, \
             \"remote_entries\": {}, \"memo_hits\": {}, \"memo_misses\": {}, \
             \"memo_entries\": {} }}",
            s.rate_evals,
            s.node_rates_reused,
            s.share_hits,
            s.share_misses,
            s.remote_hits,
            s.remote_misses,
            s.remote_entries,
            s.memo_hits,
            s.memo_misses,
            s.memo_entries,
        )
    };
    let char_cache_json = || {
        let s = CharCache::global().stats();
        format!(
            "{{ \"hits\": {}, \"misses\": {}, \"entries\": {} }}",
            s.hits, s.misses, s.entries
        )
    };
    let mut cosim_rows: Vec<CosimRow> = Vec::new();
    for (tag, noise) in [("noise_off", NoiseModel::off()), ("mild7", NoiseModel::mild(7))] {
        let prog = hpcg_program(HpcgVariant::Modified, 96, 3);
        let eng = CoSimEngine::new(&m, prog, ranks, fig3_cfg(noise))?;
        let warm = eng.run(); // warm-up (characterization cache, allocator)
        let mut walls = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            let r = eng.run();
            walls.push(t0.elapsed().as_secs_f64());
            assert_eq!(r.events, warm.events, "co-sim must be deterministic");
        }
        let event_wall = membw::stats::median(&walls);
        println!(
            "co-sim (fig3 {tag}, event engine): {:.3} ms wall, {} events ({:.2e} events/s), {} records",
            event_wall * 1e3,
            warm.events,
            warm.events as f64 / event_wall,
            warm.trace.records.len()
        );
        #[cfg(feature = "legacy-stepper")]
        let (legacy_wall, speedup) = {
            let t0 = Instant::now();
            let leg = eng.run_legacy();
            let w = t0.elapsed().as_secs_f64();
            println!(
                "co-sim (fig3 {tag}, legacy stepper): {:.1} ms wall, {} steps — speedup {:.1}x",
                w * 1e3,
                leg.events,
                w / event_wall
            );
            (Some(w), Some(w / event_wall))
        };
        #[cfg(not(feature = "legacy-stepper"))]
        let (legacy_wall, speedup): (Option<f64>, Option<f64>) = {
            println!("co-sim (fig3 {tag}) legacy stepper: skipped (build with --features legacy-stepper)");
            (None, None)
        };
        cosim_rows.push(CosimRow {
            tag,
            wall_s: event_wall,
            events: warm.events,
            records: warm.trace.records.len(),
            legacy_wall_s: legacy_wall,
            speedup,
            stats: warm.stats,
        });
    }

    // --- scenario pipeline: fixed mix list on the fluid engine ---
    let mix_specs: &[&str] = if smoke {
        &["dcopy:10+ddot2:10", "schoenauer:8+ddot2:6+idle:6"]
    } else {
        &[
            "dcopy:10+ddot2:10",
            "schoenauer:8+ddot2:6+idle:6",
            "daxpy:5+waxpby:5+stream:5+add:5",
            "stream:20",
            "jacobil2-v1:10+ddot1:10",
            "vecsum:4+dscal:4+ddot3:4+idle:8",
        ]
    };
    let mixes: Vec<Mix> = mix_specs.iter().copied().map(Mix::parse).collect::<Result<Vec<_>>>()?;
    run_mixes(&m, &mixes, &MeasureEngine::Fluid)?; // warm the char cache
    let mut swalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        run_mixes(&m, &mixes, &MeasureEngine::Fluid)?;
        swalls.push(t0.elapsed().as_secs_f64());
    }
    let scen_wall = membw::stats::median(&swalls);
    let cases_per_s = mixes.len() as f64 / scen_wall;
    println!(
        "scenario pipeline (fluid): {} mixes in {:.3} ms ({:.1} cases/s)",
        mixes.len(),
        scen_wall * 1e3,
        cases_per_s
    );

    // --- topology: a full NPS4 Rome socket (32 ranks, four concurrent
    // per-domain contention timelines) plus a 4-domain scenario pipeline;
    // emitted as BENCH_topology.json to start the topology perf trajectory ---
    let rome = machine(MachineId::Rome);
    let rome_socket = Topology::socket(&rome);
    struct TopoRow {
        tag: &'static str,
        wall_s: f64,
        events: u64,
        records: usize,
    }
    let mut topo_rows: Vec<TopoRow> = Vec::new();
    for (tag, noise) in [("noise_off", NoiseModel::off()), ("mild7", NoiseModel::mild(7))] {
        let prog = hpcg_program(HpcgVariant::Modified, 96, 3);
        let eng = CoSimEngine::with_topology(
            &rome,
            &rome_socket,
            Placement::Compact,
            prog,
            rome_socket.total_cores(),
            fig3_cfg(noise),
            &CharSource::Ecm,
        )?;
        let warm = eng.run();
        let mut walls = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            let r = eng.run();
            walls.push(t0.elapsed().as_secs_f64());
            assert_eq!(r.events, warm.events, "topology co-sim must be deterministic");
        }
        let wall = membw::stats::median(&walls);
        println!(
            "co-sim (rome socket {tag}, 4 domains x 8 ranks): {:.3} ms wall, {} events ({:.2e} events/s), {} records",
            wall * 1e3,
            warm.events,
            warm.events as f64 / wall,
            warm.trace.records.len()
        );
        topo_rows.push(TopoRow {
            tag,
            wall_s: wall,
            events: warm.events,
            records: warm.trace.records.len(),
        });
    }
    let topo_mix_specs = [
        "dcopy:8@d0+ddot2:8@d1+stream:8@d2+daxpy:8@d3",
        "schoenauer:16@scatter+ddot2:16@scatter",
        "dcopy:32",
    ];
    let topo_mixes: Vec<Mix> =
        topo_mix_specs.iter().copied().map(Mix::parse).collect::<Result<Vec<_>>>()?;
    run_mixes_on(&rome_socket, Placement::Compact, &topo_mixes, &MeasureEngine::Fluid)?; // warm
    let mut twalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        run_mixes_on(&rome_socket, Placement::Compact, &topo_mixes, &MeasureEngine::Fluid)?;
        twalls.push(t0.elapsed().as_secs_f64());
    }
    let topo_scen_wall = membw::stats::median(&twalls);
    let topo_cases_per_s = topo_mixes.len() as f64 / topo_scen_wall;
    println!(
        "topology scenario pipeline (fluid, rome socket): {} mixes in {:.3} ms ({:.1} cases/s)",
        topo_mixes.len(),
        topo_scen_wall * 1e3,
        topo_cases_per_s
    );
    let topo_json_rows: Vec<String> = topo_rows
        .iter()
        .map(|row| {
            format!(
                "    {{\n      \"variant\": \"hpcg_rome_socket_32ranks_nx96_it3_{}\",\n      \"topology\": \"{}\",\n      \"placement\": \"compact\",\n      \"wall_s\": {:.6},\n      \"events\": {},\n      \"events_per_s\": {:.1},\n      \"phase_records\": {}\n    }}",
                row.tag,
                rome_socket.label(),
                row.wall_s,
                row.events,
                row.events as f64 / row.wall_s,
                row.records,
            )
        })
        .collect();
    let topo_json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"cosim\": [\n{}\n  ],\n  \"scenario\": {{\n    \"engine\": \"fluid\",\n    \"topology\": \"{}\",\n    \"cases\": {},\n    \"wall_s\": {:.6},\n    \"cases_per_s\": {:.1}\n  }},\n  \"char_cache\": {}\n}}\n",
        if smoke { "smoke" } else { "full" },
        topo_json_rows.join(",\n"),
        rome_socket.label(),
        topo_mixes.len(),
        topo_scen_wall,
        topo_cases_per_s,
        char_cache_json(),
    );
    let topo_path = out_dir.join("BENCH_topology.json");
    std::fs::write(&topo_path, &topo_json)?;
    println!("wrote {}", topo_path.display());

    // --- multi-interface substrate: remote-access mixes on a dual-socket
    // NPS4 Rome (one multi-interface fluid run per mix: 8 memory
    // interfaces + the xGMI link, per-core routed portions) against the
    // single-interface pipeline as the baseline; emitted as
    // BENCH_multi_iface.json (CI checks its existence) ---
    let rome2 = Topology::parse(&rome, "2x4")?;
    let remote_specs = [
        "dcopy:64@scatter%r0.5",
        "dcopy:32@scatter%r0.25+ddot2:32@scatter%r0.25",
        "dcopy:8@d0%r0.5+ddot2:8@d4",
    ];
    let remote_mixes: Vec<Mix> =
        remote_specs.iter().copied().map(Mix::parse).collect::<Result<Vec<_>>>()?;
    let remote_warm =
        run_mixes_on(&rome2, Placement::Compact, &remote_mixes, &MeasureEngine::Fluid)?;
    let mut mwalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        run_mixes_on(&rome2, Placement::Compact, &remote_mixes, &MeasureEngine::Fluid)?;
        mwalls.push(t0.elapsed().as_secs_f64());
    }
    let multi_wall = membw::stats::median(&mwalls);
    let multi_cases_per_s = remote_mixes.len() as f64 / multi_wall;
    let single_specs = ["dcopy:8", "dcopy:4+ddot2:4", "ddot2:8"];
    let single_mixes: Vec<Mix> =
        single_specs.iter().copied().map(Mix::parse).collect::<Result<Vec<_>>>()?;
    run_mixes(&rome, &single_mixes, &MeasureEngine::Fluid)?; // warm
    let mut bwalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        run_mixes(&rome, &single_mixes, &MeasureEngine::Fluid)?;
        bwalls.push(t0.elapsed().as_secs_f64());
    }
    let single_wall = membw::stats::median(&bwalls);
    let single_cases_per_s = single_mixes.len() as f64 / single_wall;
    println!(
        "multi-interface pipeline (fluid, rome 2x4 + xGMI): {} remote mixes in {:.3} ms \
         ({:.1} cases/s); single-interface baseline: {} mixes in {:.3} ms ({:.1} cases/s)",
        remote_mixes.len(),
        multi_wall * 1e3,
        multi_cases_per_s,
        single_mixes.len(),
        single_wall * 1e3,
        single_cases_per_s,
    );
    let case_rows: Vec<String> = remote_warm
        .cases
        .iter()
        .map(|case| {
            let link_gbs: f64 = case.links.iter().map(|l| l.measured_total_gbs).sum();
            format!(
                "    {{\n      \"mix\": \"{}\",\n      \"simulated_total_gbs\": {:.4},\n      \"model_total_gbs\": {:.4},\n      \"link_simulated_gbs\": {:.4}\n    }}",
                case.mix.label(),
                case.measured_total_gbs,
                case.model_total_gbs,
                link_gbs,
            )
        })
        .collect();
    let multi_json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"multi_iface\": {{\n    \"engine\": \"fluid\",\n    \"topology\": \"{}\",\n    \"link_capacity_gbs\": {:.1},\n    \"cases\": {},\n    \"wall_s\": {:.6},\n    \"cases_per_s\": {:.1}\n  }},\n  \"single_iface_baseline\": {{\n    \"engine\": \"fluid\",\n    \"cases\": {},\n    \"wall_s\": {:.6},\n    \"cases_per_s\": {:.1}\n  }},\n  \"case_detail\": [\n{}\n  ],\n  \"char_cache\": {}\n}}\n",
        if smoke { "smoke" } else { "full" },
        rome2.label(),
        rome.link_bw_gbs,
        remote_mixes.len(),
        multi_wall,
        multi_cases_per_s,
        single_mixes.len(),
        single_wall,
        single_cases_per_s,
        case_rows.join(",\n"),
        char_cache_json(),
    );
    let multi_path = out_dir.join("BENCH_multi_iface.json");
    std::fs::write(&multi_path, &multi_json)?;
    println!("wrote {}", multi_path.display());

    // --- cache-topology substrate: explicitly cache-bound (`@l3`) groups
    // contending at a shared-L3 interface alongside DRAM-bound streams, on
    // a single Rome domain with the paper's per-domain L3 estimate
    // (120 GB/s). Each mix runs through the topology pipeline (L3 node +
    // memory interface fixed point); emitted as BENCH_cache.json (CI
    // checks its existence and gates cases/s regressions) ---
    let mut rome_l3 = machine(MachineId::Rome);
    rome_l3.l3_bw_gbs = 120.0;
    let cache_topo = Topology::single(&rome_l3);
    let cache_specs = [
        "jacobil3-v1:4@l3+dcopy:4",
        "jacobil3-v1:8@l3",
        "jacobil3-v1:4@l3+ddot2:4",
    ];
    let cache_mixes: Vec<Mix> =
        cache_specs.iter().copied().map(Mix::parse).collect::<Result<Vec<_>>>()?;
    let cache_warm =
        run_mixes_on(&cache_topo, Placement::Compact, &cache_mixes, &MeasureEngine::Fluid)?;
    let mut cwalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        run_mixes_on(&cache_topo, Placement::Compact, &cache_mixes, &MeasureEngine::Fluid)?;
        cwalls.push(t0.elapsed().as_secs_f64());
    }
    let cache_wall = membw::stats::median(&cwalls);
    let cache_cases_per_s = cache_mixes.len() as f64 / cache_wall;
    // Classifier hit counters: how many socket-level groups the runner
    // routed to a shared-L3 node vs the memory interface across the sweep.
    let cache_groups_total: usize = cache_warm.cases.iter().map(|c| c.socket.len()).sum();
    let cache_groups_l3: usize = cache_warm
        .cases
        .iter()
        .map(|c| c.l3.iter().map(|r| r.origins.len()).sum::<usize>())
        .sum();
    println!(
        "cache-topology pipeline (fluid, rome 1 domain, l3_bw {:.0} GB/s): {} cache mixes \
         in {:.3} ms ({:.1} cases/s)",
        rome_l3.l3_bw_gbs,
        cache_mixes.len(),
        cache_wall * 1e3,
        cache_cases_per_s,
    );
    let cache_rows: Vec<String> = cache_warm
        .cases
        .iter()
        .map(|case| {
            // Exactly one shared-L3 record per case here (single socket,
            // every mix carries an @l3 group).
            let l3 = &case.l3[0];
            format!(
                "    {{\n      \"mix\": \"{}\",\n      \"simulated_total_gbs\": {:.4},\n      \"model_total_gbs\": {:.4},\n      \"l3_simulated_gbs\": {:.4},\n      \"l3_model_gbs\": {:.4},\n      \"l3_saturated\": {}\n    }}",
                case.mix.label(),
                case.measured_total_gbs,
                case.model_total_gbs,
                l3.measured_total_gbs,
                l3.model_total_gbs,
                l3.saturated,
            )
        })
        .collect();
    let cache_json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"cache\": {{\n    \"engine\": \"fluid\",\n    \"topology\": \"{}\",\n    \"l3_bw_gbs\": {:.1},\n    \"cases\": {},\n    \"wall_s\": {:.6},\n    \"cases_per_s\": {:.1}\n  }},\n  \"classifier\": {{\n    \"groups\": {},\n    \"l3_bound_groups\": {},\n    \"mem_bound_groups\": {}\n  }},\n  \"case_detail\": [\n{}\n  ],\n  \"char_cache\": {}\n}}\n",
        if smoke { "smoke" } else { "full" },
        cache_topo.label(),
        rome_l3.l3_bw_gbs,
        cache_mixes.len(),
        cache_wall,
        cache_cases_per_s,
        cache_groups_total,
        cache_groups_l3,
        cache_groups_total - cache_groups_l3,
        cache_rows.join(",\n"),
        char_cache_json(),
    );
    let cache_path = out_dir.join("BENCH_cache.json");
    std::fs::write(&cache_path, &cache_json)?;
    println!("wrote {}", cache_path.display());

    // --- cluster co-sim: a 64-node fleet of NPS4 Rome sockets (256
    // domains, 2048 ranks) with inter-domain remote traffic inside every
    // node. The incremental path (interface-composition
    // fingerprints: only nodes whose group composition changed are
    // re-rated) is timed against the full-recompute reference, which
    // re-rates all 64 nodes on every refresh. The two rating modes are
    // pinned bit-identical first, so the events/s ratio is pure engine
    // speedup, not a model change. Emitted as BENCH_cluster.json (CI
    // checks its existence and gates events/s regressions) ---
    let cluster = Topology::parse(&rome, "64n1x4")?;
    let cluster_ranks = cluster.total_cores();
    let cluster_frac = 0.25;
    let cluster_iters = if smoke { 2 } else { 3 };
    let cprog = hpcg_program(HpcgVariant::Modified, 96, cluster_iters);
    let ceng = CoSimEngine::with_topology_remote(
        &rome,
        &cluster,
        Placement::Compact,
        cluster_frac,
        cprog,
        cluster_ranks,
        fig3_cfg(NoiseModel::mild(7)),
        &CharSource::Ecm,
    )?;
    let cwarm = ceng.run(); // warm-up (characterization + composition memos)
    let cfull = ceng.run_full_recompute();
    assert_eq!(cfull.events, cwarm.events, "rating modes must process identical event streams");
    assert!(
        cfull.finish_s.iter().zip(&cwarm.finish_s).all(|(a, b)| a.to_bits() == b.to_bits()),
        "incremental re-rating must be bit-identical to the full-recompute reference"
    );
    let mut cwalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = ceng.run();
        cwalls.push(t0.elapsed().as_secs_f64());
        assert_eq!(r.events, cwarm.events, "cluster co-sim must be deterministic");
    }
    let cluster_wall = membw::stats::median(&cwalls);
    let mut fwalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = ceng.run_full_recompute();
        fwalls.push(t0.elapsed().as_secs_f64());
        assert_eq!(r.events, cwarm.events, "cluster co-sim must be deterministic");
    }
    let full_wall = membw::stats::median(&fwalls);
    let cluster_eps = cwarm.events as f64 / cluster_wall;
    let full_eps = cwarm.events as f64 / full_wall;
    let cluster_speedup = full_wall / cluster_wall;
    println!(
        "cluster co-sim ({}, {} nodes, {} ranks, %r{}): incremental {:.1} ms ({:.2e} events/s), \
         full-recompute {:.1} ms ({:.2e} events/s) — speedup {:.1}x; \
         {} node ratings skipped, {} performed",
        cluster.label(),
        cluster.nodes,
        cluster_ranks,
        cluster_frac,
        cluster_wall * 1e3,
        cluster_eps,
        full_wall * 1e3,
        full_eps,
        cluster_speedup,
        cwarm.stats.node_rates_reused,
        cwarm.stats.rate_evals,
    );
    let cluster_json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"cluster\": {{\n    \"topology\": \"{}\",\n    \"nodes\": {},\n    \"domains\": {},\n    \"ranks\": {},\n    \"remote_frac\": {},\n    \"hpcg_iterations\": {},\n    \"events\": {},\n    \"wall_s\": {:.6},\n    \"events_per_s\": {:.1},\n    \"full_recompute_wall_s\": {:.6},\n    \"full_recompute_events_per_s\": {:.1},\n    \"speedup_vs_full\": {:.3},\n    \"stats\": {},\n    \"full_recompute_stats\": {}\n  }},\n  \"char_cache\": {}\n}}\n",
        if smoke { "smoke" } else { "full" },
        cluster.label(),
        cluster.nodes,
        cluster.n_domains(),
        cluster_ranks,
        cluster_frac,
        cluster_iters,
        cwarm.events,
        cluster_wall,
        cluster_eps,
        full_wall,
        full_eps,
        cluster_speedup,
        stats_json(&cwarm.stats),
        stats_json(&cfull.stats),
        char_cache_json(),
    );
    let cluster_path = out_dir.join("BENCH_cluster.json");
    std::fs::write(&cluster_path, &cluster_json)?;
    println!("wrote {}", cluster_path.display());

    // --- placement optimizer: an 8-group 64-core mix on a dual-socket
    // NPS4 Rome (8 domains + 2 directed xGMI links). The production path
    // (incremental delta re-rating + batched parallel scoring + sharded
    // score memo) is timed against the sequential baseline that re-solves
    // the full remote fixed point for every candidate. Both modes are
    // pinned to the identical winner and bit-identical best score first,
    // so evaluations/s ratios are pure engine speedup. Emitted as
    // BENCH_optimizer.json (CI checks its existence and gates
    // evaluations/s + speedup regressions) ---
    let opt_topo = Topology::parse(&rome, "2x4")?;
    let opt_mix = Mix::parse(
        "dcopy:8+ddot2:8+stream:8+daxpy:8+schoenauer:8+vecsum:8+dscal:8+ddot3:8",
    )?;
    let mut opt_kernels: Vec<KernelId> = opt_mix.groups.iter().map(|g| g.kernel).collect();
    opt_kernels.sort_by_key(|k| k.key());
    opt_kernels.dedup();
    let opt_meas =
        CharCache::global().characterize_source(&opt_topo.base, &opt_kernels, &CharSource::Ecm)?;
    let opt_chars: HashMap<KernelId, (f64, f64)> =
        opt_meas.iter().map(|(&k, c)| (k, (c.f, c.bs_gbs))).collect();
    let opt_space = SearchSpace::from_mix(&opt_topo, &opt_mix, &opt_chars)?;
    let opt_cfg = SearchConfig {
        budget: if smoke { 400 } else { 1500 },
        ..SearchConfig::default()
    };
    let base_cfg = SearchConfig {
        parallel: false,
        use_delta: false,
        memoize: false,
        ..opt_cfg
    };
    let opt_warm = optimize(&opt_space, &opt_cfg)?; // warm-up + reference
    let base_warm = optimize(&opt_space, &base_cfg)?;
    assert_eq!(
        base_warm.best, opt_warm.best,
        "delta/parallel/memo scoring must find the identical winner"
    );
    assert_eq!(
        base_warm.best_score.to_bits(),
        opt_warm.best_score.to_bits(),
        "delta re-rating must be bit-identical to the full re-solve"
    );
    let mut owalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = optimize(&opt_space, &opt_cfg)?;
        owalls.push(t0.elapsed().as_secs_f64());
        assert_eq!(r.best, opt_warm.best, "optimizer search must be deterministic");
    }
    let opt_wall = membw::stats::median(&owalls);
    let mut bwalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = optimize(&opt_space, &base_cfg)?;
        bwalls.push(t0.elapsed().as_secs_f64());
        assert_eq!(r.best, opt_warm.best, "optimizer search must be deterministic");
    }
    let base_wall = membw::stats::median(&bwalls);
    let opt_eps = opt_warm.scored as f64 / opt_wall;
    let base_eps = base_warm.scored as f64 / base_wall;
    let opt_speedup = (base_wall / base_warm.scored as f64) / (opt_wall / opt_warm.scored as f64);
    println!(
        "optimizer ({}, {} groups, budget {}): delta+parallel+memo {:.1} ms ({:.0} evals/s), \
         sequential full {:.1} ms ({:.0} evals/s) — speedup {:.1}x; \
         {} interfaces re-rated, {} reused, {} full solves",
        opt_topo.label(),
        opt_space.k(),
        opt_cfg.budget,
        opt_wall * 1e3,
        opt_eps,
        base_wall * 1e3,
        base_eps,
        opt_speedup,
        opt_warm.delta.iface_evals,
        opt_warm.delta.iface_reused,
        opt_warm.delta.full_solves,
    );
    let opt_json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"optimizer\": {{\n    \"topology\": \"{}\",\n    \"groups\": {},\n    \"objective\": \"{}\",\n    \"starts\": {},\n    \"beam\": {},\n    \"budget\": {},\n    \"evaluations\": {},\n    \"wall_s\": {:.6},\n    \"evaluations_per_s\": {:.1},\n    \"full_evaluations\": {},\n    \"full_wall_s\": {:.6},\n    \"full_evaluations_per_s\": {:.1},\n    \"speedup_vs_full\": {:.3},\n    \"best_label\": \"{}\",\n    \"best_score\": {:.6},\n    \"delta\": {{ \"evals\": {}, \"iface_evals\": {}, \"iface_reused\": {}, \"full_solves\": {} }},\n    \"stats\": {}\n  }},\n  \"char_cache\": {}\n}}\n",
        if smoke { "smoke" } else { "full" },
        opt_topo.label(),
        opt_space.k(),
        opt_cfg.objective.name(),
        opt_cfg.starts,
        opt_cfg.beam,
        opt_cfg.budget,
        opt_warm.scored,
        opt_wall,
        opt_eps,
        base_warm.scored,
        base_wall,
        base_eps,
        opt_speedup,
        opt_warm.best_label,
        opt_warm.best_score,
        opt_warm.delta.evals,
        opt_warm.delta.iface_evals,
        opt_warm.delta.iface_reused,
        opt_warm.delta.full_solves,
        stats_json(&opt_warm.stats),
        char_cache_json(),
    );
    let opt_path = out_dir.join("BENCH_optimizer.json");
    std::fs::write(&opt_path, &opt_json)?;
    println!("wrote {}", opt_path.display());

    // --- serve: amortized streaming admissions vs per-request cold
    // optimize. A 10-request session (9 submits + 1 finish) admits
    // single-group jobs onto the dual-socket Rome; the service searches
    // only the residual per submit and shares the process-wide score memo
    // across requests (and reps). The cold baseline is what a stateless
    // caller would do instead: a full `optimize` over the union of the
    // then-active mixes at every submit event, fresh memo each call.
    // First-admission equivalence is pinned bit-identically before
    // timing, so the speedup is pure amortization, not approximation ---
    let serve_topo = Topology::parse(&rome, "2x4")?;
    let serve_mixes: [&str; 8] = [
        "dcopy:6", "ddot2:6", "stream:6", "daxpy:6", "vecsum:6", "dscal:6", "waxpby:6", "ddot1:6",
    ];
    let mut session: Vec<String> = serve_mixes
        .iter()
        .enumerate()
        .map(|(i, mx)| format!(r#"{{"op":"submit","id":"j{i}","mix":"{mx}"}}"#))
        .collect();
    session.push(r#"{"op":"finish","id":"j0"}"#.to_string());
    session.push(r#"{"op":"submit","id":"j8","mix":"dcopy:6"}"#.to_string());
    let serve_cfg =
        ServeConfig { budget: if smoke { 400 } else { 1500 }, ..ServeConfig::default() };
    let run_session = |cfg: &ServeConfig| -> Result<Service<'static>> {
        let mut s = Service::new(serve_topo.clone(), cfg.clone(), CharSource::Ecm);
        for line in &session {
            let resp = s.handle_line(line);
            assert!(resp.contains("\"ok\":true"), "serve request failed: {resp}");
        }
        Ok(s)
    };
    let serve_scfg = SearchConfig { budget: serve_cfg.budget, ..SearchConfig::default() };
    let cold_solve = |spec: &str| -> Result<membw::optimizer::OptResult> {
        let mx = Mix::parse(spec)?;
        let meas = CharCache::global().characterize_source(
            &serve_topo.base,
            &mx.kernels(),
            &CharSource::Ecm,
        )?;
        let chars: HashMap<KernelId, (f64, f64)> =
            meas.iter().map(|(&k, c)| (k, (c.f, c.bs_gbs))).collect();
        optimize(&SearchSpace::from_mix(&serve_topo, &mx, &chars)?, &serve_scfg)
    };
    {
        let cold0 = cold_solve(serve_mixes[0])?;
        let mut probe = Service::new(serve_topo.clone(), serve_cfg.clone(), CharSource::Ecm);
        let resp = probe.handle_line(&session[0]);
        assert!(resp.contains("\"ok\":true"), "{resp}");
        let first = probe.last_result().expect("submit succeeded");
        assert_eq!(first.best, cold0.best, "serve admission must match cold optimize");
        assert_eq!(
            first.best_score.to_bits(),
            cold0.best_score.to_bits(),
            "serve admission must be bit-identical to cold optimize"
        );
    }
    let warm_svc = run_session(&serve_cfg)?; // warms the process-wide memo
    let mut swalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let s = run_session(&serve_cfg)?;
        swalls.push(t0.elapsed().as_secs_f64());
        assert_eq!(
            s.placements(),
            warm_svc.placements(),
            "serve session replay must be deterministic"
        );
    }
    let serve_wall = membw::stats::median(&swalls);
    // The union of active mixes at each of the 9 submit events.
    let submit_unions: Vec<String> = {
        let mut unions = Vec::new();
        let mut active: Vec<&str> = Vec::new();
        for mx in &serve_mixes {
            active.push(mx);
            unions.push(active.join("+"));
        }
        active.remove(0); // finish j0
        active.push("dcopy:6"); // submit j8
        unions.push(active.join("+"));
        unions
    };
    let cold_once = || -> Result<()> {
        for u in &submit_unions {
            cold_solve(u)?;
        }
        Ok(())
    };
    cold_once()?; // warm-up (characterization cache, allocator)
    let mut coldwalls = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        cold_once()?;
        coldwalls.push(t0.elapsed().as_secs_f64());
    }
    let serve_cold_wall = membw::stats::median(&coldwalls);
    let serve_rps = session.len() as f64 / serve_wall;
    let cold_rps = submit_unions.len() as f64 / serve_cold_wall;
    let serve_speedup = serve_rps / cold_rps;
    let (sm_hits, sm_misses, sm_entries) = service_memo().stats();
    println!(
        "serve ({}, {} requests, budget {}): warm {:.1} ms ({:.0} requests/s), \
         cold-per-request {:.1} ms ({:.0} requests/s) — amortized speedup {:.1}x; \
         memo {} hits / {} misses",
        serve_topo.label(),
        session.len(),
        serve_cfg.budget,
        serve_wall * 1e3,
        serve_rps,
        serve_cold_wall * 1e3,
        cold_rps,
        serve_speedup,
        sm_hits,
        sm_misses,
    );
    let serve_json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"serve\": {{\n    \"topology\": \"{}\",\n    \"requests\": {},\n    \"submits\": {},\n    \"budget\": {},\n    \"repack_every\": {},\n    \"wall_s\": {:.6},\n    \"requests_per_s\": {:.1},\n    \"cold_wall_s\": {:.6},\n    \"cold_requests_per_s\": {:.1},\n    \"speedup_vs_cold\": {:.3},\n    \"final_score\": {:.6},\n    \"memo\": {{ \"hits\": {}, \"misses\": {}, \"entries\": {} }}\n  }},\n  \"char_cache\": {}\n}}\n",
        if smoke { "smoke" } else { "full" },
        serve_topo.label(),
        session.len(),
        submit_unions.len(),
        serve_cfg.budget,
        serve_cfg.repack_every,
        serve_wall,
        serve_rps,
        serve_cold_wall,
        cold_rps,
        serve_speedup,
        warm_svc.last_result().map(|r| r.best_score).unwrap_or(f64::NAN),
        sm_hits,
        sm_misses,
        sm_entries,
        char_cache_json(),
    );
    let serve_path = out_dir.join("BENCH_serve.json");
    std::fs::write(&serve_path, &serve_json)?;
    println!("wrote {}", serve_path.display());

    let json_opt = |x: Option<f64>| x.map(|v| format!("{v:.6}")).unwrap_or_else(|| "null".into());
    let cosim_json: Vec<String> = cosim_rows
        .iter()
        .map(|row| {
            format!(
                "    {{\n      \"variant\": \"fig3_clx_20ranks_nx96_it3_{}\",\n      \"wall_s\": {:.6},\n      \"events\": {},\n      \"events_per_s\": {:.1},\n      \"phase_records\": {},\n      \"legacy_wall_s\": {},\n      \"speedup_vs_legacy\": {},\n      \"stats\": {}\n    }}",
                row.tag,
                row.wall_s,
                row.events,
                row.events as f64 / row.wall_s,
                row.records,
                json_opt(row.legacy_wall_s),
                json_opt(row.speedup),
                stats_json(&row.stats),
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"cosim\": [\n{}\n  ],\n  \"scenario\": {{\n    \"engine\": \"fluid\",\n    \"cases\": {},\n    \"wall_s\": {:.6},\n    \"cases_per_s\": {:.1}\n  }},\n  \"char_cache\": {}\n}}\n",
        if smoke { "smoke" } else { "full" },
        cosim_json.join(",\n"),
        mixes.len(),
        scen_wall,
        cases_per_s,
        char_cache_json(),
    );
    let path = out_dir.join("BENCH_cosim.json");
    std::fs::write(&path, &json)?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_dump_configs(rest: &[String]) -> Result<()> {
    let dir = PathBuf::from(rest.first().map(String::as_str).unwrap_or("configs/machines"));
    std::fs::create_dir_all(&dir)?;
    for m in builtin_machines() {
        let path = dir.join(format!("{}.toml", m.id.key()));
        std::fs::write(&path, machine_to_toml(&m))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// Cross-validate the PJRT artifact against the in-process engines.
fn cmd_selftest(f: &HashMap<String, String>) -> Result<()> {
    let runtime = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", runtime.platform());
    let exec = PjrtSimExecutor::load(&runtime, &ArtifactPaths::default_dir())?;
    println!("artifact geometry: {:?}", exec.meta());

    let tolerance: f64 = f.get("tol").and_then(|s| s.parse().ok()).unwrap_or(0.03);
    let mut worst: f64 = 0.0;
    for mid in MachineId::ALL {
        let m = machine(mid);
        let cases = vec![
            PairingCase {
                k1: KernelId::Dcopy,
                k2: KernelId::Ddot2,
                n1: m.cores / 2,
                n2: m.cores - m.cores / 2,
            },
            PairingCase { k1: KernelId::Stream, k2: KernelId::JacobiV1L2, n1: 1, n2: 1 },
        ];
        let via_pjrt = run_cases(&m, &cases, &MeasureEngine::Pjrt(&exec))?;
        let via_fluid = run_cases(&m, &cases, &MeasureEngine::Fluid)?;
        for (a, b) in via_pjrt.cases.iter().zip(&via_fluid.cases) {
            for g in 0..2 {
                let rel = (a.measured_per_core[g] - b.measured_per_core[g]).abs()
                    / b.measured_per_core[g].max(1e-9);
                worst = worst.max(rel);
            }
        }
        // Solo sanity: one DDOT2 core through the artifact.
        let w = CoreWorkload::from_kernel(&kernel(KernelId::Ddot2), &m, 0);
        let solo = exec.run(&[SimCase { machine: m.clone(), workloads: vec![w] }])?;
        let ecm_b1 = membw::ecm::predict(&kernel(KernelId::Ddot2), &m).b1_gbs;
        let rel = (solo[0][0] - ecm_b1).abs() / ecm_b1;
        println!(
            "[{}] solo DDOT2 via pjrt: {:.2} GB/s (ECM {:.2}, {:.1}%)",
            mid.key(),
            solo[0][0],
            ecm_b1,
            rel * 100.0
        );
        worst = worst.max(rel);
    }
    println!("worst pjrt-vs-rust deviation: {:.2}%", worst * 100.0);
    if worst > tolerance {
        return Err(membw::Error::Runtime(format!(
            "selftest deviation {:.2}% exceeds tolerance {:.2}%",
            worst * 100.0,
            tolerance * 100.0
        )));
    }
    println!("selftest OK");
    Ok(())
}
