//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Pattern from /opt/xla-example/load_hlo.rs: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.

use std::path::Path;

use crate::error::{Error, Result};

/// A PJRT client plus compiled executables (one per artifact).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled HLO module ready for execution.
pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Path the module was loaded from (diagnostics).
    pub source: String,
}

impl PjrtRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(Error::runtime)?;
        Ok(PjrtRuntime { client })
    }

    /// Human-readable platform string.
    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Load an HLO text file and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<PjrtExecutable> {
        if !path.exists() {
            return Err(Error::MissingArtifact(path.display().to_string()));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )
        .map_err(Error::runtime)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(Error::runtime)?;
        Ok(PjrtExecutable { exe, source: path.display().to_string() })
    }
}

impl PjrtExecutable {
    /// Execute with f32 input planes; returns the flat f32 outputs of the
    /// (1-tuple or k-tuple) result, in order.
    ///
    /// Each input is `(data, dims)`; data length must equal the dim product.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
                xla::Literal::vec1(data).reshape(dims).map_err(Error::runtime)
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(Error::runtime)?;
        let out = result[0][0].to_literal_sync().map_err(Error::runtime)?;
        // Lowered with return_tuple=True: the output is always a tuple.
        let parts = out.to_tuple().map_err(Error::runtime)?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Error::runtime))
            .collect()
    }
}
