//! The priority-queue event core.
//!
//! The queue holds the *externally scheduled* events: staggered starts,
//! noise arrivals, idle expiries, and collective releases. Phase
//! completions are not stored here — under a fixed composition the next
//! completion time is a closed-form number, so the engine keeps it as a
//! single analytic time and compares it against the queue head
//! ([`crate::timeline::engine`]); at equal times queue events win, which
//! gives completions the lowest tie-break priority.
//!
//! Events that can become stale (noise arrivals for ranks that were
//! preempted meanwhile) are validated lazily at pop time, keeping
//! cancellation O(1).
//!
//! # Data layout
//!
//! Internally the heap stores no [`Event`] structs at all: every event is
//! packed into a single `u128` key whose ascending numeric order *is* the
//! event order —
//!
//! ```text
//! bits 127..64   t.to_bits()   (f64; monotone under to_bits for t ≥ 0)
//! bits  63..62   kind priority (Start=0 < Noise < IdleEnd < CollectiveRelease)
//! bits  61..32   idx           (rank / flat phase index)
//! bits  31..0    seq           (insertion order: FIFO among exact duplicates)
//! ```
//!
//! so the heap is a flat `Vec<u128>` under the hood (one word-pair per
//! event, single integer compares while sifting) instead of a vector of
//! padded structs with four-field lexicographic comparisons. For
//! cluster-scale runs (hundreds of thousands of scheduled events) this
//! halves the queue's memory traffic and removes all branching from the
//! comparator. Event times are non-negative and finite by construction
//! (simulation time starts at 0 and only advances), which is exactly the
//! range where `f64::to_bits` is order-preserving.

use std::cmp::Ordering;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// What an event does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A rank's (possibly staggered) program start.
    Start,
    /// A noise arrival. Valid only while the rank runs a kernel and the
    /// arrival time still matches the rank's stream (a deferred arrival is
    /// consumed by `enter_running` instead and the popped event dropped).
    Noise,
    /// End of an idle interval — an explicit `Phase::Idle` or a noise idle.
    IdleEnd,
    /// Release of a collective: every rank has arrived and the collective
    /// cost has elapsed. `idx` carries the flat phase index.
    CollectiveRelease,
}

impl EventKind {
    /// Same-time tie-break priority. Noise preempts everything that drains
    /// bytes at the same instant, mirroring the legacy stepper where
    /// `poll` runs before the per-step drain.
    fn priority(self) -> u8 {
        match self {
            EventKind::Start => 0,
            EventKind::Noise => 1,
            EventKind::IdleEnd => 2,
            EventKind::CollectiveRelease => 3,
        }
    }

    fn from_priority(p: u8) -> Self {
        match p {
            0 => EventKind::Start,
            1 => EventKind::Noise,
            2 => EventKind::IdleEnd,
            _ => EventKind::CollectiveRelease,
        }
    }
}

/// One scheduled event (the unpacked view handed back by
/// [`EventQueue::pop`]; the queue itself stores packed keys).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Absolute simulation time, seconds.
    pub t: f64,
    /// Event kind.
    pub kind: EventKind,
    /// Rank index (`Start`/`Noise`/`IdleEnd`), flat phase index
    /// (`CollectiveRelease`).
    pub idx: usize,
    /// Insertion order (total-order tie break, FIFO within ties).
    seq: u64,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed on every field: earliest event (then lowest
        // priority/idx/seq) first — the order the packed keys realize.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.kind.priority().cmp(&self.kind.priority()))
            .then_with(|| other.idx.cmp(&self.idx))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Widest `idx` the packed key can carry (30 bits).
const MAX_IDX: usize = (1 << 30) - 1;

fn pack(t: f64, kind: EventKind, idx: usize, seq: u64) -> u128 {
    debug_assert!(t.is_finite() && t >= 0.0, "event time {t} outside [0, ∞)");
    debug_assert!(idx <= MAX_IDX, "event idx {idx} exceeds the 30-bit key field");
    debug_assert!(seq <= u32::MAX as u64, "event seq overflow (2^32 events scheduled)");
    ((t.to_bits() as u128) << 64)
        | ((kind.priority() as u128) << 62)
        | ((idx as u128) << 32)
        | (seq as u128 & 0xFFFF_FFFF)
}

fn unpack(key: u128) -> Event {
    Event {
        t: f64::from_bits((key >> 64) as u64),
        kind: EventKind::from_priority(((key >> 62) & 0b11) as u8),
        idx: ((key >> 32) & (MAX_IDX as u128)) as usize,
        seq: key as u32 as u64,
    }
}

/// Deterministic min-queue of [`Event`]s over packed `u128` keys.
///
/// `Clone` is derived so a paused simulation can checkpoint the queue
/// (`engine::EngineCheckpoint`): cloning a [`BinaryHeap`] preserves its
/// internal layout, so a resumed run pops the exact same sequence as an
/// uninterrupted one.
#[derive(Default, Clone)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<u128>>,
    seq: u64,
}

impl EventQueue {
    /// An empty queue.
    pub fn new() -> Self {
        EventQueue::default()
    }

    /// Schedule an event.
    pub fn push(&mut self, t: f64, kind: EventKind, idx: usize) {
        self.heap.push(Reverse(pack(t, kind, idx, self.seq)));
        self.seq += 1;
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|k| f64::from_bits((k.0 >> 64) as u64))
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|k| unpack(k.0))
    }

    /// Pending event count (including stale entries awaiting lazy skip).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total number of events ever scheduled.
    pub fn scheduled(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::CollectiveRelease, 0);
        q.push(1.0, EventKind::IdleEnd, 2);
        q.push(2.0, EventKind::Start, 1);
        assert_eq!(q.peek_time(), Some(1.0));
        let ts: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.t).collect();
        assert_eq!(ts, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn same_time_orders_by_kind_priority_then_idx() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::CollectiveRelease, 0);
        q.push(1.0, EventKind::Noise, 5);
        q.push(1.0, EventKind::Noise, 2);
        q.push(1.0, EventKind::Start, 9);
        let order: Vec<(EventKind, usize)> =
            std::iter::from_fn(|| q.pop()).map(|e| (e.kind, e.idx)).collect();
        assert_eq!(
            order,
            vec![
                (EventKind::Start, 9),
                (EventKind::Noise, 2),
                (EventKind::Noise, 5),
                (EventKind::CollectiveRelease, 0),
            ]
        );
    }

    #[test]
    fn full_ties_are_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::IdleEnd, 1);
        q.push(1.0, EventKind::IdleEnd, 1);
        q.push(1.0, EventKind::IdleEnd, 1);
        assert_eq!(q.len(), 3);
        assert_eq!(q.scheduled(), 3);
        let mut last = None;
        while let Some(e) = q.pop() {
            assert_eq!(e.t, 1.0);
            last = Some(e);
        }
        assert!(last.is_some());
        assert!(q.is_empty());
    }

    #[test]
    fn packed_key_round_trips_and_preserves_struct_order() {
        // The packed ascending-u128 order must agree with the Event
        // comparator on every field, including times whose exponent bits
        // differ by orders of magnitude.
        let cases = [
            (0.0, EventKind::Start, 0),
            (1e-12, EventKind::Noise, 3),
            (1e-12, EventKind::IdleEnd, 3),
            (1e-12, EventKind::IdleEnd, 4),
            (7.25, EventKind::CollectiveRelease, MAX_IDX),
            (1e9, EventKind::Start, 17),
        ];
        let mut q = EventQueue::new();
        for &(t, k, i) in cases.iter().rev() {
            q.push(t, k, i);
        }
        let popped: Vec<(f64, EventKind, usize)> =
            std::iter::from_fn(|| q.pop()).map(|e| (e.t, e.kind, e.idx)).collect();
        let want: Vec<(f64, EventKind, usize)> = cases.to_vec();
        assert_eq!(popped, want);
        // Round trip of the widest representable index.
        let e = unpack(pack(7.25, EventKind::CollectiveRelease, MAX_IDX, 9));
        assert_eq!(e.t, 7.25);
        assert_eq!(e.kind, EventKind::CollectiveRelease);
        assert_eq!(e.idx, MAX_IDX);
        assert_eq!(e.seq, 9);
    }
}
