//! Golden-equivalence suite: the event-driven timeline engine pinned
//! against the legacy fixed-`dt` stepper on the Fig. 3 configuration.
//!
//! The event engine is the exact `dt → 0` limit of the stepper, so on a
//! noise-free run every phase record must agree with the stepper to grid
//! precision (deviations are pure `dt` quantization and shrink linearly
//! with `dt` — see the scaling test). With noise enabled, the stepper's
//! grid shifts noise arrival times by up to one `dt` *per event*, so exact
//! duration agreement is not defined; there the suite pins structure (same
//! phase records per rank) and the Fig. 3 physics (DDOT skewness signs).

use crate::config::{machine, MachineId};
use crate::desync::program::{hpcg_program, HpcgVariant};
use crate::desync::{CoSimConfig, CoSimEngine, CoSimResult, NoiseModel};
use crate::stats::skewness_dimensioned;

const FIG3_RANKS: usize = 20;
const FIG3_DT: f64 = 20e-6;

/// The Fig. 3 configuration (CLX, modified HPCG, nx=96, 3 iterations).
fn fig3_config(noise: NoiseModel) -> CoSimConfig {
    CoSimConfig {
        dt_s: FIG3_DT,
        t_max_s: 600.0,
        initial_stagger_s: 0.2e-3,
        neighbor_radius: 3,
        noise,
    }
}

fn fig3_engine(noise: NoiseModel) -> CoSimEngine<'static> {
    let m: &'static _ = Box::leak(Box::new(machine(MachineId::Clx)));
    let prog = hpcg_program(HpcgVariant::Modified, 96, 3);
    CoSimEngine::new(m, prog, FIG3_RANKS, fig3_config(noise)).unwrap()
}

/// Per-rank label sequences, in record order.
fn label_seqs(r: &CoSimResult, n: usize) -> Vec<Vec<&'static str>> {
    let mut out = vec![Vec::new(); n];
    for rec in &r.trace.records {
        out[rec.rank].push(rec.label);
    }
    out
}

/// Per-rank duration sequences, in record order.
fn duration_seqs(r: &CoSimResult, n: usize) -> Vec<Vec<f64>> {
    let mut out = vec![Vec::new(); n];
    for rec in &r.trace.records {
        out[rec.rank].push(rec.duration());
    }
    out
}

#[test]
fn event_matches_stepper_noise_free() {
    let eng = fig3_engine(NoiseModel::off());
    let legacy = eng.run_legacy();
    let event = eng.run();

    // Identical per-rank phase sequences.
    let (ls, es) = (label_seqs(&legacy, FIG3_RANKS), label_seqs(&event, FIG3_RANKS));
    assert_eq!(ls, es, "per-rank phase orderings must match");

    // Durations agree to grid precision: the stepper quantizes each phase
    // boundary up to one dt, so individual records deviate by at most ~one
    // dt (plus second-order composition-overlap shifts).
    let (ld, ed) = (duration_seqs(&legacy, FIG3_RANKS), duration_seqs(&event, FIG3_RANKS));
    let mut devs: Vec<f64> = Vec::new();
    for (a, b) in ld.iter().zip(&ed) {
        for (x, y) in a.iter().zip(b) {
            devs.push((x - y).abs());
        }
    }
    devs.sort_by(f64::total_cmp);
    let max = *devs.last().unwrap();
    let median = devs[devs.len() / 2];
    let within_dt = devs.iter().filter(|d| **d <= FIG3_DT).count() as f64 / devs.len() as f64;
    assert!(max <= 2.0 * FIG3_DT, "max duration deviation {max:.2e} > 2 dt");
    assert!(median <= FIG3_DT, "median duration deviation {median:.2e} > one dt");
    assert!(within_dt >= 0.8, "only {:.0}% of durations within one legacy dt", within_dt * 100.0);

    // Completion times agree to the accumulated grid error (one dt per
    // phase transition).
    let budget = (legacy.trace.records.len() / FIG3_RANKS + 2) as f64 * FIG3_DT;
    for (a, b) in legacy.finish_s.iter().zip(&event.finish_s) {
        assert!((a - b).abs() <= budget, "finish {a} vs {b} (budget {budget})");
    }
}

#[test]
fn stepper_deviation_shrinks_linearly_with_dt() {
    // The event engine is the dt→0 limit: halving the stepper's dt must
    // (roughly) halve the worst duration deviation from the event trace.
    let eng = fig3_engine(NoiseModel::off());
    let event = eng.run();
    let ed = duration_seqs(&event, FIG3_RANKS);

    let max_dev_at = |dt: f64| -> f64 {
        let m = machine(MachineId::Clx);
        let prog = hpcg_program(HpcgVariant::Modified, 96, 3);
        let mut cfg = fig3_config(NoiseModel::off());
        cfg.dt_s = dt;
        let leg = CoSimEngine::new(&m, prog, FIG3_RANKS, cfg).unwrap().run_legacy();
        let ld = duration_seqs(&leg, FIG3_RANKS);
        let mut max = 0.0f64;
        for (a, b) in ld.iter().zip(&ed) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                max = max.max((x - y).abs());
            }
        }
        max
    };
    let coarse = max_dev_at(40e-6);
    let fine = max_dev_at(10e-6);
    assert!(
        fine < coarse / 1.8,
        "deviation must shrink ~linearly with dt: {fine:.2e} vs {coarse:.2e}"
    );
}

#[test]
fn event_matches_stepper_on_fig3_with_noise() {
    let eng = fig3_engine(NoiseModel::mild(7));
    let legacy = eng.run_legacy();
    let event = eng.run();

    // Structure: same phase records per rank, in the same order.
    let (ls, es) = (label_seqs(&legacy, FIG3_RANKS), label_seqs(&event, FIG3_RANKS));
    assert_eq!(ls, es, "per-rank phase orderings must match under noise");
    assert_eq!(legacy.trace.records.len(), event.trace.records.len());

    // Physics: the Fig. 3 skewness signs agree (DDOT2#1 resynchronizes,
    // DDOT2#2 / DDOT1 desynchronize) and have comparable magnitude.
    for (label, resync) in [("DDOT2#1", true), ("DDOT2#2", false), ("DDOT1", false)] {
        let sl = skewness_dimensioned(&legacy.trace.durations_by_rank(label, 1, FIG3_RANKS));
        let se = skewness_dimensioned(&event.trace.durations_by_rank(label, 1, FIG3_RANKS));
        assert!(
            sl.signum() == se.signum(),
            "{label}: legacy skew {sl:+.3e} vs event {se:+.3e}"
        );
        if resync {
            assert!(se < 0.0, "{label} must resynchronize (skew {se:+.3e})");
        } else {
            assert!(se > 0.0, "{label} must desynchronize (skew {se:+.3e})");
        }
    }
}

/// Measure legacy-vs-event wall time on one engine configuration. Legacy is
/// timed once (it is the long pole and CI interference only inflates it);
/// the event engine takes the min of `reps` runs.
fn measure_speedup(eng: &CoSimEngine, reps: usize) -> (f64, f64, f64) {
    use std::time::Instant;
    let ev = eng.run(); // warm-up (characterization cache, allocator)
    let t0 = Instant::now();
    let leg = eng.run_legacy();
    let legacy_wall = t0.elapsed().as_secs_f64();
    let mut event_wall = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = eng.run();
        event_wall = event_wall.min(t0.elapsed().as_secs_f64());
        assert_eq!(r.trace.records.len(), leg.trace.records.len());
        assert_eq!(r.events, ev.events, "event engine must be deterministic");
    }
    (legacy_wall, event_wall, legacy_wall / event_wall)
}

/// The headline speedup pin, on the configuration where the stepper and the
/// event engine are *exactly* equivalent (noise off: every duration within
/// grid precision — see `event_matches_stepper_noise_free`). The stepper
/// grinds through ~30k time steps of 20 µs; the event engine resolves the
/// same run in ~180 events.
#[test]
fn event_engine_is_50x_faster_on_fig3() {
    let eng = fig3_engine(NoiseModel::off());
    let (legacy_wall, event_wall, speedup) = measure_speedup(&eng, 5);
    assert!(
        speedup >= 50.0,
        "event engine speedup {speedup:.1}x < 50x (legacy {legacy_wall:.4}s, event {event_wall:.6}s)"
    );
}

/// With mild(7) noise (the Fig. 3 figure run), noise arrivals dominate the
/// event count (~3.5k events vs ~30k steps), so the advantage is smaller
/// but must still be a solid order of magnitude. The measured value lands
/// far above this floor and is recorded in BENCH_cosim.json by
/// `repro bench`.
#[test]
fn event_engine_beats_stepper_under_noise() {
    let eng = fig3_engine(NoiseModel::mild(7));
    let (legacy_wall, event_wall, speedup) = measure_speedup(&eng, 3);
    assert!(
        speedup >= 8.0,
        "noisy-config speedup {speedup:.1}x < 8x (legacy {legacy_wall:.4}s, event {event_wall:.6}s)"
    );
}
