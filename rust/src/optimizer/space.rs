//! The placement search space: groups, candidates, and neighborhood moves.
//!
//! A **candidate** assigns every kernel group a home ccNUMA domain and a
//! remote-access fraction (stored in parts per million, like the mix DSL's
//! `%r` suffix). The space knows which groups are pinned (`@dN` in the
//! mix) or carry a fixed `%r`, the per-domain core capacities, and the
//! palette of remote-fraction levels a retune move may pick from.
//!
//! Moves are the classic placement neighborhood: migrate one group,
//! swap two groups' homes, retune one group's remote fraction. Move
//! enumeration order is deterministic (migrations, then swaps, then
//! retunes, each in index order), which — together with the fixed-seed
//! xorshift starts — makes the whole search reproducible.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::kernels::KernelId;
use crate::scenario::Mix;
use crate::sharing::{GroupKind, RemoteGroup, TopoShape};
use crate::simulator::XorShift64;
use crate::topology::{GroupPlacement, Topology};

/// One kernel group to place: its traffic character plus any constraints
/// the mix imposed.
#[derive(Debug, Clone)]
pub struct OptGroup {
    /// Display name (kernel name; used in candidate labels and reports).
    pub name: String,
    /// Kernel identity (used by the makespan finalist co-simulation).
    pub kernel: KernelId,
    /// Cores in the group.
    pub n: usize,
    /// Memory request fraction of the kernel (Eq. 2).
    pub f: f64,
    /// Nominal saturated bandwidth of the kernel, GB/s.
    pub bs_gbs: f64,
    /// Fixed home domain (`@dN` pin); `None` = the search may place it.
    pub pinned: Option<usize>,
    /// Fixed remote fraction in ppm (`%r` suffix); `None` = the search
    /// may retune it over [`SearchSpace::remote_levels`].
    pub fixed_remote_ppm: Option<u32>,
    /// Contention class of the group. [`SearchSpace::from_mix`] always
    /// builds `Mem` groups (its `(f, b_s)` characterization is the DRAM
    /// roofline); callers constructing spaces directly may place
    /// L3-resident or compute-bound groups, which the delta evaluator
    /// re-rates on the matching interfaces.
    pub kind: GroupKind,
}

/// One point of the search space: per-group home domain + remote ppm.
///
/// Derives `Ord`/`Hash` so candidates can key the sharded score memo and
/// break score ties deterministically.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Candidate {
    /// Home domain per group.
    pub home: Vec<u16>,
    /// Remote fraction per group, parts per million.
    pub remote_ppm: Vec<u32>,
}

/// One neighborhood move on a candidate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Move {
    /// Migrate group `.0` to domain `.1`.
    Migrate(usize, u16),
    /// Swap the home domains of groups `.0` and `.1`.
    Swap(usize, usize),
    /// Set group `.0`'s remote fraction to `.1` ppm.
    Retune(usize, u32),
}

impl Candidate {
    /// The candidate with `mv` applied.
    pub fn apply(&self, mv: Move) -> Candidate {
        let mut c = self.clone();
        match mv {
            Move::Migrate(g, d) => c.home[g] = d,
            Move::Swap(a, b) => c.home.swap(a, b),
            Move::Retune(g, ppm) => c.remote_ppm[g] = ppm,
        }
        c
    }
}

/// The search space: topology shape + groups + move palette.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Topology shape the model evaluates on.
    pub shape: TopoShape,
    /// Core capacity of each domain.
    pub domain_cores: Vec<usize>,
    /// Cluster node of each domain (used by the makespan finalist
    /// co-simulation; all zero on single-node topologies).
    pub node_of: Vec<usize>,
    /// Extra collective release latency, seconds (makespan finalists).
    pub collective_extra_s: f64,
    /// The groups to place.
    pub groups: Vec<OptGroup>,
    /// Remote-fraction palette (ppm) retune moves pick from. Empty on
    /// single-domain shapes (remote traffic needs >= 2 domains).
    pub remote_levels: Vec<u32>,
}

/// Default retune palette: 0, 10%, 25%, 50% remote (ppm).
pub const DEFAULT_REMOTE_LEVELS: [u32; 4] = [0, 100_000, 250_000, 500_000];

impl SearchSpace {
    /// Build a space from explicit parts, validating capacities and pins.
    pub fn new(
        shape: TopoShape,
        domain_cores: Vec<usize>,
        groups: Vec<OptGroup>,
        remote_levels: Vec<u32>,
    ) -> Result<SearchSpace> {
        let nd = shape.n_domains();
        if domain_cores.len() != nd {
            return Err(Error::InvalidPlan(format!(
                "{} domain capacities for a {nd}-domain shape",
                domain_cores.len()
            )));
        }
        let total: usize = domain_cores.iter().sum();
        let used: usize = groups.iter().map(|g| g.n).sum();
        if used > total {
            return Err(Error::InvalidPlan(format!(
                "groups need {used} cores but the topology has {total}"
            )));
        }
        for (gi, g) in groups.iter().enumerate() {
            if g.n == 0 {
                return Err(Error::InvalidPlan(format!("group {gi} ({}) has no cores", g.name)));
            }
            if let Some(d) = g.pinned {
                if d >= nd {
                    return Err(Error::InvalidPlan(format!(
                        "group {gi} ({}) pinned to missing domain d{d}",
                        g.name
                    )));
                }
            }
            if let Some(ppm) = g.fixed_remote_ppm {
                if ppm > 1_000_000 || (ppm > 0 && nd < 2) {
                    return Err(Error::InvalidPlan(format!(
                        "group {gi} ({}) has an invalid fixed remote fraction {ppm} ppm",
                        g.name
                    )));
                }
            }
        }
        let remote_levels = if nd < 2 {
            Vec::new()
        } else {
            let mut lv: Vec<u32> = remote_levels.into_iter().filter(|&p| p <= 1_000_000).collect();
            lv.sort_unstable();
            lv.dedup();
            lv
        };
        let node_of = vec![0; nd];
        Ok(SearchSpace {
            shape,
            domain_cores,
            node_of,
            collective_extra_s: 0.0,
            groups,
            remote_levels,
        })
    }

    /// Build a space from a parsed mix on a topology: one [`OptGroup`] per
    /// mix group, characterized by `chars` (`(f, b_s)` per kernel). `@dN`
    /// pins become hard constraints; an explicit `%r` freezes that group's
    /// remote fraction and everything else searches over the default
    /// palette. Idle cores simply reduce the usable capacity headroom.
    pub fn from_mix(
        topo: &Topology,
        mix: &Mix,
        chars: &HashMap<KernelId, (f64, f64)>,
    ) -> Result<SearchSpace> {
        let mut groups = Vec::with_capacity(mix.groups.len());
        for g in &mix.groups {
            if !matches!(g.bound, crate::scenario::BoundHint::Auto | crate::scenario::BoundHint::Mem)
            {
                return Err(Error::InvalidPlan(format!(
                    "group '{}:{}{}': the placement optimizer characterizes groups on the \
                     DRAM roofline; drop the `{}` suffix or run the mix as a scenario",
                    g.kernel.key(),
                    g.cores,
                    g.bound.suffix(),
                    g.bound.suffix(),
                )));
            }
            let &(f, bs_gbs) = chars.get(&g.kernel).ok_or_else(|| {
                Error::InvalidPlan(format!("kernel {:?} not characterized", g.kernel))
            })?;
            let pinned = match g.place {
                GroupPlacement::Domain(d) => Some(d),
                _ => None,
            };
            let fixed = if g.remote_ppm > 0 { Some(g.remote_ppm) } else { None };
            groups.push(OptGroup {
                name: g.kernel.key().to_string(),
                kernel: g.kernel,
                n: g.cores,
                f,
                bs_gbs,
                pinned,
                fixed_remote_ppm: fixed,
                kind: GroupKind::Mem,
            });
        }
        let domain_cores: Vec<usize> = topo.domains.iter().map(|d| d.machine.cores).collect();
        let mut space = SearchSpace::new(
            topo.shape(),
            domain_cores,
            groups,
            DEFAULT_REMOTE_LEVELS.to_vec(),
        )?;
        space.node_of = topo.node_of();
        space.collective_extra_s = topo.collective_extra_s();
        Ok(space)
    }

    /// Number of groups.
    pub fn k(&self) -> usize {
        self.groups.len()
    }

    /// FNV-1a fingerprint of everything a candidate's score depends on:
    /// the topology shape, capacities, groups (traffic character, pins,
    /// fixed fractions, kinds), and the retune palette. Two spaces with
    /// the same fingerprint score any candidate identically, so the
    /// fingerprint is the memo namespace of a process-wide
    /// [`crate::optimizer::ShardedScoreMemo`] shared across searches
    /// (the `repro serve` service).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        let mut eat_u64 = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        };
        for &s in &self.shape.socket_of {
            eat_u64(s as u64);
        }
        for &s in &self.shape.bw_scale {
            eat_u64(s.to_bits());
        }
        eat_u64(self.shape.link_bw_gbs.to_bits());
        eat_u64(self.shape.link_bw_rev_gbs.to_bits());
        eat_u64(self.shape.l3_bw_gbs.to_bits());
        for &c in &self.domain_cores {
            eat_u64(c as u64);
        }
        for &n in &self.node_of {
            eat_u64(n as u64);
        }
        eat_u64(self.collective_extra_s.to_bits());
        for g in &self.groups {
            for b in g.kernel.key().bytes() {
                eat_u64(b as u64);
            }
            eat_u64(g.n as u64);
            eat_u64(g.f.to_bits());
            eat_u64(g.bs_gbs.to_bits());
            eat_u64(match g.pinned {
                Some(d) => d as u64 + 1,
                None => 0,
            });
            eat_u64(match g.fixed_remote_ppm {
                Some(p) => u64::from(p) + 1,
                None => 0,
            });
            match g.kind {
                GroupKind::Mem => eat_u64(1),
                GroupKind::L3 { f_l3, bs_l3_gbs } => {
                    eat_u64(2);
                    eat_u64(f_l3.to_bits());
                    eat_u64(bs_l3_gbs.to_bits());
                }
                GroupKind::Compute => eat_u64(3),
            }
        }
        for &lv in &self.remote_levels {
            eat_u64(u64::from(lv));
        }
        h ^ (h >> 32)
    }

    /// Per-domain core load of a candidate.
    pub fn loads(&self, c: &Candidate) -> Vec<usize> {
        let mut load = vec![0usize; self.shape.n_domains()];
        for (g, &d) in self.groups.iter().zip(&c.home) {
            load[d as usize] += g.n;
        }
        load
    }

    /// Whether a candidate respects pins, capacities, and fixed fractions.
    pub fn feasible(&self, c: &Candidate) -> bool {
        if c.home.len() != self.k() || c.remote_ppm.len() != self.k() {
            return false;
        }
        let nd = self.shape.n_domains();
        for (gi, g) in self.groups.iter().enumerate() {
            let d = c.home[gi] as usize;
            if d >= nd || g.pinned.is_some_and(|p| p != d) {
                return false;
            }
            let ppm = c.remote_ppm[gi];
            if ppm > 1_000_000 || (ppm > 0 && nd < 2) {
                return false;
            }
            if g.fixed_remote_ppm.is_some_and(|p| p != ppm) {
                return false;
            }
        }
        self.loads(c).iter().zip(&self.domain_cores).all(|(l, cap)| l <= cap)
    }

    /// The initial remote ppm of group `gi` (its fixed value, else 0).
    fn initial_ppm(&self, gi: usize) -> u32 {
        self.groups[gi].fixed_remote_ppm.unwrap_or(0)
    }

    /// First-fit start: pinned groups at their pins, the rest fill
    /// domains in order (the compact policy).
    pub fn start_compact(&self) -> Result<Candidate> {
        self.place_free(|free, _gi, n| free.iter().position(|&(_, room)| room >= n))
    }

    /// Round-robin start: pinned groups at their pins, free group `i`
    /// goes to the first domain with room at or after `i mod nd`.
    pub fn start_scatter(&self) -> Result<Candidate> {
        let nd = self.shape.n_domains();
        let mut turn = 0usize;
        self.place_free(move |free, _gi, n| {
            let pick = (0..free.len())
                .map(|o| (turn + o) % free.len())
                .find(|&i| free[i].1 >= n);
            turn = (turn + 1) % nd.max(1);
            pick
        })
    }

    /// Random feasible start from a deterministic xorshift stream: free
    /// groups pick a uniformly random domain with room; searchable remote
    /// fractions pick a random palette level.
    pub fn start_random(&self, rng: &mut XorShift64) -> Result<Candidate> {
        let mut c = self.place_free(|free, _gi, n| {
            let fits: Vec<usize> =
                (0..free.len()).filter(|&i| free[i].1 >= n).collect();
            // Draw even when placement is forced, to keep the stream
            // length independent of capacities.
            let pick = rng.next_below(fits.len().max(1));
            fits.get(pick).or(fits.first()).copied()
        })?;
        if !self.remote_levels.is_empty() {
            for gi in 0..self.k() {
                let lv = self.remote_levels[rng.next_below(self.remote_levels.len())];
                if self.groups[gi].fixed_remote_ppm.is_none() {
                    c.remote_ppm[gi] = lv;
                }
            }
        }
        Ok(c)
    }

    /// Shared placement scaffold: pins first, then `pick` chooses among
    /// `(domain, room)` slots for each free group in index order.
    fn place_free(
        &self,
        mut pick: impl FnMut(&[(usize, usize)], usize, usize) -> Option<usize>,
    ) -> Result<Candidate> {
        let nd = self.shape.n_domains();
        let mut room = self.domain_cores.clone();
        let mut home = vec![0u16; self.k()];
        for (gi, g) in self.groups.iter().enumerate() {
            if let Some(d) = g.pinned {
                if room[d] < g.n {
                    return Err(Error::InvalidPlan(format!(
                        "pinned group {gi} ({}) overflows domain d{d}",
                        g.name
                    )));
                }
                room[d] -= g.n;
                home[gi] = d as u16;
            }
        }
        for (gi, g) in self.groups.iter().enumerate() {
            if g.pinned.is_some() {
                continue;
            }
            let free: Vec<(usize, usize)> = (0..nd).map(|d| (d, room[d])).collect();
            let slot = pick(&free, gi, g.n).ok_or_else(|| {
                Error::InvalidPlan(format!("no domain has room for group {gi} ({})", g.name))
            })?;
            let d = free[slot].0;
            if room[d] < g.n {
                return Err(Error::InvalidPlan(format!(
                    "picked domain d{d} lacks room for group {gi} ({})",
                    g.name
                )));
            }
            room[d] -= g.n;
            home[gi] = d as u16;
        }
        let remote_ppm = (0..self.k()).map(|gi| self.initial_ppm(gi)).collect();
        Ok(Candidate { home, remote_ppm })
    }

    /// All feasible neighborhood moves of `c`, in deterministic order:
    /// migrations (group asc, domain asc), swaps (i < j), retunes
    /// (group asc, palette asc).
    pub fn neighbors(&self, c: &Candidate) -> Vec<Move> {
        let nd = self.shape.n_domains();
        let load = self.loads(c);
        let mut out = Vec::new();
        for (gi, g) in self.groups.iter().enumerate() {
            if g.pinned.is_some() {
                continue;
            }
            let from = c.home[gi] as usize;
            for d in 0..nd {
                if d != from && load[d] + g.n <= self.domain_cores[d] {
                    out.push(Move::Migrate(gi, d as u16));
                }
            }
        }
        for i in 0..self.k() {
            if self.groups[i].pinned.is_some() {
                continue;
            }
            for j in (i + 1)..self.k() {
                if self.groups[j].pinned.is_some() {
                    continue;
                }
                let (di, dj) = (c.home[i] as usize, c.home[j] as usize);
                if di == dj {
                    continue;
                }
                let (ni, nj) = (self.groups[i].n, self.groups[j].n);
                if load[di] - ni + nj <= self.domain_cores[di]
                    && load[dj] - nj + ni <= self.domain_cores[dj]
                {
                    out.push(Move::Swap(i, j));
                }
            }
        }
        for gi in 0..self.k() {
            if self.groups[gi].fixed_remote_ppm.is_some() {
                continue;
            }
            for &lv in &self.remote_levels {
                if lv != c.remote_ppm[gi] {
                    out.push(Move::Retune(gi, lv));
                }
            }
        }
        out
    }

    /// The analytic-model groups of a candidate, in group order.
    pub fn remote_groups(&self, c: &Candidate) -> Vec<RemoteGroup> {
        self.groups
            .iter()
            .enumerate()
            .map(|(gi, g)| RemoteGroup {
                home: c.home[gi] as usize,
                n: g.n,
                f: g.f,
                bs_gbs: g.bs_gbs,
                remote_frac: c.remote_ppm[gi] as f64 / 1e6,
                kind: g.kind,
            })
            .collect()
    }

    /// The groups whose `(home, remote_frac)` differ between `from` and
    /// `to`, as delta-evaluation changes.
    pub fn changes(&self, from: &Candidate, to: &Candidate) -> Vec<(usize, RemoteGroup)> {
        let mut out = Vec::new();
        for gi in 0..self.k() {
            if from.home[gi] != to.home[gi] || from.remote_ppm[gi] != to.remote_ppm[gi] {
                let g = &self.groups[gi];
                out.push((
                    gi,
                    RemoteGroup {
                        home: to.home[gi] as usize,
                        n: g.n,
                        f: g.f,
                        bs_gbs: g.bs_gbs,
                        remote_frac: to.remote_ppm[gi] as f64 / 1e6,
                        kind: g.kind,
                    },
                ));
            }
        }
        out
    }

    /// A mix-DSL-style label of a candidate:
    /// `dcopy:8@d1%r0.25+ddot2:8@d0`.
    pub fn label(&self, c: &Candidate) -> String {
        let parts: Vec<String> = self
            .groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                let r = c.remote_ppm[gi];
                let suffix = if r > 0 {
                    format!("%r{}", r as f64 / 1e6)
                } else {
                    String::new()
                };
                format!("{}:{}@d{}{}", g.name, g.n, c.home[gi], suffix)
            })
            .collect();
        parts.join("+")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape2x2() -> TopoShape {
        TopoShape {
            socket_of: vec![0, 0, 1, 1],
            bw_scale: vec![1.0; 4],
            link_bw_gbs: 30.0,
            link_bw_rev_gbs: 30.0,
            l3_bw_gbs: 0.0,
        }
    }

    fn group(name: &str, n: usize) -> OptGroup {
        OptGroup {
            name: name.into(),
            kernel: KernelId::Dcopy,
            n,
            f: 0.5,
            bs_gbs: 32.0,
            pinned: None,
            fixed_remote_ppm: None,
            kind: GroupKind::Mem,
        }
    }

    fn space4(groups: Vec<OptGroup>) -> SearchSpace {
        SearchSpace::new(shape2x2(), vec![8; 4], groups, DEFAULT_REMOTE_LEVELS.to_vec()).unwrap()
    }

    #[test]
    fn compact_and_scatter_starts_are_feasible_and_distinct() {
        let s = space4(vec![group("a", 4), group("b", 4), group("c", 4)]);
        let compact = s.start_compact().unwrap();
        let scatter = s.start_scatter().unwrap();
        assert!(s.feasible(&compact));
        assert!(s.feasible(&scatter));
        assert_eq!(compact.home, vec![0, 0, 1]);
        assert_eq!(scatter.home, vec![0, 1, 2]);
    }

    #[test]
    fn pins_and_fixed_fractions_are_respected_everywhere() {
        let mut a = group("a", 4);
        a.pinned = Some(2);
        a.fixed_remote_ppm = Some(250_000);
        let s = space4(vec![a, group("b", 4)]);
        let c = s.start_compact().unwrap();
        assert_eq!(c.home[0], 2);
        assert_eq!(c.remote_ppm[0], 250_000);
        for mv in s.neighbors(&c) {
            match mv {
                Move::Migrate(g, _) | Move::Retune(g, _) => assert_ne!(g, 0),
                Move::Swap(i, j) => {
                    assert_ne!(i, 0);
                    assert_ne!(j, 0);
                }
            }
            assert!(s.feasible(&c.apply(mv)), "{mv:?}");
        }
    }

    #[test]
    fn neighbors_respect_capacity() {
        // Two 8-core groups on 8-core domains: no domain can host both.
        let s = space4(vec![group("a", 8), group("b", 8)]);
        let c = s.start_compact().unwrap();
        assert_eq!(c.home, vec![0, 1]);
        for mv in s.neighbors(&c) {
            assert!(s.feasible(&c.apply(mv)), "{mv:?} breaks capacity");
            if let Move::Migrate(_, d) = mv {
                assert!(d >= 2, "migrating onto an occupied domain must be pruned");
            }
        }
    }

    #[test]
    fn random_starts_are_deterministic_per_seed() {
        let s = space4(vec![group("a", 4), group("b", 4), group("c", 8)]);
        let mut r1 = XorShift64::new(7);
        let mut r2 = XorShift64::new(7);
        let c1 = s.start_random(&mut r1).unwrap();
        let c2 = s.start_random(&mut r2).unwrap();
        assert_eq!(c1, c2);
        assert!(s.feasible(&c1));
    }

    #[test]
    fn label_round_trips_the_mix_dsl_shape() {
        let s = space4(vec![group("dcopy", 4), group("ddot2", 4)]);
        let mut c = s.start_compact().unwrap();
        c.remote_ppm[0] = 250_000;
        assert_eq!(s.label(&c), "dcopy:4@d0%r0.25+ddot2:4@d0");
    }
}
