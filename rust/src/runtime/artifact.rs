//! Artifact discovery and geometry metadata.
//!
//! `make artifacts` (the build-time Python step) writes the HLO text files
//! plus an `artifacts.meta` key=value file describing the compiled shapes;
//! the runtime refuses to run with mismatched geometry rather than
//! producing silent garbage.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Geometry the artifacts were compiled for (see `python/compile/aot.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Batch dimension of the contention simulation.
    pub batch: usize,
    /// Padded core dimension.
    pub n_cores: usize,
    /// Cycles per compiled chunk.
    pub chunk_cycles: usize,
    /// Warm-up chunks baked into the artifact.
    pub warmup_chunks: usize,
    /// Measurement chunks baked into the artifact.
    pub measure_chunks: usize,
    /// Total measured cycles (`measure_chunks * chunk_cycles`).
    pub measure_cycles: usize,
    /// Batch dimension of the analytic-model artifact.
    pub analytic_batch: usize,
}

/// Paths of the artifact bundle.
#[derive(Debug, Clone)]
pub struct ArtifactPaths {
    /// Directory containing the bundle.
    pub dir: PathBuf,
    /// Batched contention simulation HLO.
    pub contention_sim: PathBuf,
    /// Batched analytic model HLO.
    pub analytic_model: PathBuf,
    /// Geometry metadata.
    pub meta: PathBuf,
}

impl ArtifactPaths {
    /// Locate the bundle in `dir`, verifying all files exist.
    pub fn locate(dir: &Path) -> Result<Self> {
        let paths = ArtifactPaths {
            dir: dir.to_path_buf(),
            contention_sim: dir.join("contention_sim.hlo.txt"),
            analytic_model: dir.join("analytic_model.hlo.txt"),
            meta: dir.join("artifacts.meta"),
        };
        for p in [&paths.contention_sim, &paths.analytic_model, &paths.meta] {
            if !p.exists() {
                return Err(Error::MissingArtifact(p.display().to_string()));
            }
        }
        Ok(paths)
    }

    /// Default location: `$MEMBW_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("MEMBW_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Parse the geometry metadata.
    pub fn load_meta(&self) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(&self.meta)?;
        let map: HashMap<&str, &str> = text
            .lines()
            .filter_map(|l| l.split_once('='))
            .map(|(k, v)| (k.trim(), v.trim()))
            .collect();
        let get = |k: &str| -> Result<usize> {
            map.get(k)
                .ok_or_else(|| Error::Config {
                    path: self.meta.display().to_string(),
                    msg: format!("missing key '{k}'"),
                })?
                .parse()
                .map_err(|e| Error::Config {
                    path: self.meta.display().to_string(),
                    msg: format!("bad value for '{k}': {e}"),
                })
        };
        Ok(ArtifactMeta {
            batch: get("batch")?,
            n_cores: get("n_cores")?,
            chunk_cycles: get("chunk_cycles")?,
            warmup_chunks: get("warmup_chunks")?,
            measure_chunks: get("measure_chunks")?,
            measure_cycles: get("measure_cycles")?,
            analytic_batch: get("analytic_batch")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_reported() {
        let err = ArtifactPaths::locate(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn meta_parses_when_bundle_present() {
        // Runs against the real bundle when it has been built.
        let dir = ArtifactPaths::default_dir();
        if let Ok(paths) = ArtifactPaths::locate(&dir) {
            let meta = paths.load_meta().unwrap();
            assert!(meta.batch >= 1);
            assert!(meta.n_cores >= 20, "must cover the largest machine");
            assert_eq!(meta.measure_cycles, meta.measure_chunks * meta.chunk_cycles);
        }
    }
}
