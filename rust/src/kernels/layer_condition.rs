//! Layer-condition (LC) analysis for 2D 5-point stencils.
//!
//! Following Stengel et al. [8]: reuse across the outer stencil dimension is
//! possible at a cache level when three consecutive rows of the source grid
//! fit into (a safety fraction of) that cache. If the LC holds at L2, only
//! one read stream of the source grid crosses L2↔L3; if it is violated at L2
//! but holds at L3, three read streams cross L2↔L3.

use crate::kernels::StreamCounts;

/// Where the layer condition of a 2D 5-point stencil is first fulfilled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerCondition {
    /// Three rows fit into L2 (paper's "LC_L2" grids, e.g. 20000×4000).
    FulfilledAtL2,
    /// Three rows fit into L3 but not L2 ("LC_L3" grids, e.g. 5000×25000).
    FulfilledAtL3,
    /// Three rows do not even fit into L3 — every read comes from memory.
    Violated,
}

/// Result of analyzing a grid against a machine's cache sizes.
#[derive(Debug, Clone, Copy)]
pub struct LcAnalysis {
    /// Outcome of the analysis.
    pub condition: LayerCondition,
    /// Bytes required to hold three consecutive rows.
    pub three_rows_bytes: f64,
}

/// Fraction of a cache that can realistically hold the stencil rows
/// (the rest is occupied by the write stream and other data).
const LC_SAFETY: f64 = 0.5;

/// Analyze the layer condition of a 2D 5-point stencil with `inner` elements
/// per row of `elem_bytes` each, against private L2 and shared-per-core L3
/// capacities in bytes.
pub fn analyze_lc(inner: usize, elem_bytes: usize, l2_bytes: f64, l3_bytes_per_core: f64) -> LcAnalysis {
    let three_rows = (3 * inner * elem_bytes) as f64;
    let condition = if three_rows <= LC_SAFETY * l2_bytes {
        LayerCondition::FulfilledAtL2
    } else if three_rows <= LC_SAFETY * l3_bytes_per_core {
        LayerCondition::FulfilledAtL3
    } else {
        LayerCondition::Violated
    };
    LcAnalysis { condition, three_rows_bytes: three_rows }
}

/// Traffic per unit (one cache line of updates) of a 2D 5-point Jacobi
/// stencil with `extra_read_streams` additional non-stencil read streams
/// (0 for Jacobi-v1, 1 for Jacobi-v2 which also reads the RHS grid F).
///
/// Returns `(mem, l3, l2)` stream counts:
/// * memory traffic is LC-independent (each grid point is loaded once from
///   memory regardless): `1 + extra` reads, 1 write-back, 1 RFO;
/// * L2↔L3 traffic depends on the LC at L2: 1 vs 3 source-read streams;
/// * L1↔L2 traffic assumes the LC at L1 is always violated for the paper's
///   grid sizes (inner dimension ≥ 4000 elements): 3 source-read streams.
pub fn jacobi_traffic(lc: LayerCondition, extra_read_streams: usize) -> (StreamCounts, StreamCounts, StreamCounts) {
    let mem = StreamCounts { reads: 1 + extra_read_streams, writes: 1, rfo: 1 };
    let l3_reads = match lc {
        LayerCondition::FulfilledAtL2 => 1,
        LayerCondition::FulfilledAtL3 | LayerCondition::Violated => 3,
    };
    let l3 = StreamCounts { reads: l3_reads + extra_read_streams, writes: 1, rfo: 1 };
    let l2 = StreamCounts { reads: 3 + extra_read_streams, writes: 1, rfo: 1 };
    (mem, l3, l2)
}

#[cfg(test)]
mod tests {
    use super::*;

    const KIB: f64 = 1024.0;
    const MIB: f64 = 1024.0 * 1024.0;

    #[test]
    fn paper_grid_sizes_reproduce_lc_classes() {
        // BDW: 256 KiB L2, 2.5 MiB L3 per core.
        // LC_L2 grid: 20000 x 4000 (outer x inner).
        let a = analyze_lc(4000, 8, 256.0 * KIB, 2.5 * MIB);
        assert_eq!(a.condition, LayerCondition::FulfilledAtL2);
        // LC_L3 grid: 5000 x 25000.
        let b = analyze_lc(25000, 8, 256.0 * KIB, 2.5 * MIB);
        assert_eq!(b.condition, LayerCondition::FulfilledAtL3);
    }

    #[test]
    fn huge_inner_dimension_violates_even_l3() {
        let a = analyze_lc(50_000_000, 8, 256.0 * KIB, 2.5 * MIB);
        assert_eq!(a.condition, LayerCondition::Violated);
    }

    #[test]
    fn jacobi_v1_traffic_matches_table2() {
        // LC_L2: 3 (1+1+1) at L3 level; LC_L3: 5 (3+1+1) at L3 level.
        let (mem, l3, _l2) = jacobi_traffic(LayerCondition::FulfilledAtL2, 0);
        assert_eq!(mem.total(), 3);
        assert_eq!(l3.total(), 3);
        let (mem, l3, l2) = jacobi_traffic(LayerCondition::FulfilledAtL3, 0);
        assert_eq!(mem.total(), 3);
        assert_eq!(l3.total(), 5);
        assert_eq!(l2.total(), 5);
    }

    #[test]
    fn lc_boundary_is_inclusive_at_exactly_the_safety_fraction() {
        // Three rows landing EXACTLY on LC_SAFETY · cache stay fulfilled
        // (the comparison is `<=`): 3 · inner · 8 == 0.5 · l2 here. Cache
        // sizes are picked divisible by 3 · 8 / LC_SAFETY so the boundary
        // grid is exactly representable.
        let l2 = 240.0 * KIB;
        let l3 = 2400.0 * KIB;
        let inner = (LC_SAFETY * l2) as usize / (3 * 8);
        assert_eq!(3.0 * (inner * 8) as f64, LC_SAFETY * l2, "exact boundary grid");
        let at = analyze_lc(inner, 8, l2, l3);
        assert_eq!(at.condition, LayerCondition::FulfilledAtL2);
        assert_eq!(at.three_rows_bytes, LC_SAFETY * l2);
        // One element more tips over to the next level; same at the L3
        // boundary.
        let over = analyze_lc(inner + 1, 8, l2, l3);
        assert_eq!(over.condition, LayerCondition::FulfilledAtL3);
        let inner3 = (LC_SAFETY * l3) as usize / (3 * 8);
        assert_eq!(3.0 * (inner3 * 8) as f64, LC_SAFETY * l3, "exact boundary grid");
        assert_eq!(analyze_lc(inner3, 8, l2, l3).condition, LayerCondition::FulfilledAtL3);
        assert_eq!(analyze_lc(inner3 + 1, 8, l2, l3).condition, LayerCondition::Violated);
    }

    #[test]
    fn violated_lc_streams_match_the_l3_class_at_every_level() {
        // LC violated at L3: the source rows re-stream from memory at the
        // L2↔L3 boundary exactly as in the LC_L3 class (3 + extra reads);
        // per-level stream counts pin reads/writes/rfo individually, not
        // just the totals.
        for extra in [0usize, 1] {
            let (mem, l3, l2) = jacobi_traffic(LayerCondition::Violated, extra);
            let (_, l3_lc3, l2_lc3) = jacobi_traffic(LayerCondition::FulfilledAtL3, extra);
            assert_eq!((l3.reads, l3.writes, l3.rfo), (3 + extra, 1, 1));
            assert_eq!((l3.reads, l3.writes, l3.rfo), (l3_lc3.reads, l3_lc3.writes, l3_lc3.rfo));
            assert_eq!((l2.reads, l2.writes, l2.rfo), (l2_lc3.reads, l2_lc3.writes, l2_lc3.rfo));
            assert_eq!((mem.reads, mem.writes, mem.rfo), (1 + extra, 1, 1));
        }
    }

    #[test]
    fn jacobi_v2_traffic_matches_table2() {
        // v2 reads an extra RHS grid: LC_L2 4 (2+1+1), LC_L3 6 (4+1+1).
        let (mem, l3, _) = jacobi_traffic(LayerCondition::FulfilledAtL2, 1);
        assert_eq!(mem.total(), 4);
        assert_eq!(l3.total(), 4);
        let (_, l3, _) = jacobi_traffic(LayerCondition::FulfilledAtL3, 1);
        assert_eq!(l3.total(), 6);
    }
}
