//! The paper's contribution: the analytic bandwidth-sharing model.
//!
//! * [`model`] — Eqs. (4) and (5) for two thread groups,
//! * [`multigroup`] — the natural k-group generalization (used by the
//!   desynchronization co-simulator and the task-scheduler example), plus
//!   the per-ccNUMA-domain evaluation [`share_domains`] (domains share no
//!   state; each gets its own Eqs. 4+5),
//! * [`baseline`] — the naive models the paper argues against (equal share
//!   per thread; code-balance-weighted share), kept as ablation baselines,
//! * [`desync_predictor`] — qualitative desync/resync prediction from
//!   kernel pairings (Sect. V closing discussion),
//! * [`share_cache`] — memoized multigroup evaluations keyed by group
//!   composition (the contention-timeline engine's hot lookup).

mod baseline;
mod desync_predictor;
mod model;
mod multigroup;
mod share_cache;

pub use baseline::{code_balance_share, equal_share, BaselineKind};
pub use desync_predictor::{predict_skew, OverlapPartner, SkewPrediction};
pub use model::{overlapped_saturated_bw, share_two_groups, KernelGroup, SharingPrediction};
pub use multigroup::{share_domains, share_multigroup, GroupShare, GroupShareEntry};
pub use share_cache::{ShareCache, ShareCacheStats, MAX_GROUP_CORES, MAX_SLOTS};
