//! Simplified recursive ECM multicore scaling model (Sect. III).
//!
//! At `n` cores a latency penalty `p0 * u(n-1) * (n-1)` is added to the
//! single-core runtime, with `u(i)` the utilization of the memory interface
//! at `i` cores, `u(1) = f`, and `p0 = T_Mem / 2`. Bandwidth is additionally
//! capped by the saturated bandwidth of the kernel.

use crate::config::Machine;
use crate::ecm::prediction::EcmPrediction;

/// One point of the predicted scaling curve.
#[derive(Debug, Clone, Copy)]
pub struct ScalingPoint {
    /// Active cores.
    pub n: usize,
    /// Predicted runtime per unit at `n` cores (cycles).
    pub t_cycles: f64,
    /// Predicted utilization of the memory interface `u(n)`.
    pub u: f64,
    /// Predicted aggregate memory bandwidth, GB/s.
    pub bw_gbs: f64,
    /// Predicted per-core bandwidth, GB/s.
    pub bw_per_core_gbs: f64,
}

/// Predicted scaling curve of a homogeneous kernel from 1 to `n_max` cores.
pub fn scaling_curve(p: &EcmPrediction, m: &Machine, n_max: usize) -> Vec<ScalingPoint> {
    let p0 = p.app.t_mem / 2.0 * m.queue.latency_penalty;
    let mut out = Vec::with_capacity(n_max);
    let mut u_prev = p.f; // u(1) = f
    for n in 1..=n_max {
        let penalty = if n > 1 { p0 * u_prev * (n as f64 - 1.0) } else { 0.0 };
        let t = p.t_ecm + penalty;
        // Raw (uncapped) aggregate bandwidth from n cores at runtime t.
        let raw_lines_per_cy = n as f64 * p.app.mem_lines / t;
        let raw_bw = m.lines_per_cy_to_gbs(raw_lines_per_cy);
        let bw = raw_bw.min(p.bs_gbs);
        let u = (n as f64 * p.app.t_mem / t).min(1.0);
        out.push(ScalingPoint {
            n,
            t_cycles: t,
            u,
            bw_gbs: bw,
            bw_per_core_gbs: bw / n as f64,
        });
        u_prev = u;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::ecm::predict;
    use crate::kernels::{kernel, KernelId};

    #[test]
    fn bandwidth_monotone_and_saturating() {
        let m = machine(MachineId::Bdw1);
        let p = predict(&kernel(KernelId::Stream), &m);
        let curve = scaling_curve(&p, &m, m.cores);
        for w in curve.windows(2) {
            assert!(w[1].bw_gbs >= w[0].bw_gbs - 1e-9, "aggregate bw must not decrease");
            assert!(
                w[1].bw_per_core_gbs <= w[0].bw_per_core_gbs + 1e-9,
                "per-core bw must not increase"
            );
        }
        let last = curve.last().unwrap();
        assert!((last.bw_gbs - p.bs_gbs).abs() / p.bs_gbs < 0.02, "domain saturates");
    }

    #[test]
    fn single_core_point_equals_b1() {
        let m = machine(MachineId::Clx);
        let p = predict(&kernel(KernelId::Ddot2), &m);
        let curve = scaling_curve(&p, &m, 4);
        assert!((curve[0].bw_gbs - p.b1_gbs).abs() / p.b1_gbs < 1e-9);
    }

    /// CLX needs more cores to reach saturation than BDW-1 (it is "more
    /// scalable", Sect. V) — its saturation core count is higher.
    #[test]
    fn clx_saturates_later_than_bdw1() {
        let sat_cores = |id: MachineId| -> usize {
            let m = machine(id);
            let p = predict(&kernel(KernelId::Stream), &m);
            let curve = scaling_curve(&p, &m, m.cores);
            curve
                .iter()
                .find(|pt| pt.bw_gbs > 0.95 * p.bs_gbs)
                .map(|pt| pt.n)
                .unwrap_or(m.cores)
        };
        assert!(sat_cores(MachineId::Clx) > sat_cores(MachineId::Bdw1));
    }

    /// Rome nearly saturates with a single thread (overlapping hierarchy).
    #[test]
    fn rome_saturates_almost_immediately() {
        let m = machine(MachineId::Rome);
        let p = predict(&kernel(KernelId::Ddot2), &m);
        assert!(p.b1_gbs / p.bs_gbs > 0.7, "b1/bs = {}", p.b1_gbs / p.bs_gbs);
    }
}
