//! Machine topology: sockets → ccNUMA domains → cores.
//!
//! The paper's contention unit is one ccNUMA memory domain (its Table I
//! describes exactly one), but its Rome testbed runs NPS4 — *four* such
//! domains per socket. A [`Topology`] makes that structure explicit: an
//! ordered list of [`Domain`]s, each a full contention domain (a
//! [`Machine`], possibly with a per-domain saturated-bandwidth scale for
//! asymmetric DIMM population), grouped into sockets. Contention is
//! evaluated *independently per domain* — that is the physical content of
//! "ccNUMA": a core only queues against its own domain's memory interface.
//!
//! The single-domain [`Topology::single`] is the degenerate case every
//! pre-topology entry point reduces to; conformance tests pin it
//! bit-identical to the legacy single-domain paths.
//!
//! Multi-socket topologies (`<S>x<D>` specs) additionally expose the
//! inter-socket links ([`Topology::links`]) as contention interfaces for
//! the remote-access extension ([`crate::sharing::remote`]), and
//! Sub-NUMA-Clustering specs (`snc2`, `snc4`) split a monolithic Intel
//! socket into equal sub-domains. Cluster specs (`<N>n<spec>`, e.g.
//! `64n1x4`) replicate one node shape N times: bandwidth is shared only
//! within a node, while collectives couple the nodes in time — the
//! substrate of the cluster-scale co-simulation (`docs/SIMULATORS.md`).
//! `placement` holds the other half of the layer: how work lands on the
//! domains (compact / scatter / explicit `@dN` pinning) and the
//! per-domain splitting of workload mixes and rank sets.
//!
//! # Examples
//!
//! ```
//! use membw::config::{machine, MachineId};
//! use membw::topology::Topology;
//!
//! let rome = machine(MachineId::Rome);
//! // Two sockets x NPS4: eight ccNUMA domains, one full-duplex xGMI link
//! // (two directed interfaces).
//! let two_socket = Topology::parse(&rome, "2x4").unwrap();
//! assert_eq!(two_socket.n_domains(), 8);
//! assert_eq!(two_socket.domains[4].socket, 1);
//! assert_eq!(two_socket.links(), vec![(0, 1), (1, 0)]);
//!
//! // Sub-NUMA-Clustering splits a monolithic Cascade Lake socket.
//! let clx = machine(MachineId::Clx);
//! let snc2 = Topology::parse(&clx, "snc2").unwrap();
//! assert_eq!(snc2.n_domains(), 2);
//! assert_eq!(snc2.domains[0].machine.cores, clx.cores / 2);
//!
//! // A 64-node cluster of NPS4 Rome sockets: 256 domains, node-major.
//! let cluster = Topology::parse(&rome, "64n1x4").unwrap();
//! assert_eq!(cluster.nodes, 64);
//! assert_eq!(cluster.n_domains(), 256);
//! assert_eq!(cluster.node_of()[5], 1);
//! ```

mod placement;

pub use placement::{DomainMix, GroupPlacement, Placement, RankLayout, RemoteTraffic, SplitMix};

use crate::config::Machine;
use crate::error::{Error, Result};
use crate::sharing::TopoShape;

/// Upper bound on ccNUMA domains per topology. Sized for cluster specs
/// (`<N>n...`): 256 NPS4 nodes still fit; each domain clones a full
/// [`Machine`], so an absurd spec must fail cleanly instead of exhausting
/// memory.
pub const MAX_DOMAINS: usize = 4096;

/// One ccNUMA contention domain of a topology.
#[derive(Debug, Clone)]
pub struct Domain {
    /// Domain id, dense from 0 in socket order.
    pub id: usize,
    /// Socket the domain belongs to.
    pub socket: usize,
    /// Saturated-bandwidth scale relative to the machine's Table I row
    /// (1.0 = nominal; ≠ 1.0 models asymmetric DIMM population).
    pub bw_scale: f64,
    /// The domain as a machine model: the base machine with memory
    /// bandwidths scaled by `bw_scale`. Core count is per domain.
    pub machine: Machine,
}

/// A machine topology: an ordered list of ccNUMA domains grouped into
/// sockets, all instances of one base [`Machine`] row.
#[derive(Debug, Clone)]
pub struct Topology {
    /// The Table I row every domain instantiates.
    pub base: Machine,
    /// Number of sockets (total over all nodes of a cluster).
    pub sockets: usize,
    /// Number of cluster nodes (1 for every single-node topology). Nodes
    /// are identical replicas of one node shape; bandwidth is shared only
    /// *within* a node (remote traffic spreads over the other domains of
    /// the same node), while collectives couple nodes in time.
    pub nodes: usize,
    /// The domains, dense ids in socket order (node-major on clusters).
    pub domains: Vec<Domain>,
}

fn domain_machine(base: &Machine, bw_scale: f64) -> Machine {
    if bw_scale == 1.0 {
        return base.clone();
    }
    let mut m = base.clone();
    m.theor_bw_gbs *= bw_scale;
    m.read_bw_gbs *= bw_scale;
    m
}

impl Topology {
    /// Build a topology of `sockets` × `domains_per_socket` domains with
    /// per-domain bandwidth scales (`scales.len()` must equal the domain
    /// count; pass all-1.0 for nominal domains). At most [`MAX_DOMAINS`]
    /// domains — each domain clones a full [`Machine`], so an absurd CLI
    /// spec must fail cleanly instead of exhausting memory.
    pub fn build(base: &Machine, sockets: usize, domains_per_socket: usize, scales: &[f64]) -> Result<Self> {
        let nd = sockets
            .checked_mul(domains_per_socket)
            .filter(|&nd| nd <= MAX_DOMAINS)
            .ok_or_else(|| {
                Error::InvalidPlan(format!(
                    "topology of {sockets} x {domains_per_socket} domains exceeds the \
                     {MAX_DOMAINS}-domain limit"
                ))
            })?;
        if nd == 0 {
            return Err(Error::InvalidPlan("topology needs at least one domain".into()));
        }
        if scales.len() != nd {
            return Err(Error::InvalidPlan(format!(
                "topology has {nd} domains but {} bandwidth scales were given",
                scales.len()
            )));
        }
        for (d, &s) in scales.iter().enumerate() {
            if !(s.is_finite() && s > 0.0) {
                return Err(Error::InvalidPlan(format!("bad bandwidth scale {s} for domain d{d}")));
            }
        }
        let domains = scales
            .iter()
            .enumerate()
            .map(|(id, &bw_scale)| Domain {
                id,
                socket: id / domains_per_socket,
                bw_scale,
                machine: domain_machine(base, bw_scale),
            })
            .collect();
        Ok(Topology { base: base.clone(), sockets, nodes: 1, domains })
    }

    /// A cluster of `n_nodes` identical nodes, each a replica of `node`
    /// (which must itself be single-node). Domain ids stay dense in
    /// node-major socket order; sockets are numbered across nodes, so the
    /// existing socket machinery (links within a node, collective hop
    /// latency) extends unchanged.
    pub fn cluster(node: &Topology, n_nodes: usize) -> Result<Self> {
        if n_nodes == 0 {
            return Err(Error::InvalidPlan("cluster needs at least one node".into()));
        }
        if node.nodes != 1 {
            return Err(Error::InvalidPlan("nested cluster specs are not supported".into()));
        }
        node.n_domains()
            .checked_mul(n_nodes)
            .filter(|&nd| nd <= MAX_DOMAINS)
            .ok_or_else(|| {
                Error::InvalidPlan(format!(
                    "cluster of {n_nodes} x {} domains exceeds the {MAX_DOMAINS}-domain limit",
                    node.n_domains()
                ))
            })?;
        let mut domains = Vec::with_capacity(node.n_domains() * n_nodes);
        for node_i in 0..n_nodes {
            for d in &node.domains {
                domains.push(Domain {
                    id: domains.len(),
                    socket: node_i * node.sockets + d.socket,
                    bw_scale: d.bw_scale,
                    machine: d.machine.clone(),
                });
            }
        }
        Ok(Topology {
            base: node.base.clone(),
            sockets: n_nodes * node.sockets,
            nodes: n_nodes,
            domains,
        })
    }

    /// The degenerate single-domain topology (the pre-topology model).
    pub fn single(base: &Machine) -> Self {
        Topology::build(base, 1, 1, &[1.0]).expect("1x1 topology is always valid")
    }

    /// One full socket: `base.domains_per_socket` nominal domains (4 on
    /// Rome NPS4, 1 on the Intel machines).
    pub fn socket(base: &Machine) -> Self {
        let dps = base.domains_per_socket.max(1);
        Topology::build(base, 1, dps, &vec![1.0; dps]).expect("socket topology is always valid")
    }

    /// `n` nominal domains on one socket (explicit domain count).
    pub fn with_domains(base: &Machine, n: usize) -> Result<Self> {
        Topology::build(base, 1, n, &vec![1.0; n])
    }

    /// Number of ccNUMA domains.
    pub fn n_domains(&self) -> usize {
        self.domains.len()
    }

    /// Total cores over all domains.
    pub fn total_cores(&self) -> usize {
        self.domains.iter().map(|d| d.machine.cores).sum()
    }

    /// The domain a core belongs to under the canonical dense core
    /// numbering (cores 0..c-1 in domain 0, then domain 1, ...).
    pub fn domain_of_core(&self, core: usize) -> Option<usize> {
        let mut offset = 0;
        for d in &self.domains {
            offset += d.machine.cores;
            if core < offset {
                return Some(d.id);
            }
        }
        None
    }

    /// Whether this is the degenerate pre-topology case: one nominal
    /// domain.
    pub fn is_single(&self) -> bool {
        self.domains.len() == 1 && self.domains[0].bw_scale == 1.0
    }

    /// Per-domain bandwidth scales, in domain order.
    pub fn bw_scales(&self) -> Vec<f64> {
        self.domains.iter().map(|d| d.bw_scale).collect()
    }

    /// Socket of each domain, in domain order.
    pub fn socket_of(&self) -> Vec<usize> {
        self.domains.iter().map(|d| d.socket).collect()
    }

    /// Sockets per cluster node (= `sockets` on single-node topologies).
    pub fn sockets_per_node(&self) -> usize {
        self.sockets / self.nodes.max(1)
    }

    /// ccNUMA domains per cluster node (= `n_domains()` on single-node
    /// topologies).
    pub fn domains_per_node(&self) -> usize {
        self.n_domains() / self.nodes.max(1)
    }

    /// Cluster node of each domain, in domain order (all zero on
    /// single-node topologies).
    pub fn node_of(&self) -> Vec<usize> {
        let spn = self.sockets_per_node().max(1);
        self.domains.iter().map(|d| d.socket / spn).collect()
    }

    /// The directed inter-socket links (all *ordered* socket pairs `a → b`
    /// with `a ≠ b`, lexicographic — each physical link contributes one
    /// interface per duplex direction); empty on single-socket topologies.
    pub fn links(&self) -> Vec<(usize, usize)> {
        self.shape().links()
    }

    /// The topology as the remote-access model sees it: domain→socket map,
    /// bandwidth scales, and the base machine's per-direction link
    /// bandwidths.
    pub fn shape(&self) -> TopoShape {
        TopoShape {
            socket_of: self.socket_of(),
            bw_scale: self.bw_scales(),
            link_bw_gbs: self.base.link_bw_gbs,
            link_bw_rev_gbs: self.base.link_bw_rev_gbs,
            l3_bw_gbs: self.base.l3_bw_gbs,
        }
    }

    /// Extra collective (Allreduce) release latency of the topology: each
    /// socket beyond the first adds one inter-socket hop,
    /// `(S-1) · link_latency`. Zero on single-socket topologies.
    pub fn collective_extra_s(&self) -> f64 {
        self.sockets.saturating_sub(1) as f64 * self.base.link_latency_us * 1e-6
    }

    /// Compact display label, e.g. `rome-1s4d` (1 socket × 4 domains) or
    /// `rome-64n1s4d` (64 nodes × 1 socket × 4 domains).
    pub fn label(&self) -> String {
        let dps = self.domains.len() / self.sockets.max(1);
        if self.nodes > 1 {
            format!(
                "{}-{}n{}s{}d",
                self.base.id.key(),
                self.nodes,
                self.sockets_per_node(),
                dps
            )
        } else {
            format!("{}-{}s{}d", self.base.id.key(), self.sockets, dps)
        }
    }

    /// The base row of a Sub-NUMA-Clustering mode: the monolithic socket
    /// described by `base` split into `n` equal sub-domains (cores and
    /// memory channels divide evenly; the per-domain saturated bandwidth is
    /// `1/n` of the socket's). Inter-socket link parameters are per link
    /// and stay untouched.
    fn snc_base(base: &Machine, n: usize) -> Result<Machine> {
        if n < 2 {
            return Err(Error::InvalidPlan(format!(
                "SNC needs at least 2 sub-domains (got {n})"
            )));
        }
        if base.cores % n != 0 {
            return Err(Error::InvalidPlan(format!(
                "snc{n} needs a core count divisible by {n}, but {} has {} cores",
                base.name, base.cores
            )));
        }
        let mut m = base.clone();
        m.cores /= n;
        m.theor_bw_gbs /= n as f64;
        m.read_bw_gbs /= n as f64;
        m.domains_per_socket = n;
        m.microarch = format!("{} SNC{n}", m.microarch);
        Ok(m)
    }

    /// Parse a CLI topology spec against a base machine:
    ///
    /// * `domain` (or `single`) — one domain, the degenerate case;
    /// * `socket` — the machine's full socket (`domains_per_socket` domains);
    /// * `<D>` — D domains on one socket (e.g. `4`);
    /// * `<S>x<D>` — S sockets × D domains each (e.g. `2x4`);
    /// * `snc<N>` / `<S>xsnc<N>` — Sub-NUMA-Clustering: the monolithic
    ///   socket row split into N equal sub-domains (e.g. `snc2` on CLX);
    /// * `<N>n<spec>` — a cluster of N identical nodes, each the inner
    ///   spec (e.g. `64n1x4`, `8n2xsnc2`); bandwidth scales apply per node
    ///   and replicate across nodes;
    /// * an optional `@s0,s1,...` suffix with one saturated-bandwidth scale
    ///   per domain (e.g. `4@1,1,0.9,0.95`).
    pub fn parse(base: &Machine, spec: &str) -> Result<Self> {
        let spec = spec.trim();
        // `<N>n<inner>` cluster prefix: digits followed by 'n'. No other
        // spec form starts with digits-then-'n' ("snc2" starts with 's',
        // "<S>x<D>" has no 'n'), so the prefix is unambiguous.
        if let Some((count_txt, inner)) = spec.split_once('n') {
            if !count_txt.is_empty() && count_txt.chars().all(|c| c.is_ascii_digit()) {
                let n_nodes: usize = count_txt.parse().map_err(|_| {
                    Error::InvalidPlan(format!(
                        "bad node count '{count_txt}' in topology spec '{spec}'"
                    ))
                })?;
                let node = Topology::parse(base, inner)?;
                return Topology::cluster(&node, n_nodes);
            }
        }
        let (shape, scales_txt) = match spec.split_once('@') {
            Some((s, sc)) => (s.trim(), Some(sc.trim())),
            None => (spec, None),
        };
        let (sockets, dps, snc) = match shape.to_ascii_lowercase().as_str() {
            "domain" | "single" => (1, 1, false),
            "socket" => (1, base.domains_per_socket.max(1), false),
            other => {
                let parse_dim = |s: &str, what: &str| -> Result<usize> {
                    match s.trim().parse::<usize>() {
                        Ok(v) if v >= 1 => Ok(v),
                        _ => Err(Error::InvalidPlan(format!(
                            "bad {what} '{s}' in topology spec '{spec}' \
                             (expected: domain, socket, <D>, <S>x<D>, snc<N>, or <S>xsnc<N>)"
                        ))),
                    }
                };
                let (socket_txt, domain_txt) = match other.split_once('x') {
                    Some((s, d)) => (Some(s), d),
                    None => (None, other),
                };
                let sockets = match socket_txt {
                    Some(s) => parse_dim(s, "socket count")?,
                    None => 1,
                };
                match domain_txt.trim().strip_prefix("snc") {
                    Some(n_txt) => (sockets, parse_dim(n_txt, "SNC sub-domain count")?, true),
                    None => (sockets, parse_dim(domain_txt, "domain count")?, false),
                }
            }
        };
        let nd = sockets * dps;
        let scales = match scales_txt {
            None => vec![1.0; nd],
            Some(txt) => txt
                .split(',')
                .map(|t| {
                    t.trim().parse::<f64>().map_err(|_| {
                        Error::InvalidPlan(format!(
                            "bad bandwidth scale '{t}' in topology spec '{spec}'"
                        ))
                    })
                })
                .collect::<Result<Vec<f64>>>()?,
        };
        if snc {
            let sub = Topology::snc_base(base, dps)?;
            Topology::build(&sub, sockets, dps, &scales)
        } else {
            Topology::build(base, sockets, dps, &scales)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};

    #[test]
    fn single_topology_is_degenerate() {
        let m = machine(MachineId::Clx);
        let t = Topology::single(&m);
        assert!(t.is_single());
        assert_eq!(t.n_domains(), 1);
        assert_eq!(t.total_cores(), m.cores);
        // The degenerate domain is the base machine, unscaled.
        assert_eq!(t.domains[0].machine.read_bw_gbs.to_bits(), m.read_bw_gbs.to_bits());
    }

    #[test]
    fn rome_socket_expands_to_nps4() {
        let m = machine(MachineId::Rome);
        let t = Topology::socket(&m);
        assert_eq!(t.n_domains(), 4);
        assert_eq!(t.total_cores(), 32);
        assert_eq!(t.label(), "rome-1s4d");
        for d in &t.domains {
            assert_eq!(d.socket, 0);
            assert_eq!(d.machine.cores, 8);
        }
        // Intel sockets stay monolithic.
        let clx = Topology::socket(&machine(MachineId::Clx));
        assert_eq!(clx.n_domains(), 1);
    }

    #[test]
    fn core_to_domain_mapping_is_dense() {
        let t = Topology::socket(&machine(MachineId::Rome));
        assert_eq!(t.domain_of_core(0), Some(0));
        assert_eq!(t.domain_of_core(7), Some(0));
        assert_eq!(t.domain_of_core(8), Some(1));
        assert_eq!(t.domain_of_core(31), Some(3));
        assert_eq!(t.domain_of_core(32), None);
    }

    #[test]
    fn bandwidth_scales_apply_per_domain() {
        let m = machine(MachineId::Rome);
        let t = Topology::build(&m, 1, 4, &[1.0, 1.0, 0.9, 0.5]).unwrap();
        assert!(!t.is_single());
        assert_eq!(t.domains[0].machine.read_bw_gbs.to_bits(), m.read_bw_gbs.to_bits());
        assert!((t.domains[2].machine.read_bw_gbs - 0.9 * m.read_bw_gbs).abs() < 1e-12);
        assert!((t.domains[3].machine.read_bw_gbs - 0.5 * m.read_bw_gbs).abs() < 1e-12);
        assert!(Topology::build(&m, 1, 4, &[1.0]).is_err(), "scale arity enforced");
        assert!(Topology::build(&m, 1, 4, &[1.0, 1.0, 0.0, 1.0]).is_err(), "positive scales");
    }

    #[test]
    fn snc_specs_split_monolithic_sockets() {
        let clx = machine(MachineId::Clx); // 20 cores, 110 GB/s read
        let snc2 = Topology::parse(&clx, "snc2").unwrap();
        assert_eq!(snc2.n_domains(), 2);
        assert_eq!(snc2.total_cores(), clx.cores);
        for d in &snc2.domains {
            assert_eq!(d.machine.cores, 10);
            assert!((d.machine.read_bw_gbs - clx.read_bw_gbs / 2.0).abs() < 1e-12);
        }
        let snc4 = Topology::parse(&clx, "snc4").unwrap();
        assert_eq!(snc4.n_domains(), 4);
        assert_eq!(snc4.domains[0].machine.cores, 5);
        // Two-socket SNC2: four domains over two sockets.
        let two = Topology::parse(&clx, "2xsnc2").unwrap();
        assert_eq!(two.n_domains(), 4);
        assert_eq!(two.sockets, 2);
        assert_eq!(two.domains[2].socket, 1);
        // Link parameters are per link, not per domain: untouched by SNC.
        assert_eq!(two.base.link_bw_gbs.to_bits(), clx.link_bw_gbs.to_bits());
        // BDW-1 has 10 cores: snc4 does not divide evenly.
        let bdw = machine(MachineId::Bdw1);
        assert!(Topology::parse(&bdw, "snc4").is_err());
        assert!(Topology::parse(&bdw, "snc2").is_ok());
        assert!(Topology::parse(&clx, "snc1").is_err(), "SNC needs >= 2 sub-domains");
        assert!(Topology::parse(&clx, "sncx").is_err());
    }

    #[test]
    fn links_and_shape_expose_socket_structure() {
        let m = machine(MachineId::Rome);
        let one = Topology::socket(&m);
        assert!(one.links().is_empty());
        assert_eq!(one.collective_extra_s(), 0.0);
        let two = Topology::parse(&m, "2x4").unwrap();
        // Directed duplex: one interface per direction of the socket pair.
        assert_eq!(two.links(), vec![(0, 1), (1, 0)]);
        let shape = two.shape();
        assert_eq!(shape.socket_of, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        assert_eq!(shape.n_sockets(), 2);
        assert_eq!(shape.link_bw_gbs.to_bits(), m.link_bw_gbs.to_bits());
        assert_eq!(shape.link_bw_rev_gbs.to_bits(), m.link_bw_rev_gbs.to_bits());
        let want = m.link_latency_us * 1e-6;
        assert!((two.collective_extra_s() - want).abs() < 1e-18);
        let four = Topology::parse(&m, "4x1").unwrap();
        assert_eq!(four.links().len(), 12);
        assert!((four.collective_extra_s() - 3.0 * want).abs() < 1e-18);
    }

    #[test]
    fn parse_accepts_all_spec_forms() {
        let m = machine(MachineId::Rome);
        assert_eq!(Topology::parse(&m, "domain").unwrap().n_domains(), 1);
        assert_eq!(Topology::parse(&m, "single").unwrap().n_domains(), 1);
        assert_eq!(Topology::parse(&m, "socket").unwrap().n_domains(), 4);
        assert_eq!(Topology::parse(&m, "2").unwrap().n_domains(), 2);
        let two_socket = Topology::parse(&m, "2x4").unwrap();
        assert_eq!(two_socket.n_domains(), 8);
        assert_eq!(two_socket.sockets, 2);
        assert_eq!(two_socket.domains[4].socket, 1);
        let scaled = Topology::parse(&m, "4@1,1,0.9,0.95").unwrap();
        assert!((scaled.domains[3].bw_scale - 0.95).abs() < 1e-12);
        assert!(Topology::parse(&m, "0").is_err());
        assert!(Topology::parse(&m, "4@1,1").is_err());
        assert!(Topology::parse(&m, "fullmesh").is_err());
        // Absurd sizes fail cleanly (no allocation, no overflow).
        assert!(Topology::parse(&m, "1000000000x100").is_err());
        assert!(Topology::parse(&m, "8192").is_err());
    }

    #[test]
    fn cluster_specs_replicate_nodes() {
        let m = machine(MachineId::Rome);
        let c = Topology::parse(&m, "64n1x4").unwrap();
        assert_eq!(c.nodes, 64);
        assert_eq!(c.sockets, 64);
        assert_eq!(c.sockets_per_node(), 1);
        assert_eq!(c.domains_per_node(), 4);
        assert_eq!(c.n_domains(), 256);
        assert_eq!(c.total_cores(), 64 * 32);
        assert_eq!(c.label(), "rome-64n1s4d");
        // Node-major socket and node numbering.
        assert_eq!(c.domains[4].socket, 1);
        let node_of = c.node_of();
        assert_eq!(node_of[0], 0);
        assert_eq!(node_of[3], 0);
        assert_eq!(node_of[4], 1);
        assert_eq!(node_of[255], 63);
        // Multi-socket nodes: sockets number across nodes.
        let two = Topology::parse(&m, "2n2x4").unwrap();
        assert_eq!(two.nodes, 2);
        assert_eq!(two.sockets, 4);
        assert_eq!(two.sockets_per_node(), 2);
        assert_eq!(two.domains[8].socket, 2);
        assert_eq!(two.node_of(), [vec![0usize; 8], vec![1usize; 8]].concat());
        // SNC inner specs compose.
        let clx = machine(MachineId::Clx);
        let snc = Topology::parse(&clx, "4n2xsnc2").unwrap();
        assert_eq!(snc.nodes, 4);
        assert_eq!(snc.n_domains(), 16);
        assert_eq!(snc.domains[0].machine.cores, clx.cores / 2);
        // Per-node scales replicate across nodes.
        let scaled = Topology::parse(&m, "2n4@1,1,0.9,0.95").unwrap();
        assert!((scaled.domains[7].bw_scale - 0.95).abs() < 1e-12);
        assert!((scaled.domains[2].bw_scale - 0.9).abs() < 1e-12);
        // Degenerate one-node cluster is the inner topology plus nodes=1.
        let one = Topology::parse(&m, "1nsocket").unwrap();
        assert_eq!(one.nodes, 1);
        assert_eq!(one.n_domains(), 4);
        assert_eq!(one.label(), "rome-1s4d");
        // Rejections: zero nodes, nesting, over the domain cap.
        assert!(Topology::parse(&m, "0n4").is_err());
        assert!(Topology::parse(&m, "2n2n4").is_err());
        assert!(Topology::parse(&m, "100000n1x4").is_err());
    }
}
