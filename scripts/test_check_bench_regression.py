#!/usr/bin/env python3
"""Tests for the bench regression gate (``check_bench_regression.py``).

The gate is the only thing standing between a perf regression and a green
CI run, so it gets its own coverage: the pass, fail, unseeded-skip,
mode-mismatch, and ``--update`` paths are each exercised end-to-end as a
subprocess against fixture JSON — including the ``BENCH_serve.json``
metrics of the streaming co-scheduling service.

Stdlib only; runs in CI right before the real gate::

    python3 scripts/test_check_bench_regression.py
"""

from __future__ import annotations

import json
import subprocess
import sys
import tempfile
import unittest
from pathlib import Path

GATE = Path(__file__).resolve().parent / "check_bench_regression.py"


def serve_doc(requests_per_s: float, speedup: float, mode: str = "smoke") -> dict:
    """A minimal but schema-true BENCH_serve.json document."""
    return {
        "mode": mode,
        "serve": {
            "topology": "2x4",
            "requests": 10,
            "submits": 9,
            "budget": 400,
            "repack_every": 8,
            "wall_s": 10.0 / requests_per_s,
            "requests_per_s": requests_per_s,
            "cold_wall_s": 1.0,
            "cold_requests_per_s": requests_per_s / speedup,
            "speedup_vs_cold": speedup,
            "final_score": 123.456,
            "memo": {"hits": 1000, "misses": 100, "entries": 100},
        },
        "char_cache": {"hits": 10, "misses": 8, "entries": 8},
    }


def optimizer_doc(evals_per_s: float, mode: str = "smoke") -> dict:
    return {
        "mode": mode,
        "optimizer": {"evaluations_per_s": evals_per_s, "speedup_vs_full": 4.0},
        "char_cache": {"hits": 1, "misses": 1, "entries": 1},
    }


def run_gate(*args: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, str(GATE), *args],
        capture_output=True,
        text=True,
        check=False,
    )


class GateTest(unittest.TestCase):
    def setUp(self) -> None:
        self._tmp = tempfile.TemporaryDirectory()
        root = Path(self._tmp.name)
        self.results = root / "results"
        self.baselines = root / "baselines"
        self.results.mkdir()
        self.baselines.mkdir()

    def tearDown(self) -> None:
        self._tmp.cleanup()

    def write(self, where: Path, name: str, doc: dict) -> None:
        (where / name).write_text(json.dumps(doc) + "\n")

    def gate(self, *extra: str) -> subprocess.CompletedProcess:
        return run_gate(
            "--results", str(self.results), "--baselines", str(self.baselines), *extra
        )

    def test_pass_within_threshold(self) -> None:
        self.write(self.baselines, "BENCH_serve.json", serve_doc(100.0, 8.0))
        # 10% slower: inside the 15% budget.
        self.write(self.results, "BENCH_serve.json", serve_doc(90.0, 7.5))
        p = self.gate()
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertIn("ok    BENCH_serve.json serve.requests_per_s", p.stdout)
        self.assertIn("serve.speedup_vs_cold", p.stdout)
        self.assertNotIn("FAIL", p.stdout)

    def test_fail_on_throughput_regression(self) -> None:
        self.write(self.baselines, "BENCH_serve.json", serve_doc(100.0, 8.0))
        # 50% slower: far past the 15% budget.
        self.write(self.results, "BENCH_serve.json", serve_doc(50.0, 8.0))
        p = self.gate()
        self.assertEqual(p.returncode, 1, p.stdout + p.stderr)
        self.assertIn("FAIL  BENCH_serve.json serve.requests_per_s", p.stdout)

    def test_fail_on_speedup_regression(self) -> None:
        # Requests/s held, but the amortization edge collapsed.
        self.write(self.baselines, "BENCH_serve.json", serve_doc(100.0, 8.0))
        self.write(self.results, "BENCH_serve.json", serve_doc(100.0, 2.0))
        p = self.gate()
        self.assertEqual(p.returncode, 1, p.stdout + p.stderr)
        self.assertIn("FAIL  BENCH_serve.json serve.speedup_vs_cold", p.stdout)

    def test_unseeded_baseline_skips_with_exit_zero(self) -> None:
        self.write(self.results, "BENCH_serve.json", serve_doc(100.0, 8.0))
        p = self.gate()
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertIn("SKIP  BENCH_serve.json: no committed baseline", p.stdout)
        self.assertIn("gate passes vacuously", p.stdout)

    def test_mode_mismatch_skips_that_file(self) -> None:
        self.write(self.baselines, "BENCH_serve.json", serve_doc(100.0, 8.0, mode="full"))
        self.write(self.results, "BENCH_serve.json", serve_doc(10.0, 1.0, mode="smoke"))
        p = self.gate()
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertIn("mode mismatch", p.stdout)
        self.assertNotIn("FAIL", p.stdout)

    def test_regression_in_one_file_fails_while_other_passes(self) -> None:
        self.write(self.baselines, "BENCH_optimizer.json", optimizer_doc(1000.0))
        self.write(self.results, "BENCH_optimizer.json", optimizer_doc(990.0))
        self.write(self.baselines, "BENCH_serve.json", serve_doc(100.0, 8.0))
        self.write(self.results, "BENCH_serve.json", serve_doc(40.0, 8.0))
        p = self.gate()
        self.assertEqual(p.returncode, 1, p.stdout + p.stderr)
        self.assertIn("ok    BENCH_optimizer.json", p.stdout)
        self.assertIn("FAIL  BENCH_serve.json", p.stdout)

    def test_report_json_is_written(self) -> None:
        self.write(self.baselines, "BENCH_serve.json", serve_doc(100.0, 8.0))
        self.write(self.results, "BENCH_serve.json", serve_doc(95.0, 8.0))
        report = self.results / "BENCH_regression_report.json"
        p = self.gate("--report", str(report))
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        doc = json.loads(report.read_text())
        self.assertEqual(doc["regressions"], 0)
        metrics = {row["metric"] for row in doc["comparisons"]}
        self.assertIn("serve.requests_per_s", metrics)
        self.assertIn("serve.speedup_vs_cold", metrics)

    def test_update_seeds_the_baselines(self) -> None:
        self.write(self.results, "BENCH_serve.json", serve_doc(100.0, 8.0))
        p = self.gate("--update")
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertTrue((self.baselines / "BENCH_serve.json").exists())
        # An identical re-run against the fresh baselines passes.
        p = self.gate()
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertIn("ok    BENCH_serve.json", p.stdout)

    def test_update_with_empty_results_fails(self) -> None:
        p = self.gate("--update")
        self.assertEqual(p.returncode, 1, p.stdout + p.stderr)
        self.assertIn("nothing to update", p.stdout)


if __name__ == "__main__":
    unittest.main(verbosity=2)
