//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Pattern from /opt/xla-example/load_hlo.rs: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.
//!
//! The real client is gated behind the `pjrt` cargo feature because the
//! `xla` crate cannot be fetched in the offline build (it must be vendored
//! locally and added to `[dependencies]` by hand). Without the feature a
//! stub with the same API returns a descriptive runtime error from
//! [`PjrtRuntime::cpu`], so everything downstream (the executor, sweeps,
//! benches) compiles and falls back to the in-process engines.

use std::path::Path;

use crate::error::{Error, Result};

#[cfg(not(feature = "pjrt"))]
const PJRT_DISABLED: &str =
    "PJRT support not compiled in: build with `--features pjrt` and a vendored `xla` crate";

/// A PJRT client plus compiled executables (one per artifact).
pub struct PjrtRuntime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(not(feature = "pjrt"))]
    _priv: (),
}

/// One compiled HLO module ready for execution.
pub struct PjrtExecutable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Path the module was loaded from (diagnostics).
    pub source: String,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(Error::runtime)?;
        Ok(PjrtRuntime { client })
    }

    /// Human-readable platform string.
    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Load an HLO text file and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<PjrtExecutable> {
        if !path.exists() {
            return Err(Error::MissingArtifact(path.display().to_string()));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )
        .map_err(Error::runtime)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(Error::runtime)?;
        Ok(PjrtExecutable { exe, source: path.display().to_string() })
    }
}

#[cfg(feature = "pjrt")]
impl PjrtExecutable {
    /// Execute with f32 input planes; returns the flat f32 outputs of the
    /// (1-tuple or k-tuple) result, in order.
    ///
    /// Each input is `(data, dims)`; data length must equal the dim product.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
                xla::Literal::vec1(data).reshape(dims).map_err(Error::runtime)
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(Error::runtime)?;
        let out = result[0][0].to_literal_sync().map_err(Error::runtime)?;
        // Lowered with return_tuple=True: the output is always a tuple.
        let parts = out.to_tuple().map_err(Error::runtime)?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Error::runtime))
            .collect()
    }
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Stub: always fails with a descriptive error (the build has no PJRT).
    pub fn cpu() -> Result<Self> {
        Err(Error::Runtime(PJRT_DISABLED.into()))
    }

    /// Human-readable platform string.
    pub fn platform(&self) -> String {
        "pjrt-disabled".to_string()
    }

    /// Stub: unreachable in practice ([`PjrtRuntime::cpu`] never succeeds),
    /// kept so downstream code compiles unchanged.
    pub fn load_hlo_text(&self, path: &Path) -> Result<PjrtExecutable> {
        let _ = path;
        Err(Error::Runtime(PJRT_DISABLED.into()))
    }
}

#[cfg(not(feature = "pjrt"))]
impl PjrtExecutable {
    /// Stub: always fails (no executable can exist without the feature).
    pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        Err(Error::Runtime(PJRT_DISABLED.into()))
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_disabled_pjrt() {
        let err = PjrtRuntime::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }
}
