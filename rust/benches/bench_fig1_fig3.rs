//! Bench: the Fig. 1 / Fig. 3 HPCG co-simulations — wall time and
//! simulated-seconds-per-wall-second throughput of the desync engine.

use membw::benchutil::Bench;
use membw::config::{machine, MachineId};
use membw::desync::{hpcg_program, CoSimConfig, CoSimEngine, HpcgVariant, NoiseModel};
use membw::report::{fig1_report, fig3_report, ExperimentCtx};

fn main() {
    let mut b = Bench::new("fig1_fig3");

    let m = machine(MachineId::Clx);
    let cfg = CoSimConfig {
        dt_s: 20e-6,
        t_max_s: 600.0,
        initial_stagger_s: 0.2e-3,
        neighbor_radius: 3,
        noise: NoiseModel::mild(7),
    };

    // Raw co-sim throughput: simulated seconds per wall second.
    let prog = hpcg_program(HpcgVariant::Modified, 96, 3);
    let eng = CoSimEngine::new(&m, prog, m.cores, cfg.clone()).unwrap();
    b.throughput("co-sim throughput (20 ranks, CLX)", "sim-s", || eng.run().t_end_s);

    // Figure regeneration.
    let ctx = ExperimentCtx::fluid(std::path::PathBuf::from("results"));
    let mut fig1 = String::new();
    b.run("full Fig. 1 (BDW-2 + CLX co-sims)", 1, || {
        fig1 = fig1_report(&ctx).expect("fig1");
    });
    for line in fig1.lines().filter(|l| l.contains("early-starter")) {
        println!("{line}");
    }
    let mut fig3 = String::new();
    b.run("full Fig. 3 (modified HPCG)", 1, || {
        fig3 = fig3_report(&ctx).expect("fig3");
    });
    for line in fig3.lines().filter(|l| l.contains("skew =")) {
        println!("{line}");
    }
    b.finish();
}
