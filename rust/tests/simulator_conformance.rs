//! Simulator conformance suite for the multi-interface substrate.
//!
//! The fluid and DES engines were generalized from one capacity-`C`
//! memory interface to a network of interfaces (per-domain memory
//! controllers + inter-socket links); the single-interface engines are now
//! the degenerate one-portion case of `simulator::network`. This suite
//! pins the generalization:
//!
//! 1. **Seed equivalence** — the delegating single-interface engines are
//!    bit-identical to *verbatim copies of the seed loops* kept below
//!    (the same retained-reference pattern as `desync::legacy`);
//! 2. **r = 0 degeneracy** — a multi-domain run with no remote traffic is
//!    bit-identical to independent per-domain single-interface runs, for
//!    both engines (including scaled domains);
//! 3. **Remote fidelity** — the homogeneous two-socket remote scenario
//!    stays within the paper's 8% ceiling against the analytic
//!    `share_remote` fixed point, end to end through the scenario runner,
//!    with one reported row per directed link interface whose traffic is
//!    *simulated* (never exceeds the direction's capacity).
//!
//! The numerics are mirrored operation-for-operation in
//! `python/netfluid_mirror.py` (run it directly for the same checks).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use membw::config::{machine, Machine, MachineId};
use membw::kernels::{kernel, KernelId};
use membw::scenario::{run_mixes_on, MeasureEngine, Mix};
use membw::simulator::{
    CoreWorkload, DesConfig, DesSimulator, FluidConfig, FluidSimulator, XorShift64,
};
use membw::topology::{Placement, Topology};

fn wl(k: KernelId, m: &Machine) -> CoreWorkload {
    CoreWorkload::from_kernel(&kernel(k), m, 0)
}

/// Verbatim copy of the seed single-interface fluid loop (pre-network
/// `FluidSimulator::run`), kept as the bit-level reference.
fn seed_fluid(m: &Machine, workloads: &[CoreWorkload], cfg: &FluidConfig) -> (Vec<f64>, f64) {
    let n = workloads.len();
    let cap = m.capacity_lines_per_cy();
    let q = &m.queue;
    let d: Vec<f64> = workloads.iter().map(|w| w.demand_lines_per_cy).collect();
    let c: Vec<f64> = workloads.iter().map(|w| w.cost_factor).collect();
    let win: Vec<f64> = workloads
        .iter()
        .map(|w| {
            q.depth_floor + q.depth_beta * w.demand_lines_per_cy * w.cost_factor * q.base_latency_cy
        })
        .collect();

    let mut occ = vec![0.0f64; n];
    let mut served = vec![0.0f64; n];
    let mut u_accum = 0.0f64;
    let total_cycles = cfg.warmup_cycles + cfg.measure_cycles;
    let mut occ_cost = 0.0f64;
    for cycle in 0..=total_cycles {
        let measuring = cycle > cfg.warmup_cycles;
        let lambda = if occ_cost > 1e-12 { (cap / occ_cost).min(1.0) } else { 1.0 };
        if measuring {
            u_accum += (occ_cost / cap).min(1.0);
        }
        let keep = 1.0 - lambda;
        occ_cost = 0.0;
        for i in 0..n {
            let o_pre = occ[i];
            if measuring {
                served[i] += lambda * o_pre;
            }
            let mut o = o_pre * keep;
            let di = d[i];
            if di > 0.0 {
                o += di.min((win[i] - o).max(0.0));
            }
            occ[i] = o;
            occ_cost += o * c[i];
        }
    }
    let cycles = cfg.measure_cycles as f64;
    let per_core: Vec<f64> = served.iter().map(|s| m.lines_per_cy_to_gbs(s / cycles)).collect();
    (per_core, u_accum / cycles)
}

/// Verbatim copy of the seed single-interface DES loop (pre-network
/// `DesSimulator::run`), kept as the bit-level reference.
fn seed_des(m: &Machine, workloads: &[CoreWorkload], cfg: &DesConfig) -> (Vec<f64>, f64, u64) {
    #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
    struct TimeKey(u64);
    impl TimeKey {
        fn of(t: f64) -> Self {
            TimeKey(t.to_bits())
        }
        fn time(&self) -> f64 {
            f64::from_bits(self.0)
        }
    }
    struct CoreState {
        gap_cy: f64,
        window: usize,
        cost_cy: f64,
        queued: usize,
        outstanding: usize,
        blocked: bool,
        served: u64,
    }
    let cap = m.capacity_lines_per_cy();
    let q = &m.queue;
    let mut rng = XorShift64::new(cfg.seed);
    let mut cores: Vec<CoreState> = workloads
        .iter()
        .map(|w| {
            let window = (q.depth_floor
                + q.depth_beta * w.demand_lines_per_cy * w.cost_factor * q.base_latency_cy)
                .round()
                .max(1.0) as usize;
            CoreState {
                gap_cy: if w.is_active() { 1.0 / w.demand_lines_per_cy } else { f64::INFINITY },
                window,
                cost_cy: w.cost_factor / cap,
                queued: 0,
                outstanding: 0,
                blocked: false,
                served: 0,
            }
        })
        .collect();
    let mut heap: BinaryHeap<Reverse<(TimeKey, usize, u8)>> = BinaryHeap::new();
    for (i, c) in cores.iter().enumerate() {
        if c.gap_cy.is_finite() {
            heap.push(Reverse((TimeKey::of(rng.next_f64() * c.gap_cy), i, 0u8)));
        }
    }
    let t_end = cfg.warmup_cycles + cfg.measure_cycles;
    let mut server_busy = false;
    let mut busy_accum = 0.0f64;
    let mut events: u64 = 0;
    fn try_serve(
        t: f64,
        cores: &mut [CoreState],
        server_busy: &mut bool,
        rng: &mut XorShift64,
        heap: &mut BinaryHeap<Reverse<(TimeKey, usize, u8)>>,
    ) {
        if *server_busy {
            return;
        }
        let total: usize = cores.iter().map(|c| c.queued).sum();
        if total == 0 {
            return;
        }
        let mut x = (rng.next_f64() * total as f64) as usize;
        let mut pick = 0;
        for (i, c) in cores.iter().enumerate() {
            if x < c.queued {
                pick = i;
                break;
            }
            x -= c.queued;
        }
        cores[pick].queued -= 1;
        *server_busy = true;
        let done = t + cores[pick].cost_cy;
        heap.push(Reverse((TimeKey::of(done), pick, 1u8)));
    }
    while let Some(Reverse((key, core, kind))) = heap.pop() {
        let t = key.time();
        if t >= t_end {
            break;
        }
        events += 1;
        match kind {
            0 => {
                let c = &mut cores[core];
                if c.outstanding < c.window {
                    c.queued += 1;
                    c.outstanding += 1;
                    c.blocked = false;
                    let jitter = 0.95 + 0.1 * rng.next_f64();
                    heap.push(Reverse((TimeKey::of(t + c.gap_cy * jitter), core, 0u8)));
                    try_serve(t, &mut cores, &mut server_busy, &mut rng, &mut heap);
                } else {
                    c.blocked = true;
                }
            }
            _ => {
                let in_measure = t >= cfg.warmup_cycles;
                {
                    let c = &mut cores[core];
                    c.outstanding -= 1;
                    if in_measure {
                        c.served += 1;
                    }
                }
                if in_measure {
                    busy_accum += cores[core].cost_cy;
                }
                server_busy = false;
                if cores[core].blocked {
                    cores[core].blocked = false;
                    heap.push(Reverse((TimeKey::of(t), core, 0u8)));
                }
                try_serve(t, &mut cores, &mut server_busy, &mut rng, &mut heap);
            }
        }
    }
    let cycles = cfg.measure_cycles;
    let per_core: Vec<f64> =
        cores.iter().map(|c| m.lines_per_cy_to_gbs(c.served as f64 / cycles)).collect();
    ((per_core), (busy_accum / cycles).min(1.0), events)
}

/// The conformance workloads: mixed kernels, an idle core, on two machine
/// classes (Intel inclusive-LLC and Rome victim-LLC).
fn mixes(m: &Machine, mid: MachineId) -> Vec<Vec<CoreWorkload>> {
    let half = m.cores / 2;
    vec![
        vec![wl(KernelId::Stream, m); m.cores],
        {
            let mut ws = vec![wl(KernelId::Dcopy, m); half];
            ws.extend(vec![wl(KernelId::Ddot2, m); m.cores - half - 1]);
            ws.push(CoreWorkload::idle());
            ws
        },
        vec![wl(
            if mid == MachineId::Rome { KernelId::Daxpy } else { KernelId::VecSum },
            m,
        )],
    ]
}

/// Pin 1a: the delegating fluid engine reproduces the seed fused loop bit
/// for bit (per-core bandwidths, total, utilization).
#[test]
fn fluid_engine_is_bit_identical_to_seed_loop() {
    for mid in MachineId::ALL {
        let m = machine(mid);
        for ws in mixes(&m, mid) {
            let cfg = FluidConfig::default();
            let (want_pc, want_u) = seed_fluid(&m, &ws, &cfg);
            let got = FluidSimulator::new(&m, cfg).run(&ws);
            assert_eq!(got.per_core_gbs.len(), want_pc.len());
            for (a, b) in got.per_core_gbs.iter().zip(&want_pc) {
                assert_eq!(a.to_bits(), b.to_bits(), "{mid:?}: fluid per-core diverged");
            }
            assert_eq!(got.utilization.to_bits(), want_u.to_bits(), "{mid:?}: utilization");
            let want_total: f64 = want_pc.iter().sum();
            assert_eq!(got.total_gbs.to_bits(), want_total.to_bits(), "{mid:?}: total");
        }
    }
}

/// Pin 1b: the delegating DES engine reproduces the seed event loop bit
/// for bit — same xorshift draw sequence, same heap tie-breaking, same
/// event count.
#[test]
fn des_engine_is_bit_identical_to_seed_loop() {
    for mid in [MachineId::Bdw1, MachineId::Rome] {
        let m = machine(mid);
        for ws in mixes(&m, mid) {
            let cfg = DesConfig { measure_cycles: 120_000.0, ..Default::default() };
            let (want_pc, want_u, want_events) = seed_des(&m, &ws, &cfg);
            let got = DesSimulator::new(&m, cfg).run(&ws);
            for (a, b) in got.per_core_gbs.iter().zip(&want_pc) {
                assert_eq!(a.to_bits(), b.to_bits(), "{mid:?}: DES per-core diverged");
            }
            assert_eq!(got.utilization.to_bits(), want_u.to_bits(), "{mid:?}: utilization");
            assert_eq!(got.events, want_events, "{mid:?}: event count");
        }
    }
}

/// Pin 2a: r = 0 on a multi-domain network decomposes into the per-domain
/// single-interface fluid runs, bit for bit — including a scaled domain.
#[test]
fn net_fluid_r0_matches_per_domain_runs_bitwise() {
    use membw::simulator::{IfaceNet, NetFluidSimulator, NetStream};
    let m = machine(MachineId::Rome);
    let topo = Topology::build(&m, 1, 2, &[1.0, 0.5]).unwrap();
    let net = IfaceNet::of_topology(&topo);
    // Domain 0: 4x dcopy + 2x ddot2 (+1 idle); domain 1 (scaled): 3x ddot2.
    let d0m = &topo.domains[0].machine;
    let d1m = &topo.domains[1].machine;
    let mut streams: Vec<NetStream> = Vec::new();
    let mut w0 = vec![wl(KernelId::Dcopy, d0m); 4];
    w0.extend(vec![wl(KernelId::Ddot2, d0m); 2]);
    w0.push(CoreWorkload::idle());
    for &w in &w0 {
        streams.push(NetStream { workload: w, home: 0, remote_frac: 0.0, l3_frac: 0.0 });
    }
    let w1 = vec![wl(KernelId::Ddot2, d1m); 3];
    for &w in &w1 {
        streams.push(NetStream { workload: w, home: 1, remote_frac: 0.0, l3_frac: 0.0 });
    }
    let r = NetFluidSimulator::new(&net, FluidConfig::default()).run(&streams);
    let solo0 = FluidSimulator::new(d0m, FluidConfig::default()).run(&w0);
    let solo1 = FluidSimulator::new(d1m, FluidConfig::default()).run(&w1);
    let want: Vec<f64> =
        solo0.per_core_gbs.iter().chain(&solo1.per_core_gbs).copied().collect();
    assert_eq!(r.per_stream_gbs.len(), want.len());
    for (a, b) in r.per_stream_gbs.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits(), "net fluid r=0 diverged from per-domain runs");
    }
    assert_eq!(r.mem_utilization[0].to_bits(), solo0.utilization.to_bits());
    assert_eq!(r.mem_utilization[1].to_bits(), solo1.utilization.to_bits());
}

/// Pin 2b: the same for the DES — components replay the per-domain seed
/// runs with their own RNG streams.
#[test]
fn net_des_r0_matches_per_domain_runs_bitwise() {
    use membw::simulator::{IfaceNet, NetDesSimulator, NetStream};
    let m = machine(MachineId::Rome);
    let topo = Topology::parse(&m, "2").unwrap();
    let net = IfaceNet::of_topology(&topo);
    let cfg = DesConfig { measure_cycles: 120_000.0, ..Default::default() };
    let w0 = vec![wl(KernelId::Dcopy, &m); 3];
    let w1 = vec![wl(KernelId::Ddot2, &m); 4];
    let mut streams: Vec<NetStream> = Vec::new();
    for &w in &w0 {
        streams.push(NetStream { workload: w, home: 0, remote_frac: 0.0, l3_frac: 0.0 });
    }
    for &w in &w1 {
        streams.push(NetStream { workload: w, home: 1, remote_frac: 0.0, l3_frac: 0.0 });
    }
    let r = NetDesSimulator::new(&net, cfg.clone()).run(&streams);
    let solo0 = DesSimulator::new(&m, cfg.clone()).run(&w0);
    let solo1 = DesSimulator::new(&m, cfg).run(&w1);
    let want: Vec<f64> =
        solo0.per_core_gbs.iter().chain(&solo1.per_core_gbs).copied().collect();
    for (a, b) in r.per_stream_gbs.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits(), "net DES r=0 diverged from per-domain runs");
    }
    assert_eq!(r.events, solo0.events + solo1.events);
}

/// Pin 3: the homogeneous remote scenario end to end through the runner —
/// 64 dcopy cores at r = 0.5 on dual-socket NPS4 Rome. Under directed
/// full-duplex links each direction carries only one socket's outbound
/// lines, so the memory interfaces gate the streams (per-direction
/// throughput 37.54 of 64 GB/s — the historical half-duplex accounting
/// summed both directions onto one 64 GB/s server and misread this
/// scenario as link-gated). Measured (simulated) and modeled socket
/// shares agree within the paper's 8% ceiling, both directed link rows
/// are reported, and reported link traffic is simulated (never offered
/// demand, which is ~3x per-direction capacity).
#[test]
fn spread_remote_scenario_within_model_ceiling_end_to_end() {
    let m = machine(MachineId::Rome);
    let topo = Topology::parse(&m, "2x4").unwrap();
    let mix = Mix::parse("dcopy:64@scatter%r0.5").unwrap();
    let rs = run_mixes_on(&topo, Placement::Compact, &[mix], &MeasureEngine::Fluid).unwrap();
    let case = &rs.cases[0];
    for g in &case.socket {
        assert!(
            g.error() < 0.08,
            "remote socket share: model {} vs simulated {} ({}%)",
            g.model_per_core,
            g.measured_per_core,
            g.error() * 100.0
        );
    }
    // One LinkResult per duplex direction.
    assert_eq!(case.links.len(), 2);
    assert_eq!(case.links[0].sockets, (0, 1));
    assert_eq!(case.links[1].sockets, (1, 0));
    for link in &case.links {
        // Offered demand still exceeds each direction's capacity...
        assert!(link.saturated, "offered demand exceeds per-direction capacity");
        assert!(
            link.measured_total_gbs <= link.link_bw_gbs * 1.001,
            "simulated link traffic {} exceeds capacity {} — this would be offered demand",
            link.measured_total_gbs,
            link.link_bw_gbs
        );
        // ...but the lockstep streams are memory-gated well below it
        // (mirror: 37.536 GB/s per direction against the 64 GB/s cap).
        assert!(
            link.measured_total_gbs > 0.5 * link.link_bw_gbs
                && link.measured_total_gbs < 0.7 * link.link_bw_gbs,
            "per-direction traffic should be memory-gated near 0.59x capacity (got {})",
            link.measured_total_gbs
        );
        // Simulated crossings track the model's effective link grant.
        let rel = (link.measured_total_gbs - link.model_total_gbs).abs()
            / link.model_total_gbs;
        assert!(rel < 0.08, "link {} vs model {}", link.measured_total_gbs, link.model_total_gbs);
        assert!(link.model_total_gbs <= link.link_bw_gbs * (1.0 + 1e-9));
    }
    // Scatter symmetry: both directions carry the same traffic.
    let (a, b) = (case.links[0].measured_total_gbs, case.links[1].measured_total_gbs);
    assert!((a - b).abs() < 0.01 * a, "duplex symmetry: {a} vs {b}");
}

/// DES cross-check of the remote spread case at a loose band (stochastic
/// arbitration + tandem-queue discretization): per-core within 10% of the
/// fluid engine (mirror: 4.6%), every directed link capped.
#[test]
fn remote_spread_des_agrees_with_fluid() {
    let m = machine(MachineId::Rome);
    let topo = Topology::parse(&m, "2x4").unwrap();
    let mix = Mix::parse("dcopy:16@scatter%r0.5").unwrap();
    let fluid =
        run_mixes_on(&topo, Placement::Compact, &[mix.clone()], &MeasureEngine::Fluid).unwrap();
    let des = run_mixes_on(&topo, Placement::Compact, &[mix], &MeasureEngine::Des).unwrap();
    let (gf, gd) = (&fluid.cases[0].socket[0], &des.cases[0].socket[0]);
    let rel = (gf.measured_per_core - gd.measured_per_core).abs() / gf.measured_per_core;
    assert!(rel < 0.10, "fluid {} vs DES {}", gf.measured_per_core, gd.measured_per_core);
    for l in &des.cases[0].links {
        assert!(l.measured_total_gbs <= l.link_bw_gbs * 1.001);
    }
}
