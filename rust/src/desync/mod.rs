//! Rank-level co-simulation of barrier-free bulk-synchronous MPI programs
//! on one memory contention domain — the paper's motivating HPCG scenario
//! (Sect. I-A, Figs. 1 and 3) and its proposed application ("a new kind of
//! MPI simulation technique that can take node-level bottlenecks into
//! account", Sect. VI).
//!
//! Each MPI rank executes a *phase program* (loop kernels with data volumes,
//! collectives, point-to-point halo waits, idle noise). Since per-core
//! bandwidth is an analytic function of the instantaneous group composition
//! (generalized Eqs. 4+5), kernel completion times between composition
//! changes are solved in closed form: the simulation is **event-driven**
//! ([`crate::timeline`]) and carries zero time-discretization error.
//!
//! * `program` — phase programs and the HPCG program builder,
//! * `engine` — the co-simulation driver over the timeline layer,
//! * `trace` — phase traces, concurrency timelines, ASCII rendering,
//! * `noise` — reproducible system-noise injection (continuous-time
//!   sampler + the legacy per-`dt` poll),
//! * `legacy` — the seed's fixed-`dt` stepper, kept temporarily as the
//!   golden reference (tests / `legacy-stepper` feature only).

mod engine;
#[cfg(test)]
mod golden;
#[cfg(any(test, feature = "legacy-stepper"))]
pub mod legacy;
mod noise;
mod program;
mod trace;

pub use engine::{CoSimConfig, CoSimEngine, CoSimResult, SimStats};
pub use noise::{NoiseModel, NoiseStream};
pub use program::{hpcg_program, HpcgVariant, Phase, Program, SyncKind};
pub use trace::{ConcurrencyPoint, PhaseRecord, TraceLog};
