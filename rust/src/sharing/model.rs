//! The analytic bandwidth-sharing model, Eqs. (4) and (5).
//!
//! Inputs per kernel group: thread count `n`, memory request fraction `f`
//! (Eq. 3: measured single-thread bandwidth over saturated bandwidth) and
//! saturated bandwidth `b_s`. Nothing else about the code matters — that is
//! the paper's point.

/// One group of threads all executing the same kernel.
#[derive(Debug, Clone, Copy)]
pub struct KernelGroup {
    /// Number of threads in the group (`n_t^I` / `n_t^II`).
    pub n: usize,
    /// Memory request fraction `f` of the kernel.
    pub f: f64,
    /// Saturated (full-domain, homogeneous) bandwidth of the kernel, GB/s.
    pub bs_gbs: f64,
}

/// Model output for a two-group pairing.
#[derive(Debug, Clone, Copy)]
pub struct SharingPrediction {
    /// Overlapped saturated bandwidth `b(n_I, n_II)` (Eq. 4), GB/s.
    pub b_mix_gbs: f64,
    /// Group bandwidth shares `α^I`, `α^II` (Eq. 5); sum to 1.
    pub alpha: [f64; 2],
    /// Aggregate bandwidth per group, GB/s.
    pub group_bw_gbs: [f64; 2],
    /// Per-core bandwidth per group, GB/s (what Figs. 6–8 plot).
    pub per_core_gbs: [f64; 2],
    /// True iff the domain is bandwidth-saturated (the raw Eq. 5 regime);
    /// otherwise each group was capped at its unconstrained demand
    /// `n * f * b_s` and the leftover redistributed (nonsaturated case,
    /// Sect. IV last paragraph).
    pub saturated: bool,
}

/// Eq. (4): thread-weighted mean of the homogeneous saturated bandwidths.
pub fn overlapped_saturated_bw(g1: &KernelGroup, g2: &KernelGroup) -> f64 {
    let (n1, n2) = (g1.n as f64, g2.n as f64);
    if n1 + n2 == 0.0 {
        return 0.0;
    }
    (n1 * g1.bs_gbs + n2 * g2.bs_gbs) / (n1 + n2)
}

/// Apply the full model (Eqs. 4 + 5) to a two-group pairing.
///
/// In the saturated regime this is exactly the paper's Eq. (5). When the
/// combined demand `Σ n_k f_k b_s,k` does not fill the overlapped saturated
/// bandwidth, each group simply runs at its unconstrained speed (`f b_s` per
/// core) — the paper notes the model "can also be applied to the
/// nonsaturated case"; the cap makes that statement concrete and matches
/// the linear low-core region of Fig. 7.
pub fn share_two_groups(g1: &KernelGroup, g2: &KernelGroup) -> SharingPrediction {
    let groups = [*g1, *g2];
    let multi = crate::sharing::share_multigroup(&groups);
    SharingPrediction {
        b_mix_gbs: multi.b_mix_gbs,
        alpha: [multi.groups[0].alpha, multi.groups[1].alpha],
        group_bw_gbs: [multi.groups[0].group_bw_gbs, multi.groups[1].group_bw_gbs],
        per_core_gbs: [multi.groups[0].per_core_gbs, multi.groups[1].per_core_gbs],
        saturated: multi.saturated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(n: usize, f: f64, bs: f64) -> KernelGroup {
        KernelGroup { n, f, bs_gbs: bs }
    }

    #[test]
    fn eq4_weighted_mean() {
        // Fig. 5 example: 6 cores kernel I, 4 cores kernel II.
        let b = overlapped_saturated_bw(&g(6, 0.3, 50.0), &g(4, 0.2, 70.0));
        assert!((b - (6.0 * 50.0 + 4.0 * 70.0) / 10.0).abs() < 1e-12);
    }

    #[test]
    fn homogeneous_pairing_splits_by_thread_count() {
        // f^I = f^II: share is solely determined by thread counts (Sect. IV).
        let p = share_two_groups(&g(6, 0.3, 60.0), &g(4, 0.3, 60.0));
        assert!((p.alpha[0] - 0.6).abs() < 1e-12);
        assert!((p.alpha[1] - 0.4).abs() < 1e-12);
        // Per-core bandwidth is then identical across groups.
        assert!((p.per_core_gbs[0] - p.per_core_gbs[1]).abs() < 1e-9);
    }

    #[test]
    fn higher_f_gets_disproportionate_share() {
        // Saturated domain: kernel with higher f queues more requests.
        let p = share_two_groups(&g(5, 0.4, 60.0), &g(5, 0.2, 60.0));
        assert!(p.saturated);
        assert!((p.alpha[0] - 2.0 / 3.0).abs() < 1e-12); // 5*0.4 / (5*0.4+5*0.2)
        assert!(p.per_core_gbs[0] > p.per_core_gbs[1]);
    }

    #[test]
    fn nonsaturated_case_runs_at_solo_speed() {
        // One core each, tiny f: no contention, both get f*bs per core.
        let p = share_two_groups(&g(1, 0.2, 60.0), &g(1, 0.3, 80.0));
        assert!(!p.saturated);
        assert!((p.per_core_gbs[0] - 0.2 * 60.0).abs() < 1e-9);
        assert!((p.per_core_gbs[1] - 0.3 * 80.0).abs() < 1e-9);
    }

    #[test]
    fn shares_sum_to_one_and_bandwidth_conserved() {
        let p = share_two_groups(&g(7, 0.35, 55.0), &g(3, 0.18, 65.0));
        assert!((p.alpha[0] + p.alpha[1] - 1.0).abs() < 1e-12);
        assert!(
            (p.group_bw_gbs[0] + p.group_bw_gbs[1] - p.b_mix_gbs).abs() < 1e-9,
            "saturated: group bandwidths must sum to the overlapped b_s"
        );
    }
}
