//! Bench: raw simulator performance — the L3 perf-optimization targets.
//!
//! * fluid engine: core-cycles advanced per wall second,
//! * DES: line-service events per wall second,
//! * multigroup sharing model: evaluations per second (the desync co-sim
//!   calls it every time step).

use membw::benchutil::Bench;
use membw::config::{machine, MachineId};
use membw::kernels::{kernel, KernelId};
use membw::sharing::{share_multigroup, KernelGroup};
use membw::simulator::{
    CoreWorkload, DesConfig, DesSimulator, FluidConfig, FluidSimulator,
};

fn main() {
    let mut b = Bench::new("simulator");

    let m = machine(MachineId::Clx);
    let ws: Vec<CoreWorkload> = (0..m.cores)
        .map(|i| {
            let k = if i % 2 == 0 { KernelId::Dcopy } else { KernelId::Ddot2 };
            CoreWorkload::from_kernel(&kernel(k), &m, i % 2)
        })
        .collect();

    // Fluid: core-cycles/s (cycles x cores).
    let fluid_cfg = FluidConfig { warmup_cycles: 20_000, measure_cycles: 60_000 };
    let total_cycles = (fluid_cfg.warmup_cycles + fluid_cfg.measure_cycles) as f64;
    let sim = FluidSimulator::new(&m, fluid_cfg.clone());
    b.throughput("fluid core-cycles (20 cores, CLX)", "core-cy", || {
        sim.run(&ws);
        total_cycles * m.cores as f64
    });

    // DES: events/s.
    let des = DesSimulator::new(&m, DesConfig::default());
    b.throughput("DES line events (20 cores, CLX)", "events", || des.run(&ws).events as f64);

    // Sharing model evaluations.
    let groups: Vec<KernelGroup> = (0..4)
        .map(|i| KernelGroup { n: 3 + i, f: 0.15 + 0.05 * i as f64, bs_gbs: 60.0 + i as f64 })
        .collect();
    b.throughput("multigroup model evals", "evals", || {
        let mut acc = 0.0;
        for _ in 0..1_000_000 {
            acc += share_multigroup(&groups).b_mix_gbs;
        }
        assert!(acc > 0.0);
        1_000_000.0
    });

    b.finish();
}
