//! Conformance pins of the `repro serve` layer: the service's incremental
//! admission is *exact*, not approximate.
//!
//! * A submit on an empty fleet is bit-identical to the cold
//!   `repro optimize` of the same mix (the shared warm memo changes
//!   counters, never outcomes).
//! * A submit→finish→submit replay equals the cold optimize of the
//!   hand-built residual space (settled jobs pinned, newcomer free).
//! * A repack equals the cold optimize of the combined mix under its
//!   mix-native constraints.
//! * The checkpoint/resume makespan probe path
//!   (`simulate_placed_until` / `resume_placed`) is bit-identical to
//!   simulating from `t = 0`, over randomized noisy cluster traces in
//!   both rating modes.

use std::collections::HashMap;

use membw::config::machine_by_name;
use membw::desync::{CoSimConfig, NoiseModel, Phase, Program, SyncKind};
use membw::kernels::KernelId;
use membw::optimizer::{
    optimize, OptGroup, OptResult, SearchConfig, SearchSpace, DEFAULT_REMOTE_LEVELS,
};
use membw::scenario::{CharCache, CharSource, Mix};
use membw::service::{ServeConfig, Service};
use membw::sharing::GroupKind;
use membw::timeline::{
    resume_placed, simulate_placed_mode, simulate_placed_until, RatingMode, SimStep,
};
use membw::topology::{RankLayout, Topology};

fn rome_2x4() -> Topology {
    let m = machine_by_name("rome").unwrap();
    Topology::parse(&m, "2x4").unwrap()
}

fn chars_for(topo: &Topology, mix: &Mix) -> HashMap<KernelId, (f64, f64)> {
    let meas = CharCache::global()
        .characterize_source(&topo.base, &mix.kernels(), &CharSource::Ecm)
        .unwrap();
    meas.iter().map(|(&k, c)| (k, (c.f, c.bs_gbs))).collect()
}

/// The search configuration the service derives from a [`ServeConfig`].
fn search_cfg(cfg: &ServeConfig) -> SearchConfig {
    SearchConfig {
        objective: cfg.objective,
        seed: cfg.seed,
        starts: cfg.starts,
        beam: cfg.beam,
        budget: cfg.budget,
        gb_per_core: cfg.gb_per_core,
        ..SearchConfig::default()
    }
}

/// Bit-level outcome equality: winner, score, rates, and the full
/// incumbent trace. `evaluated` and memo counters are *expected* to
/// differ between a warm shared memo and a cold one — everything that
/// describes the search's outcome must not.
fn assert_same_outcome(warm: &OptResult, cold: &OptResult) {
    assert_eq!(warm.best, cold.best, "winner candidate diverged");
    assert_eq!(
        warm.best_score.to_bits(),
        cold.best_score.to_bits(),
        "best score diverged: {} vs {}",
        warm.best_score,
        cold.best_score
    );
    assert_eq!(warm.best_label, cold.best_label);
    assert_eq!(warm.scored, cold.scored, "scored-candidate count diverged");
    assert_eq!(warm.best_rates.len(), cold.best_rates.len());
    for (a, b) in warm.best_rates.iter().zip(&cold.best_rates) {
        assert_eq!(a.to_bits(), b.to_bits(), "per-group rate diverged");
    }
    assert_eq!(warm.trace.len(), cold.trace.len(), "trace length diverged");
    for (a, b) in warm.trace.iter().zip(&cold.trace) {
        assert_eq!(a.scored_at, b.scored_at);
        assert_eq!(a.start, b.start);
        assert_eq!(a.step, b.step);
        assert_eq!(a.score.to_bits(), b.score.to_bits());
        assert_eq!(a.label, b.label);
        assert_eq!(a.candidate, b.candidate);
    }
}

#[test]
fn empty_fleet_submit_is_bit_identical_to_cold_optimize() {
    let topo = rome_2x4();
    let cfg = ServeConfig { budget: 600, ..ServeConfig::default() };
    let spec = "dcopy:8+ddot2:8+stream:8+daxpy:8";

    let mut svc = Service::new(topo.clone(), cfg.clone(), CharSource::Ecm);
    svc.submit("j0", spec).unwrap();
    let warm = svc.last_result().unwrap();

    let mix = Mix::parse(spec).unwrap();
    let chars = chars_for(&topo, &mix);
    let space = SearchSpace::from_mix(&topo, &mix, &chars).unwrap();
    let cold = optimize(&space, &search_cfg(&cfg)).unwrap();
    assert_same_outcome(warm, &cold);

    // Mix-native constraints survive the service path too.
    let spec = "dcopy:8@d2+ddot2:8%r0.25+stream:8";
    let mut svc = Service::new(topo.clone(), cfg.clone(), CharSource::Ecm);
    svc.submit("j0", spec).unwrap();
    let mix = Mix::parse(spec).unwrap();
    let chars = chars_for(&topo, &mix);
    let space = SearchSpace::from_mix(&topo, &mix, &chars).unwrap();
    let cold = optimize(&space, &search_cfg(&cfg)).unwrap();
    assert_same_outcome(svc.last_result().unwrap(), &cold);
    let (_, groups) = &svc.placements()[0];
    assert_eq!(groups[0].2, 2, "@d2 pin must be honored");
    assert_eq!(groups[1].3, 250_000, "%r0.25 freeze must be honored");
}

#[test]
fn residual_admission_matches_cold_optimize_of_the_pinned_space() {
    let topo = rome_2x4();
    // repack_every: 0 keeps every admission on the residual path.
    let cfg = ServeConfig { budget: 600, repack_every: 0, ..ServeConfig::default() };

    let mut svc = Service::new(topo.clone(), cfg.clone(), CharSource::Ecm);
    svc.submit("j0", "dcopy:6+ddot2:6").unwrap();
    svc.submit("j1", "stream:6").unwrap();
    svc.finish("j0").unwrap();
    // The placement j1 holds now is what the next admission pins.
    let settled = svc.placements();
    assert_eq!(settled.len(), 1);
    assert_eq!(settled[0].0, "j1");
    let incoming = Mix::parse("daxpy:6+vecsum:6").unwrap();
    svc.submit("j2", "daxpy:6+vecsum:6").unwrap();
    let warm = svc.last_result().unwrap();

    // Hand-build the residual space the service must have searched: j1's
    // groups pinned at their committed placement, then j2's groups free.
    let union = Mix::parse("stream:6+daxpy:6+vecsum:6").unwrap();
    let chars = chars_for(&topo, &union);
    let mut groups: Vec<OptGroup> = Vec::new();
    for &(kernel, cores, home, remote_ppm) in &settled[0].1 {
        let (f, bs_gbs) = chars[&kernel];
        groups.push(OptGroup {
            name: kernel.key().to_string(),
            kernel,
            n: cores,
            f,
            bs_gbs,
            pinned: Some(home as usize),
            fixed_remote_ppm: Some(remote_ppm),
            kind: GroupKind::Mem,
        });
    }
    for g in &incoming.groups {
        let (f, bs_gbs) = chars[&g.kernel];
        groups.push(OptGroup {
            name: g.kernel.key().to_string(),
            kernel: g.kernel,
            n: g.cores,
            f,
            bs_gbs,
            pinned: None,
            fixed_remote_ppm: None,
            kind: GroupKind::Mem,
        });
    }
    let domain_cores: Vec<usize> = topo.domains.iter().map(|d| d.machine.cores).collect();
    let mut space =
        SearchSpace::new(topo.shape(), domain_cores, groups, DEFAULT_REMOTE_LEVELS.to_vec())
            .unwrap();
    space.node_of = topo.node_of();
    space.collective_extra_s = topo.collective_extra_s();
    let cold = optimize(&space, &search_cfg(&cfg)).unwrap();
    assert_same_outcome(warm, &cold);

    // And the settled job really did not move.
    let after = svc.placements();
    assert_eq!(after[0].1, settled[0].1, "pinned job moved during admission");
}

#[test]
fn repack_equals_cold_optimize_of_the_combined_mix() {
    let topo = rome_2x4();
    // Every 2nd submit repacks; the 2nd submit below is one.
    let cfg = ServeConfig { budget: 600, repack_every: 2, ..ServeConfig::default() };

    let mut svc = Service::new(topo.clone(), cfg.clone(), CharSource::Ecm);
    svc.submit("a", "dcopy:6@d1").unwrap();
    svc.submit("b", "ddot2:6%r0.25+stream:6").unwrap();
    let warm = svc.last_result().unwrap();

    // A repack frees everything except mix-native constraints — exactly
    // the cold optimize of the concatenated mix.
    let union = Mix::parse("dcopy:6@d1+ddot2:6%r0.25+stream:6").unwrap();
    let chars = chars_for(&topo, &union);
    let space = SearchSpace::from_mix(&topo, &union, &chars).unwrap();
    let cold = optimize(&space, &search_cfg(&cfg)).unwrap();
    assert_same_outcome(warm, &cold);
    let (_, a_groups) = &svc.placements()[0];
    assert_eq!(a_groups[0].2, 1, "@d1 pin must survive the repack");
}

/// Deterministic xorshift64* driver for the randomized traces.
struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    /// Uniform in [0, 1).
    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

#[test]
fn sliced_checkpoint_resume_is_bit_identical_to_oneshot() {
    let kernels = Mix::parse("dcopy:1+ddot2:1+stream:1").unwrap().kernels();
    let chars: Vec<(KernelId, f64, f64)> = kernels
        .iter()
        .enumerate()
        .map(|(i, &k)| (k, 0.3 + 0.05 * i as f64, 90.0 + 10.0 * i as f64))
        .collect();
    let syncs = [SyncKind::None, SyncKind::Neighbors, SyncKind::Global];
    let labels = ["A", "B", "C"];

    for (mode, remote_frac, trace_seed) in [
        (RatingMode::Incremental, 0.0, 1u64),
        (RatingMode::Incremental, 0.25, 2),
        (RatingMode::FullRecompute, 0.0, 3),
        (RatingMode::FullRecompute, 0.25, 4),
    ] {
        let mut rng = XorShift(0x9e37_79b9_7f4a_7c15 ^ trace_seed);
        let phases: Vec<Phase> = (0..3)
            .map(|i| Phase::Kernel {
                kernel: chars[i].0,
                volume_bytes: 2e8 + 6e8 * rng.f64(),
                sync: syncs[(rng.next() % 3) as usize],
                label: labels[i],
            })
            .collect();
        let program = Program { phases, iterations: 2 };
        let config = CoSimConfig {
            dt_s: 1.0, // ignored by the event engine
            t_max_s: 1e6,
            initial_stagger_s: 1e-4 + 4e-4 * rng.f64(),
            neighbor_radius: 1 + (rng.next() % 2) as usize,
            noise: NoiseModel::mild(7 + trace_seed),
        };
        let n_ranks = 8;
        let layout = RankLayout {
            n_domains: 4,
            rank_domain: (0..n_ranks).map(|r| r % 4).collect(),
            bw_scale: vec![1.0; 4],
            socket_of: vec![0, 0, 1, 1],
            node_of: vec![0, 0, 1, 1],
            link_bw_gbs: 40.0,
            link_bw_rev_gbs: 40.0,
            collective_extra_s: 2e-6,
            remote: None,
        }
        .with_remote(remote_frac)
        .unwrap();

        let oneshot = simulate_placed_mode(&program, n_ranks, &config, &chars, &layout, mode);

        // Replay the identical run in randomized slices through the
        // checkpoint.
        let mut t_stop = 1e-3 * (0.5 + rng.f64());
        let mut resumes = 0u32;
        let mut step =
            simulate_placed_until(&program, n_ranks, &config, &chars, &layout, mode, t_stop);
        let sliced = loop {
            match step {
                SimStep::Done(r) => break r,
                SimStep::Paused(cp) => {
                    assert!(
                        cp.t_end() <= t_stop,
                        "paused past the stop time: {} > {t_stop}",
                        cp.t_end()
                    );
                    t_stop += 1e-3 * (0.5 + rng.f64());
                    resumes += 1;
                    step = resume_placed(
                        &program, n_ranks, &config, &chars, &layout, mode, cp, t_stop,
                    );
                }
            }
        };
        assert!(resumes > 2, "trace too short to exercise resume ({resumes} resumes)");

        assert_eq!(sliced.events, oneshot.events, "event count diverged (mode {mode:?})");
        assert_eq!(
            sliced.t_end_s.to_bits(),
            oneshot.t_end_s.to_bits(),
            "t_end diverged (mode {mode:?})"
        );
        assert_eq!(sliced.finish_s.len(), oneshot.finish_s.len());
        for (a, b) in sliced.finish_s.iter().zip(&oneshot.finish_s) {
            assert_eq!(a.to_bits(), b.to_bits(), "finish time diverged (mode {mode:?})");
        }
        assert_eq!(
            sliced.trace.records.len(),
            oneshot.trace.records.len(),
            "trace length diverged (mode {mode:?})"
        );
        for (a, b) in sliced.trace.records.iter().zip(&oneshot.trace.records) {
            assert_eq!(a.rank, b.rank);
            assert_eq!(a.iteration, b.iteration);
            assert_eq!(a.label, b.label);
            assert_eq!(a.t_start.to_bits(), b.t_start.to_bits());
            assert_eq!(a.t_end.to_bits(), b.t_end.to_bits());
        }
    }
}
