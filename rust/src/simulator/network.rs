//! The multi-interface simulation substrate: fluid and discrete-event
//! engines over a *network* of contention interfaces (per-domain memory
//! controllers plus inter-socket links) instead of one capacity-`C`
//! interface.
//!
//! A core's request stream is split into traffic **portions** — a home
//! portion of weight `1-r` plus, for remote fraction `r > 0`, one portion
//! of weight `r/(D-1)` per remote domain ([`route_streams`], mirroring the
//! analytic model's expansion in [`crate::sharing::remote`], so model and
//! measurement share one routing abstraction). Each portion is routed over
//! an interface *path*: the target domain's memory interface and, when the
//! target sits on another socket, the DIRECTED inter-socket link
//! `socket(home) → socket(target)` (each direction of a full-duplex link
//! is its own interface with its own capacity).
//!
//! Both engines issue **lockstep streams**: a core interleaves its local
//! and remote lines in fixed proportion, so all portions of one stream
//! share ONE issue window — a lagging portion (e.g. a link-gated remote
//! slice) clogs the shared window and throttles the whole stream. That is
//! exactly what the analytic lockstep rule `min_p grant_p / (n·w_p)` and
//! its fixed point assume; per-portion windows would let fast portions
//! keep draining and would validate the stranded-capacity bug instead.
//!
//! **Fluid** ([`NetFluidSimulator`]): the per-cycle service step
//! water-fills every interface independently (`λ_j = min(1, C_j / Σ o c)`),
//! and a portion crossing a link drains at the *slower* of its two
//! interfaces (`min(λ_mem, λ_link)`). Issue is per stream with the
//! bandwidth-delay window `W = D0 + β d c L0` of the stream's full demand;
//! the inflow admitted by the shared window is split over the stream's
//! portions by routing weight. Links transfer lines at wire rate, so their
//! service cost factor is 1.0 regardless of the line mix (memory
//! interfaces keep the kernel's read/write cost factor).
//!
//! **DES** ([`NetDesSimulator`]): the interface graph decomposes into
//! connected components (interfaces joined by link-crossing portions and
//! by the shared windows of multi-portion streams); each component replays
//! its own event loop with its own xorshift64* stream, so an `r = 0`
//! multi-domain run is *bit-identical* to the independent per-domain runs
//! of the single-interface engine. Components are replayed **in parallel**
//! over the crate's lock-free worker pool into private per-component
//! buffers — bit-identical to the serial replay
//! ([`NetDesSimulator::run_serial`], pinned by a test), since components
//! partition the interfaces and every component seeds its own RNG. Each stream runs one issue process;
//! every issued line picks a portion by routing weight (one RNG draw,
//! skipped for single-portion streams to preserve the seed draw sequence).
//! A link-crossing line is served in tandem: first by the directed link
//! server (cost `1/C_link`), then by the target memory server — the
//! steady-state throughput is gated by the slower stage, the event-level
//! analogue of the fluid `min(λ)` rule.
//!
//! The single-interface engines ([`crate::simulator::FluidSimulator`],
//! [`crate::simulator::DesSimulator`]) are the degenerate one-portion,
//! zero-link case and delegate here; `rust/tests/simulator_conformance.rs`
//! pins them bit-identical to verbatim copies of the seed loops, and the
//! whole substrate is mirrored operation-for-operation by
//! `python/netfluid_mirror.py` (see `docs/SIMULATORS.md`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::{Machine, QueueParams};
use crate::simulator::des::DesConfig;
use crate::simulator::fluid::FluidConfig;
use crate::simulator::measurement::Engine;
use crate::simulator::workload::CoreWorkload;
use crate::simulator::xorshift::XorShift64;
use crate::topology::Topology;

/// A network of contention interfaces: one memory interface per ccNUMA
/// domain plus the inter-socket links, all in capacity units of
/// (read-cost) cache lines per core cycle.
#[derive(Debug, Clone)]
pub struct IfaceNet {
    /// Memory-interface capacity per domain, lines/cy.
    pub mem_capacity: Vec<f64>,
    /// Socket of each domain.
    pub socket_of: Vec<usize>,
    /// Inter-socket links (DIRECTED socket pairs, lexicographic — the
    /// same enumeration as [`crate::sharing::TopoShape::links`]). Empty
    /// when links are not modeled; remote portions then only contend on
    /// the target memory interface.
    pub links: Vec<(usize, usize)>,
    /// Capacity of each directed link, lines/cy, parallel to
    /// [`IfaceNet::links`] (positive whenever the link exists).
    pub link_caps: Vec<f64>,
    /// Shared-L3 capacity per SOCKET, lines/cy. Empty when the cache
    /// topology is not modeled ([`Machine::l3_bw_gbs`] = 0): L3-resident
    /// streams are then rejected by [`route_streams`] and everything else
    /// is bit-identical to the memory-only network.
    pub l3_caps: Vec<f64>,
    /// Core clock, GHz (converts line rates to GB/s).
    pub freq_ghz: f64,
    /// Queueing calibration shared by every interface.
    pub queue: QueueParams,
}

impl IfaceNet {
    /// The degenerate single-interface network of one machine row — the
    /// network the pre-existing single-interface engines run on.
    pub fn single(m: &Machine) -> Self {
        IfaceNet {
            mem_capacity: vec![m.capacity_lines_per_cy()],
            socket_of: vec![0],
            links: Vec::new(),
            link_caps: Vec::new(),
            l3_caps: Vec::new(),
            freq_ghz: m.freq_ghz,
            queue: m.queue,
        }
    }

    /// The network of a [`Topology`]: one memory interface per domain
    /// (scaled rows keep their scaled capacity) plus the base machine's
    /// directed inter-socket links (forward directions at `link_bw_gbs`,
    /// reverse at `link_bw_rev_gbs`).
    pub fn of_topology(topo: &Topology) -> Self {
        let links = if topo.base.link_bw_gbs > 0.0 { topo.links() } else { Vec::new() };
        let to_lines = |gbs: f64| gbs / topo.base.freq_ghz / crate::CACHE_LINE_BYTES;
        let link_caps = links
            .iter()
            .map(|&(a, b)| {
                to_lines(if a < b { topo.base.link_bw_gbs } else { topo.base.link_bw_rev_gbs })
            })
            .collect();
        let socket_of = topo.socket_of();
        let n_sockets = socket_of.iter().copied().max().map_or(0, |s| s + 1);
        let l3_caps = if topo.base.l3_bw_gbs > 0.0 {
            vec![to_lines(topo.base.l3_bw_gbs); n_sockets]
        } else {
            Vec::new()
        };
        IfaceNet {
            mem_capacity: topo.domains.iter().map(|d| d.machine.capacity_lines_per_cy()).collect(),
            socket_of,
            links,
            link_caps,
            l3_caps,
            freq_ghz: topo.base.freq_ghz,
            queue: topo.base.queue,
        }
    }

    /// Number of ccNUMA domains (memory interfaces).
    pub fn n_domains(&self) -> usize {
        self.mem_capacity.len()
    }

    /// Convert a line rate (lines/cy) to GB/s (same arithmetic as
    /// [`Machine::lines_per_cy_to_gbs`]).
    pub fn to_gbs(&self, lines_per_cy: f64) -> f64 {
        lines_per_cy * crate::CACHE_LINE_BYTES * self.freq_ghz
    }
}

/// One simulated core with its routing: the workload it runs, the domain
/// its cores are pinned to, and the fraction of its cache-line stream that
/// targets remote domains (uniform spread).
#[derive(Debug, Clone, Copy)]
pub struct NetStream {
    /// The core's workload (intrinsic demand + service-cost factor).
    pub workload: CoreWorkload,
    /// Home ccNUMA domain.
    pub home: usize,
    /// Remote-access fraction in `[0, 1]`.
    pub remote_frac: f64,
    /// Fraction of the stream's lines that complete at the home socket's
    /// shared L3 in `[0, 1]` (0 = purely DRAM-resident, the degenerate
    /// memory-only case). When `> 0` the workload demand is the L3-level
    /// line rate `d_l3` and the remainder `1 - l3_frac` is served in
    /// tandem L3 → memory (the LC-at-L3 stencil shape). Requires
    /// `remote_frac == 0` and a modeled L3 ([`IfaceNet::l3_caps`]).
    pub l3_frac: f64,
}

/// One traffic portion of a stream: the slice aimed at one target domain,
/// possibly crossing one inter-socket link.
#[derive(Debug, Clone, Copy)]
pub struct NetPortion {
    /// Index of the stream in the input slice.
    pub stream: usize,
    /// Target domain of the portion.
    pub target: usize,
    /// Index into [`IfaceNet::links`] when the portion crosses sockets.
    pub link: Option<usize>,
    /// Fraction of the stream's lines in this portion (`> 0`).
    pub weight: f64,
    /// Socket whose shared-L3 node serves this portion's FIRST stage
    /// (L3-resident streams only; `None` for memory-only portions).
    pub l3: Option<usize>,
    /// Whether the portion has a memory-interface stage. `true` for every
    /// memory-only portion; `false` for the L3-hit slice of an L3-resident
    /// stream (its lines complete at the L3 node).
    pub mem: bool,
}

/// Expand streams into routed portions through the *same* routing rule
/// the analytic model uses ([`crate::sharing::portion_routes`], shared
/// with [`crate::sharing::share_remote`]) — home portion first, then
/// remote targets in domain order; the two sides cannot drift apart.
///
/// # Panics
/// On a remote fraction outside `[0, 1]`, a home domain out of range, or
/// remote traffic on a single-domain network — all programming errors of
/// the caller (the scenario runner validates specs before routing).
pub fn route_streams(net: &IfaceNet, streams: &[NetStream]) -> Vec<NetPortion> {
    let nd = net.n_domains();
    let mut portions = Vec::with_capacity(streams.len());
    for (si, s) in streams.iter().enumerate() {
        let r = s.remote_frac;
        assert!(r.is_finite() && (0.0..=1.0).contains(&r), "remote fraction {r} outside [0, 1]");
        assert!(s.home < nd, "stream {si} homed on domain d{} of {nd}", s.home);
        assert!(r == 0.0 || nd >= 2, "remote accesses need at least two ccNUMA domains");
        let l3f = s.l3_frac;
        assert!(l3f.is_finite() && (0.0..=1.0).contains(&l3f), "L3 fraction {l3f} outside [0, 1]");
        if l3f > 0.0 {
            // L3-resident stream: an L3-hit slice completing at the home
            // socket's shared-L3 node plus, for the miss slice, a tandem
            // L3 → memory portion (same two-stage shape as link → memory).
            assert!(r == 0.0, "L3-resident streams cannot have remote accesses");
            assert!(!net.l3_caps.is_empty(), "L3-resident stream on a network without l3_bw_gbs");
            let sock = net.socket_of[s.home];
            portions.push(NetPortion {
                stream: si,
                target: s.home,
                link: None,
                weight: l3f,
                l3: Some(sock),
                mem: false,
            });
            if l3f < 1.0 {
                portions.push(NetPortion {
                    stream: si,
                    target: s.home,
                    link: None,
                    weight: 1.0 - l3f,
                    l3: Some(sock),
                    mem: true,
                });
            }
            continue;
        }
        for (target, link, weight) in crate::sharing::portion_routes(
            &net.socket_of,
            &net.links,
            !net.links.is_empty(),
            s.home,
            r,
        ) {
            portions.push(NetPortion { stream: si, target, link, weight, l3: None, mem: true });
        }
    }
    portions
}

/// Result of a multi-interface run (fluid or DES).
#[derive(Debug, Clone)]
pub struct NetResult {
    /// The routed portions the run simulated, in routing order.
    pub portions: Vec<NetPortion>,
    /// Drained bandwidth per portion, GB/s.
    pub per_portion_gbs: Vec<f64>,
    /// Effective per-core bandwidth per stream after the lockstep rule
    /// (`min_p drain_p / w_p`), GB/s.
    pub per_stream_gbs: Vec<f64>,
    /// Total drained bandwidth per memory interface, GB/s.
    pub mem_total_gbs: Vec<f64>,
    /// Total *simulated* traffic per link, GB/s (lines that actually
    /// crossed, not offered demand).
    pub link_total_gbs: Vec<f64>,
    /// Total drained L3-level traffic per socket's shared-L3 node, GB/s
    /// (empty when L3 is not modeled).
    pub l3_total_gbs: Vec<f64>,
    /// Mean utilization per memory interface (0..1).
    pub mem_utilization: Vec<f64>,
    /// Mean utilization per link (0..1).
    pub link_utilization: Vec<f64>,
    /// Mean utilization per shared-L3 node (0..1; empty when L3 is not
    /// modeled).
    pub l3_utilization: Vec<f64>,
    /// Events processed (DES; 0 for the fluid engine).
    pub events: u64,
}

impl NetResult {
    fn from_served(
        net: &IfaceNet,
        streams: &[NetStream],
        portions: Vec<NetPortion>,
        served_lines_per_cy: &[f64],
        mem_utilization: Vec<f64>,
        link_utilization: Vec<f64>,
        l3_utilization: Vec<f64>,
        events: u64,
    ) -> Self {
        let per_portion_gbs: Vec<f64> =
            served_lines_per_cy.iter().map(|&s| net.to_gbs(s)).collect();
        let mut per_stream_gbs = vec![0.0f64; streams.len()];
        for (si, rate) in per_stream_gbs.iter_mut().enumerate() {
            let mut r = f64::INFINITY;
            for (pi, p) in portions.iter().enumerate() {
                if p.stream == si {
                    r = r.min(per_portion_gbs[pi] / p.weight);
                }
            }
            *rate = if r.is_finite() { r } else { 0.0 };
        }
        let mut mem_total_gbs = vec![0.0f64; net.n_domains()];
        let mut link_total_gbs = vec![0.0f64; net.links.len()];
        let mut l3_total_gbs = vec![0.0f64; net.l3_caps.len()];
        for (pi, p) in portions.iter().enumerate() {
            if p.mem {
                mem_total_gbs[p.target] += per_portion_gbs[pi];
            }
            if let Some(l) = p.link {
                link_total_gbs[l] += per_portion_gbs[pi];
            }
            if let Some(s3) = p.l3 {
                l3_total_gbs[s3] += per_portion_gbs[pi];
            }
        }
        NetResult {
            portions,
            per_portion_gbs,
            per_stream_gbs,
            mem_total_gbs,
            link_total_gbs,
            l3_total_gbs,
            mem_utilization,
            link_utilization,
            l3_utilization,
            events,
        }
    }
}

/// The multi-interface fluid simulator (per-cycle fractional state; see
/// the module docs for the physics).
pub struct NetFluidSimulator<'a> {
    net: &'a IfaceNet,
    config: FluidConfig,
}

impl<'a> NetFluidSimulator<'a> {
    /// Create a simulator for `net`.
    pub fn new(net: &'a IfaceNet, config: FluidConfig) -> Self {
        NetFluidSimulator { net, config }
    }

    /// Run the per-cycle fluid model for the given streams.
    pub fn run(&self, streams: &[NetStream]) -> NetResult {
        let net = self.net;
        let q = &net.queue;
        let nd = net.n_domains();
        let nl = net.links.len();
        let ns = streams.len();
        let portions = route_streams(net, streams);
        let np = portions.len();
        let by_stream: Vec<Vec<usize>> = (0..ns)
            .map(|s| (0..np).filter(|&i| portions[i].stream == s).collect())
            .collect();
        let n3 = net.l3_caps.len();
        let ds: Vec<f64> = streams.iter().map(|s| s.workload.demand_lines_per_cy).collect();
        let cs: Vec<f64> = streams.iter().map(|s| s.workload.cost_factor).collect();
        let l3fs: Vec<f64> = streams.iter().map(|s| s.l3_frac).collect();
        // ONE shared issue window per stream, sized from the stream's
        // DRAM-equivalent demand — the lockstep-stream substrate (module
        // docs). L3 hits complete at cache latency and do not need
        // DRAM-latency-hiding slots, so the window scales with the miss
        // slice `d · (1 - l3_frac)`; at `l3_frac = 0` the product
        // `d · 1.0` is bitwise `d` and the window is the memory-only one.
        let win: Vec<f64> = (0..ns)
            .map(|s| {
                q.depth_floor
                    + q.depth_beta * (ds[s] * (1.0 - l3fs[s])) * cs[s] * q.base_latency_cy
            })
            .collect();

        let mut occ = vec![0.0f64; np];
        let mut served = vec![0.0f64; np];
        let mut occ_mem = vec![0.0f64; nd];
        let mut occ_link = vec![0.0f64; nl];
        let mut occ_l3 = vec![0.0f64; n3];
        let mut u_mem = vec![0.0f64; nd];
        let mut u_link = vec![0.0f64; nl];
        let mut u_l3 = vec![0.0f64; n3];
        let mut lam_mem = vec![1.0f64; nd];
        let mut lam_link = vec![1.0f64; nl];
        let mut lam_l3 = vec![1.0f64; n3];

        // Drain / issue / accumulate phases per cycle; with r = 0 every
        // stream has one portion of weight 1 and the arithmetic is
        // operation-for-operation the seed fused loop (pinned bitwise by
        // the simulator conformance suite and python/netfluid_mirror.py).
        let total_cycles = self.config.warmup_cycles + self.config.measure_cycles;
        for cycle in 0..=total_cycles {
            let measuring = cycle > self.config.warmup_cycles;
            for d in 0..nd {
                lam_mem[d] = if occ_mem[d] > 1e-12 {
                    (net.mem_capacity[d] / occ_mem[d]).min(1.0)
                } else {
                    1.0
                };
            }
            for l in 0..nl {
                lam_link[l] = if occ_link[l] > 1e-12 {
                    (net.link_caps[l] / occ_link[l]).min(1.0)
                } else {
                    1.0
                };
            }
            for s3 in 0..n3 {
                lam_l3[s3] = if occ_l3[s3] > 1e-12 {
                    (net.l3_caps[s3] / occ_l3[s3]).min(1.0)
                } else {
                    1.0
                };
            }
            if measuring {
                for d in 0..nd {
                    u_mem[d] += (occ_mem[d] / net.mem_capacity[d]).min(1.0);
                }
                for l in 0..nl {
                    u_link[l] += (occ_link[l] / net.link_caps[l]).min(1.0);
                }
                for s3 in 0..n3 {
                    u_l3[s3] += (occ_l3[s3] / net.l3_caps[s3]).min(1.0);
                }
            }
            occ_mem.fill(0.0);
            occ_link.fill(0.0);
            occ_l3.fill(0.0);
            // Drain every portion at its interface rate; a tandem portion
            // (link → mem, or L3 → mem) drains at the slower stage.
            for i in 0..np {
                let p = &portions[i];
                let lam = if let Some(s3) = p.l3 {
                    if p.mem { lam_l3[s3].min(lam_mem[p.target]) } else { lam_l3[s3] }
                } else {
                    match p.link {
                        Some(l) => lam_mem[p.target].min(lam_link[l]),
                        None => lam_mem[p.target],
                    }
                };
                let o_pre = occ[i];
                if measuring {
                    served[i] += lam * o_pre;
                }
                occ[i] = o_pre * (1.0 - lam);
            }
            // Issue per stream through the shared window, split by weight.
            for s in 0..ns {
                if ds[s] > 0.0 {
                    let occ_s: f64 = by_stream[s].iter().map(|&i| occ[i]).sum();
                    let inflow = ds[s].min((win[s] - occ_s).max(0.0));
                    for &i in &by_stream[s] {
                        occ[i] += inflow * portions[i].weight;
                    }
                }
            }
            // Accumulate interface occupancies for the next cycle's λ.
            for i in 0..np {
                let p = &portions[i];
                if p.mem {
                    occ_mem[p.target] += occ[i] * cs[p.stream];
                }
                if let Some(l) = p.link {
                    occ_link[l] += occ[i]; // wire rate: link cost factor 1.0
                }
                if let Some(s3) = p.l3 {
                    occ_l3[s3] += occ[i]; // L3 serves lines at wire rate too
                }
            }
        }

        let cycles = self.config.measure_cycles as f64;
        let served_rate: Vec<f64> = served.iter().map(|s| s / cycles).collect();
        NetResult::from_served(
            net,
            streams,
            portions,
            &served_rate,
            u_mem.iter().map(|u| u / cycles).collect(),
            u_link.iter().map(|u| u / cycles).collect(),
            u_l3.iter().map(|u| u / cycles).collect(),
            0,
        )
    }
}

/// Heap key ordering nonnegative event times by their IEEE-754 bits (the
/// same trick as the seed DES).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct TimeKey(u64);

impl TimeKey {
    fn of(t: f64) -> Self {
        debug_assert!(t >= 0.0 && t.is_finite());
        TimeKey(t.to_bits())
    }
    fn time(&self) -> f64 {
        f64::from_bits(self.0)
    }
}

/// Event kinds of the multi-interface DES, ordered so that at equal
/// `(time, index)` an Issue fires before a memory completion before a
/// link completion (the seed engine's Issue-before-ServiceDone rule).
/// Issue events carry a component-local STREAM index; completion events a
/// component-local PORTION index (identical spaces at `r = 0`, preserving
/// the seed event order bit for bit).
const EV_ISSUE: u8 = 0;
const EV_MEM_DONE: u8 = 1;
const EV_LINK_DONE: u8 = 2;
const EV_L3_DONE: u8 = 3;

/// The multi-interface discrete-event simulator (see the module docs).
pub struct NetDesSimulator<'a> {
    net: &'a IfaceNet,
    config: DesConfig,
}

impl<'a> NetDesSimulator<'a> {
    /// Create a DES for `net`.
    pub fn new(net: &'a IfaceNet, config: DesConfig) -> Self {
        NetDesSimulator { net, config }
    }

    /// Run the DES for the given streams, replaying independent connected
    /// components **in parallel** over the crate's lock-free worker pool
    /// ([`crate::parallel::par_map`]). Each component owns private served /
    /// busy-time buffers and its own xorshift stream, and components
    /// partition the interfaces and portions, so the merged result is
    /// bit-identical to [`NetDesSimulator::run_serial`] (pinned by a test).
    pub fn run(&self, streams: &[NetStream]) -> NetResult {
        self.run_impl(streams, true)
    }

    /// The serial reference replay: identical physics, components replayed
    /// one after another on the calling thread. Retained as the
    /// determinism anchor for the parallel path.
    pub fn run_serial(&self, streams: &[NetStream]) -> NetResult {
        self.run_impl(streams, false)
    }

    fn run_impl(&self, streams: &[NetStream], parallel: bool) -> NetResult {
        let net = self.net;
        let nd = net.n_domains();
        let nl = net.links.len();
        let n3 = net.l3_caps.len();
        let portions = route_streams(net, streams);
        let np = portions.len();

        // Connected components of the interface graph, via union-find over
        // interface ids (mem d → d, link l → nd + l, shared-L3 s →
        // nd + nl + s). Interfaces are joined by link-crossing portions,
        // by L3-stage portions, AND by the shared issue window of every
        // multi-portion stream — the lockstep window couples all
        // interfaces one stream touches.
        let mut parent: Vec<usize> = (0..nd + nl + n3).collect();
        fn find(parent: &mut [usize], mut x: usize) -> usize {
            while parent[x] != x {
                parent[x] = parent[parent[x]];
                x = parent[x];
            }
            x
        }
        fn union(parent: &mut [usize], a: usize, b: usize) {
            let (ra, rb) = (find(parent, a), find(parent, b));
            if ra != rb {
                parent[ra.max(rb)] = ra.min(rb);
            }
        }
        for p in &portions {
            if let Some(l) = p.link {
                union(&mut parent, p.target, nd + l);
            }
            if let Some(s3) = p.l3 {
                union(&mut parent, p.target, nd + nl + s3);
            }
        }
        for s in 0..streams.len() {
            let mut first: Option<usize> = None;
            for p in portions.iter().filter(|p| p.stream == s) {
                match first {
                    None => first = Some(p.target),
                    Some(t0) => union(&mut parent, t0, p.target),
                }
            }
        }
        let comp_of_iface: Vec<usize> = (0..nd + nl + n3).map(|x| find(&mut parent, x)).collect();
        let mut roots: Vec<usize> = portions.iter().map(|p| comp_of_iface[p.target]).collect();
        roots.sort_unstable();
        roots.dedup();

        let comps: Vec<Vec<usize>> = roots
            .iter()
            .map(|&root| {
                (0..np).filter(|&i| comp_of_iface[portions[i].target] == root).collect()
            })
            .collect();
        // One private (served, mem-busy, link-busy) buffer set per
        // component: components partition the portions and interfaces, so
        // summing the zero-initialized buffers reproduces the serial
        // accumulation bit for bit (every index is written by exactly one
        // component). Each component seeds its own xorshift stream inside
        // `run_des_component`, so replay order cannot matter either.
        let run_one = |local: &Vec<usize>| {
            let mut served = vec![0u64; np];
            let mut mem_busy_accum = vec![0.0f64; nd];
            let mut link_busy_accum = vec![0.0f64; nl];
            let mut l3_busy_accum = vec![0.0f64; n3];
            let events = run_des_component(
                net,
                &self.config,
                streams,
                &portions,
                local,
                &mut served,
                &mut mem_busy_accum,
                &mut link_busy_accum,
                &mut l3_busy_accum,
            );
            (events, served, mem_busy_accum, link_busy_accum, l3_busy_accum)
        };
        let results = if parallel {
            crate::parallel::par_map(&comps, run_one)
        } else {
            comps.iter().map(run_one).collect()
        };
        let mut served = vec![0u64; np];
        let mut mem_busy_accum = vec![0.0f64; nd];
        let mut link_busy_accum = vec![0.0f64; nl];
        let mut l3_busy_accum = vec![0.0f64; n3];
        let mut events: u64 = 0;
        for (ev, s, mb, lb, l3b) in &results {
            events += ev;
            for (acc, v) in served.iter_mut().zip(s) {
                *acc += v;
            }
            for (acc, v) in mem_busy_accum.iter_mut().zip(mb) {
                *acc += v;
            }
            for (acc, v) in link_busy_accum.iter_mut().zip(lb) {
                *acc += v;
            }
            for (acc, v) in l3_busy_accum.iter_mut().zip(l3b) {
                *acc += v;
            }
        }

        let cycles = self.config.measure_cycles;
        let served_rate: Vec<f64> = served.iter().map(|&s| s as f64 / cycles).collect();
        NetResult::from_served(
            net,
            streams,
            portions,
            &served_rate,
            mem_busy_accum.iter().map(|b| (b / cycles).min(1.0)).collect(),
            link_busy_accum.iter().map(|b| (b / cycles).min(1.0)).collect(),
            l3_busy_accum.iter().map(|b| (b / cycles).min(1.0)).collect(),
            events,
        )
    }
}

/// One component's event loop, with its own RNG stream — for a component
/// containing a single memory interface and single-portion streams this is
/// the seed DES loop verbatim (pinned bitwise by the conformance suite).
///
/// Streams issue, portions are served: each local stream runs one issue
/// process against its shared window; every admitted line picks one of the
/// stream's portions by routing weight (one extra RNG draw, made only for
/// multi-portion streams) and queues at that portion's first service stage.
#[allow(clippy::too_many_arguments)]
fn run_des_component(
    net: &IfaceNet,
    config: &DesConfig,
    streams: &[NetStream],
    portions: &[NetPortion],
    local: &[usize],
    served: &mut [u64],
    mem_busy_accum: &mut [f64],
    link_busy_accum: &mut [f64],
    l3_busy_accum: &mut [f64],
) -> u64 {
    let q = &net.queue;
    let mut rng = XorShift64::new(config.seed);
    let k = local.len();

    // Local streams (issuers), in increasing global-stream order.
    let mut sl: Vec<usize> = local.iter().map(|&i| portions[i].stream).collect();
    sl.sort_unstable();
    sl.dedup();
    let ks = sl.len();

    // Per local stream: issue gap, shared window, and its local portions.
    let mut gap = vec![f64::INFINITY; ks];
    let mut window = vec![1usize; ks];
    let mut pof: Vec<Vec<usize>> = vec![Vec::new(); ks];
    for (sj, &s) in sl.iter().enumerate() {
        let d = streams[s].workload.demand_lines_per_cy;
        let c = streams[s].workload.cost_factor;
        gap[sj] = if d > 0.0 { 1.0 / d } else { f64::INFINITY };
        // Window sized from the DRAM-equivalent demand (see the fluid
        // engine): bitwise the memory-only window at `l3_frac = 0`.
        window[sj] = (q.depth_floor
            + q.depth_beta * (d * (1.0 - streams[s].l3_frac)) * c * q.base_latency_cy)
            .round()
            .max(1.0) as usize;
    }
    // Per local portion: service costs and owning local stream.
    let mut mcost = vec![0.0f64; k];
    let mut lcost = vec![0.0f64; k];
    let mut l3cost = vec![0.0f64; k];
    let mut stream_of = vec![0usize; k];
    let mut q_mem = vec![0usize; k];
    let mut q_link = vec![0usize; k];
    let mut q_l3 = vec![0usize; k];
    for (j, &i) in local.iter().enumerate() {
        let p = &portions[i];
        let c = streams[p.stream].workload.cost_factor;
        mcost[j] = c / net.mem_capacity[p.target];
        if let Some(l) = p.link {
            lcost[j] = 1.0 / net.link_caps[l];
        }
        if let Some(s3) = p.l3 {
            l3cost[j] = 1.0 / net.l3_caps[s3]; // L3 serves at wire rate
        }
        let sj = sl.binary_search(&p.stream).expect("portion's stream is local");
        stream_of[j] = sj;
        pof[sj].push(j);
    }
    let mut outstanding = vec![0usize; ks];
    let mut blocked = vec![false; ks];

    // Per-interface member lists (component-local indices, routing order —
    // the lottery iterates them in this order).
    let mut mem_members: Vec<Vec<usize>> = vec![Vec::new(); net.n_domains()];
    let mut link_members: Vec<Vec<usize>> = vec![Vec::new(); net.links.len()];
    let mut l3_members: Vec<Vec<usize>> = vec![Vec::new(); net.l3_caps.len()];
    for (j, &i) in local.iter().enumerate() {
        if portions[i].mem {
            mem_members[portions[i].target].push(j);
        }
        if let Some(l) = portions[i].link {
            link_members[l].push(j);
        }
        if let Some(s3) = portions[i].l3 {
            l3_members[s3].push(j);
        }
    }
    let mut mem_busy = vec![false; net.n_domains()];
    let mut link_busy = vec![false; net.links.len()];
    let mut l3_busy = vec![false; net.l3_caps.len()];

    let mut heap: BinaryHeap<Reverse<(TimeKey, usize, u8)>> = BinaryHeap::new();
    for (sj, g) in gap.iter().enumerate() {
        if g.is_finite() {
            heap.push(Reverse((TimeKey::of(rng.next_f64() * g), sj, EV_ISSUE)));
        }
    }
    let t_end = config.warmup_cycles + config.measure_cycles;

    /// Weighted lottery over one interface's queues (no allocation in the
    /// hot path), then start service — the seed `try_serve`, per interface.
    fn try_serve(
        t: f64,
        members: &[usize],
        queues: &mut [usize],
        busy: &mut bool,
        cost: &[f64],
        done_kind: u8,
        rng: &mut XorShift64,
        heap: &mut BinaryHeap<Reverse<(TimeKey, usize, u8)>>,
    ) {
        if *busy {
            return;
        }
        let total: usize = members.iter().map(|&j| queues[j]).sum();
        if total == 0 {
            return;
        }
        let mut x = (rng.next_f64() * total as f64) as usize;
        let mut pick = members[0];
        for &j in members {
            if x < queues[j] {
                pick = j;
                break;
            }
            x -= queues[j];
        }
        queues[pick] -= 1;
        *busy = true;
        heap.push(Reverse((TimeKey::of(t + cost[pick]), pick, done_kind)));
    }

    let mut events: u64 = 0;
    while let Some(Reverse((key, j, kind))) = heap.pop() {
        let t = key.time();
        if t >= t_end {
            break;
        }
        events += 1;
        match kind {
            EV_ISSUE => {
                // `j` is a component-local STREAM index.
                if outstanding[j] < window[j] {
                    outstanding[j] += 1;
                    blocked[j] = false;
                    let jitter = 0.95 + 0.1 * rng.next_f64();
                    heap.push(Reverse((TimeKey::of(t + gap[j] * jitter), j, EV_ISSUE)));
                    // Pick the line's portion by routing weight; the draw
                    // is skipped for single-portion streams so the r = 0
                    // RNG sequence matches the seed engine exactly.
                    let mine = &pof[j];
                    let pick = if mine.len() == 1 {
                        mine[0]
                    } else {
                        let mut x = rng.next_f64();
                        let mut pick = *mine.last().expect("streams have portions");
                        for &cand in mine {
                            let w = portions[local[cand]].weight;
                            if x < w {
                                pick = cand;
                                break;
                            }
                            x -= w;
                        }
                        pick
                    };
                    let pp = &portions[local[pick]];
                    if let Some(l) = pp.link {
                        q_link[pick] += 1;
                        try_serve(
                            t,
                            &link_members[l],
                            &mut q_link,
                            &mut link_busy[l],
                            &lcost,
                            EV_LINK_DONE,
                            &mut rng,
                            &mut heap,
                        );
                    } else if let Some(s3) = pp.l3 {
                        // L3-resident line: the shared-L3 node is the
                        // FIRST service stage (tandem L3 → mem for the
                        // miss slice, completion at L3 for the hit slice).
                        q_l3[pick] += 1;
                        try_serve(
                            t,
                            &l3_members[s3],
                            &mut q_l3,
                            &mut l3_busy[s3],
                            &l3cost,
                            EV_L3_DONE,
                            &mut rng,
                            &mut heap,
                        );
                    } else {
                        let tgt = pp.target;
                        q_mem[pick] += 1;
                        try_serve(
                            t,
                            &mem_members[tgt],
                            &mut q_mem,
                            &mut mem_busy[tgt],
                            &mcost,
                            EV_MEM_DONE,
                            &mut rng,
                            &mut heap,
                        );
                    }
                } else {
                    blocked[j] = true;
                }
            }
            EV_LINK_DONE => {
                // `j` is a component-local PORTION index: the line crossed
                // the link and now queues at the target memory interface
                // (tandem service).
                let p = &portions[local[j]];
                let l = p.link.expect("link completion on a link portion");
                q_mem[j] += 1;
                if t >= config.warmup_cycles {
                    link_busy_accum[l] += lcost[j];
                }
                link_busy[l] = false;
                try_serve(
                    t,
                    &mem_members[p.target],
                    &mut q_mem,
                    &mut mem_busy[p.target],
                    &mcost,
                    EV_MEM_DONE,
                    &mut rng,
                    &mut heap,
                );
                try_serve(
                    t,
                    &link_members[l],
                    &mut q_link,
                    &mut link_busy[l],
                    &lcost,
                    EV_LINK_DONE,
                    &mut rng,
                    &mut heap,
                );
            }
            EV_L3_DONE => {
                // `j` is a component-local PORTION index: the line finished
                // shared-L3 service. A miss-slice (tandem) line queues at
                // the home memory interface; a hit-slice line is fully
                // served and leaves its stream's window.
                let p = &portions[local[j]];
                let s3 = p.l3.expect("L3 completion on an L3 portion");
                if t >= config.warmup_cycles {
                    l3_busy_accum[s3] += l3cost[j];
                }
                l3_busy[s3] = false;
                if p.mem {
                    q_mem[j] += 1;
                    try_serve(
                        t,
                        &mem_members[p.target],
                        &mut q_mem,
                        &mut mem_busy[p.target],
                        &mcost,
                        EV_MEM_DONE,
                        &mut rng,
                        &mut heap,
                    );
                } else {
                    let sj = stream_of[j];
                    outstanding[sj] -= 1;
                    if t >= config.warmup_cycles {
                        served[local[j]] += 1;
                    }
                    if blocked[sj] {
                        blocked[sj] = false;
                        heap.push(Reverse((TimeKey::of(t), sj, EV_ISSUE)));
                    }
                }
                try_serve(
                    t,
                    &l3_members[s3],
                    &mut q_l3,
                    &mut l3_busy[s3],
                    &l3cost,
                    EV_L3_DONE,
                    &mut rng,
                    &mut heap,
                );
            }
            _ => {
                // EV_MEM_DONE: `j` is a component-local PORTION index; the
                // line is fully served and leaves its stream's window.
                let p = &portions[local[j]];
                let sj = stream_of[j];
                outstanding[sj] -= 1;
                if t >= config.warmup_cycles {
                    served[local[j]] += 1;
                    mem_busy_accum[p.target] += mcost[j];
                }
                mem_busy[p.target] = false;
                if blocked[sj] {
                    blocked[sj] = false;
                    heap.push(Reverse((TimeKey::of(t), sj, EV_ISSUE)));
                }
                try_serve(
                    t,
                    &mem_members[p.target],
                    &mut q_mem,
                    &mut mem_busy[p.target],
                    &mcost,
                    EV_MEM_DONE,
                    &mut rng,
                    &mut heap,
                );
            }
        }
    }
    events
}

/// Run `streams` on `net` with the given in-process engine and default
/// config (the multi-interface analogue of
/// [`crate::simulator::run_engine`]).
pub fn run_net_engine(net: &IfaceNet, streams: &[NetStream], engine: Engine) -> NetResult {
    match engine {
        Engine::Fluid => NetFluidSimulator::new(net, FluidConfig::default()).run(streams),
        Engine::Des => NetDesSimulator::new(net, DesConfig::default()).run(streams),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::{kernel, KernelId};

    fn stream(k: KernelId, m: &Machine, home: usize, r: f64) -> NetStream {
        NetStream {
            workload: CoreWorkload::from_kernel(&kernel(k), m, 0),
            home,
            remote_frac: r,
            l3_frac: 0.0,
        }
    }

    fn two_socket_rome() -> (Machine, Topology) {
        let m = machine(MachineId::Rome);
        let topo = Topology::parse(&m, "2x4").unwrap();
        (m, topo)
    }

    #[test]
    fn routing_mirrors_share_remote_expansion() {
        let (m, topo) = two_socket_rome();
        let net = IfaceNet::of_topology(&topo);
        assert_eq!(net.n_domains(), 8);
        assert_eq!(net.links, vec![(0, 1), (1, 0)]);
        assert_eq!(net.link_caps.len(), 2);
        assert!(net.link_caps.iter().all(|&c| c > 0.0));
        let ps = route_streams(&net, &[stream(KernelId::Dcopy, &m, 0, 0.25)]);
        // Home portion + 7 remote portions, home first.
        assert_eq!(ps.len(), 8);
        assert_eq!(ps[0].target, 0);
        assert!(ps[0].link.is_none());
        assert!((ps[0].weight - 0.75).abs() < 1e-15);
        let crossing: Vec<&NetPortion> = ps.iter().filter(|p| p.link.is_some()).collect();
        assert_eq!(crossing.len(), 4, "four targets on the other socket");
        assert!(crossing.iter().all(|p| p.target >= 4));
        let wsum: f64 = ps.iter().map(|p| p.weight).sum();
        assert!((wsum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r_zero_net_fluid_matches_single_interface_engine() {
        // One domain populated, one idle: the populated domain's streams
        // drain exactly as the single-interface fluid engine drains them.
        use crate::simulator::fluid::FluidSimulator;
        let (m, topo) = two_socket_rome();
        let net = IfaceNet::of_topology(&topo);
        let ws = [
            stream(KernelId::Dcopy, &m, 0, 0.0),
            stream(KernelId::Dcopy, &m, 0, 0.0),
            stream(KernelId::Ddot2, &m, 0, 0.0),
        ];
        let r = NetFluidSimulator::new(&net, FluidConfig::default()).run(&ws);
        let solo = FluidSimulator::new(&m, FluidConfig::default())
            .run(&ws.iter().map(|s| s.workload).collect::<Vec<_>>());
        for (a, b) in r.per_stream_gbs.iter().zip(&solo.per_core_gbs) {
            assert_eq!(a.to_bits(), b.to_bits(), "r=0 must be the single-interface engine");
        }
    }

    #[test]
    fn spread_fluid_matches_model_within_ceiling() {
        // The docs/SIMULATORS.md worked example: 64 dcopy cores at r = 0.5
        // on 2xNPS4 Rome. With directed full-duplex links each xGMI
        // direction carries ~37.5 of 64 GB/s, so the memory interfaces —
        // not the link — saturate; the fluid per-core rate matches the
        // analytic water-fill (mirror-checked in python/netfluid_mirror.py).
        use crate::sharing::{share_remote, RemoteGroup};
        let (m, topo) = two_socket_rome();
        let net = IfaceNet::of_topology(&topo);
        let chars = crate::ecm::predict(&kernel(KernelId::Dcopy), &m);
        let streams: Vec<NetStream> = (0..8)
            .flat_map(|d| (0..8).map(move |_| (d, 0.5)))
            .map(|(d, r)| stream(KernelId::Dcopy, &m, d, r))
            .collect();
        let r = NetFluidSimulator::new(&net, FluidConfig::default()).run(&streams);
        let groups: Vec<RemoteGroup> = (0..8)
            .map(|d| RemoteGroup {
                home: d,
                n: 8,
                f: chars.f,
                bs_gbs: chars.bs_gbs,
                remote_frac: 0.5,
                kind: crate::sharing::GroupKind::Mem,
            })
            .collect();
        let model = share_remote(&topo.shape(), &groups).unwrap();
        for d in 0..8 {
            let sim = r.per_stream_gbs[8 * d];
            let err = (sim - model.per_core_gbs[d]).abs() / model.per_core_gbs[d];
            assert!(err < 0.08, "domain {d}: fluid {sim} vs model {}", model.per_core_gbs[d]);
        }
        // Simulated traffic per direction never exceeds that direction's
        // capacity, and the symmetric scenario loads both directions
        // equally (mirror value: 37.536 GB/s each of 64).
        for l in 0..2 {
            assert!(r.link_total_gbs[l] <= m.link_bw_gbs * 1.001, "{}", r.link_total_gbs[l]);
            let rel = (r.link_total_gbs[l] - 37.53595794884311).abs() / 37.53595794884311;
            assert!(rel < 1e-6, "direction {l}: {} GB/s", r.link_total_gbs[l]);
            // Queued lines clog the directed link even though drain is
            // memory-gated: occupancy-based utilization saturates.
            assert!(r.link_utilization[l] > 0.95);
        }
    }

    #[test]
    fn des_and_fluid_agree_on_a_remote_case() {
        let (m, topo) = two_socket_rome();
        let net = IfaceNet::of_topology(&topo);
        let streams: Vec<NetStream> =
            (0..8).map(|_| stream(KernelId::Dcopy, &m, 0, 0.5)).collect();
        let rf = NetFluidSimulator::new(&net, FluidConfig::default()).run(&streams);
        let rd = NetDesSimulator::new(&net, DesConfig::default()).run(&streams);
        assert!(rd.events > 0);
        for (a, b) in rf.per_stream_gbs.iter().zip(&rd.per_stream_gbs) {
            let rel = (a - b).abs() / a;
            assert!(rel < 0.12, "fluid {a} vs DES {b}");
        }
    }

    #[test]
    fn parallel_component_replay_is_bit_identical_to_serial() {
        // 8 domains at r = 0: every domain is its own connected component,
        // so the parallel path replays 8 components concurrently. Served
        // counts, busy times, and event totals must match the serial
        // replay bit for bit (private per-component buffers + per-component
        // RNG streams). A coupled r > 0 case (fewer, larger components)
        // must match too.
        let (m, topo) = two_socket_rome();
        let net = IfaceNet::of_topology(&topo);
        for r in [0.0, 0.25] {
            let streams: Vec<NetStream> = (0..8)
                .flat_map(|d| (0..4).map(move |_| d))
                .map(|d| stream(KernelId::Dcopy, &m, d, r))
                .collect();
            let sim = NetDesSimulator::new(&net, DesConfig::default());
            let par = sim.run(&streams);
            let ser = sim.run_serial(&streams);
            assert_eq!(par.events, ser.events, "r={r}");
            for (a, b) in par.per_portion_gbs.iter().zip(&ser.per_portion_gbs) {
                assert_eq!(a.to_bits(), b.to_bits(), "r={r}");
            }
            for (a, b) in par.per_stream_gbs.iter().zip(&ser.per_stream_gbs) {
                assert_eq!(a.to_bits(), b.to_bits(), "r={r}");
            }
            for (a, b) in par.mem_utilization.iter().zip(&ser.mem_utilization) {
                assert_eq!(a.to_bits(), b.to_bits(), "r={r}");
            }
            for (a, b) in par.link_utilization.iter().zip(&ser.link_utilization) {
                assert_eq!(a.to_bits(), b.to_bits(), "r={r}");
            }
        }
    }

    #[test]
    fn idle_and_all_remote_streams_are_handled() {
        let (m, topo) = two_socket_rome();
        let net = IfaceNet::of_topology(&topo);
        let idle =
            NetStream { workload: CoreWorkload::idle(), home: 0, remote_frac: 0.0, l3_frac: 0.0 };
        let all_remote = stream(KernelId::Ddot2, &m, 0, 1.0);
        let r = NetFluidSimulator::new(&net, FluidConfig::default()).run(&[idle, all_remote]);
        assert_eq!(r.per_stream_gbs[0], 0.0, "idle streams drain nothing");
        assert!(r.per_stream_gbs[1] > 0.0, "r = 1 still drains through remote portions");
        // r = 1 has no home portion.
        assert!(r.portions.iter().all(|p| p.stream != 1 || p.target != 0));
    }

    #[test]
    #[should_panic(expected = "remote fraction")]
    fn routing_rejects_bad_fractions() {
        let (m, topo) = two_socket_rome();
        let net = IfaceNet::of_topology(&topo);
        route_streams(&net, &[stream(KernelId::Dcopy, &m, 0, 1.5)]);
    }
}
