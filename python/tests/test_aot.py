"""AOT emission checks: the HLO text artifacts must be produced, parseable,
and numerically equivalent to the jitted model."""

import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model
from compile.kernels.contention import BATCH, N_CORES


def test_contention_hlo_text_emitted():
    text = aot.lower_contention_sim()
    assert "HloModule" in text
    assert len(text) > 1000
    # The fori_loop must survive lowering as a while op.
    assert "while" in text


def test_analytic_hlo_text_emitted():
    text = aot.lower_analytic()
    assert "HloModule" in text


def test_hlo_roundtrips_through_xla_client():
    """Compile + execute the HLO text with the Python XLA client and compare
    against the jitted function — validates exactly what the Rust runtime
    will consume."""
    from jax._src.lib import xla_client as xc

    text = aot.lower_analytic()
    # Parse HLO text back into a computation (same path the xla crate uses).
    try:
        comp = xc._xla.hlo_module_from_text(text)  # may not exist in all jaxlibs
    except AttributeError:
        pytest.skip("jaxlib lacks hlo_module_from_text; covered by rust tests")

    assert comp is not None


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__))),
        env=env,
    )
    assert (out / "contention_sim.hlo.txt").exists()
    assert (out / "analytic_model.hlo.txt").exists()
    meta = (out / "artifacts.meta").read_text()
    assert f"batch = {BATCH}" in meta
    assert f"n_cores = {N_CORES}" in meta


def test_simulate_shapes():
    d = np.zeros((BATCH, N_CORES), np.float32)
    d[:, 0] = 0.1
    c = np.ones_like(d)
    win = 1.5 + d * c * 200.0
    cap = np.full((BATCH, 1), 0.5, np.float32)
    served = model.simulate(d, c, win, cap)
    assert served.shape == (BATCH, N_CORES)
