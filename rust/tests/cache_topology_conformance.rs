//! Cache-topology conformance — shared-L3 interfaces and compute-bound
//! groups.
//!
//! The contention model historically knew one interface class per ccNUMA
//! domain: the memory controller. This suite pins the cache-topology
//! extension against the authoritative Python reference
//! (`python/netfluid_mirror.py`, whose self-checks derive every number
//! asserted here):
//!
//! 1. **degenerate bit-identity** — memory-bound-only traffic on a shape
//!    WITH a configured shared-L3 node is bitwise the no-L3 answer at the
//!    model layer and through the whole topology pipeline (this is what
//!    lets the builtin machine rows carry `l3_bw_gbs` estimates without
//!    perturbing any existing scenario);
//! 2. **auto-classification** — every registry kernel classifies
//!    memory-bound (the roofline knee `1/f` of the most compute-heavy
//!    kernel still lies well inside a socket), so only an explicit
//!    `@l3`/`@comp` suffix or `%r` changes routes;
//! 3. **pure-L3 water-fill** — an L3-resident group fills the shared-L3
//!    node exactly like a memory group fills a controller (mirror
//!    `check_pure_l3`: 15.0 GB/s/core);
//! 4. **compute-bound zero share** — a compute-bound group caps at `f·b_s`
//!    and its memory-bound peers are bitwise unchanged (mirror
//!    `check_compute_zero_share`);
//! 5. **the LC-at-L3 mixed scenario end to end** — a jacobi stencil whose
//!    layer condition holds at L3 shares a Rome domain with streaming
//!    dcopy under a 120 GB/s shared L3; both interfaces saturate and the
//!    fluid/DES engines stay within the paper's 8% ceiling of the fixed
//!    point (mirror `l3_mixed_example`: worst 4.55%).

use membw::config::{machine, MachineId};
use membw::error::Error;
use membw::kernels::kernel;
use membw::scenario::{run_mixes, run_mixes_on, MeasureEngine, Mix};
use membw::sharing::{share_remote, GroupKind, RemoteGroup, TopoShape};
use membw::topology::{Placement, Topology};

/// Rome full-socket dcopy characterization, exactly as
/// `python/netfluid_mirror.py::ecm_workload` computes it.
const DCOPY_F: f64 = 0.8357432872482309;
const DCOPY_BS: f64 = 32.843963205239454;

/// One monolithic domain, optionally with a shared-L3 node.
fn one_domain(l3_gbs: f64) -> TopoShape {
    TopoShape {
        socket_of: vec![0],
        bw_scale: vec![1.0],
        link_bw_gbs: 0.0,
        link_bw_rev_gbs: 0.0,
        l3_bw_gbs: l3_gbs,
    }
}

/// Two monolithic sockets joined by a symmetric-duplex link.
fn two_socket(link_gbs: f64, l3_gbs: f64) -> TopoShape {
    TopoShape {
        socket_of: vec![0, 1],
        bw_scale: vec![1.0, 1.0],
        link_bw_gbs: link_gbs,
        link_bw_rev_gbs: link_gbs,
        l3_bw_gbs: l3_gbs,
    }
}

fn mem(home: usize, n: usize, f: f64, bs: f64, r: f64) -> RemoteGroup {
    RemoteGroup { home, n, f, bs_gbs: bs, remote_frac: r, kind: GroupKind::Mem }
}

/// Mirror `check_l3_degenerate` (model layer): memory-bound groups —
/// local and remote — produce bitwise identical rates, grants, and
/// iteration counts whether or not the shape models a shared L3.
#[test]
fn mem_only_model_is_bit_identical_with_an_l3_node() {
    let groups = [
        mem(0, 4, DCOPY_F, DCOPY_BS, 0.25),
        mem(1, 3, 0.8299900114233997, 34.23, 0.0),
    ];
    let without = share_remote(&two_socket(64.0, 0.0), &groups).unwrap();
    let with = share_remote(&two_socket(64.0, 120.0), &groups).unwrap();
    assert_eq!(without.iterations, with.iterations);
    for (a, b) in without.per_core_gbs.iter().zip(&with.per_core_gbs) {
        assert_eq!(a.to_bits(), b.to_bits(), "model perturbed by an unused L3 node");
    }
    for (a, b) in without.portions.iter().zip(&with.portions) {
        assert_eq!(a.mem_bw_gbs.to_bits(), b.mem_bw_gbs.to_bits());
        assert_eq!((a.group, a.target, a.link, a.mem), (b.group, b.target, b.link, b.mem));
        assert_eq!(a.l3, None);
        assert_eq!(b.l3, None);
    }
    // The L3 interfaces exist on the second shape but hold no portions
    // and grant nothing.
    assert!(without.l3.is_empty());
    assert_eq!(with.l3.len(), 2);
    for iface in &with.l3 {
        assert_eq!(iface.demand_gbs, 0.0);
        assert!(!iface.saturated);
    }
}

/// The whole topology pipeline — placement split, simulation, model,
/// reporting — is bitwise invariant to the builtin `l3_bw_gbs` estimate
/// for registry mixes, on both the per-domain path (all-local) and the
/// multi-interface path (`%r`). This also pins auto-classification:
/// every registry kernel is memory-bound on Rome, so no kernel silently
/// reroutes to the L3 or compute class.
#[test]
fn registry_mixes_are_invariant_to_the_builtin_l3_estimate() {
    let with = machine(MachineId::Rome);
    assert!(with.l3_bw_gbs > 0.0, "builtin Rome should estimate its shared-L3 bandwidth");
    let mut without = with.clone();
    without.l3_bw_gbs = 0.0;

    for mix_s in ["dcopy:8@d0+ddot2:8@d1+jacobil3-v1:8@d2+idle:8", "dcopy:16%r0.25+ddot2:16"] {
        let mix = Mix::parse(mix_s).unwrap();
        let a = run_mixes_on(
            &Topology::socket(&with),
            Placement::Compact,
            &[mix.clone()],
            &MeasureEngine::Fluid,
        )
        .unwrap();
        let b = run_mixes_on(
            &Topology::socket(&without),
            Placement::Compact,
            &[mix],
            &MeasureEngine::Fluid,
        )
        .unwrap();
        let (ca, cb) = (&a.cases[0], &b.cases[0]);
        assert_eq!(ca.measured_total_gbs.to_bits(), cb.measured_total_gbs.to_bits(), "{mix_s}");
        assert_eq!(ca.model_total_gbs.to_bits(), cb.model_total_gbs.to_bits(), "{mix_s}");
        for (ga, gb) in ca.socket.iter().zip(&cb.socket) {
            assert_eq!(ga.measured_per_core.to_bits(), gb.measured_per_core.to_bits(), "{mix_s}");
            assert_eq!(ga.model_per_core.to_bits(), gb.model_per_core.to_bits(), "{mix_s}");
        }
        // No L3 records on either: memory-bound groups post no L3 portions.
        assert!(ca.l3.is_empty(), "{mix_s}: spurious L3 record");
        assert!(cb.l3.is_empty(), "{mix_s}");
    }
}

/// Every registry kernel's roofline knee `1/f` lies inside a Rome socket
/// (`f · cores >= 1`), so `Auto` never classifies a registry group as
/// compute-bound — the arithmetic backstop of the bit-identity pin above.
#[test]
fn no_registry_kernel_is_compute_bound_on_builtin_machines() {
    for id in [MachineId::Bdw1, MachineId::Bdw2, MachineId::Clx, MachineId::Rome] {
        let m = machine(id);
        for (kid, sig) in membw::kernels::all_kernels() {
            let p = membw::ecm::predict(&sig, &m);
            assert!(
                p.f * m.cores as f64 >= 1.0,
                "{:?} on {:?}: f = {} never saturates memory",
                kid,
                id,
                p.f
            );
        }
    }
}

/// Mirror `check_pure_l3`: a fully L3-resident group (no DRAM traffic at
/// all) water-fills the shared-L3 node exactly like a memory group fills
/// a controller — 8 cores demanding `f3·b_3 = 47` GB/s each against a
/// 120 GB/s node split it fairly at 15.0 GB/s/core.
#[test]
fn pure_l3_group_water_fills_the_l3_node() {
    let shape = one_domain(120.0);
    let f3 = 0.625;
    let bs3 = 32.0 * 2.35; // l2l3_bpc · freq on Rome = 75.2 GB/s
    let groups = [RemoteGroup {
        home: 0,
        n: 8,
        f: 0.0,
        bs_gbs: 0.0,
        remote_frac: 0.0,
        kind: GroupKind::L3 { f_l3: f3, bs_l3_gbs: bs3 },
    }];
    let share = share_remote(&shape, &groups).unwrap();
    let want = (f3 * bs3).min(120.0 / 8.0);
    assert!(
        (share.per_core_gbs[0] - want).abs() < 1e-12,
        "pure-L3 rate {} != {want}",
        share.per_core_gbs[0]
    );
    assert_eq!(share.iterations, 1);
    assert_eq!(share.portions.len(), 1, "no DRAM tandem when f·b_s = 0");
    assert_eq!(share.portions[0].l3, Some(0));
    assert!(!share.portions[0].mem);
    assert_eq!(share.l3.len(), 1);
    assert!(share.l3[0].saturated, "8 × 47 GB/s of demand saturates 120 GB/s");
    // The memory controller below is untouched.
    assert_eq!(share.domains[0].demand_gbs, 0.0);
}

/// Mirror `check_compute_zero_share`: a compute-bound group caps at its
/// core-bound rate `f·b_s` and consumes zero bandwidth share — its
/// memory-bound peer is bitwise unchanged by the co-residency.
#[test]
fn compute_bound_group_takes_zero_bandwidth_share() {
    let shape = one_domain(120.0);
    let alone = share_remote(&shape, &[mem(0, 4, DCOPY_F, DCOPY_BS, 0.0)]).unwrap();
    let peer = RemoteGroup {
        home: 0,
        n: 4,
        f: 0.05,
        bs_gbs: DCOPY_BS,
        remote_frac: 0.0,
        kind: GroupKind::Compute,
    };
    let both = share_remote(&shape, &[mem(0, 4, DCOPY_F, DCOPY_BS, 0.0), peer]).unwrap();
    assert_eq!(
        both.per_core_gbs[0].to_bits(),
        alone.per_core_gbs[0].to_bits(),
        "compute peer perturbed the memory-bound group"
    );
    assert_eq!(both.per_core_gbs[1].to_bits(), (0.05 * DCOPY_BS).to_bits());
    assert!(both.portions.iter().all(|p| p.group == 0), "compute group expanded portions");
    assert_eq!(both.iterations, 1);
}

/// THE LC-at-L3 conformance case, end to end through the scenario
/// pipeline (mirror `l3_mixed_example`): `jacobil3-v1:4@l3 + dcopy:4` on
/// one Rome domain with the shared L3 squeezed to 120 GB/s. The stencil
/// contends on BOTH the L3 node (all 5 L2-miss lines per update) and the
/// memory controller (its 3-line DRAM continuation, in tandem); dcopy
/// contends on the controller only. Both interfaces saturate and both
/// engines land within the paper's 8% ceiling (mirror: fluid worst
/// 4.55%, DES worst 1.80%; model 6.842 / 4.105 GB/s/core).
#[test]
fn lc_at_l3_mixed_scenario_stays_within_the_paper_ceiling() {
    let mut m = machine(MachineId::Rome);
    m.l3_bw_gbs = 120.0;
    let topo = Topology::single(&m);
    let mix = Mix::parse("jacobil3-v1:4@l3+dcopy:4").unwrap();

    for engine in [MeasureEngine::Fluid, MeasureEngine::Des] {
        let rs = run_mixes_on(&topo, Placement::Compact, &[mix.clone()], &engine).unwrap();
        let case = &rs.cases[0];
        assert_eq!(case.remote_converged, Some(true));

        // Model pins (mirror values; both sides are the same double
        // arithmetic, so they agree far tighter than the print precision).
        let stencil = &case.socket[0];
        let dcopy = &case.socket[1];
        assert!((stencil.model_per_core - 6.842).abs() < 5e-3, "{}", stencil.model_per_core);
        assert!((dcopy.model_per_core - 4.105).abs() < 5e-3, "{}", dcopy.model_per_core);

        // Simulation within the ceiling, per group.
        for g in &case.socket {
            assert!(
                g.error() < 0.08,
                "{:?}: simulated {} vs model {} ({:.1}%)",
                g.kernel,
                g.measured_per_core,
                g.model_per_core,
                g.error() * 100.0
            );
        }

        // One saturated L3 record carrying only the stencil.
        assert_eq!(case.l3.len(), 1);
        let l3 = &case.l3[0];
        assert_eq!(l3.socket, 0);
        assert_eq!(l3.l3_bw_gbs, 120.0);
        assert!(l3.saturated, "4 stencil cores demand > 120 GB/s of L3");
        assert_eq!(l3.origins, vec![0]);
        assert_eq!(l3.groups[0].n, 4);
        let l3_err =
            (l3.measured_total_gbs - l3.model_total_gbs).abs() / l3.model_total_gbs;
        assert!(l3_err < 0.08, "L3 totals: {} vs {}", l3.measured_total_gbs, l3.model_total_gbs);
    }
}

/// Classification guard rails: `@l3` needs a modeled L3, L3-resident
/// reuse, and no `%r`; the flat single-machine pipeline rejects every
/// non-memory-bound group with a pointer at the topology path.
#[test]
fn misclassified_groups_are_rejected_with_useful_errors() {
    let rome = machine(MachineId::Rome);
    let engine = MeasureEngine::Fluid;

    // @l3 on a streaming kernel: every L2-miss line continues to DRAM,
    // there is no L3-resident reuse to model.
    let err = run_mixes_on(
        &Topology::single(&rome),
        Placement::Compact,
        &[Mix::parse("dcopy:4@l3+ddot2:4").unwrap()],
        &engine,
    )
    .unwrap_err();
    assert!(matches!(err, Error::InvalidPlan(ref s) if s.contains("L3-resident")), "{err}");

    // @l3 on a machine that does not model shared-L3 bandwidth.
    let mut no_l3 = rome.clone();
    no_l3.l3_bw_gbs = 0.0;
    let err = run_mixes_on(
        &Topology::single(&no_l3),
        Placement::Compact,
        &[Mix::parse("jacobil3-v1:4@l3+dcopy:4").unwrap()],
        &engine,
    )
    .unwrap_err();
    assert!(matches!(err, Error::InvalidPlan(ref s) if s.contains("l3_bw_gbs")), "{err}");

    // @l3 combined with a remote fraction is contradictory: an
    // L3-resident working set does not stream to another socket.
    let err = run_mixes_on(
        &Topology::socket(&rome),
        Placement::Compact,
        &[Mix::parse("jacobil3-v1:4@d0@l3%r0.25+dcopy:4@d1+idle:24").unwrap()],
        &engine,
    )
    .unwrap_err();
    assert!(matches!(err, Error::InvalidPlan(ref s) if s.contains("remote")), "{err}");

    // The flat pipeline models memory contention only.
    let err = run_mixes(&rome, &[Mix::parse("jacobil3-v1:4@l3+dcopy:4").unwrap()], &engine)
        .unwrap_err();
    assert!(matches!(err, Error::InvalidPlan(ref s) if s.contains("topology")), "{err}");
    let err = run_mixes(&rome, &[Mix::parse("dcopy:4@comp+ddot2:4").unwrap()], &engine)
        .unwrap_err();
    assert!(matches!(err, Error::InvalidPlan(ref s) if s.contains("topology")), "{err}");
}
