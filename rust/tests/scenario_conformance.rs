//! Conformance suite for the scenario engine: Fluid, DES, and the
//! multigroup analytic model must agree on a matrix of k-group workload
//! mixes, with the paper's <8% two-group error bound as the ceiling; the
//! pairing sweep must be reproduced exactly as the k=2 special case; and
//! the shared characterization cache must be safe under concurrent sweeps.

use membw::config::{machine, MachineId};
use membw::kernels::KernelId;
use membw::scenario::{run_mixes, run_scenario, MeasureEngine, Mix, Scenario};
use membw::sweep::{full_domain_splits, run_cases};

/// The conformance matrix: k = 2..4 group mixes, with and without idle
/// cores, spanning saturated and nonsaturated regimes on all four machines.
fn matrix(mid: MachineId) -> Vec<Mix> {
    let specs: &[&str] = match mid {
        MachineId::Bdw1 => &[
            "dcopy:4+ddot2:3+stream:3",
            "dcopy:3+ddot2:3+idle:4",
            "vecsum:2+daxpy:3+schoenauer:3+dscal:2",
        ],
        MachineId::Bdw2 => &["ddot2:6+daxpy:6+jacobil2-v1:6"],
        MachineId::Clx => &["dcopy:7+ddot2:7+stream:6"],
        MachineId::Rome => &["dcopy:3+ddot2:3+stream:2", "daxpy:2+vecsum:2+idle:4"],
    };
    specs.iter().map(|s| Mix::parse(s).unwrap()).collect()
}

/// Per-group agreement between the multigroup model and the fluid engine on
/// the whole matrix, within the paper's 8% ceiling.
#[test]
fn model_vs_fluid_within_paper_bound() {
    for mid in MachineId::ALL {
        let m = machine(mid);
        let rs = run_mixes(&m, &matrix(mid), &MeasureEngine::Fluid).unwrap();
        for r in &rs.cases {
            for g in &r.groups {
                assert!(
                    g.error() < 0.08,
                    "{mid:?} [{}] {:?}: model {:.3} vs fluid {:.3} ({:.1}%)",
                    r.mix.label(),
                    g.kernel,
                    g.model_per_core,
                    g.measured_per_core,
                    g.error() * 100.0
                );
            }
        }
    }
}

/// Per-group agreement between the multigroup model and the DES engine
/// (slower, so only the small-domain machines), same 8% ceiling.
#[test]
fn model_vs_des_within_paper_bound() {
    for mid in [MachineId::Bdw1, MachineId::Rome] {
        let m = machine(mid);
        let rs = run_mixes(&m, &matrix(mid), &MeasureEngine::Des).unwrap();
        for r in &rs.cases {
            for g in &r.groups {
                assert!(
                    g.error() < 0.08,
                    "{mid:?} [{}] {:?}: model {:.3} vs DES {:.3}",
                    r.mix.label(),
                    g.kernel,
                    g.model_per_core,
                    g.measured_per_core
                );
            }
        }
    }
}

/// Cross-engine agreement: DES and fluid must agree per group (6%) and on
/// the aggregate (6%) across the matrix — the two independent measurement
/// substrates see the same physics.
#[test]
fn des_vs_fluid_cross_engine_agreement() {
    for mid in [MachineId::Bdw1, MachineId::Rome] {
        let m = machine(mid);
        let mixes = matrix(mid);
        let fluid = run_mixes(&m, &mixes, &MeasureEngine::Fluid).unwrap();
        let des = run_mixes(&m, &mixes, &MeasureEngine::Des).unwrap();
        for (rf, rd) in fluid.cases.iter().zip(&des.cases) {
            let tot_rel = (rf.measured_total_gbs - rd.measured_total_gbs).abs()
                / rf.measured_total_gbs;
            assert!(tot_rel < 0.06, "{mid:?} [{}]: totals diverge {tot_rel}", rf.mix.label());
            for (gf, gd) in rf.groups.iter().zip(&rd.groups) {
                let rel = (gf.measured_per_core - gd.measured_per_core).abs()
                    / gf.measured_per_core;
                assert!(
                    rel < 0.06,
                    "{mid:?} [{}] {:?}: fluid {:.3} vs DES {:.3}",
                    rf.mix.label(),
                    gf.kernel,
                    gf.measured_per_core,
                    gd.measured_per_core
                );
            }
        }
    }
}

/// The two-group pairing sweep and the scenario pipeline are the same
/// measurement: `run_cases` (k=2 conversion) is bit-identical to running
/// the equivalent mixes directly.
#[test]
fn pairing_sweep_is_the_k2_special_case() {
    let m = machine(MachineId::Bdw1);
    let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
    let legacy = run_cases(&m, &cases, &MeasureEngine::Fluid).unwrap();
    let mixes: Vec<Mix> = cases.iter().map(Mix::from_pairing).collect();
    let unified = run_mixes(&m, &mixes, &MeasureEngine::Fluid).unwrap();
    for (c, u) in legacy.cases.iter().zip(&unified.cases) {
        for g in 0..2 {
            assert!(
                (c.measured_per_core[g] - u.groups[g].measured_per_core).abs() < 1e-12,
                "measured diverges at {:?}",
                c.n
            );
            assert!(
                (c.model_per_core[g] - u.groups[g].model_per_core).abs() < 1e-12,
                "model diverges at {:?}",
                c.n
            );
        }
        assert!((c.measured_total - u.measured_total_gbs).abs() < 1e-12);
        assert!((c.model_total - u.model_total_gbs).abs() < 1e-12);
    }
}

/// A nonsaturated mix (one low-demand core per kernel, rest idle) runs
/// every group at its solo speed: the model predicts exactly `f·b_s` per
/// core, and the engine measurement agrees to better than 1%.
#[test]
fn nonsaturated_mix_runs_at_solo_speed() {
    let m = machine(MachineId::Bdw1);
    let mix = Mix::parse("ddot2:1+vecsum:1+idle:8").unwrap();
    let rs = run_mixes(&m, std::slice::from_ref(&mix), &MeasureEngine::Fluid).unwrap();
    let r = &rs.cases[0];
    assert!(!r.saturated, "two low-f cores cannot saturate BDW-1");
    for g in &r.groups {
        assert!(
            g.error() < 0.01,
            "{:?}: solo-speed mismatch (model {:.3}, measured {:.3})",
            g.kernel,
            g.model_per_core,
            g.measured_per_core
        );
    }
}

/// A solo-core mix reproduces the characterization's single-thread
/// bandwidth (the ECM value `f·b_s`) exactly — same deterministic engine,
/// same workload.
#[test]
fn solo_mix_reduces_to_single_thread_bandwidth() {
    use membw::scenario::{CharCache, EngineKind};
    let m = machine(MachineId::Clx);
    let mix = Mix::new().with(KernelId::Stream, 1);
    let rs = run_mixes(&m, std::slice::from_ref(&mix), &MeasureEngine::Fluid).unwrap();
    let c = CharCache::global()
        .lookup(&(m.fingerprint(), KernelId::Stream, EngineKind::Fluid))
        .expect("characterized by run_mixes");
    let measured = rs.cases[0].groups[0].measured_per_core;
    assert!(
        (measured - c.b1_gbs).abs() < 1e-9,
        "solo mix {measured} vs characterization b1 {}",
        c.b1_gbs
    );
    assert!(
        (rs.cases[0].groups[0].model_per_core - c.f * c.bs_gbs).abs() < 1e-9,
        "model must predict f*b_s for a solo core"
    );
}

/// Time-phased scenarios: every phase of the built-in demo stays within the
/// 8% ceiling on every machine, and idle phases speed up the active groups.
#[test]
fn demo_scenario_conforms_on_all_machines() {
    for mid in MachineId::ALL {
        let m = machine(mid);
        let sc = Scenario::demo(&m);
        let r = run_scenario(&m, &sc, &MeasureEngine::Fluid).unwrap();
        assert_eq!(r.phases.len(), 3);
        for e in r.all_errors() {
            assert!(e < 0.08, "{mid:?}: demo phase error {e}");
        }
        // Phase 2 idles the cores phase 1 gave to the third group: the two
        // surviving groups must get more bandwidth per core.
        for g in 0..2 {
            assert!(
                r.phases[1].groups[g].measured_per_core > r.phases[0].groups[g].measured_per_core,
                "{mid:?}: idling must free bandwidth"
            );
        }
    }
}

/// Concurrent sweeps through the shared characterization cache produce
/// identical results (thread safety of the global cache + batched runner).
#[test]
fn concurrent_sweeps_share_the_cache_safely() {
    let m = machine(MachineId::Rome);
    let mixes = matrix(MachineId::Rome);
    let results: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..6)
            .map(|_| scope.spawn(|| run_mixes(&m, &mixes, &MeasureEngine::Fluid).unwrap()))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for rs in &results[1..] {
        for (a, b) in rs.cases.iter().zip(&results[0].cases) {
            for (ga, gb) in a.groups.iter().zip(&b.groups) {
                assert_eq!(ga.measured_per_core.to_bits(), gb.measured_per_core.to_bits());
                assert_eq!(ga.model_per_core.to_bits(), gb.model_per_core.to_bits());
            }
        }
    }
}
