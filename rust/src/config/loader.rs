//! Machine-config (de)serialization in a TOML subset.
//!
//! Built-in machines cover the paper's Table I; this loader lets users add
//! further architectures (the paper's outlook mentions Power and Arm) or
//! override calibration parameters without recompiling. The build is fully
//! offline (no external TOML crate), so we parse a well-defined subset:
//! `key = value` lines, one optional `[queue]` section, `#` comments,
//! bare strings in double quotes, numbers, and the enum keywords used by
//! [`Machine`].

use std::collections::HashMap;
use std::path::Path;

use crate::config::machine::{LlcKind, Machine, MachineId, OverlapKind, QueueParams};
use crate::error::{Error, Result};

/// Serialize a machine description to TOML text (round-trips through
/// [`load_machine_toml`]).
pub fn machine_to_toml(m: &Machine) -> String {
    let llc = match m.llc {
        LlcKind::Inclusive => "inclusive",
        LlcKind::Victim => "victim",
    };
    let overlap = match m.overlap {
        OverlapKind::NonOverlapping => "non-overlapping",
        OverlapKind::Overlapping => "overlapping",
    };
    format!(
        "# Machine model (paper Table I row + simulator calibration)\n\
         id = \"{}\"\n\
         name = \"{}\"\n\
         microarch = \"{}\"\n\
         cores = {}\n\
         domains_per_socket = {}\n\
         freq_ghz = {}\n\
         simd_bytes = {}\n\
         ld_per_cy = {}\n\
         st_per_cy = {}\n\
         l1l2_bpc = {}\n\
         l2l3_bpc = {}\n\
         llc = \"{}\"\n\
         overlap = \"{}\"\n\
         theor_bw_gbs = {}\n\
         read_bw_gbs = {}\n\
         stream_penalty = {}\n\
         latency_residue_cy = {}\n\
         residue_on_all_lines = {}\n\
         link_bw_gbs = {}\n\
         link_bw_rev_gbs = {}\n\
         link_latency_us = {}\n\
         l3_bw_gbs = {}\n\
         \n[queue]\n\
         base_latency_cy = {}\n\
         depth_floor = {}\n\
         depth_beta = {}\n\
         latency_penalty = {}\n\
         write_penalty = {}\n",
        m.id.key(),
        m.name,
        m.microarch,
        m.cores,
        m.domains_per_socket,
        m.freq_ghz,
        m.simd_bytes,
        m.ld_per_cy,
        m.st_per_cy,
        m.l1l2_bpc,
        m.l2l3_bpc,
        llc,
        overlap,
        m.theor_bw_gbs,
        m.read_bw_gbs,
        m.stream_penalty,
        m.latency_residue_cy,
        m.residue_on_all_lines,
        m.link_bw_gbs,
        m.link_bw_rev_gbs,
        m.link_latency_us,
        m.l3_bw_gbs,
        m.queue.base_latency_cy,
        m.queue.depth_floor,
        m.queue.depth_beta,
        m.queue.latency_penalty,
        m.queue.write_penalty,
    )
}

/// Parse `key = value` lines into (section, key) -> raw value.
fn parse_kv(text: &str) -> HashMap<(String, String), String> {
    let mut map = HashMap::new();
    let mut section = String::new();
    for raw in text.lines() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        if let Some((k, v)) = line.split_once('=') {
            let v = v.trim().trim_matches('"').to_string();
            map.insert((section.clone(), k.trim().to_string()), v);
        }
    }
    map
}

/// Load a machine description from a TOML file (see [`machine_to_toml`] for
/// the schema; `configs/machines/*.toml` contains generated examples).
pub fn load_machine_toml(path: &Path) -> Result<Machine> {
    let text = std::fs::read_to_string(path)?;
    let map = parse_kv(&text);
    let err = |msg: String| Error::Config { path: path.display().to_string(), msg };
    let get = |section: &str, key: &str| -> Result<String> {
        map.get(&(section.to_string(), key.to_string()))
            .cloned()
            .ok_or_else(|| err(format!("missing key '{key}'")))
    };
    let get_f = |section: &str, key: &str| -> Result<f64> {
        get(section, key)?
            .parse::<f64>()
            .map_err(|e| err(format!("bad number for '{key}': {e}")))
    };
    let get_u = |section: &str, key: &str| -> Result<usize> {
        get(section, key)?
            .parse::<usize>()
            .map_err(|e| err(format!("bad integer for '{key}': {e}")))
    };
    let get_f_or = |section: &str, key: &str, default: f64| -> Result<f64> {
        match map.get(&(section.to_string(), key.to_string())) {
            Some(v) => v
                .parse::<f64>()
                .map_err(|e| err(format!("bad number for '{key}': {e}"))),
            None => Ok(default),
        }
    };

    let llc = match get("", "llc")?.as_str() {
        "inclusive" => LlcKind::Inclusive,
        "victim" => LlcKind::Victim,
        other => return Err(err(format!("bad llc kind '{other}'"))),
    };
    let overlap = match get("", "overlap")?.as_str() {
        "non-overlapping" => OverlapKind::NonOverlapping,
        "overlapping" => OverlapKind::Overlapping,
        other => return Err(err(format!("bad overlap kind '{other}'"))),
    };
    // Optional with default 0 (= no inter-socket link modeled): config
    // files predating the remote-access extension describe a machine whose
    // remote traffic never contends on a link. The reverse direction
    // defaults to the forward capacity: files predating directed links
    // describe a symmetric full-duplex interconnect.
    let link_bw_gbs = get_f_or("", "link_bw_gbs", 0.0)?;
    let link_bw_rev_gbs = get_f_or("", "link_bw_rev_gbs", link_bw_gbs)?;
    Ok(Machine {
        id: MachineId::parse(&get("", "id")?)?,
        name: get("", "name")?,
        microarch: get("", "microarch")?,
        cores: get_u("", "cores")?,
        // Optional with default 1: config files predating the topology
        // layer describe a single-domain socket.
        domains_per_socket: match map.get(&(String::new(), "domains_per_socket".to_string())) {
            Some(v) => v
                .parse::<usize>()
                .map_err(|e| err(format!("bad integer for 'domains_per_socket': {e}")))?,
            None => 1,
        },
        freq_ghz: get_f("", "freq_ghz")?,
        simd_bytes: get_u("", "simd_bytes")?,
        ld_per_cy: get_f("", "ld_per_cy")?,
        st_per_cy: get_f("", "st_per_cy")?,
        l1l2_bpc: get_f("", "l1l2_bpc")?,
        l2l3_bpc: get_f("", "l2l3_bpc")?,
        llc,
        overlap,
        theor_bw_gbs: get_f("", "theor_bw_gbs")?,
        read_bw_gbs: get_f("", "read_bw_gbs")?,
        stream_penalty: get_f("", "stream_penalty")?,
        latency_residue_cy: get_f("", "latency_residue_cy")?,
        residue_on_all_lines: get("", "residue_on_all_lines")? == "true",
        link_bw_gbs,
        link_bw_rev_gbs,
        link_latency_us: get_f_or("", "link_latency_us", 0.0)?,
        // Optional with default 0 (= no shared-L3 interface modeled):
        // config files predating the cache-topology extension describe a
        // machine on which every group contends on the memory controller.
        l3_bw_gbs: get_f_or("", "l3_bw_gbs", 0.0)?,
        queue: QueueParams {
            base_latency_cy: get_f("queue", "base_latency_cy")?,
            depth_floor: get_f("queue", "depth_floor")?,
            depth_beta: get_f("queue", "depth_beta")?,
            latency_penalty: get_f("queue", "latency_penalty")?,
            write_penalty: get_f("queue", "write_penalty")?,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin_machines;

    #[test]
    fn toml_roundtrip_all_builtin() {
        let dir = std::env::temp_dir().join("membw-toml-test");
        std::fs::create_dir_all(&dir).unwrap();
        for m in builtin_machines() {
            let text = machine_to_toml(&m);
            let path = dir.join(format!("{}.toml", m.id.key()));
            std::fs::write(&path, &text).unwrap();
            let back = load_machine_toml(&path).unwrap();
            assert_eq!(back.id, m.id);
            assert_eq!(back.cores, m.cores);
            assert_eq!(back.domains_per_socket, m.domains_per_socket);
            assert_eq!(back.llc, m.llc);
            assert_eq!(back.overlap, m.overlap);
            assert!((back.read_bw_gbs - m.read_bw_gbs).abs() < 1e-12);
            assert!((back.queue.write_penalty - m.queue.write_penalty).abs() < 1e-12);
            assert!((back.link_bw_gbs - m.link_bw_gbs).abs() < 1e-12);
            assert!((back.link_bw_rev_gbs - m.link_bw_rev_gbs).abs() < 1e-12);
            assert!((back.link_latency_us - m.link_latency_us).abs() < 1e-12);
            assert!((back.l3_bw_gbs - m.l3_bw_gbs).abs() < 1e-12);
        }
    }

    #[test]
    fn comments_and_whitespace_tolerated() {
        let dir = std::env::temp_dir().join("membw-toml-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("commented.toml");
        let mut text = machine_to_toml(&builtin_machines()[0]);
        text.push_str("\n# trailing comment\n\n");
        std::fs::write(&path, text.replace("cores = 10", "cores = 10   # ten cores")).unwrap();
        let m = load_machine_toml(&path).unwrap();
        assert_eq!(m.cores, 10);
    }

    #[test]
    fn missing_domains_per_socket_defaults_to_one() {
        // Pre-topology config files lack the key; they describe one domain.
        let dir = std::env::temp_dir().join("membw-toml-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy.toml");
        let text = machine_to_toml(&builtin_machines()[3]); // Rome: 4 domains
        let legacy: String =
            text.lines().filter(|l| !l.starts_with("domains_per_socket")).collect::<Vec<_>>().join("\n");
        std::fs::write(&path, legacy).unwrap();
        let m = load_machine_toml(&path).unwrap();
        assert_eq!(m.domains_per_socket, 1);
    }

    #[test]
    fn missing_link_fields_default_to_unmodeled() {
        // Pre-remote-access config files lack the link keys; they describe
        // a machine with no inter-socket link contention.
        let dir = std::env::temp_dir().join("membw-toml-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("no-link.toml");
        let text = machine_to_toml(&builtin_machines()[0]);
        let legacy: String = text
            .lines()
            .filter(|l| !l.starts_with("link_"))
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(&path, legacy).unwrap();
        let m = load_machine_toml(&path).unwrap();
        assert_eq!(m.link_bw_gbs, 0.0);
        assert_eq!(m.link_bw_rev_gbs, 0.0);
        assert_eq!(m.link_latency_us, 0.0);
    }

    #[test]
    fn missing_reverse_capacity_defaults_to_symmetric_duplex() {
        // Files predating directed links carry only `link_bw_gbs`; they
        // describe a symmetric full-duplex interconnect.
        let dir = std::env::temp_dir().join("membw-toml-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("symmetric.toml");
        let text = machine_to_toml(&builtin_machines()[3]);
        let legacy: String = text
            .lines()
            .filter(|l| !l.starts_with("link_bw_rev_gbs"))
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(&path, legacy).unwrap();
        let m = load_machine_toml(&path).unwrap();
        assert!(m.link_bw_gbs > 0.0);
        assert_eq!(m.link_bw_rev_gbs.to_bits(), m.link_bw_gbs.to_bits());
    }

    #[test]
    fn missing_l3_bw_defaults_to_unmodeled() {
        // Pre-cache-topology config files lack the key; they describe a
        // machine with no shared-L3 interface (bit-identical old behavior).
        let dir = std::env::temp_dir().join("membw-toml-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("no-l3.toml");
        let text = machine_to_toml(&builtin_machines()[0]);
        let legacy: String = text
            .lines()
            .filter(|l| !l.starts_with("l3_bw_gbs"))
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(&path, legacy).unwrap();
        let m = load_machine_toml(&path).unwrap();
        assert_eq!(m.l3_bw_gbs, 0.0);
    }

    #[test]
    fn missing_key_reports_path() {
        let dir = std::env::temp_dir().join("membw-toml-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("broken.toml");
        std::fs::write(&path, "cores = 10\n").unwrap();
        let e = load_machine_toml(&path).unwrap_err();
        assert!(e.to_string().contains("broken.toml"));
    }
}
