#!/usr/bin/env python3
"""Pure-Python mirror of the multi-interface simulation substrate.

Mirrors `rust/src/simulator/network.rs` (and the single-interface seed
loops it generalizes) operation for operation — same IEEE-754 double
arithmetic in the same order, same xorshift64* draw sequence — so the two
implementations can be compared *bitwise*. Run it directly:

    python3 python/netfluid_mirror.py

It executes the mirror's own conformance checks:

1. the generalized multi-interface fluid loop, run on a degenerate
   single-interface network, is bit-identical to the seed fused loop of
   `rust/src/simulator/fluid.rs`;
2. the generalized multi-interface DES, run with r = 0 on a multi-domain
   network, decomposes into components that replay the seed DES of
   `rust/src/simulator/des.rs` per domain, bit for bit;
3. the worked 2xNPS4 Rome link-gated example of `docs/SIMULATORS.md`:
   multi-interface fluid vs the analytic `share_remote` water-fill within
   the paper's 8% ceiling (and the link never exceeds its capacity).

Keep this file in sync with the Rust — it is the reference the docs'
numbers are cross-checked against (see docs/SIMULATORS.md).
"""

import heapq
import math

CACHE_LINE = 64.0
ELEMS_PER_LINE = 8.0

# --------------------------------------------------------------------------
# Machine rows (rust/src/config/machine.rs) — the fields the simulators use.
# --------------------------------------------------------------------------

MACHINES = {
    "bdw1": dict(cores=10, freq=2.2, simd=32, ld_per_cy=2.0, l1l2=64.0, l2l3=32.0,
                 llc="inclusive", overlap="sum", read_bw=66.9, stream_pen=0.0,
                 residue=3.2, residue_all=False, link_bw=38.4,
                 L0=200.0, D0=1.5, beta=1.0, wp=0.26),
    "rome": dict(cores=8, freq=2.35, simd=32, ld_per_cy=2.0, l1l2=64.0, l2l3=32.0,
                 llc="victim", overlap="max", read_bw=35.0, stream_pen=0.022,
                 residue=0.9, residue_all=True, link_bw=64.0,
                 L0=260.0, D0=1.5, beta=1.0, wp=0.02),
}

# Streaming kernels: (reads, writes, rfo, loads/iter, stores/iter, flops/iter)
KERNELS = {
    "dcopy": (1, 1, 1, 1, 1, 0),
    "ddot2": (2, 0, 0, 2, 0, 2),
    "stream": (2, 1, 1, 2, 1, 2),
    "daxpy": (2, 1, 0, 2, 1, 2),
}


def cost_factor(m, write_frac, streams):
    g = 1.0 - math.exp(-write_frac / 0.12)
    wr = 1.0 + m["wp"] * g
    st = max(1.0 - m["stream_pen"] * (streams - 1), 0.5)
    return wr / st


def saturated_bw(m, write_frac, streams):
    return m["read_bw"] / cost_factor(m, write_frac, streams)


def capacity_lines_per_cy(m):
    return m["read_bw"] / m["freq"] / CACHE_LINE


def to_gbs(m, lines_per_cy):
    return lines_per_cy * CACHE_LINE * m["freq"]


def ecm_workload(m, kname):
    """Mirror of ecm::predict -> CoreWorkload: (d, c, f, bs)."""
    reads, writes, rfo, loads, stores, flops = KERNELS[kname]
    total = reads + writes + rfo
    wf = writes / total
    lanes = m["simd"] / 8.0
    iters = ELEMS_PER_LINE
    t_ol = iters * flops / (2.0 * lanes * 2.0)
    t_l1reg = math.ceil(iters * loads / lanes) / m["ld_per_cy"]
    t_l1l2 = total * CACHE_LINE / m["l1l2"]
    if m["llc"] == "inclusive":
        l3_lines = total
    else:
        l3_lines = max(reads - reads, 0) + writes  # l3 == mem for streaming
    t_l2l3 = l3_lines * CACHE_LINE / m["l2l3"]
    bs = saturated_bw(m, wf, total)
    t_mem = total * CACHE_LINE / (bs / m["freq"])
    residue_lines = total if m["residue_all"] else reads + rfo
    t_lat = m["residue"] * residue_lines
    if m["overlap"] == "sum":
        t_ecm = max(t_ol, t_l1reg + t_l1l2 + t_l2l3 + t_mem + t_lat)
    else:
        t_ecm = max(t_ol, t_l1reg, t_l1l2, t_l2l3, t_mem + t_lat)
    f = t_mem / t_ecm
    d = total / t_ecm
    c = cost_factor(m, wf, total)
    return d, c, f, bs


# --------------------------------------------------------------------------
# xorshift64* (rust/src/simulator/xorshift.rs)
# --------------------------------------------------------------------------

M64 = (1 << 64) - 1


class XorShift64:
    def __init__(self, seed):
        self.state = seed if seed != 0 else 0x9E3779B97F4A7C15

    def next_u64(self):
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & M64
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & M64

    def next_f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)


# --------------------------------------------------------------------------
# Seed single-interface loops (fluid.rs / des.rs, verbatim semantics)
# --------------------------------------------------------------------------

def fluid_seed(m, workloads, warmup=4096, measure=12288):
    """workloads: list of (d, c). Returns (per_core_lines_per_cy, util)."""
    cap = capacity_lines_per_cy(m)
    n = len(workloads)
    d = [w[0] for w in workloads]
    c = [w[1] for w in workloads]
    win = [m["D0"] + m["beta"] * d[i] * c[i] * m["L0"] for i in range(n)]
    occ = [0.0] * n
    served = [0.0] * n
    u_accum = 0.0
    occ_cost = 0.0
    for cycle in range(warmup + measure + 1):
        measuring = cycle > warmup
        lam = min(cap / occ_cost, 1.0) if occ_cost > 1e-12 else 1.0
        if measuring:
            u_accum += min(occ_cost / cap, 1.0)
        keep = 1.0 - lam
        occ_cost = 0.0
        for i in range(n):
            o_pre = occ[i]
            if measuring:
                served[i] += lam * o_pre
            o = o_pre * keep
            if d[i] > 0.0:
                o += min(d[i], max(win[i] - o, 0.0))
            occ[i] = o
            occ_cost += o * c[i]
    return [s / measure for s in served], u_accum / measure


def des_seed(m, workloads, warmup=40000.0, measure=400000.0, seed=0xB4D5EED):
    """Seed DES. workloads: list of (d, c). Returns per-core served lines/cy."""
    cap = capacity_lines_per_cy(m)
    rng = XorShift64(seed)
    n = len(workloads)
    gap, window, cost, queued, busy_flag = [], [], [], [], [False]
    outstanding = [0] * n
    blocked = [False] * n
    served = [0] * n
    for d, c in workloads:
        gap.append(1.0 / d if d > 0.0 else math.inf)
        w = m["D0"] + m["beta"] * d * c * m["L0"]
        window.append(max(int(math.floor(w + 0.5)), 1))  # f64::round, half away
        cost.append(c / cap)
        queued.append(0)
    heap = []
    for i in range(n):
        if math.isfinite(gap[i]):
            heapq.heappush(heap, (rng.next_f64() * gap[i], i, 0))
    t_end = warmup + measure

    def try_serve(t):
        if busy_flag[0]:
            return
        total = sum(queued)
        if total == 0:
            return
        x = int(rng.next_f64() * total)
        pick = 0
        for i in range(n):
            if x < queued[i]:
                pick = i
                break
            x -= queued[i]
        queued[pick] -= 1
        busy_flag[0] = True
        heapq.heappush(heap, (t + cost[pick], pick, 1))

    while heap:
        t, idx, kind = heapq.heappop(heap)
        if t >= t_end:
            break
        if kind == 0:
            if outstanding[idx] < window[idx]:
                queued[idx] += 1
                outstanding[idx] += 1
                blocked[idx] = False
                jitter = 0.95 + 0.1 * rng.next_f64()
                heapq.heappush(heap, (t + gap[idx] * jitter, idx, 0))
                try_serve(t)
            else:
                blocked[idx] = True
        else:
            outstanding[idx] -= 1
            if t >= warmup:
                served[idx] += 1
            busy_flag[0] = False
            if blocked[idx]:
                blocked[idx] = False
                heapq.heappush(heap, (t, idx, 0))
            try_serve(t)
    return [s / measure for s in served]


# --------------------------------------------------------------------------
# The interface network (network.rs)
# --------------------------------------------------------------------------

class Net:
    """mem_caps: lines/cy per domain; links: socket pairs; link_cap lines/cy."""

    def __init__(self, mem_caps, socket_of, links, link_cap, m):
        self.mem_caps = mem_caps
        self.socket_of = socket_of
        self.links = links
        self.link_cap = link_cap
        self.m = m


def net_of(m, sockets, domains_per_socket, bw_scale=None):
    nd = sockets * domains_per_socket
    scale = bw_scale or [1.0] * nd
    mem_caps = [capacity_lines_per_cy(m) * s for s in scale]
    socket_of = [d // domains_per_socket for d in range(nd)]
    links = [(a, b) for a in range(sockets) for b in range(a + 1, sockets)]
    link_cap = m["link_bw"] / m["freq"] / CACHE_LINE if m["link_bw"] > 0 else 0.0
    return Net(mem_caps, socket_of, links, link_cap, m)


def route(net, streams):
    """streams: list of (d, c, home, r). Returns portions
    (stream, target, link_or_None, weight)."""
    nd = len(net.mem_caps)
    portions = []
    for si, (d, c, home, r) in enumerate(streams):
        home_w = 1.0 - r
        if home_w > 0.0:
            portions.append((si, home, None, home_w))
        if r > 0.0:
            w = r / (nd - 1)
            for t in range(nd):
                if t == home:
                    continue
                link = None
                if net.socket_of[t] != net.socket_of[home] and net.link_cap > 0.0:
                    pair = (min(net.socket_of[home], net.socket_of[t]),
                            max(net.socket_of[home], net.socket_of[t]))
                    link = net.links.index(pair)
                portions.append((si, t, link, w))
    return portions


def fluid_net(net, streams, warmup=4096, measure=12288):
    """Generalized fluid loop. Returns (per-portion lines/cy, portions,
    per-interface utilization [mem..., links...])."""
    m = net.m
    nd = len(net.mem_caps)
    nl = len(net.links)
    portions = route(net, streams)
    np_ = len(portions)
    dp = [streams[p[0]][0] * p[3] for p in portions]
    cp = [streams[p[0]][1] for p in portions]
    win = [m["D0"] + m["beta"] * dp[i] * cp[i] * m["L0"] for i in range(np_)]
    occ = [0.0] * np_
    served = [0.0] * np_
    occ_mem = [0.0] * nd
    occ_link = [0.0] * nl
    u_mem = [0.0] * nd
    u_link = [0.0] * nl
    for cycle in range(warmup + measure + 1):
        measuring = cycle > warmup
        lam_mem = [min(net.mem_caps[d] / occ_mem[d], 1.0) if occ_mem[d] > 1e-12 else 1.0
                   for d in range(nd)]
        lam_link = [min(net.link_cap / occ_link[l], 1.0) if occ_link[l] > 1e-12 else 1.0
                    for l in range(nl)]
        if measuring:
            for d in range(nd):
                u_mem[d] += min(occ_mem[d] / net.mem_caps[d], 1.0)
            for l in range(nl):
                u_link[l] += min(occ_link[l] / net.link_cap, 1.0)
        occ_mem = [0.0] * nd
        occ_link = [0.0] * nl
        for i in range(np_):
            _, tgt, link, _ = portions[i]
            lam = lam_mem[tgt] if link is None else min(lam_mem[tgt], lam_link[link])
            o_pre = occ[i]
            if measuring:
                served[i] += lam * o_pre
            o = o_pre * (1.0 - lam)
            if dp[i] > 0.0:
                o += min(dp[i], max(win[i] - o, 0.0))
            occ[i] = o
            occ_mem[tgt] += o * cp[i]
            if link is not None:
                occ_link[link] += o
    util = [u / measure for u in u_mem] + [u / measure for u in u_link]
    return [s / measure for s in served], portions, util


def des_net(net, streams, warmup=40000.0, measure=400000.0, seed=0xB4D5EED):
    """Generalized DES: connected components of the interface graph, each
    replayed with its own xorshift stream. Links are a first service stage
    (cost 1/C_link per line), the target memory interface the second.
    Returns (per-portion lines/cy, portions)."""
    m = net.m
    nd = len(net.mem_caps)
    portions = route(net, streams)
    np_ = len(portions)

    # Union-find over interfaces (mem d -> d, link l -> nd + l).
    parent = list(range(nd + len(net.links)))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for _, tgt, link, _ in portions:
        if link is not None:
            ra, rb = find(tgt), find(nd + link)
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)

    comp_of_iface = [find(x) for x in range(nd + len(net.links))]
    comps = sorted(set(comp_of_iface[portions[i][1]] for i in range(np_)))
    served = [0] * np_
    for comp in comps:
        local = [i for i in range(np_) if comp_of_iface[portions[i][1]] == comp]
        rng = XorShift64(seed)
        k = len(local)
        gap, window, mcost, lcost = [], [], [], []
        q_mem, q_link = [0] * k, [0] * k
        outstanding, blocked = [0] * k, [False] * k
        for i in local:
            _, tgt, link, _ = portions[i]
            d, c = (streams[portions[i][0]][0] * portions[i][3],
                    streams[portions[i][0]][1])
            gap.append(1.0 / d if d > 0.0 else math.inf)
            w = m["D0"] + m["beta"] * d * c * m["L0"]
            window.append(max(int(math.floor(w + 0.5)), 1))
            mcost.append(c / net.mem_caps[tgt])
            lcost.append(1.0 / net.link_cap if link is not None else 0.0)
        mem_busy = {}
        link_busy = {}
        heap = []
        for j in range(k):
            if math.isfinite(gap[j]):
                heapq.heappush(heap, (rng.next_f64() * gap[j], j, 0))
        t_end = warmup + measure

        def try_serve_mem(t, d):
            if mem_busy.get(d, False):
                return
            members = [j for j in range(k) if portions[local[j]][1] == d]
            total = sum(q_mem[j] for j in members)
            if total == 0:
                return
            x = int(rng.next_f64() * total)
            pick = members[0]
            for j in members:
                if x < q_mem[j]:
                    pick = j
                    break
                x -= q_mem[j]
            q_mem[pick] -= 1
            mem_busy[d] = True
            heapq.heappush(heap, (t + mcost[pick], pick, 1))

        def try_serve_link(t, l):
            if link_busy.get(l, False):
                return
            members = [j for j in range(k) if portions[local[j]][2] == l]
            total = sum(q_link[j] for j in members)
            if total == 0:
                return
            x = int(rng.next_f64() * total)
            pick = members[0]
            for j in members:
                if x < q_link[j]:
                    pick = j
                    break
                x -= q_link[j]
            q_link[pick] -= 1
            link_busy[l] = True
            heapq.heappush(heap, (t + lcost[pick], pick, 2))

        while heap:
            t, j, kind = heapq.heappop(heap)
            if t >= t_end:
                break
            _, tgt, link, _ = portions[local[j]]
            if kind == 0:
                if outstanding[j] < window[j]:
                    outstanding[j] += 1
                    blocked[j] = False
                    jitter = 0.95 + 0.1 * rng.next_f64()
                    heapq.heappush(heap, (t + gap[j] * jitter, j, 0))
                    if link is not None:
                        q_link[j] += 1
                        try_serve_link(t, link)
                    else:
                        q_mem[j] += 1
                        try_serve_mem(t, tgt)
                else:
                    blocked[j] = True
            elif kind == 2:
                q_mem[j] += 1
                link_busy[link] = False
                try_serve_mem(t, tgt)
                try_serve_link(t, link)
            else:
                outstanding[j] -= 1
                if t >= warmup:
                    served[local[j]] += 1
                mem_busy[tgt] = False
                if blocked[j]:
                    blocked[j] = False
                    heapq.heappush(heap, (t, j, 0))
                try_serve_mem(t, tgt)
    return [s / measure for s in served], portions


def lockstep_per_stream(net, streams, per_portion, portions):
    """min_p drain_p / weight_p, in GB/s."""
    out = []
    for si in range(len(streams)):
        rate = math.inf
        for i, (s, _, _, w) in enumerate(portions):
            if s == si:
                rate = min(rate, to_gbs(net.m, per_portion[i]) / w)
        out.append(rate if math.isfinite(rate) else 0.0)
    return out


# --------------------------------------------------------------------------
# The analytic model (sharing/multigroup.rs + sharing/remote.rs)
# --------------------------------------------------------------------------

def share_weighted_capacity(groups, capacity):
    """groups: list of (n, f, bs). Returns per-group bandwidth."""
    k = len(groups)
    demand = [n * f * bs for n, f, bs in groups]
    weight = [n * f for n, f, _ in groups]
    bw = [0.0] * k
    capped = [False] * k
    remaining = min(capacity, sum(demand))
    for _ in range(k):
        wsum = sum(weight[i] for i in range(k) if not capped[i])
        if wsum <= 0.0 or remaining <= 0.0:
            break
        newly = False
        for i in range(k):
            if capped[i]:
                continue
            if remaining * weight[i] / wsum >= demand[i] - 1e-12:
                bw[i] = demand[i]
                capped[i] = True
                newly = True
        if newly:
            remaining = max(min(capacity, sum(demand))
                            - sum(bw[i] for i in range(k) if capped[i]), 0.0)
        else:
            for i in range(k):
                if not capped[i]:
                    bw[i] = remaining * weight[i] / wsum
            break
    return bw


def share_remote(net, groups):
    """groups: (home, n, f, bs, r). Returns (per_core, portions-with-grants).
    Mirrors sharing::remote::share_remote (uniform spread + lockstep min)."""
    nd = len(net.mem_caps)
    scale = [net.mem_caps[d] / capacity_lines_per_cy(net.m) for d in range(nd)]
    portions = []  # (group, target, link, weight)
    for gi, (home, n, f, bs, r) in enumerate(groups):
        if 1.0 - r > 0.0:
            portions.append((gi, home, None, 1.0 - r))
        if r > 0.0:
            w = r / (nd - 1)
            for t in range(nd):
                if t == home:
                    continue
                link = None
                if net.socket_of[t] != net.socket_of[home] and net.m["link_bw"] > 0:
                    pair = (min(net.socket_of[home], net.socket_of[t]),
                            max(net.socket_of[home], net.socket_of[t]))
                    link = net.links.index(pair)
                portions.append((gi, t, link, w))
    mem_grant = [0.0] * len(portions)
    link_grant = [0.0] * len(portions)
    for d in range(nd):
        idx = [i for i, p in enumerate(portions) if p[1] == d]
        wg = [(groups[portions[i][0]][1] * portions[i][3],
               groups[portions[i][0]][2],
               groups[portions[i][0]][3] * scale[d]) for i in idx]
        n_tot = sum(g[0] for g in wg)
        if n_tot == 0.0:
            continue
        b_mix = sum(g[0] * g[2] for g in wg) / n_tot
        for i, bw in zip(idx, share_weighted_capacity(wg, b_mix)):
            mem_grant[i] = bw
    for l in range(len(net.links)):
        idx = [i for i, p in enumerate(portions) if p[2] == l]
        if not idx:
            continue
        wg = [(groups[portions[i][0]][1] * portions[i][3],
               groups[portions[i][0]][2],
               groups[portions[i][0]][3] * scale[portions[i][1]]) for i in idx]
        for i, bw in zip(idx, share_weighted_capacity(wg, net.m["link_bw"])):
            link_grant[i] = bw
    per_core = []
    for gi, (home, n, f, bs, r) in enumerate(groups):
        rate = math.inf
        for i, (g, _, link, w) in enumerate(portions):
            if g != gi:
                continue
            grant = mem_grant[i] if link is None else min(mem_grant[i], link_grant[i])
            rate = min(rate, grant / (n * w))
        per_core.append(rate if math.isfinite(rate) else 0.0)
    return per_core, portions


# --------------------------------------------------------------------------
# Conformance checks
# --------------------------------------------------------------------------

def check_fluid_degenerate():
    for mname in ("bdw1", "rome"):
        m = MACHINES[mname]
        wl = [ecm_workload(m, "dcopy")[:2]] * 4 + [ecm_workload(m, "ddot2")[:2]] * 3
        wl += [(0.0, 1.0)]  # idle core
        seed_pc, seed_u = fluid_seed(m, wl)
        net = net_of(m, 1, 1)
        streams = [(d, c, 0, 0.0) for d, c in wl]
        pp, portions, util = fluid_net(net, streams)
        assert len(pp) == len(wl)
        for a, b in zip(seed_pc, pp):
            assert a == b, f"fluid degenerate mismatch on {mname}: {a} vs {b}"
        assert seed_u == util[0], f"utilization mismatch on {mname}"
    print("ok: generalized fluid == seed fluid (single interface, bitwise)")


def check_fluid_r0_multidomain():
    m = MACHINES["rome"]
    dc = ecm_workload(m, "dcopy")[:2]
    dd = ecm_workload(m, "ddot2")[:2]
    # Domain 0: 4x dcopy + 2x ddot2; domain 1 (scaled 0.5): 3x ddot2.
    net = net_of(m, 1, 2, bw_scale=[1.0, 0.5])
    streams = ([(dc[0], dc[1], 0, 0.0)] * 4 + [(dd[0], dd[1], 0, 0.0)] * 2
               + [(dd[0], dd[1], 1, 0.0)] * 3)
    pp, portions, _ = fluid_net(net, streams)
    # Per-domain seed runs (scaled domain: scaled capacity).
    seed0, _ = fluid_seed(m, [dc] * 4 + [dd] * 2)
    m_scaled = dict(m)
    m_scaled["read_bw"] = m["read_bw"] * 0.5
    seed1, _ = fluid_seed(m_scaled, [dd] * 3)
    want = seed0 + seed1
    for a, b in zip(want, pp):
        assert a == b, f"fluid r=0 multi-domain mismatch: {a} vs {b}"
    print("ok: generalized fluid r=0 == per-domain seed runs (bitwise)")


def check_des_degenerate_and_r0():
    m = MACHINES["rome"]
    dc = ecm_workload(m, "dcopy")[:2]
    dd = ecm_workload(m, "ddot2")[:2]
    cfg = dict(warmup=20000.0, measure=100000.0)
    # Degenerate single interface.
    wl = [dc] * 3 + [dd] * 2
    seed_pc = des_seed(m, wl, **cfg)
    net = net_of(m, 1, 1)
    pp, portions = des_net(net, [(d, c, 0, 0.0) for d, c in wl], **cfg)
    for a, b in zip(seed_pc, pp):
        assert a == b, f"DES degenerate mismatch: {a} vs {b}"
    # r=0 over two domains == two independent seed runs.
    net2 = net_of(m, 1, 2)
    streams = [(dc[0], dc[1], 0, 0.0)] * 3 + [(dd[0], dd[1], 1, 0.0)] * 4
    pp2, _ = des_net(net2, streams, **cfg)
    want = des_seed(m, [dc] * 3, **cfg) + des_seed(m, [dd] * 4, **cfg)
    for a, b in zip(want, pp2):
        assert a == b, f"DES r=0 multi-domain mismatch: {a} vs {b}"
    print("ok: generalized DES == seed DES (degenerate + r=0, bitwise)")


def worked_example(verbose=True):
    """docs/SIMULATORS.md: 2 x NPS4 Rome, dcopy:64@scatter %r0.5 —
    the xGMI link is the bottleneck of every cross-socket portion."""
    m = MACHINES["rome"]
    net = net_of(m, 2, 4)
    d, c, f, bs = ecm_workload(m, "dcopy")
    # 64 cores, 8 per domain, each sending half its lines remote.
    streams = [(d, c, dom, 0.5) for dom in range(8) for _ in range(8)]
    pp, portions, util = fluid_net(net, streams)
    sim_pc = lockstep_per_stream(net, streams, pp, portions)
    groups = [(dom, 8, f, bs, 0.5) for dom in range(8)]
    model_pc, _ = share_remote(net, groups)
    # Link throughput: sum of cross-portion drains, in GB/s.
    link_gbs = sum(to_gbs(m, pp[i]) for i, p in enumerate(portions)
                   if p[2] is not None)
    link_cap_gbs = m["link_bw"]
    errs = [abs(sim_pc[8 * dom] - model_pc[dom]) / model_pc[dom] for dom in range(8)]
    if verbose:
        print("\nworked example: 2xNPS4 Rome, dcopy on all 64 cores, r = 0.5")
        print(f"  kernel chars: f = {f:.3f}, b_s = {bs:.2f} GB/s, "
              f"d = {d:.4f} lines/cy, c = {c:.4f}")
        print(f"  model  per-core: {model_pc[0]:.3f} GB/s (link-gated)")
        print(f"  fluid  per-core: {sim_pc[0]:.3f} GB/s "
              f"(err {errs[0] * 100:.2f}%)")
        print(f"  link traffic: {link_gbs:.2f} GB/s simulated vs "
              f"{link_cap_gbs:.1f} GB/s capacity (util {util[8]:.3f})")
    assert link_gbs <= link_cap_gbs * 1.001, "link exceeded capacity"
    assert max(errs) < 0.08, f"link-gated fluid vs model error {max(errs)}"
    print("ok: link-gated fluid within 8% of the analytic water-fill "
          f"(worst {max(errs) * 100:.2f}%)")
    return sim_pc, model_pc, link_gbs


def mixed_example(verbose=True):
    """The docs/MODEL.md-style example: dcopy:8@d0%r0.25 + ddot2:8@d4."""
    m = MACHINES["rome"]
    net = net_of(m, 2, 4)
    d1, c1, f1, bs1 = ecm_workload(m, "dcopy")
    d2, c2, f2, bs2 = ecm_workload(m, "ddot2")
    streams = [(d1, c1, 0, 0.25)] * 8 + [(d2, c2, 4, 0.0)] * 8
    pp, portions, _ = fluid_net(net, streams)
    sim_pc = lockstep_per_stream(net, streams, pp, portions)
    model_pc, _ = share_remote(net, [(0, 8, f1, bs1, 0.25), (4, 8, f2, bs2, 0.0)])
    if verbose:
        print("\nmixed example: dcopy:8@d0%r0.25 + ddot2:8@d4 on 2x4 Rome")
        print(f"  dcopy: model {model_pc[0]:.3f}, fluid {sim_pc[0]:.3f} GB/s/core")
        print(f"  ddot2: model {model_pc[1]:.3f}, fluid {sim_pc[8]:.3f} GB/s/core")
    return sim_pc, model_pc


if __name__ == "__main__":
    check_fluid_degenerate()
    check_fluid_r0_multidomain()
    check_des_degenerate_and_r0()
    worked_example()
    mixed_example()
    print("\nall mirror checks passed")
