//! Fuzz-style never-panic property tests for the two text surfaces of
//! the crate: the mix/scenario DSL (`scenario::spec`) and the `repro
//! serve` request protocol (`service::request`).
//!
//! Both parsers face hostile input — the DSL arrives via `--mix` and the
//! request parser via a long-running stdin stream — so every byte soup
//! must come back as a structured [`membw::Error`], never a panic, and
//! every valid spec must survive a Display → parse round trip. The
//! generators are seeded xorshift, so failures reproduce exactly.

use membw::scenario::{Mix, Scenario};
use membw::service::{parse_json, Request};

/// Deterministic xorshift64* driver.
struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }

    /// A printable-heavy but arbitrary byte string (always valid UTF-8 —
    /// both surfaces take `&str`, so UTF-8 validity is the caller's
    /// contract; hostile *bytes* are rejected upstream by I/O).
    fn soup(&mut self, max_len: usize) -> String {
        let len = self.below(max_len + 1);
        (0..len)
            .map(|_| {
                match self.below(10) {
                    // DSL/JSON-relevant punctuation, to reach deep parser states.
                    0 => *b"+:@%./{}[]\",\\ud".get(self.below(15)).unwrap() as char,
                    // Digits and signs.
                    1 | 2 => *b"0123456789-+.eE".get(self.below(15)).unwrap() as char,
                    // Keywords fragments.
                    3 => *b"dcopystreamidlesubmt".get(self.below(20)).unwrap() as char,
                    // Any printable ASCII.
                    4..=7 => (0x20 + self.below(0x5f) as u8) as char,
                    // Control bytes.
                    8 => (self.below(0x20) as u8) as char,
                    // Non-ASCII scalar values.
                    _ => char::from_u32(0xa0 + self.next() as u32 % 0x2_0000)
                        .unwrap_or('\u{fffd}'),
                }
            })
            .collect()
    }

    /// Mutate a valid template: splice, truncate, duplicate, or corrupt.
    fn mutate(&mut self, template: &str) -> String {
        let mut s: Vec<char> = template.chars().collect();
        for _ in 0..1 + self.below(4) {
            if s.is_empty() {
                break;
            }
            match self.below(4) {
                0 => {
                    let at = self.below(s.len());
                    s.truncate(at);
                }
                1 => {
                    let at = self.below(s.len());
                    s.remove(at);
                }
                2 => {
                    let at = self.below(s.len() + 1);
                    let c = (0x20 + self.below(0x5f) as u8) as char;
                    s.insert(at, c);
                }
                _ => {
                    let at = self.below(s.len());
                    let from = self.below(s.len());
                    s[at] = s[from];
                }
            }
        }
        s.into_iter().collect()
    }
}

const KERNELS: [&str; 8] =
    ["dcopy", "ddot2", "stream", "daxpy", "vecsum", "dscal", "waxpby", "ddot1"];
const FRACS: [&str; 3] = ["0.1", "0.25", "0.5"];

/// A random syntactically valid mix spec (groups with optional `@dN`
/// pins, `@mem` bounds, `%r` fractions, optional idle tail — all
/// suffix-order combinations the DSL accepts).
fn random_valid_mix(rng: &mut XorShift) -> String {
    let n_groups = 1 + rng.below(4);
    let mut parts: Vec<String> = (0..n_groups)
        .map(|_| {
            let mut g = format!("{}:{}", KERNELS[rng.below(KERNELS.len())], 1 + rng.below(8));
            if rng.below(3) == 0 {
                g.push_str(&format!("@d{}", rng.below(8)));
            }
            if rng.below(4) == 0 {
                g.push_str("@mem");
            }
            if rng.below(3) == 0 {
                g.push_str(&format!("%r{}", FRACS[rng.below(FRACS.len())]));
            }
            g
        })
        .collect();
    if rng.below(3) == 0 {
        parts.push(format!("idle:{}", 1 + rng.below(6)));
    }
    parts.join("+")
}

#[test]
fn mix_and_scenario_parsers_never_panic_on_soup() {
    let mut rng = XorShift(0xfeed_beef_0001);
    for _ in 0..4000 {
        let s = rng.soup(80);
        // Any Err is fine; a panic fails the test by unwinding.
        let _ = Mix::parse(&s);
        let _ = Scenario::parse("fuzz", &s);
    }
}

#[test]
fn mix_parser_never_panics_on_mutated_valid_specs() {
    let mut rng = XorShift(0xfeed_beef_0002);
    for _ in 0..4000 {
        let template = random_valid_mix(&mut rng);
        let s = rng.mutate(&template);
        let _ = Mix::parse(&s);
        // Scenario shares the group grammar; `/` separators come from
        // mutation occasionally.
        let _ = Scenario::parse("fuzz", &s);
    }
}

#[test]
fn valid_mixes_round_trip_through_their_canonical_label() {
    let mut rng = XorShift(0xfeed_beef_0003);
    for _ in 0..500 {
        let spec = random_valid_mix(&mut rng);
        let mix = Mix::parse(&spec).unwrap_or_else(|e| panic!("'{spec}' must parse: {e}"));
        let label = mix.label();
        let reparsed =
            Mix::parse(&label).unwrap_or_else(|e| panic!("canonical '{label}' must parse: {e}"));
        assert_eq!(reparsed.label(), label, "canonical form must be a fixed point");
        assert_eq!(reparsed.groups.len(), mix.groups.len());
        assert_eq!(reparsed.idle_cores, mix.idle_cores);
        for (a, b) in reparsed.groups.iter().zip(&mix.groups) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.cores, b.cores);
            assert_eq!(a.remote_ppm, b.remote_ppm);
        }
    }
}

#[test]
fn request_parser_never_panics_on_soup() {
    let mut rng = XorShift(0xfeed_beef_0004);
    for _ in 0..4000 {
        let s = rng.soup(120);
        let _ = parse_json(&s);
        let _ = Request::parse(&s);
    }
}

#[test]
fn request_parser_never_panics_on_mutated_valid_requests() {
    let templates = [
        r#"{"op":"submit","id":"j0","mix":"dcopy:6+ddot2:6@d3%r0.25"}"#,
        r#"{"op":"finish","id":"j0"}"#,
        r#"{"op":"query","id":"j-é😀"}"#,
        r#"{"op":"snapshot"}"#,
        r#"{"op":"submit","id":"x","mix":"stream:4","extra":[1,2,{"a":null}]}"#,
    ];
    let mut rng = XorShift(0xfeed_beef_0005);
    for _ in 0..4000 {
        let s = rng.mutate(templates[rng.below(templates.len())]);
        let _ = parse_json(&s);
        let _ = Request::parse(&s);
    }
}

#[test]
fn valid_requests_parse_to_their_structured_form() {
    // The happy paths stay reachable under the same entry points the fuzz
    // loops hammer (guards the fuzz tests against vacuous success).
    assert!(matches!(
        Request::parse(r#"{"op":"submit","id":"a","mix":"dcopy:4"}"#),
        Ok(Request::Submit { .. })
    ));
    assert!(matches!(
        Request::parse(r#"{"op":"finish","id":"a"}"#),
        Ok(Request::Finish { .. })
    ));
    assert!(matches!(
        Request::parse(r#"{"op":"query","id":"a"}"#),
        Ok(Request::Query { .. })
    ));
    assert!(matches!(Request::parse(r#"{"op":"snapshot"}"#), Ok(Request::Snapshot)));
    assert!(Request::parse("").is_err());
    assert!(Request::parse(r#"{"op":"submit","id":"","mix":"dcopy:4"}"#).is_err());
}
