//! Bench: regenerate Table II (kernel characterization) and time the
//! characterization pipeline per engine.

use membw::benchutil::Bench;
use membw::config::{machine, MachineId};
use membw::kernels::all_kernels;
use membw::report::{table2_report, ExperimentCtx};
use membw::simulator::{measure_f_bs, Engine};

fn main() {
    let mut b = Bench::new("table2");

    // Time a single-kernel characterization per engine.
    let m = machine(MachineId::Bdw1);
    let (_, stream) = all_kernels().into_iter().find(|(_, k)| k.name == "STREAM").unwrap();
    b.run("characterize STREAM/bdw1 (fluid)", 5, || {
        let _ = measure_f_bs(&stream, &m, Engine::Fluid);
    });
    b.run("characterize STREAM/bdw1 (des)", 3, || {
        let _ = measure_f_bs(&stream, &m, Engine::Des);
    });

    // Full Table II regeneration (all 15 kernels x 4 machines).
    let ctx = ExperimentCtx::fluid(std::path::PathBuf::from("results"));
    let mut table = String::new();
    b.run("full Table II (fluid)", 1, || {
        table = table2_report(&ctx).expect("table2");
    });
    println!("\n{table}");
    b.finish();
}
