//! Memoized sharing-model evaluations keyed by group composition.
//!
//! The desynchronization co-simulator evaluates the multigroup model
//! (generalized Eqs. 4+5) every time the set of concurrently running kernels
//! changes, but the number of *distinct* compositions in a run is small
//! (hundreds), so evaluations are memoized. This used to live as an ad-hoc
//! `HashMap` inside the co-sim engine; it is now a reusable component with
//! hit/miss accounting, shared by the timeline engine and available to any
//! future consumer (schedulers, what-if explorers).
//!
//! Kernels are mapped to dense *slots* at construction; a composition is a
//! per-slot core-count vector, packed into a 128-bit key (8 bits per slot).

use std::collections::HashMap;

use crate::kernels::KernelId;
use crate::sharing::{share_multigroup, KernelGroup};

/// Maximum number of distinct kernels one cache can track (the composition
/// key packs 8 bits per slot into a `u128`). The full Table II registry has
/// 15 kernels, so this is not a practical limit.
pub const MAX_SLOTS: usize = 16;

/// Maximum core count per group representable in the packed key.
pub const MAX_GROUP_CORES: usize = 255;

/// Counter snapshot of a [`ShareCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShareCacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that evaluated the model.
    pub misses: u64,
    /// Distinct compositions stored.
    pub entries: usize,
}

/// Memoized `share_multigroup` evaluations for a fixed kernel set.
pub struct ShareCache {
    kernels: Vec<KernelId>,
    /// `(f, b_s[GB/s])` per slot.
    chars: Vec<(f64, f64)>,
    /// Composition key → per-core drain rate in bytes/s, per slot.
    cache: HashMap<u128, Vec<f64>>,
    /// Two-entry MRU over `cache`: co-sims alternate between a handful of
    /// compositions around noise events, and this keeps the hot path free of
    /// hashing. `u128::MAX` marks an empty way.
    mru: [u128; 2],
    hits: u64,
    misses: u64,
}

impl ShareCache {
    /// Build a cache for the kernel set `chars`: `(kernel, f, b_s[GB/s])`
    /// per slot, in slot order.
    ///
    /// # Panics
    /// If more than [`MAX_SLOTS`] kernels are given or a kernel repeats.
    pub fn new(chars: &[(KernelId, f64, f64)]) -> Self {
        assert!(
            chars.len() <= MAX_SLOTS,
            "ShareCache supports at most {MAX_SLOTS} distinct kernels ({} given)",
            chars.len()
        );
        let kernels: Vec<KernelId> = chars.iter().map(|c| c.0).collect();
        for (i, k) in kernels.iter().enumerate() {
            assert!(!kernels[..i].contains(k), "duplicate kernel {k:?} in ShareCache");
        }
        ShareCache {
            kernels,
            chars: chars.iter().map(|c| (c.1, c.2)).collect(),
            cache: HashMap::new(),
            mru: [u128::MAX; 2],
            hits: 0,
            misses: 0,
        }
    }

    /// Number of kernel slots.
    pub fn slots(&self) -> usize {
        self.kernels.len()
    }

    /// Slot of a kernel, if tracked.
    pub fn slot_of(&self, k: KernelId) -> Option<usize> {
        self.kernels.iter().position(|kk| *kk == k)
    }

    /// Kernel of a slot.
    pub fn kernel_of(&self, slot: usize) -> KernelId {
        self.kernels[slot]
    }

    /// `(f, b_s)` of a slot.
    pub fn chars_of(&self, slot: usize) -> (f64, f64) {
        self.chars[slot]
    }

    fn key_of(counts: &[u16]) -> u128 {
        let mut key = 0u128;
        for (i, &c) in counts.iter().enumerate() {
            debug_assert!(c as usize <= MAX_GROUP_CORES);
            key |= (c as u128) << (8 * i);
        }
        key
    }

    /// Per-core drain rates (bytes/s) per slot for the composition
    /// `counts[slot] = number of cores running that kernel` (idle cores are
    /// simply absent — scenario (c) of Fig. 2). Memoized.
    pub fn rates_bytes(&mut self, counts: &[u16]) -> &[f64] {
        debug_assert_eq!(counts.len(), self.kernels.len());
        let key = Self::key_of(counts);
        if self.mru[0] == key || self.mru[1] == key || self.cache.contains_key(&key) {
            self.hits += 1;
        } else {
            self.misses += 1;
            let groups: Vec<KernelGroup> = counts
                .iter()
                .zip(&self.chars)
                .map(|(&n, &(f, bs))| KernelGroup { n: n as usize, f, bs_gbs: bs })
                .collect();
            let rates: Vec<f64> = if counts.iter().all(|&c| c == 0) {
                vec![0.0; self.kernels.len()]
            } else {
                share_multigroup(&groups)
                    .groups
                    .iter()
                    .map(|e| e.per_core_gbs * 1e9)
                    .collect()
            };
            self.cache.insert(key, rates);
        }
        if self.mru[0] != key {
            self.mru[1] = self.mru[0];
            self.mru[0] = key;
        }
        self.cache.get(&key).expect("just inserted").as_slice()
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ShareCacheStats {
        ShareCacheStats { hits: self.hits, misses: self.misses, entries: self.cache.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> ShareCache {
        ShareCache::new(&[
            (KernelId::Ddot2, 0.16, 110.0),
            (KernelId::Daxpy, 0.21, 103.0),
            (KernelId::Schoenauer, 0.19, 104.0),
        ])
    }

    #[test]
    fn rates_match_direct_model_evaluation() {
        let mut c = cache();
        let counts = [4u16, 3, 2];
        let rates = c.rates_bytes(&counts).to_vec();
        let direct = share_multigroup(&[
            KernelGroup { n: 4, f: 0.16, bs_gbs: 110.0 },
            KernelGroup { n: 3, f: 0.21, bs_gbs: 103.0 },
            KernelGroup { n: 2, f: 0.19, bs_gbs: 104.0 },
        ]);
        for (slot, e) in direct.groups.iter().enumerate() {
            assert_eq!(rates[slot].to_bits(), (e.per_core_gbs * 1e9).to_bits());
        }
    }

    #[test]
    fn zero_count_slots_do_not_perturb_active_groups() {
        // A composition with an absent kernel must equal the model run on
        // the active groups only (idle groups carry zero demand).
        let mut c = cache();
        let rates = c.rates_bytes(&[5, 0, 3]).to_vec();
        let direct = share_multigroup(&[
            KernelGroup { n: 5, f: 0.16, bs_gbs: 110.0 },
            KernelGroup { n: 0, f: 0.21, bs_gbs: 103.0 },
            KernelGroup { n: 3, f: 0.19, bs_gbs: 104.0 },
        ]);
        assert_eq!(rates[0].to_bits(), (direct.groups[0].per_core_gbs * 1e9).to_bits());
        assert_eq!(rates[1], 0.0);
        assert_eq!(rates[2].to_bits(), (direct.groups[2].per_core_gbs * 1e9).to_bits());
    }

    #[test]
    fn memoizes_by_composition() {
        let mut c = cache();
        c.rates_bytes(&[4, 3, 2]);
        c.rates_bytes(&[4, 3, 2]);
        c.rates_bytes(&[4, 3, 2]);
        c.rates_bytes(&[1, 0, 0]);
        let s = c.stats();
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 2);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn mru_alternation_hits() {
        // The noise-preemption pattern: composition alternates A, B, A, B.
        let mut c = cache();
        let a = [4u16, 3, 2];
        let b = [4u16, 2, 2];
        c.rates_bytes(&a);
        c.rates_bytes(&b);
        for _ in 0..10 {
            c.rates_bytes(&a);
            c.rates_bytes(&b);
        }
        let s = c.stats();
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 20);
    }

    #[test]
    fn empty_composition_yields_zero_rates() {
        let mut c = cache();
        assert!(c.rates_bytes(&[0, 0, 0]).iter().all(|&r| r == 0.0));
    }

    #[test]
    fn slot_mapping_round_trips() {
        let c = cache();
        assert_eq!(c.slots(), 3);
        assert_eq!(c.slot_of(KernelId::Daxpy), Some(1));
        assert_eq!(c.slot_of(KernelId::Dcopy), None);
        assert_eq!(c.kernel_of(2), KernelId::Schoenauer);
        assert_eq!(c.chars_of(0), (0.16, 110.0));
    }

    #[test]
    #[should_panic(expected = "duplicate kernel")]
    fn rejects_duplicate_kernels() {
        ShareCache::new(&[(KernelId::Ddot2, 0.1, 50.0), (KernelId::Ddot2, 0.2, 60.0)]);
    }
}
