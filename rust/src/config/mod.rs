//! Machine and experiment configuration — the paper's Table I as data.
//!
//! The four validation machines (BDW-1, BDW-2, CLX, Rome) are built in;
//! additional machines can be loaded from TOML files (see
//! [`loader::load_machine_toml`]), which is how the paper's outlook
//! ("validation on Power- or Arm-based CPUs") is supported without code
//! changes.

mod loader;
mod machine;

pub use loader::{load_machine_toml, machine_to_toml};
pub use machine::{
    LlcKind, Machine, MachineFingerprint, MachineId, OverlapKind, QueueParams, builtin_machines,
    machine, machine_by_name,
};
