//! Baseline models the paper argues against — kept for ablation benches.
//!
//! 1. **Equal share**: bandwidth splits purely by thread count, ignoring
//!    kernel characteristics (what one would assume under naive FCFS).
//! 2. **Code-balance share**: weights threads by the kernel's code balance
//!    `B_c` instead of `f`. Sect. III explains why this is a worse metric:
//!    it ignores machine overlap characteristics and intra-cache traffic.

use crate::sharing::model::KernelGroup;
use crate::sharing::multigroup::{share_multigroup, GroupShare};

/// Which baseline to evaluate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineKind {
    /// Thread-count-proportional split.
    EqualShare,
    /// Code-balance-weighted split.
    CodeBalance,
}

/// Equal-share baseline: every thread gets the same bandwidth regardless of
/// the kernel it runs (replace every `f` with a common constant — the model
/// (5) then degenerates to thread-count proportionality).
pub fn equal_share(groups: &[KernelGroup]) -> GroupShare {
    let unif: Vec<KernelGroup> = groups
        .iter()
        .map(|g| KernelGroup { n: g.n, f: 1.0, bs_gbs: g.bs_gbs })
        .collect();
    share_multigroup(&unif)
}

/// Code-balance baseline: weight by `B_c` (bytes per flop at the memory
/// level) normalized to an `f`-like scale. `code_balance[i]` must align with
/// `groups[i]`; infinite balances (flop-free kernels like DCOPY) are clamped.
pub fn code_balance_share(groups: &[KernelGroup], code_balance: &[f64]) -> GroupShare {
    assert_eq!(groups.len(), code_balance.len());
    let max_bc = code_balance
        .iter()
        .cloned()
        .filter(|b| b.is_finite())
        .fold(1.0f64, f64::max);
    let weighted: Vec<KernelGroup> = groups
        .iter()
        .zip(code_balance)
        .map(|(g, &bc)| KernelGroup {
            n: g.n,
            f: if bc.is_finite() { bc / max_bc } else { 1.0 },
            bs_gbs: g.bs_gbs,
        })
        .collect();
    share_multigroup(&weighted)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(n: usize, f: f64, bs: f64) -> KernelGroup {
        KernelGroup { n, f, bs_gbs: bs }
    }

    #[test]
    fn equal_share_ignores_f() {
        let a = equal_share(&[g(6, 0.4, 60.0), g(4, 0.1, 60.0)]);
        assert!((a.groups[0].alpha - 0.6).abs() < 1e-9);
        assert!((a.groups[1].alpha - 0.4).abs() < 1e-9);
    }

    #[test]
    fn code_balance_handles_infinite_bc() {
        let shares = code_balance_share(
            &[g(5, 0.3, 55.0), g(5, 0.3, 55.0)],
            &[f64::INFINITY, 16.0],
        );
        assert!(shares.groups[0].alpha >= shares.groups[1].alpha);
    }
}
