//! The unified measurement pipeline: batched, parallel execution of k-group
//! mixes on any engine, with the multigroup analytic prediction (generalized
//! Eqs. 4+5) attached to every measured case.
//!
//! This is the single pipeline behind both the scenario CLI and the legacy
//! two-group pairing sweeps ([`crate::sweep::run_cases`] converts its
//! [`crate::sweep::PairingCase`]s to k=2 mixes and delegates here).
//!
//! Parallelism: in-process engines (fluid, DES) fan the mix list out over a
//! dynamically scheduled worker pool (rayon-style semantics — an atomic work
//! index instead of a work-stealing deque — kept dependency-free because the
//! build is offline); the PJRT engine instead packs the whole list into
//! batched artifact dispatches. Kernel characterizations are served from the
//! process-wide [`CharCache`].

use std::collections::HashMap;

use crate::config::Machine;
use crate::error::Result;
use crate::kernels::{kernel, KernelId};
use crate::parallel::par_map;
use crate::runtime::{PjrtSimExecutor, SimCase};
use crate::scenario::cache::{CharCache, EngineKind};
use crate::scenario::results::{
    GroupOutcome, L3Result, LinkResult, MixResult, MixResultSet, ScenarioResult, TopoMixResult,
    TopoMixResultSet, TopoScenarioResult,
};
use crate::scenario::spec::{BoundHint, GroupSpec, Mix, Scenario};
use crate::sharing::{share_multigroup, share_remote, GroupKind, KernelGroup, RemoteGroup};
use crate::simulator::{
    run_engine, run_net_engine, CoreWorkload, Engine, IfaceNet, KernelMeasurement, NetStream,
};
use crate::topology::{Placement, SplitMix, Topology};

/// Measurement engine selection for a sweep or scenario run.
pub enum MeasureEngine<'a> {
    /// In-process fluid simulator, parallelized over OS threads.
    Fluid,
    /// In-process discrete-event simulator, parallelized over OS threads.
    Des,
    /// The AOT JAX/Pallas artifact through PJRT (batched).
    Pjrt(&'a PjrtSimExecutor),
}

impl MeasureEngine<'_> {
    /// The in-process engine, if this is not the PJRT path.
    pub(crate) fn inproc(&self) -> Option<Engine> {
        match self {
            MeasureEngine::Fluid => Some(Engine::Fluid),
            MeasureEngine::Des => Some(Engine::Des),
            MeasureEngine::Pjrt(_) => None,
        }
    }

    /// Engine kind for cache keying.
    pub fn kind(&self) -> EngineKind {
        match self {
            MeasureEngine::Fluid => EngineKind::Fluid,
            MeasureEngine::Des => EngineKind::Des,
            MeasureEngine::Pjrt(exec) => {
                use std::hash::{Hash, Hasher};
                let mut h = std::collections::hash_map::DefaultHasher::new();
                exec.source().hash(&mut h);
                EngineKind::Pjrt(h.finish())
            }
        }
    }

    /// Short display name.
    pub fn name(&self) -> &'static str {
        match self {
            MeasureEngine::Fluid => "fluid",
            MeasureEngine::Des => "des",
            MeasureEngine::Pjrt(_) => "pjrt",
        }
    }
}

/// Per-core workload vector of a mix: kernel groups in order, idle cores
/// last (scenario (c) of Fig. 2 — zero demand, absent from contention).
fn workloads_for(machine: &Machine, mix: &Mix) -> Vec<CoreWorkload> {
    let mut ws = Vec::with_capacity(mix.total_cores());
    for (gi, g) in mix.groups.iter().enumerate() {
        let w = CoreWorkload::from_kernel(&kernel(g.kernel), machine, gi);
        ws.extend(vec![w; g.cores]);
    }
    ws.extend(vec![CoreWorkload::idle(); mix.idle_cores]);
    ws
}

/// L3-level contention characterization of a cache-resident (or
/// `@l3`-forced) kernel on `m`.
///
/// The tandem model routes **every** L2-miss line through the shared L3
/// before the survivors continue to memory, so the L3-level demand is the
/// full L2-miss count `sig.l3.total()` — deliberately not
/// [`crate::ecm::effective_l3_lines`], which subtracts the victim-LLC
/// bypass and only feeds the single-core ECM runtime. The L3 request
/// fraction follows Eq. (2) one level up: `f_L3 = T_L2L3 / T_ECM` at the
/// wire rate `b_L3 = l2l3_bpc · freq` (identity: `f_L3 · b_L3` equals the
/// L2-miss line rate times 64 B).
fn l3_kind(sig: &crate::kernels::KernelSignature, m: &Machine) -> Result<GroupKind> {
    if m.l3_bw_gbs <= 0.0 {
        return Err(crate::error::Error::InvalidPlan(format!(
            "kernel '{}' classifies cache-bound but machine '{}' models no \
             shared-L3 bandwidth (l3_bw_gbs = 0)",
            sig.name,
            m.id.key(),
        )));
    }
    if sig.l3.total() <= sig.mem.total() {
        return Err(crate::error::Error::InvalidPlan(format!(
            "kernel '{}' has no L3-resident reuse traffic ({} L2-miss lines \
             vs {} memory lines per unit) — it contends at the memory \
             interface, not the shared L3",
            sig.name,
            sig.l3.total(),
            sig.mem.total(),
        )));
    }
    let p = crate::ecm::predict(sig, m);
    let t_l2l3 = sig.l3.total() as f64 * m.line_cycles(m.l2l3_bpc);
    Ok(GroupKind::L3 { f_l3: t_l2l3 / p.t_ecm, bs_l3_gbs: m.l2l3_bpc * m.freq_ghz })
}

/// Effective contention kind of one group on `m`: an explicit
/// `@mem`/`@l3`/`@comp` suffix wins; `Auto` classifies from the ECM
/// signature. A kernel whose working set never leaves the cache hierarchy
/// (`mem.total() == 0`) contends at the shared L3 when one is modeled; a
/// kernel whose roofline knee `n_s = 1/f` lies beyond the machine's core
/// count (`f · cores < 1`) can never saturate memory and is compute-bound.
/// Every kernel in the built-in registry classifies `Mem` on every
/// built-in machine (pinned by the cache-topology conformance suite), so
/// auto-classification leaves all pre-existing mixes bit-identical.
fn effective_kind(g: &GroupSpec, m: &Machine) -> Result<GroupKind> {
    let sig = kernel(g.kernel);
    match g.bound {
        BoundHint::Mem => Ok(GroupKind::Mem),
        BoundHint::Compute => Ok(GroupKind::Compute),
        BoundHint::L3 => {
            if g.remote_frac() > 0.0 {
                return Err(crate::error::Error::InvalidPlan(
                    "a group bound to the shared L3 (@l3) cannot also carry a \
                     remote-access fraction (%r)"
                        .into(),
                ));
            }
            l3_kind(&sig, m)
        }
        BoundHint::Auto => {
            if sig.mem.total() == 0
                && crate::ecm::effective_l3_lines(&sig, m) > 0.0
                && m.l3_bw_gbs > 0.0
            {
                return l3_kind(&sig, m);
            }
            let p = crate::ecm::predict(&sig, m);
            if p.f * m.cores as f64 < 1.0 {
                Ok(GroupKind::Compute)
            } else {
                Ok(GroupKind::Mem)
            }
        }
    }
}

/// Compose the per-mix result from raw per-core bandwidths plus the
/// multigroup model prediction.
fn compose_result(
    machine: &Machine,
    mix: &Mix,
    per_core: &[f64],
    chars: &HashMap<KernelId, KernelMeasurement>,
) -> MixResult {
    let model_groups: Vec<KernelGroup> = mix
        .groups
        .iter()
        .map(|g| {
            let c = chars[&g.kernel];
            KernelGroup { n: g.cores, f: c.f, bs_gbs: c.bs_gbs }
        })
        .collect();
    let share = share_multigroup(&model_groups);

    let mut outcomes = Vec::with_capacity(mix.k());
    let mut offset = 0usize;
    let mut measured_total = 0.0f64;
    let mut model_total = 0.0f64;
    for (gi, g) in mix.groups.iter().enumerate() {
        let bw: f64 = per_core[offset..offset + g.cores].iter().sum();
        offset += g.cores;
        measured_total += bw;
        let entry = &share.groups[gi];
        model_total += entry.group_bw_gbs;
        outcomes.push(GroupOutcome {
            kernel: g.kernel,
            n: g.cores,
            measured_bw_gbs: bw,
            measured_per_core: if g.cores > 0 { bw / g.cores as f64 } else { 0.0 },
            model_bw_gbs: entry.group_bw_gbs,
            model_per_core: entry.per_core_gbs,
            model_alpha: entry.alpha,
        });
    }
    MixResult {
        machine: machine.id,
        mix: mix.clone(),
        groups: outcomes,
        measured_total_gbs: measured_total,
        model_total_gbs: model_total,
        b_mix_gbs: share.b_mix_gbs,
        saturated: share.saturated,
    }
}

/// Raw per-core bandwidth measurement of a batch of mixes on one contention
/// domain, in input order (batched on PJRT, worker pool otherwise).
fn measure_mixes(
    machine: &Machine,
    mixes: &[Mix],
    engine: &MeasureEngine,
) -> Result<Vec<Vec<f64>>> {
    match engine {
        MeasureEngine::Pjrt(exec) => {
            let sim_cases: Vec<SimCase> = mixes
                .iter()
                .map(|mx| SimCase {
                    machine: machine.clone(),
                    workloads: workloads_for(machine, mx),
                })
                .collect();
            exec.run(&sim_cases)
        }
        _ => {
            let eng = engine.inproc().expect("non-PJRT engines are in-process");
            Ok(par_map(mixes, |mx| run_engine(machine, &workloads_for(machine, mx), eng)))
        }
    }
}

/// Measure a batch of mixes on `machine` with `engine`; results are in
/// input order, each carrying the multigroup analytic prediction.
pub fn run_mixes(machine: &Machine, mixes: &[Mix], engine: &MeasureEngine) -> Result<MixResultSet> {
    for mix in mixes {
        mix.validate(machine)?;
        // The flat single-interface pipeline models memory contention only;
        // cache- and compute-bound groups need the multi-interface path.
        for g in &mix.groups {
            if effective_kind(g, machine)? != GroupKind::Mem {
                return Err(crate::error::Error::InvalidPlan(format!(
                    "group '{}:{}{}' is not memory-bound; cache- and \
                     compute-bound groups need the topology pipeline (run \
                     the mix on a topology, e.g. `--domains 1`)",
                    g.kernel.key(),
                    g.cores,
                    g.bound.suffix(),
                )));
            }
        }
    }
    let mut kernels: Vec<KernelId> = mixes.iter().flat_map(|m| m.kernels()).collect();
    kernels.sort_by_key(|k| k.key());
    kernels.dedup();
    let chars = CharCache::global().characterize(machine, &kernels, engine)?;

    let per_core = measure_mixes(machine, mixes, engine)?;

    Ok(MixResultSet {
        cases: mixes
            .iter()
            .zip(&per_core)
            .map(|(mx, pc)| compose_result(machine, mx, pc, &chars))
            .collect(),
    })
}

/// Run every phase of a scenario (batched through [`run_mixes`]).
pub fn run_scenario(
    machine: &Machine,
    scenario: &Scenario,
    engine: &MeasureEngine,
) -> Result<ScenarioResult> {
    let rs = run_mixes(machine, &scenario.mixes, engine)?;
    Ok(ScenarioResult { name: scenario.name.clone(), machine: machine.id, phases: rs.cases })
}

/// Measure a batch of *socket-level* mixes on a multi-domain topology.
///
/// Every mix is resolved onto the domains by `placement` (explicit `@dN`
/// pins first, then scatter, then compact — see
/// [`crate::topology::Placement::split`]); each domain's sub-mixes are then
/// measured and modeled **independently** — one Eqs. (4)+(5) evaluation per
/// domain over that domain's resident groups, which is the ccNUMA
/// contention semantics. Kernel characterization happens once on the base
/// machine (cache-keyed); a domain with bandwidth scale `s` sees `s·b_s`
/// (the memory request fraction `f` is a property of kernel and core
/// microarchitecture, not of the DIMM population).
///
/// On [`Topology::single`] this reduces bit-identically to [`run_mixes`]
/// (pinned by the topology conformance suite).
pub fn run_mixes_on(
    topo: &Topology,
    placement: Placement,
    mixes: &[Mix],
    engine: &MeasureEngine,
) -> Result<TopoMixResultSet> {
    // Remote traffic couples domains and links, and cache-/compute-bound
    // groups contend on interfaces the per-domain path does not model; both
    // route through the multi-interface pipeline. The all-local all-Mem
    // path below stays untouched (and bit-identical to its pre-remote
    // form) — with the built-in registry, auto-classification is always
    // `Mem`, so only `%r` or an explicit `@l3`/`@comp` changes routes.
    let mut needs_network = mixes.iter().any(|m| m.has_remote());
    for mx in mixes {
        for g in &mx.groups {
            if effective_kind(g, &topo.base)? != GroupKind::Mem {
                needs_network = true;
            }
        }
    }
    if needs_network {
        return run_mixes_on_remote(topo, placement, mixes, engine);
    }
    // split rejects empty mixes, out-of-range pins, and capacity overflow.
    let splits: Vec<SplitMix> =
        mixes.iter().map(|mx| placement.split(topo, mx)).collect::<Result<_>>()?;

    let mut kernels: Vec<KernelId> = mixes.iter().flat_map(|m| m.kernels()).collect();
    kernels.sort_by_key(|k| k.key());
    kernels.dedup();
    // Derived base rows (SNC sub-domains) carry their own cache fingerprint
    // (cores + bandwidth bits), so the global cache serves every row —
    // registry or derived — without aliasing.
    let base_chars = CharCache::global().characterize(&topo.base, &kernels, engine)?;

    // Skeleton results; domains fill in below in domain order.
    let mut cases: Vec<TopoMixResult> = mixes
        .iter()
        .map(|mx| TopoMixResult {
            machine: topo.base.id,
            topology: topo.label(),
            placement: placement.name(),
            mix: mx.clone(),
            domain_ids: Vec::new(),
            domains: Vec::new(),
            origins: Vec::new(),
            socket: Vec::new(),
            links: Vec::new(),
            l3: Vec::new(),
            measured_total_gbs: 0.0,
            model_total_gbs: 0.0,
            remote_converged: None,
        })
        .collect();

    for (d, dom) in topo.domains.iter().enumerate() {
        let batch: Vec<(usize, &crate::topology::DomainMix)> = splits
            .iter()
            .enumerate()
            .filter(|(_, s)| s.domains[d].mix.active_cores() > 0)
            .map(|(ci, s)| (ci, &s.domains[d]))
            .collect();
        if batch.is_empty() {
            continue;
        }
        let dmixes: Vec<Mix> = batch.iter().map(|(_, dm)| dm.mix.clone()).collect();
        let per_core = measure_mixes(&dom.machine, &dmixes, engine)?;
        let chars_d: HashMap<KernelId, KernelMeasurement> = if dom.bw_scale == 1.0 {
            base_chars.clone()
        } else {
            base_chars
                .iter()
                .map(|(k, c)| {
                    (
                        *k,
                        KernelMeasurement {
                            b1_gbs: c.b1_gbs * dom.bw_scale,
                            bs_gbs: c.bs_gbs * dom.bw_scale,
                            f: c.f,
                        },
                    )
                })
                .collect()
        };
        for ((ci, dm), pc) in batch.iter().zip(&per_core) {
            let r = compose_result(&dom.machine, &dm.mix, pc, &chars_d);
            let case = &mut cases[*ci];
            case.domain_ids.push(d);
            case.domains.push(r);
            case.origins.push(dm.origin.clone());
        }
    }

    // Socket-level aggregation per original group.
    for (case, mix) in cases.iter_mut().zip(mixes) {
        aggregate_socket(case, mix);
    }

    Ok(TopoMixResultSet { cases })
}

/// Fill a topology case's socket-level aggregate from its per-domain
/// results: bandwidths summed over domains per original group, α = share
/// of the socket aggregate.
fn aggregate_socket(case: &mut TopoMixResult, mix: &Mix) {
    let k = mix.groups.len();
    let mut meas = vec![0.0f64; k];
    let mut model = vec![0.0f64; k];
    for (dr, origin) in case.domains.iter().zip(&case.origins) {
        for (gi, g) in dr.groups.iter().enumerate() {
            meas[origin[gi]] += g.measured_bw_gbs;
            model[origin[gi]] += g.model_bw_gbs;
        }
    }
    let model_total: f64 = model.iter().sum();
    case.measured_total_gbs = meas.iter().sum();
    case.model_total_gbs = model_total;
    case.socket = mix
        .groups
        .iter()
        .enumerate()
        .map(|(gi, g)| GroupOutcome {
            kernel: g.kernel,
            n: g.cores,
            measured_bw_gbs: meas[gi],
            measured_per_core: if g.cores > 0 { meas[gi] / g.cores as f64 } else { 0.0 },
            model_bw_gbs: model[gi],
            model_per_core: if g.cores > 0 { model[gi] / g.cores as f64 } else { 0.0 },
            model_alpha: if model_total > 0.0 { model[gi] / model_total } else { 0.0 },
        })
        .collect();
}

/// The multi-interface variant of [`run_mixes_on`], taken when any group
/// carries a `%r` suffix or classifies cache- or compute-bound.
///
/// **Model**: one [`share_remote`] evaluation per mix — every memory
/// interface and every inter-socket link runs the generalized Eqs. (4)+(5)
/// water-fill over the traffic portions it carries, and a group's per-core
/// bandwidth is gated by its slowest portion (lockstep streams).
///
/// **Measurement**: one *multi-interface* simulation per mix
/// ([`run_net_engine`] on [`IfaceNet::of_topology`]): every resident core
/// is one routed stream whose portions mirror the model's expansion, the
/// engine water-fills every memory interface *and* every inter-socket
/// link directly, and each core is gated by its slowest portion inside
/// the engine. Per-link rows therefore report **simulated** link traffic
/// (lines that actually crossed), not offered demand. Mixes fan out over
/// the same worker pool as the all-local pipeline. Not available on the
/// PJRT engine, whose artifact has a fixed single-interface geometry.
fn run_mixes_on_remote(
    topo: &Topology,
    placement: Placement,
    mixes: &[Mix],
    engine: &MeasureEngine,
) -> Result<TopoMixResultSet> {
    if matches!(engine, MeasureEngine::Pjrt(_)) {
        return Err(crate::error::Error::InvalidPlan(
            "remote-access and cache-/compute-bound mixes need an in-process \
             engine (fluid or des); the PJRT artifact has a fixed \
             single-interface geometry"
                .into(),
        ));
    }
    let eng = engine.inproc().expect("PJRT rejected above");
    // split validates capacity, pins, and the >= 2 domains remote rule.
    let splits: Vec<SplitMix> =
        mixes.iter().map(|mx| placement.split(topo, mx)).collect::<Result<_>>()?;
    let mut kernels: Vec<KernelId> = mixes.iter().flat_map(|m| m.kernels()).collect();
    kernels.sort_by_key(|k| k.key());
    kernels.dedup();
    // Derived rows carry their own cache fingerprint, so the global cache
    // serves SNC and scaled bases without aliasing their parents.
    let base_chars = CharCache::global().characterize(&topo.base, &kernels, engine)?;
    let shape = topo.shape();
    let links = shape.links();
    let net = IfaceNet::of_topology(topo);

    struct Resident {
        domain: usize,
        origin: usize,
        spec: GroupSpec,
        /// Effective contention kind on the base machine (domains scale
        /// memory bandwidth only; L3 and core rates are base properties).
        kind: GroupKind,
    }

    /// One mix's model evaluation plus its routed measurement streams.
    struct Prepared {
        residents: Vec<Resident>,
        share: crate::sharing::RemoteShare,
        streams: Vec<NetStream>,
        /// Resident index of each stream.
        stream_resident: Vec<usize>,
    }

    // Pass 1 (cheap, serial): the analytic evaluation and the stream lists.
    let mut prepared: Vec<Prepared> = Vec::with_capacity(mixes.len());
    for split in &splits {
        // Resident sub-groups in (domain, sub-mix) order.
        let mut residents: Vec<Resident> = Vec::new();
        for dm in &split.domains {
            for (sg, &origin) in dm.mix.groups.iter().zip(&dm.origin) {
                let kind = effective_kind(sg, &topo.base)?;
                residents.push(Resident { domain: dm.domain, origin, spec: *sg, kind });
            }
        }
        let groups: Vec<RemoteGroup> = residents
            .iter()
            .map(|r| {
                let c = base_chars[&r.spec.kernel];
                RemoteGroup {
                    home: r.domain,
                    n: r.spec.cores,
                    f: c.f,
                    bs_gbs: c.bs_gbs,
                    remote_frac: r.spec.remote_frac(),
                    kind: r.kind,
                }
            })
            .collect();
        let share = share_remote(&shape, &groups)?;
        // Every resident core is one stream homed on its domain; its
        // intrinsic demand comes from the home domain's (possibly scaled)
        // machine row, exactly as on the all-local per-domain path. An
        // L3-resident group's stream instead carries its L2-miss line rate
        // with the surviving fraction `1 - mem/l3` stopping at the shared
        // L3 (the tandem expansion in `simulator::network::route_streams`);
        // a compute-bound group's stream keeps its (low) intrinsic memory
        // demand — the engine grants a non-saturating demand in full, which
        // is exactly the model's "capped at the core-bound rate" claim.
        let mut streams: Vec<NetStream> = Vec::new();
        let mut stream_resident: Vec<usize> = Vec::new();
        for (ri, r) in residents.iter().enumerate() {
            let dmach = &topo.domains[r.domain].machine;
            let sig = kernel(r.spec.kernel);
            let mut w = CoreWorkload::from_kernel(&sig, dmach, ri);
            let mut l3_frac = 0.0;
            if matches!(r.kind, GroupKind::L3 { .. }) {
                let p = crate::ecm::predict(&sig, dmach);
                w.demand_lines_per_cy = sig.l3.total() as f64 / p.t_ecm;
                l3_frac = 1.0 - sig.mem.total() as f64 / sig.l3.total() as f64;
            }
            for _ in 0..r.spec.cores {
                streams.push(NetStream {
                    workload: w,
                    home: r.domain,
                    remote_frac: r.spec.remote_frac(),
                    l3_frac,
                });
                stream_resident.push(ri);
            }
        }
        prepared.push(Prepared { residents, share, streams, stream_resident });
    }

    // Pass 2: one multi-interface engine run per mix, batch-parallel.
    let sims = par_map(&prepared, |p| run_net_engine(&net, &p.streams, eng));

    // Pass 3: compose the per-domain, per-link, and socket records.
    let mut cases = Vec::with_capacity(mixes.len());
    for ((mx, split), (prep, sim)) in
        mixes.iter().zip(&splits).zip(prepared.iter().zip(&sims))
    {
        let Prepared { residents, share, stream_resident, .. } = prep;

        // Aggregate the engine's per-core portion drains onto the model's
        // portion list (both sides enumerate portions in the same routing
        // order: home first, then remote targets in domain order). The key
        // carries the memory-stage flag because an L3-resident group owns
        // *two* portions on the same (group, target) pair: the L3-level
        // portion (`mem == false`) and the tandem continuation that drains
        // against the home memory controller (`mem == true`). Compute-bound
        // groups have no model portions at all, so their simulated drain
        // maps onto nothing and is reported per-stream only.
        let mut portion_index: HashMap<(usize, usize, bool), usize> = HashMap::new();
        for (p, portion) in share.portions.iter().enumerate() {
            portion_index.insert((portion.group, portion.target, portion.mem), p);
        }
        let mut portion_meas = vec![0.0f64; share.portions.len()];
        for (pi, np) in sim.portions.iter().enumerate() {
            let ri = stream_resident[np.stream];
            if let Some(&p) = portion_index.get(&(ri, np.target, np.mem)) {
                portion_meas[p] += sim.per_portion_gbs[pi];
            }
        }

        // Per-core lockstep rates straight from the engine (slowest portion
        // gates each core; the model applies the identical rule inside
        // share_remote), averaged over each resident group's cores.
        let mut meas_pc = vec![0.0f64; residents.len()];
        for (si, &ri) in stream_resident.iter().enumerate() {
            meas_pc[ri] += sim.per_stream_gbs[si];
        }
        for (pc, r) in meas_pc.iter_mut().zip(residents) {
            if r.spec.cores > 0 {
                *pc /= r.spec.cores as f64;
            }
        }

        // Per-domain results: every domain with resident groups *or*
        // incoming remote traffic appears, so a saturated visitor-only
        // interface is not invisible in the report (its resident table is
        // just empty).
        let mut domain_ids = Vec::new();
        let mut domains_out = Vec::new();
        let mut origins_out = Vec::new();
        for dm in &split.domains {
            let d = dm.domain;
            if dm.mix.active_cores() == 0 && share.domains[d].demand_gbs == 0.0 {
                continue;
            }
            let ridx: Vec<usize> =
                (0..residents.len()).filter(|&ri| residents[ri].domain == d).collect();
            let model_domain_total: f64 = ridx.iter().map(|&ri| share.group_bw_gbs[ri]).sum();
            let mut outcomes = Vec::with_capacity(ridx.len());
            let mut meas_total = 0.0f64;
            let mut model_total = 0.0f64;
            for &ri in &ridx {
                let r = &residents[ri];
                let mbw = meas_pc[ri] * r.spec.cores as f64;
                meas_total += mbw;
                model_total += share.group_bw_gbs[ri];
                outcomes.push(GroupOutcome {
                    kernel: r.spec.kernel,
                    n: r.spec.cores,
                    measured_bw_gbs: mbw,
                    measured_per_core: meas_pc[ri],
                    model_bw_gbs: share.group_bw_gbs[ri],
                    model_per_core: share.per_core_gbs[ri],
                    model_alpha: if model_domain_total > 0.0 {
                        share.group_bw_gbs[ri] / model_domain_total
                    } else {
                        0.0
                    },
                });
            }
            domain_ids.push(d);
            domains_out.push(MixResult {
                machine: topo.base.id,
                mix: dm.mix.clone(),
                groups: outcomes,
                measured_total_gbs: meas_total,
                model_total_gbs: model_total,
                b_mix_gbs: share.domains[d].b_mix_gbs,
                saturated: share.domains[d].saturated,
            });
            origins_out.push(dm.origin.clone());
        }

        // Per-link records, aggregated by socket-level group.
        let mut link_results: Vec<LinkResult> = Vec::new();
        for (li, &(a, b)) in links.iter().enumerate() {
            let pidx: Vec<usize> = (0..share.portions.len())
                .filter(|&p| share.portions[p].link == Some(li))
                .collect();
            if pidx.is_empty() {
                continue;
            }
            let k = mx.groups.len();
            let mut meas = vec![0.0f64; k];
            let mut model = vec![0.0f64; k];
            let mut cores = vec![0usize; k];
            let mut counted = vec![false; residents.len()];
            for &p in &pidx {
                let portion = &share.portions[p];
                let ri = portion.group;
                let origin = residents[ri].origin;
                meas[origin] += portion_meas[p];
                model[origin] += portion.granted_bw_gbs;
                if !counted[ri] {
                    counted[ri] = true;
                    cores[origin] += residents[ri].spec.cores;
                }
            }
            let meas_total: f64 = meas.iter().sum();
            let model_total: f64 = model.iter().sum();
            let mut groups_out = Vec::new();
            let mut origins = Vec::new();
            for gi in 0..k {
                if cores[gi] == 0 {
                    continue;
                }
                groups_out.push(GroupOutcome {
                    kernel: mx.groups[gi].kernel,
                    n: cores[gi],
                    measured_bw_gbs: meas[gi],
                    measured_per_core: meas[gi] / cores[gi] as f64,
                    model_bw_gbs: model[gi],
                    model_per_core: model[gi] / cores[gi] as f64,
                    model_alpha: if model_total > 0.0 { model[gi] / model_total } else { 0.0 },
                });
                origins.push(gi);
            }
            link_results.push(LinkResult {
                sockets: (a, b),
                link_bw_gbs: shape.link_capacity_gbs((a, b)),
                groups: groups_out,
                origins,
                measured_total_gbs: meas_total,
                model_total_gbs: model_total,
                saturated: share.links[li].saturated,
            });
        }

        // Per-shared-L3 records, aggregated by socket-level group. In the
        // tandem model *all* of an L3-resident group's L2-miss lines cross
        // its home socket's shared L3 (the L3-resident fraction stops
        // there, the rest continues to memory), so the measured column is
        // the group's full simulated L3-level drain and the model column
        // its achieved L3-level bandwidth from the fixed point.
        let mut l3_results: Vec<L3Result> = Vec::new();
        let n_sockets = shape.socket_of.iter().copied().max().map_or(0, |s| s + 1);
        for s in 0..n_sockets {
            let pidx: Vec<usize> = (0..share.portions.len())
                .filter(|&p| share.portions[p].l3 == Some(s) && !share.portions[p].mem)
                .collect();
            if pidx.is_empty() {
                continue;
            }
            let k = mx.groups.len();
            let mut meas = vec![0.0f64; k];
            let mut model = vec![0.0f64; k];
            let mut cores = vec![0usize; k];
            for &p in &pidx {
                let ri = share.portions[p].group;
                let origin = residents[ri].origin;
                meas[origin] += meas_pc[ri] * residents[ri].spec.cores as f64;
                model[origin] += share.group_bw_gbs[ri];
                cores[origin] += residents[ri].spec.cores;
            }
            let meas_total: f64 = meas.iter().sum();
            let model_total: f64 = model.iter().sum();
            let mut groups_out = Vec::new();
            let mut origins = Vec::new();
            for gi in 0..k {
                if cores[gi] == 0 {
                    continue;
                }
                groups_out.push(GroupOutcome {
                    kernel: mx.groups[gi].kernel,
                    n: cores[gi],
                    measured_bw_gbs: meas[gi],
                    measured_per_core: meas[gi] / cores[gi] as f64,
                    model_bw_gbs: model[gi],
                    model_per_core: model[gi] / cores[gi] as f64,
                    model_alpha: if model_total > 0.0 { model[gi] / model_total } else { 0.0 },
                });
                origins.push(gi);
            }
            l3_results.push(L3Result {
                socket: s,
                l3_bw_gbs: shape.l3_bw_gbs,
                groups: groups_out,
                origins,
                measured_total_gbs: meas_total,
                model_total_gbs: model_total,
                saturated: share.l3[s].saturated,
            });
        }

        let mut case = TopoMixResult {
            machine: topo.base.id,
            topology: topo.label(),
            placement: placement.name(),
            mix: mx.clone(),
            domain_ids,
            domains: domains_out,
            origins: origins_out,
            socket: Vec::new(),
            links: link_results,
            l3: l3_results,
            measured_total_gbs: 0.0,
            model_total_gbs: 0.0,
            remote_converged: Some(share.converged),
        };
        aggregate_socket(&mut case, mx);
        cases.push(case);
    }
    Ok(TopoMixResultSet { cases })
}

/// Run every phase of a scenario on a topology (batched through
/// [`run_mixes_on`]).
pub fn run_scenario_on(
    topo: &Topology,
    placement: Placement,
    scenario: &Scenario,
    engine: &MeasureEngine,
) -> Result<TopoScenarioResult> {
    let rs = run_mixes_on(topo, placement, &scenario.mixes, engine)?;
    Ok(TopoScenarioResult {
        name: scenario.name.clone(),
        machine: topo.base.id,
        topology: topo.label(),
        phases: rs.cases,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};

    #[test]
    fn three_group_mix_measures_and_predicts() {
        let m = machine(MachineId::Rome);
        let mix = Mix::parse("dcopy:3+ddot2:3+stream:2").unwrap();
        let rs = run_mixes(&m, std::slice::from_ref(&mix), &MeasureEngine::Fluid).unwrap();
        let r = &rs.cases[0];
        assert_eq!(r.groups.len(), 3);
        assert!(r.measured_total_gbs > 0.0);
        assert!(r.model_total_gbs > 0.0);
        let alpha_sum: f64 = r.groups.iter().map(|g| g.model_alpha).sum();
        assert!((alpha_sum - 1.0).abs() < 1e-9);
        for g in &r.groups {
            assert!(g.error() < 0.08, "{:?}: err {}", g.kernel, g.error());
        }
    }

    #[test]
    fn idle_cores_leave_bandwidth_to_active_groups() {
        let m = machine(MachineId::Bdw1);
        let contended = Mix::parse("dcopy:3+ddot2:3+stream:4").unwrap();
        let idle = Mix::parse("dcopy:3+ddot2:3+idle:4").unwrap();
        let rs = run_mixes(&m, &[contended, idle], &MeasureEngine::Fluid).unwrap();
        for g in 0..2 {
            assert!(
                rs.cases[1].groups[g].measured_per_core > rs.cases[0].groups[g].measured_per_core,
                "group {g} should speed up when the third group idles"
            );
        }
    }

    #[test]
    fn batched_run_matches_individual_runs() {
        let m = machine(MachineId::Rome);
        let mixes = vec![
            Mix::parse("dcopy:4+ddot2:4").unwrap(),
            Mix::parse("stream:2+vecsum:2+idle:4").unwrap(),
            Mix::parse("daxpy:8").unwrap(),
        ];
        let batched = run_mixes(&m, &mixes, &MeasureEngine::Fluid).unwrap();
        for (i, mix) in mixes.iter().enumerate() {
            let solo = run_mixes(&m, std::slice::from_ref(mix), &MeasureEngine::Fluid).unwrap();
            for (a, b) in batched.cases[i].groups.iter().zip(&solo.cases[0].groups) {
                assert_eq!(a.measured_per_core.to_bits(), b.measured_per_core.to_bits());
                assert_eq!(a.model_per_core.to_bits(), b.model_per_core.to_bits());
            }
        }
    }

    #[test]
    fn invalid_mix_rejected_before_measurement() {
        let m = machine(MachineId::Rome);
        let overfull = Mix::parse("dcopy:6+ddot2:6").unwrap();
        assert!(run_mixes(&m, &[overfull], &MeasureEngine::Fluid).is_err());
    }

    #[test]
    fn single_domain_topology_matches_flat_pipeline_bitwise() {
        let m = machine(MachineId::Rome);
        let topo = Topology::single(&m);
        let mixes = vec![
            Mix::parse("dcopy:4+ddot2:4").unwrap(),
            Mix::parse("stream:2+vecsum:2+idle:4").unwrap(),
        ];
        let flat = run_mixes(&m, &mixes, &MeasureEngine::Fluid).unwrap();
        for placement in [Placement::Compact, Placement::Scatter] {
            let topod = run_mixes_on(&topo, placement, &mixes, &MeasureEngine::Fluid).unwrap();
            for (t, f) in topod.cases.iter().zip(&flat.cases) {
                assert_eq!(t.domain_ids, vec![0]);
                assert_eq!(t.domains[0].groups.len(), f.groups.len());
                for (a, b) in t.domains[0].groups.iter().zip(&f.groups) {
                    assert_eq!(a.measured_per_core.to_bits(), b.measured_per_core.to_bits());
                    assert_eq!(a.model_per_core.to_bits(), b.model_per_core.to_bits());
                    assert_eq!(a.model_alpha.to_bits(), b.model_alpha.to_bits());
                }
                // Socket aggregate of one domain is that domain.
                for (a, b) in t.socket.iter().zip(&f.groups) {
                    assert_eq!(a.measured_bw_gbs.to_bits(), b.measured_bw_gbs.to_bits());
                }
            }
        }
    }

    #[test]
    fn pinned_domains_are_modeled_independently() {
        use crate::sharing::{share_multigroup, KernelGroup};
        let m = machine(MachineId::Rome);
        let topo = Topology::socket(&m); // 4 domains x 8 cores
        let mix = Mix::parse("dcopy:4@d0+ddot2:4@d0+stream:4@d1+daxpy:4@d1").unwrap();
        let rs = run_mixes_on(&topo, Placement::Compact, &[mix], &MeasureEngine::Fluid).unwrap();
        let case = &rs.cases[0];
        assert_eq!(case.domain_ids, vec![0, 1]);
        // Each domain's shares are exactly Eq. 5 over that domain's groups.
        let get = |k| {
            crate::scenario::CharCache::global()
                .lookup(&(m.fingerprint(), k, EngineKind::Fluid))
                .expect("characterized by run_mixes_on")
        };
        for (dr, wanted) in case.domains.iter().zip([
            [KernelId::Dcopy, KernelId::Ddot2],
            [KernelId::Stream, KernelId::Daxpy],
        ]) {
            let groups: Vec<KernelGroup> = wanted
                .iter()
                .map(|&k| {
                    let c = get(k);
                    KernelGroup { n: 4, f: c.f, bs_gbs: c.bs_gbs }
                })
                .collect();
            let direct = share_multigroup(&groups);
            for (g, e) in dr.groups.iter().zip(&direct.groups) {
                assert!(
                    (g.model_alpha - e.alpha).abs() < 1e-12,
                    "{:?}: alpha {} vs {}",
                    g.kernel,
                    g.model_alpha,
                    e.alpha
                );
            }
        }
    }

    #[test]
    fn scaled_domain_scales_model_bandwidth() {
        let m = machine(MachineId::Rome);
        let nominal = Topology::build(&m, 1, 2, &[1.0, 1.0]).unwrap();
        let scaled = Topology::build(&m, 1, 2, &[1.0, 0.5]).unwrap();
        let mix = Mix::parse("dcopy:8@d0+dcopy:8@d1").unwrap();
        let a = run_mixes_on(&nominal, Placement::Compact, &[mix.clone()], &MeasureEngine::Fluid)
            .unwrap();
        let b =
            run_mixes_on(&scaled, Placement::Compact, &[mix], &MeasureEngine::Fluid).unwrap();
        // Domain 0 is identical; domain 1's saturated model bandwidth halves.
        let (a0, b0) = (&a.cases[0].domains[0], &b.cases[0].domains[0]);
        assert_eq!(a0.groups[0].model_bw_gbs.to_bits(), b0.groups[0].model_bw_gbs.to_bits());
        let (a1, b1) = (&a.cases[0].domains[1], &b.cases[0].domains[1]);
        assert!(
            (b1.groups[0].model_bw_gbs - 0.5 * a1.groups[0].model_bw_gbs).abs() < 1e-9,
            "halved domain: {} vs {}",
            b1.groups[0].model_bw_gbs,
            a1.groups[0].model_bw_gbs
        );
        // And the measured bandwidth drops too (the simulator sees the
        // scaled memory interface).
        assert!(b1.groups[0].measured_bw_gbs < 0.6 * a1.groups[0].measured_bw_gbs);
    }
}
