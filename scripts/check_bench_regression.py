#!/usr/bin/env python3
"""Bench regression gate: compare a fresh `repro bench` run against the
committed baselines and fail on a >15% throughput drop.

Metrics (higher is better):

* ``BENCH_cosim.json``   — ``events_per_s`` of every co-sim variant and
  ``scenario.cases_per_s`` of the scenario sweep;
* ``BENCH_multi_iface.json`` — ``cases_per_s`` of the multi-interface
  pipeline and of its single-interface baseline sweep;
* ``BENCH_cache.json`` — ``cases_per_s`` of the cache-topology pipeline
  (shared-L3 ``@l3`` mixes next to DRAM-bound streams);
* ``BENCH_cluster.json`` — ``events_per_s`` of the 64-node cluster co-sim
  and its ``speedup_vs_full`` over the full-recompute rating reference
  (a drop in either means the incremental path lost its edge);
* ``BENCH_optimizer.json`` — ``evaluations_per_s`` of the placement
  optimizer's delta + parallel + memo search and its ``speedup_vs_full``
  over the sequential full-re-solve baseline;
* ``BENCH_serve.json`` — ``requests_per_s`` of the streaming
  co-scheduling service's warm session replay and its
  ``speedup_vs_cold`` over per-request cold ``repro optimize`` runs
  (a drop means incremental admission or the shared memo lost its edge).

Usage::

    # gate (CI): compare results/ against benchmarks/baselines/
    python3 scripts/check_bench_regression.py \
        --results results --baselines benchmarks/baselines \
        --report results/BENCH_regression_report.json

    # refresh the baselines from a trusted run, then commit them
    python3 scripts/check_bench_regression.py --results results \
        --baselines benchmarks/baselines --update

Behaviour:

* missing baseline files (fresh clone, first run) → SKIP with exit 0, so
  the gate is safe to wire into CI before baselines are committed;
* a ``mode`` mismatch (``smoke`` vs ``full``) between run and baseline →
  SKIP that file (the two modes are not comparable);
* speed-ups are reported but never fail;
* the comparison report is written as JSON (``--report``) so CI can
  upload it as an artifact next to the bench output itself.

Wall-clock noise on shared CI runners is real; the 15% threshold is
deliberately loose — it catches algorithmic regressions (an accidental
O(n^2), a lost cache), not scheduler jitter.
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

# >15% slower than the committed baseline fails the gate.
THRESHOLD = 0.15

GATED_FILES = [
    "BENCH_cosim.json",
    "BENCH_multi_iface.json",
    "BENCH_cache.json",
    "BENCH_cluster.json",
    "BENCH_optimizer.json",
    "BENCH_serve.json",
]


def metrics_of(name: str, doc: dict) -> dict[str, float]:
    """Flatten one bench JSON into {metric key: throughput}."""
    out: dict[str, float] = {}
    if name == "BENCH_cosim.json":
        for row in doc.get("cosim", []):
            out[f"cosim[{row['variant']}].events_per_s"] = float(row["events_per_s"])
        if "scenario" in doc:
            out["scenario.cases_per_s"] = float(doc["scenario"]["cases_per_s"])
    elif name == "BENCH_multi_iface.json":
        out["multi_iface.cases_per_s"] = float(doc["multi_iface"]["cases_per_s"])
        out["single_iface_baseline.cases_per_s"] = float(
            doc["single_iface_baseline"]["cases_per_s"]
        )
    elif name == "BENCH_cache.json":
        out["cache.cases_per_s"] = float(doc["cache"]["cases_per_s"])
    elif name == "BENCH_cluster.json":
        out["cluster.events_per_s"] = float(doc["cluster"]["events_per_s"])
        out["cluster.speedup_vs_full"] = float(doc["cluster"]["speedup_vs_full"])
    elif name == "BENCH_optimizer.json":
        out["optimizer.evaluations_per_s"] = float(doc["optimizer"]["evaluations_per_s"])
        out["optimizer.speedup_vs_full"] = float(doc["optimizer"]["speedup_vs_full"])
    elif name == "BENCH_serve.json":
        out["serve.requests_per_s"] = float(doc["serve"]["requests_per_s"])
        out["serve.speedup_vs_cold"] = float(doc["serve"]["speedup_vs_cold"])
    return out


def compare(results_dir: Path, baselines_dir: Path) -> tuple[list[dict], list[str]]:
    """Return (per-metric comparison rows, skip notes)."""
    rows: list[dict] = []
    skipped: list[str] = []
    for name in GATED_FILES:
        cur_path = results_dir / name
        base_path = baselines_dir / name
        if not cur_path.exists():
            skipped.append(f"{name}: no fresh result at {cur_path} (run `repro bench` first)")
            continue
        if not base_path.exists():
            skipped.append(
                f"{name}: no committed baseline at {base_path} (seed with --update)"
            )
            continue
        cur = json.loads(cur_path.read_text())
        base = json.loads(base_path.read_text())
        if cur.get("mode") != base.get("mode"):
            skipped.append(
                f"{name}: mode mismatch (run {cur.get('mode')!r} vs baseline "
                f"{base.get('mode')!r}) — not comparable"
            )
            continue
        cur_m = metrics_of(name, cur)
        base_m = metrics_of(name, base)
        for key in sorted(base_m):
            if key not in cur_m:
                skipped.append(f"{name}: metric {key} gone from the fresh run")
                continue
            b, c = base_m[key], cur_m[key]
            ratio = c / b if b > 0 else float("inf")
            rows.append(
                {
                    "file": name,
                    "metric": key,
                    "baseline": b,
                    "current": c,
                    "ratio": ratio,
                    "regressed": ratio < 1.0 - THRESHOLD,
                }
            )
    return rows, skipped


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results", type=Path, default=Path("results"))
    ap.add_argument("--baselines", type=Path, default=Path("benchmarks/baselines"))
    ap.add_argument("--report", type=Path, default=None, help="write comparison JSON here")
    ap.add_argument(
        "--update",
        action="store_true",
        help="copy the fresh results over the baselines instead of gating",
    )
    args = ap.parse_args()

    if args.update:
        args.baselines.mkdir(parents=True, exist_ok=True)
        copied = []
        for name in GATED_FILES:
            src = args.results / name
            if src.exists():
                shutil.copyfile(src, args.baselines / name)
                copied.append(name)
        if not copied:
            print(f"nothing to update: no bench JSON under {args.results}")
            return 1
        print(f"baselines refreshed from {args.results}: {', '.join(copied)}")
        return 0

    rows, skipped = compare(args.results, args.baselines)

    for note in skipped:
        print(f"SKIP  {note}")
    regressions = [r for r in rows if r["regressed"]]
    for r in rows:
        tag = "FAIL" if r["regressed"] else "ok  "
        print(
            f"{tag}  {r['file']} {r['metric']}: {r['current']:.1f} vs "
            f"baseline {r['baseline']:.1f} ({(r['ratio'] - 1.0) * 100:+.1f}%)"
        )

    if args.report is not None:
        args.report.parent.mkdir(parents=True, exist_ok=True)
        args.report.write_text(
            json.dumps(
                {
                    "threshold": THRESHOLD,
                    "comparisons": rows,
                    "skipped": skipped,
                    "regressions": len(regressions),
                },
                indent=2,
            )
            + "\n"
        )
        print(f"report written to {args.report}")

    if regressions:
        print(
            f"{len(regressions)} metric(s) regressed by more than "
            f"{THRESHOLD:.0%} — failing the gate"
        )
        return 1
    if not rows:
        print("no comparable metrics (baselines not seeded yet) — gate passes vacuously")
    return 0


if __name__ == "__main__":
    sys.exit(main())
