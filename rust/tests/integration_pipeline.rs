//! Integration: the full config → sweep → report pipeline on the in-process
//! engines, plus the headline paper claims end to end.

use membw::config::{machine, MachineId};
use membw::kernels::{pairing_set, KernelId};
use membw::report::{table1_report, table2_report, ExperimentCtx};
use membw::stats::ErrorStats;
use membw::sweep::{full_domain_splits, pairing_cases, run_cases, symmetric_splits, MeasureEngine};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("membw-int-{tag}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Fig. 6 headline behaviour, DCOPY+DDOT2 on every machine:
/// DCOPY (higher f) takes a growing share as its thread count rises, and
/// the overall bandwidth decreases (DCOPY's b_s is lower than DDOT2's).
#[test]
fn fig6_dcopy_ddot2_shape_on_all_machines() {
    for mid in MachineId::ALL {
        let m = machine(mid);
        let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
        let rs = run_cases(&m, &cases, &MeasureEngine::Fluid).unwrap();
        let first = &rs.cases[0]; // 1 DCOPY core
        let last = rs.cases.last().unwrap(); // cores-1 DCOPY cores
        assert!(
            last.measured_total < first.measured_total,
            "{mid:?}: overall bandwidth must decrease as DCOPY grows ({} -> {})",
            first.measured_total,
            last.measured_total
        );
        // DCOPY per-core bandwidth always above DDOT2's (higher f).
        for c in &rs.cases {
            assert!(
                c.measured_per_core[0] > c.measured_per_core[1],
                "{mid:?} at {:?}: DCOPY per-core below DDOT2",
                c.n
            );
        }
    }
}

/// Fig. 8 headline: global error of the analytic model vs the fluid
/// measurement stays below the paper's 8% bound (we sample a subset of
/// pairings per machine to keep the test fast; the full sweep runs in
/// `examples/e2e_validation.rs` and `benches/bench_fig8_fig9.rs`).
#[test]
fn fig8_error_band_subset() {
    let set = pairing_set();
    let pairs = pairing_cases(&set, false);
    let mut errors = Vec::new();
    for mid in MachineId::ALL {
        let m = machine(mid);
        for (i, &(k1, k2)) in pairs.iter().enumerate() {
            if i % 5 != 0 {
                continue; // sample every 5th pairing
            }
            let cases = symmetric_splits(&m, k1, k2);
            let rs = run_cases(&m, &cases, &MeasureEngine::Fluid).unwrap();
            errors.extend(rs.all_errors());
        }
    }
    let stats = ErrorStats::of(&errors);
    assert!(stats.n > 100, "sample too small: {}", stats.n);
    assert!(stats.max < 0.08, "max error {:.3} exceeds the paper bound", stats.max);
    assert!(stats.frac_below_5pct > 0.75, "fewer than 75% below 5%");
}

/// Fig. 9 headline: whether a kernel gains or loses bandwidth against a
/// partner is decided by the f-ratio (Sect. V) — check the sign pattern for
/// DCOPY and DDOT2 partners on BDW-1.
#[test]
fn fig9_gain_loss_signs_follow_f_ratio() {
    let m = machine(MachineId::Bdw1);
    let half = m.cores / 2;
    let chars: Vec<(KernelId, f64)> = pairing_set()
        .iter()
        .map(|&k| {
            let c = membw::simulator::measure_f_bs(
                &membw::kernels::kernel(k),
                &m,
                membw::simulator::Engine::Fluid,
            );
            (k, c.f)
        })
        .collect();
    let f_of = |k: KernelId| chars.iter().find(|(id, _)| *id == k).unwrap().1;

    let probe = KernelId::Ddot2;
    for &partner in &[KernelId::Dcopy, KernelId::VecSum, KernelId::Schoenauer] {
        let self_case = membw::sweep::PairingCase { k1: probe, k2: probe, n1: half, n2: m.cores - half };
        let pair_case = membw::sweep::PairingCase { k1: probe, k2: partner, n1: half, n2: m.cores - half };
        let rs = run_cases(&m, &[self_case, pair_case], &MeasureEngine::Fluid).unwrap();
        let rel = rs.cases[1].measured_per_core[0] / rs.cases[0].measured_per_core[0];
        if f_of(partner) > f_of(probe) * 1.03 {
            assert!(rel < 1.0, "{partner:?} (higher f) should cost DDOT2 bandwidth (rel {rel})");
        } else if f_of(partner) < f_of(probe) * 0.97 {
            assert!(rel > 1.0, "{partner:?} (lower f) should give DDOT2 bandwidth (rel {rel})");
        }
    }
}

/// Report generation writes the promised files.
#[test]
fn reports_write_outputs() {
    let dir = tmp_dir("reports");
    let ctx = ExperimentCtx::fluid(dir.clone());
    let t1 = table1_report();
    assert!(t1.contains("TABLE I"));
    let t2 = table2_report(&ctx).unwrap();
    assert!(t2.contains("STREAM"));
    assert!(dir.join("table2.csv").exists());
    let csv = std::fs::read_to_string(dir.join("table2.csv")).unwrap();
    assert_eq!(csv.lines().count(), 1 + 15 * 4, "15 kernels x 4 machines + header");
}

/// The DES engine reproduces the same Fig. 6 shape as the fluid engine
/// (cross-engine consistency at the sweep level).
#[test]
fn des_fluid_sweep_consistency() {
    let m = machine(MachineId::Rome);
    let cases = full_domain_splits(&m, KernelId::Dcopy, KernelId::Ddot2);
    let fluid = run_cases(&m, &cases, &MeasureEngine::Fluid).unwrap();
    let des = run_cases(&m, &cases, &MeasureEngine::Des).unwrap();
    for (f, d) in fluid.cases.iter().zip(&des.cases) {
        let rel = (f.measured_total - d.measured_total).abs() / f.measured_total;
        assert!(rel < 0.08, "totals diverge at {:?}: {rel}", f.n);
    }
}
