//! Single-core ECM prediction: Eq. (1), the request fraction f (Eq. 2) and
//! derived bandwidths.

use crate::config::{Machine, OverlapKind};
use crate::ecm::application::ApplicationModel;
use crate::kernels::KernelSignature;

/// Full single-core ECM prediction of one kernel on one machine.
#[derive(Debug, Clone, Copy)]
pub struct EcmPrediction {
    /// The application-model contributions.
    pub app: ApplicationModel,
    /// Single-core runtime per unit (cycles), Eq. (1) with the machine's
    /// overlap rule.
    pub t_ecm: f64,
    /// Memory request fraction `f = T_Mem / T_ECM` (Eq. 2).
    pub f: f64,
    /// Predicted saturated bandwidth of the kernel on the full domain, GB/s.
    pub bs_gbs: f64,
    /// Predicted single-core memory bandwidth, GB/s (`b_1 = f * b_s`).
    pub b1_gbs: f64,
    /// Intrinsic single-core demand rate in lines/cycle (`mem_lines/T_ECM`)
    /// — the issue rate the simulator's cores are driven with.
    pub demand_lines_per_cy: f64,
    /// Service-cost factor of this kernel's line mix (1.0 = pure reads).
    pub cost_factor: f64,
}

/// Compose the ECM single-core runtime (Eq. 1).
///
/// * Intel (non-overlapping): `max(T_OL, T_L1Reg + ΣT_i + T_Mem + T_lat)`
/// * Rome (overlapping): `max(T_OL, T_L1Reg, T_L1L2, T_L2L3, T_Mem + T_lat)`
fn compose(m: &Machine, a: &ApplicationModel) -> f64 {
    match m.overlap {
        OverlapKind::NonOverlapping => a
            .t_ol
            .max(a.t_l1reg + a.t_l1l2 + a.t_l2l3 + a.t_mem + a.t_lat),
        OverlapKind::Overlapping => a
            .t_ol
            .max(a.t_l1reg)
            .max(a.t_l1l2)
            .max(a.t_l2l3)
            .max(a.t_mem + a.t_lat),
    }
}

/// Predict single-core behaviour of kernel `k` on machine `m`.
pub fn predict(k: &KernelSignature, m: &Machine) -> EcmPrediction {
    let app = ApplicationModel::new(k, m);
    let t_ecm = compose(m, &app);
    let f = app.t_mem / t_ecm;
    let bs_gbs = m.saturated_bw(app.write_frac, app.streams);
    let b1_gbs = f * bs_gbs;
    let demand_lines_per_cy = app.mem_lines / t_ecm;
    let cost_factor = m.cost_factor(app.write_frac, app.streams);
    EcmPrediction {
        app,
        t_ecm,
        f,
        bs_gbs,
        b1_gbs,
        demand_lines_per_cy,
        cost_factor,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::{kernel, pairing_set, KernelId};

    /// Paper Table II anchors for the STREAM triad (the fully legible row).
    #[test]
    fn stream_f_matches_paper_anchors() {
        let anchors = [
            (MachineId::Bdw1, 0.309),
            (MachineId::Bdw2, 0.228),
            (MachineId::Clx, 0.199),
            (MachineId::Rome, 0.838),
        ];
        for (id, want) in anchors {
            let p = predict(&kernel(KernelId::Stream), &machine(id));
            let err = (p.f - want).abs() / want;
            assert!(err < 0.06, "{id:?}: f = {:.3}, want {want}", p.f);
        }
    }

    /// Paper Sect. V: on Intel, f_DSCAL > f_DAXPY; on Rome, reversed.
    #[test]
    fn dscal_daxpy_ordering_reverses_on_rome() {
        for id in [MachineId::Bdw1, MachineId::Bdw2, MachineId::Clx] {
            let m = machine(id);
            let f_dscal = predict(&kernel(KernelId::Dscal), &m).f;
            let f_daxpy = predict(&kernel(KernelId::Daxpy), &m).f;
            assert!(f_dscal > f_daxpy, "{id:?}: {f_dscal} !> {f_daxpy}");
        }
        let rome = machine(MachineId::Rome);
        let f_dscal = predict(&kernel(KernelId::Dscal), &rome).f;
        let f_daxpy = predict(&kernel(KernelId::Daxpy), &rome).f;
        assert!(f_daxpy > f_dscal, "Rome: {f_daxpy} !> {f_dscal}");
    }

    /// Rome's overlapping hierarchy pushes f towards 1 for all kernels.
    #[test]
    fn rome_f_near_one() {
        let rome = machine(MachineId::Rome);
        for (_, k) in crate::kernels::all_kernels() {
            let p = predict(&k, &rome);
            assert!(p.f > 0.55, "{}: f = {}", k.name, p.f);
            assert!(p.f < 1.0, "{}: f = {}", k.name, p.f);
        }
    }

    /// Paper Sect. V: CLX shows less spread in f (2.4x) than BDW-1 (2.7x)
    /// across the pairing kernel set, and less spread in b_s (10% vs 20%).
    #[test]
    fn clx_spread_smaller_than_bdw1() {
        let spread = |mid: MachineId| -> (f64, f64) {
            let m = machine(mid);
            let preds: Vec<EcmPrediction> =
                pairing_set().iter().map(|&k| predict(&kernel(k), &m)).collect();
            let fmax = preds.iter().map(|p| p.f).fold(0.0, f64::max);
            let fmin = preds.iter().map(|p| p.f).fold(f64::MAX, f64::min);
            let bmax = preds.iter().map(|p| p.bs_gbs).fold(0.0, f64::max);
            let bmin = preds.iter().map(|p| p.bs_gbs).fold(f64::MAX, f64::min);
            (fmax / fmin, (bmax - bmin) / bmax)
        };
        let (f_bdw, b_bdw) = spread(MachineId::Bdw1);
        let (f_clx, b_clx) = spread(MachineId::Clx);
        assert!(f_clx < f_bdw, "f spread: CLX {f_clx} !< BDW-1 {f_bdw}");
        assert!(b_clx < b_bdw, "b_s spread: CLX {b_clx} !< BDW-1 {b_bdw}");
    }

    /// Stencil with violated L2 layer condition has a lower f than the
    /// LC-fulfilled variant (more intra-cache traffic, same memory traffic).
    #[test]
    fn layer_condition_reduces_f() {
        for id in [MachineId::Bdw1, MachineId::Bdw2, MachineId::Clx] {
            let m = machine(id);
            let f_l2 = predict(&kernel(KernelId::JacobiV1L2), &m).f;
            let f_l3 = predict(&kernel(KernelId::JacobiV1L3), &m).f;
            assert!(f_l3 < f_l2, "{id:?}: {f_l3} !< {f_l2}");
        }
    }

    #[test]
    fn b1_consistent_with_demand_rate() {
        let m = machine(MachineId::Bdw1);
        let p = predict(&kernel(KernelId::Ddot2), &m);
        let b1_from_demand = m.lines_per_cy_to_gbs(p.demand_lines_per_cy);
        assert!((b1_from_demand - p.b1_gbs).abs() / p.b1_gbs < 1e-9);
    }
}
