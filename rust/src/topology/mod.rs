//! Machine topology: sockets → ccNUMA domains → cores.
//!
//! The paper's contention unit is one ccNUMA memory domain (its Table I
//! describes exactly one), but its Rome testbed runs NPS4 — *four* such
//! domains per socket. A [`Topology`] makes that structure explicit: an
//! ordered list of [`Domain`]s, each a full contention domain (a
//! [`Machine`], possibly with a per-domain saturated-bandwidth scale for
//! asymmetric DIMM population), grouped into sockets. Contention is
//! evaluated *independently per domain* — that is the physical content of
//! "ccNUMA": a core only queues against its own domain's memory interface.
//!
//! The single-domain [`Topology::single`] is the degenerate case every
//! pre-topology entry point reduces to; conformance tests pin it
//! bit-identical to the legacy single-domain paths.
//!
//! [`placement`] holds the other half of the layer: how work lands on the
//! domains (compact / scatter / explicit `@dN` pinning) and the per-domain
//! splitting of workload mixes and rank sets.

mod placement;

pub use placement::{DomainMix, GroupPlacement, Placement, RankLayout, SplitMix};

use crate::config::Machine;
use crate::error::{Error, Result};

/// Upper bound on ccNUMA domains per topology (generous: the largest real
/// systems are well under 100 domains across all sockets).
pub const MAX_DOMAINS: usize = 1024;

/// One ccNUMA contention domain of a topology.
#[derive(Debug, Clone)]
pub struct Domain {
    /// Domain id, dense from 0 in socket order.
    pub id: usize,
    /// Socket the domain belongs to.
    pub socket: usize,
    /// Saturated-bandwidth scale relative to the machine's Table I row
    /// (1.0 = nominal; ≠ 1.0 models asymmetric DIMM population).
    pub bw_scale: f64,
    /// The domain as a machine model: the base machine with memory
    /// bandwidths scaled by `bw_scale`. Core count is per domain.
    pub machine: Machine,
}

/// A machine topology: an ordered list of ccNUMA domains grouped into
/// sockets, all instances of one base [`Machine`] row.
#[derive(Debug, Clone)]
pub struct Topology {
    /// The Table I row every domain instantiates.
    pub base: Machine,
    /// Number of sockets.
    pub sockets: usize,
    /// The domains, dense ids in socket order.
    pub domains: Vec<Domain>,
}

fn domain_machine(base: &Machine, bw_scale: f64) -> Machine {
    if bw_scale == 1.0 {
        return base.clone();
    }
    let mut m = base.clone();
    m.theor_bw_gbs *= bw_scale;
    m.read_bw_gbs *= bw_scale;
    m
}

impl Topology {
    /// Build a topology of `sockets` × `domains_per_socket` domains with
    /// per-domain bandwidth scales (`scales.len()` must equal the domain
    /// count; pass all-1.0 for nominal domains). At most [`MAX_DOMAINS`]
    /// domains — each domain clones a full [`Machine`], so an absurd CLI
    /// spec must fail cleanly instead of exhausting memory.
    pub fn build(base: &Machine, sockets: usize, domains_per_socket: usize, scales: &[f64]) -> Result<Self> {
        let nd = sockets
            .checked_mul(domains_per_socket)
            .filter(|&nd| nd <= MAX_DOMAINS)
            .ok_or_else(|| {
                Error::InvalidPlan(format!(
                    "topology of {sockets} x {domains_per_socket} domains exceeds the \
                     {MAX_DOMAINS}-domain limit"
                ))
            })?;
        if nd == 0 {
            return Err(Error::InvalidPlan("topology needs at least one domain".into()));
        }
        if scales.len() != nd {
            return Err(Error::InvalidPlan(format!(
                "topology has {nd} domains but {} bandwidth scales were given",
                scales.len()
            )));
        }
        for (d, &s) in scales.iter().enumerate() {
            if !(s.is_finite() && s > 0.0) {
                return Err(Error::InvalidPlan(format!("bad bandwidth scale {s} for domain d{d}")));
            }
        }
        let domains = scales
            .iter()
            .enumerate()
            .map(|(id, &bw_scale)| Domain {
                id,
                socket: id / domains_per_socket,
                bw_scale,
                machine: domain_machine(base, bw_scale),
            })
            .collect();
        Ok(Topology { base: base.clone(), sockets, domains })
    }

    /// The degenerate single-domain topology (the pre-topology model).
    pub fn single(base: &Machine) -> Self {
        Topology::build(base, 1, 1, &[1.0]).expect("1x1 topology is always valid")
    }

    /// One full socket: `base.domains_per_socket` nominal domains (4 on
    /// Rome NPS4, 1 on the Intel machines).
    pub fn socket(base: &Machine) -> Self {
        let dps = base.domains_per_socket.max(1);
        Topology::build(base, 1, dps, &vec![1.0; dps]).expect("socket topology is always valid")
    }

    /// `n` nominal domains on one socket (explicit domain count).
    pub fn with_domains(base: &Machine, n: usize) -> Result<Self> {
        Topology::build(base, 1, n, &vec![1.0; n])
    }

    /// Number of ccNUMA domains.
    pub fn n_domains(&self) -> usize {
        self.domains.len()
    }

    /// Total cores over all domains.
    pub fn total_cores(&self) -> usize {
        self.domains.iter().map(|d| d.machine.cores).sum()
    }

    /// The domain a core belongs to under the canonical dense core
    /// numbering (cores 0..c-1 in domain 0, then domain 1, ...).
    pub fn domain_of_core(&self, core: usize) -> Option<usize> {
        let mut offset = 0;
        for d in &self.domains {
            offset += d.machine.cores;
            if core < offset {
                return Some(d.id);
            }
        }
        None
    }

    /// Whether this is the degenerate pre-topology case: one nominal
    /// domain.
    pub fn is_single(&self) -> bool {
        self.domains.len() == 1 && self.domains[0].bw_scale == 1.0
    }

    /// Per-domain bandwidth scales, in domain order.
    pub fn bw_scales(&self) -> Vec<f64> {
        self.domains.iter().map(|d| d.bw_scale).collect()
    }

    /// Compact display label, e.g. `rome-1s4d` (1 socket × 4 domains).
    pub fn label(&self) -> String {
        format!(
            "{}-{}s{}d",
            self.base.id.key(),
            self.sockets,
            self.domains.len() / self.sockets.max(1)
        )
    }

    /// Parse a CLI topology spec against a base machine:
    ///
    /// * `domain` (or `single`) — one domain, the degenerate case;
    /// * `socket` — the machine's full socket (`domains_per_socket` domains);
    /// * `<D>` — D domains on one socket (e.g. `4`);
    /// * `<S>x<D>` — S sockets × D domains each (e.g. `2x4`);
    /// * an optional `@s0,s1,...` suffix with one saturated-bandwidth scale
    ///   per domain (e.g. `4@1,1,0.9,0.95`).
    pub fn parse(base: &Machine, spec: &str) -> Result<Self> {
        let spec = spec.trim();
        let (shape, scales_txt) = match spec.split_once('@') {
            Some((s, sc)) => (s.trim(), Some(sc.trim())),
            None => (spec, None),
        };
        let (sockets, dps) = match shape.to_ascii_lowercase().as_str() {
            "domain" | "single" => (1, 1),
            "socket" => (1, base.domains_per_socket.max(1)),
            other => {
                let parse_dim = |s: &str, what: &str| -> Result<usize> {
                    match s.trim().parse::<usize>() {
                        Ok(v) if v >= 1 => Ok(v),
                        _ => Err(Error::InvalidPlan(format!(
                            "bad {what} '{s}' in topology spec '{spec}' \
                             (expected: domain, socket, <D>, or <S>x<D>)"
                        ))),
                    }
                };
                match other.split_once('x') {
                    Some((s, d)) => (parse_dim(s, "socket count")?, parse_dim(d, "domain count")?),
                    None => (1, parse_dim(other, "domain count")?),
                }
            }
        };
        let nd = sockets * dps;
        let scales = match scales_txt {
            None => vec![1.0; nd],
            Some(txt) => txt
                .split(',')
                .map(|t| {
                    t.trim().parse::<f64>().map_err(|_| {
                        Error::InvalidPlan(format!(
                            "bad bandwidth scale '{t}' in topology spec '{spec}'"
                        ))
                    })
                })
                .collect::<Result<Vec<f64>>>()?,
        };
        Topology::build(base, sockets, dps, &scales)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};

    #[test]
    fn single_topology_is_degenerate() {
        let m = machine(MachineId::Clx);
        let t = Topology::single(&m);
        assert!(t.is_single());
        assert_eq!(t.n_domains(), 1);
        assert_eq!(t.total_cores(), m.cores);
        // The degenerate domain is the base machine, unscaled.
        assert_eq!(t.domains[0].machine.read_bw_gbs.to_bits(), m.read_bw_gbs.to_bits());
    }

    #[test]
    fn rome_socket_expands_to_nps4() {
        let m = machine(MachineId::Rome);
        let t = Topology::socket(&m);
        assert_eq!(t.n_domains(), 4);
        assert_eq!(t.total_cores(), 32);
        assert_eq!(t.label(), "rome-1s4d");
        for d in &t.domains {
            assert_eq!(d.socket, 0);
            assert_eq!(d.machine.cores, 8);
        }
        // Intel sockets stay monolithic.
        let clx = Topology::socket(&machine(MachineId::Clx));
        assert_eq!(clx.n_domains(), 1);
    }

    #[test]
    fn core_to_domain_mapping_is_dense() {
        let t = Topology::socket(&machine(MachineId::Rome));
        assert_eq!(t.domain_of_core(0), Some(0));
        assert_eq!(t.domain_of_core(7), Some(0));
        assert_eq!(t.domain_of_core(8), Some(1));
        assert_eq!(t.domain_of_core(31), Some(3));
        assert_eq!(t.domain_of_core(32), None);
    }

    #[test]
    fn bandwidth_scales_apply_per_domain() {
        let m = machine(MachineId::Rome);
        let t = Topology::build(&m, 1, 4, &[1.0, 1.0, 0.9, 0.5]).unwrap();
        assert!(!t.is_single());
        assert_eq!(t.domains[0].machine.read_bw_gbs.to_bits(), m.read_bw_gbs.to_bits());
        assert!((t.domains[2].machine.read_bw_gbs - 0.9 * m.read_bw_gbs).abs() < 1e-12);
        assert!((t.domains[3].machine.read_bw_gbs - 0.5 * m.read_bw_gbs).abs() < 1e-12);
        assert!(Topology::build(&m, 1, 4, &[1.0]).is_err(), "scale arity enforced");
        assert!(Topology::build(&m, 1, 4, &[1.0, 1.0, 0.0, 1.0]).is_err(), "positive scales");
    }

    #[test]
    fn parse_accepts_all_spec_forms() {
        let m = machine(MachineId::Rome);
        assert_eq!(Topology::parse(&m, "domain").unwrap().n_domains(), 1);
        assert_eq!(Topology::parse(&m, "single").unwrap().n_domains(), 1);
        assert_eq!(Topology::parse(&m, "socket").unwrap().n_domains(), 4);
        assert_eq!(Topology::parse(&m, "2").unwrap().n_domains(), 2);
        let two_socket = Topology::parse(&m, "2x4").unwrap();
        assert_eq!(two_socket.n_domains(), 8);
        assert_eq!(two_socket.sockets, 2);
        assert_eq!(two_socket.domains[4].socket, 1);
        let scaled = Topology::parse(&m, "4@1,1,0.9,0.95").unwrap();
        assert!((scaled.domains[3].bw_scale - 0.95).abs() < 1e-12);
        assert!(Topology::parse(&m, "0").is_err());
        assert!(Topology::parse(&m, "4@1,1").is_err());
        assert!(Topology::parse(&m, "fullmesh").is_err());
        // Absurd sizes fail cleanly (no allocation, no overflow).
        assert!(Topology::parse(&m, "1000000000x100").is_err());
        assert!(Topology::parse(&m, "2048").is_err());
    }
}
