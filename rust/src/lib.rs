//! # membw — bandwidth-sharing model reproduction
//!
//! Reproduction of Afzal, Hager, Wellein, *"An analytic performance model for
//! overlapping execution of memory-bound loop kernels on multicore CPUs"*
//! (2020).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * [`config`] — machine descriptions (the paper's Table I) and global
//!   experiment configuration,
//! * [`kernels`] — the loop-kernel substrate (Table II): stream signatures
//!   and layer-condition analysis,
//! * [`parallel`] — the dependency-free lock-free worker pool shared by
//!   the scenario pipeline and the component-parallel DES,
//! * [`ecm`] — the Execution-Cache-Memory model used by the paper to predict
//!   single-core runtime, the memory request fraction `f` (Eq. 2) and the
//!   multicore scaling behaviour,
//! * [`topology`] — machine topology (sockets → ccNUMA domains → cores)
//!   and work placement (compact / scatter / explicit `@dN` pinning): the
//!   layer that turns the paper's single contention domain into a full
//!   NPS4 Rome socket, a Sub-NUMA-Clustered Intel socket (`snc2`/`snc4`),
//!   or any multi-socket grid with explicit inter-socket links,
//! * [`sharing`] — **the paper's contribution**: the analytic
//!   bandwidth-sharing model (Eqs. 4–5) plus its multigroup generalization,
//!   the per-domain evaluation (`share_domains`), and the remote-access
//!   extension (`sharing::remote`: cache-line streams split over home
//!   domain, remote domains, and UPI/xGMI links),
//! * [`simulator`] — the measurement substrate: fluid-queueing and
//!   line-granularity discrete-event engines over a *network* of
//!   contention interfaces (per-domain memory controllers + inter-socket
//!   links; `docs/SIMULATORS.md`), standing in for the physical
//!   BDW/CLX/Rome machines of the paper,
//! * [`timeline`] — **the contention-timeline layer**: exact event-driven
//!   simulation of ranks sharing one memory domain (priority-queue core;
//!   closed-form constant-rate drains between events; zero `dt` error),
//! * [`desync`] — rank-level co-simulation of barrier-free MPI programs
//!   (HPCG), reproducing the desynchronization phenomenology of Figs. 1/3;
//!   a thin driver over [`timeline`],
//! * [`optimizer`] — the placement/co-schedule search engine built *on*
//!   the model: neighborhood search over home domains and remote
//!   fractions with incremental (bit-identical) delta re-rating, batched
//!   parallel scoring, and a sharded score memo (`docs/OPTIMIZER.md`),
//! * [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas batched
//!   simulator (`artifacts/*.hlo.txt`) and runs it from the hot path (gated
//!   behind the `pjrt` cargo feature; a stub fails gracefully without it),
//! * [`scenario`] — **the unified measurement pipeline**: arbitrary k-group
//!   workload mixes (kernel groups + idle cores) and time-phased scenarios,
//!   executed batched and parallel on any engine through the shared
//!   characterization cache, with the multigroup prediction attached,
//! * [`service`] — the streaming co-scheduling service behind
//!   `repro serve`: jobs submitted/retired over a line-delimited JSON
//!   protocol, admitted by *incremental but exact* residual search with
//!   periodic repacks, sharing one process-wide score memo and
//!   characterization cache, with a checkpoint-resumed makespan probe,
//! * [`sweep`] — pairing-sweep plans (the Fig. 4 parameter space) and the
//!   two-group runner, now the k=2 special case of [`scenario`],
//! * [`stats`] — descriptive statistics, error metrics, skewness,
//! * [`report`] — per-table/figure emitters (CSV + ASCII rendering), plus
//!   the k-group scenario share tables.
//!
//! See `README.md` for the crate tour, `docs/MODEL.md` for the
//! paper-to-code map (every equation with its implementing function), and
//! `docs/CLI.md` for the full `repro` command reference.

pub mod benchutil;
pub mod config;
pub mod desync;
pub mod ecm;
pub mod error;
pub mod kernels;
pub mod optimizer;
pub mod parallel;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod service;
pub mod sharing;
pub mod simulator;
pub mod stats;
pub mod sweep;
pub mod timeline;
pub mod topology;

pub use error::{Error, Result};

/// Bytes per cache line on every modeled architecture.
pub const CACHE_LINE_BYTES: f64 = 64.0;

/// Double-precision elements per cache line.
pub const ELEMS_PER_LINE: usize = 8;
