//! The shared contention-timeline layer: exact event-driven simulation of
//! ranks contending for one memory domain.
//!
//! The paper's co-simulation application (Sect. VI) observes that per-core
//! bandwidth is an *analytic* function of the instantaneous group
//! composition (generalized Eqs. 4+5). Between composition changes nothing
//! varies, so the simulation reduces to exactly four event families —
//! phase completions, collective releases, staggered starts, and noise
//! interruptions. Starts, noise, idle expiries, and releases live in a
//! priority queue; the next phase completion is a *closed-form* time under
//! the current composition and is simply compared against the queue head.
//! This eliminates the legacy stepper's `dt` discretization error entirely
//! and runs orders of magnitude faster (see `repro bench` /
//! `BENCH_cosim.json`).
//!
//! * [`event`] — the priority-queue event core (lazy invalidation),
//! * [`engine`] — the drained-bytes-integral simulation core
//!   ([`engine::simulate`]; [`engine::simulate_placed`] keys all
//!   contention state by ccNUMA domain, so a full NPS4 socket runs as
//!   concurrent per-domain timelines over one shared event queue).
//!
//! [`crate::desync::CoSimEngine`] is the user-facing driver over this
//! layer; the legacy stepper survives behind the `legacy-stepper` feature
//! (and in unit tests) as the golden reference.

pub mod event;
pub mod engine;

pub use engine::{simulate, simulate_placed};
pub use event::{Event, EventKind, EventQueue};
