//! Result records for k-group mixes: measured vs modeled bandwidth per
//! group, with CSV and JSON-lines emission (hand-rolled — the build is
//! offline).

use std::io::Write;
use std::path::Path;

use crate::config::MachineId;
use crate::error::Result;
use crate::kernels::KernelId;
use crate::scenario::spec::Mix;
use crate::stats::rel_error;

/// Outcome of one kernel group within a measured mix.
#[derive(Debug, Clone)]
pub struct GroupOutcome {
    /// Kernel of the group.
    pub kernel: KernelId,
    /// Cores in the group.
    pub n: usize,
    /// Measured aggregate bandwidth of the group, GB/s.
    pub measured_bw_gbs: f64,
    /// Measured per-core bandwidth, GB/s.
    pub measured_per_core: f64,
    /// Multigroup-model aggregate bandwidth, GB/s.
    pub model_bw_gbs: f64,
    /// Multigroup-model per-core bandwidth, GB/s.
    pub model_per_core: f64,
    /// Model bandwidth share α of the group (sums to 1 over groups).
    pub model_alpha: f64,
}

impl GroupOutcome {
    /// Relative per-core model error (the paper's Fig. 8 metric).
    pub fn error(&self) -> f64 {
        rel_error(self.measured_per_core, self.model_per_core)
    }
}

/// Outcome of one measured mix: per-group results plus totals.
#[derive(Debug, Clone)]
pub struct MixResult {
    /// Machine the mix ran on.
    pub machine: MachineId,
    /// The mix specification.
    pub mix: Mix,
    /// Per-group outcomes, in mix order.
    pub groups: Vec<GroupOutcome>,
    /// Measured aggregate bandwidth over all groups, GB/s.
    pub measured_total_gbs: f64,
    /// Modeled aggregate bandwidth, GB/s.
    pub model_total_gbs: f64,
    /// Overlapped saturated bandwidth (generalized Eq. 4), GB/s.
    pub b_mix_gbs: f64,
    /// Whether the model ran in the saturated regime.
    pub saturated: bool,
}

impl MixResult {
    /// Per-group relative errors (groups with zero cores are skipped).
    pub fn errors(&self) -> Vec<f64> {
        self.groups.iter().filter(|g| g.n > 0).map(|g| g.error()).collect()
    }

    /// Measured bandwidth share of group `gi`.
    pub fn measured_alpha(&self, gi: usize) -> f64 {
        if self.measured_total_gbs > 0.0 {
            self.groups[gi].measured_bw_gbs / self.measured_total_gbs
        } else {
            0.0
        }
    }

    /// CSV header matching [`MixResult::to_csv_rows`].
    pub fn csv_header() -> &'static str {
        "machine,mix,k,idle,group,kernel,n,meas_pc_gbs,model_pc_gbs,meas_bw_gbs,model_bw_gbs,alpha_meas,alpha_model,err"
    }

    /// One CSV row per group.
    pub fn to_csv_rows(&self) -> Vec<String> {
        self.groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                format!(
                    "{},{},{},{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.5},{:.5},{:.5}",
                    self.machine.key(),
                    self.mix.label(),
                    self.mix.k(),
                    self.mix.idle_cores,
                    gi,
                    g.kernel.key(),
                    g.n,
                    g.measured_per_core,
                    g.model_per_core,
                    g.measured_bw_gbs,
                    g.model_bw_gbs,
                    self.measured_alpha(gi),
                    g.model_alpha,
                    g.error(),
                )
            })
            .collect()
    }

    /// One JSON object per mix (hand-rolled).
    pub fn to_json(&self) -> String {
        let groups: Vec<String> = self
            .groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                format!(
                    "{{\"kernel\":\"{}\",\"n\":{},\"meas_pc\":{:.5},\"model_pc\":{:.5},\
                     \"alpha_meas\":{:.6},\"alpha_model\":{:.6},\"err\":{:.6}}}",
                    g.kernel.key(),
                    g.n,
                    g.measured_per_core,
                    g.model_per_core,
                    self.measured_alpha(gi),
                    g.model_alpha,
                    g.error(),
                )
            })
            .collect();
        format!(
            "{{\"machine\":\"{}\",\"mix\":\"{}\",\"idle\":{},\"saturated\":{},\
             \"meas_total\":{:.5},\"model_total\":{:.5},\"b_mix\":{:.5},\"groups\":[{}]}}",
            self.machine.key(),
            self.mix.label(),
            self.mix.idle_cores,
            self.saturated,
            self.measured_total_gbs,
            self.model_total_gbs,
            self.b_mix_gbs,
            groups.join(","),
        )
    }
}

/// A set of mix results with persistence helpers.
#[derive(Debug, Clone, Default)]
pub struct MixResultSet {
    /// All mix results, in input order.
    pub cases: Vec<MixResult>,
}

impl MixResultSet {
    /// All per-group relative errors, flattened.
    pub fn all_errors(&self) -> Vec<f64> {
        self.cases.iter().flat_map(|c| c.errors()).collect()
    }

    /// Write as CSV (one row per group).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", MixResult::csv_header())?;
        for c in &self.cases {
            for row in c.to_csv_rows() {
                writeln!(f, "{row}")?;
            }
        }
        Ok(())
    }

    /// Write as JSON lines (one object per mix).
    pub fn write_jsonl(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        for c in &self.cases {
            writeln!(f, "{}", c.to_json())?;
        }
        Ok(())
    }
}

/// Contention outcome of one *directed* inter-socket link interface under
/// a mix: the groups whose remote portions cross it in this direction,
/// with simulated traffic and modeled link grants. A full-duplex physical
/// link contributes two records, one per direction.
///
/// The multi-interface substrate simulates the link direction as a
/// contention interface of its own, so the measured columns are the
/// **simulated** link traffic — the lines that actually crossed, gated by
/// the link server — while the model columns come from the direction's
/// Eqs. (4)+(5) water-fill at `link_bw_gbs` capacity (see
/// `docs/SIMULATORS.md`).
#[derive(Debug, Clone)]
pub struct LinkResult {
    /// Ordered socket pair the directed interface connects (source,
    /// destination).
    pub sockets: (usize, usize),
    /// Saturated bandwidth of this direction of the link, GB/s.
    pub link_bw_gbs: f64,
    /// Per-group traffic over the link (`n` = cores whose streams cross
    /// it; `model_alpha` = share of the link's granted traffic).
    pub groups: Vec<GroupOutcome>,
    /// For each entry of `groups`, the socket-level group index it
    /// aggregates.
    pub origins: Vec<usize>,
    /// Total simulated (measured) link traffic, GB/s.
    pub measured_total_gbs: f64,
    /// Total modeled link grant, GB/s.
    pub model_total_gbs: f64,
    /// Whether the model finds the link saturated.
    pub saturated: bool,
}

impl LinkResult {
    /// Display label of the directed link interface, e.g. `s0->s1`.
    pub fn label(&self) -> String {
        format!("s{}->s{}", self.sockets.0, self.sockets.1)
    }
}

/// Contention outcome of one socket's shared-L3 interface under a mix:
/// the groups whose working sets are L3-resident on this socket, with
/// simulated L3-level traffic and modeled L3 grants. Only present when
/// the machine models a shared-L3 bandwidth (`l3_bw_gbs > 0`) *and* some
/// group classifies (or is forced) cache-bound.
///
/// Bandwidths here are **L3-level** GB/s (lines crossing L2↔L3), not
/// DRAM traffic: an LC-at-L3 stencil moves more lines at L3 than at the
/// memory interface, and it is the L3-level rate the shared cache grants.
#[derive(Debug, Clone)]
pub struct L3Result {
    /// Socket whose shared L3 this record describes.
    pub socket: usize,
    /// Modeled aggregate L3 bandwidth of the socket, GB/s.
    pub l3_bw_gbs: f64,
    /// Per-group L3-level traffic (`n` = cores contending at this L3;
    /// `model_alpha` = share of the L3's granted traffic).
    pub groups: Vec<GroupOutcome>,
    /// For each entry of `groups`, the socket-level group index it
    /// aggregates.
    pub origins: Vec<usize>,
    /// Total simulated (measured) L3-level traffic, GB/s.
    pub measured_total_gbs: f64,
    /// Total modeled L3 grant, GB/s.
    pub model_total_gbs: f64,
    /// Whether the model finds the shared L3 saturated.
    pub saturated: bool,
}

impl L3Result {
    /// Display label of the L3 interface, e.g. `l3s0`.
    pub fn label(&self) -> String {
        format!("l3s{}", self.socket)
    }
}

/// Outcome of one socket-level mix resolved onto a multi-domain topology:
/// per-domain [`MixResult`]s (contention is evaluated independently per
/// ccNUMA domain) plus the socket-level aggregate per original group.
#[derive(Debug, Clone)]
pub struct TopoMixResult {
    /// Machine the domains instantiate.
    pub machine: MachineId,
    /// Topology label (e.g. `rome-1s4d`).
    pub topology: String,
    /// Placement policy name the split used.
    pub placement: &'static str,
    /// The socket-level mix.
    pub mix: Mix,
    /// Ids of the reported domains, in domain order: every domain that ran
    /// kernels and, on the remote-access path, every domain that received
    /// remote traffic (its per-domain result then has no resident groups).
    pub domain_ids: Vec<usize>,
    /// Per-domain results, parallel to `domain_ids`.
    pub domains: Vec<MixResult>,
    /// For each entry of `domains`, the socket-level group index of each of
    /// its sub-groups.
    pub origins: Vec<Vec<usize>>,
    /// Socket-level aggregate per original group (bandwidths summed over
    /// domains; α is the share of the socket aggregate).
    pub socket: Vec<GroupOutcome>,
    /// Per-link traffic records (empty when no group sends remote traffic
    /// across sockets).
    pub links: Vec<LinkResult>,
    /// Per-socket shared-L3 records (empty when no group contends at a
    /// modeled shared L3).
    pub l3: Vec<L3Result>,
    /// Measured aggregate bandwidth over the whole socket, GB/s.
    pub measured_total_gbs: f64,
    /// Modeled aggregate bandwidth over the whole socket, GB/s.
    pub model_total_gbs: f64,
    /// Whether the remote-access fixed point converged
    /// ([`crate::sharing::RemoteShare::converged`]). `None` on the
    /// all-local path (no fixed point runs); `Some(false)` marks model
    /// columns that stopped at the sweep cap and should be read as
    /// approximate.
    pub remote_converged: Option<bool>,
}

impl TopoMixResult {
    /// All per-domain per-group relative errors.
    pub fn all_errors(&self) -> Vec<f64> {
        self.domains.iter().flat_map(|d| d.errors()).collect()
    }

    /// CSV header matching [`TopoMixResult::to_csv_rows`]. Domain rows
    /// carry the per-domain Eq. 5 shares; `socket` rows the aggregate.
    pub fn csv_header() -> &'static str {
        "machine,topology,placement,mix,domain,origin,kernel,n,meas_pc_gbs,model_pc_gbs,\
         meas_bw_gbs,model_bw_gbs,alpha_meas,alpha_model,err"
    }

    /// One CSV row per (domain, sub-group), then one `l<a>-<b>` row per
    /// (link, crossing group), then one `l3s<s>` row per (shared L3,
    /// resident group), then one `socket` row per original group.
    pub fn to_csv_rows(&self) -> Vec<String> {
        let mut rows = Vec::new();
        for ((did, dr), origin) in self.domain_ids.iter().zip(&self.domains).zip(&self.origins) {
            for (gi, g) in dr.groups.iter().enumerate() {
                rows.push(format!(
                    "{},{},{},{},d{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.5},{:.5},{:.5}",
                    self.machine.key(),
                    self.topology,
                    self.placement,
                    self.mix.label(),
                    did,
                    origin[gi],
                    g.kernel.key(),
                    g.n,
                    g.measured_per_core,
                    g.model_per_core,
                    g.measured_bw_gbs,
                    g.model_bw_gbs,
                    dr.measured_alpha(gi),
                    g.model_alpha,
                    g.error(),
                ));
            }
        }
        for link in &self.links {
            for (g, origin) in link.groups.iter().zip(&link.origins) {
                let alpha_meas = if link.measured_total_gbs > 0.0 {
                    g.measured_bw_gbs / link.measured_total_gbs
                } else {
                    0.0
                };
                rows.push(format!(
                    "{},{},{},{},l{}-{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.5},{:.5},{:.5}",
                    self.machine.key(),
                    self.topology,
                    self.placement,
                    self.mix.label(),
                    link.sockets.0,
                    link.sockets.1,
                    origin,
                    g.kernel.key(),
                    g.n,
                    g.measured_per_core,
                    g.model_per_core,
                    g.measured_bw_gbs,
                    g.model_bw_gbs,
                    alpha_meas,
                    g.model_alpha,
                    g.error(),
                ));
            }
        }
        for l3 in &self.l3 {
            for (g, origin) in l3.groups.iter().zip(&l3.origins) {
                let alpha_meas = if l3.measured_total_gbs > 0.0 {
                    g.measured_bw_gbs / l3.measured_total_gbs
                } else {
                    0.0
                };
                rows.push(format!(
                    "{},{},{},{},{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.5},{:.5},{:.5}",
                    self.machine.key(),
                    self.topology,
                    self.placement,
                    self.mix.label(),
                    l3.label(),
                    origin,
                    g.kernel.key(),
                    g.n,
                    g.measured_per_core,
                    g.model_per_core,
                    g.measured_bw_gbs,
                    g.model_bw_gbs,
                    alpha_meas,
                    g.model_alpha,
                    g.error(),
                ));
            }
        }
        for (gi, g) in self.socket.iter().enumerate() {
            let alpha_meas = if self.measured_total_gbs > 0.0 {
                g.measured_bw_gbs / self.measured_total_gbs
            } else {
                0.0
            };
            rows.push(format!(
                "{},{},{},{},socket,{},{},{},{:.4},{:.4},{:.4},{:.4},{:.5},{:.5},{:.5}",
                self.machine.key(),
                self.topology,
                self.placement,
                self.mix.label(),
                gi,
                g.kernel.key(),
                g.n,
                g.measured_per_core,
                g.model_per_core,
                g.measured_bw_gbs,
                g.model_bw_gbs,
                alpha_meas,
                g.model_alpha,
                g.error(),
            ));
        }
        rows
    }
}

/// A set of topology mix results with persistence helpers.
#[derive(Debug, Clone, Default)]
pub struct TopoMixResultSet {
    /// All results, in input order.
    pub cases: Vec<TopoMixResult>,
}

impl TopoMixResultSet {
    /// All per-domain per-group relative errors, flattened.
    pub fn all_errors(&self) -> Vec<f64> {
        self.cases.iter().flat_map(|c| c.all_errors()).collect()
    }

    /// Write as CSV (domain rows + socket-aggregate rows per mix).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", TopoMixResult::csv_header())?;
        for c in &self.cases {
            for row in c.to_csv_rows() {
                writeln!(f, "{row}")?;
            }
        }
        Ok(())
    }
}

/// Result of a time-phased scenario on a topology: one [`TopoMixResult`]
/// per phase.
#[derive(Debug, Clone)]
pub struct TopoScenarioResult {
    /// Scenario name.
    pub name: String,
    /// Machine the topology instantiates.
    pub machine: MachineId,
    /// Topology label.
    pub topology: String,
    /// Per-phase results, in time order.
    pub phases: Vec<TopoMixResult>,
}

impl TopoScenarioResult {
    /// All per-domain per-group relative errors over all phases.
    pub fn all_errors(&self) -> Vec<f64> {
        self.phases.iter().flat_map(|p| p.all_errors()).collect()
    }

    /// Safe file stem derived from the scenario name.
    pub fn file_stem(&self) -> String {
        crate::scenario::slugify(&self.name)
    }

    /// Write all phases as one CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        TopoMixResultSet { cases: self.phases.clone() }.write_csv(path)
    }
}

/// Result of a time-phased scenario: one [`MixResult`] per phase.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// Scenario name.
    pub name: String,
    /// Machine the scenario ran on.
    pub machine: MachineId,
    /// Per-phase results, in time order.
    pub phases: Vec<MixResult>,
}

impl ScenarioResult {
    /// All per-group relative errors over all phases.
    pub fn all_errors(&self) -> Vec<f64> {
        self.phases.iter().flat_map(|p| p.errors()).collect()
    }

    /// Safe file stem derived from the scenario name.
    pub fn file_stem(&self) -> String {
        crate::scenario::slugify(&self.name)
    }

    /// Write all phases as one CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        MixResultSet { cases: self.phases.clone() }.write_csv(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelId;

    fn sample() -> MixResult {
        MixResult {
            machine: MachineId::Bdw1,
            mix: Mix::new().with(KernelId::Dcopy, 6).with(KernelId::Ddot2, 4).idle(0),
            groups: vec![
                GroupOutcome {
                    kernel: KernelId::Dcopy,
                    n: 6,
                    measured_bw_gbs: 37.7,
                    measured_per_core: 6.29,
                    model_bw_gbs: 38.6,
                    model_per_core: 6.44,
                    model_alpha: 0.65,
                },
                GroupOutcome {
                    kernel: KernelId::Ddot2,
                    n: 4,
                    measured_bw_gbs: 20.0,
                    measured_per_core: 5.0,
                    model_bw_gbs: 20.4,
                    model_per_core: 5.09,
                    model_alpha: 0.35,
                },
            ],
            measured_total_gbs: 57.7,
            model_total_gbs: 59.0,
            b_mix_gbs: 59.0,
            saturated: true,
        }
    }

    #[test]
    fn errors_match_fig8_definition() {
        let r = sample();
        let e = r.errors();
        assert_eq!(e.len(), 2);
        assert!((e[0] - (6.44 - 6.29) / 6.44).abs() < 1e-12);
    }

    #[test]
    fn measured_alpha_partitions_total() {
        let r = sample();
        assert!((r.measured_alpha(0) + r.measured_alpha(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn csv_rows_match_header_arity() {
        let r = sample();
        let header_cols = MixResult::csv_header().split(',').count();
        for row in r.to_csv_rows() {
            assert_eq!(row.split(',').count(), header_cols);
        }
    }

    #[test]
    fn json_is_wellformed_enough() {
        let j = sample().to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(j.contains("\"mix\":\"dcopy:6+ddot2:4\""));
    }

    #[test]
    fn topo_csv_rows_match_header_arity() {
        let d0 = sample();
        let socket = d0.groups.clone();
        let link = LinkResult {
            sockets: (0, 1),
            link_bw_gbs: 64.0,
            groups: vec![d0.groups[0].clone()],
            origins: vec![0],
            measured_total_gbs: d0.groups[0].measured_bw_gbs,
            model_total_gbs: d0.groups[0].model_bw_gbs,
            saturated: false,
        };
        assert_eq!(link.label(), "s0->s1");
        let l3 = L3Result {
            socket: 0,
            l3_bw_gbs: 320.0,
            groups: vec![d0.groups[1].clone()],
            origins: vec![1],
            measured_total_gbs: d0.groups[1].measured_bw_gbs,
            model_total_gbs: d0.groups[1].model_bw_gbs,
            saturated: false,
        };
        assert_eq!(l3.label(), "l3s0");
        let topo = TopoMixResult {
            machine: MachineId::Rome,
            topology: "rome-1s4d".into(),
            placement: "compact",
            mix: d0.mix.clone(),
            domain_ids: vec![0, 1],
            domains: vec![d0.clone(), sample()],
            origins: vec![vec![0, 1], vec![0, 1]],
            socket,
            links: vec![link],
            l3: vec![l3],
            measured_total_gbs: 2.0 * d0.measured_total_gbs,
            model_total_gbs: 2.0 * d0.model_total_gbs,
            remote_converged: None,
        };
        let header_cols = TopoMixResult::csv_header().split(',').count();
        let rows = topo.to_csv_rows();
        // 2 groups x 2 domains + 1 link row + 1 L3 row + 2 socket rows.
        assert_eq!(rows.len(), 8);
        for row in &rows {
            assert_eq!(row.split(',').count(), header_cols, "{row}");
        }
        assert!(rows[4].contains(",l0-1,"));
        assert!(rows[5].contains(",l3s0,"));
        assert!(rows[6].contains(",socket,"));
        assert_eq!(topo.all_errors().len(), 4);
        let dir = std::env::temp_dir().join("membw-topo-results-test");
        let set = TopoMixResultSet { cases: vec![topo] };
        set.write_csv(&dir.join("topo.csv")).unwrap();
        let csv = std::fs::read_to_string(dir.join("topo.csv")).unwrap();
        assert_eq!(csv.lines().count(), 1 + 8);
    }

    #[test]
    fn files_roundtrip() {
        let dir = std::env::temp_dir().join("membw-scenario-results-test");
        let set = MixResultSet { cases: vec![sample(), sample()] };
        set.write_csv(&dir.join("mixes.csv")).unwrap();
        set.write_jsonl(&dir.join("mixes.jsonl")).unwrap();
        let csv = std::fs::read_to_string(dir.join("mixes.csv")).unwrap();
        assert_eq!(csv.lines().count(), 1 + 2 * 2, "header + 2 groups x 2 mixes");
        let jsonl = std::fs::read_to_string(dir.join("mixes.jsonl")).unwrap();
        assert_eq!(jsonl.lines().count(), 2);
    }
}
