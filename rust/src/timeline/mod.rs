//! The shared contention-timeline layer: exact event-driven simulation of
//! ranks contending for one memory domain.
//!
//! The paper's co-simulation application (Sect. VI) observes that per-core
//! bandwidth is an *analytic* function of the instantaneous group
//! composition (generalized Eqs. 4+5). Between composition changes nothing
//! varies, so the simulation reduces to exactly four event families —
//! phase completions, collective releases, staggered starts, and noise
//! interruptions. Starts, noise, idle expiries, and releases live in a
//! priority queue; the next phase completion is a *closed-form* time under
//! the current composition and is simply compared against the queue head.
//! This eliminates the legacy stepper's `dt` discretization error entirely
//! and runs orders of magnitude faster (see `repro bench` /
//! `BENCH_cosim.json`).
//!
//! * [`event`] — the priority-queue event core (lazy invalidation),
//! * [`engine`] — the drained-bytes-integral simulation core
//!   ([`engine::simulate`]; [`engine::simulate_placed`] keys all
//!   contention state by ccNUMA domain, so a full NPS4 socket runs as
//!   concurrent per-domain timelines over one shared event queue; on
//!   cluster layouts the coupled remote path re-rates *per node*,
//!   incrementally — see [`engine::RatingMode`] and the engine's module
//!   docs on cluster scaling). A run can also be paused at a stop time
//!   and resumed bit-identically from its [`engine::EngineCheckpoint`]
//!   ([`engine::simulate_placed_until`] / [`engine::resume_placed`] —
//!   the incremental makespan probe of `repro serve`).
//!
//! [`crate::desync::CoSimEngine`] is the user-facing driver over this
//! layer; the legacy stepper survives behind the `legacy-stepper` feature
//! (and in unit tests) as the golden reference. The timeline drains ranks
//! at *model* rates (Eqs. 4+5 / the coupled remote model); the
//! measurement-side analogue — simulating the same interface network with
//! fluid or DES physics — lives in `simulator::network` and is documented
//! next to it in `docs/SIMULATORS.md`.
//!
//! # Examples
//!
//! One rank draining one kernel completes at the closed-form time
//! `volume / (f · b_s)` — exactly, with no time step:
//!
//! ```
//! use membw::desync::{CoSimConfig, NoiseModel, Phase, Program, SyncKind};
//! use membw::kernels::KernelId;
//! use membw::timeline::simulate;
//!
//! let program = Program {
//!     phases: vec![Phase::Kernel {
//!         kernel: KernelId::Ddot2,
//!         volume_bytes: 2e9,
//!         sync: SyncKind::None,
//!         label: "K",
//!     }],
//!     iterations: 1,
//! };
//! let config = CoSimConfig {
//!     dt_s: 1.0, // ignored: the event engine has no time step
//!     t_max_s: 1e6,
//!     initial_stagger_s: 0.0,
//!     neighbor_radius: 1,
//!     noise: NoiseModel::off(),
//! };
//! let r = simulate(&program, 1, &config, &[(KernelId::Ddot2, 0.2, 100.0)]);
//! let expect = 2e9 / (0.2 * 100.0e9);
//! assert!((r.finish_s[0] - expect).abs() < 1e-9 * expect);
//! ```

pub mod event;
pub mod engine;

pub use engine::{
    resume_placed, simulate, simulate_placed, simulate_placed_mode, simulate_placed_until,
    EngineCheckpoint, RatingMode, SimStep,
};
pub use event::{Event, EventKind, EventQueue};
