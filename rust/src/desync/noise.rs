//! Reproducible system-noise injection.
//!
//! The paper observes that desynchronization "can occur automatically by
//! natural system noise and small load imbalances" (Sect. I). We model
//! noise as per-rank random idle insertions with exponentially distributed
//! inter-arrival times and durations — the standard OS-jitter model.

use crate::simulator::XorShift64;

/// Noise model parameters.
#[derive(Debug, Clone, Copy)]
pub struct NoiseModel {
    /// Mean time between noise events per rank, seconds.
    pub mean_interval_s: f64,
    /// Mean duration of one noise event, seconds.
    pub mean_duration_s: f64,
    /// RNG seed.
    pub seed: u64,
}

impl NoiseModel {
    /// Silence (no noise).
    pub fn off() -> Self {
        NoiseModel { mean_interval_s: f64::INFINITY, mean_duration_s: 0.0, seed: 1 }
    }

    /// Mild OS jitter: ~150 µs events every ~8 ms — enough to seed
    /// desynchronization over the long SymGS/SpMV phases without putting a
    /// heavy artificial tail on the short DDOT durations.
    pub fn mild(seed: u64) -> Self {
        NoiseModel { mean_interval_s: 8e-3, mean_duration_s: 150e-6, seed }
    }

    /// Whether noise is enabled.
    pub fn enabled(&self) -> bool {
        self.mean_interval_s.is_finite() && self.mean_duration_s > 0.0
    }

    /// Per-rank noise event stream generator.
    pub fn stream(&self, rank: usize) -> NoiseStream {
        let mut rng = XorShift64::new(self.seed.wrapping_mul(0x9E37).wrapping_add(rank as u64 + 1));
        let first = if self.enabled() { rng.next_exp(self.mean_interval_s) } else { f64::INFINITY };
        NoiseStream { model: *self, rng, next_at: first }
    }
}

/// Lazily generated noise events for one rank.
///
/// The stream has two consumption modes sharing one RNG draw sequence
/// (duration first, then the next inter-arrival gap):
///
/// * [`NoiseStream::poll`] — the legacy stepper's per-`dt` polling,
/// * [`NoiseStream::next_at`] + [`NoiseStream::fire`] — the continuous-time
///   sampler used by the event-driven timeline engine: the next event time
///   is known in advance, so it can sit in a priority queue instead of being
///   polled every step.
///
/// The stream is `Clone` so a paused timeline run can checkpoint it
/// (`timeline::EngineCheckpoint`): the RNG state and the pending arrival
/// time are the entire stream state, and cloning them preserves the draw
/// sequence bit for bit.
#[derive(Debug, Clone)]
pub struct NoiseStream {
    model: NoiseModel,
    rng: XorShift64,
    next_at: f64,
}

impl NoiseStream {
    /// Whether this stream can ever fire.
    pub fn enabled(&self) -> bool {
        self.model.enabled()
    }

    /// Absolute time of the next noise event (+∞ when noise is off).
    pub fn next_at(&self) -> f64 {
        self.next_at
    }

    /// Consume the pending event at time `t` (continuous-time semantics):
    /// returns the event duration and schedules the next arrival at
    /// `t + Exp(mean_interval)`.
    pub fn fire(&mut self, t: f64) -> f64 {
        let duration = self.rng.next_exp(self.model.mean_duration_s);
        self.next_at = t + self.rng.next_exp(self.model.mean_interval_s);
        duration
    }

    /// If a noise event fires in `[t, t+dt)`, returns its duration and
    /// schedules the next one (legacy `dt`-grid semantics: the next arrival
    /// is offset from the end of the current step).
    pub fn poll(&mut self, t: f64, dt: f64) -> Option<f64> {
        if !self.model.enabled() || t + dt < self.next_at {
            return None;
        }
        let duration = self.rng.next_exp(self.model.mean_duration_s);
        self.next_at = t + dt + self.rng.next_exp(self.model.mean_interval_s);
        Some(duration)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_model_never_fires() {
        let mut s = NoiseModel::off().stream(0);
        for i in 0..1000 {
            assert!(s.poll(i as f64 * 1e-3, 1e-3).is_none());
        }
    }

    #[test]
    fn mild_model_fires_at_roughly_the_right_rate() {
        let mut s = NoiseModel::mild(42).stream(3);
        let dt = 1e-4;
        let mut events = 0;
        let mut t = 0.0;
        for _ in 0..200_000 {
            if s.poll(t, dt).is_some() {
                events += 1;
            }
            t += dt;
        }
        // 20 s of simulated time at 8 ms mean interval -> ~2500 events.
        assert!((1500..3500).contains(&events), "events {events}");
    }

    #[test]
    fn continuous_sampler_matches_poll_draw_sequence() {
        // fire() and poll() consume the same RNG draws (duration, interval),
        // so the k-th event of a stream has the same duration under both
        // consumption modes.
        let m = NoiseModel::mild(11);
        let mut cont = m.stream(4);
        let mut durs_cont = Vec::new();
        for _ in 0..50 {
            let at = cont.next_at();
            assert!(at.is_finite());
            durs_cont.push(cont.fire(at));
        }
        let mut poll = m.stream(4);
        let mut durs_poll = Vec::new();
        let dt = 1e-5;
        let mut t = 0.0;
        while durs_poll.len() < 50 {
            if let Some(d) = poll.poll(t, dt) {
                durs_poll.push(d);
            }
            t += dt;
        }
        assert_eq!(durs_cont, durs_poll);
    }

    #[test]
    fn disabled_stream_never_schedules() {
        let s = NoiseModel::off().stream(0);
        assert!(!s.enabled());
        assert_eq!(s.next_at(), f64::INFINITY);
    }

    #[test]
    fn fire_advances_strictly_forward() {
        let mut s = NoiseModel::mild(3).stream(1);
        let mut t = 0.0;
        for _ in 0..1000 {
            let at = s.next_at();
            assert!(at > t);
            t = at;
            let d = s.fire(at);
            assert!(d >= 0.0);
        }
    }

    #[test]
    fn streams_differ_across_ranks_but_reproduce() {
        let m = NoiseModel::mild(7);
        let a: Vec<_> = (0..10).filter_map(|i| m.stream(0).poll(i as f64 * 0.05, 0.05)).collect();
        let b: Vec<_> = (0..10).filter_map(|i| m.stream(0).poll(i as f64 * 0.05, 0.05)).collect();
        assert_eq!(a, b, "same rank reproduces");
    }
}
