//! Work placement on a [`Topology`]: how kernel groups, idle cores, and MPI
//! ranks land on ccNUMA domains.
//!
//! Three mechanisms compose:
//!
//! * a per-group [`GroupPlacement`] carried by the mix DSL — `@dN` pins a
//!   group to one domain, `@scatter`/`@compact` override the mix-level
//!   policy for that group, and the default (`Auto`) follows it;
//! * a mix-level [`Placement`] policy (`compact` fills domains in order,
//!   `scatter` round-robins cores over domains — OpenMP's close/spread);
//! * [`Placement::split`] resolves both into per-domain sub-mixes, and
//!   [`Placement::rank_layout`] does the same for co-simulation ranks.
//!
//! Splitting is deterministic and order-preserving: sub-mixes list their
//! groups in original mix order, so the single-domain split of any mix is
//! the mix itself (the degenerate path the conformance suite pins).

use crate::error::{Error, Result};
use crate::scenario::{GroupSpec, Mix};
use crate::topology::Topology;

/// Where one kernel group of a mix goes.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum GroupPlacement {
    /// Follow the mix-level [`Placement`] policy.
    #[default]
    Auto,
    /// Fill domains in order (first fit), regardless of the mix policy.
    Compact,
    /// Round-robin the group's cores over the domains.
    Scatter,
    /// Pin every core of the group to one domain (`@dN` in the DSL).
    Domain(usize),
}

impl GroupPlacement {
    /// DSL suffix of this placement (empty for `Auto`).
    pub fn suffix(&self) -> String {
        match self {
            GroupPlacement::Auto => String::new(),
            GroupPlacement::Compact => "@compact".into(),
            GroupPlacement::Scatter => "@scatter".into(),
            GroupPlacement::Domain(d) => format!("@d{d}"),
        }
    }
}

/// Mix-level placement policy for `Auto` groups and for co-simulation
/// ranks.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum Placement {
    /// Fill domains in order (OpenMP "close").
    #[default]
    Compact,
    /// Round-robin over domains (OpenMP "spread").
    Scatter,
}

impl Placement {
    /// Parse a CLI key.
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "compact" | "close" => Ok(Placement::Compact),
            "scatter" | "spread" => Ok(Placement::Scatter),
            other => Err(Error::InvalidPlan(format!(
                "unknown placement '{other}' (compact, scatter)"
            ))),
        }
    }

    /// Short display name.
    pub fn name(&self) -> &'static str {
        match self {
            Placement::Compact => "compact",
            Placement::Scatter => "scatter",
        }
    }

    /// Split a socket-level mix into per-domain sub-mixes.
    ///
    /// Assignment passes, all deterministic: explicitly pinned groups
    /// first, then scatter groups (round-robin from domain 0 over free
    /// capacity), then compact groups (first fit in domain order), then
    /// idle cores (compact fill). Sub-mixes keep groups in original mix
    /// order; `origin[i]` maps sub-group `i` back to its socket-level
    /// group.
    pub fn split(&self, topo: &Topology, mix: &Mix) -> Result<SplitMix> {
        if mix.active_cores() == 0 {
            return Err(Error::InvalidPlan(format!(
                "mix '{}' has no active cores",
                mix.label()
            )));
        }
        let nd = topo.n_domains();
        if nd < 2 {
            if let Some(g) = mix.groups.iter().find(|g| g.remote_ppm > 0) {
                return Err(Error::InvalidPlan(format!(
                    "mix '{}': group {}:{} has remote fraction {} but topology {} has a single \
                     domain (remote accesses need at least two)",
                    mix.label(),
                    g.kernel.key(),
                    g.cores,
                    g.remote_frac(),
                    topo.label(),
                )));
            }
        }
        let mut free: Vec<usize> = topo.domains.iter().map(|d| d.machine.cores).collect();
        let mut assign = vec![vec![0usize; nd]; mix.groups.len()];
        let overflow = |g: &GroupSpec| {
            Error::InvalidPlan(format!(
                "mix '{}': no free cores left for group {}:{} on topology {} ({} cores total)",
                mix.label(),
                g.kernel.key(),
                g.cores,
                topo.label(),
                topo.total_cores(),
            ))
        };

        // Pass 1: explicit `@dN` pins.
        for (gi, g) in mix.groups.iter().enumerate() {
            if let GroupPlacement::Domain(d) = g.place {
                if d >= nd {
                    return Err(Error::InvalidPlan(format!(
                        "mix '{}': group {}:{} pinned to domain d{d} but topology {} has {nd} domains",
                        mix.label(),
                        g.kernel.key(),
                        g.cores,
                        topo.label(),
                    )));
                }
                if free[d] < g.cores {
                    return Err(Error::InvalidPlan(format!(
                        "mix '{}': domain d{d} of topology {} has {} free cores, group {}:{} needs {}",
                        mix.label(),
                        topo.label(),
                        free[d],
                        g.kernel.key(),
                        g.cores,
                        g.cores,
                    )));
                }
                free[d] -= g.cores;
                assign[gi][d] = g.cores;
            }
        }

        let effective = |p: GroupPlacement| match p {
            GroupPlacement::Auto => match self {
                Placement::Compact => GroupPlacement::Compact,
                Placement::Scatter => GroupPlacement::Scatter,
            },
            other => other,
        };

        // Pass 2: scatter groups, one core at a time round-robin.
        for (gi, g) in mix.groups.iter().enumerate() {
            if effective(g.place) != GroupPlacement::Scatter {
                continue;
            }
            let (mut d, mut left, mut stuck) = (0usize, g.cores, 0usize);
            while left > 0 {
                if free[d] > 0 {
                    assign[gi][d] += 1;
                    free[d] -= 1;
                    left -= 1;
                    stuck = 0;
                } else {
                    stuck += 1;
                    if stuck >= nd {
                        return Err(overflow(g));
                    }
                }
                d = (d + 1) % nd;
            }
        }

        // Pass 3: compact groups, first fit in domain order.
        for (gi, g) in mix.groups.iter().enumerate() {
            if effective(g.place) != GroupPlacement::Compact {
                continue;
            }
            let mut left = g.cores;
            for d in 0..nd {
                let take = left.min(free[d]);
                assign[gi][d] += take;
                free[d] -= take;
                left -= take;
                if left == 0 {
                    break;
                }
            }
            if left > 0 {
                return Err(overflow(g));
            }
        }

        // Idle cores: compact fill of the remaining capacity.
        let mut idle = vec![0usize; nd];
        let mut left = mix.idle_cores;
        for d in 0..nd {
            let take = left.min(free[d]);
            idle[d] = take;
            free[d] -= take;
            left -= take;
        }
        if left > 0 {
            return Err(Error::InvalidPlan(format!(
                "mix '{}': {} idle cores do not fit the remaining capacity of topology {}",
                mix.label(),
                mix.idle_cores,
                topo.label(),
            )));
        }

        // Emit per-domain sub-mixes in original group order.
        let domains = (0..nd)
            .map(|d| {
                let mut sub = Mix::new();
                let mut origin = Vec::new();
                for (gi, g) in mix.groups.iter().enumerate() {
                    if assign[gi][d] > 0 {
                        sub.groups.push(GroupSpec {
                            kernel: g.kernel,
                            cores: assign[gi][d],
                            place: g.place,
                            remote_ppm: g.remote_ppm,
                        });
                        origin.push(gi);
                    }
                }
                sub.idle_cores = idle[d];
                DomainMix { domain: d, mix: sub, origin }
            })
            .collect();
        Ok(SplitMix { domains })
    }

    /// Assign `n_ranks` co-simulation ranks to domains: compact fills
    /// domains in order, scatter round-robins (rank r → domain r mod nd on
    /// a uniform topology).
    pub fn rank_layout(&self, topo: &Topology, n_ranks: usize) -> Result<RankLayout> {
        let total = topo.total_cores();
        if n_ranks == 0 || n_ranks > total {
            return Err(Error::InvalidPlan(format!(
                "{n_ranks} ranks on topology {} with {total} cores",
                topo.label()
            )));
        }
        let nd = topo.n_domains();
        let mut free: Vec<usize> = topo.domains.iter().map(|d| d.machine.cores).collect();
        let mut rank_domain = Vec::with_capacity(n_ranks);
        match self {
            Placement::Compact => {
                let mut d = 0;
                for _ in 0..n_ranks {
                    while free[d] == 0 {
                        d += 1;
                    }
                    rank_domain.push(d);
                    free[d] -= 1;
                }
            }
            Placement::Scatter => {
                let mut d = 0;
                for _ in 0..n_ranks {
                    while free[d] == 0 {
                        d = (d + 1) % nd;
                    }
                    rank_domain.push(d);
                    free[d] -= 1;
                    d = (d + 1) % nd;
                }
            }
        }
        Ok(RankLayout {
            n_domains: nd,
            rank_domain,
            bw_scale: topo.bw_scales(),
            socket_of: topo.socket_of(),
            node_of: topo.node_of(),
            link_bw_gbs: topo.base.link_bw_gbs,
            link_bw_rev_gbs: topo.base.link_bw_rev_gbs,
            collective_extra_s: topo.collective_extra_s(),
            remote: None,
        })
    }
}

/// One domain's share of a split mix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DomainMix {
    /// Domain id.
    pub domain: usize,
    /// The domain-local sub-mix (may be empty).
    pub mix: Mix,
    /// For each sub-group, the index of its socket-level group.
    pub origin: Vec<usize>,
}

/// A socket-level mix resolved onto a topology: one [`DomainMix`] per
/// domain, in domain order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SplitMix {
    /// Per-domain sub-mixes (every domain present, possibly empty).
    pub domains: Vec<DomainMix>,
}

impl SplitMix {
    /// Domains that actually run kernels.
    pub fn active(&self) -> impl Iterator<Item = &DomainMix> {
        self.domains.iter().filter(|d| d.mix.active_cores() > 0)
    }
}

/// Remote-access traffic of a co-simulation layout: every rank homed on
/// domain `d` sends `frac[d]` of its cache-line stream to remote domains
/// (uniform spread, inter-socket portions crossing the links — see
/// [`crate::sharing::remote`]).
#[derive(Debug, Clone, PartialEq)]
pub struct RemoteTraffic {
    /// Remote fraction per home domain, each in `[0, 1]`.
    pub frac: Vec<f64>,
}

/// Rank→domain assignment of a co-simulation on a topology (the timeline
/// engine keys its contention state by `rank_domain`).
#[derive(Debug, Clone, PartialEq)]
pub struct RankLayout {
    /// Number of ccNUMA domains.
    pub n_domains: usize,
    /// Domain of each rank.
    pub rank_domain: Vec<usize>,
    /// Per-domain saturated-bandwidth scale (1.0 = nominal).
    pub bw_scale: Vec<f64>,
    /// Socket of each domain (all zero on single-socket layouts).
    pub socket_of: Vec<usize>,
    /// Cluster node of each domain (all zero on single-node layouts).
    /// Bandwidth couples domains only within a node; the timeline engine
    /// re-rates per node (see `crate::timeline`).
    pub node_of: Vec<usize>,
    /// Saturated bandwidth of the forward (lower → higher socket index)
    /// direction of one inter-socket link, GB/s (0 = links not modeled).
    pub link_bw_gbs: f64,
    /// Saturated bandwidth of the reverse direction, GB/s (symmetric
    /// duplex when equal to `link_bw_gbs`).
    pub link_bw_rev_gbs: f64,
    /// Extra collective (Allreduce) release latency from inter-socket
    /// barrier hops, seconds; 0 on single-socket layouts.
    pub collective_extra_s: f64,
    /// Remote-access traffic spec (None = all traffic stays home).
    pub remote: Option<RemoteTraffic>,
}

impl RankLayout {
    /// The degenerate layout: every rank on one nominal domain.
    pub fn single(n_ranks: usize) -> Self {
        RankLayout {
            n_domains: 1,
            rank_domain: vec![0; n_ranks],
            bw_scale: vec![1.0],
            socket_of: vec![0],
            node_of: vec![0],
            link_bw_gbs: 0.0,
            link_bw_rev_gbs: 0.0,
            collective_extra_s: 0.0,
            remote: None,
        }
    }

    /// Whether this is the degenerate single-domain layout.
    pub fn is_single(&self) -> bool {
        self.n_domains == 1 && self.bw_scale[0] == 1.0
    }

    /// Number of cluster nodes in the layout.
    pub fn n_nodes(&self) -> usize {
        self.node_of.iter().copied().max().unwrap_or(0) + 1
    }

    /// Attach a uniform remote-access fraction: every rank sends `frac` of
    /// its cache-line stream to remote domains. Fails when `frac` is
    /// outside `[0, 1]` or nonzero on a single-domain layout.
    pub fn with_remote(mut self, frac: f64) -> Result<Self> {
        if !frac.is_finite() || !(0.0..=1.0).contains(&frac) {
            return Err(Error::InvalidPlan(format!(
                "remote fraction {frac} outside [0, 1]"
            )));
        }
        if frac > 0.0 && self.n_domains < 2 {
            return Err(Error::InvalidPlan(
                "remote accesses need at least two ccNUMA domains".into(),
            ));
        }
        self.remote = Some(RemoteTraffic { frac: vec![frac; self.n_domains] });
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::KernelId;

    fn rome_socket() -> Topology {
        Topology::socket(&machine(MachineId::Rome))
    }

    #[test]
    fn scatter_round_robins_over_domains() {
        // 12 cores over 4x8: 3 per domain.
        let topo = rome_socket();
        let mix = Mix::new().with(KernelId::Dcopy, 12);
        let split = Placement::Scatter.split(&topo, &mix).unwrap();
        for d in 0..4 {
            assert_eq!(split.domains[d].mix.active_cores(), 3, "domain {d}");
            assert_eq!(split.domains[d].origin, vec![0]);
        }
    }

    #[test]
    fn compact_fills_domains_in_order() {
        let topo = rome_socket();
        let mix = Mix::new().with(KernelId::Dcopy, 12);
        let split = Placement::Compact.split(&topo, &mix).unwrap();
        let cores: Vec<usize> = split.domains.iter().map(|d| d.mix.active_cores()).collect();
        assert_eq!(cores, vec![8, 4, 0, 0]);
    }

    #[test]
    fn explicit_pins_take_priority() {
        let topo = rome_socket();
        let mix = Mix::new()
            .with_on(KernelId::Ddot2, 4, GroupPlacement::Domain(0))
            .with_on(KernelId::Dcopy, 4, GroupPlacement::Domain(1));
        let split = Placement::Compact.split(&topo, &mix).unwrap();
        assert_eq!(split.domains[0].mix.groups[0].kernel, KernelId::Ddot2);
        assert_eq!(split.domains[1].mix.groups[0].kernel, KernelId::Dcopy);
        assert_eq!(split.domains[2].mix.groups.len(), 0);
        // Scatter fills around the pins: 2 free in d0, then round-robin.
        let mixed = Mix::new()
            .with_on(KernelId::Stream, 6, GroupPlacement::Domain(0))
            .with(KernelId::Daxpy, 8);
        let s = Placement::Scatter.split(&topo, &mixed).unwrap();
        let daxpy: Vec<usize> = s
            .domains
            .iter()
            .map(|d| {
                d.mix
                    .groups
                    .iter()
                    .filter(|g| g.kernel == KernelId::Daxpy)
                    .map(|g| g.cores)
                    .sum()
            })
            .collect();
        assert_eq!(daxpy, vec![2, 2, 2, 2]);
    }

    #[test]
    fn single_domain_split_is_identity() {
        let m = machine(MachineId::Clx);
        let topo = Topology::single(&m);
        let mix = Mix::new().with(KernelId::Dcopy, 7).with(KernelId::Ddot2, 7).idle(6);
        for p in [Placement::Compact, Placement::Scatter] {
            let split = p.split(&topo, &mix).unwrap();
            assert_eq!(split.domains.len(), 1);
            assert_eq!(split.domains[0].mix, mix, "degenerate split must be the mix itself");
            assert_eq!(split.domains[0].origin, vec![0, 1]);
        }
    }

    #[test]
    fn capacity_and_range_errors() {
        let topo = rome_socket();
        // Pin beyond a domain's capacity.
        let over = Mix::new().with_on(KernelId::Dcopy, 9, GroupPlacement::Domain(0));
        assert!(Placement::Compact.split(&topo, &over).is_err());
        // Pin to a nonexistent domain.
        let oob = Mix::new().with_on(KernelId::Dcopy, 4, GroupPlacement::Domain(9));
        let e = Placement::Compact.split(&topo, &oob).unwrap_err().to_string();
        assert!(e.contains("d9") && e.contains("4 domains"), "{e}");
        // Socket overflow.
        let too_big = Mix::new().with(KernelId::Dcopy, 30).idle(4);
        assert!(Placement::Compact.split(&topo, &too_big).is_err());
    }

    #[test]
    fn idle_cores_fill_remaining_capacity() {
        let topo = rome_socket();
        let mix = Mix::new().with(KernelId::Dcopy, 30).idle(2);
        let split = Placement::Compact.split(&topo, &mix).unwrap();
        assert_eq!(split.domains[3].mix.idle_cores, 2);
        assert_eq!(split.active().count(), 4);
    }

    #[test]
    fn rank_layouts_cover_both_policies() {
        let topo = rome_socket();
        let compact = Placement::Compact.rank_layout(&topo, 10).unwrap();
        assert_eq!(&compact.rank_domain[..10], &[0, 0, 0, 0, 0, 0, 0, 0, 1, 1]);
        let scatter = Placement::Scatter.rank_layout(&topo, 10).unwrap();
        assert_eq!(&scatter.rank_domain[..10], &[0, 1, 2, 3, 0, 1, 2, 3, 0, 1]);
        assert!(Placement::Compact.rank_layout(&topo, 33).is_err());
        assert!(Placement::Compact.rank_layout(&topo, 0).is_err());
        // Degenerate layout.
        let single = Placement::Scatter.rank_layout(&Topology::single(&machine(MachineId::Clx)), 5).unwrap();
        assert!(single.is_single());
        assert_eq!(single.rank_domain, vec![0; 5]);
    }

    #[test]
    fn split_carries_remote_fractions_to_sub_groups() {
        let topo = rome_socket();
        let mix = Mix::parse("dcopy:12@scatter%r0.25+ddot2:4@d1").unwrap();
        let split = Placement::Scatter.split(&topo, &mix).unwrap();
        for d in 0..4 {
            let dcopy = split.domains[d]
                .mix
                .groups
                .iter()
                .find(|g| g.kernel == KernelId::Dcopy)
                .expect("dcopy scattered everywhere");
            assert_eq!(dcopy.remote_ppm, 250_000, "domain {d}");
        }
        let ddot = split.domains[1]
            .mix
            .groups
            .iter()
            .find(|g| g.kernel == KernelId::Ddot2)
            .unwrap();
        assert_eq!(ddot.remote_ppm, 0);
        // Remote fractions on a single-domain topology are rejected.
        let single = Topology::single(&machine(MachineId::Clx));
        let remote = Mix::parse("dcopy:4%r0.5").unwrap();
        let e = Placement::Compact.split(&single, &remote).unwrap_err().to_string();
        assert!(e.contains("single"), "{e}");
    }

    #[test]
    fn rank_layout_exposes_sockets_links_and_remote() {
        let m = machine(MachineId::Rome);
        let two = Topology::parse(&m, "2x4").unwrap();
        let layout = Placement::Compact.rank_layout(&two, 16).unwrap();
        assert_eq!(layout.socket_of, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        assert_eq!(layout.node_of, vec![0; 8], "a single node spans both sockets");
        assert_eq!(layout.n_nodes(), 1);
        // Cluster layouts expose the node partition.
        let cl = Topology::parse(&m, "4n1x4").unwrap();
        let clayout = Placement::Scatter.rank_layout(&cl, 32).unwrap();
        assert_eq!(clayout.n_nodes(), 4);
        assert_eq!(clayout.node_of[0], 0);
        assert_eq!(clayout.node_of[15], 3);
        assert_eq!(layout.link_bw_gbs.to_bits(), m.link_bw_gbs.to_bits());
        assert_eq!(layout.link_bw_rev_gbs.to_bits(), m.link_bw_rev_gbs.to_bits());
        assert!((layout.collective_extra_s - m.link_latency_us * 1e-6).abs() < 1e-18);
        assert!(layout.remote.is_none());
        let with = layout.clone().with_remote(0.25).unwrap();
        assert_eq!(with.remote.as_ref().unwrap().frac, vec![0.25; 8]);
        assert!(layout.clone().with_remote(1.5).is_err());
        // Single-socket layouts have no collective extra; single-domain
        // layouts reject remote traffic.
        let one = Placement::Compact.rank_layout(&Topology::socket(&m), 8).unwrap();
        assert_eq!(one.collective_extra_s, 0.0);
        assert!(RankLayout::single(4).with_remote(0.5).is_err());
        assert!(RankLayout::single(4).with_remote(0.0).is_ok());
    }

    #[test]
    fn placement_parse() {
        assert_eq!(Placement::parse("compact").unwrap(), Placement::Compact);
        assert_eq!(Placement::parse(" SPREAD ").unwrap(), Placement::Scatter);
        assert!(Placement::parse("random").is_err());
    }
}
