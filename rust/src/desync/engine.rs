//! The co-simulation driver.
//!
//! `CoSimEngine` resolves kernel characterizations (through the process-wide
//! [`CharCache`], for the analytic ECM route or any measurement engine) and
//! hands the program to the event-driven contention-timeline layer
//! ([`crate::timeline`]): a priority-queue simulation whose only events are
//! phase completions, collective releases, staggered starts, and noise
//! interruptions. Between events every running rank drains at the constant
//! rate the multigroup sharing model (generalized Eqs. 4+5) assigns to its
//! group, so results carry **zero** time-discretization error.
//!
//! The seed's fixed-`dt` stepper survives as `CoSimEngine::run_legacy`
//! (tests and the `legacy-stepper` feature only) — the golden reference the
//! event engine is pinned against.

use std::collections::HashMap;

use crate::config::Machine;
use crate::desync::program::{Phase, Program};
use crate::desync::trace::TraceLog;
use crate::desync::NoiseModel;
use crate::error::{Error, Result};
use crate::kernels::KernelId;
use crate::scenario::{CharCache, CharSource};
use crate::timeline;
use crate::topology::{Placement, RankLayout, Topology};

/// Co-simulation configuration.
#[derive(Debug, Clone)]
pub struct CoSimConfig {
    /// Time step of the **legacy stepper**, seconds. The event-driven
    /// engine is exact and ignores this knob entirely (pinned by a property
    /// test).
    pub dt_s: f64,
    /// Hard wall on simulated time.
    pub t_max_s: f64,
    /// Initial per-rank start stagger, seconds (rank r starts at r*stagger;
    /// 0 = lockstep start).
    pub initial_stagger_s: f64,
    /// Halo radius of the `SyncKind::Neighbors` dependency: how many ranks
    /// on each side must have completed the previous phase. 1 models a 1D
    /// chain; HPCG's 3D decomposition couples more broadly (default 3).
    pub neighbor_radius: usize,
    /// Noise model.
    pub noise: NoiseModel,
}

impl Default for CoSimConfig {
    fn default() -> Self {
        CoSimConfig {
            dt_s: 20e-6,
            t_max_s: 120.0,
            initial_stagger_s: 0.0,
            neighbor_radius: 3,
            noise: NoiseModel::off(),
        }
    }
}

/// Engine-internal efficiency counters of one co-simulation run (all zero
/// on the legacy stepper, which predates the caches it counts).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Node re-ratings the coupled remote path performed: one per dirty
    /// node per refresh on the incremental path, one per node per refresh
    /// on the full-recompute reference.
    pub rate_evals: u64,
    /// Node re-ratings skipped because the node's composition was clean —
    /// the incremental path's savings. Always zero on the full-recompute
    /// reference and on runs without remote traffic.
    pub node_rates_reused: u64,
    /// Aggregated composition-memo hits over the per-domain
    /// [`crate::sharing::ShareCache`]s (independent-domain path).
    pub share_hits: u64,
    /// Aggregated composition-memo misses over the per-domain
    /// [`crate::sharing::ShareCache`]s.
    pub share_misses: u64,
    /// Composition-memo hits of the [`crate::sharing::RemoteRateModel`]
    /// (coupled path; identical cluster nodes share one memo).
    pub remote_hits: u64,
    /// Composition-memo misses of the remote rate model.
    pub remote_misses: u64,
    /// Live entries in the remote rate model's memo at the end of the run.
    pub remote_entries: usize,
    /// Hits of the placement optimizer's sharded score memo
    /// ([`crate::optimizer::ShardedScoreMemo`]); zero on plain co-sim
    /// runs — the field rides along so every BENCH payload surfaces
    /// cache-thrash regressions through one counter struct.
    pub memo_hits: u64,
    /// Misses of the sharded score memo (zero on plain co-sim runs).
    pub memo_misses: u64,
    /// Live entries in the sharded score memo at the end of the search
    /// (zero on plain co-sim runs).
    pub memo_entries: usize,
}

/// Result of a co-simulation.
#[derive(Debug, Clone)]
pub struct CoSimResult {
    /// Full phase trace.
    pub trace: TraceLog,
    /// Per-rank completion time, seconds (NaN if the wall clock hit first).
    pub finish_s: Vec<f64>,
    /// Simulated time at which the run ended.
    pub t_end_s: f64,
    /// Simulation effort: events processed by the timeline engine, or time
    /// steps executed by the legacy stepper.
    pub events: u64,
    /// Cache and re-rating counters (surfaced in `repro bench` payloads).
    pub stats: SimStats,
}

/// The engine.
pub struct CoSimEngine<'a> {
    /// Machine the ranks run on (kept for diagnostics / future extensions).
    pub machine: &'a Machine,
    program: Program,
    n_ranks: usize,
    config: CoSimConfig,
    /// `(f, b_s[GB/s])` per program kernel, served by the characterization
    /// cache (ECM route by default).
    chars: HashMap<KernelId, (f64, f64)>,
    /// Rank→ccNUMA-domain layout (the degenerate single-domain layout
    /// unless built with [`CoSimEngine::with_topology`]).
    layout: RankLayout,
}

impl<'a> CoSimEngine<'a> {
    /// Build an engine for `n_ranks` ranks of `program` on `machine`,
    /// characterizing kernels through the analytic ECM route (the paper's
    /// default: the co-sim is the *application* of the model, not its
    /// validation).
    pub fn new(
        machine: &'a Machine,
        program: Program,
        n_ranks: usize,
        config: CoSimConfig,
    ) -> Result<Self> {
        CoSimEngine::with_source(machine, program, n_ranks, config, &CharSource::Ecm)
    }

    /// Build an engine with an explicit characterization source — ECM or
    /// any measurement engine (fluid, DES, PJRT), served through the
    /// process-wide [`CharCache`].
    pub fn with_source(
        machine: &'a Machine,
        program: Program,
        n_ranks: usize,
        config: CoSimConfig,
        source: &CharSource,
    ) -> Result<Self> {
        if n_ranks == 0 || n_ranks > machine.cores {
            return Err(Error::InvalidPlan(format!(
                "{n_ranks} ranks on a {}-core domain",
                machine.cores
            )));
        }
        CoSimEngine::build(
            machine,
            machine,
            program,
            n_ranks,
            config,
            source,
            RankLayout::single(n_ranks),
        )
    }

    /// Build an engine on a multi-domain topology: `placement` assigns the
    /// ranks to ccNUMA domains (compact fills domains in order, scatter
    /// round-robins) and the timeline engine runs one contention timeline
    /// per domain. A full NPS4 Rome socket is
    /// `CoSimEngine::with_topology(&m, &Topology::socket(&m), Placement::Compact, ...)`.
    pub fn with_topology(
        machine: &'a Machine,
        topology: &Topology,
        placement: Placement,
        program: Program,
        n_ranks: usize,
        config: CoSimConfig,
        source: &CharSource,
    ) -> Result<Self> {
        if machine.id != topology.base.id {
            return Err(Error::InvalidPlan(format!(
                "topology {} instantiates {:?}, not {:?}",
                topology.label(),
                topology.base.id,
                machine.id
            )));
        }
        // Characterize on the topology's *base row*: for SNC topologies
        // that is the derived sub-domain row (halved cores and bandwidth),
        // whose cache fingerprint differs from the parent socket's — so
        // `repro hpcg --topology snc2` gets real sub-domain f/b_s instead
        // of being rejected (the pre-fingerprint cache would have served
        // stale socket values here).
        let layout = placement.rank_layout(topology, n_ranks)?;
        CoSimEngine::build(machine, &topology.base, program, n_ranks, config, source, layout)
    }

    /// [`CoSimEngine::with_topology`] plus a uniform remote-access
    /// fraction: every rank sends `remote_frac` of its cache-line stream
    /// to remote ccNUMA domains (inter-socket portions contending on the
    /// machine's QPI/UPI/xGMI links — see [`crate::sharing::remote`]).
    /// `remote_frac = 0` is exactly [`CoSimEngine::with_topology`].
    #[allow(clippy::too_many_arguments)]
    pub fn with_topology_remote(
        machine: &'a Machine,
        topology: &Topology,
        placement: Placement,
        remote_frac: f64,
        program: Program,
        n_ranks: usize,
        config: CoSimConfig,
        source: &CharSource,
    ) -> Result<Self> {
        let mut eng = CoSimEngine::with_topology(
            machine, topology, placement, program, n_ranks, config, source,
        )?;
        eng.layout = eng.layout.clone().with_remote(remote_frac)?;
        Ok(eng)
    }

    /// `char_machine` is the row kernels characterize on — the machine
    /// itself on the flat path, the topology's base row (possibly a
    /// derived SNC sub-domain) on the topology paths.
    fn build(
        machine: &'a Machine,
        char_machine: &Machine,
        program: Program,
        n_ranks: usize,
        config: CoSimConfig,
        source: &CharSource,
        layout: RankLayout,
    ) -> Result<Self> {
        let mut kernels: Vec<KernelId> = program
            .phases
            .iter()
            .filter_map(|p| match p {
                Phase::Kernel { kernel, .. } => Some(*kernel),
                _ => None,
            })
            .collect();
        kernels.sort_by_key(|k| k.key());
        kernels.dedup();
        let measured = CharCache::global().characterize_source(char_machine, &kernels, source)?;
        let chars: HashMap<KernelId, (f64, f64)> = measured
            .into_iter()
            .map(|(k, m)| (k, (m.f, m.bs_gbs)))
            .collect();
        Ok(CoSimEngine { machine, program, n_ranks, config, chars, layout })
    }

    /// The characterizations in deterministic (kernel-key) slot order.
    fn chars_dense(&self) -> Vec<(KernelId, f64, f64)> {
        let mut out: Vec<(KernelId, f64, f64)> = self
            .chars
            .iter()
            .map(|(k, &(f, bs))| (*k, f, bs))
            .collect();
        out.sort_by_key(|c| c.0.key());
        out
    }

    /// Run the co-simulation on the event-driven timeline engine (one
    /// contention timeline per ccNUMA domain of the layout).
    pub fn run(&self) -> CoSimResult {
        timeline::simulate_placed(
            &self.program,
            self.n_ranks,
            &self.config,
            &self.chars_dense(),
            &self.layout,
        )
    }

    /// Run with the full-recompute rating reference
    /// ([`timeline::RatingMode::FullRecompute`]): every refresh re-rates
    /// every node. Pinned bit-identical to [`CoSimEngine::run`]; exists so
    /// `repro bench` can measure the incremental path's speedup.
    pub fn run_full_recompute(&self) -> CoSimResult {
        timeline::simulate_placed_mode(
            &self.program,
            self.n_ranks,
            &self.config,
            &self.chars_dense(),
            &self.layout,
            timeline::RatingMode::FullRecompute,
        )
    }

    /// Run the legacy fixed-`dt` stepper (golden reference; tests and the
    /// `legacy-stepper` feature only). The stepper predates the topology
    /// layer and models a single contention domain.
    #[cfg(any(test, feature = "legacy-stepper"))]
    pub fn run_legacy(&self) -> CoSimResult {
        assert!(self.layout.is_single(), "legacy stepper is single-domain only");
        crate::desync::legacy::run_stepped(&self.program, self.n_ranks, &self.config, &self.chars)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::desync::program::{hpcg_program, HpcgVariant};
    use crate::scenario::EngineKind;

    fn small_config() -> CoSimConfig {
        CoSimConfig { dt_s: 50e-6, t_max_s: 600.0, ..Default::default() }
    }

    #[test]
    fn all_ranks_complete_without_noise() {
        let m = machine(MachineId::Rome);
        let prog = hpcg_program(HpcgVariant::Plain, 48, 2);
        let eng = CoSimEngine::new(&m, prog, 4, small_config()).unwrap();
        let r = eng.run();
        assert!(r.finish_s.iter().all(|f| f.is_finite()), "finish: {:?}", r.finish_s);
        // Lockstep start, no noise: ranks stay synchronized through the
        // collectives — the event engine resolves this exactly.
        let min = r.finish_s.iter().cloned().fold(f64::MAX, f64::min);
        let max = r.finish_s.iter().cloned().fold(0.0, f64::max);
        assert!(max - min < 1e-12, "spread {}", max - min);
    }

    #[test]
    fn allreduce_resynchronizes_staggered_start() {
        let m = machine(MachineId::Bdw1);
        let prog = hpcg_program(HpcgVariant::Plain, 48, 2);
        let mut cfg = small_config();
        cfg.initial_stagger_s = 5e-3;
        let eng = CoSimEngine::new(&m, prog, 6, cfg).unwrap();
        let r = eng.run();
        // After the first Allreduce, all ranks leave at the same time —
        // exactly, with event-driven collective releases.
        let recs = r.trace.of("Allreduce#1", Some(0));
        assert_eq!(recs.len(), 6);
        let ends: Vec<f64> = recs.iter().map(|x| x.t_end).collect();
        let spread = ends.iter().cloned().fold(0.0, f64::max)
            - ends.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread.abs() < 1e-15, "collective exit spread {spread}");
    }

    #[test]
    fn trace_contains_all_phases_per_rank() {
        let m = machine(MachineId::Clx);
        let prog = hpcg_program(HpcgVariant::Modified, 32, 1);
        let phases = prog.phases.len();
        let eng = CoSimEngine::new(&m, prog, 5, small_config()).unwrap();
        let r = eng.run();
        assert_eq!(r.trace.records.len(), phases * 5);
    }

    /// The Fig. 3 headline: skewness signs of the DDOT distributions.
    /// DDOT2#1 (tail overlaps halo waits) resynchronizes; DDOT2#2 and
    /// DDOT1 (followed by higher-f DAXPY/WAXPBY) desynchronize.
    #[test]
    fn fig3_skewness_signs() {
        use crate::desync::noise::NoiseModel;
        let m = machine(MachineId::Clx);
        let prog = hpcg_program(HpcgVariant::Modified, 96, 3);
        let cfg = CoSimConfig {
            dt_s: 20e-6,
            t_max_s: 600.0,
            initial_stagger_s: 0.2e-3,
            neighbor_radius: 3,
            noise: NoiseModel::mild(7),
        };
        let eng = CoSimEngine::new(&m, prog, 20, cfg).unwrap();
        let r = eng.run();
        let skew = |label: &str| {
            let d = r.trace.durations_by_rank(label, 1, 20);
            crate::stats::skewness_dimensioned(&d)
        };
        assert!(skew("DDOT2#1") < 0.0, "DDOT2#1 must resynchronize");
        assert!(skew("DDOT2#2") > 0.0, "DDOT2#2 must desynchronize");
        assert!(skew("DDOT1") > 0.0, "DDOT1 must desynchronize");
    }

    #[test]
    fn rejects_too_many_ranks() {
        let m = machine(MachineId::Rome);
        let prog = hpcg_program(HpcgVariant::Plain, 16, 1);
        assert!(CoSimEngine::new(&m, prog, 9, small_config()).is_err());
    }

    #[test]
    fn full_rome_socket_runs_four_domain_timelines() {
        // 32 ranks on the 4-domain NPS4 socket — impossible pre-topology
        // (the single-domain path rejects ranks > 8).
        let m = machine(MachineId::Rome);
        let prog = hpcg_program(HpcgVariant::Plain, 32, 1);
        let topo = Topology::socket(&m);
        let eng = CoSimEngine::with_topology(
            &m,
            &topo,
            Placement::Compact,
            prog,
            32,
            small_config(),
            &CharSource::Ecm,
        )
        .unwrap();
        let r = eng.run();
        assert!(r.finish_s.iter().all(|f| f.is_finite()), "finish: {:?}", r.finish_s);
        // Lockstep start, identical per-domain composition, no noise: the
        // whole socket stays synchronized.
        let min = r.finish_s.iter().cloned().fold(f64::MAX, f64::min);
        let max = r.finish_s.iter().cloned().fold(0.0, f64::max);
        assert!(max - min < 1e-12, "spread {}", max - min);
        // Ranks beyond the socket still fail.
        let prog2 = hpcg_program(HpcgVariant::Plain, 32, 1);
        assert!(CoSimEngine::with_topology(
            &m,
            &topo,
            Placement::Compact,
            prog2,
            33,
            small_config(),
            &CharSource::Ecm,
        )
        .is_err());
    }

    #[test]
    fn remote_cosim_zero_fraction_matches_plain_topology_bitwise() {
        let m = machine(MachineId::Rome);
        let topo = Topology::parse(&m, "2x4").unwrap();
        let prog = hpcg_program(HpcgVariant::Plain, 32, 1);
        let plain = CoSimEngine::with_topology(
            &m,
            &topo,
            Placement::Compact,
            prog.clone(),
            16,
            small_config(),
            &CharSource::Ecm,
        )
        .unwrap();
        let zero = CoSimEngine::with_topology_remote(
            &m,
            &topo,
            Placement::Compact,
            0.0,
            prog.clone(),
            16,
            small_config(),
            &CharSource::Ecm,
        )
        .unwrap();
        let (a, b) = (plain.run(), zero.run());
        assert_eq!(a.trace.records.len(), b.trace.records.len());
        for (x, y) in a.trace.records.iter().zip(&b.trace.records) {
            assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        }
        assert_eq!(a.events, b.events);
        // A nonzero remote fraction completes too, on different timings
        // (the stream splits re-balance every interface).
        let remote = CoSimEngine::with_topology_remote(
            &m,
            &topo,
            Placement::Compact,
            0.5,
            prog,
            16,
            small_config(),
            &CharSource::Ecm,
        )
        .unwrap();
        let r = remote.run();
        assert!(r.finish_s.iter().all(|f| f.is_finite()), "finish: {:?}", r.finish_s);
        assert!((r.finish_s[0] - a.finish_s[0]).abs() > 1e-12);
        // Bad fractions are rejected at construction.
        let prog2 = hpcg_program(HpcgVariant::Plain, 32, 1);
        assert!(CoSimEngine::with_topology_remote(
            &m,
            &topo,
            Placement::Compact,
            1.5,
            prog2,
            16,
            small_config(),
            &CharSource::Ecm,
        )
        .is_err());
    }

    #[test]
    fn single_domain_topology_matches_plain_engine_bitwise() {
        let m = machine(MachineId::Clx);
        let prog = hpcg_program(HpcgVariant::Modified, 32, 1);
        let mut cfg = small_config();
        cfg.initial_stagger_s = 1e-3;
        let plain = CoSimEngine::new(&m, prog.clone(), 6, cfg.clone()).unwrap();
        let topo = Topology::single(&m);
        let placed = CoSimEngine::with_topology(
            &m,
            &topo,
            Placement::Scatter,
            prog,
            6,
            cfg,
            &CharSource::Ecm,
        )
        .unwrap();
        let (a, b) = (plain.run(), placed.run());
        assert_eq!(a.trace.records.len(), b.trace.records.len());
        for (x, y) in a.trace.records.iter().zip(&b.trace.records) {
            assert_eq!(x.rank, y.rank);
            assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        }
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn ecm_characterizations_are_cached_process_wide() {
        let m = machine(MachineId::Bdw2);
        let prog = hpcg_program(HpcgVariant::Modified, 16, 1);
        let eng = CoSimEngine::new(&m, prog.clone(), 3, small_config()).unwrap();
        // Every program kernel now sits in the global cache under the ECM
        // engine kind.
        for k in [KernelId::Ddot2, KernelId::Daxpy, KernelId::Schoenauer] {
            assert!(
                CharCache::global().contains(&(m.fingerprint(), k, EngineKind::Ecm)),
                "{k:?} not cached"
            );
        }
        // A second engine re-uses the cached entries and produces the same
        // characterizations (determinism through the cache).
        let eng2 = CoSimEngine::new(&m, prog, 3, small_config()).unwrap();
        let (a, b) = (eng.chars_dense(), eng2.chars_dense());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits());
            assert_eq!(x.2.to_bits(), y.2.to_bits());
        }
    }

    #[test]
    fn measured_source_differs_from_ecm_but_stays_close() {
        use crate::scenario::MeasureEngine;
        let m = machine(MachineId::Rome);
        let prog = hpcg_program(HpcgVariant::Modified, 24, 1);
        let ecm = CoSimEngine::new(&m, prog.clone(), 4, small_config()).unwrap();
        let fluid = CoSimEngine::with_source(
            &m,
            prog,
            4,
            small_config(),
            &CharSource::Measured(MeasureEngine::Fluid),
        )
        .unwrap();
        let (a, b) = (ecm.chars_dense(), fluid.chars_dense());
        for (x, y) in a.iter().zip(b.iter()) {
            let (k, f_e, bs_e) = *x;
            let (k2, f_f, bs_f) = *y;
            assert_eq!(k, k2);
            // Eq.-3 measurement and the ECM prediction agree to ~8%
            // (conformance suite level) but are not identical.
            assert!((f_e - f_f).abs() / f_e < 0.08, "{k:?}: f {f_e} vs {f_f}");
            assert!((bs_e - bs_f).abs() / bs_e < 0.08, "{k:?}: bs {bs_e} vs {bs_f}");
        }
        // Both engines still complete the program.
        let r = fluid.run();
        assert!(r.finish_s.iter().all(|f| f.is_finite()));
    }
}
