//! Documentation link check: every *relative* markdown link in README.md
//! and docs/*.md must resolve to an existing file or directory. Dangling
//! links are exactly the kind of rot a docs-heavy PR introduces; CI runs
//! this test as its link-check step.

use std::path::{Path, PathBuf};

/// Extract `](target)` link targets from one markdown file.
fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = text.as_bytes();
    let mut i = 0;
    while i + 1 < bytes.len() {
        if bytes[i] == b']' && bytes[i + 1] == b'(' {
            if let Some(end) = text[i + 2..].find(')') {
                out.push(text[i + 2..i + 2 + end].to_string());
                i += 2 + end;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// Whether a link target is a relative filesystem path we should resolve
/// (not a URL, not an intra-page anchor, not an autolink).
fn is_relative(target: &str) -> bool {
    !(target.is_empty()
        || target.starts_with('#')
        || target.contains("://")
        || target.starts_with("mailto:")
        || target.starts_with('<'))
}

#[test]
fn no_dangling_relative_links_in_docs() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut files: Vec<PathBuf> = vec![root.join("README.md")];
    let docs = root.join("docs");
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&docs)
        .expect("docs/ exists")
        .map(|e| e.expect("readable docs entry").path())
        .filter(|p| p.extension().is_some_and(|e| e == "md"))
        .collect();
    entries.sort();
    assert!(!entries.is_empty(), "docs/*.md must exist");
    files.extend(entries);

    let mut dangling: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for file in &files {
        let text = std::fs::read_to_string(file).expect("readable markdown");
        let dir = file.parent().unwrap_or(Path::new("."));
        for raw in link_targets(&text) {
            let target = raw.split(&[' ', '#'][..]).next().unwrap_or("").trim();
            if !is_relative(target) {
                continue;
            }
            checked += 1;
            let resolved = dir.join(target);
            if !resolved.exists() {
                dangling.push(format!("{}: ({})", file.display(), raw));
            }
        }
    }
    assert!(checked > 0, "expected at least one relative link across the docs");
    assert!(dangling.is_empty(), "dangling relative links:\n{}", dangling.join("\n"));
}

#[test]
fn link_extractor_handles_edge_cases() {
    let md = "see [a](docs/MODEL.md), [b](https://x.y/z), [c](#anchor), \
              and [d](missing.md#frag).";
    let targets = link_targets(md);
    assert_eq!(targets, vec!["docs/MODEL.md", "https://x.y/z", "#anchor", "missing.md#frag"]);
    assert!(is_relative("docs/MODEL.md"));
    assert!(!is_relative("https://x.y/z"));
    assert!(!is_relative("#anchor"));
    assert!(is_relative("missing.md#frag"));
}
